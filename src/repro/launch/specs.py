"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

Train cells feed (state, batch, step); decode cells feed
(params, token, cache, cur_len); prefill cells feed (params, tokens[, aux]).
Modality frontends are stubs per the assignment: aux inputs are precomputed
frame/patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import batch_spec, cache_specs
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.lm import init_cache


def _aux_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), dtype)
    if cfg.family == "encdec":
        return jax.ShapeDtypeStruct(
            (batch, cfg.n_audio_frames, cfg.d_model), dtype)
    return None


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    shardings = {
        "tokens": NamedSharding(mesh, batch_spec(mesh, b, 1)),
        "labels": NamedSharding(mesh, batch_spec(mesh, b, 1)),
    }
    aux = _aux_spec(cfg, b)
    if aux is not None:
        batch["aux"] = aux
        shardings["aux"] = NamedSharding(mesh, batch_spec(mesh, b, 2))
    return batch, shardings


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       cache_dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cur_len = jax.ShapeDtypeStruct((b,), jnp.int32)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, dtype=cache_dtype))
    cache_sh = jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        cache_specs(cfg, cache, mesh))
    tok_sh = NamedSharding(mesh, batch_spec(mesh, b, 1))
    len_sh = NamedSharding(mesh, batch_spec(mesh, b, 0))
    return (token, cache, cur_len), (tok_sh, cache_sh, len_sh)


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    b, s = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
    sh = {"tokens": NamedSharding(mesh, batch_spec(mesh, b, 1))}
    batch = {"tokens": tokens}
    aux = _aux_spec(cfg, b)
    if aux is not None:
        batch["aux"] = aux
        sh["aux"] = NamedSharding(mesh, batch_spec(mesh, b, 2))
    return batch, sh

"""Production meshes (DESIGN.md §5).

Defined as functions so importing this module never touches jax device state.
Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; 'pod' is DP by default
(or the pipeline axis with --pipeline).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "run under launch/dryrun.py (it sets "
            "--xla_force_host_platform_device_count=512)")
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh():
    """1-device mesh with the production axis names (tests, examples)."""
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (the two lines above MUST run before any jax-importing module)
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell and record memory/cost/collective
artifacts for the roofline analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod/--singlepod]
  PYTHONPATH=src python -m repro.launch.dryrun --pipeline   # PP compile check

Artifacts: .cache/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import (
    batch_spec, param_sharding, sharding_rules)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    decode_input_specs, prefill_input_specs, train_input_specs)
from repro.models import abstract_model, model_specs, shapes_for
from repro.models.config import ShapeConfig
from repro.models.lm import decode_step, prefill
from repro.training.optimizer import AdamWConfig, adamw_init, opt_state_specs
from repro.training.train_loop import TrainConfig, build_train_step

OUT_DIR = os.path.join(os.environ.get("REPRO_CACHE", ".cache"), "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "pred": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    op_re = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")
    for m in op_re.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(type_str):
            if dt not in _DTYPE_BYTES:
                continue
            numel = 1
            for d in dims.split(","):
                if d:
                    numel *= int(d)
            nbytes += numel * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _accum_for(cfg) -> int:
    if cfg.d_model >= 7000 or cfg.n_layers >= 90:
        return 8
    if cfg.d_model >= 2560:
        return 4
    return 1


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    out = {}
    for f in fields:
        try:
            out[f] = int(getattr(ma, f))
        except Exception:
            pass
    return out


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def run_cell(arch: str, shape: ShapeConfig, *, multi_pod: bool,
             save_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # decode serves read-only weights: replicate over dp instead of ZeRO-3
    # (kills per-token weight all-gathers — §Perf iteration 4). Archs whose
    # replicated params would blow the 16 GiB budget (llama-90B dense) keep
    # FSDP and pay the gathers — the policy is capacity-aware.
    mode = "train"
    if shape.kind == "decode":
        from repro.models.accounting import local_param_bytes
        from repro.distributed.sharding import mesh_axis_sizes

        serve_bytes = local_param_bytes(
            cfg, mesh_axis_sizes(mesh), mode="serve")
        mode = "serve" if serve_bytes < 9 * 2**30 else "train"
    rules = sharding_rules(mesh, mode=mode)
    record_mode = mode
    pspecs = model_specs(cfg, rules)
    psh = param_sharding(pspecs, mesh)
    params_abs = abstract_model(cfg, jnp.bfloat16)
    record = {
        "arch": arch, "shape": shape.name, "kind": shape.kind,
        "param_mode": record_mode if shape.kind == "decode" else "train",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    t0 = time.time()

    if shape.kind == "train":
        tc = TrainConfig(accum_steps=_accum_for(cfg),
                         accum_dtype="bfloat16",
                         opt=AdamWConfig(quantize_moments=True))
        record["accum_steps"] = tc.accum_steps
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, cfg=tc.opt), params_abs)
        ospecs = opt_state_specs(pspecs, tc.opt, params_abs)
        osh = param_sharding(ospecs, mesh)
        state_abs = {"params": params_abs, "opt": opt_abs}
        state_sh = {"params": psh, "opt": osh}
        batch_abs, batch_sh = train_input_specs(cfg, shape, mesh)
        step = build_train_step(cfg, tc)
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh, None),
            out_shardings=(state_sh, None),
            donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(
                state_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
    elif shape.kind == "decode":
        (token, cache, cur_len), (tok_sh, cache_sh, len_sh) = \
            decode_input_specs(cfg, shape, mesh)
        fn = functools.partial(decode_step, cfg=None)  # placeholder

        def serve_step(params, tok, cch, cl):
            return decode_step(params, cfg, tok, cch, cl)

        jitted = jax.jit(
            serve_step,
            in_shardings=(psh, tok_sh, cache_sh, len_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(params_abs, token, cache, cur_len)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        batch_abs, batch_sh = prefill_input_specs(cfg, shape, mesh)
        out_spec = NamedSharding(
            mesh, P(batch_spec(mesh, shape.global_batch, 0)[0], None,
                    "model" if cfg.d_model % 16 == 0 else None))

        def prefill_step(params, batch):
            return prefill(params, cfg, batch["tokens"], batch.get("aux"))

        jitted = jax.jit(prefill_step, in_shardings=(psh, batch_sh),
                         out_shardings=out_spec)
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
            compiled = lowered.compile()
    else:
        raise ValueError(shape.kind)

    record["compile_seconds"] = round(time.time() - t0, 1)
    record["memory"] = _memory_analysis(compiled)
    record["cost"] = _cost_analysis(compiled)
    hlo = compiled.as_text()
    record["collectives"] = collective_bytes(hlo)
    record["hlo_bytes"] = len(hlo)
    # always keep the optimized HLO: the roofline analyzer re-walks it with
    # while-loop trip counts (XLA cost analysis counts loop bodies once)
    import gzip

    hdir = os.path.join(OUT_DIR, "hlo")
    os.makedirs(hdir, exist_ok=True)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    with gzip.open(os.path.join(
            hdir, f"{arch}__{shape.name}__{mesh_name}.txt.gz"), "wt") as f:
        f.write(hlo)
    print(f"[dryrun] {arch} {shape.name} mesh={record['mesh']} "
          f"compile={record['compile_seconds']}s "
          f"flops={record['cost'].get('flops', float('nan')):.3g} "
          f"coll={record['collectives']['total_bytes']:.3g}B")
    mem = record["memory"]
    if mem:
        print(f"  memory: args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"out={mem.get('output_size_in_bytes', 0)/2**30:.2f}GiB")
    return record


def run_pipeline_check(multi_pod: bool = True) -> dict:
    """PP-over-pod compile check on qwen2-0.5b (DESIGN.md §5)."""
    from repro.distributed.pipeline import pipeline_forward
    from jax.experimental.shard_map import shard_map
    from repro.models.blocks import stage_forward, superblock_table

    cfg = get_config("qwen2-0.5b")
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_stages = 2
    table, kinds, n_rep, _ = superblock_table(cfg)
    params_abs = abstract_model(cfg, jnp.bfloat16)
    blocks = params_abs["blocks"]
    staged = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(
            (n_stages, l.shape[0] // n_stages) + l.shape[1:], l.dtype),
        blocks)

    def stage_fn(p_stage, x):
        h, _ = stage_forward(p_stage, None, cfg, kinds, x)
        return h

    n_micro, bm, s = 4, 8, 4096
    x_micro = jax.ShapeDtypeStruct((n_micro, bm, s, cfg.d_model),
                                   jnp.bfloat16)
    run = pipeline_forward(stage_fn, n_stages, axis="pod")
    spec_p = jax.tree_util.tree_map(lambda _: P("pod"), staged)
    fn = shard_map(run, mesh=mesh, in_specs=(spec_p, P()), out_specs=P(),
                   check_rep=False)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(staged, x_micro)
        compiled = lowered.compile()
    rec = {"arch": "qwen2-0.5b", "shape": "pipeline_pp2", "kind": "pipeline",
           "mesh": "2x16x16", "compile_seconds": round(time.time() - t0, 1),
           "memory": _memory_analysis(compiled),
           "cost": _cost_analysis(compiled),
           "collectives": collective_bytes(compiled.as_text())}
    print(f"[dryrun] pipeline pp2 compile={rec['compile_seconds']}s "
          f"coll={rec['collectives']['total_bytes']:.3g}B")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--singlepod", action="store_true")
    ap.add_argument("--pipeline", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    meshes = []
    if args.singlepod or not args.multipod:
        meshes.append(False)
    if args.multipod or not args.singlepod:
        meshes.append(True)

    if args.pipeline:
        rec = run_pipeline_check()
        with open(os.path.join(OUT_DIR, "pipeline_pp2.json"), "w") as f:
            json.dump(rec, f, indent=1)
        return

    cells = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = []
    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        path = os.path.join(
            OUT_DIR, f"{arch}__{shape.name}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {path}")
            continue
        try:
            rec = run_cell(arch, shape, multi_pod=mp)
            with open(path + ".tmp", "w") as f:
                json.dump(rec, f, indent=1)
            os.replace(path + ".tmp", path)
        except Exception as e:
            failures.append((arch, shape.name, mesh_name, repr(e)))
            traceback.print_exc()
        jax.clear_caches()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells compiled")


if __name__ == "__main__":
    main()

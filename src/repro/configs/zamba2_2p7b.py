"""zamba2-2.7b [hybrid]: Mamba2 backbone + one shared attention block applied
every 6 layers (weight-tied). [arXiv:2411.15242; hf]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_state=64, version=2, expand=2, head_dim=64, chunk=32),
    attn_every=6,
    rope_theta=10000.0,
    supports_long_context=True,   # hybrid: run long_500k
)

"""Architecture registry: the ten assigned configs (--arch <id>)."""

from repro.configs.zamba2_2p7b import CONFIG as ZAMBA2
from repro.configs.granite_20b import CONFIG as GRANITE
from repro.configs.yi_34b import CONFIG as YI
from repro.configs.deepseek_coder_33b import CONFIG as DEEPSEEK
from repro.configs.qwen2_0p5b import CONFIG as QWEN2
from repro.configs.llama_3p2_vision_90b import CONFIG as LLAMA_VISION
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4
from repro.configs.qwen3_moe_30b import CONFIG as QWEN3
from repro.configs.seamless_m4t_large import CONFIG as SEAMLESS
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA

ARCHS = {c.name: c for c in (
    ZAMBA2, GRANITE, YI, DEEPSEEK, QWEN2, LLAMA_VISION, LLAMA4, QWEN3,
    SEAMLESS, FALCON_MAMBA)}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; one of {sorted(ARCHS)}")
    return ARCHS[name]

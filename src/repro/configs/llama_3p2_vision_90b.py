"""llama-3.2-vision-90b [vlm]: 100L backbone, gated cross-attention image
layers every 5; the vision frontend is a STUB — input_specs() provides
pre-projected patch embeddings [B, n_image_tokens, d_model].
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=128256,
    cross_attn_every=5,
    n_image_tokens=1601,
    rope_theta=500000.0,
)

"""seamless-m4t-large-v2 [audio]: encoder-decoder; the speech frontend is a
STUB — input_specs() provides precomputed frame embeddings
[B, n_audio_frames, d_model]. [arXiv:2308.11596; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                     # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256206,                    # padded to 256512 internally
    n_audio_frames=4096,
    rope_theta=10000.0,
)

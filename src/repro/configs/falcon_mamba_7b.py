"""falcon-mamba-7b [ssm]: pure Mamba1, attention-free.
[arXiv:2410.05355; unverified]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                       # unused (attention-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(d_state=16, version=1, expand=2, chunk=64),
    supports_long_context=True,      # SSM: run long_500k
)

"""llama4-maverick-400b-a17b [moe]: alternating dense/MoE layers, 128 experts
top-1 + shared expert, early-fusion multimodal (frontend stubbed).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,                       # dense (non-MoE) interleaved layers
    vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, interleave=2,
                  d_ff_shared=8192),
    rope_theta=500000.0,
)

"""Train-step builder + fault-tolerant host loop.

The step is a single jit with donated state: microbatched grad accumulation
(lax.scan; bf16 or fp32 accumulation buffer — bf16 is what fits llama4 on a
single pod, DESIGN.md §5), AdamW (optionally 8-bit moments), warmup-cosine LR.

The host ``Trainer`` provides the large-scale operational posture at
laptop scale: auto-resume from the latest valid checkpoint, async
checkpointing, heartbeat file + straggler watchdog (step time > factor x
rolling median -> warning callback), SIGTERM preemption handling (checkpoint
+ clean exit), and deterministic data replay (E11).
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import train_loss
from repro.training.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, restore_checkpoint)
from repro.training.data import SyntheticLoader
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 100
    accum_steps: int = 1
    accum_dtype: str = "float32"      # float32 | bfloat16
    peak_lr: float = 3e-4
    warmup_steps: int = 20
    checkpoint_dir: str = ""
    checkpoint_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    opt: AdamWConfig = AdamWConfig()


def _split_accum(batch, accum: int):
    def r(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape((accum, b // accum) + x.shape[1:])

    return jax.tree_util.tree_map(r, batch)


def build_train_step(model_cfg, tc: TrainConfig):
    """Returns step(state, batch, step_idx) -> (state, metrics)."""

    def loss_fn(params, mb):
        return train_loss(params, model_cfg, mb)

    def step(state, batch, step_idx):
        lr = warmup_cosine(step_idx, peak_lr=tc.peak_lr,
                           warmup_steps=tc.warmup_steps,
                           total_steps=tc.total_steps)
        params = state["params"]
        if tc.accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            stacked = _split_accum(batch, tc.accum_steps)
            acc_dt = jnp.dtype(tc.accum_dtype)

            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, l

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, losses = jax.lax.scan(micro, zeros, stacked)
            grads = jax.tree_util.tree_map(
                lambda g: g / tc.accum_steps, grads)
            loss = losses.mean()
        new_params, new_opt = adamw_update(grads, state["opt"], params,
                                           tc.opt, lr)
        metrics = {"loss": loss.astype(jnp.float32), "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def init_train_state(model_cfg, tc: TrainConfig, key, dtype=jnp.float32):
    from repro.models.lm import init_model

    params = init_model(model_cfg, key, dtype)
    return {"params": params, "opt": adamw_init(params, tc.opt)}


def build_sparse_ffn_train_step(ffn, *, lr: float = 1e-3,
                                opt: AdamWConfig = AdamWConfig(),
                                loss_fn=None):
    """Jitted sparse-FFN training step with SpGEMM inside the trace.

    ``ffn`` is a :class:`~repro.models.sparse_ffn.SparseFFN` whose matmuls
    run the differentiable spgemm path (``from_params(..., path="spgemm")``,
    DESIGN.md §10).  Returns ``(step, state)`` where ``step(state, (x, y))
    -> (state, metrics)`` is a single ``jax.jit``: forward (three SpGEMM
    device-stream replays per token block), loss (MSE by default; pass
    ``loss_fn(pred, y)`` to override), reverse pass (each stream's custom
    vjp — two more replays through the same frozen indices), and an AdamW
    update of the sparse weight *values*.  The weight patterns are static,
    so the first call per activation shape plans + traces once and every
    later step is a compiled replay — zero per-step Python plan traversal.
    """
    params = ffn.trainable_params()
    state = {"params": params, "opt": adamw_init(params, opt)}
    loss_fn = loss_fn or (lambda pred, y: jnp.mean((pred - y) ** 2))

    def objective(p, batch):
        x, y = batch
        return loss_fn(ffn.apply(p, x), y)

    @jax.jit
    def step(state, batch):
        loss, grads = jax.value_and_grad(objective)(state["params"], batch)
        new_params, new_opt = adamw_update(grads, state["opt"],
                                           state["params"], opt, lr)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss.astype(jnp.float32)})

    return step, state


class Trainer:
    """Host loop with the fault-tolerance drill (E11)."""

    def __init__(self, model_cfg, tc: TrainConfig, loader: SyntheticLoader,
                 state, *, jit_step=None, on_warning: Optional[Callable] = None,
                 prepare_batch=None):
        self.model_cfg = model_cfg
        self.tc = tc
        self.loader = loader
        self.state = state
        self.step_idx = 0
        self.on_warning = on_warning or (lambda msg: print(f"[warn] {msg}"))
        self.prepare_batch = prepare_batch or (lambda b: b)
        self._step = jit_step or jax.jit(
            build_train_step(model_cfg, tc), donate_argnums=(0,))
        self._ckpt = (AsyncCheckpointer(tc.checkpoint_dir)
                      if tc.checkpoint_dir else None)
        self._durations: list[float] = []
        self._preempted = False
        self.metrics_log: list[dict] = []

    # -- fault tolerance ---------------------------------------------------

    def install_preemption_handler(self, sig=signal.SIGTERM):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(sig, handler)

    def try_resume(self) -> bool:
        if not self.tc.checkpoint_dir:
            return False
        path = latest_checkpoint(self.tc.checkpoint_dir)
        if path is None:
            return False
        self.state, step, extra = restore_checkpoint(path, self.state)
        self.step_idx = step
        self.loader = SyntheticLoader.restore(
            self.loader.cfg, extra.get("data", {"step": step,
                                                "seed": self.loader.cfg.seed}))
        print(f"[resume] restored step {step} from {path}")
        return True

    def _heartbeat(self):
        if not self.tc.checkpoint_dir:
            return
        os.makedirs(self.tc.checkpoint_dir, exist_ok=True)
        hb = os.path.join(self.tc.checkpoint_dir, "heartbeat.json")
        with open(hb, "w") as f:
            json.dump({"step": self.step_idx, "time": time.time()}, f)

    def _watchdog(self, dt: float):
        self._durations.append(dt)
        hist = self._durations[-50:]
        if len(hist) >= 10:
            med = float(np.median(hist[:-1]))
            if dt > self.tc.straggler_factor * med:
                self.on_warning(
                    f"straggler: step {self.step_idx} took {dt:.2f}s "
                    f"(median {med:.2f}s)")

    def checkpoint(self):
        if self._ckpt:
            self._ckpt.save(self.step_idx, self.state,
                            extra={"data": self.loader.state()})

    # -- the loop ------------------------------------------------------------

    def run(self, n_steps: int | None = None) -> list[dict]:
        end = self.tc.total_steps if n_steps is None \
            else self.step_idx + n_steps
        while self.step_idx < end and not self._preempted:
            batch = self.prepare_batch(next(self.loader))
            t0 = time.perf_counter()
            self.state, metrics = self._step(
                self.state, batch, jnp.int32(self.step_idx))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step_idx += 1
            self._watchdog(dt)
            self._heartbeat()
            metrics.update(step=self.step_idx, sec=dt)
            self.metrics_log.append(metrics)
            if self.step_idx % self.tc.log_every == 0:
                print(f"step {self.step_idx:5d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} {dt*1e3:.0f}ms")
            if (self.tc.checkpoint_every
                    and self.step_idx % self.tc.checkpoint_every == 0):
                self.checkpoint()
        if self._preempted:
            print("[preempt] saving final checkpoint")
            self.checkpoint()
        if self._ckpt:
            self._ckpt.wait()
        return self.metrics_log

"""Deterministic synthetic token pipeline.

Stateless by construction: batch ``i`` is a pure function of (seed, i), so
resume-after-failure replays the exact stream from the checkpointed step with
no iterator state to persist (E11). Sharding: the host materializes only its
slice when ``process_count > 1``; in this single-process environment it
materializes the global batch and device_put's with the batch sharding.

The synthetic LM task is learnable (examples/train_lm.py drives loss down):
each sequence interleaves affine-map segments t_{i+1} = (a*t_i + b) mod V
with uniform-noise tokens, so a model can learn the deterministic bigram
structure but not memorize sequences.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    n_maps: int = 8           # distinct affine maps (sub-languages)


def synth_batch(cfg: DataConfig, step: int) -> dict:
    """{'tokens': [B,S] int32, 'labels': [B,S] int32} for this step."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    maps_a = 1 + 2 * rng.integers(1, max(v // 7, 2), size=cfg.n_maps)
    maps_b = rng.integers(0, v, size=cfg.n_maps)
    which = rng.integers(0, cfg.n_maps, size=b)
    a = maps_a[which][:, None]
    bb = maps_b[which][:, None]
    toks = np.empty((b, s + 1), np.int64)
    toks[:, 0] = rng.integers(0, v, size=b)
    for i in range(s):
        toks[:, i + 1] = (a[:, 0] * toks[:, i] + bb[:, 0]) % v
    noise_mask = rng.uniform(size=(b, s + 1)) < cfg.noise
    noise_tok = rng.integers(0, v, size=(b, s + 1))
    toks = np.where(noise_mask, noise_tok, toks)
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


class SyntheticLoader:
    """Iterator facade with explicit step addressing (resumable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        batch = synth_batch(self.cfg, self.step)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict) -> "SyntheticLoader":
        assert state["seed"] == cfg.seed, "data seed mismatch on resume"
        return cls(cfg, start_step=state["step"])

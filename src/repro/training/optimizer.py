"""AdamW with optional 8-bit block-quantized moments.

The quantized variant stores both Adam moments as int8 with per-row fp32
absmax scales (last-axis granularity), preserving each tensor's shape — so
moment shards inherit the parameter's PartitionSpec and FSDP placement. This
is the distributed-optimization trick that fits llama4-maverick's 400B
parameters on a 256-chip pod (DESIGN.md §5): 2 (bf16 param) + 2x1 (int8
moments) + scales ~= 4.1 bytes/param of persistent state.

Tensors with < 2 dims (norm scales, biases) keep fp32 moments — negligible
memory, avoids degenerate scale shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantize_moments: bool = False
    grad_clip: float = 1.0


def _quantizable(x) -> bool:
    return x.ndim >= 2


def _quantize(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def adamw_init(params, cfg: AdamWConfig):
    def moment(p):
        if cfg.quantize_moments and _quantizable(p):
            z = jnp.zeros(p.shape, jnp.int8)
            s = jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
            return {"q": z, "scale": s}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(moment, params),
        "v": jax.tree_util.tree_map(moment, params),
    }


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, lr):
    """Returns (new_params, new_opt_state). ``lr`` may be a traced scalar."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequantize(m["q"], m["scale"]) if isinstance(m, dict) else m
        v_f = _dequantize(v["q"], v["scale"]) if isinstance(v, dict) else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        v_f = jnp.maximum(v_f, 0.0)  # quantization can ring slightly negative
        m_hat = m_f / (1 - cfg.b1 ** step.astype(jnp.float32))
        v_hat = v_f / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if isinstance(m, dict):
            mq, ms = _quantize(m_f)
            vq, vs = _quantize(v_f)
            return new_p, {"q": mq, "scale": ms}, {"q": vq, "scale": vs}
        return new_p, m_f, v_f

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}


def opt_state_specs(param_specs, cfg: AdamWConfig, params_abstract):
    """PartitionSpec tree for the optimizer state (mirrors params)."""
    from jax.sharding import PartitionSpec as P

    def moment_spec(spec, p):
        if cfg.quantize_moments and p.ndim >= 2:
            return {"q": spec, "scale": spec}  # scale: last dim is 1 (=None)
        return spec

    def scale_fix(spec, p):
        # scale tensors have last dim 1 -> drop that axis from the spec
        if cfg.quantize_moments and p.ndim >= 2:
            q = spec
            s = P(*(list(spec)[:-1] + [None])) if len(spec) else spec
            return {"q": q, "scale": s}
        return spec

    m = jax.tree_util.tree_map(scale_fix, param_specs, params_abstract,
                               is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "m": m, "v": m}

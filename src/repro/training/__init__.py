"""Training substrate: optimizer, schedules, data, checkpointing, loop."""

from repro.training.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, restore_checkpoint,
    save_checkpoint,
)
from repro.training.data import DataConfig, SyntheticLoader, synth_batch
from repro.training.optimizer import (
    AdamWConfig, adamw_init, adamw_update, opt_state_specs,
)
from repro.training.schedule import constant, warmup_cosine
from repro.training.train_loop import (
    TrainConfig, Trainer, build_train_step, init_train_state,
)

__all__ = [
    "AsyncCheckpointer", "latest_checkpoint", "restore_checkpoint",
    "save_checkpoint", "DataConfig", "SyntheticLoader", "synth_batch",
    "AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
    "constant", "warmup_cosine", "TrainConfig", "Trainer",
    "build_train_step", "init_train_state",
]

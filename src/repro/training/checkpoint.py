"""Fault-tolerant checkpointing: atomic, async, checksummed, re-shardable.

Layout: <dir>/step_<N>/ containing one .npy per pytree leaf (path-encoded
filenames) + manifest.json {leaf -> {file, shape, dtype, crc32}}. Writes go
to a temp directory first and are os.replace'd into place, so readers never
observe a partial checkpoint; the manifest checksum catches torn files after
hard crashes (E11).

Restore is *elastic*: leaves are loaded on host and device_put with whatever
sharding the (possibly different) restore-time mesh dictates, so a job can
come back on a smaller/larger slice.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[key] = leaf
    return out


def _unflatten_into(template, loaded: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in loaded:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = loaded[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        treedef, [l for _, l in zip(flat, leaves)])


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None,
                    keep: int = 3) -> str:
    """Blocking atomic save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    host = {k: np.asarray(jax.device_get(v))
            for k, v in _flatten(tree).items()}
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (key, arr) in enumerate(sorted(host.items())):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(directory, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host on the caller thread, write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree, extra=None):
        self.wait()
        host = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host, extra, self.keep)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            if best is None or int(m.group(1)) > best[0]:
                best = (int(m.group(1)), os.path.join(directory, name))
    return best[1] if best else None


def restore_checkpoint(path: str, template, shardings=None,
                       verify: bool = True):
    """Load into ``template``'s structure; device_put per-leaf ``shardings``
    (same structure) if given — this is the elastic re-shard path."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    loaded = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch in {path}:{key}")
        loaded[key] = arr
    tree = _unflatten_into(template, loaded)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["step"], manifest.get("extra", {})


def _gc(directory: str, keep: int):
    ckpts = sorted(
        (name for name in os.listdir(directory)
         if re.fullmatch(r"step_\d+", name)))
    for name in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)

"""Offline stand-ins for the paper's 40 SuiteSparse matrices (Table 1).

The SuiteSparse collection is not downloadable in this environment, so we encode
the *published per-matrix statistics* from Table 1 (size, NNZ, min/max/avg/var of
nnz-per-column, min/max/avg/var of multiplications-per-column for C = A·A) and
synthesize matrices that match them:

1. exact n, NNZ, min/max column degree, column-degree variance (iterative
   pairwise-transfer repair on the degree sequence);
2. approximate multiplications-per-column stats via a degree-weighted row-
   sampling exponent beta fitted so that E[deg(row)] per stored element matches
   ``mult_avg / nnz_avg`` (assortativity tuning).

``synthesize_suitesparse`` returns the matrix plus its achieved stats so the
benchmark can print achieved-vs-published columns. The paper's reported speedups
are stored alongside for Table-1 validation.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.format import CSC
from repro.sparse.stats import matrix_stats, MatrixStats


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    n: int
    nnz: int
    nnz_min: int
    nnz_max: int
    nnz_avg: float
    nnz_var: float
    mult_min: int
    mult_max: int
    mult_avg: float
    mult_var: float
    spa_seconds: float
    # paper speedups vs SPA: (spars_16_64, spars_40_40, hspa_16_64, hspa_40_40,
    #                         hash_32_256, hash_256_256, hhash_32_256,
    #                         hhash_256_256, esc)
    paper_speedups: tuple


def _spec(name, n, nnz, zmin, zmax, zavg, zvar, mmin, mmax, mavg, mvar, spa, *sp):
    assert len(sp) == 9
    return MatrixSpec(
        name, n, nnz, zmin, zmax, zavg, zvar, mmin, mmax, mavg, mvar, spa, tuple(sp)
    )


# Table 1, transcribed. Columns: name, Size, #NNZ, nnz/col (min,max,avg,var),
# mult/col (min,max,avg,var), SPA seconds, 9 speedup columns.
SUITESPARSE_TABLE1: tuple = (
    _spec("poli", 4008, 8188, 1, 15, 2.04, 0.46, 1, 38, 3.92, 5.83, 1.50e-1,
          2.10, 2.22, 2.10, 2.21, 4.21, 3.83, 4.20, 3.83, 0.95),
    _spec("S40PI_n1", 2028, 5007, 0, 8, 2.47, 0.30, 0, 25, 6.39, 1.50, 8.69e-2,
          2.05, 2.05, 2.05, 2.04, 3.63, 3.30, 3.61, 3.28, 0.70),
    _spec("Kohonen", 4470, 12731, 0, 51, 2.85, 10.20, 0, 221, 11.88, 238.58, 2.32e-1,
          1.17, 1.21, 1.19, 1.26, 1.22, 1.27, 1.37, 1.69, 0.54),
    _spec("Hamrle2", 5952, 22162, 2, 8, 3.72, 3.42, 4, 40, 14.07, 82.28, 3.78e-1,
          1.29, 1.42, 1.29, 1.42, 2.26, 2.31, 2.25, 2.32, 0.59),
    _spec("bp_0", 822, 3276, 1, 20, 3.99, 10.43, 1, 107, 14.18, 272.39, 4.97e-2,
          1.33, 1.46, 1.41, 1.49, 1.26, 1.05, 1.43, 1.43, 0.54),
    _spec("barth4", 6019, 23492, 2, 10, 3.90, 0.68, 4, 51, 14.91, 22.04, 3.79e-1,
          1.36, 1.48, 1.36, 1.48, 2.27, 2.29, 2.28, 2.33, 0.57),
    _spec("oscil_dcop_30", 430, 1544, 1, 13, 3.59, 2.33, 1, 60, 15.00, 65.90, 2.43e-2,
          1.33, 1.45, 1.35, 1.51, 1.23, 1.13, 1.32, 1.42, 0.50),
    _spec("rw5151", 5151, 20199, 1, 4, 3.92, 0.11, 2, 16, 15.49, 3.148, 3.09e-1,
          1.32, 1.40, 1.32, 1.40, 2.20, 2.21, 2.19, 2.21, 0.53),
    _spec("olm1000", 1000, 3996, 3, 4, 4.00, 0.00, 10, 16, 15.97, 0.15, 5.39e-2,
          1.55, 1.48, 1.55, 1.48, 2.15, 2.18, 2.12, 2.16, 0.51),
    _spec("tub1000", 1000, 3996, 3, 4, 4.00, 0.00, 10, 16, 15.97, 0.15, 5.80e-2,
          1.68, 1.60, 1.68, 1.60, 2.29, 2.33, 2.28, 2.32, 0.56),
    _spec("bcspwr09", 1723, 6511, 2, 15, 3.78, 3.02, 5, 80, 17.30, 102.80, 1.10e-1,
          1.30, 1.38, 1.30, 1.37, 1.39, 1.57, 1.42, 1.77, 0.48),
    _spec("saylr3", 1000, 3750, 1, 7, 3.75, 4.06, 1, 42, 18.13, 166.59, 6.00e-2,
          1.25, 1.38, 1.26, 1.36, 1.66, 2.03, 1.63, 1.92, 0.53),
    _spec("sherman4", 1104, 3786, 1, 7, 3.43, 6.40, 1, 47, 18.16, 332.27, 5.77e-2,
          1.17, 1.23, 1.17, 1.20, 1.33, 1.53, 1.30, 1.42, 0.35),
    _spec("gh1484", 1484, 6110, 2, 13, 4.12, 2.56, 5, 68, 19.51, 94.54, 9.71e-2,
          1.28, 1.34, 1.28, 1.33, 1.38, 1.49, 1.40, 1.67, 0.43),
    _spec("shyy41", 4720, 20042, 1, 6, 4.25, 1.63, 2, 36, 19.62, 129.92, 3.12e-1,
          1.26, 1.38, 1.26, 1.38, 2.16, 2.23, 2.16, 2.23, 0.48),
    _spec("rajat03", 7602, 32653, 1, 52, 4.29, 1.26, 3, 303, 19.71, 51.70, 5.15e-1,
          1.19, 1.27, 1.22, 1.33, 1.98, 1.40, 2.16, 2.18, 0.48),
    _spec("young3c", 841, 4089, 3, 5, 4.74, 0.21, 11, 25, 22.51, 11.03, 5.85e-2,
          1.40, 1.38, 1.40, 1.38, 1.99, 2.12, 2.00, 2.12, 0.49),
    _spec("sherman3", 5005, 20033, 1, 7, 4.00, 7.09, 1, 49, 23.11, 411.19, 3.36e-1,
          1.00, 1.10, 1.09, 1.12, 1.64, 1.83, 1.34, 1.40, 0.42),
    _spec("dw1024", 2048, 10114, 3, 8, 4.94, 0.26, 11, 49, 24.54, 17.05, 1.52e-1,
          1.26, 1.23, 1.25, 1.22, 1.79, 1.84, 1.82, 1.84, 0.41),
    _spec("rdb1250", 1250, 7300, 4, 6, 5.84, 0.15, 18, 36, 34.25, 14.17, 1.07e-1,
          1.21, 1.17, 1.21, 1.17, 1.64, 1.63, 1.63, 1.63, 0.33),
    _spec("tols1090", 663, 1712, 1, 22, 3.25, 25.97, 1, 471, 38.00, 13361.58, 7.30e-2,
          0.92, 0.79, 1.36, 1.36, 0.70, 0.35, 1.52, 1.52, 0.25),
    _spec("fpga_dcop_05", 1220, 5852, 1, 36, 4.80, 20.44, 7, 164, 38.12, 427.76,
          1.09e-1, 0.95, 1.00, 1.03, 1.06, 0.90, 0.84, 1.05, 1.15, 0.32),
    _spec("watt_1", 1856, 11360, 2, 7, 6.12, 1.67, 6, 49, 39.37, 125.89, 1.72e-1,
          1.08, 1.08, 1.05, 1.05, 1.36, 1.39, 1.14, 1.13, 0.35),
    _spec("saylr4", 3564, 22316, 3, 7, 6.26, 0.56, 13, 49, 39.76, 52.96, 3.55e-1,
          0.93, 1.02, 0.98, 1.02, 1.48, 1.61, 1.16, 1.20, 0.37),
    _spec("orsreg_1", 2205, 14133, 4, 7, 6.41, 0.41, 19, 49, 41.49, 49.78, 2.06e-1,
          1.04, 1.04, 1.00, 1.00, 1.55, 1.59, 1.19, 1.20, 0.33),
    _spec("wang1", 2903, 19093, 4, 7, 6.58, 0.37, 19, 49, 43.62, 46.98, 2.93e-1,
          1.01, 1.07, 1.01, 1.03, 1.52, 1.56, 1.11, 1.12, 0.35),
    _spec("gemat12", 4929, 33044, 1, 28, 6.70, 11.56, 1, 206, 45.27, 735.35, 6.12e-1,
          0.85, 0.93, 0.99, 1.02, 0.79, 0.95, 1.06, 1.10, 0.37),
    _spec("lshp3466", 3466, 23896, 4, 7, 6.89, 0.20, 21, 49, 47.74, 20.56, 3.44e-1,
          0.94, 1.01, 0.98, 0.98, 1.46, 1.48, 1.00, 1.00, 0.31),
    _spec("LeGresley_4908", 4908, 30482, 2, 34, 6.21, 9.39, 8, 324, 48.25, 1065.07,
          5.03e-1, 0.79, 0.86, 0.99, 1.02, 1.04, 1.00, 1.17, 1.20, 0.32),
    _spec("lns_3937", 3937, 25407, 1, 13, 6.45, 10.39, 1, 113, 48.44, 866.46, 4.00e-1,
          0.82, 0.89, 0.99, 1.01, 1.22, 1.23, 1.06, 1.07, 0.32),
    _spec("pores_2", 1224, 9613, 2, 30, 7.85, 29.53, 10, 298, 63.62, 2199.05, 1.50e-1,
          0.78, 0.89, 1.01, 1.01, 0.77, 0.59, 1.03, 1.01, 0.29),
    _spec("Chebyshev3", 6435, 51480, 3, 9, 8.99, 0.02, 15, 65, 64.92, 2.12, 5.23e-1,
          0.94, 1.01, 1.01, 1.01, 1.36, 1.36, 1.00, 1.00, 0.31),
    _spec("str_200", 363, 3068, 1, 26, 8.45, 84.35, 1, 449, 70.61, 12314.86, 4.93e-2,
          0.83, 0.91, 1.02, 1.05, 0.65, 0.25, 0.99, 0.93, 0.32),
    _spec("dwt_2680", 2680, 25026, 4, 19, 9.34, 3.44, 27, 228, 90.65, 623.75, 4.01e-1,
          0.70, 0.76, 1.00, 1.01, 0.77, 0.91, 1.00, 0.99, 0.26),
    _spec("cage9", 3534, 41594, 3, 23, 11.77, 14.08, 15, 474, 152.60, 7046.60, 8.00e-1,
          0.65, 0.73, 1.00, 1.00, 0.57, 0.59, 1.00, 1.00, 0.25),
    _spec("nasa1824", 1824, 39208, 6, 42, 21.50, 49.58, 65, 1197, 511.64, 59420.46,
          8.14e-1, 0.41, 0.47, 1.00, 0.99, 0.36, 0.31, 0.99, 0.99, 0.16),
    _spec("ex22", 839, 22460, 7, 62, 26.77, 190.67, 176, 2270, 907.22, 220428.89,
          5.50e-1, 0.33, 0.41, 1.00, 1.01, 0.29, 0.20, 1.02, 1.00, 0.17),
    _spec("adder_dcop_01", 1813, 11156, 1, 1332, 6.15, 1076.11, 2, 9439, 1014.45,
          396265.13, 2.25, 0.61, 0.64, 1.00, 1.00, 0.34, 0.18, 1.00, 1.00, 0.20),
    _spec("Goodwin_013", 1965, 56059, 5, 62, 28.53, 224.66, 138, 2359, 1048.69,
          316412.44, 1.47, 0.31, 0.38, 1.00, 1.00, 0.27, 0.24, 1.00, 0.99, 0.14),
    _spec("iprob", 3001, 9000, 2, 3000, 3.00, 2994.00, 3002, 6000, 3003.00, 2994.00,
          10.33, 0.77, 0.72, 1.00, 1.00, 0.34, 0.31, 1.00, 0.99, 0.18),
)

# paper's Table-1 average-speedup row, same column order as paper_speedups
TABLE1_AVERAGE_SPEEDUPS = (1.079, 1.131, 1.204, 1.235, 1.436, 1.413, 1.535, 1.569,
                           0.399)

ALGO_COLUMNS = (
    "spars_16_64", "spars_40_40", "hspa_16_64", "hspa_40_40",
    "hash_32_256", "hash_256_256", "hhash_32_256", "hhash_256_256", "esc",
)


def by_name(name: str) -> MatrixSpec:
    for s in SUITESPARSE_TABLE1:
        if s.name == name:
            return s
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Degree-sequence synthesis
# ---------------------------------------------------------------------------


def _degree_sequence(spec: MatrixSpec, rng: np.random.Generator) -> np.ndarray:
    """Integer degrees: exact sum/min/max, variance matched by pair transfers."""
    n, total = spec.n, spec.nnz
    lo, hi = spec.nnz_min, spec.nnz_max
    base = total // n
    deg = np.full(n, base, np.int64)
    deg[: total - base * n] += 1  # exact sum
    deg = np.clip(deg, max(lo, 0), hi)
    # repair sum after clipping (clip can only matter for degenerate specs)
    _fix_sum(deg, total, lo, hi)
    # plant the published extremes
    if deg.max() < hi:
        i = int(np.argmax(deg))
        delta = hi - deg[i]
        deg[i] = hi
        _shed(deg, delta, lo, exclude=i)
    if deg.min() > lo:
        i = int(np.argmin(deg))
        delta = deg[i] - lo
        deg[i] = lo
        _absorb(deg, delta, hi, exclude=i)
    # variance repair: batched unit transfers between *disjoint* donor/receiver
    # pairs (donors from the low end of the degree ordering, receivers from the
    # high end, paired until their sort positions cross).
    target_ss = spec.nnz_var * n + (total / n) ** 2 * n  # sum of squares target
    for _ in range(200_000):
        cur_ss = float((deg.astype(np.float64) ** 2).sum())
        err = target_ss - cur_ss
        if abs(err) <= max(2.0 * hi, 0.002 * target_ss):
            break
        asc = np.argsort(deg, kind="stable")
        pos = np.empty(n, np.int64)
        pos[asc] = np.arange(n)
        if err > 0:  # need more spread: take from small, give to large
            d_cand = asc[deg[asc] > lo]          # ascending degree
            r_cand = asc[deg[asc] < hi][::-1]    # descending degree
            k = min(len(d_cand), len(r_cand), 512)
            if k == 0:
                break
            d, r = d_cand[:k], r_cand[:k]
            keep = pos[d] < pos[r]               # disjoint by position
            d, r = d[keep], r[keep]
            if len(d) == 0:
                break
            gain = 2.0 * (deg[r] - deg[d]).astype(np.float64) + 2.0
            take = np.cumsum(gain) <= err + gain  # don't wildly overshoot
            d, r = d[take], r[take]
            if len(d) == 0:
                break
            np.add.at(deg, r, 1)
            np.add.at(deg, d, -1)
        else:  # reduce spread: take from large, give to small
            d_cand = asc[deg[asc] > lo][::-1]    # descending degree
            r_cand = asc[deg[asc] < hi]          # ascending degree
            k = min(len(d_cand), len(r_cand), 512)
            if k == 0:
                break
            d, r = d_cand[:k], r_cand[:k]
            keep = (pos[d] > pos[r]) & (deg[d] - deg[r] >= 2)
            d, r = d[keep], r[keep]
            if len(d) == 0:
                break
            loss = 2.0 * (deg[d] - deg[r]).astype(np.float64) - 2.0
            take = np.cumsum(loss) <= -err + loss
            d, r = d[take], r[take]
            if len(d) == 0:
                break
            np.add.at(deg, d, -1)
            np.add.at(deg, r, 1)
    assert deg.sum() == total, (deg.sum(), total)
    rng.shuffle(deg)
    return deg


def _fix_sum(deg, total, lo, hi):
    diff = int(total - deg.sum())
    while diff != 0:
        if diff > 0:
            idx = np.nonzero(deg < hi)[0][: abs(diff)]
            if len(idx) == 0:
                raise ValueError("cannot reach target nnz within [min,max]")
            deg[idx] += 1
            diff -= len(idx)
        else:
            idx = np.nonzero(deg > lo)[0][: abs(diff)]
            if len(idx) == 0:
                raise ValueError("cannot reach target nnz within [min,max]")
            deg[idx] -= 1
            diff += len(idx)


def _shed(deg, delta, lo, exclude):
    """Remove ``delta`` units from columns other than ``exclude``."""
    while delta > 0:
        idx = np.nonzero(deg > lo)[0]
        idx = idx[idx != exclude][:delta]
        if len(idx) == 0:
            raise ValueError("cannot shed degree mass")
        deg[idx] -= 1
        delta -= len(idx)


def _absorb(deg, delta, hi, exclude):
    while delta > 0:
        idx = np.nonzero(deg < hi)[0]
        idx = idx[idx != exclude][:delta]
        if len(idx) == 0:
            raise ValueError("cannot absorb degree mass")
        deg[idx] += 1
        delta -= len(idx)


def _sample_rows(
    deg: np.ndarray,
    beta: float,
    sigma: float,
    rng: np.random.Generator,
    chunk: int = 512,
) -> list[np.ndarray]:
    """Weighted sampling-without-replacement of row indices per column.

    Gumbel top-k per column: scores = beta_j * log(deg) + Gumbel; take the z_j
    largest. ``beta_j = beta + sigma * N(0,1)`` varies the assortativity tilt
    per column (raises the variance of multiplications-per-column).
    """
    n = len(deg)
    logd = np.log(np.maximum(deg.astype(np.float64), 0.5))
    out: list[np.ndarray] = [np.zeros(0, np.int32)] * n
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        betas = beta + sigma * rng.standard_normal(hi - lo)
        scores = betas[:, None] * logd[None, :]
        scores += rng.gumbel(size=(hi - lo, n))
        for jj in range(hi - lo):
            z = int(deg[lo + jj])
            if z == 0:
                continue
            idx = np.argpartition(scores[jj], n - z)[n - z:]
            idx.sort()
            out[lo + jj] = idx.astype(np.int32)
    return out


def _mult_moments(deg: np.ndarray, rows: list[np.ndarray]) -> tuple[float, float]:
    d = deg.astype(np.float64)
    ops = np.array([d[r].sum() for r in rows])
    return float(ops.mean()), float(ops.var())


def synthesize_suitesparse(
    spec: MatrixSpec | str, *, seed: int = 0, dtype=np.float64,
    calibrate_iters: int = 4,
) -> tuple[CSC, MatrixStats]:
    """Generate a matrix matching ``spec``'s published statistics.

    Degree sequence matches nnz/col stats exactly (sum/min/max) or near-exactly
    (variance). Row placement is calibrated: an assortativity exponent ``beta``
    is secant-fitted to the published mult/col mean, then a per-column tilt
    ``sigma`` to the published mult/col variance. Returns (matrix, stats).
    """
    if isinstance(spec, str):
        spec = by_name(spec)
    rng = np.random.default_rng(seed ^ hash(spec.name) % (2**31))
    deg = _degree_sequence(spec, rng)
    n = spec.n

    # --- calibrate beta (mult mean) by secant on the *achieved* mean --------
    def achieved(beta, sigma, salt):
        r = _sample_rows(deg, beta, sigma, np.random.default_rng(seed * 7919 + salt))
        return r, *_mult_moments(deg, r)

    b0, b1 = 0.0, 1.5
    rows, m0, _ = achieved(b0, 0.0, 0)
    _, m1, _ = achieved(b1, 0.0, 1)
    beta = b0
    best = (abs(m0 - spec.mult_avg), b0, rows)
    for it in range(calibrate_iters):
        if abs(m1 - m0) < 1e-9:
            break
        beta = b1 + (spec.mult_avg - m1) * (b1 - b0) / (m1 - m0)
        beta = float(np.clip(beta, -6.0, 10.0))
        rows, m2, _ = achieved(beta, 0.0, 2 + it)
        if abs(m2 - spec.mult_avg) < best[0]:
            best = (abs(m2 - spec.mult_avg), beta, rows)
        b0, m0, b1, m1 = b1, m1, beta, m2
        if abs(m2 - spec.mult_avg) / max(spec.mult_avg, 1.0) < 0.02:
            break
    _, beta, rows = best

    # --- calibrate sigma (mult variance) ------------------------------------
    _, mm, vv = achieved(beta, 0.0, 100)
    best_rows, best_err = rows, abs(vv - spec.mult_var)
    if vv < spec.mult_var * 0.8:  # need more spread than the base tilt gives
        for it, sigma in enumerate((0.25, 0.5, 1.0, 2.0)[: max(calibrate_iters, 1)]):
            r2, m2, v2 = achieved(beta, sigma, 200 + it)
            # keep mean fidelity: only accept if mean stays within 10 %
            if abs(m2 - spec.mult_avg) / max(spec.mult_avg, 1.0) < 0.10:
                err = abs(v2 - spec.mult_var)
                if err < best_err:
                    best_rows, best_err = r2, err
    rows = best_rows

    # Arrow-structure repair: if the published mult/col minimum can only be met
    # when every column references the heaviest column (e.g. iprob, whose one
    # 3000-nnz column appears in every other column's row set), force-include it.
    if spec.mult_min >= spec.nnz_max and spec.nnz_max > 4 * spec.nnz_avg:
        mega = int(np.argmax(deg))
        for j in range(n):
            r = rows[j]
            if len(r) and mega not in set(r.tolist()):
                # replace the lightest entry with the mega row
                repl = int(np.argmin(deg[r]))
                r = r.copy()
                r[repl] = mega
                r.sort()
                rows[j] = r

    vals_l, col_ptr = [], np.zeros(n + 1, np.int32)
    for j in range(n):
        z = len(rows[j])
        col_ptr[j + 1] = col_ptr[j] + z
        vals_l.append(rng.uniform(0.5, 1.5, size=z).astype(dtype))
    m = CSC(np.concatenate(vals_l), np.concatenate(rows), col_ptr, (n, n))
    return m, matrix_stats(m)


def load_or_synthesize(
    spec: MatrixSpec | str, *, seed: int = 0, cache_dir: str | None = ".cache/matrices"
) -> tuple[CSC, MatrixStats]:
    """Disk-cached synthesize (generation is calibrated and costs seconds)."""
    import os

    if isinstance(spec, str):
        spec = by_name(spec)
    if cache_dir is None:
        return synthesize_suitesparse(spec, seed=seed)
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, f"{spec.name}_s{seed}.npz")
    if os.path.exists(path):
        try:
            z = np.load(path)
            m = CSC(z["values"], z["row_indices"], z["col_ptr"],
                    (int(z["n_rows"]), int(z["n_cols"])))
            return m, matrix_stats(m)
        except Exception:
            pass  # corrupt cache entry: regenerate
    m, st = synthesize_suitesparse(spec, seed=seed)
    tmp = path + ".tmp"
    np.savez(tmp, values=m.values, row_indices=m.row_indices, col_ptr=m.col_ptr,
             n_rows=m.shape[0], n_cols=m.shape[1])
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return m, st

"""Sparse matrix containers (CSC primary, matching the paper) + conversions.

Design notes
------------
* CSC is the paper's working format: ``values``/``row_indices`` of length nnz and
  ``col_ptr`` of length ``n_cols + 1`` (first cell 0, last cell nnz).
* Containers are frozen dataclasses registered as JAX pytrees; ``shape`` is static
  aux data. Arrays may be numpy (host preprocessing) or jax.Array (device compute);
  all conversions preserve the array namespace where practical.
* Capacities are static: a container may be over-allocated (``values.shape[0] >=
  nnz``) so jit'd producers with data-dependent output size can write into a fixed
  buffer. ``nnz`` is always derivable as ``int(col_ptr[-1])``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array  # or np.ndarray; duck-typed throughout.


def _np(x):
    """Host view of an array (no-op for numpy)."""
    return np.asarray(x)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSC:
    """Compressed Sparse Column matrix.

    values[p]       value of the p-th stored element
    row_indices[p]  its row
    col_ptr[j]      offset of the first stored element of column j; col_ptr[n] = nnz
    shape           (n_rows, n_cols), static
    """

    values: Array
    row_indices: Array
    col_ptr: Array
    shape: Tuple[int, int]

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.values, self.row_indices, self.col_ptr), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, row_indices, col_ptr = children
        return cls(values, row_indices, col_ptr, aux)

    # -- basic properties ------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(_np(self.col_ptr)[-1])

    @property
    def capacity(self) -> int:
        return int(self.values.shape[0])

    @property
    def dtype(self):
        return self.values.dtype

    def to_device(self) -> "CSC":
        return CSC(
            jnp.asarray(self.values),
            jnp.asarray(self.row_indices, jnp.int32),
            jnp.asarray(self.col_ptr, jnp.int32),
            self.shape,
        )

    def to_host(self) -> "CSC":
        return CSC(
            _np(self.values), _np(self.row_indices), _np(self.col_ptr), self.shape
        )

    def column(self, j: int):
        """Host-side (rows, vals) of column j."""
        cp = _np(self.col_ptr)
        lo, hi = int(cp[j]), int(cp[j + 1])
        return _np(self.row_indices)[lo:hi], _np(self.values)[lo:hi]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BatchedCSC:
    """B same-pattern CSC matrices: one structure, stacked values.

    values[b, p]    value of the p-th stored element in batch element b
    row_indices[p]  its row (shared by every batch element)
    col_ptr[j]      shared column offsets; col_ptr[n] = nnz
    shape           (n_rows, n_cols) of each element, static

    This is the operand type of the batched SpGEMM path (DESIGN.md §7): the
    symbolic plan is built once for the shared pattern and the numeric phase
    runs all B value sets through one set of kernel launches.
    """

    values: Array          # [B, capacity]
    row_indices: Array
    col_ptr: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.row_indices, self.col_ptr), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, row_indices, col_ptr = children
        return cls(values, row_indices, col_ptr, aux)

    @property
    def batch(self) -> int:
        return int(self.values.shape[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(_np(self.col_ptr)[-1])

    @property
    def dtype(self):
        return self.values.dtype

    @classmethod
    def stack(cls, mats) -> "BatchedCSC":
        """Stack same-pattern CSC matrices (structure verified, O(nnz))."""
        mats = list(mats)
        if not mats:
            raise ValueError("need at least one matrix to stack")
        head = mats[0]
        nnz = head.nnz
        cp = _np(head.col_ptr)
        ri = _np(head.row_indices)[:nnz]
        for m in mats[1:]:
            if (
                tuple(m.shape) != tuple(head.shape)
                or not np.array_equal(_np(m.col_ptr), cp)
                or not np.array_equal(_np(m.row_indices)[: m.nnz], ri)
            ):
                raise ValueError(
                    "cannot stack: sparsity patterns differ (BatchedCSC "
                    "requires one shared pattern)")
        vals = np.stack([_np(m.values)[:nnz] for m in mats])
        return cls(vals, ri.astype(np.int32), cp.astype(np.int32),
                   tuple(head.shape))

    @classmethod
    def from_values(cls, pattern_csc: CSC, values) -> "BatchedCSC":
        """Bind a [B, nnz] value stack to an existing pattern."""
        v = _np(values)
        if v.ndim != 2 or v.shape[0] < 1 or v.shape[1] < pattern_csc.nnz:
            raise ValueError(
                f"values must be [B >= 1, >={pattern_csc.nnz}], "
                f"got {v.shape}")
        return cls(v, _np(pattern_csc.row_indices), _np(pattern_csc.col_ptr),
                   tuple(pattern_csc.shape))

    def element(self, b: int) -> CSC:
        """The b-th matrix as a plain CSC (structure arrays shared)."""
        return CSC(self.values[b], self.row_indices, self.col_ptr, self.shape)

    def __getitem__(self, b: int) -> CSC:
        return self.element(b)

    def unstack(self) -> list:
        return [self.element(b) for b in range(self.batch)]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed Sparse Row matrix (transpose-dual of CSC)."""

    values: Array
    col_indices: Array
    row_ptr: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.col_indices, self.row_ptr), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, col_indices, row_ptr = children
        return cls(values, col_indices, row_ptr, aux)

    @property
    def nnz(self) -> int:
        return int(_np(self.row_ptr)[-1])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class COO:
    """Coordinate format (row, col, val triplets)."""

    rows: Array
    cols: Array
    values: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.rows, self.cols, self.values), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, values = children
        return cls(rows, cols, values, aux)

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])


# ---------------------------------------------------------------------------
# Conversions (host-side; generators and tests use these)
# ---------------------------------------------------------------------------


def csc_from_dense(dense, tol: float = 0.0) -> CSC:
    d = _np(dense)
    n_rows, n_cols = d.shape
    mask = np.abs(d) > tol
    col_nnz = mask.sum(axis=0)
    col_ptr = np.zeros(n_cols + 1, np.int32)
    np.cumsum(col_nnz, out=col_ptr[1:])
    rows_list = []
    vals_list = []
    for j in range(n_cols):
        (r,) = np.nonzero(mask[:, j])
        rows_list.append(r)
        vals_list.append(d[r, j])
    rows = (
        np.concatenate(rows_list).astype(np.int32)
        if rows_list
        else np.zeros(0, np.int32)
    )
    vals = np.concatenate(vals_list) if vals_list else np.zeros(0, d.dtype)
    return CSC(vals, rows, col_ptr, (n_rows, n_cols))


def csc_to_dense(m: CSC):
    vals = _np(m.values)
    rows = _np(m.row_indices)
    cp = _np(m.col_ptr)
    out = np.zeros(m.shape, vals.dtype)
    for j in range(m.n_cols):
        lo, hi = cp[j], cp[j + 1]
        # duplicate row entries within a column accumulate (general CSC semantics)
        np.add.at(out[:, j], rows[lo:hi], vals[lo:hi])
    return out


def csc_from_coo(coo: COO, sum_duplicates: bool = True) -> CSC:
    rows = _np(coo.rows).astype(np.int64)
    cols = _np(coo.cols).astype(np.int64)
    vals = _np(coo.values)
    n_rows, n_cols = coo.shape
    order = np.lexsort((rows, cols))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and len(rows):
        key = cols * n_rows + rows
        uniq, inv = np.unique(key, return_inverse=True)
        acc = np.zeros(len(uniq), vals.dtype)
        np.add.at(acc, inv, vals)
        cols = (uniq // n_rows).astype(np.int64)
        rows = (uniq % n_rows).astype(np.int64)
        vals = acc
    col_ptr = np.zeros(n_cols + 1, np.int32)
    np.add.at(col_ptr[1:], cols, 1)
    np.cumsum(col_ptr, out=col_ptr)
    return CSC(vals, rows.astype(np.int32), col_ptr, (n_rows, n_cols))


def csc_to_csr(m: CSC) -> CSR:
    vals = _np(m.values)[: m.nnz]
    rows = _np(m.row_indices)[: m.nnz]
    cp = _np(m.col_ptr)
    cols = np.repeat(np.arange(m.n_cols, dtype=np.int32), np.diff(cp))
    order = np.lexsort((cols, rows))
    row_ptr = np.zeros(m.n_rows + 1, np.int32)
    np.add.at(row_ptr[1:], rows, 1)
    np.cumsum(row_ptr, out=row_ptr)
    return CSR(vals[order], cols[order], row_ptr, m.shape)


def csr_to_csc(m: CSR) -> CSC:
    vals = _np(m.values)[: m.nnz]
    cols = _np(m.col_indices)[: m.nnz]
    rp = _np(m.row_ptr)
    rows = np.repeat(np.arange(m.shape[0], dtype=np.int32), np.diff(rp))
    order = np.lexsort((rows, cols))
    col_ptr = np.zeros(m.shape[1] + 1, np.int32)
    np.add.at(col_ptr[1:], cols, 1)
    np.cumsum(col_ptr, out=col_ptr)
    return CSC(vals[order], rows[order], col_ptr, m.shape)


def transpose_csc(m: CSC) -> CSC:
    """C^T in CSC == C in CSR reinterpreted."""
    r = csc_to_csr(m)
    return CSC(r.values, r.col_indices, r.row_ptr, (m.shape[1], m.shape[0]))


def csc_pad_gather(m: CSC, pad_to: int | None = None):
    """Pattern-only padded-column layout (the symbolic half of padding).

    Returns ``(rows [n_cols, Z] int32, gather [n_cols, Z] int64,
    mask [n_cols, Z] bool, nnz [n_cols] int32)``.  ``gather``/``mask`` turn any
    values vector with this sparsity pattern into its padded rectangular view
    via ``padded_values`` — a single vectorized gather, with no per-column
    Python loop — so a cached plan can re-pad new numeric values cheaply
    (DESIGN.md §6).
    """
    cp = _np(m.col_ptr)
    nnz_col = np.diff(cp).astype(np.int32)
    width = int(nnz_col.max()) if len(nnz_col) and nnz_col.max() > 0 else 1
    if pad_to is not None:
        if pad_to < width:
            raise ValueError(f"pad_to={pad_to} < max column nnz {width}")
        width = pad_to
    z = np.arange(width)
    mask = z[None, :] < nnz_col[:, None]
    gather = np.where(mask, cp[:-1, None].astype(np.int64) + z[None, :], 0)
    rr = _np(m.row_indices)
    if rr.size:
        rows = np.where(mask, rr[gather], 0).astype(np.int32)
    else:
        rows = np.zeros(gather.shape, np.int32)
    return rows, gather, mask, nnz_col


def padded_values(values, gather, mask):
    """Numeric half of padding: values -> padded [n_cols, Z] (zeros in pads)."""
    v = _np(values)
    if v.size == 0:
        return np.zeros(gather.shape, v.dtype)
    return np.where(mask, v[gather], 0).astype(v.dtype, copy=False)


def padded_values_batched(values, gather, mask):
    """Batched ``padded_values``: [B, nnz] -> [B, n_cols, Z] in one gather.

    Row b of the output equals ``padded_values(values[b], gather, mask)``
    exactly; the batched SpGEMM path uses this to re-pad all B value sets of
    a :class:`BatchedCSC` without a per-element Python loop (DESIGN.md §7).
    """
    v = _np(values)
    if v.ndim != 2:
        raise ValueError(f"expected [B, nnz] values, got shape {v.shape}")
    if v.shape[1] == 0:
        return np.zeros((v.shape[0],) + gather.shape, v.dtype)
    return np.where(mask[None], v[:, gather], 0).astype(v.dtype, copy=False)


def segment_reduce(values, seg_starts, axis: int = -1):
    """Per-segment sums along ``axis``: segment ``i`` spans
    ``[seg_starts[i], seg_starts[i+1])`` (last segment runs to the end).

    A thin wrapper over ``np.add.reduceat`` that handles the empty-segment-
    list edge case (reduceat rejects empty index arrays).  The 2-D
    ``axis=1`` form is bit-identical per row to the 1-D reduction, which is
    what lets the batched stream engine promise batched == looped
    (DESIGN.md §9).
    """
    v = np.asarray(values)
    if len(seg_starts) == 0:
        shape = list(v.shape)
        shape[axis] = 0
        return np.zeros(shape, v.dtype)
    return np.add.reduceat(v, seg_starts, axis=axis)


def csc_to_padded_columns(m: CSC, pad_to: int | None = None):
    """Ragged→rectangular view for lock-step kernels.

    Returns (row_idx [n_cols, pad_to] int32, vals [n_cols, pad_to], nnz [n_cols]).
    Padding slots have row_idx == 0 and vals == 0 (masked by nnz downstream).
    """
    rows, gather, mask, nnz_col = csc_pad_gather(m, pad_to)
    return rows, padded_values(m.values, gather, mask), nnz_col


class CSCBuilder:
    """Incremental column-sliced CSC assembly from per-group kernel outputs.

    The SpGEMM executors produce results group by group — dense ``[m, L]``
    accumulator tiles (SPA/SPARS) or ``[H, L]`` hash tables (HASH), with
    ``L`` bounded by the plan's tile width.  The builder compacts each group
    straight into per-column (rows, values) slices and assembles the final
    CSC once, so an ``[m, n]`` dense intermediate never exists; peak
    transient memory is one group tile (DESIGN.md §6).  ``tile_shapes``
    records every tile seen so tests can assert the no-dense guarantee.
    """

    def __init__(self, shape, dtype=np.float32):
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self._rows = [None] * self.shape[1]
        self._vals = [None] * self.shape[1]
        self.tile_shapes: list = []  # (kind, (rows, cols)) per compacted tile

    @property
    def peak_tile_elems(self) -> int:
        """Largest intermediate tile compacted so far, in elements."""
        return max((s[0] * s[1] for _, s in self.tile_shapes), default=0)

    def _set_columns(self, col_ids, rows, vals, offsets):
        for i, j in enumerate(col_ids):
            j = int(j)
            if self._rows[j] is not None:
                raise ValueError(f"column {j} assembled twice")
            lo, hi = offsets[i], offsets[i + 1]
            self._rows[j] = rows[lo:hi]
            self._vals[j] = vals[lo:hi]

    def add_dense_tile(self, col_ids, tile) -> None:
        """Compact a dense [m, L] accumulator tile; tile[:, i] is C column
        col_ids[i].  Matches ``csc_from_dense`` semantics per column
        (rows ascending, exact zeros dropped)."""
        t = _np(tile)
        if t.shape[1] != len(col_ids):
            raise ValueError(
                f"tile has {t.shape[1]} columns for {len(col_ids)} col_ids")
        self.tile_shapes.append(("dense", t.shape))
        present = np.abs(t) > 0
        counts = present.sum(axis=0)
        nz_c, nz_r = np.nonzero(present.T)  # column-major: rows ascending/col
        vals = t[nz_r, nz_c].astype(self.dtype)
        offsets = np.concatenate(([0], np.cumsum(counts)))
        self._set_columns(col_ids, nz_r.astype(np.int32), vals, offsets)

    def add_hash_tables(self, col_ids, keys, vals) -> None:
        """Compact per-lane hash tables keys/vals [H, L]; lane i holds C
        column col_ids[i].  Keys are row indices (-1 = empty slot); zero
        values are dropped exactly as densify-then-compact would."""
        kt = _np(keys).T  # [L, H]
        vt = _np(vals).T
        if kt.shape[0] != len(col_ids):
            raise ValueError(
                f"tables hold {kt.shape[0]} lanes for {len(col_ids)} col_ids")
        self.tile_shapes.append(("hash", _np(keys).shape))
        occupied = (kt >= 0) & (np.abs(vt) > 0)
        counts = occupied.sum(axis=1)
        nz_l, nz_h = np.nonzero(occupied)
        r = kt[nz_l, nz_h].astype(np.int64)
        v = vt[nz_l, nz_h].astype(self.dtype)
        order = np.lexsort((r, nz_l))  # per lane, rows ascending
        offsets = np.concatenate(([0], np.cumsum(counts)))
        self._set_columns(col_ids, r[order].astype(np.int32), v[order],
                          offsets)

    def build(self) -> CSC:
        m, n = self.shape
        empty_r = np.zeros(0, np.int32)
        empty_v = np.zeros(0, self.dtype)
        rows_l = [r if r is not None else empty_r for r in self._rows]
        vals_l = [v if v is not None else empty_v for v in self._vals]
        col_ptr = np.zeros(n + 1, np.int32)
        np.cumsum([len(r) for r in rows_l], out=col_ptr[1:])
        rows = np.concatenate(rows_l) if n else empty_r
        vals = np.concatenate(vals_l) if n else empty_v
        return CSC(vals.astype(self.dtype), rows.astype(np.int32), col_ptr,
                   (m, n))


class BatchedCSCBuilder:
    """Batch-axis-aware CSC assembly from batched kernel outputs.

    Consumes one ``[B, m, L]`` dense tile (or ``[B, H, L]`` hash-table pair)
    per plan group — the output of a single batched kernel launch — and
    compacts it into B independent CSC results.  Per-element compaction
    delegates to :class:`CSCBuilder`, so each element is bit-identical to
    what a per-call execution would have produced; only the tile bookkeeping
    (shape checks, peak accounting) is shared.  Peak transient memory is one
    ``[B, m, tile_cols]`` tile (DESIGN.md §7).
    """

    def __init__(self, batch: int, shape, dtype=np.float32):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.batch = int(batch)
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self.builders = [CSCBuilder(shape, dtype) for _ in range(batch)]
        self.tile_shapes: list = []  # (kind, (B, rows, cols)) per group tile

    @property
    def peak_tile_elems(self) -> int:
        """Largest batched intermediate tile compacted so far, in elements."""
        return max((int(np.prod(s)) for _, s in self.tile_shapes), default=0)

    def add_dense_tile(self, col_ids, tiles) -> None:
        """Compact a batched dense [B, m, L] accumulator tile."""
        t = _np(tiles)
        if t.ndim != 3 or t.shape[0] != self.batch:
            raise ValueError(
                f"expected [B={self.batch}, m, L] tile, got {t.shape}")
        self.tile_shapes.append(("dense", t.shape))
        for b, builder in enumerate(self.builders):
            builder.add_dense_tile(col_ids, t[b])

    def add_hash_tables(self, col_ids, keys, vals) -> None:
        """Compact batched per-lane hash tables keys/vals [B, H, L]."""
        kt = _np(keys)
        vt = _np(vals)
        if kt.ndim != 3 or kt.shape[0] != self.batch:
            raise ValueError(
                f"expected [B={self.batch}, H, L] tables, got {kt.shape}")
        self.tile_shapes.append(("hash", kt.shape))
        for b, builder in enumerate(self.builders):
            builder.add_hash_tables(col_ids, kt[b], vt[b])

    def build(self) -> list:
        """The B assembled CSC results, in batch order."""
        return [builder.build() for builder in self.builders]


def validate_csc(m: CSC, *, sorted_rows: bool = False) -> None:
    """Structural invariants; raises AssertionError on violation."""
    cp = _np(m.col_ptr)
    rows = _np(m.row_indices)
    assert cp.shape == (m.n_cols + 1,), "col_ptr length"
    assert cp[0] == 0, "col_ptr[0] must be 0"
    assert (np.diff(cp) >= 0).all(), "col_ptr must be non-decreasing"
    nnz = int(cp[-1])
    assert nnz <= m.capacity, "nnz exceeds capacity"
    assert rows.shape[0] >= nnz, "row_indices capacity"
    if nnz:
        assert rows[:nnz].min() >= 0 and rows[:nnz].max() < m.n_rows, "row bounds"
    if sorted_rows:
        for j in range(m.n_cols):
            seg = rows[cp[j] : cp[j + 1]]
            assert (np.diff(seg) > 0).all(), f"rows not strictly sorted in col {j}"


def csc_equal(a: CSC, b: CSC, rtol: float = 1e-6, atol: float = 1e-8) -> bool:
    """Semantic equality (order-insensitive within columns, via densification)."""
    if a.shape != b.shape:
        return False
    return np.allclose(csc_to_dense(a), csc_to_dense(b), rtol=rtol, atol=atol)


def csc_bit_identical(a: CSC, b: CSC) -> bool:
    """Exact structural + value equality (storage order included).

    The strictest comparison level: plan reuse, batched-vs-looped, and
    column-only tiled execution all promise results identical at this level
    (DESIGN.md §6-§8); tests and benchmarks assert through this one helper.
    """
    return (
        a.shape == b.shape
        and np.array_equal(_np(a.col_ptr), _np(b.col_ptr))
        and np.array_equal(_np(a.row_indices)[: a.nnz],
                           _np(b.row_indices)[: b.nnz])
        and np.array_equal(_np(a.values)[: a.nnz], _np(b.values)[: b.nnz])
    )

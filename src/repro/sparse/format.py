"""Sparse matrix containers (CSC primary, matching the paper) + conversions.

Design notes
------------
* CSC is the paper's working format: ``values``/``row_indices`` of length nnz and
  ``col_ptr`` of length ``n_cols + 1`` (first cell 0, last cell nnz).
* Containers are frozen dataclasses registered as JAX pytrees; ``shape`` is static
  aux data. Arrays may be numpy (host preprocessing) or jax.Array (device compute);
  all conversions preserve the array namespace where practical.
* Capacities are static: a container may be over-allocated (``values.shape[0] >=
  nnz``) so jit'd producers with data-dependent output size can write into a fixed
  buffer. ``nnz`` is always derivable as ``int(col_ptr[-1])``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array  # or np.ndarray; duck-typed throughout.


def _np(x):
    """Host view of an array (no-op for numpy)."""
    return np.asarray(x)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSC:
    """Compressed Sparse Column matrix.

    values[p]       value of the p-th stored element
    row_indices[p]  its row
    col_ptr[j]      offset of the first stored element of column j; col_ptr[n] = nnz
    shape           (n_rows, n_cols), static
    """

    values: Array
    row_indices: Array
    col_ptr: Array
    shape: Tuple[int, int]

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.values, self.row_indices, self.col_ptr), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, row_indices, col_ptr = children
        return cls(values, row_indices, col_ptr, aux)

    # -- basic properties ------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(_np(self.col_ptr)[-1])

    @property
    def capacity(self) -> int:
        return int(self.values.shape[0])

    @property
    def dtype(self):
        return self.values.dtype

    def to_device(self) -> "CSC":
        return CSC(
            jnp.asarray(self.values),
            jnp.asarray(self.row_indices, jnp.int32),
            jnp.asarray(self.col_ptr, jnp.int32),
            self.shape,
        )

    def to_host(self) -> "CSC":
        return CSC(
            _np(self.values), _np(self.row_indices), _np(self.col_ptr), self.shape
        )

    def column(self, j: int):
        """Host-side (rows, vals) of column j."""
        cp = _np(self.col_ptr)
        lo, hi = int(cp[j]), int(cp[j + 1])
        return _np(self.row_indices)[lo:hi], _np(self.values)[lo:hi]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed Sparse Row matrix (transpose-dual of CSC)."""

    values: Array
    col_indices: Array
    row_ptr: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.col_indices, self.row_ptr), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, col_indices, row_ptr = children
        return cls(values, col_indices, row_ptr, aux)

    @property
    def nnz(self) -> int:
        return int(_np(self.row_ptr)[-1])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class COO:
    """Coordinate format (row, col, val triplets)."""

    rows: Array
    cols: Array
    values: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.rows, self.cols, self.values), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, values = children
        return cls(rows, cols, values, aux)

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])


# ---------------------------------------------------------------------------
# Conversions (host-side; generators and tests use these)
# ---------------------------------------------------------------------------


def csc_from_dense(dense, tol: float = 0.0) -> CSC:
    d = _np(dense)
    n_rows, n_cols = d.shape
    mask = np.abs(d) > tol
    col_nnz = mask.sum(axis=0)
    col_ptr = np.zeros(n_cols + 1, np.int32)
    np.cumsum(col_nnz, out=col_ptr[1:])
    rows_list = []
    vals_list = []
    for j in range(n_cols):
        (r,) = np.nonzero(mask[:, j])
        rows_list.append(r)
        vals_list.append(d[r, j])
    rows = (
        np.concatenate(rows_list).astype(np.int32)
        if rows_list
        else np.zeros(0, np.int32)
    )
    vals = np.concatenate(vals_list) if vals_list else np.zeros(0, d.dtype)
    return CSC(vals, rows, col_ptr, (n_rows, n_cols))


def csc_to_dense(m: CSC):
    vals = _np(m.values)
    rows = _np(m.row_indices)
    cp = _np(m.col_ptr)
    out = np.zeros(m.shape, vals.dtype)
    for j in range(m.n_cols):
        lo, hi = cp[j], cp[j + 1]
        # duplicate row entries within a column accumulate (general CSC semantics)
        np.add.at(out[:, j], rows[lo:hi], vals[lo:hi])
    return out


def csc_from_coo(coo: COO, sum_duplicates: bool = True) -> CSC:
    rows = _np(coo.rows).astype(np.int64)
    cols = _np(coo.cols).astype(np.int64)
    vals = _np(coo.values)
    n_rows, n_cols = coo.shape
    order = np.lexsort((rows, cols))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and len(rows):
        key = cols * n_rows + rows
        uniq, inv = np.unique(key, return_inverse=True)
        acc = np.zeros(len(uniq), vals.dtype)
        np.add.at(acc, inv, vals)
        cols = (uniq // n_rows).astype(np.int64)
        rows = (uniq % n_rows).astype(np.int64)
        vals = acc
    col_ptr = np.zeros(n_cols + 1, np.int32)
    np.add.at(col_ptr[1:], cols, 1)
    np.cumsum(col_ptr, out=col_ptr)
    return CSC(vals, rows.astype(np.int32), col_ptr, (n_rows, n_cols))


def csc_to_csr(m: CSC) -> CSR:
    vals = _np(m.values)[: m.nnz]
    rows = _np(m.row_indices)[: m.nnz]
    cp = _np(m.col_ptr)
    cols = np.repeat(np.arange(m.n_cols, dtype=np.int32), np.diff(cp))
    order = np.lexsort((cols, rows))
    row_ptr = np.zeros(m.n_rows + 1, np.int32)
    np.add.at(row_ptr[1:], rows, 1)
    np.cumsum(row_ptr, out=row_ptr)
    return CSR(vals[order], cols[order], row_ptr, m.shape)


def csr_to_csc(m: CSR) -> CSC:
    vals = _np(m.values)[: m.nnz]
    cols = _np(m.col_indices)[: m.nnz]
    rp = _np(m.row_ptr)
    rows = np.repeat(np.arange(m.shape[0], dtype=np.int32), np.diff(rp))
    order = np.lexsort((rows, cols))
    col_ptr = np.zeros(m.shape[1] + 1, np.int32)
    np.add.at(col_ptr[1:], cols, 1)
    np.cumsum(col_ptr, out=col_ptr)
    return CSC(vals[order], rows[order], col_ptr, m.shape)


def transpose_csc(m: CSC) -> CSC:
    """C^T in CSC == C in CSR reinterpreted."""
    r = csc_to_csr(m)
    return CSC(r.values, r.col_indices, r.row_ptr, (m.shape[1], m.shape[0]))


def csc_to_padded_columns(m: CSC, pad_to: int | None = None):
    """Ragged→rectangular view for lock-step kernels.

    Returns (row_idx [n_cols, pad_to] int32, vals [n_cols, pad_to], nnz [n_cols]).
    Padding slots have row_idx == 0 and vals == 0 (masked by nnz downstream).
    """
    cp = _np(m.col_ptr)
    nnz_col = np.diff(cp).astype(np.int32)
    width = int(nnz_col.max()) if len(nnz_col) and nnz_col.max() > 0 else 1
    if pad_to is not None:
        if pad_to < width:
            raise ValueError(f"pad_to={pad_to} < max column nnz {width}")
        width = pad_to
    rows = np.zeros((m.n_cols, width), np.int32)
    vals = np.zeros((m.n_cols, width), _np(m.values).dtype)
    rr = _np(m.row_indices)
    vv = _np(m.values)
    for j in range(m.n_cols):
        lo, hi = cp[j], cp[j + 1]
        rows[j, : hi - lo] = rr[lo:hi]
        vals[j, : hi - lo] = vv[lo:hi]
    return rows, vals, nnz_col


def validate_csc(m: CSC, *, sorted_rows: bool = False) -> None:
    """Structural invariants; raises AssertionError on violation."""
    cp = _np(m.col_ptr)
    rows = _np(m.row_indices)
    assert cp.shape == (m.n_cols + 1,), "col_ptr length"
    assert cp[0] == 0, "col_ptr[0] must be 0"
    assert (np.diff(cp) >= 0).all(), "col_ptr must be non-decreasing"
    nnz = int(cp[-1])
    assert nnz <= m.capacity, "nnz exceeds capacity"
    assert rows.shape[0] >= nnz, "row_indices capacity"
    if nnz:
        assert rows[:nnz].min() >= 0 and rows[:nnz].max() < m.n_rows, "row bounds"
    if sorted_rows:
        for j in range(m.n_cols):
            seg = rows[cp[j] : cp[j + 1]]
            assert (np.diff(seg) > 0).all(), f"rows not strictly sorted in col {j}"


def csc_equal(a: CSC, b: CSC, rtol: float = 1e-6, atol: float = 1e-8) -> bool:
    """Semantic equality (order-insensitive within columns, via densification)."""
    if a.shape != b.shape:
        return False
    return np.allclose(csc_to_dense(a), csc_to_dense(b), rtol=rtol, atol=atol)

"""2D tile partition and stitch primitives for tiled SpGEMM (DESIGN.md §8).

The tiled multiply decomposes ``C = A @ B`` into a grid of outer-block
products: A is sliced into column blocks ``A[:, k0:k1]``, B into matching
row blocks crossed with column blocks ``B[k0:k1, j0:j1]``, so

    C[:, j0:j1] = sum_k  A[:, k0:k1] @ B[k0:k1, j0:j1]

Each tile product is an ordinary (smaller) SpGEMM handled by its own cached
:class:`~repro.core.planner.SpgemmPlan`; this module provides the
pattern-level plumbing around that: slicing CSC operands along either axis
(returning the value-gather metadata a plan needs to re-slice *new* numeric
values cheaply), summing the per-k partial products, and stitching column
blocks back into one CSC.

Everything here is host-side numpy and value-layout preserving: a column
slice is a contiguous range of the parent's value storage, a row slice is a
pattern-static gather.  ``merge_csc_partials`` accumulates partials in the
given (k-ascending) order, so the only numeric deviation a tile grid can
introduce versus an untiled run is floating-point re-association across row
blocks — a grid with a single row block is bit-identical per column.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.format import CSC, _np

# auto grid sizing (spgemm(method="auto", tile=None)): target nnz per B
# column block / per A column block.  The n-axis target is small enough that
# a mixed-density matrix splits into blocks the cost model can specialize;
# the k-axis target is much larger because row splits cost a merge pass and
# re-associate floating-point sums (see module docstring).
DEFAULT_TILE_NNZ = 16_384
DEFAULT_KSPLIT_NNZ = 262_144


# ---------------------------------------------------------------------------
# grid boundaries
# ---------------------------------------------------------------------------


def width_col_bounds(n_cols: int, width: int) -> np.ndarray:
    """Even-width column-block boundaries: [0, w, 2w, ..., n_cols].

    A width >= n_cols (or a degenerate 0-column axis) yields a single block.
    """
    if width < 1:
        raise ValueError(f"tile width must be >= 1, got {width}")
    if n_cols <= 0:
        return np.asarray([0], np.int64)
    return np.concatenate(
        (np.arange(0, n_cols, width, dtype=np.int64), [n_cols]))


def nnz_balanced_col_bounds(m: CSC, n_blocks: int) -> np.ndarray:
    """Column-block boundaries that roughly equalize nnz per block.

    Computed from the cumulative column nnz (``col_ptr``) by placing cuts at
    the nnz quantiles; duplicate cuts collapse, so the result may have fewer
    than ``n_blocks`` blocks (always at least one for a non-empty axis).
    """
    n = m.n_cols
    if n <= 0:
        return np.asarray([0], np.int64)
    n_blocks = max(1, min(int(n_blocks), n))
    cp = _np(m.col_ptr).astype(np.int64)
    targets = np.linspace(0, cp[-1], n_blocks + 1)[1:-1]
    cuts = np.clip(np.searchsorted(cp, targets, side="left"), 1, n - 1) \
        if n > 1 else np.zeros(0, np.int64)
    return np.unique(np.concatenate(([0], cuts, [n]))).astype(np.int64)


def auto_tile_grid(a: CSC, b: CSC, *, n_target: int | None = None,
                   k_target: int | None = None) -> tuple:
    """(k_blocks, n_blocks) sized from operand nnz (DESIGN.md §8).

    Small operands get a 1x1 grid (tiling then degenerates to the untiled
    path, bit for bit); the n axis splits once B carries more than
    ``n_target`` stored values, the k axis only for much larger A.

    Targets left as ``None`` resolve through the machine profile's tuned
    ``tile_n_target``/``tile_k_target`` knobs when a calibrated profile is
    loaded (``core.profile``, DESIGN.md §15), falling back to the module
    defaults above.
    """
    if n_target is None or k_target is None:
        from repro.core import profile

        tuning = profile.current_profile().tuning
        if n_target is None:
            n_target = int(tuning.get("tile_n_target", DEFAULT_TILE_NNZ))
        if k_target is None:
            k_target = int(tuning.get("tile_k_target", DEFAULT_KSPLIT_NNZ))
    k_blocks = max(1, -(-a.nnz // k_target)) if a.n_cols else 1
    n_blocks = max(1, -(-b.nnz // n_target)) if b.n_cols else 1
    return min(k_blocks, max(a.n_cols, 1)), min(n_blocks, max(b.n_cols, 1))


# ---------------------------------------------------------------------------
# slicing (pattern + value-gather metadata)
# ---------------------------------------------------------------------------


def csc_col_slice(m: CSC, j0: int, j1: int):
    """Columns [j0, j1) as a CSC, plus the (lo, hi) value range it occupies.

    Column slicing is free in CSC: the slice's values are the contiguous
    range ``[lo, hi)`` of the parent's value storage, so a cached tile plan
    can bind fresh numeric values with a single array slice.
    """
    if not (0 <= j0 <= j1 <= m.n_cols):
        raise ValueError(f"column slice [{j0}, {j1}) out of range "
                         f"for {m.n_cols} columns")
    cp = _np(m.col_ptr).astype(np.int64)
    lo, hi = int(cp[j0]), int(cp[j1])
    out = CSC(
        _np(m.values)[lo:hi],
        _np(m.row_indices)[lo:hi],
        (cp[j0:j1 + 1] - lo).astype(np.int32),
        (m.n_rows, j1 - j0),
    )
    return out, (lo, hi)


def csc_row_slice(m: CSC, i0: int, i1: int):
    """Rows [i0, i1) as a CSC of shape (i1-i0, n_cols), plus the gather.

    The second return value is the index array of the kept entries in the
    parent's value storage — pattern-only, so it re-slices any value set
    with the parent's sparsity pattern (``new_vals[idx]``).
    """
    if not (0 <= i0 <= i1 <= m.n_rows):
        raise ValueError(f"row slice [{i0}, {i1}) out of range "
                         f"for {m.n_rows} rows")
    cp = _np(m.col_ptr).astype(np.int64)
    nnz = int(cp[-1])
    rows = _np(m.row_indices)[:nnz]
    keep = (rows >= i0) & (rows < i1)
    idx = np.nonzero(keep)[0]
    col_of = np.repeat(np.arange(m.n_cols, dtype=np.int64), np.diff(cp))
    counts = np.bincount(col_of[idx], minlength=m.n_cols)
    col_ptr = np.zeros(m.n_cols + 1, np.int32)
    np.cumsum(counts, out=col_ptr[1:])
    out = CSC(
        _np(m.values)[:nnz][idx],
        (rows[idx] - i0).astype(np.int32),
        col_ptr,
        (i1 - i0, m.n_cols),
    )
    return out, idx


# ---------------------------------------------------------------------------
# stitch / merge
# ---------------------------------------------------------------------------


def csc_empty(shape, dtype=np.float64) -> CSC:
    """All-zero CSC of the given shape."""
    return CSC(np.zeros(0, dtype), np.zeros(0, np.int32),
               np.zeros(shape[1] + 1, np.int32), tuple(shape))


def csc_hstack(parts, n_rows: int) -> CSC:
    """Concatenate column blocks left-to-right into one CSC.

    Inverse of slicing with :func:`csc_col_slice` along a boundary list:
    stitching the slices back reproduces the parent bit for bit (values and
    per-column row order are passed through untouched).
    """
    parts = list(parts)
    if not parts:
        raise ValueError("need at least one column block")
    if any(p.n_rows != n_rows for p in parts):
        raise ValueError("column blocks disagree on the row dimension")
    dtype = np.result_type(*[p.values.dtype for p in parts])
    vals, rows, cps = [], [], [np.zeros(1, np.int64)]
    offset = 0
    for p in parts:
        nnz = p.nnz
        vals.append(_np(p.values)[:nnz])
        rows.append(_np(p.row_indices)[:nnz])
        cps.append(_np(p.col_ptr).astype(np.int64)[1:] + offset)
        offset += nnz
    n_cols = sum(p.n_cols for p in parts)
    return CSC(
        np.concatenate(vals).astype(dtype, copy=False) if offset
        else np.zeros(0, dtype),
        np.concatenate(rows).astype(np.int32) if offset
        else np.zeros(0, np.int32),
        np.concatenate(cps).astype(np.int32),
        (n_rows, n_cols),
    )


def merge_csc_partials(parts, shape, dtype=None) -> CSC:
    """Sum same-shape partial products C = sum_k parts[k] into one CSC.

    The merge layer of the tiled executor (DESIGN.md §8): each part is one
    row block's contribution ``A[:, k] @ B[k, :]``.  Output columns are
    canonical (rows strictly ascending); each element accumulates its
    per-part contributions in the given (k-ascending) order, so the merge is
    deterministic.  Entries that cancel to exactly 0.0 across parts are kept
    explicit — dropping them would make the output pattern value-dependent,
    which would defeat pattern-keyed plan reuse downstream.

    A single part is returned unchanged (bit-identical passthrough), which
    is what makes single-row-block grids exactly reproduce untiled results.
    """
    parts = [p for p in parts]
    if not parts:
        return csc_empty(shape, dtype or np.float64)
    if any(tuple(p.shape) != tuple(shape) for p in parts):
        raise ValueError(
            f"partial shapes {[p.shape for p in parts]} != merged {shape}")
    if len(parts) == 1:
        return parts[0]
    m, n = shape
    dtype = dtype or np.result_type(*[p.values.dtype for p in parts])
    all_rows, all_cols, all_vals, all_k = [], [], [], []
    for k, p in enumerate(parts):
        nnz = p.nnz
        if nnz == 0:
            continue
        cp = _np(p.col_ptr).astype(np.int64)
        all_rows.append(_np(p.row_indices)[:nnz].astype(np.int64))
        all_cols.append(np.repeat(np.arange(n, dtype=np.int64), np.diff(cp)))
        all_vals.append(_np(p.values)[:nnz])
        all_k.append(np.full(nnz, k, np.int64))
    if not all_rows:
        return csc_empty(shape, dtype)
    rows = np.concatenate(all_rows)
    cols = np.concatenate(all_cols)
    vals = np.concatenate(all_vals).astype(dtype, copy=False)
    ktag = np.concatenate(all_k)
    # sort by (col, row, k): equal (col, row) runs are contiguous with parts
    # in k order, so the unbuffered add accumulates each element's
    # contributions deterministically, k-ascending
    order = np.lexsort((ktag, rows, cols))
    rows, cols, vals = rows[order], cols[order], vals[order]
    key = cols * m + rows
    boundary = np.empty(len(key), bool)
    boundary[0] = True
    boundary[1:] = key[1:] != key[:-1]
    seg = np.cumsum(boundary) - 1
    sums = np.zeros(int(seg[-1]) + 1, dtype)
    np.add.at(sums, seg, vals)
    u_rows = rows[boundary].astype(np.int32)
    u_cols = cols[boundary]
    col_ptr = np.zeros(n + 1, np.int32)
    np.add.at(col_ptr[1:], u_cols, 1)
    np.cumsum(col_ptr, out=col_ptr)
    return CSC(sums, u_rows, col_ptr, (m, n))

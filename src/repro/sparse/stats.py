"""Matrix statistics used by the paper's pre-processing and evaluation tables."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.format import CSC, _np


def column_nnz(m: CSC) -> np.ndarray:
    """nnz per column, length n_cols."""
    return np.diff(_np(m.col_ptr)).astype(np.int64)


def ops_per_column(a: CSC, b: CSC) -> np.ndarray:
    """Op_j = sum over nonzero B[k,j] of nnz(A[:,k])  (paper, Section 3.1).

    The number of scalar multiplications needed for column j of C = A @ B.
    """
    if a.n_cols != b.n_rows:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    za = column_nnz(a)  # [n_a_cols]
    rows_b = _np(b.row_indices)[: b.nnz]
    cp_b = _np(b.col_ptr)
    contrib = za[rows_b]  # one term per stored B element
    out = np.zeros(b.n_cols, np.int64)
    seg = np.repeat(np.arange(b.n_cols), np.diff(cp_b))
    np.add.at(out, seg, contrib)
    return out


def steps_per_column(a: CSC, b: CSC) -> np.ndarray:
    """Lock-step trip-count bound per C column: sum of max(nnz(A[:,k]), 1).

    A lock-step lane consumes one step per stored B[k,j] even when A's
    column k is *empty* (the entry yields no products but the cursor still
    has to advance past it), so the kernel trip count must bound this — not
    ``ops_per_column``, which counts only real products and under-counts
    whenever B references an empty A column.
    """
    if a.n_cols != b.n_rows:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    za = np.maximum(column_nnz(a), 1)
    rows_b = _np(b.row_indices)[: b.nnz]
    cp_b = _np(b.col_ptr)
    out = np.zeros(b.n_cols, np.int64)
    seg = np.repeat(np.arange(b.n_cols), np.diff(cp_b))
    np.add.at(out, seg, za[rows_b])
    return out


@dataclasses.dataclass(frozen=True)
class TileStats:
    """Cheap per-tile statistics feeding the auto cost model (DESIGN.md §8).

    One instance summarizes one tile-pair product ``A[:, k] @ B[k, n]``:
    the per-output-column work profile (``ops``/``steps``) plus operand
    occupancy.  Everything is pattern-only and O(nnz) to compute.
    """

    m: int                 # output rows  (= tile A rows)
    k: int                 # contraction width (= tile A cols = tile B rows)
    n: int                 # output cols  (= tile B cols)
    nnz_a: int
    nnz_b: int
    ops: np.ndarray        # [n] Op_j per output column (scalar multiplies)
    steps: np.ndarray      # [n] lock-step trip-count bound per column

    @property
    def flops(self) -> int:
        return int(self.ops.sum())

    @property
    def ops_max(self) -> int:
        return int(self.ops.max()) if len(self.ops) else 0

    @property
    def cols_nonempty(self) -> int:
        return int((self.ops > 0).sum())

    @property
    def density_a(self) -> float:
        return self.nnz_a / max(self.m * self.k, 1)

    @property
    def density_b(self) -> float:
        return self.nnz_b / max(self.k * self.n, 1)


def tile_stats(a: CSC, b: CSC) -> TileStats:
    """Per-tile Op_j / density profile of the product A @ B."""
    if a.n_cols != b.n_rows:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    return TileStats(
        m=a.n_rows, k=a.n_cols, n=b.n_cols,
        nnz_a=a.nnz, nnz_b=b.nnz,
        ops=ops_per_column(a, b),
        steps=steps_per_column(a, b),
    )


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    """The statistics columns of the paper's Table 1."""

    n_rows: int
    n_cols: int
    nnz: int
    nnz_min: int
    nnz_max: int
    nnz_avg: float
    nnz_var: float
    mult_min: int
    mult_max: int
    mult_avg: float
    mult_var: float

    def row(self) -> str:
        return (
            f"{self.n_rows}x{self.n_cols} nnz={self.nnz} "
            f"nnz/col[min={self.nnz_min} max={self.nnz_max} "
            f"avg={self.nnz_avg:.2f} var={self.nnz_var:.2f}] "
            f"mult/col[min={self.mult_min} max={self.mult_max} "
            f"avg={self.mult_avg:.2f} var={self.mult_var:.2f}]"
        )


def matrix_stats(m: CSC, other: CSC | None = None) -> MatrixStats:
    """Stats for C = M @ M (paper uses A = B) or C = other @ m if given."""
    a = other if other is not None else m
    z = column_nnz(m)
    ops = ops_per_column(a, m)
    return MatrixStats(
        n_rows=m.n_rows,
        n_cols=m.n_cols,
        nnz=m.nnz,
        nnz_min=int(z.min()),
        nnz_max=int(z.max()),
        nnz_avg=float(z.mean()),
        nnz_var=float(z.var()),
        mult_min=int(ops.min()),
        mult_max=int(ops.max()),
        mult_avg=float(ops.mean()),
        mult_var=float(ops.var()),
    )

"""Sparse-matrix substrate: formats, generators, statistics.

The containers here are deliberately simple, static-capacity pytrees so they can
flow through jit/pjit. All preprocessing (sorting, blocking, stats) operates on
host numpy for speed and determinism; kernels consume the JAX-array views.
"""

from repro.sparse.format import (
    CSC,
    CSR,
    COO,
    BatchedCSC,
    BatchedCSCBuilder,
    CSCBuilder,
    csc_from_dense,
    csc_to_dense,
    csc_to_csr,
    csr_to_csc,
    csc_from_coo,
    csc_pad_gather,
    csc_to_padded_columns,
    padded_values,
    padded_values_batched,
    validate_csc,
)
from repro.sparse.generate import (
    random_uniform_csc,
    random_density_csc,
    random_banded_csc,
    random_powerlaw_csc,
)
from repro.sparse.stats import (
    column_nnz,
    ops_per_column,
    steps_per_column,
    matrix_stats,
    MatrixStats,
)
from repro.sparse.suitesparse import (
    SUITESPARSE_TABLE1,
    MatrixSpec,
    synthesize_suitesparse,
)

__all__ = [
    "CSC",
    "CSR",
    "COO",
    "BatchedCSC",
    "BatchedCSCBuilder",
    "csc_from_dense",
    "csc_to_dense",
    "csc_to_csr",
    "csr_to_csc",
    "csc_from_coo",
    "csc_pad_gather",
    "csc_to_padded_columns",
    "padded_values",
    "padded_values_batched",
    "CSCBuilder",
    "validate_csc",
    "random_uniform_csc",
    "random_density_csc",
    "random_banded_csc",
    "random_powerlaw_csc",
    "column_nnz",
    "ops_per_column",
    "steps_per_column",
    "matrix_stats",
    "MatrixStats",
    "SUITESPARSE_TABLE1",
    "MatrixSpec",
    "synthesize_suitesparse",
]

"""Sparse-matrix substrate: formats, generators, statistics.

The containers here are deliberately simple, static-capacity pytrees so they can
flow through jit/pjit. All preprocessing (sorting, blocking, stats) operates on
host numpy for speed and determinism; kernels consume the JAX-array views.
"""

from repro.sparse.format import (
    CSC,
    CSR,
    COO,
    BatchedCSC,
    BatchedCSCBuilder,
    CSCBuilder,
    csc_bit_identical,
    csc_from_dense,
    csc_to_dense,
    csc_to_csr,
    csr_to_csc,
    csc_from_coo,
    csc_pad_gather,
    csc_to_padded_columns,
    padded_values,
    padded_values_batched,
    validate_csc,
)
from repro.sparse.generate import (
    random_uniform_csc,
    random_density_csc,
    random_banded_csc,
    random_powerlaw_csc,
)
from repro.sparse.partition import (
    auto_tile_grid,
    csc_col_slice,
    csc_empty,
    csc_hstack,
    csc_row_slice,
    merge_csc_partials,
    nnz_balanced_col_bounds,
    width_col_bounds,
)
from repro.sparse.stats import (
    column_nnz,
    ops_per_column,
    steps_per_column,
    matrix_stats,
    tile_stats,
    MatrixStats,
    TileStats,
)
from repro.sparse.suitesparse import (
    SUITESPARSE_TABLE1,
    MatrixSpec,
    synthesize_suitesparse,
)

__all__ = [
    "CSC",
    "CSR",
    "COO",
    "BatchedCSC",
    "BatchedCSCBuilder",
    "csc_bit_identical",
    "csc_from_dense",
    "csc_to_dense",
    "csc_to_csr",
    "csr_to_csc",
    "csc_from_coo",
    "csc_pad_gather",
    "csc_to_padded_columns",
    "padded_values",
    "padded_values_batched",
    "CSCBuilder",
    "validate_csc",
    "random_uniform_csc",
    "random_density_csc",
    "random_banded_csc",
    "random_powerlaw_csc",
    "auto_tile_grid",
    "csc_col_slice",
    "csc_empty",
    "csc_hstack",
    "csc_row_slice",
    "merge_csc_partials",
    "nnz_balanced_col_bounds",
    "width_col_bounds",
    "column_nnz",
    "ops_per_column",
    "steps_per_column",
    "matrix_stats",
    "tile_stats",
    "MatrixStats",
    "TileStats",
    "SUITESPARSE_TABLE1",
    "MatrixSpec",
    "synthesize_suitesparse",
]

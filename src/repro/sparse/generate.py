"""Synthetic sparse-matrix generators (host-side numpy, deterministic by seed).

``random_uniform_csc`` is the paper's synthetic-matrix setup (Section 5.2): n×n,
exactly Z non-zeros per column, rows uniform without replacement.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.format import CSC


def _rng(seed):
    return np.random.default_rng(seed)


def random_uniform_csc(
    n: int, z: int, *, seed: int = 0, dtype=np.float64, n_rows: int | None = None
) -> CSC:
    """n_rows × n matrix with exactly ``z`` non-zeros per column, uniform rows."""
    rng = _rng(seed)
    n_rows = n if n_rows is None else n_rows
    if z > n_rows:
        raise ValueError(f"z={z} > n_rows={n_rows}")
    rows = np.empty((n, z), np.int32)
    for j in range(n):
        rows[j] = rng.choice(n_rows, size=z, replace=False)
        rows[j].sort()
    vals = rng.uniform(0.5, 1.5, size=(n, z)).astype(dtype)  # bounded away from 0
    col_ptr = np.arange(0, (n + 1) * z, z, dtype=np.int32)
    return CSC(vals.reshape(-1), rows.reshape(-1), col_ptr, (n_rows, n))


def random_density_csc(
    n_rows: int, n_cols: int, density: float, *, seed: int = 0, dtype=np.float64
) -> CSC:
    """Bernoulli(density) occupancy."""
    rng = _rng(seed)
    mask = rng.uniform(size=(n_rows, n_cols)) < density
    dense = np.where(mask, rng.uniform(0.5, 1.5, size=(n_rows, n_cols)), 0.0)
    from repro.sparse.format import csc_from_dense

    return csc_from_dense(dense.astype(dtype))


def random_banded_csc(
    n: int, bandwidth: int, *, fill: float = 1.0, seed: int = 0, dtype=np.float64
) -> CSC:
    """Banded matrix (PDE-like pattern, e.g. olm1000/tub1000 family)."""
    rng = _rng(seed)
    rows_l, vals_l, col_ptr = [], [], [0]
    for j in range(n):
        lo = max(0, j - bandwidth)
        hi = min(n, j + bandwidth + 1)
        cand = np.arange(lo, hi)
        if fill < 1.0:
            keep = rng.uniform(size=len(cand)) < fill
            keep[cand == j] = True  # keep the diagonal
            cand = cand[keep]
        rows_l.append(cand.astype(np.int32))
        vals_l.append(rng.uniform(0.5, 1.5, size=len(cand)).astype(dtype))
        col_ptr.append(col_ptr[-1] + len(cand))
    return CSC(
        np.concatenate(vals_l),
        np.concatenate(rows_l),
        np.asarray(col_ptr, np.int32),
        (n, n),
    )


def random_powerlaw_csc(
    n: int,
    avg_nnz: float,
    alpha: float = 2.0,
    *,
    max_nnz: int | None = None,
    seed: int = 0,
    dtype=np.float64,
) -> CSC:
    """Power-law column degrees (graph-like pattern, e.g. Kohonen)."""
    rng = _rng(seed)
    max_nnz = max_nnz or n
    raw = rng.pareto(alpha, size=n) + 1.0
    deg = np.clip(np.round(raw * avg_nnz / raw.mean()).astype(np.int64), 1, max_nnz)
    rows_l, vals_l, col_ptr = [], [], [0]
    for j in range(n):
        z = int(min(deg[j], n))
        r = rng.choice(n, size=z, replace=False)
        r.sort()
        rows_l.append(r.astype(np.int32))
        vals_l.append(rng.uniform(0.5, 1.5, size=z).astype(dtype))
        col_ptr.append(col_ptr[-1] + z)
    return CSC(
        np.concatenate(vals_l),
        np.concatenate(rows_l),
        np.asarray(col_ptr, np.int32),
        (n, n),
    )

"""Exact instruction schedules of the paper's algorithms (structure-only).

Every builder walks the pseudocode and emits the instructions it would
execute — per-instruction vector length, active-lane count, and the address
range its gathers/scatters touch — without computing any values. Combined with
``vm.machine`` this reproduces the paper's timing behaviour; combined with
``core.naive`` (value-level, tested against the dense oracle) it constitutes
the full reproduction.
"""

from __future__ import annotations

import numpy as np

from repro.core.analysis import HASH_C, Preprocess, VL_MAX
from repro.core.expand import product_col_ptr
from repro.sparse.format import CSC, _np
from repro.sparse.stats import column_nnz
from repro.vm.trace import Trace

BYTES_V = 8  # double-precision values
BYTES_I = 4  # 32-bit indices
BYTES_F = 1  # flag bytes


# ---------------------------------------------------------------------------
# shared structure helpers
# ---------------------------------------------------------------------------


def _chunk(t: Trace, kind: str, vls: np.ndarray, *, ws: float = 0,
           per: float = 1, vlmax: int = VL_MAX):
    """Emit ``per`` instructions for each natural vector length in ``vls``,
    split into VLMAX-sized chunks (the paper's strip-mining, Section 2.2)."""
    vls = np.asarray(vls, np.int64)
    vls = vls[vls > 0]
    if len(vls) == 0:
        return
    n_full = int((vls // vlmax).sum())
    if n_full:
        t.add(kind, vlmax, count=n_full * per, ws=ws)
    rem = vls % vlmax
    t.add_many(kind, rem, ws=ws, per=per)


def expanded_rows(a: CSC, b: CSC) -> tuple[np.ndarray, np.ndarray]:
    """(rows of every intermediate product in Gustavson order, col_ptr)."""
    a_cp = _np(a.col_ptr).astype(np.int64)
    a_rows = _np(a.row_indices)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)[: b.nnz]
    seg_starts = a_cp[b_rows]
    seg_lens = (a_cp[b_rows + 1] - seg_starts).astype(np.int64)
    total = int(seg_lens.sum())
    if total == 0:
        return np.zeros(0, np.int32), product_col_ptr(a, b)
    stream_starts = np.concatenate(([0], np.cumsum(seg_lens)[:-1]))
    apos = np.arange(total, dtype=np.int64) + np.repeat(
        seg_starts - stream_starts, seg_lens
    )
    return a_rows[apos], product_col_ptr(a, b)


def c_column_nnz(a: CSC, b: CSC) -> np.ndarray:
    """nnz of each C column (distinct rows among its products)."""
    rows, pcp = expanded_rows(a, b)
    n = b.n_cols
    out = np.zeros(n, np.int64)
    for j in range(n):
        seg = rows[pcp[j] : pcp[j + 1]]
        if len(seg):
            out[j] = len(np.unique(seg))
    return out


# ---------------------------------------------------------------------------
# SPA  (Algorithm 2)
# ---------------------------------------------------------------------------


def trace_spa(
    a: CSC, b: CSC, columns: np.ndarray | None = None, *,
    c_nnz: np.ndarray | None = None, trace: Trace | None = None,
    vlmax: int = VL_MAX,
) -> Trace:
    t = trace if trace is not None else Trace()
    m = a.n_rows
    za = column_nnz(a)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)[: b.nnz]
    if columns is None:
        cols = np.arange(b.n_cols)
        elem_rows = b_rows
    else:
        cols = np.asarray(columns, np.int64)
        if len(cols) == 0:
            return t
        segs = [b_rows[b_cp[j] : b_cp[j + 1]] for j in cols]
        elem_rows = np.concatenate(segs) if segs else np.zeros(0, np.int64)
    vls = za[elem_rows]  # natural VL per B element = nnz(A[:,k])

    # main loop, per B non-zero (strip-mined to vlmax):
    _chunk(t, "vload", vls, per=2)                        # A values + rows
    _chunk(t, "vload_idx", vls, ws=m * BYTES_V)           # SPA_values gather
    _chunk(t, "vload_idx", vls, ws=m * BYTES_F)           # SPA_flags gather
    _chunk(t, "vfma", vls)
    _chunk(t, "vstore_idx", vls, ws=m * BYTES_V)          # SPA_values scatter
    _chunk(t, "valu", vls, per=2)                         # flag cmp + compress
    _chunk(t, "vstore_idx", vls, ws=m * BYTES_F)          # flags set
    _chunk(t, "vstore", vls)                              # append new indices
    t.add("scalar", 1, count=4 * len(vls))                # loop bookkeeping

    # output phase, per processed column:
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz
    cn_sel = cn[cols]
    _chunk(t, "vload_idx", cn_sel, ws=m * BYTES_V)        # gather values
    _chunk(t, "vload", cn_sel)                            # read SPA_indices
    _chunk(t, "vstore", cn_sel, per=2)                    # C values + rows
    _chunk(t, "vstore_idx", cn_sel, ws=m * BYTES_V)       # reset values
    _chunk(t, "vstore_idx", cn_sel, ws=m * BYTES_F)       # reset flags
    t.add("scalar", 1, count=10 * len(cols))
    return t


# ---------------------------------------------------------------------------
# SPARS  (Algorithm 3)
# ---------------------------------------------------------------------------

# instruction mix executed once per lock-step iteration (all at VL = block):
# (kind, multiplicity, working-set key)
_SPARS_STEP_MIX = (
    ("vload_idx", 1, "b_span"),    # vB gather through vIndices_B
    ("vload_idx", 2, "a_colptr"),  # A col_ptr base + end gathers
    ("vload_idx", 1, "a_vals"),    # vA values
    ("vload_idx", 1, "a_rows"),    # vA row indices
    ("vload_idx", 1, "acc_vals"),  # SPA_values gather
    ("vload_idx", 1, "acc_flags"),
    ("vfma", 1, None),
    ("vstore_idx", 1, "acc_vals"),
    ("valu", 2, None),             # flag compare, vMask update
    ("vstore_idx", 1, "acc_flags"),
    ("vstore_idx", 1, "acc_idx"),  # SPA_indices append
    ("valu", 3, None),             # cursor compare/add/select
)


def _blocked_steps(
    t: Trace, a: CSC, b: CSC, pre: Preprocess, mix, ws_fn, *, vlmax: int
):
    """Emit the lock-step main loop for SPARS/HASH; returns per-block info."""
    b_cp = _np(b.col_ptr).astype(np.int64)
    info = []
    for bi, (start, size) in enumerate(pre.blocks):
        cols = pre.perm[start : start + size]
        L = int(size)
        ops_blk = pre.ops_sorted[start : start + size]
        # max, not [0]: blocks are sorted for the paper's algorithms but the
        # prior-work baseline (hash-sota) runs unsorted natural order
        steps = int(ops_blk.max()) if L else 0
        if steps == 0:
            t.add("scalar", 1, count=8)
            info.append((bi, cols, L, 0))
            continue
        # active lanes at step s = #lanes with Op > s
        o_sorted = np.sort(ops_blk)
        active = L - np.searchsorted(o_sorted, np.arange(1, steps + 1), "left")
        mean_active = float(active.mean())
        ws = ws_fn(bi, cols, L)
        for kind, mult, wkey in mix:
            t.add(kind, L, count=steps * mult, ws=ws.get(wkey, 0),
                  active=mean_active)
        t.add("scalar", 1, count=20)
        info.append((bi, cols, L, steps))
    return info


def _blocked_output(t: Trace, cn_cols: np.ndarray, L: int, acc_ws: float,
                    *, vlmax: int):
    """Per-block column store-out + accumulator reset (SPARS flavour)."""
    _chunk(t, "vload_idx", cn_cols, ws=acc_ws, vlmax=vlmax)
    _chunk(t, "vload", cn_cols, vlmax=vlmax)
    _chunk(t, "vstore", cn_cols, per=2, vlmax=vlmax)
    _chunk(t, "vstore_idx", cn_cols, ws=acc_ws, per=2, vlmax=vlmax)
    t.add("scalar", 1, count=6 * len(cn_cols))


def trace_spars(
    a: CSC, b: CSC, pre: Preprocess, *, c_nnz: np.ndarray | None = None,
    trace: Trace | None = None, vlmax: int = VL_MAX,
) -> Trace:
    t = trace if trace is not None else Trace()
    m = a.n_rows
    nnz_a = a.nnz
    b_cp = _np(b.col_ptr).astype(np.int64)
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz

    def ws_fn(bi, cols, L):
        span = (b_cp[cols + 1].max() - b_cp[cols].min()) * BYTES_V if L else 0
        return {
            "b_span": float(span),
            "a_colptr": a.n_cols * BYTES_I,
            "a_vals": nnz_a * BYTES_V,
            "a_rows": nnz_a * BYTES_I,
            "acc_vals": m * L * BYTES_V,
            "acc_flags": m * L * BYTES_F,
            "acc_idx": m * L * BYTES_I,
        }

    info = _blocked_steps(t, a, b, pre, _SPARS_STEP_MIX, ws_fn, vlmax=vlmax)
    for bi, cols, L, steps in info:
        if L:
            _blocked_output(t, cn[cols], L, m * L * BYTES_V, vlmax=vlmax)
    return t


# ---------------------------------------------------------------------------
# HASH  (Section 3.2)
# ---------------------------------------------------------------------------


def _column_displacements(rows_seq: np.ndarray, H: int) -> np.ndarray:
    """Linear-probing displacement of each product's key, order-independent.

    Occupied-slot multiset of linear probing is insertion-order independent,
    so we assign positions in hash order (parking process) and read each
    product's cost as its key's displacement.
    """
    if len(rows_seq) == 0:
        return np.zeros(0, np.int64)
    keys, inv = np.unique(rows_seq, return_inverse=True)
    h = (keys.astype(np.int64) * HASH_C) % H
    order = np.argsort(h, kind="stable")
    hs = h[order]
    # parking: pos_i = max(h_i, pos_{i-1}+1); with q_i = pos_i - i this is
    # q = cummax(h - i), pos = q + i
    idx = np.arange(len(hs))
    pos = np.maximum.accumulate(hs - idx) + idx
    disp = pos - hs  # non-circular approximation (exact when no wraparound)
    disp_by_key = np.empty(len(keys), np.int64)
    disp_by_key[order] = disp
    return disp_by_key[inv]


_HASH_STEP_MIX = (
    ("vload_idx", 1, "b_span"),
    ("vload_idx", 2, "a_colptr"),
    ("vload_idx", 1, "a_vals"),
    ("vload_idx", 1, "a_rows"),
    ("valu", 2, None),             # hash: multiply + mask/mod
    ("vload_idx", 1, "tab_keys"),  # probe read
    ("vload_idx", 1, "tab_vals"),
    ("vfma", 1, None),
    ("vstore_idx", 1, "tab_vals"),
    ("vstore_idx", 1, "tab_keys"),
    ("valu", 2, None),             # key compare, vMask update
    ("valu", 3, None),             # cursors
)


def trace_hash(
    a: CSC, b: CSC, pre: Preprocess, *, c_nnz: np.ndarray | None = None,
    trace: Trace | None = None, vlmax: int = VL_MAX,
    prod_rows: np.ndarray | None = None, prod_cp: np.ndarray | None = None,
) -> Trace:
    t = trace if trace is not None else Trace()
    nnz_a = a.nnz
    b_cp = _np(b.col_ptr).astype(np.int64)
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz
    if prod_rows is None:
        prod_rows, prod_cp = expanded_rows(a, b)

    hash_sizes = pre.hash_sizes

    def ws_fn(bi, cols, L):
        H = int(hash_sizes[bi])
        span = (b_cp[cols + 1].max() - b_cp[cols].min()) * BYTES_V if L else 0
        return {
            "b_span": float(span),
            "a_colptr": a.n_cols * BYTES_I,
            "a_vals": nnz_a * BYTES_V,
            "a_rows": nnz_a * BYTES_I,
            "tab_keys": H * L * BYTES_I,
            "tab_vals": H * L * BYTES_V,
        }

    info = _blocked_steps(t, a, b, pre, _HASH_STEP_MIX, ws_fn, vlmax=vlmax)

    # probe stalls: per step, one collision among the VL lanes stalls them all
    # (Section 3.2) -> extra probe iterations = max displacement across the
    # lanes active at that step.
    for bi, cols, L, steps in info:
        if steps == 0:
            continue
        H = int(hash_sizes[bi])
        disp_mat = np.zeros((steps, L), np.int64)
        for ln, col in enumerate(cols):
            seg = prod_rows[prod_cp[col] : prod_cp[col + 1]]
            if len(seg):
                disp_mat[: len(seg), ln] = _column_displacements(seg, H)
        stalls = disp_mat.max(axis=1)  # per-step extra probe iterations
        n_stall = int(stalls.sum())
        if n_stall:
            t.add("vload_idx", L, count=n_stall, ws=H * L * BYTES_I)
            t.add("valu", L, count=2 * n_stall)

        # output: scan the H x L table, compress, store per column; reset
        scan_chunks = max(1, -(-H * L // vlmax))
        t.add("vload", vlmax, count=2 * scan_chunks)   # keys + values
        t.add("valu", vlmax, count=scan_chunks)        # occupancy compress
        _chunk(t, "vstore", cn[cols], per=2, vlmax=vlmax)
        t.add("vstore", vlmax, count=2 * scan_chunks)  # table reset
        t.add("scalar", 1, count=6 * len(cols))
    return t


# ---------------------------------------------------------------------------
# ESC  (Section 4)
# ---------------------------------------------------------------------------


def trace_esc(
    a: CSC, b: CSC, *, group_threshold: int = 10_000,
    trace: Trace | None = None, vlmax: int = VL_MAX,
) -> Trace:
    t = trace if trace is not None else Trace()
    m, n = a.n_rows, b.n_cols
    za = column_nnz(a)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)[: b.nnz]
    pcp = product_col_ptr(a, b)

    def radix_rounds(kmax):
        bits = max(int(np.ceil(np.log2(max(kmax, 2)))), 1)
        r5, r6 = -(-bits // 5), -(-bits // 6)
        return (6, r6) if r6 < r5 else (5, r5)

    j = 0
    while j < n:
        j2 = j + 1
        while j2 < n and pcp[j2 + 1] - pcp[j] < group_threshold:
            j2 += 1
        k = int(pcp[j2] - pcp[j])
        # Expand: per B element in group, one vector op of VL=nnz(A col)
        seg = b_rows[b_cp[j] : b_cp[j2]]
        vls = za[seg]
        _chunk(t, "vload", vls, per=2, vlmax=vlmax)      # A col values+rows
        _chunk(t, "vfma", vls, vlmax=vlmax)
        _chunk(t, "vstore", vls, per=3, vlmax=vlmax)     # val/row/col triples
        _chunk(t, "valu", vls, vlmax=vlmax)              # id generation
        t.add("scalar", 1, count=3 * len(vls))
        if k == 0:
            j = j2
            continue
        # Sort: LSD radix over row key then col key
        chunks = -(-k // vlmax)
        for kmax in (m, n):
            r, rounds = radix_rounds(kmax)
            bucket_ws = vlmax * (1 << r) * BYTES_I
            for _ in range(rounds):
                # histogram
                t.add("valu", vlmax, count=chunks)                 # digit
                t.add("vload_idx", vlmax, count=chunks, ws=bucket_ws)
                t.add("valu", vlmax, count=chunks)
                t.add("vstore_idx", vlmax, count=chunks, ws=bucket_ws)
                # bucket scan
                t.add("valu", vlmax, count=3 * (1 << r))
                # rank + permute (3 payload arrays)
                t.add("vload_idx", vlmax, count=chunks, ws=bucket_ws)
                t.add("valu", vlmax, count=chunks)
                t.add("vstore_idx", vlmax, count=chunks, ws=bucket_ws)
                t.add("vload", vlmax, count=3 * chunks)
                t.add("vstore_idx", vlmax, count=3 * chunks,
                      ws=k * (BYTES_V + 2 * BYTES_I))
        # Compress: strided per virtual processor
        stride_ws = k * (BYTES_V + 2 * BYTES_I)
        t.add("vload_idx", vlmax, count=3 * chunks, ws=stride_ws)
        t.add("valu", vlmax, count=2 * chunks)
        t.add("vstore_idx", vlmax, count=2 * chunks, ws=stride_ws)
        t.add("scalar", 1, count=vlmax)  # sequential VL-length boundary loop
        t.add("scalar", 1, count=20)
        j = j2
    return t


# ---------------------------------------------------------------------------
# Hybrids  (Section 3.3)
# ---------------------------------------------------------------------------


def trace_hybrid(
    a: CSC, b: CSC, pre: Preprocess, accumulator: str = "hash", *,
    c_nnz: np.ndarray | None = None, vlmax: int = VL_MAX,
) -> Trace:
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz
    t = Trace()
    head = pre.perm[: pre.split]
    trace_spa(a, b, columns=head, c_nnz=cn, trace=t, vlmax=vlmax)
    if accumulator == "spa":
        trace_spars(a, b, pre, c_nnz=cn, trace=t, vlmax=vlmax)
    elif accumulator == "hash":
        trace_hash(a, b, pre, c_nnz=cn, trace=t, vlmax=vlmax)
    else:
        raise ValueError(accumulator)
    return t


def trace_preprocess(a: CSC, b: CSC, *, vlmax: int = VL_MAX) -> Trace:
    """Sorting pre-process cost (reported separately, as the paper does)."""
    t = Trace()
    nnz_b = b.nnz
    n = b.n_cols
    chunks = -(-max(nnz_b, 1) // vlmax)
    t.add("vload_idx", vlmax, count=chunks, ws=a.n_cols * BYTES_I)  # Z_A gather
    t.add("valu", vlmax, count=2 * chunks)                          # seg-sum
    # sort: model as radix over Op values (few rounds) on n elements
    sort_chunks = -(-n // vlmax)
    t.add("valu", vlmax, count=10 * sort_chunks)
    t.add("vload_idx", vlmax, count=6 * sort_chunks, ws=n * BYTES_I)
    t.add("vstore_idx", vlmax, count=6 * sort_chunks, ws=n * BYTES_I)
    t.add("scalar", 1, count=2 * n)
    return t


# ---------------------------------------------------------------------------
# BEYOND-PAPER variants (EXPERIMENTS.md kernel-level §Perf)
# ---------------------------------------------------------------------------


def _ws_makespan(ops_blk: np.ndarray, L: int) -> tuple[int, float, int]:
    """(steps, mean active lanes, refills) under lane refill.

    Columns (sorted by decreasing load) are claimed by the earliest-free
    lane; the block retires when the last lane drains. Classic list
    scheduling: makespan <= P/L + max_op.
    """
    import heapq

    if len(ops_blk) == 0:
        return 0, 0.0, 0
    lanes = [0] * min(L, len(ops_blk))
    heapq.heapify(lanes)
    for op in ops_blk:
        t0 = heapq.heappop(lanes)
        heapq.heappush(lanes, t0 + int(op))
    steps = max(lanes)
    total = int(ops_blk.sum())
    mean_active = total / max(steps, 1)
    return steps, mean_active, len(ops_blk)


def trace_spars_ws(
    a: CSC, b: CSC, pre: Preprocess, *, c_nnz: np.ndarray | None = None,
    trace: Trace | None = None, vlmax: int = VL_MAX,
) -> Trace:
    """SPARS with lane refill (work-stealing): masked-idle steps removed,
    plus per-refill cursor-reload cost. Value-level twin:
    core.naive.spars_ws_numpy (oracle-tested)."""
    t = trace if trace is not None else Trace()
    m = a.n_rows
    nnz_a = a.nnz
    b_cp = _np(b.col_ptr).astype(np.int64)
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz

    for start, size in pre.blocks:
        cols = pre.perm[start : start + size]
        L = int(size)
        ops_blk = pre.ops_sorted[start : start + size]
        steps, mean_active, refills = _ws_makespan(ops_blk, L)
        if steps == 0:
            t.add("scalar", 1, count=8)
            continue
        span = (b_cp[cols + 1].max() - b_cp[cols].min()) * BYTES_V
        ws = {
            "b_span": float(span), "a_colptr": a.n_cols * BYTES_I,
            "a_vals": nnz_a * BYTES_V, "a_rows": nnz_a * BYTES_I,
            "acc_vals": m * L * BYTES_V, "acc_flags": m * L * BYTES_F,
            "acc_idx": m * L * BYTES_I,
        }
        for kind, mult, wkey in _SPARS_STEP_MIX:
            t.add(kind, L, count=steps * mult, ws=ws.get(wkey, 0),
                  active=mean_active)
        # refill overhead: cursor reload + queue pop per column claim
        t.add("valu", L, count=2 * max(steps // max(L, 1), 1))
        t.add("scalar", 1, count=3 * refills + 20)
        _blocked_output(t, cn[cols], L, m * L * BYTES_V, vlmax=vlmax)
    return t


def trace_hash_ws(
    a: CSC, b: CSC, pre: Preprocess, *, c_nnz: np.ndarray | None = None,
    trace: Trace | None = None, vlmax: int = VL_MAX,
    prod_rows: np.ndarray | None = None, prod_cp: np.ndarray | None = None,
) -> Trace:
    """HASH with lane refill."""
    t = trace if trace is not None else Trace()
    nnz_a = a.nnz
    b_cp = _np(b.col_ptr).astype(np.int64)
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz
    if prod_rows is None:
        prod_rows, prod_cp = expanded_rows(a, b)

    for bi, (start, size) in enumerate(pre.blocks):
        cols = pre.perm[start : start + size]
        L = int(size)
        ops_blk = pre.ops_sorted[start : start + size]
        steps, mean_active, refills = _ws_makespan(ops_blk, L)
        if steps == 0:
            t.add("scalar", 1, count=8)
            continue
        H = int(pre.hash_sizes[bi])
        span = (b_cp[cols + 1].max() - b_cp[cols].min()) * BYTES_V
        ws = {
            "b_span": float(span), "a_colptr": a.n_cols * BYTES_I,
            "a_vals": nnz_a * BYTES_V, "a_rows": nnz_a * BYTES_I,
            "tab_keys": H * L * BYTES_I, "tab_vals": H * L * BYTES_V,
        }
        for kind, mult, wkey in _HASH_STEP_MIX:
            t.add(kind, L, count=steps * mult, ws=ws.get(wkey, 0),
                  active=mean_active)
        # probe stalls: per-column displacements as in trace_hash; under
        # refill the per-step max is over a denser lane set — model with the
        # same per-product displacement stream averaged into steps
        stall_total = 0
        for col in cols:
            seg = prod_rows[prod_cp[col] : prod_cp[col + 1]]
            if len(seg):
                stall_total += int(
                    _column_displacements(seg, H).sum()) 
        n_stall = int(stall_total / max(L, 1))
        if n_stall:
            t.add("vload_idx", L, count=n_stall, ws=H * L * BYTES_I)
            t.add("valu", L, count=2 * n_stall)
        t.add("valu", L, count=2 * max(steps // max(L, 1), 1))
        t.add("scalar", 1, count=3 * refills + 20)
        scan_chunks = max(1, -(-H * L // vlmax))
        t.add("vload", vlmax, count=2 * scan_chunks)
        t.add("valu", vlmax, count=scan_chunks)
        _chunk(t, "vstore", cn[cols], per=2, vlmax=vlmax)
        t.add("vstore", vlmax, count=2 * scan_chunks)
        t.add("scalar", 1, count=6 * len(cols))
    return t


def trace_hybrid_ws(
    a: CSC, b: CSC, pre: Preprocess, accumulator: str = "hash", *,
    c_nnz: np.ndarray | None = None, vlmax: int = VL_MAX,
) -> Trace:
    cn = c_column_nnz(a, b) if c_nnz is None else c_nnz
    t = Trace()
    head = pre.perm[: pre.split]
    trace_spa(a, b, columns=head, c_nnz=cn, trace=t, vlmax=vlmax)
    if accumulator == "spa":
        trace_spars_ws(a, b, pre, c_nnz=cn, trace=t, vlmax=vlmax)
    else:
        trace_hash_ws(a, b, pre, c_nnz=cn, trace=t, vlmax=vlmax)
    return t

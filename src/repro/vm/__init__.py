"""Cycle-level cost model of the paper's vector machine.

The paper evaluates on an FPGA prototype (RISC-V scalar core + 8-lane VPU,
max VL 256 doubles, 50 MHz, 1 MB L2, 4 GB DRAM) that we cannot run. The
algorithms' performance, however, is fully determined by their *instruction
schedules* (which we derive exactly from the matrix structure, per the paper's
pseudocode) plus a machine model (issue cost, per-beat throughput, and the
indexed-access range penalty that creates the paper's b_max effects).

- trace.py     instruction-group aggregation
- schedule.py  exact per-algorithm schedule -> trace (structure only, no values)
- machine.py   trace -> cycles/seconds; constants calibrated against Table 1
"""

from repro.vm.trace import Trace
from repro.vm.machine import Machine, DEFAULT_MACHINE
from repro.vm.schedule import (
    trace_spa,
    trace_spars,
    trace_hash,
    trace_esc,
    trace_hybrid,
    c_column_nnz,
)

__all__ = [
    "Trace",
    "Machine",
    "DEFAULT_MACHINE",
    "trace_spa",
    "trace_spars",
    "trace_hash",
    "trace_esc",
    "trace_hybrid",
    "c_column_nnz",
]

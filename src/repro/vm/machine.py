"""Machine model: instruction trace -> cycles -> seconds.

Models the paper's platform (Section 5.1): single-issue RISC-V scalar core
driving an 8-lane VPU, max VL 256 doubles, 50 MHz, 1 MB L2, DDR4 DRAM.

A vector instruction of length VL costs
    issue + ceil(VL / lanes) * beat(kind) * range_factor(kind, ws)
where ``range_factor`` models the indexed-access locality cliff the paper
observes (Section 5.2): gathers/scatters whose target working set fits L2 run
at near unit-stride beat; past L2 every element risks a DRAM-latency miss.
The factor interpolates with the L2-resident fraction of the working set:
    f(ws) = 1 + miss_penalty * max(0, 1 - L2/ws).

Default constants were calibrated against Table 1 (see
benchmarks/calibrate.py): SPA absolute seconds and all nine speedup columns.
"""

from __future__ import annotations

import dataclasses

from repro.vm.trace import Trace


@dataclasses.dataclass(frozen=True)
class Machine:
    lanes: int = 8
    vl_max: int = 256
    clock_hz: float = 50e6
    l2_bytes: float = 1 << 20

    issue: float = 6.0            # cycles to issue/decode a vector instruction
    beat_alu: float = 1.0         # per-group (8-elem) cycles, vector ALU
    beat_fma: float = 1.0
    beat_mem: float = 1.0         # unit-stride load/store
    beat_idx: float = 8.0         # gather/scatter (element-serialized)
    miss_penalty: float = 6.0     # extra beats per element when ws >> L2
    range_log_coef: float = 0.25  # sub-L2 growth of gather cost with range
    range_log_base: float = 16 << 10
    scalar_cpi: float = 1.5       # scalar-core cycles per instruction

    _BEATS = {
        "valu": "beat_alu",
        "vfma": "beat_fma",
        "vload": "beat_mem",
        "vstore": "beat_mem",
        "vload_idx": "beat_idx",
        "vstore_idx": "beat_idx",
    }

    def range_factor(self, kind: str, ws: float) -> float:
        """Indexed-access slowdown as a function of target address range.

        Two regimes, both observed in the paper's Section 5.2 discussion:
        (a) within L2, wider ranges stress banking/TLB — logarithmic growth;
        (b) past L2, elements miss to DRAM — penalty scaled by the
            non-resident fraction.
        """
        if kind not in ("vload_idx", "vstore_idx") or ws <= 0:
            return 1.0
        import math

        sub = self.range_log_coef * max(
            0.0, math.log2(min(ws, self.l2_bytes) / self.range_log_base)
        )
        resident = min(1.0, self.l2_bytes / ws)
        return 1.0 + sub + self.miss_penalty * (1.0 - resident)

    def instr_cycles(self, kind: str, vl: int, ws: float) -> float:
        if kind == "scalar":
            return self.scalar_cpi
        beat = getattr(self, self._BEATS[kind])
        groups = -(-vl // self.lanes)
        return self.issue + groups * beat * self.range_factor(kind, ws)

    def cycles(self, trace: Trace) -> float:
        total = 0.0
        for (kind, vl, ws), count in trace.counts.items():
            total += count * self.instr_cycles(kind, vl, ws)
        return total

    def seconds(self, trace: Trace) -> float:
        return self.cycles(trace) / self.clock_hz

    def replace(self, **kw) -> "Machine":
        return dataclasses.replace(self, **kw)


# Constants fitted against Table 1 by benchmarks/calibrate.py (geomean
# per-cell speedup error 11.9% over 40 matrices x 9 algorithm columns).
CALIBRATED = dict(
    issue=23.886430233209833,
    beat_mem=4.0,
    beat_idx=22.547063450115633,
    miss_penalty=0.9976311574844396,
    range_log_coef=0.17698644609603448,
    scalar_cpi=16.0,
)


def _default() -> Machine:
    """Fitted constants, refreshed from benchmarks/fitted_machine.json when a
    newer calibration exists."""
    import json
    import os

    path = os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "benchmarks",
        "fitted_machine.json")
    try:
        with open(path) as f:
            return Machine(**{**CALIBRATED, **json.load(f)})
    except Exception:
        return Machine(**CALIBRATED)


DEFAULT_MACHINE = _default()

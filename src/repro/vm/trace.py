"""Aggregated instruction traces.

A trace is a multiset of vector/scalar instructions grouped by
(kind, vector length, working-set bucket). Working set is the address range an
indexed (gather/scatter) instruction may touch — the quantity the paper
identifies as the driver of indexed load/store performance (Section 5.2).
"""

from __future__ import annotations

import collections

import numpy as np

KINDS = (
    "valu",        # vector arithmetic / compare / mask ops
    "vfma",        # fused multiply-add
    "vload",       # unit-stride load
    "vstore",      # unit-stride store
    "vload_idx",   # gather
    "vstore_idx",  # scatter
    "scalar",      # scalar-core instruction
)


def _ws_bucket(ws: float) -> int:
    """Power-of-two bucket of the working set (0 for non-memory ops)."""
    if ws <= 0:
        return 0
    return 1 << int(np.ceil(np.log2(max(ws, 1))))


class Trace:
    """count[(kind, vl, ws_bucket)] plus active-element tallies."""

    __slots__ = ("counts", "active_elems", "total_elems")

    def __init__(self):
        self.counts = collections.Counter()
        self.active_elems = 0.0  # useful lanes
        self.total_elems = 0.0   # lanes incl. masked-off

    def add(self, kind: str, vl: int, count: float = 1, ws: float = 0,
            active: float | None = None):
        if count <= 0 or vl <= 0:
            return
        self.counts[(kind, int(vl), _ws_bucket(ws))] += count
        self.total_elems += count * vl
        self.active_elems += count * (vl if active is None else active)

    def add_many(self, kind: str, vls: np.ndarray, ws: float = 0,
                 actives: np.ndarray | None = None, per: float = 1):
        """One instruction (x per) for each entry of ``vls``."""
        vls = np.asarray(vls)
        vls = vls[vls > 0]
        if len(vls) == 0:
            return
        bucket = _ws_bucket(ws)
        uniq, cnt = np.unique(vls, return_counts=True)
        for v, c in zip(uniq.tolist(), cnt.tolist()):
            self.counts[(kind, int(v), bucket)] += c * per
        self.total_elems += per * float(vls.sum())
        if actives is not None:
            self.active_elems += per * float(np.asarray(actives).sum())
        else:
            self.active_elems += per * float(vls.sum())

    def merge(self, other: "Trace") -> "Trace":
        self.counts.update(other.counts)
        self.active_elems += other.active_elems
        self.total_elems += other.total_elems
        return self

    @property
    def n_instructions(self) -> float:
        return float(sum(self.counts.values()))

    @property
    def utilization(self) -> float:
        """Fraction of processed vector lanes that did useful work."""
        return self.active_elems / max(self.total_elems, 1.0)

    def by_kind(self) -> dict:
        out = collections.Counter()
        for (kind, _, _), c in self.counts.items():
            out[kind] += c
        return dict(out)

    def __repr__(self):
        return (
            f"Trace({self.n_instructions:.0f} instrs, "
            f"util={self.utilization:.2%}, kinds={self.by_kind()})"
        )

"""Model primitives: norm, rotary, chunked (flash-style) attention, FFN, loss.

All functions are pure; parameters come from ``params.py`` tables. Attention
is two-level chunked with online softmax so no [S, S] score tensor is ever
materialized — required for the 32k prefill shapes (DESIGN.md §5) — and is
plain jnp, so GSPMD shards it with the surrounding program.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.hints import hint, hint_heads
from repro.models import params as pp

NEG_INF = -1e30


def rms_norm(p, x, eps=1e-5):
    # variance in f32, but the main data path stays in x.dtype so backward
    # cotangents (which cross TP all-reduces) stay bf16 — see EXPERIMENTS.md
    # §Perf iteration 2
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x [..., S, H, D]; positions [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    ang = ang[..., None, :]                                # head axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_table(cfg, *, kv_from: int | None = None, bias=None):
    """QKV + out projections; fused head dims (see DESIGN.md §5 sharding)."""
    d = cfg.d_model
    d_kv_src = kv_from if kv_from is not None else d
    bias = cfg.qkv_bias if bias is None else bias
    return {
        "wq": pp.linear(d, cfg.qkv_fused_q, "embed", "heads", bias=bias),
        "wk": pp.linear(d_kv_src, cfg.qkv_fused_kv, "embed", "heads",
                        bias=bias),
        "wv": pp.linear(d_kv_src, cfg.qkv_fused_kv, "embed", "heads",
                        bias=bias),
        "wo": pp.linear(cfg.qkv_fused_q, d, "heads", "embed"),
    }


def _chunked_attn(q, k, v, *, causal: bool, q_offset, q_chunk, kv_chunk):
    """Online-softmax attention. q [B,Sq,Hkv,G,D], k/v [B,Skv,Hkv,D]."""
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    cq = min(q_chunk, sq)
    ck = min(kv_chunk, skv)
    if sq % cq:
        cq = sq   # non-divisible (e.g. ragged memory): single chunk
    if skv % ck:
        ck = skv  # e.g. 1601 image tokens: one kv chunk
    nq, nk = sq // cq, skv // ck
    scale = dh ** -0.5

    qs = q.reshape(b, nq, cq, hkv, g, dh)
    ks = k.reshape(b, nk, ck, hkv, dh)
    vs = v.reshape(b, nk, ck, hkv, dh)

    def q_block(iq):
        qb = qs[:, iq] * scale                     # [B,cq,Hkv,G,D]
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        @jax.checkpoint  # recompute p-matrices in backward (flash-style)
        def kv_step(carry, ik):
            m, l, acc = carry
            kb = ks[:, ik]
            vb = vs[:, ik]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32)
            if causal:
                k_pos = ik * ck + jnp.arange(ck)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, cq), jnp.float32),
            jnp.zeros((b, hkv, g, cq, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)        # [B,cq,Hkv,G,D]

    outs = jax.lax.map(q_block, jnp.arange(nq))     # [nq,B,cq,Hkv,G,D]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dh)
    return out


def attention(p, cfg, x, *, kv_src=None, causal=True, positions=None,
              kv_positions=None, use_rope=True):
    """Self- or cross-attention over full sequences (train/prefill)."""
    b, s, _ = x.shape
    kv_in = x if kv_src is None else kv_src
    skv = kv_in.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = hq // hkv
    q = hint_heads(dense(p["wq"], x).reshape(b, s, hkv, g, dh))
    k = hint_heads(dense(p["wk"], kv_in).reshape(b, skv, hkv, dh),
                   head_dims=(2,))
    v = hint_heads(dense(p["wv"], kv_in).reshape(b, skv, hkv, dh),
                   head_dims=(2,))
    if use_rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        if kv_positions is None:
            kv_positions = jnp.arange(skv)[None, :]
        q = hint_heads(rope(q.reshape(b, s, hkv * g, dh), positions,
                            cfg.rope_theta).reshape(b, s, hkv, g, dh))
        k = hint_heads(rope(k, kv_positions, cfg.rope_theta), head_dims=(2,))
    out = _chunked_attn(q, k, v, causal=causal, q_offset=0,
                        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    out = hint(out.reshape(b, s, hq * dh).astype(x.dtype),
               "dp", None, "model")
    return dense(p["wo"], out)


def attention_decode(p, cfg, x, cache_k, cache_v, cur_len, *, use_rope=True):
    """One-token decode against a KV cache.

    x [B,1,D]; cache_k/v [B,S,Hkv,Dh]; cur_len: scalar or [B] per-slot counts
    of tokens already cached (continuous batching). Returns
    (out [B,1,D], new_k, new_v).
    """
    b, _, _ = x.shape
    s_max = cache_k.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = hq // hkv
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    q = dense(p["wq"], x).reshape(b, 1, hkv, g, dh)
    k = dense(p["wk"], x).reshape(b, 1, hkv, dh)
    v = dense(p["wv"], x).reshape(b, 1, hkv, dh)
    if use_rope:
        pos = cur[:, None]
        q = rope(q.reshape(b, 1, hkv * g, dh), pos,
                 cfg.rope_theta).reshape(b, 1, hkv, g, dh)
        k = rope(k, pos, cfg.rope_theta)
    # per-slot scatter of the new KV at position cur_len[b]
    slot = (jnp.arange(s_max)[None, :] == cur[:, None])[..., None, None]
    cache_k = jnp.where(slot, k.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(slot, v.astype(cache_v.dtype), cache_v)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q * dh ** -0.5,
                   cache_k.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    mask = (jnp.arange(s_max)[None, :] <= cur[:, None])[
        :, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(cache_v.dtype),
                     cache_v, preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, hq * dh).astype(x.dtype)
    return dense(p["wo"], out), cache_k, cache_v


def cross_attention_cached(p, cfg, x, mem_k, mem_v):
    """Cross-attention against precomputed memory K/V (decode path)."""
    b = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = hq // hkv
    q = dense(p["wq"], x).reshape(b, 1, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q * dh ** -0.5,
                   mem_k.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(mem_v.dtype), mem_v,
                     preferred_element_type=jnp.float32)
    return dense(p["wo"], out.reshape(b, 1, hq * dh).astype(x.dtype))


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_table(cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "gate": pp.linear(d, f, "embed", "mlp"),
        "up": pp.linear(d, f, "embed", "mlp"),
        "down": pp.linear(f, d, "mlp", "embed"),
    }


def ffn(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x))
                 * dense(p["up"], x))


# ---------------------------------------------------------------------------
# embedding + chunked LM loss
# ---------------------------------------------------------------------------


def embed_table(cfg):
    return {"embedding": pp.Leaf((cfg.vocab_padded, cfg.d_model),
                                 ("vocab", "embed"), "normal:0.02")}


def embed(p, tokens):
    return p["embedding"][tokens]


def unembed_table(cfg):
    return pp.linear(cfg.d_model, cfg.vocab_padded, "embed", "vocab")


def lm_loss(p_unembed, cfg, h, labels):
    """Mean next-token cross-entropy; seq-chunked so [B,S,Vpad] never exists.

    h [B,S,D] (already final-normed); labels [B,S] int32 (-1 = ignore).
    """
    b, s, d = h.shape
    c = min(cfg.logits_chunk, s)
    assert s % c == 0
    vpad, v = cfg.vocab_padded, cfg.vocab
    hs = h.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, s // c, c).transpose(1, 0, 2)

    @jax.checkpoint  # recompute the [B,c,Vpad] logits in backward
    def chunk(carry, hl):
        hc, lc = hl
        logits = (hc @ p_unembed["w"].astype(hc.dtype)).astype(jnp.float32)
        if vpad > v:
            pad_mask = jnp.arange(vpad) >= v
            logits = jnp.where(pad_mask[None, None, :], NEG_INF, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        tot, cnt = carry
        return (tot + ((lse - gold) * valid).sum(), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def lm_logits(p_unembed, cfg, h):
    """Full logits (serve path; callers keep S tiny)."""
    logits = (h @ p_unembed["w"].astype(h.dtype)).astype(jnp.float32)
    if cfg.vocab_padded > cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, NEG_INF, logits)
    return logits

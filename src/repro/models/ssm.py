"""Mamba1 (selective scan) and Mamba2 (scalar-decay SSD) blocks.

Training/prefill uses a chunked associative scan: the sequence is cut into
``cfg.ssm.chunk``-length chunks; within a chunk the linear recurrence runs as
``jax.lax.associative_scan`` (log-depth, VPU-friendly), across chunks a
lax.scan carries the state. Memory per chunk is [B, c, d_inner/TP, d_state],
which is what makes the 500k-token shapes feasible (DESIGN.md §4).

Decode is the exact single-step recurrence with (conv window, SSM state)
carried in the serve cache.

Simplification vs reference Mamba2: the short conv is applied to x only (not
B/C); noted in DESIGN.md §2 as a non-essential deviation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import params as pp
from repro.models.layers import dense, rms_norm


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------


def mamba_table(cfg):
    s = cfg.ssm
    d, din, ds = cfg.d_model, cfg.d_inner, s.d_state
    if s.version == 1:
        dtr = cfg.dt_rank_actual
        return {
            "in_proj": pp.linear(d, 2 * din, "embed", "ssm_inner"),
            "conv_w": pp.Leaf((s.d_conv, din), (None, "ssm_inner"),
                              "normal:0.1"),
            "conv_b": pp.Leaf((din,), ("ssm_inner",), "zeros"),
            "x_proj": pp.linear(din, dtr + 2 * ds, "ssm_inner", None),
            "dt_proj": pp.linear(dtr, din, None, "ssm_inner",
                                 init="normal:0.01"),
            "dt_bias": pp.Leaf((din,), ("ssm_inner",), "dt_bias"),
            "a_log": pp.Leaf((din, ds), ("ssm_inner", None), "ssm_a"),
            "d_skip": pp.Leaf((din,), ("ssm_inner",), "ones"),
            "out_proj": pp.linear(din, d, "ssm_inner", "embed"),
        }
    nh = din // s.head_dim
    return {
        "in_proj": pp.linear(d, 2 * din + 2 * ds + nh, "embed", "ssm_inner"),
        "conv_w": pp.Leaf((s.d_conv, din), (None, "ssm_inner"), "normal:0.1"),
        "conv_b": pp.Leaf((din,), ("ssm_inner",), "zeros"),
        "dt_bias": pp.Leaf((nh,), (None,), "dt_bias"),
        "a_log": pp.Leaf((nh,), (None,), "ssm_a"),
        "d_skip": pp.Leaf((nh,), (None,), "ones"),
        "norm": pp.Leaf((din,), ("ssm_inner",), "ones"),
        "out_proj": pp.linear(din, d, "ssm_inner", "embed"),
    }


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _causal_conv(x, w, b, window=None):
    """Depthwise causal conv. x [B,S,C], w [K,C]. window: [B,K-1,C] history
    for decode continuity (None = zero history)."""
    k = w.shape[0]
    if window is None:
        window = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([window, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _scan_chunks(a, u, h0):
    """h_t = a_t * h_{t-1} + u_t over time axis 1, associative scan.

    a, u: [B, c, ...] (same shape); h0 [B, ...]. Returns (h_all [B,c,...],
    h_last).
    """

    def op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, u_cum = jax.lax.associative_scan(op, (a, u), axis=1)
    h_all = a_cum * h0[:, None] + u_cum
    return h_all, h_all[:, -1]


def _chunked_ssm_apply(build_fn, inputs, h0, chunk, seq_len):
    """Chunked linear recurrence without materializing [B,S,...,d_state].

    ``inputs``: pytree of [B, S, ...] per-timestep tensors. Per chunk, the
    (rematerialized) body calls ``build_fn(chunk_inputs)`` ->
    (a [B,c,...,state], u [B,c,...,state], y_fn(h_all) -> y_chunk), runs the
    associative scan, and emits only the chunk output — so the
    state-expanded tensors exist for one chunk at a time (DESIGN.md §4).
    Returns ([B, S, ...out], h_last).
    """
    c = min(chunk, seq_len)
    assert seq_len % c == 0, (seq_len, c)
    n = seq_len // c

    def to_chunks(x):
        b = x.shape[0]
        return x.reshape((b, n, c) + x.shape[2:]).swapaxes(0, 1)

    chunked = jax.tree_util.tree_map(to_chunks, inputs)

    @jax.checkpoint
    def step(h, ch_in):
        a, u, y_fn = build_fn(ch_in)
        h_all, h_last = _scan_chunks(a, u, h)
        return h_last, y_fn(h_all)

    h_last, ys = jax.lax.scan(step, h0, chunked)
    ys = ys.swapaxes(0, 1)  # [B, n, c, ...]
    return ys.reshape((ys.shape[0], seq_len) + ys.shape[3:]), h_last


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_forward(p, cfg, x, state=None):
    """x [B,S,D] -> (y [B,S,D], new_state). state = (conv_win, h)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    din, ds = cfg.d_inner, s_cfg.d_state
    dtr = cfg.dt_rank_actual
    conv_win, h0 = state if state is not None else (None, None)

    xz = dense(p["in_proj"], x)
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(xin, p["conv_w"].astype(x.dtype),
                      p["conv_b"].astype(x.dtype), conv_win)
    new_conv_win = jnp.concatenate(
        [conv_win if conv_win is not None
         else jnp.zeros((b, s_cfg.d_conv - 1, din), x.dtype), xin],
        axis=1)[:, -(s_cfg.d_conv - 1):]
    xc = jax.nn.silu(xc)

    proj = dense(p["x_proj"], xc)
    dt_raw, bmat, cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_raw)
                         + p["dt_bias"][None, None, :]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # [din, ds]
    if h0 is None:
        h0 = jnp.zeros((b, din, ds), jnp.float32)

    def build(ch):
        dt_c, xc_c, b_c, c_c = ch                           # [B,c,...]
        decay = jnp.exp(dt_c[..., None] * a[None, None])    # [B,c,din,ds]
        drive = (dt_c * xc_c.astype(jnp.float32))[..., None] \
            * b_c.astype(jnp.float32)[:, :, None, :]
        y_fn = lambda h_all: jnp.einsum(
            "bsdn,bsn->bsd", h_all, c_c.astype(jnp.float32))
        return decay, drive, y_fn

    y, h_last = _chunked_ssm_apply(
        build, (dt, xc, bmat, cmat), h0, s_cfg.chunk, s)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return dense(p["out_proj"], y), (new_conv_win, h_last)


# ---------------------------------------------------------------------------
# Mamba2 (scalar decay per head)
# ---------------------------------------------------------------------------


def mamba2_forward(p, cfg, x, state=None):
    s_cfg = cfg.ssm
    b, s, d = x.shape
    din, ds, hd = cfg.d_inner, s_cfg.d_state, s_cfg.head_dim
    nh = din // hd
    conv_win, h0 = state if state is not None else (None, None)

    zxbcdt = dense(p["in_proj"], x)
    z, xin, bmat, cmat, dt_raw = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + ds, 2 * din + 2 * ds], axis=-1)
    xc = _causal_conv(xin, p["conv_w"].astype(x.dtype),
                      p["conv_b"].astype(x.dtype), conv_win)
    new_conv_win = jnp.concatenate(
        [conv_win if conv_win is not None
         else jnp.zeros((b, s_cfg.d_conv - 1, din), x.dtype), xin],
        axis=1)[:, -(s_cfg.d_conv - 1):]
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])       # [B,S,nh]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # [nh]
    xh = xc.reshape(b, s, nh, hd).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)

    def build(ch):
        dt_c, xh_c, b_c, c_c = ch
        decay = jnp.exp(dt_c * a[None, None])[..., None, None]
        drive = (dt_c[..., None] * xh_c)[..., None] \
            * b_c.astype(jnp.float32)[:, :, None, None, :]   # [B,c,nh,hd,ds]
        decay_b = jnp.broadcast_to(decay, drive.shape)
        y_fn = lambda h_all: jnp.einsum(
            "bshdn,bsn->bshd", h_all, c_c.astype(jnp.float32))
        return decay_b, drive, y_fn

    y, h_last = _chunked_ssm_apply(
        build, (dt, xh, bmat, cmat), h0, s_cfg.chunk, s)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm({"scale": p["norm"]}, y, cfg.norm_eps)
    return dense(p["out_proj"], y), (new_conv_win, h_last)


def mamba_forward(p, cfg, x, state=None):
    fn = mamba1_forward if cfg.ssm.version == 1 else mamba2_forward
    return fn(p, cfg, x, state)


def mamba_init_state(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    din = cfg.d_inner
    conv = jnp.zeros((batch, s.d_conv - 1, din), dtype)
    if s.version == 1:
        h = jnp.zeros((batch, din, s.d_state), jnp.float32)
    else:
        h = jnp.zeros((batch, din // s.head_dim, s.head_dim, s.d_state),
                      jnp.float32)
    return conv, h

"""Declarative parameters: one table drives init, shapes, and sharding.

A *table* is a nested dict whose leaves are ``Leaf(shape, axes, init)``:
  shape : tuple of ints
  axes  : tuple of logical axis names (len == len(shape)); None = replicated
  init  : "normal:<std>" | "zeros" | "ones" | "fan_in" | "ssm_a" | "dt_bias"

From one table we derive
  * init_params(table, key, dtype)      -> pytree of arrays
  * abstract_params(table, dtype)       -> pytree of ShapeDtypeStruct
  * partition_specs(table, rules)       -> pytree of PartitionSpec

``rules`` maps logical axis -> mesh axis (or tuple). Divisibility is checked
per-leaf: if a dim doesn't divide over the assigned mesh axes, the rule falls
back to a prefix of the mesh-axis tuple, then to replication — so one rule set
serves every architecture (56-head models simply get that tensor replicated
or fused-dim sharded; see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Leaf:
    shape: tuple
    axes: tuple
    init: str = "fan_in"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x):
    return isinstance(x, Leaf)


def _map_table(table, fn):
    return jax.tree_util.tree_map(fn, table, is_leaf=_is_leaf)


def _init_leaf(leaf: Leaf, key, dtype):
    shape = leaf.shape
    kind = leaf.init
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "ssm_a":
        # mamba: A = -exp(A_log), A_log ~ log(uniform[1, d_state])
        n = shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, shape).astype(dtype)
    if kind == "dt_bias":
        # mamba: dt bias so softplus(dt) ~ uniform[1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if kind.startswith("normal:"):
        std = float(kind.split(":")[1])
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    if kind == "fan_in":
        fan_in = shape[0] if len(shape) > 1 else shape[0]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    raise ValueError(kind)


def init_params(table, key, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(table, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_init_leaf(l, k, dtype) for l, k in zip(leaves, keys)])


def abstract_params(table, dtype=jnp.float32):
    return _map_table(
        table, lambda l: jax.ShapeDtypeStruct(l.shape, dtype))


def stack_tables(table, n: int):
    """Prepend a scan ('layers') axis of length n to every leaf."""
    return _map_table(
        table,
        lambda l: Leaf((n,) + l.shape, ("layers",) + l.axes, l.init))


def _spec_for(leaf: Leaf, rules: dict) -> P:
    parts = []
    used: set = set()  # a mesh axis may shard at most one dim per tensor
    for dim, ax in zip(leaf.shape, leaf.axes):
        assigned = rules.get(ax)
        if assigned is None:
            parts.append(None)
            continue
        if isinstance(assigned, str):
            assigned = (assigned,)
        assigned = tuple(a for a in assigned if a not in used)
        # longest prefix of the mesh-axis tuple that divides the dim
        chosen = None
        for k in range(len(assigned), 0, -1):
            prod = int(np.prod([rules["__sizes__"][a] for a in assigned[:k]]))
            if dim % prod == 0:
                chosen = assigned[:k]
                break
        if chosen:
            used.update(chosen)
        parts.append(chosen if chosen is None or len(chosen) > 1
                     else chosen[0])
    return P(*parts)


def partition_specs(table, rules: dict):
    return _map_table(table, lambda l: _spec_for(l, rules))


# -- common table builders --------------------------------------------------


def linear(d_in, d_out, ax_in, ax_out, *, bias=False, init="fan_in"):
    t = {"w": Leaf((d_in, d_out), (ax_in, ax_out), init)}
    if bias:
        t["b"] = Leaf((d_out,), (ax_out,), "zeros")
    return t


def rmsnorm(d, ax="embed"):
    return {"scale": Leaf((d,), (ax,), "ones")}

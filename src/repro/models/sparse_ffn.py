"""SparseFFN: pruned-weight FFN served through the paper's hybrid policy.

The TPU re-targeting of H-SPA(t)/H-HASH(t) (DESIGN.md §3.1): the switching
statistic is block-level density instead of per-column Op_j, and the
execution regimes are
  * dense path  — plain MXU matmul (the SPA analogue: dense accumulator,
    throughput-optimal when most blocks are present), chosen when the kept-
    block fraction >= ``t_density``;
  * sparse path — the BSR Pallas kernel (kernels/bsr_spmm.py), which skips
    absent blocks entirely (the SPARS/HASH analogue), chosen for sparser
    weights;
  * spgemm path — the *differentiable* re-targeting (DESIGN.md §10): the
    pruned weight is stored as an element-level CSC whose values are
    trainable, activations ride as dense-pattern CSC value arrays, and the
    multiply is the cached SpGEMM plan's device stream
    (``core.jax_stream``) — jit-compatible and reverse-differentiable, so a
    sparse FFN can *train* with SpGEMM inside the traced step
    (``training.train_loop.build_sparse_ffn_train_step``).  Opt-in via
    ``path="spgemm"``; weight patterns are static across steps (pruned at
    conversion time), so each distinct token count plans once and every
    later step is a pure compiled replay.

``from_dense`` prunes by block magnitude to a target density. The policy is
per-matrix, decided at conversion time (weights are static at serving time,
exactly like the paper's pre-processing phase).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmm import bsr_from_dense, bsr_spmm
from repro.sparse.format import CSC, csc_from_dense


@dataclasses.dataclass
class SparseMatmul:
    """One pruned weight matrix with its chosen execution path."""

    path: str                   # "dense" | "bsr" | "spgemm"
    dense_w: jax.Array | None
    block_idx: jax.Array | None
    block_nnz: jax.Array | None
    blocks: jax.Array | None
    shape: tuple
    density: float
    w_csc: CSC | None = None    # spgemm path: static pattern, jnp values
    #: spgemm path: per-plan plan-memory-guard override (products); large
    #: FFNs * long token blocks exceed the global default, and mutating
    #: fast.STREAM_MAX_PRODUCTS would re-key every cached plan
    stream_limit: int | None = None
    # spgemm path: per-token-count SpGEMM plan + densify indices, resolved
    # once at trace time.  A bounded LRU: each entry pins a plan (host +
    # device stream, O(nnz_w * N)) past plan-LRU eviction, so workloads
    # cycling through many distinct token counts must not accumulate them
    _spgemm_memo: "OrderedDict" = dataclasses.field(
        default_factory=OrderedDict, repr=False)

    SPGEMM_MEMO_SIZE = 8        # distinct token counts held per matrix

    @classmethod
    def from_dense(cls, w, *, bm=8, bk=8, keep_density=0.5,
                   t_density=0.75, path: str | None = None,
                   stream_limit: int | None = None) -> "SparseMatmul":
        """Prune ``w`` by block magnitude and pick an execution path.

        ``path=None`` applies the serving policy (dense above ``t_density``,
        BSR below); ``path="spgemm"`` forces the differentiable CSC/SpGEMM
        path (DESIGN.md §10), whose values are trainable; ``"dense"`` /
        ``"bsr"`` force the serving paths.  ``stream_limit`` raises this
        matrix's plan-memory guard (the spgemm path's stream holds
        ``nnz_w * tokens`` products, which outgrows the global default at
        large FFN sizes) without touching the global knob.
        """
        if path not in (None, "dense", "bsr", "spgemm"):
            raise ValueError(
                f"unknown path {path!r}; None, 'dense', 'bsr' or 'spgemm'")
        w = np.asarray(w, np.float32)
        m, k = w.shape
        n_rb, n_cb = m // bm, k // bk
        tiles = w.reshape(n_rb, bm, n_cb, bk).transpose(0, 2, 1, 3)
        norms = np.abs(tiles).max(axis=(2, 3))
        n_keep = max(1, int(round(keep_density * n_rb * n_cb)))
        thresh = np.partition(norms.reshape(-1), -n_keep)[-n_keep]
        pruned = np.where((norms >= thresh)[:, :, None, None], tiles, 0.0)
        w_pruned = pruned.transpose(0, 2, 1, 3).reshape(m, k)
        density = float((norms >= thresh).mean())
        if path == "spgemm":
            csc = csc_from_dense(w_pruned)
            csc = CSC(jnp.asarray(np.asarray(csc.values, np.float32)),
                      csc.row_indices, csc.col_ptr, csc.shape)
            return cls("spgemm", None, None, None, None, (m, k), density,
                       w_csc=csc, stream_limit=stream_limit)
        if path == "dense" or (path is None and density >= t_density):
            # paper's hybrid switch: stay dense (SPA)
            return cls("dense", jnp.asarray(w_pruned), None, None, None,
                       (m, k), density)
        bi, bn, blocks = bsr_from_dense(w_pruned, bm, bk)
        return cls("bsr", None, jnp.asarray(bi), jnp.asarray(bn),
                   jnp.asarray(blocks), (m, k), density)

    @classmethod
    def from_shared_pattern(cls, w_stack, *, keep_density=0.5,
                            stream_limit: int | None = None):
        """Shared-pattern spgemm matmuls for a stack of same-shape weights.

        The serving-engine regime (DESIGN.md §12): scanned super-blocks
        need every repeated layer to share *one* CSC structure so the scan
        body traces once and all reps replay the same cached plan — the
        paper's static pre-processing contract, batched over depth.
        ``w_stack`` is ``[R, m, k]`` in ``W @ x`` orientation; pruning
        keeps the element positions whose rep-wise max magnitude lands in
        the top ``keep_density`` fraction (element granularity: one mask
        must serve every rep, so block-local magnitudes of a single layer
        cannot decide it).  Returns ``(matmul, values)`` where ``matmul``
        holds rep 0's values and ``values`` is the ``[R, nnz]`` trainable
        stack in the pattern's CSC (column-major) order.
        """
        w = np.asarray(w_stack, np.float32)
        if w.ndim != 3:
            raise ValueError(f"w_stack must be [R, m, k], got {w.shape}")
        _, m, k = w.shape
        mag = np.abs(w).max(axis=0)
        n_keep = max(1, int(round(keep_density * m * k)))
        thresh = np.partition(mag.reshape(-1), -n_keep)[-n_keep]
        cols, rows = np.nonzero((mag >= thresh).T)   # CSC coordinate order
        col_ptr = np.zeros(k + 1, np.int64)
        np.cumsum(np.bincount(cols, minlength=k), out=col_ptr[1:])
        values = w[:, rows, cols]                    # [R, nnz], CSC order
        csc = CSC(jnp.asarray(values[0]), rows.astype(np.int32),
                  col_ptr.astype(np.int32), (m, k))
        mat = cls("spgemm", None, None, None, None, (m, k),
                  float(rows.size / (m * k)), w_csc=csc,
                  stream_limit=stream_limit)
        return mat, jnp.asarray(values)

    # -- spgemm path (DESIGN.md §10) -------------------------------------

    @property
    def w_values(self) -> jax.Array:
        """Trainable weight values (spgemm path): the CSC value array."""
        if self.path != "spgemm":
            raise ValueError(
                f"w_values is the spgemm path's parameter array "
                f"(this matmul runs path={self.path!r})")
        return self.w_csc.values

    def _spgemm_plan(self, n: int, backend: str = "jax"):
        """Plan W @ X for X dense [K, N], memoized per token count.

        The activation operand is a *fully dense* pattern — its structure
        depends only on (K, N), so the symbolic phase runs once per
        distinct N (at trace time) and the numeric phase is the plan's
        jitted device stream (``backend="jax"``) or the vectorized numpy
        stream (``backend="host"``, the serving fallback — DESIGN.md §12).
        Returns ``(plan, scatter_rows, scatter_cols)`` where the scatter
        indices densify the canonical CSC result into ``[M, N]``
        (plan-static numpy, free under jit).
        """
        memo_key = (n, backend)
        if memo_key in self._spgemm_memo:
            self._spgemm_memo.move_to_end(memo_key)
            return self._spgemm_memo[memo_key]
        from repro.core.api import cached_plan

        m, k = self.shape
        x_pat = CSC(np.zeros(k * n, np.float32),
                    np.tile(np.arange(k, dtype=np.int32), n),
                    np.arange(n + 1, dtype=np.int32) * k, (k, n))
        w_pat = CSC(np.zeros(self.w_csc.nnz, np.float32),
                    self.w_csc.row_indices, self.w_csc.col_ptr,
                    self.shape)
        plan = cached_plan(w_pat, x_pat, "expand", backend=backend,
                           stream_limit=self.stream_limit)
        s = plan.stream
        if s is None:
            raise ValueError(
                "spgemm-path weight stream exceeds the plan-memory guard; "
                "pass stream_limit= to from_dense/from_params (per-plan "
                "override) or shrink the token block")
        cols = np.repeat(np.arange(n, dtype=np.int32),
                         np.diff(s.c_col_ptr))
        self._spgemm_memo[memo_key] = (plan, s.c_rows, cols)
        while len(self._spgemm_memo) > self.SPGEMM_MEMO_SIZE:
            self._spgemm_memo.popitem(last=False)
        return self._spgemm_memo[memo_key]

    def apply_values(self, w_values, x):
        """y [M, N] = W @ x for trainable values ``w_values`` (spgemm path).

        Pure and jit/grad/vmap-compatible: ``w_values`` and ``x`` may be
        tracers; the plan lookup keys only on ``x``'s static shape.
        Column-major flattening turns the dense activations into the CSC
        value array of the plan's dense B pattern, and the plan's canonical
        result scatters back to dense through plan-static indices.
        """
        if self.path != "spgemm":
            raise ValueError(
                f"apply_values needs path='spgemm' (got {self.path!r})")
        n = x.shape[1]
        plan, rows, cols = self._spgemm_plan(int(n))
        c_vals = plan.stream_apply(w_values, x.T.reshape(-1))
        # plan-static, unique, in-bounds scatter indices: skip XLA's
        # bounds-check/dup handling (same rationale as the stream gathers)
        return jnp.zeros(self.shape[0:1] + (int(n),), c_vals.dtype).at[
            rows, cols].set(c_vals, mode="promise_in_bounds",
                            unique_indices=True)

    def apply_values_host(self, w_values, x) -> np.ndarray:
        """Host-stream spelling of :meth:`apply_values` (concrete numpy).

        The serving fallback path (DESIGN.md §12): while the device plan
        is still building/compiling in the background, a decode tick runs
        the same multiply through the *host* product stream — a cheap
        synchronous plan on the same LRU, no device lift and no XLA
        compile on the tick.  Concrete values only (never call under a
        trace); same contraction order as the host stream engine.
        """
        if self.path != "spgemm":
            raise ValueError(
                f"apply_values_host needs path='spgemm' (got {self.path!r})")
        x = np.asarray(x, np.float32)
        n = x.shape[1]
        plan, rows, cols = self._spgemm_plan(int(n), backend="host")
        c = plan.execute(np.asarray(w_values, np.float32),
                         x.T.reshape(-1), engine="stream")
        out = np.zeros((self.shape[0], int(n)), np.float32)
        out[rows, cols] = np.asarray(c.values, np.float32)
        return out

    def __call__(self, x, *, bn=None, interpret=True):
        """y = W @ x for x [K, N]."""
        if self.path == "dense":
            return self.dense_w @ x
        if self.path == "spgemm":
            return self.apply_values(self.w_values, x)
        n = x.shape[1]
        bn = bn or min(128, n)
        return bsr_spmm(self.block_idx, self.block_nnz, self.blocks, x,
                        bn=bn, interpret=interpret)

    def batched(self, xs, *, bn=None, interpret=True):
        """y [B, M, N] = W @ xs[b] for xs [B, K, N] — one launch for all B.

        The weight pattern is static (pruned at conversion time), so a batch
        of activations is exactly the same-pattern regime as batched SpGEMM
        (DESIGN.md §7): the BSR structure operands are shared and only the
        dense activations carry the batch axis, vmapped into a single
        leading-grid-dimension launch instead of B Python round-trips.
        """
        if self.path == "dense":
            return self.dense_w @ xs              # broadcasts over the batch
        if self.path == "spgemm":
            # same-pattern batched regime: the plan's vmapped device stream
            return jax.vmap(
                lambda x: self.apply_values(self.w_values, x))(xs)
        n = xs.shape[2]
        bn = bn or min(128, n)
        f = lambda x: bsr_spmm(self.block_idx, self.block_nnz, self.blocks,
                               x, bn=bn, interpret=interpret)
        return jax.vmap(f)(xs)

    @property
    def flops_per_col(self) -> int:
        m, k = self.shape
        if self.path == "dense":
            return 2 * m * k
        if self.path == "spgemm":
            return 2 * self.w_csc.nnz
        nb = int(np.asarray(self.block_nnz).sum())
        bm, bk = self.blocks.shape[2], self.blocks.shape[3]
        return 2 * nb * bm * bk


@dataclasses.dataclass
class SparseFFN:
    """SwiGLU FFN with pruned gate/up/down matrices."""

    gate: SparseMatmul
    up: SparseMatmul
    down: SparseMatmul

    @classmethod
    def from_params(cls, p, *, keep_density=0.4, t_density=0.75, bm=8, bk=8,
                    path: str | None = None,
                    stream_limit: int | None = None):
        mk = lambda w: SparseMatmul.from_dense(
            np.asarray(w).T, bm=bm, bk=bk, keep_density=keep_density,
            t_density=t_density, path=path, stream_limit=stream_limit)
        return cls(mk(p["gate"]["w"]), mk(p["up"]["w"]), mk(p["down"]["w"]))

    # -- differentiable spgemm path (DESIGN.md §10) ----------------------

    def trainable_params(self) -> dict:
        """The trainable weight-value pytree of an all-spgemm-path FFN."""
        mats = {"gate": self.gate, "up": self.up, "down": self.down}
        bad = [k for k, m in mats.items() if m.path != "spgemm"]
        if bad:
            raise ValueError(
                f"trainable_params needs every matmul on path='spgemm' "
                f"(convert with from_params(..., path='spgemm')); "
                f"{bad} are not")
        return {k: m.w_values for k, m in mats.items()}

    def apply(self, params, x):
        """Functional forward pass: ``params`` override the stored values.

        ``x`` is ``[T, D]`` (or a batch ``[B, T, D]``); the three matmuls
        run the differentiable SpGEMM stream with ``params['gate'/'up'/
        'down']`` as the weight values, so ``jax.grad`` of anything
        downstream reaches the sparse weights (the values of a *fixed*
        pruned pattern — structure never re-derives during training,
        exactly the paper's static pre-processing contract).
        """
        if x.ndim == 3:
            return jax.vmap(lambda xb: self.apply(params, xb))(x)
        xt = x.T                                   # [D, T]
        h = (jax.nn.silu(self.gate.apply_values(params["gate"], xt))
             * self.up.apply_values(params["up"], xt))
        return self.down.apply_values(params["down"], h).T

    def apply_host(self, params, x) -> np.ndarray:
        """Host-stream spelling of :meth:`apply` (concrete numpy values).

        The serving fallback (DESIGN.md §12): same SwiGLU dataflow, every
        matmul through the host product stream via
        :meth:`SparseMatmul.apply_values_host`.  ``x`` is ``[T, D]`` or a
        batch ``[B, T, D]``; returns float32 numpy.
        """
        x = np.asarray(x, np.float32)
        if x.ndim == 3:
            return np.stack([self.apply_host(params, xb) for xb in x])
        xt = x.T                                   # [D, T]
        g = self.gate.apply_values_host(params["gate"], xt)
        u = self.up.apply_values_host(params["up"], xt)
        h = (g / (1.0 + np.exp(-g))) * u           # numpy silu
        return self.down.apply_values_host(params["down"], h).T

    def __call__(self, x):
        """x [T, D] -> [T, D], or a batch [B, T, D] -> [B, T, D].

        A 3-D input runs the batched path: one vmapped kernel launch per
        matrix for the whole batch, replacing the caller-side per-sequence
        loop (the inner loop of batched serving).
        """
        if x.ndim == 3:
            xt = jnp.swapaxes(x, 1, 2)             # [B, D, T]
            h = jax.nn.silu(self.gate.batched(xt)) * self.up.batched(xt)
            return jnp.swapaxes(self.down.batched(h), 1, 2)
        xt = x.T                                   # [D, T]
        h = jax.nn.silu(self.gate(xt)) * self.up(xt)
        return self.down(h).T

    @property
    def flops_per_token(self) -> int:
        return (self.gate.flops_per_col + self.up.flops_per_col
                + self.down.flops_per_col)


# ---------------------------------------------------------------------------
# serving integration (DESIGN.md §12)
# ---------------------------------------------------------------------------


def sparsify_ffn_params(cfg, params, *, keep_density=0.5,
                        stream_limit: int | None = None):
    """Convert every scanned FFN sub-layer of a model to ``path="spgemm"``.

    The serving-engine entry point (DESIGN.md §12): walks the model's
    super-block table and, for each sub-layer carrying a dense SwiGLU
    ``ffn`` subtree (kinds ``attn_ffn`` / ``attn_ffn_cross`` / ...),
    replaces its stacked ``[n_rep, d_in, d_out]`` weight leaves with CSC
    value stacks ``{"gate"/"up"/"down": [n_rep, nnz]}`` on a pattern
    *shared across the scanned reps* (:meth:`SparseMatmul
    .from_shared_pattern` — one mask per matrix, so the scan body traces
    once and all reps replay one cached plan).  MoE and shared-table
    sub-layers are left dense.

    Returns ``(new_params, overlay)``: ``new_params`` is the params pytree
    with the sparse value stacks spliced in, ``overlay`` maps sub-layer
    keys ``"l{i}"`` to the pattern-holding :class:`SparseFFN` that
    ``decode_step(..., sparse_ffn=overlay)`` (and ``ServeEngine``) applies
    with each rep's values.  Raises if the config has no scanned FFN
    sub-layer to convert.
    """
    from repro.models.blocks import superblock_table

    _, kinds, _, _ = superblock_table(cfg)
    overlay = {}
    new_blocks = dict(params["blocks"])
    for i, _kind in enumerate(kinds):
        li = f"l{i}"
        sub = params["blocks"].get(li, {})
        if "ffn" not in sub:
            continue
        fp = sub["ffn"]

        def shared(name):
            w = np.asarray(fp[name]["w"])        # [R, d_in, d_out]
            return SparseMatmul.from_shared_pattern(
                w.transpose(0, 2, 1),            # -> W @ x orientation
                keep_density=keep_density, stream_limit=stream_limit)

        gate, gv = shared("gate")
        up, uv = shared("up")
        down, dv = shared("down")
        overlay[li] = SparseFFN(gate, up, down)
        new_blocks[li] = dict(sub, ffn={"gate": gv, "up": uv, "down": dv})
    if not overlay:
        raise ValueError(
            f"config {cfg.name!r} (family {cfg.family!r}) has no scanned "
            "dense-FFN sub-layer to convert to path='spgemm'")
    return dict(params, blocks=new_blocks), overlay


def densify_ffn_params(cfg, params, overlay):
    """Inverse view of :func:`sparsify_ffn_params` for reference checks.

    Scatters each overlay matrix's ``[n_rep, nnz]`` value stacks back into
    dense ``[n_rep, d_in, d_out]`` weight leaves (zeros at pruned
    positions), so a plain dense ``decode_step`` over the result is the
    numerical oracle for the sparse decode path (tests, and the honesty
    check in ``benchmarks/serving_spgemm.py``).
    """
    new_blocks = dict(params["blocks"])
    for li, sffn in overlay.items():
        vals = params["blocks"][li]["ffn"]
        dense = {}
        for name, mat in (("gate", sffn.gate), ("up", sffn.up),
                          ("down", sffn.down)):
            c = mat.w_csc
            rows = np.asarray(c.row_indices)[: c.nnz]
            cols = np.repeat(np.arange(c.shape[1], dtype=np.int32),
                             np.diff(np.asarray(c.col_ptr)))
            v = np.asarray(vals[name], np.float32)        # [R, nnz]
            w = np.zeros((v.shape[0],) + tuple(c.shape), np.float32)
            w[:, rows, cols] = v
            # back to the param table's [R, d_in, d_out] orientation
            dense[name] = {"w": jnp.asarray(w.transpose(0, 2, 1))}
        new_blocks[li] = dict(new_blocks[li], ffn=dense)
    return dict(params, blocks=new_blocks)

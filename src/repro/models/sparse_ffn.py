"""SparseFFN: pruned-weight FFN served through the paper's hybrid policy.

The TPU re-targeting of H-SPA(t)/H-HASH(t) (DESIGN.md §3.1): the switching
statistic is block-level density instead of per-column Op_j, and the
execution regimes are
  * dense path  — plain MXU matmul (the SPA analogue: dense accumulator,
    throughput-optimal when most blocks are present), chosen when the kept-
    block fraction >= ``t_density``;
  * sparse path — the BSR Pallas kernel (kernels/bsr_spmm.py), which skips
    absent blocks entirely (the SPARS/HASH analogue), chosen for sparser
    weights;
  * spgemm path — the *differentiable* re-targeting (DESIGN.md §10): the
    pruned weight is stored as an element-level CSC whose values are
    trainable, activations ride as dense-pattern CSC value arrays, and the
    multiply is the cached SpGEMM plan's device stream
    (``core.jax_stream``) — jit-compatible and reverse-differentiable, so a
    sparse FFN can *train* with SpGEMM inside the traced step
    (``training.train_loop.build_sparse_ffn_train_step``).  Opt-in via
    ``path="spgemm"``; weight patterns are static across steps (pruned at
    conversion time), so each distinct token count plans once and every
    later step is a pure compiled replay.

``from_dense`` prunes by block magnitude to a target density. The policy is
per-matrix, decided at conversion time (weights are static at serving time,
exactly like the paper's pre-processing phase).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmm import bsr_from_dense, bsr_spmm
from repro.sparse.format import CSC, csc_from_dense


@dataclasses.dataclass
class SparseMatmul:
    """One pruned weight matrix with its chosen execution path."""

    path: str                   # "dense" | "bsr" | "spgemm"
    dense_w: jax.Array | None
    block_idx: jax.Array | None
    block_nnz: jax.Array | None
    blocks: jax.Array | None
    shape: tuple
    density: float
    w_csc: CSC | None = None    # spgemm path: static pattern, jnp values
    #: spgemm path: per-plan plan-memory-guard override (products); large
    #: FFNs * long token blocks exceed the global default, and mutating
    #: fast.STREAM_MAX_PRODUCTS would re-key every cached plan
    stream_limit: int | None = None
    # spgemm path: per-token-count SpGEMM plan + densify indices, resolved
    # once at trace time.  A bounded LRU: each entry pins a plan (host +
    # device stream, O(nnz_w * N)) past plan-LRU eviction, so workloads
    # cycling through many distinct token counts must not accumulate them
    _spgemm_memo: "OrderedDict" = dataclasses.field(
        default_factory=OrderedDict, repr=False)

    SPGEMM_MEMO_SIZE = 8        # distinct token counts held per matrix

    @classmethod
    def from_dense(cls, w, *, bm=8, bk=8, keep_density=0.5,
                   t_density=0.75, path: str | None = None,
                   stream_limit: int | None = None) -> "SparseMatmul":
        """Prune ``w`` by block magnitude and pick an execution path.

        ``path=None`` applies the serving policy (dense above ``t_density``,
        BSR below); ``path="spgemm"`` forces the differentiable CSC/SpGEMM
        path (DESIGN.md §10), whose values are trainable; ``"dense"`` /
        ``"bsr"`` force the serving paths.  ``stream_limit`` raises this
        matrix's plan-memory guard (the spgemm path's stream holds
        ``nnz_w * tokens`` products, which outgrows the global default at
        large FFN sizes) without touching the global knob.
        """
        if path not in (None, "dense", "bsr", "spgemm"):
            raise ValueError(
                f"unknown path {path!r}; None, 'dense', 'bsr' or 'spgemm'")
        w = np.asarray(w, np.float32)
        m, k = w.shape
        n_rb, n_cb = m // bm, k // bk
        tiles = w.reshape(n_rb, bm, n_cb, bk).transpose(0, 2, 1, 3)
        norms = np.abs(tiles).max(axis=(2, 3))
        n_keep = max(1, int(round(keep_density * n_rb * n_cb)))
        thresh = np.partition(norms.reshape(-1), -n_keep)[-n_keep]
        pruned = np.where((norms >= thresh)[:, :, None, None], tiles, 0.0)
        w_pruned = pruned.transpose(0, 2, 1, 3).reshape(m, k)
        density = float((norms >= thresh).mean())
        if path == "spgemm":
            csc = csc_from_dense(w_pruned)
            csc = CSC(jnp.asarray(np.asarray(csc.values, np.float32)),
                      csc.row_indices, csc.col_ptr, csc.shape)
            return cls("spgemm", None, None, None, None, (m, k), density,
                       w_csc=csc, stream_limit=stream_limit)
        if path == "dense" or (path is None and density >= t_density):
            # paper's hybrid switch: stay dense (SPA)
            return cls("dense", jnp.asarray(w_pruned), None, None, None,
                       (m, k), density)
        bi, bn, blocks = bsr_from_dense(w_pruned, bm, bk)
        return cls("bsr", None, jnp.asarray(bi), jnp.asarray(bn),
                   jnp.asarray(blocks), (m, k), density)

    # -- spgemm path (DESIGN.md §10) -------------------------------------

    @property
    def w_values(self) -> jax.Array:
        """Trainable weight values (spgemm path): the CSC value array."""
        if self.path != "spgemm":
            raise ValueError(
                f"w_values is the spgemm path's parameter array "
                f"(this matmul runs path={self.path!r})")
        return self.w_csc.values

    def _spgemm_plan(self, n: int):
        """Plan W @ X for X dense [K, N], memoized per token count.

        The activation operand is a *fully dense* pattern — its structure
        depends only on (K, N), so the symbolic phase runs once per
        distinct N (at trace time) and the numeric phase is the plan's
        jitted device stream.  Returns ``(plan, scatter_rows,
        scatter_cols)`` where the scatter indices densify the canonical
        CSC result into ``[M, N]`` (plan-static numpy, free under jit).
        """
        if n in self._spgemm_memo:
            self._spgemm_memo.move_to_end(n)
            return self._spgemm_memo[n]
        from repro.core.api import cached_plan

        m, k = self.shape
        x_pat = CSC(np.zeros(k * n, np.float32),
                    np.tile(np.arange(k, dtype=np.int32), n),
                    np.arange(n + 1, dtype=np.int32) * k, (k, n))
        w_pat = CSC(np.zeros(self.w_csc.nnz, np.float32),
                    self.w_csc.row_indices, self.w_csc.col_ptr,
                    self.shape)
        plan = cached_plan(w_pat, x_pat, "expand", backend="jax",
                           stream_limit=self.stream_limit)
        s = plan.stream
        if s is None:
            raise ValueError(
                "spgemm-path weight stream exceeds the plan-memory guard; "
                "pass stream_limit= to from_dense/from_params (per-plan "
                "override) or shrink the token block")
        cols = np.repeat(np.arange(n, dtype=np.int32),
                         np.diff(s.c_col_ptr))
        self._spgemm_memo[n] = (plan, s.c_rows, cols)
        while len(self._spgemm_memo) > self.SPGEMM_MEMO_SIZE:
            self._spgemm_memo.popitem(last=False)
        return self._spgemm_memo[n]

    def apply_values(self, w_values, x):
        """y [M, N] = W @ x for trainable values ``w_values`` (spgemm path).

        Pure and jit/grad/vmap-compatible: ``w_values`` and ``x`` may be
        tracers; the plan lookup keys only on ``x``'s static shape.
        Column-major flattening turns the dense activations into the CSC
        value array of the plan's dense B pattern, and the plan's canonical
        result scatters back to dense through plan-static indices.
        """
        if self.path != "spgemm":
            raise ValueError(
                f"apply_values needs path='spgemm' (got {self.path!r})")
        n = x.shape[1]
        plan, rows, cols = self._spgemm_plan(int(n))
        c_vals = plan.stream_apply(w_values, x.T.reshape(-1))
        # plan-static, unique, in-bounds scatter indices: skip XLA's
        # bounds-check/dup handling (same rationale as the stream gathers)
        return jnp.zeros(self.shape[0:1] + (int(n),), c_vals.dtype).at[
            rows, cols].set(c_vals, mode="promise_in_bounds",
                            unique_indices=True)

    def __call__(self, x, *, bn=None, interpret=True):
        """y = W @ x for x [K, N]."""
        if self.path == "dense":
            return self.dense_w @ x
        if self.path == "spgemm":
            return self.apply_values(self.w_values, x)
        n = x.shape[1]
        bn = bn or min(128, n)
        return bsr_spmm(self.block_idx, self.block_nnz, self.blocks, x,
                        bn=bn, interpret=interpret)

    def batched(self, xs, *, bn=None, interpret=True):
        """y [B, M, N] = W @ xs[b] for xs [B, K, N] — one launch for all B.

        The weight pattern is static (pruned at conversion time), so a batch
        of activations is exactly the same-pattern regime as batched SpGEMM
        (DESIGN.md §7): the BSR structure operands are shared and only the
        dense activations carry the batch axis, vmapped into a single
        leading-grid-dimension launch instead of B Python round-trips.
        """
        if self.path == "dense":
            return self.dense_w @ xs              # broadcasts over the batch
        if self.path == "spgemm":
            # same-pattern batched regime: the plan's vmapped device stream
            return jax.vmap(
                lambda x: self.apply_values(self.w_values, x))(xs)
        n = xs.shape[2]
        bn = bn or min(128, n)
        f = lambda x: bsr_spmm(self.block_idx, self.block_nnz, self.blocks,
                               x, bn=bn, interpret=interpret)
        return jax.vmap(f)(xs)

    @property
    def flops_per_col(self) -> int:
        m, k = self.shape
        if self.path == "dense":
            return 2 * m * k
        if self.path == "spgemm":
            return 2 * self.w_csc.nnz
        nb = int(np.asarray(self.block_nnz).sum())
        bm, bk = self.blocks.shape[2], self.blocks.shape[3]
        return 2 * nb * bm * bk


@dataclasses.dataclass
class SparseFFN:
    """SwiGLU FFN with pruned gate/up/down matrices."""

    gate: SparseMatmul
    up: SparseMatmul
    down: SparseMatmul

    @classmethod
    def from_params(cls, p, *, keep_density=0.4, t_density=0.75, bm=8, bk=8,
                    path: str | None = None,
                    stream_limit: int | None = None):
        mk = lambda w: SparseMatmul.from_dense(
            np.asarray(w).T, bm=bm, bk=bk, keep_density=keep_density,
            t_density=t_density, path=path, stream_limit=stream_limit)
        return cls(mk(p["gate"]["w"]), mk(p["up"]["w"]), mk(p["down"]["w"]))

    # -- differentiable spgemm path (DESIGN.md §10) ----------------------

    def trainable_params(self) -> dict:
        """The trainable weight-value pytree of an all-spgemm-path FFN."""
        mats = {"gate": self.gate, "up": self.up, "down": self.down}
        bad = [k for k, m in mats.items() if m.path != "spgemm"]
        if bad:
            raise ValueError(
                f"trainable_params needs every matmul on path='spgemm' "
                f"(convert with from_params(..., path='spgemm')); "
                f"{bad} are not")
        return {k: m.w_values for k, m in mats.items()}

    def apply(self, params, x):
        """Functional forward pass: ``params`` override the stored values.

        ``x`` is ``[T, D]`` (or a batch ``[B, T, D]``); the three matmuls
        run the differentiable SpGEMM stream with ``params['gate'/'up'/
        'down']`` as the weight values, so ``jax.grad`` of anything
        downstream reaches the sparse weights (the values of a *fixed*
        pruned pattern — structure never re-derives during training,
        exactly the paper's static pre-processing contract).
        """
        if x.ndim == 3:
            return jax.vmap(lambda xb: self.apply(params, xb))(x)
        xt = x.T                                   # [D, T]
        h = (jax.nn.silu(self.gate.apply_values(params["gate"], xt))
             * self.up.apply_values(params["up"], xt))
        return self.down.apply_values(params["down"], h).T

    def __call__(self, x):
        """x [T, D] -> [T, D], or a batch [B, T, D] -> [B, T, D].

        A 3-D input runs the batched path: one vmapped kernel launch per
        matrix for the whole batch, replacing the caller-side per-sequence
        loop (the inner loop of batched serving).
        """
        if x.ndim == 3:
            xt = jnp.swapaxes(x, 1, 2)             # [B, D, T]
            h = jax.nn.silu(self.gate.batched(xt)) * self.up.batched(xt)
            return jnp.swapaxes(self.down.batched(h), 1, 2)
        xt = x.T                                   # [D, T]
        h = jax.nn.silu(self.gate(xt)) * self.up(xt)
        return self.down(h).T

    @property
    def flops_per_token(self) -> int:
        return (self.gate.flops_per_col + self.up.flops_per_col
                + self.down.flops_per_col)

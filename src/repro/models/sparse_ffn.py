"""SparseFFN: pruned-weight FFN served through the paper's hybrid policy.

The TPU re-targeting of H-SPA(t)/H-HASH(t) (DESIGN.md §3.1): the switching
statistic is block-level density instead of per-column Op_j, and the two
execution regimes are
  * dense path  — plain MXU matmul (the SPA analogue: dense accumulator,
    throughput-optimal when most blocks are present), chosen when the kept-
    block fraction >= ``t_density``;
  * sparse path — the BSR Pallas kernel (kernels/bsr_spmm.py), which skips
    absent blocks entirely (the SPARS/HASH analogue), chosen for sparser
    weights.

``from_dense`` prunes by block magnitude to a target density. The policy is
per-matrix, decided at conversion time (weights are static at serving time,
exactly like the paper's pre-processing phase).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmm import bsr_from_dense, bsr_spmm


@dataclasses.dataclass
class SparseMatmul:
    """One pruned weight matrix with its chosen execution path."""

    path: str                   # "dense" | "bsr"
    dense_w: jax.Array | None
    block_idx: jax.Array | None
    block_nnz: jax.Array | None
    blocks: jax.Array | None
    shape: tuple
    density: float

    @classmethod
    def from_dense(cls, w, *, bm=8, bk=8, keep_density=0.5,
                   t_density=0.75) -> "SparseMatmul":
        w = np.asarray(w, np.float32)
        m, k = w.shape
        n_rb, n_cb = m // bm, k // bk
        tiles = w.reshape(n_rb, bm, n_cb, bk).transpose(0, 2, 1, 3)
        norms = np.abs(tiles).max(axis=(2, 3))
        n_keep = max(1, int(round(keep_density * n_rb * n_cb)))
        thresh = np.partition(norms.reshape(-1), -n_keep)[-n_keep]
        pruned = np.where((norms >= thresh)[:, :, None, None], tiles, 0.0)
        w_pruned = pruned.transpose(0, 2, 1, 3).reshape(m, k)
        density = float((norms >= thresh).mean())
        if density >= t_density:   # paper's hybrid switch: stay dense (SPA)
            return cls("dense", jnp.asarray(w_pruned), None, None, None,
                       (m, k), density)
        bi, bn, blocks = bsr_from_dense(w_pruned, bm, bk)
        return cls("bsr", None, jnp.asarray(bi), jnp.asarray(bn),
                   jnp.asarray(blocks), (m, k), density)

    def __call__(self, x, *, bn=None, interpret=True):
        """y = W @ x for x [K, N]."""
        if self.path == "dense":
            return self.dense_w @ x
        n = x.shape[1]
        bn = bn or min(128, n)
        return bsr_spmm(self.block_idx, self.block_nnz, self.blocks, x,
                        bn=bn, interpret=interpret)

    def batched(self, xs, *, bn=None, interpret=True):
        """y [B, M, N] = W @ xs[b] for xs [B, K, N] — one launch for all B.

        The weight pattern is static (pruned at conversion time), so a batch
        of activations is exactly the same-pattern regime as batched SpGEMM
        (DESIGN.md §7): the BSR structure operands are shared and only the
        dense activations carry the batch axis, vmapped into a single
        leading-grid-dimension launch instead of B Python round-trips.
        """
        if self.path == "dense":
            return self.dense_w @ xs              # broadcasts over the batch
        n = xs.shape[2]
        bn = bn or min(128, n)
        f = lambda x: bsr_spmm(self.block_idx, self.block_nnz, self.blocks,
                               x, bn=bn, interpret=interpret)
        return jax.vmap(f)(xs)

    @property
    def flops_per_col(self) -> int:
        m, k = self.shape
        if self.path == "dense":
            return 2 * m * k
        nb = int(np.asarray(self.block_nnz).sum())
        bm, bk = self.blocks.shape[2], self.blocks.shape[3]
        return 2 * nb * bm * bk


@dataclasses.dataclass
class SparseFFN:
    """SwiGLU FFN with pruned gate/up/down matrices."""

    gate: SparseMatmul
    up: SparseMatmul
    down: SparseMatmul

    @classmethod
    def from_params(cls, p, *, keep_density=0.4, t_density=0.75, bm=8, bk=8):
        mk = lambda w: SparseMatmul.from_dense(
            np.asarray(w).T, bm=bm, bk=bk, keep_density=keep_density,
            t_density=t_density)
        return cls(mk(p["gate"]["w"]), mk(p["up"]["w"]), mk(p["down"]["w"]))

    def __call__(self, x):
        """x [T, D] -> [T, D], or a batch [B, T, D] -> [B, T, D].

        A 3-D input runs the batched path: one vmapped kernel launch per
        matrix for the whole batch, replacing the caller-side per-sequence
        loop (the inner loop of batched serving).
        """
        if x.ndim == 3:
            xt = jnp.swapaxes(x, 1, 2)             # [B, D, T]
            h = jax.nn.silu(self.gate.batched(xt)) * self.up.batched(xt)
            return jnp.swapaxes(self.down.batched(h), 1, 2)
        xt = x.T                                   # [D, T]
        h = jax.nn.silu(self.gate(xt)) * self.up(xt)
        return self.down(h).T

    @property
    def flops_per_token(self) -> int:
        return (self.gate.flops_per_col + self.up.flops_per_col
                + self.down.flops_per_col)

"""Super-block assembly: every architecture is a scan over repeated blocks.

A *super-block* is the smallest repeating unit of a family (one layer for
dense/MoE/SSM; ``attn_every`` Mamba layers + one shared attention block for
zamba2; ``cross_attn_every`` layers with a trailing cross-attention layer for
the VLM; alternating dense/MoE pair for llama4). Stacking super-block params
on a leading 'layers' axis and scanning keeps the HLO size O(1) in depth —
essential for 100-layer dry-run compiles (DESIGN.md §5).

Sub-layer kinds: "attn_ffn", "attn_moe", "mamba", "shared_attn" (applies the
tied block), "attn_ffn_cross", "enc_attn_ffn", "dec_attn_cross_ffn".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as pp
from repro.models.layers import (
    attention, attention_table, attention_decode, cross_attention_cached,
    dense, ffn, ffn_table, rms_norm,
)
from repro.models.moe import moe_aux_loss, moe_ffn, moe_table
from repro.models.ssm import mamba_forward, mamba_init_state, mamba_table


def block_structure(cfg):
    """(sub-layer kinds per super-block, n_rep, has_shared)."""
    f = cfg.family
    if f in ("dense",):
        return ["attn_ffn"], cfg.n_layers, False
    if f == "moe":
        il = cfg.moe.interleave
        if il == 1:
            return ["attn_moe"], cfg.n_layers, False
        kinds = ["attn_ffn"] * (il - 1) + ["attn_moe"]
        assert cfg.n_layers % il == 0
        return kinds, cfg.n_layers // il, False
    if f == "ssm":
        return ["mamba"], cfg.n_layers, False
    if f == "hybrid":
        k = cfg.attn_every
        assert cfg.n_layers % k == 0
        return ["mamba"] * k + ["shared_attn"], cfg.n_layers // k, True
    if f == "vlm":
        k = cfg.cross_attn_every
        assert cfg.n_layers % k == 0
        kinds = ["attn_ffn"] * (k - 1) + ["attn_ffn_cross"]
        return kinds, cfg.n_layers // k, False
    if f == "encdec":
        return ["dec_attn_cross_ffn"], cfg.n_layers, False
    raise ValueError(f)


def _sub_table(cfg, kind):
    if kind == "attn_ffn":
        return {"ln1": pp.rmsnorm(cfg.d_model), "attn": attention_table(cfg),
                "ln2": pp.rmsnorm(cfg.d_model), "ffn": ffn_table(cfg)}
    if kind == "attn_moe":
        return {"ln1": pp.rmsnorm(cfg.d_model), "attn": attention_table(cfg),
                "ln2": pp.rmsnorm(cfg.d_model), "moe": moe_table(cfg)}
    if kind == "mamba":
        return {"ln": pp.rmsnorm(cfg.d_model), "mamba": mamba_table(cfg)}
    if kind == "shared_attn":
        return {}  # weights live in the shared table
    if kind == "attn_ffn_cross":
        return {"ln1": pp.rmsnorm(cfg.d_model), "attn": attention_table(cfg),
                "lnx": pp.rmsnorm(cfg.d_model),
                "xattn": attention_table(cfg, bias=False),
                "xgate": pp.Leaf((), (), "zeros"),
                "ln2": pp.rmsnorm(cfg.d_model), "ffn": ffn_table(cfg)}
    if kind == "enc_attn_ffn":
        return {"ln1": pp.rmsnorm(cfg.d_model), "attn": attention_table(cfg),
                "ln2": pp.rmsnorm(cfg.d_model), "ffn": ffn_table(cfg)}
    if kind == "dec_attn_cross_ffn":
        return {"ln1": pp.rmsnorm(cfg.d_model), "attn": attention_table(cfg),
                "lnx": pp.rmsnorm(cfg.d_model),
                "xattn": attention_table(cfg, bias=False),
                "ln2": pp.rmsnorm(cfg.d_model), "ffn": ffn_table(cfg)}
    raise ValueError(kind)


def superblock_table(cfg):
    kinds, n_rep, has_shared = block_structure(cfg)
    table = {f"l{i}": _sub_table(cfg, k) for i, k in enumerate(kinds)}
    shared = None
    if has_shared:
        shared = {"ln1": pp.rmsnorm(cfg.d_model),
                  "attn": attention_table(cfg),
                  "ln2": pp.rmsnorm(cfg.d_model), "ffn": ffn_table(cfg)}
    return table, kinds, n_rep, shared


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _sub_forward(p, shared, cfg, kind, h, *, memory=None, causal=True,
                 sffn=None):
    """One sub-layer, full sequence. Returns (h, aux_loss).

    ``sffn`` is this sub-layer's spgemm-path FFN overlay (DESIGN.md §12):
    a shared-pattern :class:`~repro.models.sparse_ffn.SparseFFN` applied
    with the rep's value stacks ``p["ffn"]`` in place of the dense SwiGLU.
    """
    aux = jnp.float32(0)
    if kind in ("attn_ffn", "attn_moe", "attn_ffn_cross", "enc_attn_ffn",
                "dec_attn_cross_ffn"):
        h = h + attention(p["attn"], cfg, rms_norm(p["ln1"], h, cfg.norm_eps),
                          causal=causal and kind != "enc_attn_ffn")
        if kind in ("attn_ffn_cross", "dec_attn_cross_ffn"):
            xa = attention(p["xattn"], cfg,
                           rms_norm(p["lnx"], h, cfg.norm_eps),
                           kv_src=memory, causal=False, use_rope=False)
            if "xgate" in p:
                xa = jnp.tanh(p["xgate"]).astype(h.dtype) * xa
            h = h + xa
        hn = rms_norm(p["ln2"], h, cfg.norm_eps)
        if kind == "attn_moe":
            aux = moe_aux_loss(p["moe"], cfg, hn)
            h = h + moe_ffn(p["moe"], cfg, hn)
        elif sffn is not None:
            h = h + sffn.apply(p["ffn"], hn)
        else:
            h = h + ffn(p["ffn"], hn)
        return h, aux
    if kind == "mamba":
        y, _ = mamba_forward(p["mamba"], cfg,
                             rms_norm(p["ln"], h, cfg.norm_eps))
        return h + y, aux
    if kind == "shared_attn":
        sp = shared
        h = h + attention(sp["attn"], cfg,
                          rms_norm(sp["ln1"], h, cfg.norm_eps), causal=True)
        h = h + ffn(sp["ffn"], rms_norm(sp["ln2"], h, cfg.norm_eps))
        return h, aux
    raise ValueError(kind)


def stage_forward(stacked, shared, cfg, kinds, h, *, memory=None,
                  causal=True, sparse_ffn=None):
    """Scan the super-block over its reps. Returns (h, total_aux)."""

    from repro.distributed.hints import hint

    sparse_ffn = sparse_ffn or {}

    def block(carry, p_rep):
        h, aux = carry
        h = hint(h, "dp", None, None)  # pin residual-stream batch sharding
        for i, kind in enumerate(kinds):
            h, a = _sub_forward(p_rep.get(f"l{i}", {}), shared, cfg, kind, h,
                                memory=memory, causal=causal,
                                sffn=sparse_ffn.get(f"l{i}"))
            aux = aux + a
        return (h, aux), None

    if cfg.remat == "full":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        block = jax.checkpoint(
            block,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    (h, aux), _ = jax.lax.scan(block, (h, jnp.float32(0)), stacked)
    return h, aux


# ---------------------------------------------------------------------------
# decode (one token against caches)
# ---------------------------------------------------------------------------


def sub_cache_shape(cfg, kind, batch, cache_len, dtype=jnp.bfloat16):
    """Zero/abstract cache for one sub-layer."""
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    kv = lambda s: jnp.zeros((batch, s, hkv, dh), dtype)
    if kind in ("attn_ffn", "attn_moe", "shared_attn"):
        return {"k": kv(cache_len), "v": kv(cache_len)}
    if kind == "mamba":
        conv, h = mamba_init_state(cfg, batch, dtype)
        return {"conv": conv, "h": h}
    if kind == "attn_ffn_cross":
        return {"k": kv(cache_len), "v": kv(cache_len),
                "xk": kv(cfg.n_image_tokens), "xv": kv(cfg.n_image_tokens)}
    if kind == "dec_attn_cross_ffn":
        return {"k": kv(cache_len), "v": kv(cache_len),
                "xk": kv(cfg.n_audio_frames), "xv": kv(cfg.n_audio_frames)}
    raise ValueError(kind)


def _sub_decode(p, shared, cfg, kind, h, cache, cur_len, *, sffn=None,
                sffn_host=False):
    if kind in ("attn_ffn", "attn_moe", "attn_ffn_cross",
                "dec_attn_cross_ffn"):
        a, ck, cv = attention_decode(
            p["attn"], cfg, rms_norm(p["ln1"], h, cfg.norm_eps),
            cache["k"], cache["v"], cur_len)
        h = h + a
        cache = dict(cache, k=ck, v=cv)
        if kind in ("attn_ffn_cross", "dec_attn_cross_ffn"):
            xa = cross_attention_cached(
                p["xattn"], cfg, rms_norm(p["lnx"], h, cfg.norm_eps),
                cache["xk"], cache["xv"])
            if "xgate" in p:
                xa = jnp.tanh(p["xgate"]).astype(h.dtype) * xa
            h = h + xa
        hn = rms_norm(p["ln2"], h, cfg.norm_eps)
        if kind == "attn_moe":
            h = h + moe_ffn(p["moe"], cfg, hn)
        elif sffn is not None:
            # spgemm-path FFN overlay (DESIGN.md §12); sffn_host runs the
            # host product stream on concrete values (the serving fallback
            # while the device plans warm — eager loop decode only)
            y = (sffn.apply_host(p["ffn"], np.asarray(hn)) if sffn_host
                 else sffn.apply(p["ffn"], hn))
            h = h + jnp.asarray(y, h.dtype)
        else:
            h = h + ffn(p["ffn"], hn)
        return h, cache
    if kind == "mamba":
        y, (conv, hs) = mamba_forward(
            p["mamba"], cfg, rms_norm(p["ln"], h, cfg.norm_eps),
            state=(cache["conv"], cache["h"]))
        return h + y, {"conv": conv, "h": hs}
    if kind == "shared_attn":
        sp = shared
        a, ck, cv = attention_decode(
            sp["attn"], cfg, rms_norm(sp["ln1"], h, cfg.norm_eps),
            cache["k"], cache["v"], cur_len)
        h = h + a
        h = h + ffn(sp["ffn"], rms_norm(sp["ln2"], h, cfg.norm_eps))
        return h, dict(cache, k=ck, v=cv)
    raise ValueError(kind)


def stage_decode(stacked, shared, cfg, kinds, h, caches, cur_len, *,
                 sparse_ffn=None):
    """Scan decode over reps; caches stacked on the rep axis."""

    sparse_ffn = sparse_ffn or {}

    def block(h, pc):
        p_rep, c_rep = pc
        new_c = {}
        for i, kind in enumerate(kinds):
            h, new_c[f"l{i}"] = _sub_decode(
                p_rep.get(f"l{i}", {}), shared, cfg, kind, h,
                c_rep[f"l{i}"], cur_len, sffn=sparse_ffn.get(f"l{i}"))
        return h, new_c

    h, new_caches = jax.lax.scan(block, h, (stacked, caches))
    return h, new_caches


def stage_decode_loop(stacked, shared, cfg, kinds, h, caches, cur_len, *,
                      sparse_ffn=None, sparse_host=True):
    """Eager python-loop spelling of :func:`stage_decode` (no scan).

    The serving fallback path (DESIGN.md §12): while the jitted sparse
    decode step is still tracing/compiling in the background, ticks run
    this loop on concrete values — same math, sub-layer by sub-layer, with
    overlay FFNs on the *host* product stream (``sparse_host=True``) so
    nothing on the tick waits for a device plan build.  Never call under a
    trace (the host FFN needs concrete operands).
    """
    sparse_ffn = sparse_ffn or {}
    n_rep = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    per_rep = []
    for r in range(n_rep):
        p_rep = jax.tree_util.tree_map(lambda a: a[r], stacked)
        c_rep = jax.tree_util.tree_map(lambda a: a[r], caches)
        new_c = {}
        for i, kind in enumerate(kinds):
            h, new_c[f"l{i}"] = _sub_decode(
                p_rep.get(f"l{i}", {}), shared, cfg, kind, h,
                c_rep[f"l{i}"], cur_len, sffn=sparse_ffn.get(f"l{i}"),
                sffn_host=sparse_host)
        per_rep.append(new_c)
    new_caches = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *per_rep)
    return h, new_caches


def stage_cache(cfg, kinds, n_rep, batch, cache_len, dtype=jnp.bfloat16):
    one = {f"l{i}": sub_cache_shape(cfg, k, batch, cache_len, dtype)
           for i, k in enumerate(kinds)}
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((n_rep,) + x.shape, x.dtype), one)

"""Mixture-of-Experts layer with sort-based capacity dispatch.

The router's top-k assignment defines a sparse tokens x experts matrix; the
dispatch ``R^T X`` and combine ``R Y`` are exactly the SpGEMM pattern of the
paper (DESIGN.md §3.2): the per-expert token count is the ``Op_j`` load
statistic, capacity is the block size, and dropping beyond capacity is the
masked-lane tail. Two execution paths:

 * ``dispatch="sort"`` (default, jit/pjit; used by the full-scale dry runs):
   flat top-k pairs are argsorted by expert, gathered, padded to per-expert
   capacity, and expert FFNs run as one batched einsum. All ops are plain
   jnp, so GSPMD shards experts over 'model' (EP) and tokens over 'data'.
 * ``dispatch="spgemm"`` (host demonstration/test path): the routing matrix
   is materialized as CSC and dispatched through ``core.spgemm`` — validates
   the equivalence end to end (E10).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import params as pp
from repro.models.layers import dense


def moe_table(cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    t = {
        "router": pp.linear(d, e, "embed", None, init="normal:0.02"),
        "gate": pp.Leaf((e, d, f), ("experts", "embed", "mlp"), "fan_in"),
        "up": pp.Leaf((e, d, f), ("experts", "embed", "mlp"), "fan_in"),
        "down": pp.Leaf((e, f, d), ("experts", "mlp", "embed"), "fan_in"),
    }
    if m.d_ff_shared:
        t["shared"] = {
            "gate": pp.linear(d, m.d_ff_shared, "embed", "mlp"),
            "up": pp.linear(d, m.d_ff_shared, "embed", "mlp"),
            "down": pp.linear(m.d_ff_shared, d, "mlp", "embed"),
        }
    return t


def _capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _n_groups(t: int, target: int = 32) -> int:
    """Largest divisor of t not exceeding ``target`` (DP-shard count).

    Decode-sized batches (t < 4096) use one group: with so few tokens the
    per-group capacity floor would multiply expert slots ~G-fold (observed
    as 256x FLOP waste on llama4 decode — §Perf iteration 3 follow-up)."""
    if t < 4096:
        return 1
    g = min(target, t)
    while t % g:
        g -= 1
    return max(g, 1)


def _dispatch_group(xg, eg, gg, *, e: int, cap: int):
    """Sort-based dispatch within one token group (all indices group-local).

    xg [Tg, D]; eg/gg [Tg, k] expert ids / gates.
    Returns (x_disp [E, cap, D], dst [Tg*k], keep [Tg*k], g_sorted, tok_sorted).
    """
    tg, d = xg.shape
    k = eg.shape[1]
    flat_e = eg.reshape(-1)
    flat_g = gg.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(tg), k)
    order = jnp.argsort(flat_e)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]
    counts = jnp.bincount(flat_e, length=e)
    seg_start = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(tg * k) - seg_start[e_sorted]
    keep = pos_in_e < cap
    dst = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)
    x_sorted = xg[tok_sorted]
    x_disp = jnp.zeros((e * cap + 1, d), xg.dtype).at[dst].add(
        jnp.where(keep[:, None], x_sorted, 0))
    return x_disp[:-1].reshape(e, cap, d), dst, keep, g_sorted, tok_sorted


def moe_ffn(p, cfg, x):
    """x [B,S,D] -> [B,S,D]. Grouped sort-based capacity dispatch.

    Tokens are split into DP-aligned groups; the permutation/gather/scatter
    of dispatch is *group-local* (no cross-shard movement — §Perf iteration
    3: the global-argsort formulation all-gathered the token tensor per MoE
    layer), and only the dispatched [G, E, cap_g, D] buffer crosses the mesh
    via the expert-parallel all-to-all, which is the minimum the computation
    requires. GShard capacity semantics (overflow dropped).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xf = x.reshape(t, d)

    logits = dense(p["router"], xf).astype(jnp.float32)     # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    from repro.distributed.hints import hint

    g = _n_groups(t)
    tg = t // g
    cap = _capacity(tg, cfg)
    xg = hint(xf.reshape(g, tg, d), "dp", None, None)
    eg = expert_idx.reshape(g, tg, k)
    gg = gate_vals.reshape(g, tg, k)

    x_disp, dst, keep, g_sorted, tok_sorted = jax.vmap(
        functools.partial(_dispatch_group, e=e, cap=cap))(xg, eg, gg)
    x_disp = hint(x_disp, "dp", "model", None, None)  # [G, E, cap, D]

    h = jnp.einsum("gecd,edf->gecf", x_disp, p["gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", x_disp, p["up"].astype(x.dtype))
    y_disp = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u,
                        p["down"].astype(x.dtype))        # [G, E, cap, D]
    y_disp = hint(y_disp, "dp", "model", None, None)

    def combine(yd, dst_g, keep_g, gs, toks):
        y_pair = yd.reshape(e * cap, d)[jnp.where(keep_g, dst_g, 0)]
        y_pair = jnp.where(keep_g[:, None], y_pair, 0) * gs[:, None]
        return jnp.zeros((tg, d), x.dtype).at[toks].add(y_pair)

    y = jax.vmap(combine)(y_disp, dst, keep, g_sorted, tok_sorted)
    y = hint(y, "dp", None, None).reshape(t, d)

    if "shared" in p:
        y = y + (dense(p["shared"]["down"],
                       jax.nn.silu(dense(p["shared"]["gate"], xf))
                       * dense(p["shared"]["up"], xf)))
    return y.reshape(b, s, d)


def moe_aux_loss(p, cfg, x):
    """Switch-style load-balance loss (fraction * mean-prob per expert)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax(dense(p["router"], xf).astype(jnp.float32), -1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.n_experts, dtype=jnp.float32), 0)
    mean_p = probs.mean(0)
    return m.n_experts * jnp.sum(frac * mean_p)


# ---------------------------------------------------------------------------
# E10: dispatch as an explicit SpGEMM through the paper's engine (host path)
# ---------------------------------------------------------------------------


def moe_dispatch_spgemm(x, expert_idx, gate_vals, n_experts: int,
                        method: str = "h-hash-256/256"):
    """Host demonstration: combine(expertify(dispatch)) via core.spgemm.

    Builds R [T, E*? ] as CSC — R[t, e] = gate weight of token t on expert e —
    and computes the dispatch X^T R (columns = experts' weighted token sums)
    with the paper's algorithms. Returns [E, D] per-expert weighted input
    sums (the linear part of dispatch), for equivalence testing against the
    dense einsum.
    """
    import numpy as np

    from repro.core import spgemm
    from repro.sparse.format import CSC, csc_from_dense, csc_to_dense

    x = np.asarray(x, np.float64)          # [T, D]
    t, d = x.shape
    k = expert_idx.shape[1]
    # routing matrix R [T, E]
    rows = np.repeat(np.arange(t), k)
    cols = np.asarray(expert_idx).reshape(-1)
    vals = np.asarray(gate_vals, np.float64).reshape(-1)
    r_dense = np.zeros((t, n_experts))
    r_dense[rows, cols] += vals
    r = csc_from_dense(r_dense)
    xt = csc_from_dense(x.T)               # [D, T] sparse view of dense x
    out = spgemm(xt, r, method=method)     # [D, E]
    return csc_to_dense(out).T             # [E, D]

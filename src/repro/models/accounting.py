"""Analytical parameter and FLOP accounting per (architecture x shape).

MODEL_FLOPS follows the assignment's convention: 6·N·D for training (N =
active parameters, D = tokens), 2·N·D for single-pass inference, plus the
quadratic attention term (not captured by N·D). SSM scan work is elementwise
(VPU) and reported separately. Used by the roofline report as the
"useful compute" numerator against HLO-measured compute.
"""

from __future__ import annotations

import jax

from repro.models.blocks import block_structure
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.lm import abstract_model


def total_params(cfg: ModelConfig) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(abstract_model(cfg)))


def _attn_params(cfg) -> int:
    return cfg.d_model * (cfg.qkv_fused_q * 2 + cfg.qkv_fused_kv * 2)


def _ffn_params(cfg, d_ff) -> int:
    return 3 * cfg.d_model * d_ff


def _mamba_params(cfg) -> int:
    s = cfg.ssm
    din = cfg.d_inner
    if s.version == 1:
        dtr = cfg.dt_rank_actual
        return (cfg.d_model * 2 * din + s.d_conv * din
                + din * (dtr + 2 * s.d_state) + dtr * din
                + din * cfg.d_model)
    nh = din // s.head_dim
    return (cfg.d_model * (2 * din + 2 * s.d_state + nh) + s.d_conv * din
            + din * cfg.d_model)


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k experts + shared only)."""
    kinds, n_rep, _ = block_structure(cfg)
    per_block = 0
    for kind in kinds:
        if kind == "mamba":
            per_block += _mamba_params(cfg)
        elif kind == "attn_moe":
            m = cfg.moe
            per_block += _attn_params(cfg)
            per_block += m.top_k * 3 * cfg.d_model * m.d_ff_expert
            per_block += 3 * cfg.d_model * m.d_ff_shared
            per_block += cfg.d_model * m.n_experts  # router
        elif kind in ("attn_ffn", "enc_attn_ffn"):
            per_block += _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        elif kind == "attn_ffn_cross":
            per_block += 2 * _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        elif kind == "dec_attn_cross_ffn":
            per_block += 2 * _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        elif kind == "shared_attn":
            per_block += _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
    total = n_rep * per_block
    if cfg.family == "encdec":  # encoder runs once per sequence too
        total += cfg.n_encoder_layers * (
            _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff))
    total += cfg.d_model * cfg.vocab_padded  # unembed projection
    return total


def _n_attn_applications(cfg) -> int:
    """Causal self-attention applications per token (for the S^2 term)."""
    kinds, n_rep, _ = block_structure(cfg)
    per = sum(1 for k in kinds if k in (
        "attn_ffn", "attn_moe", "attn_ffn_cross", "dec_attn_cross_ffn",
        "shared_attn"))
    return n_rep * per


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Global MODEL_FLOPS for one step of this cell."""
    b, s = shape.global_batch, shape.seq_len
    n_act = active_params(cfg)
    n_attn = _n_attn_applications(cfg)
    hd = cfg.n_heads * cfg.d_head
    if shape.kind == "train":
        tokens = b * s
        linear = 6 * n_act * tokens
        attn = 3 * n_attn * 2 * b * s * s * hd  # fwd 2BS^2·H·Dh (qk+pv), x3
        return {"model_flops": linear + attn, "linear": linear,
                "attention": attn, "tokens": tokens, "n_active": n_act}
    if shape.kind == "prefill":
        tokens = b * s
        linear = 2 * n_act * tokens
        attn = n_attn * 2 * b * s * s * hd
        return {"model_flops": linear + attn, "linear": linear,
                "attention": attn, "tokens": tokens, "n_active": n_act}
    # decode: one token per slot against an S-long cache
    tokens = b
    linear = 2 * n_act * tokens
    attn = n_attn * 4 * b * s * cfg.n_kv_heads * cfg.d_head
    return {"model_flops": linear + attn, "linear": linear,
            "attention": attn, "tokens": tokens, "n_active": n_act}


def local_param_bytes(cfg: ModelConfig, axis_sizes: dict,
                      mode: str = "train", dtype_bytes: int = 2) -> float:
    """Exact per-device parameter bytes under the sharding rules."""
    from repro.models.lm import model_tables
    from repro.models.params import partition_specs, abstract_params, _is_leaf
    import jax
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in axis_sizes)
    rules = {
        "__sizes__": axis_sizes,
        "embed": dp if mode == "train" else None,
        "vocab": "model", "mlp": "model", "heads": "model",
        "experts": "model" if mode == "train" else tuple(dp),
        "ssm_inner": "model", "layers": None, None: None,
    }
    table = model_tables(cfg)
    specs = partition_specs(table, rules)
    abst = abstract_params(table)
    total = 0.0
    for spec, leaf in zip(
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves(abst)):
        shards = 1
        for part in spec:
            if part is None:
                continue
            for a in (part if isinstance(part, tuple) else (part,)):
                shards *= axis_sizes[a]
        total += leaf.size * dtype_bytes / shards
    return total


def hbm_bytes_estimate(cfg: ModelConfig, shape: ShapeConfig,
                       n_devices: int, model_shards: int = 16,
                       accum: int = 1, w_local: float | None = None) -> float:
    """Per-device HBM traffic estimate (roofline memory term).

    Weights: each device reads its TP shard of every (all-gathered) weight
    per microbatch pass (fwd + bwd + remat-fwd for train). Optimizer: read +
    write moments and params once per step. Activations: ~16 bytes/token/
    d_model/layer rule of thumb (bf16 residual + block internals after
    remat). KV cache: full local shard read per decoded token.
    """
    n_total = total_params(cfg)
    if w_local is None:
        w_local = 2 * n_total / model_shards  # bf16 weight bytes, fallback
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        passes = 3 * accum            # fwd + remat fwd + bwd
        opt = 3 * (n_total / n_devices) * (2 + 1 + 1 + 8)  # p,m8,v8,scales...
        tokens_local = b * s / max(n_devices / model_shards, 1)
        act = 16 * tokens_local * cfg.d_model * cfg.n_layers / model_shards
        return w_local * passes + opt + act
    if shape.kind == "prefill":
        tokens_local = b * s / max(n_devices / model_shards, 1)
        act = 8 * tokens_local * cfg.d_model * cfg.n_layers / model_shards
        return w_local + act
    # decode
    kv_local = 0.0
    n_attn = _n_attn_applications(cfg)
    kv_global = 2 * n_attn * b * s * cfg.n_kv_heads * cfg.d_head * 2
    kv_local = kv_global / n_devices
    return w_local + kv_local


# hardware constants (TPU v5e, per assignment)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

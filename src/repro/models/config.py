"""Model + input-shape configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    interleave: int = 1        # 1 = every layer MoE; 2 = alternate dense/MoE
    capacity_factor: float = 1.25
    d_ff_shared: int = 0       # shared-expert FFN width (0 = none)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    version: int = 1           # 1 = Mamba1 (selective scan), 2 = Mamba2 (SSD)
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64         # Mamba2 only
    dt_rank: int = 0           # Mamba1; 0 => ceil(d_model/16)
    chunk: int = 64            # scan chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): one *shared* attention block applied every k
    attn_every: int = 0
    # vlm (llama-3.2-V-style): cross-attention layer every k
    cross_attn_every: int = 0
    n_image_tokens: int = 1601
    # encdec (seamless-style)
    n_encoder_layers: int = 0
    n_audio_frames: int = 4096

    # execution
    scan_layers: bool = True
    remat: str = "full"        # full | dots | none
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    logits_chunk: int = 512

    # which serve shapes apply (DESIGN.md §4)
    supports_long_context: bool = False   # sub-quadratic archs only
    has_decoder: bool = True

    @property
    def qkv_fused_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def qkv_fused_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 512) * 512

    @property
    def dt_rank_actual(self) -> int:
        if self.ssm and self.ssm.dt_rank:
            return self.ssm.dt_rank
        return -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand if self.ssm else 2) * self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig):
    """The assignment's applicability rules (DESIGN.md §4)."""
    out = [TRAIN_4K, PREFILL_32K]
    if cfg.has_decoder:
        out.append(DECODE_32K)
        if cfg.supports_long_context:
            out.append(LONG_500K)
    return tuple(out)


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=256,
        d_head=32,
        vocab=512,
        attn_q_chunk=64,
        attn_kv_chunk=64,
        logits_chunk=64,
        scan_layers=cfg.scan_layers,
        n_image_tokens=24,
        n_audio_frames=32,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if cfg.moe.d_ff_shared else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk=16)
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["n_layers"] = 4
    if cfg.cross_attn_every:
        kw["cross_attn_every"] = 2
        kw["n_layers"] = 4
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 2
        kw["n_layers"] = 2
    if cfg.moe and cfg.moe.interleave > 1:
        kw["n_layers"] = 4
    return dataclasses.replace(cfg, **kw)

"""Full language model: tables, init, train/prefill/decode entry points.

Public surface (consumed by launch/, serving/, training/):
  model_tables(cfg)            -> declarative param table (+ spec derivation)
  init_params / abstract_params
  train_loss(params, cfg, batch)            batch: tokens, labels (+aux)
  prefill(params, cfg, tokens, ...)         -> final hidden
  decode_step(params, cfg, token, cache, cur_len) -> (logits, cache)
  init_cache(cfg, batch, cache_len)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import params as pp
from repro.models.blocks import (
    block_structure, stage_cache, stage_decode, stage_decode_loop,
    stage_forward, superblock_table,
)
from repro.models.layers import (
    attention_table, embed, embed_table, ffn_table, lm_logits, lm_loss,
    rms_norm, unembed_table, dense,
)
from repro.models.params import (
    abstract_params, init_params as _init, partition_specs, stack_tables,
)

AUX_COEF = 0.01


def model_tables(cfg):
    table, kinds, n_rep, shared = superblock_table(cfg)
    t = {
        "embed": embed_table(cfg),
        "blocks": stack_tables(table, n_rep),
        "final_norm": pp.rmsnorm(cfg.d_model),
        "unembed": unembed_table(cfg),
    }
    if shared is not None:
        t["shared"] = shared
    if cfg.family == "encdec":
        enc_table = stack_tables(
            {"l0": {"ln1": pp.rmsnorm(cfg.d_model),
                    "attn": attention_table(cfg),
                    "ln2": pp.rmsnorm(cfg.d_model),
                    "ffn": ffn_table(cfg)}},
            cfg.n_encoder_layers)
        t["encoder"] = enc_table
        t["enc_norm"] = pp.rmsnorm(cfg.d_model)
    return t


def init_model(cfg, key, dtype=jnp.float32):
    return _init(model_tables(cfg), key, dtype)


def abstract_model(cfg, dtype=jnp.bfloat16):
    return abstract_params(model_tables(cfg), dtype)


def model_specs(cfg, rules):
    return partition_specs(model_tables(cfg), rules)


# ---------------------------------------------------------------------------


def _memory_from_aux(params, cfg, aux):
    """Encoder memory (encdec) or image embeddings (vlm) for cross-attn."""
    if cfg.family == "encdec":
        h = aux  # [B, S_enc, D] precomputed frame embeddings (stub frontend)
        kinds = ["enc_attn_ffn"]
        h, _ = stage_forward(params["encoder"], None, cfg, kinds, h,
                             causal=False)
        return rms_norm(params["enc_norm"], h, cfg.norm_eps)
    if cfg.family == "vlm":
        return aux  # [B, N_img, D] pre-projected patch embeddings (stub)
    return None


def backbone(params, cfg, tokens, aux=None, *, sparse_ffn=None):
    """tokens [B,S] -> final-normed hidden [B,S,D] (+ MoE aux loss).

    ``sparse_ffn`` is the spgemm-path FFN overlay from
    :func:`~repro.models.sparse_ffn.sparsify_ffn_params` (DESIGN.md §12).
    """
    h = embed(params["embed"], tokens)
    memory = _memory_from_aux(params, cfg, aux)
    _, kinds, _, _ = superblock_table(cfg)
    h, aux_loss = stage_forward(
        params["blocks"], params.get("shared"), cfg, kinds, h, memory=memory,
        sparse_ffn=sparse_ffn)
    return rms_norm(params["final_norm"], h, cfg.norm_eps), aux_loss


def train_loss(params, cfg, batch, *, sparse_ffn=None):
    """batch: dict(tokens [B,S], labels [B,S], aux?) -> scalar loss."""
    h, aux_loss = backbone(params, cfg, batch["tokens"], batch.get("aux"),
                           sparse_ffn=sparse_ffn)
    loss = lm_loss(params["unembed"], cfg, h, batch["labels"])
    return loss + AUX_COEF * aux_loss.astype(loss.dtype)


def prefill(params, cfg, tokens, aux=None, *, sparse_ffn=None):
    h, _ = backbone(params, cfg, tokens, aux, sparse_ffn=sparse_ffn)
    return h


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    _, kinds, n_rep, _ = superblock_table(cfg)
    return stage_cache(cfg, kinds, n_rep, batch, cache_len, dtype)


def decode_step(params, cfg, token, cache, cur_len, *, sparse_ffn=None):
    """token [B,1] int32 -> (logits [B,1,Vpad], new_cache).

    cur_len: scalar count of tokens already in the cache.  ``sparse_ffn``
    is the spgemm-path FFN overlay (DESIGN.md §12): each overlaid
    sub-layer's FFN runs the cached SpGEMM device stream on its rep's
    value stacks instead of the dense SwiGLU.
    """
    h = embed(params["embed"], token)
    _, kinds, _, _ = superblock_table(cfg)
    h, new_cache = stage_decode(
        params["blocks"], params.get("shared"), cfg, kinds, h, cache,
        cur_len, sparse_ffn=sparse_ffn)
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    return lm_logits(params["unembed"], cfg, h), new_cache


def decode_step_loop(params, cfg, token, cache, cur_len, *,
                     sparse_ffn=None, sparse_host=True):
    """Eager (no scan, no jit) spelling of :func:`decode_step`.

    The serving fallback tick (DESIGN.md §12): runs on concrete values
    with overlay FFNs on the *host* product stream, so it never waits on
    a device plan build or XLA compile in flight on the background
    builder.  Same signature/return as :func:`decode_step`.
    """
    h = embed(params["embed"], token)
    _, kinds, _, _ = superblock_table(cfg)
    h, new_cache = stage_decode_loop(
        params["blocks"], params.get("shared"), cfg, kinds, h, cache,
        cur_len, sparse_ffn=sparse_ffn, sparse_host=sparse_host)
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    return lm_logits(params["unembed"], cfg, h), new_cache

"""Model substrate: params, layers, blocks, architectures."""

from repro.models.config import (
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, shapes_for, smoke,
)
from repro.models.lm import (
    abstract_model, backbone, decode_step, decode_step_loop, init_cache,
    init_model, model_specs, model_tables, prefill, train_loss,
)

__all__ = [
    "ALL_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "TRAIN_4K",
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "shapes_for",
    "smoke", "abstract_model", "backbone", "decode_step",
    "decode_step_loop", "init_cache", "init_model", "model_specs",
    "model_tables", "prefill", "train_loss",
]

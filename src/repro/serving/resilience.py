"""Degradation state machine for the serving plan pipeline (DESIGN.md §14).

The serving engine always has two ways to decode: the jitted sparse step
(fast, but needs a successful background warm — plan build, device lift,
XLA compile) and the eager host-stream fallback (slower, but needs
nothing).  This module decides *which one the engine should be trying to
use*, as a circuit breaker per (backend, engine):

``HEALTHY``
    warms are succeeding (or none attempted yet); the engine promotes to
    the jitted step as soon as one lands.
``DEGRADED``
    recent warm failures below the pin threshold; the engine keeps
    serving on the fallback and keeps retrying warms normally.
``FALLBACK_PINNED``
    repeated failures tripped the breaker open: the engine stops burning
    builder capacity on doomed warms and serves the fallback until a
    cooldown elapses.  Then a single **half-open probe** warm runs in the
    background; one clean probe promotes back to ``HEALTHY`` (and the
    engine to jit), one failed probe re-pins with the cooldown doubled
    (capped).

The invariant that makes all of this safe to do under live traffic:
greedy decode output is bit-identical on either path, so transitions are
invisible to callers except in latency — pinned in
``tests/test_resilience.py`` with real injected faults.
"""

from __future__ import annotations

import enum
import threading
import time


class Health(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    FALLBACK_PINNED = "fallback-pinned"

    def __str__(self) -> str:     # tick_stats["health"] reads cleanly
        return self.value


class CircuitBreaker:
    """Failure-rate circuit breaker with half-open probes.

    ``degrade_after`` consecutive failures reach :attr:`Health.DEGRADED`;
    ``pin_after`` trip the breaker to :attr:`Health.FALLBACK_PINNED` for
    ``cooldown`` seconds.  While pinned, :meth:`allow_attempt` refuses
    work until the cooldown elapses, then admits exactly one probe
    (half-open): success fully resets, failure re-pins with the cooldown
    multiplied by ``cooldown_factor`` (capped at ``max_cooldown``).

    ``clock`` is injectable (default ``time.monotonic``) so tests drive
    cooldown expiry deterministically.  Thread-safe; every method may be
    called from serving ticks and builder workers concurrently.
    """

    def __init__(self, *, degrade_after: int = 1, pin_after: int = 3,
                 cooldown: float = 1.0, cooldown_factor: float = 2.0,
                 max_cooldown: float = 30.0, clock=time.monotonic):
        if pin_after < degrade_after:
            raise ValueError(
                f"pin_after ({pin_after}) must be >= degrade_after "
                f"({degrade_after})")
        self.degrade_after = degrade_after
        self.pin_after = pin_after
        self.base_cooldown = cooldown
        self.cooldown_factor = cooldown_factor
        self.max_cooldown = max_cooldown
        self._clock = clock
        self._lock = threading.Lock()
        self._failures = 0
        self._successes = 0
        self._trips = 0
        self._probes = 0
        self._half_open = False
        self._opened_at: float | None = None
        self._cooldown = cooldown

    @property
    def health(self) -> Health:
        with self._lock:
            return self._health_locked()

    def _health_locked(self) -> Health:
        if self._opened_at is not None:
            return Health.FALLBACK_PINNED
        if self._failures >= self.degrade_after:
            return Health.DEGRADED
        return Health.HEALTHY

    def allow_attempt(self) -> bool:
        """May the engine start (or keep scheduling) a warm right now?

        True while not pinned.  Pinned: False during the cooldown and
        while a probe is outstanding; True exactly once per elapsed
        cooldown — that call *is* the half-open probe, and its outcome
        must be reported via :meth:`record_success` /
        :meth:`record_failure` (or :meth:`probe_cancelled` if it never
        ran, e.g. shed by builder backpressure).
        """
        with self._lock:
            if self._opened_at is None:
                return True
            if self._half_open:
                return False
            if self._clock() - self._opened_at < self._cooldown:
                return False
            self._half_open = True
            self._probes += 1
            return True

    def record_failure(self) -> Health:
        with self._lock:
            self._failures += 1
            if self._half_open:
                # failed probe: re-pin, back off harder
                self._half_open = False
                self._opened_at = self._clock()
                self._cooldown = min(self._cooldown * self.cooldown_factor,
                                     self.max_cooldown)
                self._trips += 1
            elif self._opened_at is None \
                    and self._failures >= self.pin_after:
                self._opened_at = self._clock()
                self._trips += 1
            return self._health_locked()

    def record_success(self) -> Health:
        """One clean warm (including a clean half-open probe): full reset."""
        with self._lock:
            self._successes += 1
            self._failures = 0
            self._half_open = False
            self._opened_at = None
            self._cooldown = self.base_cooldown
            return self._health_locked()

    def probe_cancelled(self) -> None:
        """The admitted half-open probe never ran (shed / engine closed):
        re-arm so the next :meth:`allow_attempt` can probe again."""
        with self._lock:
            self._half_open = False

    def info(self) -> dict:
        with self._lock:
            return {"health": str(self._health_locked()),
                    "failures": self._failures,
                    "successes": self._successes,
                    "trips": self._trips,
                    "probes": self._probes,
                    "half_open": self._half_open,
                    "cooldown": self._cooldown}


_REGISTRY: dict = {}
_REGISTRY_LOCK = threading.Lock()


def breaker_for(backend: str, engine, **cfg) -> CircuitBreaker:
    """The process-wide breaker for one (backend, engine) pair.

    Engines that share a backend still degrade independently — a wedged
    warm on one overlay must not pin its neighbours.  ``cfg`` applies
    only on first creation; the registry is keyed by ``id(engine)`` and
    cleared by :func:`reset_breakers` (tests).
    """
    key = (backend, id(engine))
    with _REGISTRY_LOCK:
        br = _REGISTRY.get(key)
        if br is None:
            br = _REGISTRY[key] = CircuitBreaker(**cfg)
        return br


def reset_breakers() -> None:
    with _REGISTRY_LOCK:
        _REGISTRY.clear()

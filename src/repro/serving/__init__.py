"""Serving substrate: caches (models.init_cache) + batched engine."""

from repro.serving.engine import Request, ServeEngine
from repro.serving.resilience import (
    CircuitBreaker,
    Health,
    breaker_for,
    reset_breakers,
)

__all__ = ["Request", "ServeEngine", "CircuitBreaker", "Health",
           "breaker_for", "reset_breakers"]

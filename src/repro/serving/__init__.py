"""Serving substrate: caches (models.init_cache) + batched engine."""

from repro.serving.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]

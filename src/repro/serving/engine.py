"""Batched serving engine with continuous batching.

Fixed batch of slots; each decode tick feeds every active slot its next token
(prompt token while prefilling, sampled token after) through one jitted
``decode_step`` with per-slot cache lengths. New requests claim free slots
mid-flight; finished requests (EOS / max tokens) free theirs. This is
decode-granularity continuous batching — production chunked prefill is an
orthogonal extension, noted in DESIGN.md.

Stream-backed sparse serving (DESIGN.md §12): pass ``sparse_ffn`` (the
overlay from :func:`~repro.models.sparse_ffn.sparsify_ffn_params`) and the
jitted step runs each overlaid FFN on the cached SpGEMM device stream.
With a ``plan_builder``, the trace + XLA compile of that step happens on a
background thread; until it lands, ticks fall back to the eager host
product stream (:func:`~repro.models.lm.decode_step_loop`) so no tick ever
blocks on a plan build.

Resilience (DESIGN.md §14): each background warm is governed by a
:class:`~repro.serving.resilience.CircuitBreaker` — failed or timed-out
warms degrade the engine's health, repeated failures pin it to the
fallback path (no more warm submissions) until a cooldown elapses and a
half-open probe warm succeeds.  Greedy decode output is bit-identical on
both paths, so every transition is invisible to callers except in
latency.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.models.lm import decode_step, decode_step_loop, init_cache
from repro.serving.resilience import CircuitBreaker, Health


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_batch: int = 4,
                 cache_len: int = 256, seed: int = 0, aux=None,
                 sparse_ffn=None, plan_builder=None, breaker=None,
                 warm_deadline: float | None = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.cache = init_cache(cfg, max_batch, cache_len,
                                dtype=jnp.float32)
        if aux is not None:  # cross-attention memories (vlm/encdec)
            self._install_memory(aux)
        self.cur_len = np.zeros(max_batch, np.int32)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.prefill_pos = np.zeros(max_batch, np.int64)
        self.queue: deque[Request] = deque()
        self.finished: dict[int, Request] = {}
        self.rng = np.random.default_rng(seed)
        self._rid = 0
        self.sparse_ffn = sparse_ffn
        self.plan_builder = plan_builder
        self.warm_deadline = warm_deadline
        self.tick_stats = {"jit_ticks": 0, "fallback_ticks": 0,
                           "warm_submits": 0, "warm_failures": 0,
                           "health": str(Health.HEALTHY)}
        self._step = jax.jit(
            lambda p, t, c, l: decode_step(p, cfg, t, c, l,
                                           sparse_ffn=sparse_ffn))
        self._sparse_ready = threading.Event()
        self._warm_lock = threading.Lock()
        self._warm_gen = 0          # invalidates stale/abandoned warm tasks
        self._warm_inflight = False
        self._warm_started = 0.0
        self._closed = False
        if sparse_ffn is None or plan_builder is None:
            # No overlay (plain dense serving) or no builder to hide the
            # compile behind — first jitted tick pays it inline, as before.
            self.breaker = None
            self._sparse_ready.set()
        else:
            self.breaker = breaker if breaker is not None \
                else CircuitBreaker()
            self._maybe_rewarm()

    def _maybe_rewarm(self) -> None:
        """Submit a background warm if health and capacity allow.

        Called from ``__init__`` and the top of every :meth:`step`: the
        tick path is where failures surface (a warm that never lands), so
        the tick path is also where recovery is driven — when the breaker
        pins, submissions stop; when its cooldown elapses, the next tick's
        call here launches the half-open probe.  Never blocks.
        """
        if self._closed or self._sparse_ready.is_set() \
                or self.sparse_ffn is None or self.plan_builder is None:
            return
        with self._warm_lock:
            if self._warm_inflight:
                # engine-side deadline: if the warm wedged past the builder
                # watchdog (or no watchdog is armed), abandon it here so
                # the breaker can count it and a fresh warm can launch
                if self.warm_deadline is not None and (
                        time.monotonic() - self._warm_started
                        > self.warm_deadline + 0.25):
                    self._warm_gen += 1
                    self._warm_inflight = False
                    self.tick_stats["warm_failures"] += 1
                    self.breaker.record_failure()
                return
            if not self.breaker.allow_attempt():
                return
            self._warm_gen += 1
            gen = self._warm_gen
            self._warm_inflight = True
            self._warm_started = time.monotonic()
            self.tick_stats["warm_submits"] += 1
        status = self.plan_builder.submit_task(
            lambda: self._warm_task(gen), tag=("serve-warm", id(self), gen),
            deadline=self.warm_deadline, retries=1)
        if status == "shed":
            with self._warm_lock:
                if self._warm_gen == gen:
                    self._warm_inflight = False
            self.breaker.probe_cancelled()

    def _warm_task(self, gen: int):
        """Background warm: trace + compile the jitted sparse step.

        Runs on a PlanBuilder worker thread against throwaway zero inputs
        of serving shape; every overlay plan builds through the locked LRU
        as a side effect.  On success sets ``_sparse_ready`` so the next
        tick promotes from the host fallback to the compiled device step;
        either outcome is reported to the breaker via :meth:`_warm_done`
        (stale generations — a zombie thread finishing after the engine
        abandoned it — are discarded there).
        """
        if self._closed:
            return
        try:
            faults.check("warm_compile", key=("serve-warm", gen))
            cache0 = init_cache(self.cfg, self.max_batch, self.cache_len,
                                dtype=jnp.float32)
            tok0 = jnp.zeros((self.max_batch, 1), jnp.int32)
            len0 = jnp.zeros(self.max_batch, jnp.int32)
            out = self._step(self.params, tok0, cache0, len0)
            jax.block_until_ready(out)
        except BaseException as e:
            self._warm_done(gen, e)
            raise       # the builder's completion/stats still see it
        self._warm_done(gen, None)

    def _warm_done(self, gen: int, err) -> None:
        with self._warm_lock:
            if gen != self._warm_gen or self._closed:
                return      # stale generation: already abandoned/replaced
            self._warm_inflight = False
            if err is None:
                self.breaker.record_success()
                self._sparse_ready.set()
            else:
                self.tick_stats["warm_failures"] += 1
                self.breaker.record_failure()

    def close(self) -> None:
        """Detach from the (possibly shared) builder: no further warms.

        Invalidates any in-flight warm so its late completion is ignored.
        Never touches the builder itself — other engines sharing it keep
        running.  Idempotent.
        """
        with self._warm_lock:
            self._closed = True
            self._warm_gen += 1
            self._warm_inflight = False

    def stats(self) -> dict:
        """Tick counters + breaker health (+ builder info when attached)."""
        out = dict(self.tick_stats)
        if self.breaker is not None:
            out["breaker"] = self.breaker.info()
        if self.plan_builder is not None:
            out["builder"] = self.plan_builder.info()
        return out

    def sparse_ready(self) -> bool:
        """True once ticks run the compiled (jitted) decode step."""
        return self._sparse_ready.is_set()

    def wait_sparse(self, timeout: float | None = None) -> bool:
        """Block until the background warm finishes (tests, benchmarks)."""
        return self._sparse_ready.wait(timeout)

    def _install_memory(self, aux):
        """Precompute cross K/V from stub embeddings into the cache."""
        from repro.models.blocks import superblock_table
        from repro.models.layers import dense as _dense

        _, kinds, n_rep, _ = superblock_table(self.cfg)
        mem = aux  # [B, N, D]
        cfgc = self.cfg

        def per_rep(p_rep):
            out = {}
            for i, kind in enumerate(kinds):
                if kind in ("attn_ffn_cross", "dec_attn_cross_ffn"):
                    pr = jax.tree_util.tree_map(lambda a: a, p_rep[f"l{i}"])
                    k = _dense(pr["xattn"]["wk"], mem).reshape(
                        mem.shape[0], mem.shape[1], cfgc.n_kv_heads,
                        cfgc.d_head)
                    v = _dense(pr["xattn"]["wv"], mem).reshape(
                        mem.shape[0], mem.shape[1], cfgc.n_kv_heads,
                        cfgc.d_head)
                    out[f"l{i}"] = (k, v)
            return out

        mems = jax.vmap(per_rep)(self.params["blocks"])
        for key, (k, v) in mems.items():
            self.cache[key]["xk"] = k.astype(self.cache[key]["xk"].dtype)
            self.cache[key]["xv"] = v.astype(self.cache[key]["xv"].dtype)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, max_new_tokens=32, temperature=0.0,
               eos_id=None) -> int:
        prompt = list(prompt)
        if not prompt:
            # An empty prompt has no token to feed the first tick and no
            # last-generated token to resample — _next_tokens would crash
            # mid-flight. Reject at the API boundary instead.
            raise ValueError("empty prompt: a request needs >= 1 token")
        if len(prompt) > self.cache_len - 1:
            # The KV cache holds cache_len positions and the engine retires
            # a slot once cur_len hits cache_len - 1, so a longer prompt
            # could never produce a token — it would overrun the cache
            # during prefill. Reject up front rather than corrupting state.
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit: cache_len="
                f"{self.cache_len} leaves room for at most "
                f"{self.cache_len - 1} prompt tokens")
        self._rid += 1
        self.queue.append(Request(self._rid, prompt, max_new_tokens,
                                  temperature, eos_id))
        return self._rid

    def _admit(self):
        for b in range(self.max_batch):
            if self.slots[b] is None and self.queue:
                req = self.queue.popleft()
                self.slots[b] = req
                self.cur_len[b] = 0
                self.prefill_pos[b] = 0

    def _next_tokens(self):
        toks = np.zeros((self.max_batch, 1), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            pos = self.prefill_pos[b]
            if pos < len(req.prompt):
                toks[b, 0] = req.prompt[pos]
            else:
                toks[b, 0] = req.generated[-1]
        return toks

    def step(self):
        """One engine tick: admit, decode, sample, retire."""
        if self.breaker is not None:
            self._maybe_rewarm()
            self.tick_stats["health"] = str(self.breaker.health)
        self._admit()
        if all(s is None for s in self.slots):
            return False
        for b, req in enumerate(self.slots):
            if req is not None and self.cur_len[b] >= self.cache_len:
                raise AssertionError(
                    f"slot {b} would write past its KV cache "
                    f"(cur_len={self.cur_len[b]}, cache_len="
                    f"{self.cache_len}); submit() bounds were bypassed")
        toks = self._next_tokens()
        if self._sparse_ready.is_set():
            logits, self.cache = self._step(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(self.cur_len))
            self.tick_stats["jit_ticks"] += 1
        else:
            # Background warm still in flight: eager host-stream tick
            # (DESIGN.md §12) — never blocks on the plan build/compile.
            logits, self.cache = decode_step_loop(
                self.params, self.cfg, jnp.asarray(toks), self.cache,
                jnp.asarray(self.cur_len), sparse_ffn=self.sparse_ffn,
                sparse_host=True)
            self.tick_stats["fallback_ticks"] += 1
        logits = np.asarray(logits[:, 0, : self.cfg.vocab], np.float32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            self.cur_len[b] += 1
            if self.prefill_pos[b] < len(req.prompt) - 1:
                self.prefill_pos[b] += 1  # still prefilling; ignore logits
                continue
            self.prefill_pos[b] = len(req.prompt)
            if req.temperature > 0:
                p = np.exp((logits[b] - logits[b].max()) / req.temperature)
                tok = int(self.rng.choice(len(p), p=p / p.sum()))
            else:
                tok = int(np.argmax(logits[b]))
            req.generated.append(tok)
            full = self.cur_len[b] >= self.cache_len - 1
            if (len(req.generated) >= req.max_new_tokens or full
                    or (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                self.finished[req.rid] = req
                self.slots[b] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished

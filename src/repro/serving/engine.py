"""Batched serving engine with continuous batching.

Fixed batch of slots; each decode tick feeds every active slot its next token
(prompt token while prefilling, sampled token after) through one jitted
``decode_step`` with per-slot cache lengths. New requests claim free slots
mid-flight; finished requests (EOS / max tokens) free theirs. This is
decode-granularity continuous batching — production chunked prefill is an
orthogonal extension, noted in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_batch: int = 4,
                 cache_len: int = 256, seed: int = 0, aux=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.cache = init_cache(cfg, max_batch, cache_len,
                                dtype=jnp.float32)
        if aux is not None:  # cross-attention memories (vlm/encdec)
            self._install_memory(aux)
        self.cur_len = np.zeros(max_batch, np.int32)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.prefill_pos = np.zeros(max_batch, np.int64)
        self.queue: deque[Request] = deque()
        self.finished: dict[int, Request] = {}
        self.rng = np.random.default_rng(seed)
        self._rid = 0
        self._step = jax.jit(
            lambda p, t, c, l: decode_step(p, cfg, t, c, l))

    def _install_memory(self, aux):
        """Precompute cross K/V from stub embeddings into the cache."""
        from repro.models.blocks import superblock_table
        from repro.models.layers import dense as _dense

        _, kinds, n_rep, _ = superblock_table(self.cfg)
        mem = aux  # [B, N, D]
        cfgc = self.cfg

        def per_rep(p_rep):
            out = {}
            for i, kind in enumerate(kinds):
                if kind in ("attn_ffn_cross", "dec_attn_cross_ffn"):
                    pr = jax.tree_util.tree_map(lambda a: a, p_rep[f"l{i}"])
                    k = _dense(pr["xattn"]["wk"], mem).reshape(
                        mem.shape[0], mem.shape[1], cfgc.n_kv_heads,
                        cfgc.d_head)
                    v = _dense(pr["xattn"]["wv"], mem).reshape(
                        mem.shape[0], mem.shape[1], cfgc.n_kv_heads,
                        cfgc.d_head)
                    out[f"l{i}"] = (k, v)
            return out

        mems = jax.vmap(per_rep)(self.params["blocks"])
        for key, (k, v) in mems.items():
            self.cache[key]["xk"] = k.astype(self.cache[key]["xk"].dtype)
            self.cache[key]["xv"] = v.astype(self.cache[key]["xv"].dtype)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, max_new_tokens=32, temperature=0.0,
               eos_id=None) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, list(prompt), max_new_tokens,
                                  temperature, eos_id))
        return self._rid

    def _admit(self):
        for b in range(self.max_batch):
            if self.slots[b] is None and self.queue:
                req = self.queue.popleft()
                self.slots[b] = req
                self.cur_len[b] = 0
                self.prefill_pos[b] = 0

    def _next_tokens(self):
        toks = np.zeros((self.max_batch, 1), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            pos = self.prefill_pos[b]
            if pos < len(req.prompt):
                toks[b, 0] = req.prompt[pos]
            else:
                toks[b, 0] = req.generated[-1]
        return toks

    def step(self):
        """One engine tick: admit, decode, sample, retire."""
        self._admit()
        if all(s is None for s in self.slots):
            return False
        toks = self._next_tokens()
        logits, self.cache = self._step(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.cur_len))
        logits = np.asarray(logits[:, 0, : self.cfg.vocab], np.float32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            self.cur_len[b] += 1
            if self.prefill_pos[b] < len(req.prompt) - 1:
                self.prefill_pos[b] += 1  # still prefilling; ignore logits
                continue
            self.prefill_pos[b] = len(req.prompt)
            if req.temperature > 0:
                p = np.exp((logits[b] - logits[b].max()) / req.temperature)
                tok = int(self.rng.choice(len(p), p=p / p.sum()))
            else:
                tok = int(np.argmax(logits[b]))
            req.generated.append(tok)
            full = self.cur_len[b] >= self.cache_len - 1
            if (len(req.generated) >= req.max_new_tokens or full
                    or (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                self.finished[req.rid] = req
                self.slots[b] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished

"""Pallas TPU kernel: SPARS lock-step SpGEMM (Algorithm 3).

Faithful TPU transliteration of the paper's lane-per-column dataflow: a block
of L C-columns advances in lock-step, one intermediate product per lane per
step, with cursor vectors ``vIndices_B`` / ``vCounter_A`` and masked lanes for
exhausted columns. The per-lane dense accumulators (``SPA_values``/``flags``)
are an ``[m, L]`` VMEM tile. RVV indexed loads become one-hot MXU gathers;
indexed stores become one-hot mask FMAs (races impossible: one product per
lane per step, private accumulator column per lane — the paper's write-
independence argument by layout).

The per-block trip count (max Op_j in the block) is data-dependent; it rides
in as a scalar-prefetch operand per grid step, exactly how a production TPU
kernel consumes CSC pointer structure (PrefetchScalarGridSpec).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spars_kernel(steps_ref,            # scalar prefetch: [n_blocks] int32
                  b_rows_ref, b_vals_ref, b_nnz_ref,
                  a_rows_ref, a_vals_ref, a_nnz_ref,
                  out_ref, flags_ref, *, m: int, za: int, n_a: int):
    L, zb = b_rows_ref.shape
    steps = steps_ref[pl.program_id(0)]
    a_rows_f = a_rows_ref[...].astype(jnp.float32)
    a_vals = a_vals_ref[...]
    a_nnz_f = a_nnz_ref[...].astype(jnp.float32)
    b_nnz = b_nnz_ref[...]
    iota_na = jax.lax.broadcasted_iota(jnp.int32, (L, n_a), 1)
    iota_zb = jax.lax.broadcasted_iota(jnp.int32, (L, zb), 1)
    iota_za = jax.lax.broadcasted_iota(jnp.int32, (L, za), 1)
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (m, L), 0)

    def step(_, carry):
        vidx_b, vcnt_a, acc, flags = carry
        active = vidx_b < b_nnz                           # [L] vMask
        # -- indexed vector load of vB (gather via one-hot over this lane's
        #    B column entries)
        sel_b = (vidx_b[:, None] == iota_zb).astype(acc.dtype)
        bk = jnp.round((sel_b * b_rows_ref[...]).sum(1)).astype(jnp.int32)
        bv = (sel_b * b_vals_ref[...]).sum(1)             # [L]
        # -- indexed vector load of vA (row gather over the A table, MXU)
        oh = (bk[:, None] == iota_na).astype(acc.dtype)   # [L, n_a]
        ar_all = oh @ a_rows_f                            # [L, za]
        av_all = oh @ a_vals
        an = jnp.round(oh @ a_nnz_f).astype(jnp.int32)    # [L] col lengths
        sel_a = (vcnt_a[:, None] == iota_za).astype(acc.dtype)
        r = jnp.round((sel_a * ar_all).sum(1)).astype(jnp.int32)  # [L]
        av = (sel_a * av_all).sum(1)
        # -- FMA + indexed store into the [m, L] accumulator
        contrib = jnp.where(active, av * bv, 0.0)
        hit = (iota_m == r[None, :]).astype(acc.dtype)
        hit = hit * active[None, :].astype(acc.dtype)
        acc = acc + hit * contrib[None, :]
        flags = jnp.maximum(flags, hit)
        # -- cursor update (Algorithm 3 lines 15-19)
        last = vcnt_a + 1 >= an
        vcnt_a = jnp.where(active & ~last, vcnt_a + 1, 0)
        vidx_b = vidx_b + (active & last).astype(vidx_b.dtype)
        return vidx_b, vcnt_a, acc, flags

    init = (
        jnp.zeros((L,), jnp.int32),
        jnp.zeros((L,), jnp.int32),
        jnp.zeros((m, L), out_ref.dtype),
        jnp.zeros((m, L), out_ref.dtype),
    )
    _, _, acc, flags = jax.lax.fori_loop(0, steps, step, init)
    out_ref[...] = acc
    flags_ref[...] = flags


@functools.partial(
    jax.jit, static_argnames=("m", "block_cols", "interpret"))
def spars_spgemm(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, steps,
                 *, m: int, block_cols: int = 128, interpret: bool = True):
    """Dense C [m, n_b] + flags, SPARS dataflow.

    ``steps[i]`` = trip count of block i (max Op_j over its columns, from the
    host-side blocking pre-process). n_b % block_cols == 0.
    """
    n_a, za = a_rows.shape
    n_b, zb = b_rows.shape
    assert n_b % block_cols == 0, (n_b, block_cols)
    n_blocks = n_b // block_cols
    kernel = functools.partial(_spars_kernel, m=m, za=za, n_a=n_a)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_cols, zb), lambda i, s: (i, 0)),
            pl.BlockSpec((block_cols, zb), lambda i, s: (i, 0)),
            pl.BlockSpec((block_cols,), lambda i, s: (i,)),
            pl.BlockSpec((n_a, za), lambda i, s: (0, 0)),
            pl.BlockSpec((n_a, za), lambda i, s: (0, 0)),
            pl.BlockSpec((n_a,), lambda i, s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((m, block_cols), lambda i, s: (0, i)),
            pl.BlockSpec((m, block_cols), lambda i, s: (0, i)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, n_b), a_vals.dtype),
            jax.ShapeDtypeStruct((m, n_b), a_vals.dtype),
        ],
        interpret=interpret,
    )(steps, b_rows, b_vals, b_nnz, a_rows, a_vals, a_nnz)


@functools.partial(
    jax.jit, static_argnames=("m", "block_cols", "interpret"))
def spars_spgemm_batched(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, steps,
                         *, m: int, block_cols: int = 128,
                         interpret: bool = True):
    """Batched SPARS: C + flags [B, m, n_b] for B same-pattern value sets.

    Value operands carry the batch axis (``a_vals [B, n_a, za]``,
    ``b_vals [B, n_b, zb]``); pattern operands and the per-block trip counts
    are shared.  One vmapped launch for all B (DESIGN.md §7).
    """
    f = functools.partial(spars_spgemm, m=m, block_cols=block_cols,
                          interpret=interpret)
    return jax.vmap(f, in_axes=(None, 0, None, None, 0, None, None))(
        a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, steps)

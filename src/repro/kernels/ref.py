"""Pure-jnp oracles for the Pallas kernels.

All kernels operate on *padded-column* sparse operands (rectangular views of
CSC produced by ``sparse.csc_to_padded_columns``): for a matrix M,
``rows [n_cols, Z]``, ``vals [n_cols, Z]``, ``nnz [n_cols]``, padding slots
masked by ``z >= nnz[col]``. Oracles are vectorized jnp (grad-compatible where
meaningful) and are what the kernel sweeps assert against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spgemm_padded_ref(
    a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, m: int
) -> jax.Array:
    """Dense C [m, n_b] for C = A @ B with both operands padded-column."""
    n_b, zb = b_rows.shape
    n_a, za = a_rows.shape
    k = b_rows  # [n_b, zb] -> A column index per B element
    ar = a_rows[k]                       # [n_b, zb, za]
    av = a_vals[k]                       # [n_b, zb, za]
    an = a_nnz[k]                        # [n_b, zb]
    bmask = jnp.arange(zb)[None, :] < b_nnz[:, None]           # [n_b, zb]
    amask = jnp.arange(za)[None, None, :] < an[..., None]      # [n_b, zb, za]
    prod = av * b_vals[..., None] * bmask[..., None] * amask
    cols = jnp.broadcast_to(
        jnp.arange(n_b)[:, None, None], prod.shape
    ).reshape(-1)
    rows = ar.reshape(-1)
    c = jnp.zeros((m, n_b), prod.dtype)
    return c.at[rows, cols].add(prod.reshape(-1))


def spars_ref(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, m: int):
    """SPARS computes the same C; flags mark structurally-touched cells."""
    c = spgemm_padded_ref(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, m)
    n_b, zb = b_rows.shape
    n_a, za = a_rows.shape
    k = b_rows
    ar = a_rows[k]
    an = a_nnz[k]
    bmask = jnp.arange(zb)[None, :] < b_nnz[:, None]
    amask = jnp.arange(za)[None, None, :] < an[..., None]
    touched = (bmask[..., None] & amask).astype(jnp.float32)
    cols = jnp.broadcast_to(
        jnp.arange(n_b)[:, None, None], touched.shape
    ).reshape(-1)
    flags = jnp.zeros((m, n_b), jnp.float32)
    flags = flags.at[ar.reshape(-1), cols].add(touched.reshape(-1))
    return c, (flags > 0).astype(jnp.float32)


def hash_tables_to_dense(table_keys, table_vals, m: int) -> jax.Array:
    """Reconstruct dense columns [m, L] from per-lane hash tables [H, L]."""
    h, l = table_keys.shape
    valid = table_keys >= 0
    rows = jnp.where(valid, table_keys, 0).reshape(-1)
    cols = jnp.broadcast_to(jnp.arange(l)[None, :], (h, l)).reshape(-1)
    vals = jnp.where(valid, table_vals, 0.0).reshape(-1)
    return jnp.zeros((m, l), table_vals.dtype).at[rows, cols].add(vals)


def bsr_spmm_ref(block_idx, block_nnz, blocks, x) -> jax.Array:
    """Block-sparse (padded BSR) @ dense.

    block_idx [n_rb, max_nb] : block-column index of each stored block
    block_nnz [n_rb]         : valid blocks per block-row
    blocks [n_rb, max_nb, bm, bk]
    x [K, N] with K = n_cb * bk
    returns [n_rb * bm, N]
    """
    n_rb, max_nb, bm, bk = blocks.shape
    k_dim, n = x.shape
    xb = x.reshape(k_dim // bk, bk, n)
    gathered = xb[block_idx]            # [n_rb, max_nb, bk, N]
    mask = (jnp.arange(max_nb)[None, :] < block_nnz[:, None])
    prod = jnp.einsum("rnik,rnkj->rij", blocks * mask[..., None, None],
                      gathered)
    return prod.reshape(n_rb * bm, n)

"""Pallas TPU kernel: block-sparse (BSR) matrix x dense matrix.

This is the *production* TPU re-targeting of the paper's idea (DESIGN.md §2):
at TPU granularity the unit of sparsity worth exploiting is an MXU-aligned
block, and the paper's hybrid density policy becomes "skip absent blocks,
dense-MXU the present ones". Used by ``models.sparse_ffn.SparseFFN`` and the
MoE dispatch-as-SpGEMM path.

Layout: padded BSR — each block-row stores up to ``max_nb`` blocks
(``blocks [n_rb, max_nb, bm, bk]``) with their block-column ids in a
scalar-prefetched index array, so the kernel's inner loop runs a
*data-dependent* trip count (block_nnz[i]) and gathers X tiles by dynamic
slice. Accumulation is f32 on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bsr_kernel(idx_ref, nnz_ref,         # scalar prefetch (SMEM)
                blocks_ref, x_ref, o_ref, *, bk: int):
    i = pl.program_id(0)
    nnz = nnz_ref[i]
    bm, bn = o_ref.shape

    def body(nb, acc):
        ci = idx_ref[i, nb]
        xt = x_ref[pl.ds(ci * bk, bk), :]          # [bk, bn] gathered tile
        blk = blocks_ref[0, nb]                    # [bm, bk]
        return acc + jnp.dot(blk, xt, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, nnz, body, jnp.zeros((bm, bn), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bn", "interpret"))
def bsr_spmm(block_idx, block_nnz, blocks, x, *, bn: int = 128,
             interpret: bool = True):
    """[n_rb*bm, N] = BSR(A) @ x.

    block_idx [n_rb, max_nb] int32, block_nnz [n_rb] int32,
    blocks [n_rb, max_nb, bm, bk], x [K, N] with N % bn == 0.
    """
    n_rb, max_nb, bm, bk = blocks.shape
    k_dim, n = x.shape
    assert n % bn == 0, (n, bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_rb, n // bn),
        in_specs=[
            pl.BlockSpec((1, max_nb, bm, bk), lambda i, j, *_: (i, 0, 0, 0)),
            pl.BlockSpec((k_dim, bn), lambda i, j, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, *_: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_bsr_kernel, bk=bk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb * bm, n), x.dtype),
        interpret=interpret,
    )(block_idx, block_nnz, blocks, x)


def bsr_from_dense(w, bm: int, bk: int, *, threshold: float = 0.0):
    """Host-side converter: dense [M, K] -> padded BSR, dropping all-|.|<=thr
    blocks. Returns (block_idx, block_nnz, blocks)."""
    import numpy as np

    w = np.asarray(w)
    m, k = w.shape
    assert m % bm == 0 and k % bk == 0, (w.shape, bm, bk)
    n_rb, n_cb = m // bm, k // bk
    tiles = w.reshape(n_rb, bm, n_cb, bk).transpose(0, 2, 1, 3)
    keep = np.abs(tiles).max(axis=(2, 3)) > threshold       # [n_rb, n_cb]
    max_nb = max(int(keep.sum(1).max()), 1)
    block_idx = np.zeros((n_rb, max_nb), np.int32)
    block_nnz = keep.sum(1).astype(np.int32)
    blocks = np.zeros((n_rb, max_nb, bm, bk), w.dtype)
    for i in range(n_rb):
        cols = np.nonzero(keep[i])[0]
        block_idx[i, : len(cols)] = cols
        blocks[i, : len(cols)] = tiles[i, cols]
    return block_idx, block_nnz, blocks

"""Host-facing jit'd wrappers around the Pallas kernels.

``spgemm_pallas`` is the device backend of ``core.api.spgemm``: it performs
the paper's host-side pre-processing (sort, block, size tables), pads CSC
operands into kernel layouts, launches the right kernel per block group, and
compacts results back to CSC. One pallas_call per distinct hash-table size H
realizes the paper's dynamic table shrinking as compile-time VMEM tile
selection (DESIGN.md §2).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.analysis import hash_table_size, preprocess
from repro.sparse.format import CSC, csc_from_dense, csc_to_padded_columns
from repro.sparse.stats import ops_per_column
from repro.kernels.spa import spa_spgemm
from repro.kernels.spars import spars_spgemm
from repro.kernels.hash_spgemm import hash_spgemm
from repro.kernels.ref import hash_tables_to_dense


def _pad_cols(rows, vals, nnz, block_cols):
    """Pad the column count to a multiple of block_cols with empty columns."""
    n = rows.shape[0]
    n_pad = -(-n // block_cols) * block_cols
    if n_pad == n:
        return rows, vals, nnz, n
    pr = np.zeros((n_pad, rows.shape[1]), rows.dtype)
    pv = np.zeros((n_pad, vals.shape[1]), vals.dtype)
    pn = np.zeros(n_pad, nnz.dtype)
    pr[:n], pv[:n], pn[:n] = rows, vals, nnz
    return pr, pv, pn, n


def _padded(m: CSC):
    rows, vals, nnz = csc_to_padded_columns(m)
    return rows.astype(np.int32), vals.astype(np.float32), nnz.astype(np.int32)


def _select_cols(arrs, cols):
    return tuple(a[cols] for a in arrs)


def _steps_per_block(ops_sel: np.ndarray, block_cols: int) -> np.ndarray:
    nb = len(ops_sel) // block_cols
    if nb == 0:
        return np.zeros(0, np.int32)
    return ops_sel.reshape(nb, block_cols).max(axis=1).astype(np.int32)


def spgemm_pallas(
    a: CSC, b: CSC, method: str = "spa", *, t: float = 40.0,
    b_min: int | None = None, b_max: int | None = None,
    accumulator: str | None = None, block_cols: int = 128,
    interpret: bool = True,
) -> CSC:
    """C = A @ B on the Pallas backend.

    The lock-step kernels use fixed-width column blocks (= ``block_cols``), so
    the b_min/b_max of the named method select the *family*; the dense-tile
    width is the kernel block. Hybrids split at ``t`` exactly as the paper.
    """
    m = a.n_rows
    n = b.n_cols
    a_rows, a_vals, a_nnz = _padded(a)
    b_rows, b_vals, b_nnz = _padded(b)
    dense = np.zeros((m, n), np.float32)

    fam = method.split("-")[0] if not method.startswith("h-") else "hybrid"
    if method.startswith("h-"):
        acc = accumulator or method.split("-")[1].split("/")[0].split("-")[0]
        acc = "hash" if "hash" in method else "spa_blocked"
    ops = ops_per_column(a, b)
    order = np.argsort(-ops, kind="stable")
    ops_sorted = ops[order]

    def run_spa(col_ids):
        if len(col_ids) == 0:
            return
        br, bv, bn = _select_cols((b_rows, b_vals, b_nnz), col_ids)
        br, bv, bn, real = _pad_cols(br, bv, bn, block_cols)
        out = np.asarray(spa_spgemm(
            jnp.asarray(a_rows), jnp.asarray(a_vals), jnp.asarray(a_nnz),
            jnp.asarray(br), jnp.asarray(bv), jnp.asarray(bn),
            m=m, block_cols=block_cols, interpret=interpret))
        dense[:, col_ids] = out[:, :real]

    def run_spars(col_ids):
        if len(col_ids) == 0:
            return
        br, bv, bn = _select_cols((b_rows, b_vals, b_nnz), col_ids)
        br, bv, bn, real = _pad_cols(br, bv, bn, block_cols)
        steps = _steps_per_block(
            np.pad(ops[col_ids], (0, len(bn) - real)), block_cols)
        out, _flags = spars_spgemm(
            jnp.asarray(a_rows), jnp.asarray(a_vals), jnp.asarray(a_nnz),
            jnp.asarray(br), jnp.asarray(bv), jnp.asarray(bn),
            jnp.asarray(steps),
            m=m, block_cols=block_cols, interpret=interpret)
        dense[:, col_ids] = np.asarray(out)[:, :real]

    def run_hash(col_ids):
        if len(col_ids) == 0:
            return
        # group blocks by their (monotone shrinking) table size H
        ops_sel = ops[col_ids]
        n_pad = -(-len(col_ids) // block_cols) * block_cols
        ops_pad = np.pad(ops_sel, (0, n_pad - len(col_ids)))
        steps_all = _steps_per_block(ops_pad, block_cols)
        hs = np.asarray([hash_table_size(int(s)) for s in steps_all])
        for H in np.unique(hs):
            sel_blocks = np.nonzero(hs == H)[0]
            cols_grp, keep = [], []
            for bi in sel_blocks:
                lo, hi = bi * block_cols, (bi + 1) * block_cols
                grp = np.arange(lo, min(hi, len(col_ids)))
                cols_grp.append(col_ids[grp])
                keep.append(len(grp))
            cat = np.concatenate(cols_grp)
            br, bv, bn = _select_cols((b_rows, b_vals, b_nnz), cat)
            br, bv, bn, real = _pad_cols(br, bv, bn, block_cols)
            steps = np.asarray(
                [steps_all[bi] for bi in sel_blocks], np.int32)
            keys, vals = hash_spgemm(
                jnp.asarray(a_rows), jnp.asarray(a_vals), jnp.asarray(a_nnz),
                jnp.asarray(br), jnp.asarray(bv), jnp.asarray(bn),
                jnp.asarray(steps),
                m=m, h=int(H), block_cols=block_cols, interpret=interpret)
            cols_dense = np.asarray(hash_tables_to_dense(keys, vals, m))
            dense[:, cat] = cols_dense[:, :real]

    if method == "spa":
        run_spa(np.arange(n))
    elif method.startswith("spars"):
        run_spars(order)
    elif method.startswith("hash"):
        run_hash(order)
    elif method.startswith("h-"):
        split = int(np.searchsorted(-ops_sorted, -t, side="right"))
        run_spa(order[:split])
        (run_hash if "hash" in method else run_spars)(order[split:])
    else:
        raise ValueError(method)

    return csc_from_dense(dense)

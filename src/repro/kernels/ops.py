"""Host-facing jit'd wrappers around the Pallas kernels.

``run_spa``/``run_spars``/``run_hash`` each launch one kernel for a single
plan :class:`~repro.core.planner.KernelGroup` — the per-family column
grouping, padding, trip counts and hash sizes all come pre-computed from the
plan instead of being re-derived per call.  One launch per distinct hash
table size H realizes the paper's dynamic table shrinking as compile-time
VMEM tile selection (DESIGN.md §2); results are compacted per group straight
into CSC by the executor, so no ``[m, n]`` dense intermediate ever exists
(DESIGN.md §6).

``run_*_batched`` are the batched twins (DESIGN.md §7): the same plan group
executed once for B same-pattern value sets — value operands carry a
leading batch axis, pattern operands are shared, and the vmapped kernels
realize the batch as a leading grid dimension.

``spgemm_pallas`` is the device backend of ``core.api.spgemm``: a thin
plan-then-execute wrapper kept for direct use (tests, notebooks).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.sparse.format import CSC
from repro.kernels.spa import spa_spgemm, spa_spgemm_batched
from repro.kernels.spars import spars_spgemm, spars_spgemm_batched
from repro.kernels.hash_spgemm import hash_spgemm, hash_spgemm_batched


def device_operand(rows: np.ndarray, vals: np.ndarray, nnz: np.ndarray):
    """Padded-column operand triple as device arrays (shared by all groups)."""
    return (jnp.asarray(rows), jnp.asarray(vals), jnp.asarray(nnz))


def run_spa(group, a_arrs, b_vals, *, m: int, block_cols: int,
            interpret: bool = True) -> np.ndarray:
    """Dense [m, n_real] tile for one SPA plan group."""
    a_rows, a_vals, a_nnz = a_arrs
    out = spa_spgemm(
        a_rows, a_vals, a_nnz,
        jnp.asarray(group.b_rows), jnp.asarray(b_vals),
        jnp.asarray(group.b_nnz),
        m=m, block_cols=block_cols, interpret=interpret)
    return np.asarray(out)[:, : group.n_real]


def run_spars(group, a_arrs, b_vals, *, m: int, block_cols: int,
              interpret: bool = True) -> np.ndarray:
    """Dense [m, n_real] tile for one SPARS plan group (plan-provided steps)."""
    a_rows, a_vals, a_nnz = a_arrs
    out, _flags = spars_spgemm(
        a_rows, a_vals, a_nnz,
        jnp.asarray(group.b_rows), jnp.asarray(b_vals),
        jnp.asarray(group.b_nnz), jnp.asarray(group.steps),
        m=m, block_cols=block_cols, interpret=interpret)
    return np.asarray(out)[:, : group.n_real]


def run_hash(group, a_arrs, b_vals, *, m: int, block_cols: int,
             interpret: bool = True):
    """Hash tables (keys, vals) [H, n_real] for one HASH plan group."""
    a_rows, a_vals, a_nnz = a_arrs
    keys, vals = hash_spgemm(
        a_rows, a_vals, a_nnz,
        jnp.asarray(group.b_rows), jnp.asarray(b_vals),
        jnp.asarray(group.b_nnz), jnp.asarray(group.steps),
        m=m, h=int(group.h), block_cols=block_cols, interpret=interpret)
    return (np.asarray(keys)[:, : group.n_real],
            np.asarray(vals)[:, : group.n_real])


def run_spa_batched(group, a_arrs, b_vals, *, m: int, block_cols: int,
                    interpret: bool = True) -> np.ndarray:
    """Dense [B, m, n_real] tiles for one SPA plan group, one launch."""
    a_rows, a_vals, a_nnz = a_arrs          # a_vals carries the batch axis
    out = spa_spgemm_batched(
        a_rows, a_vals, a_nnz,
        jnp.asarray(group.b_rows), jnp.asarray(b_vals),
        jnp.asarray(group.b_nnz),
        m=m, block_cols=block_cols, interpret=interpret)
    return np.asarray(out)[:, :, : group.n_real]


def run_spars_batched(group, a_arrs, b_vals, *, m: int, block_cols: int,
                      interpret: bool = True) -> np.ndarray:
    """Dense [B, m, n_real] tiles for one SPARS plan group, one launch."""
    a_rows, a_vals, a_nnz = a_arrs
    out, _flags = spars_spgemm_batched(
        a_rows, a_vals, a_nnz,
        jnp.asarray(group.b_rows), jnp.asarray(b_vals),
        jnp.asarray(group.b_nnz), jnp.asarray(group.steps),
        m=m, block_cols=block_cols, interpret=interpret)
    return np.asarray(out)[:, :, : group.n_real]


def run_hash_batched(group, a_arrs, b_vals, *, m: int, block_cols: int,
                     interpret: bool = True):
    """Hash tables (keys, vals) [B, H, n_real] for one HASH plan group."""
    a_rows, a_vals, a_nnz = a_arrs
    keys, vals = hash_spgemm_batched(
        a_rows, a_vals, a_nnz,
        jnp.asarray(group.b_rows), jnp.asarray(b_vals),
        jnp.asarray(group.b_nnz), jnp.asarray(group.steps),
        m=m, h=int(group.h), block_cols=block_cols, interpret=interpret)
    return (np.asarray(keys)[:, :, : group.n_real],
            np.asarray(vals)[:, :, : group.n_real])


def spgemm_pallas(
    a: CSC, b: CSC, method: str = "spa", *, t: float = 40.0,
    b_min: int | None = None, b_max: int | None = None,
    accumulator: str | None = None, block_cols: int = 128,
    tile_cols: int | None = None, interpret: bool = True,
    tile=None, plan=None,
) -> CSC:
    """C = A @ B on the Pallas backend (plan once, execute once).

    The lock-step kernels use fixed-width column blocks (= ``block_cols``), so
    the b_min/b_max of the named method select the *family*; the dense-tile
    width is the kernel block. Hybrids split at ``t`` exactly as the paper.
    ``method="auto"`` builds a tiled plan whose per-tile kernel families the
    cost model picks (DESIGN.md §8; ``tile=`` sets the grid).  Pass a cached
    ``plan`` (from ``core.plan_spgemm`` / ``core.plan_spgemm_tiled``) to
    skip the symbolic phase entirely.
    """
    del accumulator  # family is selected by the method name
    from repro.core.backends import get_backend

    contract = get_backend("pallas")
    if method != "auto" and method in contract.excluded_methods:
        raise ValueError(
            f"method {method!r} has no {contract.name} kernel family "
            "(host-only)")
    if tile is not None and (plan is not None or method != "auto"):
        raise ValueError(
            "tile= only applies to method='auto' without a held plan")
    if plan is None:
        if method == "auto":
            if (t != 40.0 or b_min is not None or b_max is not None
                    or block_cols != 128 or tile_cols is not None):
                raise ValueError(
                    "t/b_min/b_max/block_cols/tile_cols do not apply to "
                    "method='auto' (per-tile methods use their own "
                    "defaults)")
            from repro.core.planner import plan_spgemm_tiled

            plan = plan_spgemm_tiled(a, b, backend="pallas", tile=tile)
        else:
            from repro.core.planner import plan_spgemm

            plan = plan_spgemm(a, b, method, backend="pallas", t=t,
                               b_min=b_min, b_max=b_max,
                               block_cols=block_cols, tile_cols=tile_cols)
    return plan.execute(a, b, interpret=interpret)

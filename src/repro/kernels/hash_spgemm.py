"""Pallas TPU kernel: HASH lock-step SpGEMM (Section 3.2).

Same lane-per-column lock-step skeleton as SPARS, but the per-lane accumulator
is a linear-probed hash table of ``H`` slots — ``table_keys``/``table_vals``
are ``[H, L]`` VMEM tiles. ``H`` is a *compile-time* parameter: the paper's
dynamic table shrinking becomes selecting a smaller-H kernel variant per block
group, which shrinks the resident VMEM tile (the TPU re-reading of the paper's
"smaller address range => faster indexed access"; see DESIGN.md §2).

Collision handling: all lanes probe in lock-step; a bounded fori over
MAX_PROBES resolves each lane's slot (first matching-or-empty), mirroring the
paper's observation that one collision stalls all VL lanes for one probe
round. MAX_PROBES = H makes the bound exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.analysis import HASH_C

_EMPTY = -1


def _hash_kernel(steps_ref,
                 b_rows_ref, b_vals_ref, b_nnz_ref,
                 a_rows_ref, a_vals_ref, a_nnz_ref,
                 keys_ref, vals_ref,
                 *, m: int, za: int, n_a: int, h: int, max_probes: int):
    L, zb = b_rows_ref.shape
    steps = steps_ref[pl.program_id(0)]
    a_rows_f = a_rows_ref[...].astype(jnp.float32)
    a_vals = a_vals_ref[...]
    a_nnz_f = a_nnz_ref[...].astype(jnp.float32)
    b_nnz = b_nnz_ref[...]
    iota_na = jax.lax.broadcasted_iota(jnp.int32, (L, n_a), 1)
    iota_zb = jax.lax.broadcasted_iota(jnp.int32, (L, zb), 1)
    iota_za = jax.lax.broadcasted_iota(jnp.int32, (L, za), 1)
    iota_h = jax.lax.broadcasted_iota(jnp.int32, (h, L), 0)

    def step(_, carry):
        vidx_b, vcnt_a, keys, vals = carry
        active = vidx_b < b_nnz
        sel_b = (vidx_b[:, None] == iota_zb).astype(vals.dtype)
        bk = jnp.round((sel_b * b_rows_ref[...]).sum(1)).astype(jnp.int32)
        bv = (sel_b * b_vals_ref[...]).sum(1)
        oh = (bk[:, None] == iota_na).astype(vals.dtype)
        ar_all = oh @ a_rows_f
        av_all = oh @ a_vals
        an = jnp.round(oh @ a_nnz_f).astype(jnp.int32)
        sel_a = (vcnt_a[:, None] == iota_za).astype(vals.dtype)
        r = jnp.round((sel_a * ar_all).sum(1)).astype(jnp.int32)   # keys [L]
        av = (sel_a * av_all).sum(1)
        contrib = jnp.where(active, av * bv, 0.0)

        # -- lock-step linear probing: h(i) = (i * c) mod H ----------------
        pos = (r * jnp.int32(HASH_C & 0x7FFFFFFF)) % h
        done = ~active                     # inactive lanes resolve trivially
        pos_final = jnp.zeros_like(pos)

        def probe(_, pc):
            pos, done, pos_final = pc
            sel = (pos[None, :] == iota_h)                  # [h, L]
            k_at = jnp.where(sel, keys, 0).sum(0)           # gather keys
            occ_at = jnp.where(sel, (keys != _EMPTY).astype(jnp.int32),
                               0).sum(0)
            ok = (k_at == r) & (occ_at == 1) | (occ_at == 0)
            newly = ~done & ok
            pos_final = jnp.where(newly, pos, pos_final)
            done = done | ok
            pos = jnp.where(done, pos, (pos + 1) % h)
            return pos, done, pos_final

        _, _, pos_final = jax.lax.fori_loop(
            0, max_probes, probe, (pos, done, pos_final))
        sel = (pos_final[None, :] == iota_h) & active[None, :]     # [h, L]
        vals = vals + jnp.where(sel, contrib[None, :], 0.0)
        keys = jnp.where(sel, r[None, :], keys)

        last = vcnt_a + 1 >= an
        vcnt_a = jnp.where(active & ~last, vcnt_a + 1, 0)
        vidx_b = vidx_b + (active & last).astype(vidx_b.dtype)
        return vidx_b, vcnt_a, keys, vals

    init = (
        jnp.zeros((L,), jnp.int32),
        jnp.zeros((L,), jnp.int32),
        jnp.full((h, L), _EMPTY, jnp.int32),
        jnp.zeros((h, L), vals_ref.dtype),
    )
    _, _, keys, vals = jax.lax.fori_loop(0, steps, step, init)
    keys_ref[...] = keys
    vals_ref[...] = vals


@functools.partial(
    jax.jit, static_argnames=("m", "h", "block_cols", "interpret"))
def hash_spgemm(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, steps,
                *, m: int, h: int, block_cols: int = 128,
                interpret: bool = True):
    """Per-lane hash tables (keys [h, n_b], vals [h, n_b]), HASH dataflow.

    ``h`` must be a power of two >= max Op_j of any processed column (the
    host blocking pass guarantees it; tables never overflow).
    """
    n_a, za = a_rows.shape
    n_b, zb = b_rows.shape
    assert n_b % block_cols == 0, (n_b, block_cols)
    assert h & (h - 1) == 0, f"h={h} must be a power of two"
    n_blocks = n_b // block_cols
    kernel = functools.partial(
        _hash_kernel, m=m, za=za, n_a=n_a, h=h, max_probes=h)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_cols, zb), lambda i, s: (i, 0)),
            pl.BlockSpec((block_cols, zb), lambda i, s: (i, 0)),
            pl.BlockSpec((block_cols,), lambda i, s: (i,)),
            pl.BlockSpec((n_a, za), lambda i, s: (0, 0)),
            pl.BlockSpec((n_a, za), lambda i, s: (0, 0)),
            pl.BlockSpec((n_a,), lambda i, s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((h, block_cols), lambda i, s: (0, i)),
            pl.BlockSpec((h, block_cols), lambda i, s: (0, i)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((h, n_b), jnp.int32),
            jax.ShapeDtypeStruct((h, n_b), a_vals.dtype),
        ],
        interpret=interpret,
    )(steps, b_rows, b_vals, b_nnz, a_rows, a_vals, a_nnz)


@functools.partial(
    jax.jit, static_argnames=("m", "h", "block_cols", "interpret"))
def hash_spgemm_batched(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, steps,
                        *, m: int, h: int, block_cols: int = 128,
                        interpret: bool = True):
    """Batched HASH: tables (keys, vals) [B, h, n_b] for B value sets.

    Probing positions depend only on row indices, so every batch element
    fills identical table slots; only ``vals`` differs across the batch.
    Value operands carry the batch axis, pattern operands and trip counts
    are shared, and all B multiplies run in one vmapped launch
    (DESIGN.md §7).
    """
    f = functools.partial(hash_spgemm, m=m, h=h, block_cols=block_cols,
                          interpret=interpret)
    return jax.vmap(f, in_axes=(None, 0, None, None, 0, None, None))(
        a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz, steps)

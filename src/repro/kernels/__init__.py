"""Pallas TPU kernels for the paper's compute hot-spots.

- spa.py          SPA SpGEMM: dense [m, L] VMEM accumulator per column block
- spars.py        SPARS lock-step SpGEMM (cursor vectors, masked lanes)
- hash_spgemm.py  HASH lock-step SpGEMM (per-lane linear-probed VMEM tables)
- bsr_spmm.py     block-sparse x dense (production TPU re-targeting; SparseFFN)
- ref.py          pure-jnp oracles
- ops.py          jit'd wrappers + spgemm_pallas host API

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling,
PrefetchScalarGridSpec for CSC pointer structure) and validated on CPU in
interpret mode.  Each SpGEMM kernel also has a ``*_batched`` variant that
carries a leading batch axis on the value operands only — B same-pattern
multiplies in one launch (DESIGN.md §7).
"""

from repro.kernels.spa import spa_spgemm, spa_spgemm_batched
from repro.kernels.spars import spars_spgemm, spars_spgemm_batched
from repro.kernels.hash_spgemm import hash_spgemm, hash_spgemm_batched
from repro.kernels.bsr_spmm import bsr_spmm, bsr_from_dense
from repro.kernels.ops import spgemm_pallas

__all__ = [
    "spa_spgemm",
    "spa_spgemm_batched",
    "spars_spgemm",
    "spars_spgemm_batched",
    "hash_spgemm",
    "hash_spgemm_batched",
    "bsr_spmm",
    "bsr_from_dense",
    "spgemm_pallas",
]

"""Pallas TPU kernel: SPA SpGEMM over a block of C columns.

TPU adaptation of Algorithm 2 (see DESIGN.md §2): the SParse Accumulator for a
block of ``L`` C columns is a dense ``[m, L]`` tile resident in VMEM for the
whole kernel instance (the paper's accumulator-locality insight transplanted
from L2 to VMEM). Per B non-zero we
  * gather the referenced A column through a one-hot MXU matmul
    (the TPU-idiomatic indexed vector load), and
  * scatter-accumulate via an ``[m, L]`` one-hot mask FMA
    (the TPU-idiomatic indexed vector store — races impossible because row
    indices within one A column are unique, exactly the paper's argument).

Operands are padded-column views (``sparse.csc_to_padded_columns``). Output is
the dense accumulator block; compaction to CSC is the caller's separate store
phase (``sparse.format.CSCBuilder.add_dense_tile``), mirroring the paper's
line-11 "store as sparse".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spa_kernel(b_rows_ref, b_vals_ref, b_nnz_ref,
                a_rows_ref, a_vals_ref, a_nnz_ref,
                out_ref, *, m: int, za: int, n_a: int):
    L, zb = b_rows_ref.shape
    a_rows = a_rows_ref[...]
    a_vals = a_vals_ref[...]
    a_nnz = a_nnz_ref[...]
    b_nnz = b_nnz_ref[...]
    iota_na = jax.lax.broadcasted_iota(jnp.int32, (L, n_a), 1)
    iota_m = jax.lax.broadcasted_iota(jnp.int32, (m, L), 0)

    def b_step(e, acc):
        k = b_rows_ref[:, e]                       # [L] A-column ids
        bv = b_vals_ref[:, e]                      # [L]
        bmask = (e < b_nnz).astype(acc.dtype)      # [L]
        # indexed vector load of the A columns: one-hot [L, n_a] @ table (MXU)
        oh = (k[:, None] == iota_na).astype(acc.dtype)
        ar = jnp.round(oh @ a_rows.astype(acc.dtype)).astype(jnp.int32)
        av = oh @ a_vals                            # [L, za]
        an = jnp.round(oh @ a_nnz.astype(acc.dtype)).astype(jnp.int32)

        def z_step(z, acc):
            amask = (z < an).astype(acc.dtype)      # [L]
            contrib = av[:, z] * bv * bmask * amask  # [L]
            # indexed vector store: one-hot row mask FMA on the VMEM tile
            hit = (iota_m == ar[:, z][None, :]).astype(acc.dtype)
            return acc + hit * contrib[None, :]

        return jax.lax.fori_loop(0, za, z_step, acc)

    out_ref[...] = jax.lax.fori_loop(
        0, zb, b_step, jnp.zeros((m, L), out_ref.dtype))


@functools.partial(jax.jit, static_argnames=("m", "block_cols", "interpret"))
def spa_spgemm(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz,
               *, m: int, block_cols: int = 128, interpret: bool = True):
    """Dense C [m, n_b] = A @ B, SPA dataflow, one grid step per column block.

    n_b must be a multiple of block_cols (callers pad; see ops.py).
    """
    n_a, za = a_rows.shape
    n_b, zb = b_rows.shape
    assert n_b % block_cols == 0, (n_b, block_cols)
    grid = (n_b // block_cols,)
    kernel = functools.partial(_spa_kernel, m=m, za=za, n_a=n_a)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_cols, zb), lambda i: (i, 0)),   # b_rows
            pl.BlockSpec((block_cols, zb), lambda i: (i, 0)),   # b_vals
            pl.BlockSpec((block_cols,), lambda i: (i,)),        # b_nnz
            pl.BlockSpec((n_a, za), lambda i: (0, 0)),          # a_rows
            pl.BlockSpec((n_a, za), lambda i: (0, 0)),          # a_vals
            pl.BlockSpec((n_a,), lambda i: (0,)),               # a_nnz
        ],
        out_specs=pl.BlockSpec((m, block_cols), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n_b), a_vals.dtype),
        interpret=interpret,
    )(b_rows, b_vals, b_nnz, a_rows, a_vals, a_nnz)


@functools.partial(jax.jit, static_argnames=("m", "block_cols", "interpret"))
def spa_spgemm_batched(a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz,
                       *, m: int, block_cols: int = 128,
                       interpret: bool = True):
    """Batched SPA: dense C [B, m, n_b] for B same-pattern value sets.

    Only the value operands carry the batch axis (``a_vals [B, n_a, za]``,
    ``b_vals [B, n_b, zb]``); the pattern operands (rows, nnz) are shared.
    ``jax.vmap`` over the pallas_call turns the batch into a leading grid
    dimension, so all B multiplies run in one launch (DESIGN.md §7), and
    each batch slice is bit-identical to the unbatched kernel.
    """
    f = functools.partial(spa_spgemm, m=m, block_cols=block_cols,
                          interpret=interpret)
    return jax.vmap(f, in_axes=(None, 0, None, None, 0, None))(
        a_rows, a_vals, a_nnz, b_rows, b_vals, b_nnz)

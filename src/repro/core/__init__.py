"""Core: the paper's SpGEMM algorithms and pre-processing analysis."""

from repro.core.analysis import (
    VL_MAX,
    N_LANES,
    HASH_C,
    BlockSchedule,
    Preprocess,
    blocking_schedule,
    hash_table_size,
    hybrid_split,
    preprocess,
    sort_columns,
)
from repro.core.expand import expand_products, product_col_ptr, spgemm_expand
from repro.core.naive import (
    esc_numpy,
    hash_numpy,
    hybrid_numpy,
    spa_numpy,
    spars_numpy,
)
from repro.core.reference import dense_product, spgemm_dense
from repro.core.cost import (
    AUTO_CANDIDATES,
    CostConstants,
    choose_method,
    estimate_cost,
    estimate_mesh_cost,
    should_distribute,
)
from repro.core.planner import (
    SpgemmPlan,
    TiledSpgemmPlan,
    pattern_fingerprint,
    plan_spgemm,
    plan_spgemm_tiled,
)
# NOTE: the mutable guard knob fast.STREAM_MAX_PRODUCTS is deliberately not
# re-exported by value — read/set it on repro.core.fast so changes take
# effect (planner/cost read it live)
from repro.core.fast import ProductStream, build_product_stream
# NOTE: backends.register_backend stays module-private — registering a
# contract alone does not wire executors/candidates, so it is not a public
# extension point (see core/backends.py)
from repro.core.backends import ExecutionContract, backend_names, get_backend
from repro.core.jax_stream import (
    DeviceStream,
    bilinear_custom_vjp,
    device_stream,
    stream_fn,
)
from repro.core.pallas_stream import FusedStream, fused_fn, fused_stream
from repro.core.executor import execute as execute_plan
from repro.core.executor import execute_batched as execute_plan_batched
from repro.core.executor import execute_tiled, execute_tiled_batched
from repro.core.executor import resolve_engine
from repro.core.api import (
    ALGORITHMS,
    PlanBuildTimeout,
    cached_plan,
    plan_cache_clear,
    plan_cache_info,
    plan_cache_key,
    plan_cache_peek,
    plan_cache_resize,
    register_eviction_listener,
    spgemm,
    spgemm_batched,
    unregister_eviction_listener,
)
from repro.core.profile import (
    MachineProfile,
    calibrate_profile,
    current_profile,
    fingerprint_key,
    load_profile,
    machine_fingerprint,
    rank_correlation,
    save_profile,
)
from repro.core.faults import FaultPlan, FaultRule, InjectedFault
from repro.core.plan_builder import (
    BuildCancelled,
    BuildResult,
    BuildShed,
    BuildTimeoutError,
    PlanBuilder,
    RetryPolicy,
    warm_plan,
)

__all__ = [
    "VL_MAX",
    "N_LANES",
    "HASH_C",
    "BlockSchedule",
    "Preprocess",
    "blocking_schedule",
    "hash_table_size",
    "hybrid_split",
    "preprocess",
    "sort_columns",
    "expand_products",
    "product_col_ptr",
    "spgemm_expand",
    "esc_numpy",
    "hash_numpy",
    "hybrid_numpy",
    "spa_numpy",
    "spars_numpy",
    "dense_product",
    "spgemm_dense",
    "SpgemmPlan",
    "TiledSpgemmPlan",
    "pattern_fingerprint",
    "plan_spgemm",
    "plan_spgemm_tiled",
    "execute_plan",
    "execute_plan_batched",
    "execute_tiled",
    "execute_tiled_batched",
    "ProductStream",
    "build_product_stream",
    "ExecutionContract",
    "backend_names",
    "get_backend",
    "DeviceStream",
    "bilinear_custom_vjp",
    "device_stream",
    "stream_fn",
    "FusedStream",
    "fused_fn",
    "fused_stream",
    "resolve_engine",
    "cached_plan",
    "plan_cache_clear",
    "plan_cache_info",
    "plan_cache_key",
    "plan_cache_peek",
    "plan_cache_resize",
    "register_eviction_listener",
    "unregister_eviction_listener",
    "PlanBuildTimeout",
    "FaultPlan",
    "FaultRule",
    "InjectedFault",
    "BuildCancelled",
    "BuildResult",
    "BuildShed",
    "BuildTimeoutError",
    "PlanBuilder",
    "RetryPolicy",
    "warm_plan",
    "spgemm",
    "spgemm_batched",
    "ALGORITHMS",
    "AUTO_CANDIDATES",
    "CostConstants",
    "choose_method",
    "estimate_cost",
    "estimate_mesh_cost",
    "should_distribute",
    "MachineProfile",
    "calibrate_profile",
    "current_profile",
    "fingerprint_key",
    "load_profile",
    "machine_fingerprint",
    "rank_correlation",
    "save_profile",
]

"""Pre-processing analysis: the paper's sorting, blocking and sizing machinery.

Everything here is host-side numpy (the paper also performs these as scalar
pre-processing, excluded from its timed region but reported — our benchmarks
report preprocessing time separately, as Section 5.3 does).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.format import CSC
from repro.sparse.stats import ops_per_column

# paper's platform: 8 lanes, max vector length 256 doubles
VL_MAX = 256
N_LANES = 8

# multiplicative hash constant (odd => bijective mod powers of two); the paper
# uses h(i) = (i*c) mod H without fixing c.
HASH_C = 2654435761  # Knuth's multiplicative constant


def sort_columns(ops: np.ndarray) -> np.ndarray:
    """Permutation P with ops[P] non-increasing (stable; paper Section 3.1).

    The matrix is never physically reordered; algorithms access B's columns
    through P and the result is C·P, undone by the caller via P.
    """
    # stable sort on negated ops keeps equal-load columns in original order,
    # which keeps blocks contiguous-ish in the original matrix
    return np.argsort(-ops, kind="stable")


@dataclasses.dataclass(frozen=True)
class BlockSchedule:
    """Output of the blocking algorithm over *sorted* columns.

    starts[i], sizes[i]: block i covers sorted-column positions
    [starts[i], starts[i] + sizes[i]).  ``sizes[i]`` is the vector length used
    to process block i.
    """

    starts: np.ndarray
    sizes: np.ndarray

    @property
    def n_blocks(self) -> int:
        return len(self.starts)

    def __iter__(self):
        return zip(self.starts.tolist(), self.sizes.tolist())


def blocking_schedule(
    ops_sorted: np.ndarray, b_min: int, b_max: int, start: int = 0
) -> BlockSchedule:
    """The paper's blocking algorithm (Section 3.1) over sorted loads.

    From position j: take b_min columns; while the next column's Op equals the
    block's max (= the first column's, since sorted), grow; stop at b_max or
    the end. ``start`` lets hybrids begin blocking at the SPA/SPARS switch.
    """
    if b_min < 1 or b_max < b_min:
        raise ValueError(f"invalid block bounds ({b_min}, {b_max})")
    n = len(ops_sorted)
    starts, sizes = [], []
    j = start
    while j < n:
        j2 = min(j + b_min, n)
        head = ops_sorted[j]
        while j2 < min(j + b_max, n) and ops_sorted[j2] == head:
            j2 += 1
        starts.append(j)
        sizes.append(j2 - j)
        j = j2
    return BlockSchedule(np.asarray(starts, np.int64), np.asarray(sizes, np.int64))


def hash_table_size(max_ops: int) -> int:
    """H = 2^k with 2^(k-1) <= max_ops < 2^k  (Section 3.2); minimum 2.

    max_ops bounds the number of intermediate products of any column in the
    block, hence the occupancy of its hash table.
    """
    if max_ops <= 1:
        return 2
    return 1 << int(np.ceil(np.log2(max_ops + 1e-12)))


def hybrid_split(ops_sorted: np.ndarray, t: float) -> int:
    """First sorted position processed by the blocked algorithm.

    H-SPA(t)/H-HASH(t): columns with Op_j >= t go to SPA; the tail (Op_j < t)
    goes to SPARS/HASH. t=0 => all SPA; t=inf => all blocked.
    """
    if t <= 0:
        return len(ops_sorted)
    if np.isinf(t):
        return 0
    return int(np.searchsorted(-ops_sorted, -t, side="right"))


@dataclasses.dataclass(frozen=True)
class Preprocess:
    """Everything the paper's pre-processing phase produces."""

    ops: np.ndarray          # Op_j in original column order
    perm: np.ndarray         # sorted-position -> original column
    ops_sorted: np.ndarray   # ops[perm]
    split: int               # SPA | blocked boundary (sorted position)
    blocks: BlockSchedule    # blocks over [split, n)
    hash_sizes: np.ndarray   # per-block H (power of two), for HASH only

    @property
    def n_cols(self) -> int:
        return len(self.ops)


def preprocess(
    a: CSC,
    b: CSC,
    *,
    t: float = np.inf,
    b_min: int = VL_MAX,
    b_max: int = VL_MAX,
    sort: bool = True,
) -> Preprocess:
    ops = ops_per_column(a, b)
    perm = sort_columns(ops) if sort else np.arange(len(ops))
    ops_sorted = ops[perm]
    split = hybrid_split(ops_sorted, t)
    blocks = blocking_schedule(ops_sorted, b_min, b_max, start=split)
    hs = np.asarray(
        [hash_table_size(int(ops_sorted[s])) if z > 0 else 2 for s, z in blocks],
        np.int64,
    )
    # Section 3.2: H never grows back while walking sorted blocks; enforce the
    # monotone shrink the paper describes (start from the first block's size).
    for i in range(1, len(hs)):
        hs[i] = min(hs[i], hs[i - 1])
    return Preprocess(ops, perm, ops_sorted, split, blocks, hs)

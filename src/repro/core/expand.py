"""Vectorized intermediate-product expansion (the Gustavson product stream).

Every algorithm in the paper enumerates exactly the same multiset of
intermediate products ``A[i,k] * B[k,j]``; they differ in *scheduling* and in
the accumulator data structure. This module materializes the stream once,
vectorized, which gives (a) a fast host-side value-level executor for any
algorithm, and (b) the per-column product sequences the schedule simulators
consume.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.format import CSC, COO, csc_from_coo, _np


def product_count(a_col_ptr, b_col_ptr, b_row_indices) -> int:
    """Number of scalar products of C = A @ B (pattern-only, O(nnz_b))."""
    a_cp = np.asarray(a_col_ptr).astype(np.int64)
    b_cp = np.asarray(b_col_ptr)
    b_rows = np.asarray(b_row_indices)[: int(b_cp[-1])]
    return int((a_cp[b_rows + 1] - a_cp[b_rows]).sum())


def expand_positions(a_col_ptr, b_col_ptr, b_row_indices):
    """Pattern-only Gustavson expansion: ``(a_pos, b_pos, cols)``.

    One entry per scalar product of C = A @ B, in Gustavson stream order —
    for each column j of B (in order), for each stored B[k,j] (in storage
    order), for each stored A[i,k] (in storage order).  ``a_pos``/``b_pos``
    index the operands' value arrays; ``cols`` is the product's C column.
    The single source of this index arithmetic: :func:`expand_products`
    (value-level COO) and the stream engine's
    :func:`repro.core.fast.build_product_stream` both build on it, which is
    what keeps their product orders — and hence summation orders — in
    lock-step (DESIGN.md §9).
    """
    a_cp = np.asarray(a_col_ptr).astype(np.int64)
    b_cp = np.asarray(b_col_ptr).astype(np.int64)
    b_rows = np.asarray(b_row_indices)[: int(b_cp[-1])]
    n = len(b_cp) - 1

    # per stored B element: the A-column slice it multiplies
    seg_starts = a_cp[b_rows]
    seg_lens = a_cp[b_rows + 1] - seg_starts
    total = int(seg_lens.sum())
    if total == 0:
        z = np.zeros(0, np.int64)
        return z, z.copy(), z.copy()
    # expanded A positions: for element e with slice [s_e, s_e+l_e), emit
    # s_e, s_e+1, ..., s_e+l_e-1 (within-segment offset = global index minus
    # the segment's start position in the stream)
    stream_starts = np.concatenate(([0], np.cumsum(seg_lens)[:-1]))
    a_pos = np.arange(total, dtype=np.int64) + np.repeat(
        seg_starts - stream_starts, seg_lens
    )
    b_pos = np.repeat(np.arange(len(b_rows), dtype=np.int64), seg_lens)
    cols = np.repeat(
        np.repeat(np.arange(n, dtype=np.int64), np.diff(b_cp)), seg_lens
    )
    return a_pos, b_pos, cols


def expand_products(a: CSC, b: CSC) -> COO:
    """All intermediate products as COO triples, in Gustavson column order.

    For each column j of B (in order), for each stored B[k,j] (in storage
    order), for each stored A[i,k] (in storage order): emit (i, j, A_ik*B_kj).
    This is exactly the paper's per-column product sequence, so slicing the
    result by column gives the SPARS/HASH lane streams.
    """
    a_rows = _np(a.row_indices)
    a_vals = _np(a.values)
    b_vals = _np(b.values)[: b.nnz]

    a_pos, b_pos, cols = expand_positions(
        _np(a.col_ptr), _np(b.col_ptr), _np(b.row_indices))
    if len(a_pos) == 0:
        return COO(
            np.zeros(0, np.int32), np.zeros(0, np.int32),
            np.zeros(0, a_vals.dtype), (a.shape[0], b.shape[1]),
        )
    rows = a_rows[a_pos].astype(np.int32)
    vals = a_vals[a_pos] * b_vals[b_pos]
    return COO(rows, cols.astype(np.int32), vals, (a.shape[0], b.shape[1]))


def product_col_ptr(a: CSC, b: CSC) -> np.ndarray:
    """Offsets of each C column's product segment in the expanded stream.

    Length n_B+1; product_col_ptr[j+1]-product_col_ptr[j] == Op_j.
    """
    from repro.sparse.stats import ops_per_column

    ops = ops_per_column(a, b)
    cp = np.zeros(len(ops) + 1, np.int64)
    np.cumsum(ops, out=cp[1:])
    return cp


def spgemm_expand(a: CSC, b: CSC) -> CSC:
    """Value-level SpGEMM via expansion + merge. Fast host-side executor."""
    coo = expand_products(a, b)
    return csc_from_coo(coo, sum_duplicates=True)

"""Vectorized intermediate-product expansion (the Gustavson product stream).

Every algorithm in the paper enumerates exactly the same multiset of
intermediate products ``A[i,k] * B[k,j]``; they differ in *scheduling* and in
the accumulator data structure. This module materializes the stream once,
vectorized, which gives (a) a fast host-side value-level executor for any
algorithm, and (b) the per-column product sequences the schedule simulators
consume.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.format import CSC, COO, csc_from_coo, _np


def expand_products(a: CSC, b: CSC) -> COO:
    """All intermediate products as COO triples, in Gustavson column order.

    For each column j of B (in order), for each stored B[k,j] (in storage
    order), for each stored A[i,k] (in storage order): emit (i, j, A_ik*B_kj).
    This is exactly the paper's per-column product sequence, so slicing the
    result by column gives the SPARS/HASH lane streams.
    """
    a_cp = _np(a.col_ptr).astype(np.int64)
    a_rows = _np(a.row_indices)
    a_vals = _np(a.values)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)[: b.nnz]
    b_vals = _np(b.values)[: b.nnz]

    # per stored B element: the A-column slice it multiplies
    seg_starts = a_cp[b_rows]
    seg_lens = (a_cp[b_rows + 1] - seg_starts).astype(np.int64)
    total = int(seg_lens.sum())
    if total == 0:
        return COO(
            np.zeros(0, np.int32), np.zeros(0, np.int32),
            np.zeros(0, a_vals.dtype), (a.shape[0], b.shape[1]),
        )
    # expanded A positions: for element e with slice [s_e, s_e+l_e), emit
    # s_e, s_e+1, ..., s_e+l_e-1 (within-segment offset = global index minus
    # the segment's start position in the stream)
    stream_starts = np.concatenate(([0], np.cumsum(seg_lens)[:-1]))
    apos = np.arange(total, dtype=np.int64) + np.repeat(
        seg_starts - stream_starts, seg_lens
    )

    rows = a_rows[apos].astype(np.int32)
    vals = a_vals[apos] * np.repeat(b_vals, seg_lens)
    b_col_of_elem = np.repeat(
        np.arange(b.shape[1], dtype=np.int32), np.diff(b_cp).astype(np.int64)
    )
    cols = np.repeat(b_col_of_elem, seg_lens)
    return COO(rows, cols, vals, (a.shape[0], b.shape[1]))


def product_col_ptr(a: CSC, b: CSC) -> np.ndarray:
    """Offsets of each C column's product segment in the expanded stream.

    Length n_B+1; product_col_ptr[j+1]-product_col_ptr[j] == Op_j.
    """
    from repro.sparse.stats import ops_per_column

    ops = ops_per_column(a, b)
    cp = np.zeros(len(ops) + 1, np.int64)
    np.cumsum(ops, out=cp[1:])
    return cp


def spgemm_expand(a: CSC, b: CSC) -> CSC:
    """Value-level SpGEMM via expansion + merge. Fast host-side executor."""
    coo = expand_products(a, b)
    return csc_from_coo(coo, sum_duplicates=True)

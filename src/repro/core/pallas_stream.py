"""Fused Pallas stream kernel: the whole numeric phase in one launch.

``engine="fused"`` lowers a plan's product stream (``core.fast``, DESIGN.md
§9) to a *single* ``pl.pallas_call``: the product axis ``[P]`` is tiled into
grid blocks of ``FUSED_BLOCK`` products, and each grid step gathers its
block's operand values, multiplies, reduces the block's segment partials,
and accumulates them into the VMEM-resident output::

    per grid step i over products [iT, (i+1)T):
      prod    = x_vals[idx_x] * y_vals[idx_y] * mask          # gather+FMA
      partial = onehot(local) @ prod                          # [T] segmented
      out[seg_first_i : seg_first_i + T] += partial           # accumulate

This is the accumulator-resident numeric phase of Nagasaka et al. /
Gu et al. transplanted to Pallas: where ``backend="jax"`` lowers the same
contraction to three separate XLA HLOs (gather → multiply → ``segment_sum``)
with ``[P]``-sized intermediates in HBM, and the original Pallas path
launches one kernel per plan group from Python, the fused kernel is one
launch whose intermediates never leave VMEM (DESIGN.md §11).

**Why the window accumulate is safe.**  The stream's segment ids are
non-decreasing and consecutive (every stored C slot has >= 1 product), so
within any block of ``T`` products the local ids ``seg - seg_first`` lie in
``[0, T)`` — each id increment consumes at least one product.  A segment
straddling a block boundary is handled by the ``+=`` into the resident
output: its left part lands from block ``i``, its right part from block
``i+1``, at the same output slot (Pallas grid steps are sequential, and the
output block is carried across steps — the revisiting guarantee).  This
"accumulate into the VMEM-resident output" strategy replaces both a
carried-scratch partial and a host-side per-block combine; DESIGN.md §11
records why it benched fastest.

**Differentiability.**  The contraction is bilinear, so the backward pass is
two more fused stream replays of the broadcast cotangent through permuted
index views (:func:`jax_stream.bilinear_custom_vjp` — the vjp machinery is
shared with the XLA device stream, only the replay lowering differs).  The
grad views sort the stream by the differentiated operand's value position;
positions with zero products would break the ``[0, T)`` window invariant as
empty segments, so the views reduce into *compact* (rank) ids and a
plan-static ``out_map`` scatter places them (DESIGN.md §11).

**Hardware note.**  The in-kernel gather is isolated in :func:`_gather`
(``jnp.take`` with an in-bounds promise) and the segmented reduction uses
the one-hot-matmul idiom of ``kernels/spa.py`` — the two points a real-TPU
port would revisit (Mosaic's arbitrary-gather support / MXU tiling).  Tier-1
runs the kernel body under ``interpret=True`` (no accelerator in CI), which
is also the default of every executor below.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import fast, jax_stream
from repro.core.jax_stream import (
    _IN_BOUNDS,
    _guard_error,
    _is_traced,
    _operand_values,
    bilinear_custom_vjp,
    check_int32_stream,
    stream_seg_ids,
)
from repro.sparse.format import CSC

# products per grid block (T): the kernel's VMEM working set per step is
# O(T) index/value lanes plus the [T, T] one-hot; the output window it
# accumulates into is T wide.  Overridable for tests (segment-boundary
# edge cases build plans under tiny blocks); views/functions memoized on a
# plan record the block they were built with and rebuild on mismatch.
# DEFAULT_FUSED_BLOCK is the shipped fallback; a calibrated machine
# profile can retune the live knob to this host's measured argmin via
# ``core.profile.apply_tuning`` (DESIGN.md §15).
DEFAULT_FUSED_BLOCK = 128
FUSED_BLOCK = DEFAULT_FUSED_BLOCK


@dataclasses.dataclass(frozen=True)
class FusedView:
    """Device-resident index arrays of one fused replay (P padded to Pp).

    The forward view replays the stream in C-slot order (``out_map`` is
    ``None`` — block partials accumulate straight into the output window).
    Grad views replay it sorted by the differentiated operand's value
    position, reduce into compact rank ids, and scatter through ``out_map``
    (the sorted unique value positions) into the operand-shaped cotangent.
    """

    idx_x: Optional[jax.Array]      # [Pp] int32 into the x operand
    idx_y: Optional[jax.Array]      # [Pp] int32 into the y operand
    local: Optional[jax.Array]      # [Pp] int32 in [0, block): seg - first
    mask: Optional[jax.Array]       # [Pp] f32 1/0 (0 on the padded tail)
    seg_first: Optional[jax.Array]  # [nblocks] int32: block's first seg id
    block_id: Optional[jax.Array]   # [nblocks] int32: 0..nblocks-1
    out_map: Optional[jax.Array]    # [n_out] int32 scatter (grad views)
    n_out: int                      # segments reduced by the kernel
    n_products: int                 # real (unpadded) product count
    block: int

    @property
    def n_blocks(self) -> int:
        return -(-max(self.n_products, 1) // self.block)

    @property
    def nbytes(self) -> int:
        """Device bytes held by this view's index arrays."""
        return sum(a.nbytes for a in (self.idx_x, self.idx_y, self.local,
                                      self.mask, self.seg_first,
                                      self.block_id, self.out_map)
                   if a is not None)


@dataclasses.dataclass(frozen=True)
class FusedStream:
    """The plan's three fused replay views (forward + the two grad views).

    Built lazily from the host :attr:`plan.stream` on first fused execution
    and memoized on the plan alongside the host/XLA-device streams;
    ``plan.fused_stream_nbytes`` / ``plan_cache_info()
    ['fused_stream_bytes']`` report these buffers separately.
    """

    forward: FusedView
    grad_a: FusedView
    grad_b: FusedView
    block: int

    @property
    def nbytes(self) -> int:
        return (self.forward.nbytes + self.grad_a.nbytes
                + self.grad_b.nbytes)


def _build_view(idx_x, idx_y, seg, block: int, n_out: int,
                out_map=None) -> FusedView:
    """One replay view: pad [P] streams to whole blocks, move to device.

    ``seg`` must be non-decreasing with unit steps covering ``0..n_out-1``
    (forward: the stream's C-slot ids; grad: compact ranks) — that is what
    bounds every block's local ids to ``[0, block)``.
    """
    p = len(idx_x)
    if p == 0:
        return FusedView(None, None, None, None, None, None,
                         None if out_map is None else jnp.asarray(
                             out_map, jnp.int32),
                         n_out, 0, block)
    nblocks = -(-p // block)
    pp = nblocks * block

    def _pad(arr, fill=0):
        out = np.full(pp, fill, arr.dtype)
        out[:p] = arr
        return out

    starts = np.arange(nblocks, dtype=np.int64) * block   # all < p
    seg = np.asarray(seg, np.int64)
    seg_first = seg[starts]
    local = seg - np.repeat(seg_first, block)[:p]
    mask = np.zeros(pp, np.float32)
    mask[:p] = 1.0
    with jax.ensure_compile_time_eval():
        # the lazy build may run inside a caller's jit trace (the first
        # traced fused execution of a fresh plan); the index arrays must
        # come out concrete — they are plan state shared by every later
        # trace, not constants of this one (same rule as device_stream)
        dev = (jnp.asarray(_pad(np.asarray(idx_x, np.int32))),
               jnp.asarray(_pad(np.asarray(idx_y, np.int32))),
               jnp.asarray(_pad(local.astype(np.int32))),
               jnp.asarray(mask),
               jnp.asarray(seg_first.astype(np.int32)),
               jnp.asarray(np.arange(nblocks, dtype=np.int32)),
               None if out_map is None
               else jnp.asarray(np.asarray(out_map, np.int32)))
    return FusedView(*dev, n_out=n_out, n_products=p, block=block)


def _grad_view(pos, other_pos, seg_ids, block: int) -> FusedView:
    """Replay view for d(operand at ``pos``): sort by ``pos``, compact ids.

    The replay gathers the output cotangent through ``seg_ids`` (x side)
    and the other operand's values through ``other_pos`` (y side); value
    positions with zero products are *absent* (compact ranks keep the
    no-empty-segment invariant), so the kernel output scatters through
    ``out_map`` — the sorted unique positions — into the full cotangent.
    """
    order = np.argsort(pos, kind="stable")
    seq = np.asarray(pos)[order]
    uniq, inv = np.unique(seq, return_inverse=True)
    return _build_view(seg_ids[order], np.asarray(other_pos)[order], inv,
                       block, n_out=len(uniq), out_map=uniq)


def fused_stream(plan, block: int | None = None) -> Optional[FusedStream]:
    """The plan's fused replay views, built lazily and memoized.

    ``None`` when the plan-memory guard tripped (no host stream to lift).
    ``block`` overrides the product-axis tile size (default
    ``FUSED_BLOCK``); a memoized entry built under a different block is
    rebuilt, so tests can shrink the tile on a fresh plan.
    """
    s = plan.stream
    if s is None:
        return None
    block = FUSED_BLOCK if block is None else int(block)
    if block < 1:
        raise ValueError(f"fused block must be >= 1, got {block}")
    memo = plan._stream_memo
    fs = memo.get("fused")
    if fs is None or fs.block != block:
        check_int32_stream(plan, s)
        seg_ids = stream_seg_ids(s)
        fs = FusedStream(
            forward=_build_view(s.a_pos, s.b_pos, seg_ids, block,
                                n_out=s.nnz),
            grad_a=_grad_view(s.a_pos, s.b_pos, seg_ids, block),
            grad_b=_grad_view(s.b_pos, s.a_pos, seg_ids, block),
            block=block,
        )
        memo["fused"] = fs
        # the jitted contraction closes over the views: drop stale entries
        for k in ("fused_contract", "fused_fn", "fused_fn_batched"):
            memo.pop(k, None)
    return fs


def _gather(values, idx):
    """In-kernel indexed vector load (the hardware-swappable point).

    A flat gather with the stream's in-bounds promise: exact under
    ``interpret=True`` (what CI runs); a Mosaic TPU port would swap this
    for the one-hot MXU gather of ``kernels/spa.py`` or a DMA-based load.
    """
    return values.at[idx].get(mode=_IN_BOUNDS)


def _fused_kernel(bid_ref, sf_ref, ix_ref, iy_ref, loc_ref, mask_ref,
                  x_ref, y_ref, out_ref, *, block: int):
    """One grid step: gather, multiply, reduce, window-accumulate.

    The output block is the whole (padded) result vector, resident across
    all grid steps; step 0 zero-initializes it.  Grid position comes from
    the ``block_id`` input (not ``pl.program_id``) so ``jax.vmap`` over the
    ``pallas_call`` stays well-defined when the batch axis becomes the
    leading grid dimension (same rule as ``kernels/spa.py``).
    """
    @pl.when(bid_ref[0] == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    prod = (_gather(x_ref[...], ix_ref[...])
            * _gather(y_ref[...], iy_ref[...]) * mask_ref[...])      # [T]
    # within-block segmented sum as a one-hot contraction (MXU idiom):
    # partial[r] = sum_c prod[c] * [local[c] == r]
    iota = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    onehot = (iota == loc_ref[...][None, :]).astype(prod.dtype)
    partial = onehot @ prod                                           # [T]
    start = sf_ref[0]
    window = pl.ds(start, block)
    out_ref[window] = out_ref[window] + partial


def _fused_call(view: FusedView, x, y, *, interpret: bool = True):
    """Run one fused replay: ``[n_out]`` segment sums in one launch."""
    dt = jnp.result_type(x, y)
    if view.n_products == 0:
        return jnp.zeros((view.n_out,), dt)
    block = view.block
    # the accumulate window [seg_first, seg_first + T) may run past the
    # last segment: pad the output by one block and slice it off
    out_pad = view.n_out + block
    nblocks = view.n_blocks
    x = jnp.asarray(x, dt)
    y = jnp.asarray(y, dt)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, block=block),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),          # block_id
            pl.BlockSpec((1,), lambda i: (i,)),          # seg_first
            pl.BlockSpec((block,), lambda i: (i,)),      # idx_x
            pl.BlockSpec((block,), lambda i: (i,)),      # idx_y
            pl.BlockSpec((block,), lambda i: (i,)),      # local
            pl.BlockSpec((block,), lambda i: (i,)),      # mask
            pl.BlockSpec(x.shape, lambda i: (0,)),       # x values (whole)
            pl.BlockSpec(y.shape, lambda i: (0,)),       # y values (whole)
        ],
        out_specs=pl.BlockSpec((out_pad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((out_pad,), dt),
        interpret=interpret,
    )(view.block_id, view.seg_first, view.idx_x, view.idx_y, view.local,
      view.mask.astype(dt), x, y)
    return out[: view.n_out]


def _fused_contract(fs: FusedStream, interpret: bool = True):
    """The custom-vjp fused contraction: forward + two fused grad replays."""

    def forward(a_values, b_values):
        return _fused_call(fs.forward, a_values, b_values,
                           interpret=interpret)

    def _scatter(view, compact, n_primal, dt):
        if view.out_map is None:      # P == 0: no contributing products
            return jnp.zeros((n_primal,), dt)
        return jnp.zeros((n_primal,), dt).at[view.out_map].set(
            compact, unique_indices=True, mode=_IN_BOUNDS)

    def grad_a(g, a_values, b_values):
        compact = _fused_call(fs.grad_a, g, b_values, interpret=interpret)
        return _scatter(fs.grad_a, compact, a_values.shape[0],
                        compact.dtype)

    def grad_b(g, a_values, b_values):
        compact = _fused_call(fs.grad_b, g, a_values, interpret=interpret)
        return _scatter(fs.grad_b, compact, b_values.shape[0],
                        compact.dtype)

    return bilinear_custom_vjp(forward, grad_a, grad_b)


def fused_fn(plan, *, interpret: bool = True, block: int | None = None):
    """The plan's jitted fused function ``f(a_values, b_values) -> c_values``.

    Pure, jit-compatible, differentiable (shared bilinear custom vjp) —
    the fused twin of :func:`jax_stream.stream_fn`.  Memoized on the plan
    (keyed on the block/interpret it was built under); guarded plans raise
    the capability error.
    """
    fs = fused_stream(plan, block)
    if fs is None:
        raise _guard_error(plan)
    memo = plan._stream_memo
    if memo.get("fused_fn_key") != (fs.block, interpret):
        memo["fused_contract"] = _fused_contract(fs, interpret=interpret)
        memo["fused_fn"] = jax.jit(memo["fused_contract"])
        memo.pop("fused_fn_batched", None)
        memo["fused_fn_key"] = (fs.block, interpret)
    return memo["fused_fn"]


def fused_fn_batched(plan, *, interpret: bool = True,
                     block: int | None = None):
    """Vmapped twin of :func:`fused_fn`: ``[B, nnz]`` stacks, one trace.

    ``jit(vmap(contract))`` — the batch axis becomes the leading grid
    dimension of the one fused launch (exactly how ``spa_spgemm_batched``
    batches, DESIGN.md §7), so the launch count stays 1 regardless of B.
    """
    fused_fn(plan, interpret=interpret, block=block)   # ensures contract
    memo = plan._stream_memo
    if "fused_fn_batched" not in memo:
        memo["fused_fn_batched"] = jax.jit(jax.vmap(memo["fused_contract"]))
    return memo["fused_fn_batched"]


def execute_fused(plan, a_values, b_values, *, interpret: bool = True,
                  stats: dict | None = None,
                  validate: str | None = None) -> CSC:
    """Numeric phase via the fused kernel (executor dispatch target).

    One ``pallas_call`` launch; result values are a device array on the
    plan's canonical stream structure.  Guarded plans fall back to the host
    stream engine on concrete operands and raise the capability error
    under a trace (same semantics as the jax backend).
    """
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    av = _operand_values(a_values)
    bv = _operand_values(b_values)
    if plan.stream is None:
        if _is_traced(av, bv):
            raise _guard_error(plan)
        out = fast.execute_stream(plan, np.asarray(av), np.asarray(bv),
                                  stats=stats)
        if stats is not None:
            stats["backend"] = plan.backend
            stats["fallback"] = "host"
        return out
    vals = fused_fn(plan, interpret=interpret)(av, bv)
    s = plan.stream
    if stats is not None:
        stats.update(engine="fused", backend=plan.backend, device=True,
                     fallback=None, n_launches=1,
                     stream_products=s.n_products,
                     fused_block=plan._stream_memo["fused"].block,
                     result_shape=s.shape)
    return CSC(vals, s.c_rows, s.c_col_ptr, s.shape)


def execute_fused_batched(plan, a_values, b_values, *,
                          interpret: bool = True,
                          stats: dict | None = None,
                          validate: str | None = None) -> list:
    """Batched fused numeric phase: B value sets, still one launch."""
    from repro.core.executor import _check_batch   # lazy: executor imports us

    av = jax_stream._batched_operand(plan.a, a_values, validate)
    bv = jax_stream._batched_operand(plan.b, b_values, validate)
    batch = _check_batch(av, bv)
    if plan.stream is None:
        if _is_traced(av, bv):
            raise _guard_error(plan)
        out = fast.execute_stream_batched(
            plan, np.asarray(av)[:, : int(plan.a.col_ptr[-1])],
            np.asarray(bv)[:, : int(plan.b.col_ptr[-1])], stats=stats)
        if stats is not None:
            stats["backend"] = plan.backend
            stats["fallback"] = "host"
            stats["batch"] = batch
        return out
    vals = fused_fn_batched(plan, interpret=interpret)(av, bv)
    s = plan.stream
    if stats is not None:
        stats.update(engine="fused", backend=plan.backend, device=True,
                     fallback=None, path="vmap", batch=batch, n_launches=1,
                     stream_products=s.n_products,
                     fused_block=plan._stream_memo["fused"].block,
                     result_shape=s.shape)
    return [CSC(vals[b], s.c_rows, s.c_col_ptr, s.shape)
            for b in range(batch)]

"""Backend/engine registry: one execution contract per backend (DESIGN.md §10).

Three execution backends host the numeric phase of a cached symbolic plan:

* ``"host"``   — the faithful numpy executors (``engine="naive"``, the
  bit-exact oracles of the paper's algorithms) and the vectorized product
  stream (``engine="stream"``, DESIGN.md §9).
* ``"pallas"`` — the TPU kernel schedule (one launch per plan
  :class:`~repro.core.planner.KernelGroup`, DESIGN.md §2/§6), plus the
  fused single-launch stream kernel (``engine="fused"``,
  ``core.pallas_stream``, DESIGN.md §11).
* ``"jax"``    — the device-resident stream (``core.jax_stream``,
  DESIGN.md §10): the plan's product stream compiled into a jitted,
  differentiable pure-JAX function; ``engine="fused"`` swaps the XLA
  lowering for the fused Pallas kernel on the same plan.

Rather than each call site string-matching backend names, everything that
needs a capability decision — ``core.api`` argument validation,
``core.planner`` method admission, ``core.executor`` engine resolution, the
cost model's candidate sets, ``kernels.ops`` — consults the
:class:`ExecutionContract` registered here.  Adding a backend means
registering one contract plus its executor pair; no if/elif chain grows.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

#: methods with no Pallas kernel family (host-only executors).  Lives here —
#: not in the planner — because it is a *capability* of the pallas contract.
HOST_ONLY_METHODS = ("esc", "expand")


@dataclasses.dataclass(frozen=True)
class ExecutionContract:
    """Capabilities and engine surface of one execution backend.

    ``engines`` are the accepted ``engine=`` spellings (``None`` always
    means "this backend's default for the plan's method").  The remaining
    flags are the capability matrix DESIGN.md §10 documents: they are what
    callers branch on instead of comparing backend names.
    """

    name: str
    #: engine= spellings valid on this backend's plans (None included)
    engines: Tuple[Optional[str], ...]
    #: engine=None resolution; ``stream_default_methods`` lists the methods
    #: whose default is "stream" instead (host: expand — its naive executor
    #: computes the same contraction, slower)
    default_engine: str
    stream_default_methods: Tuple[str, ...] = ()
    #: methods this backend cannot plan (pallas: the host-only executors)
    excluded_methods: Tuple[str, ...] = ()
    #: one plan execution runs B same-pattern value sets (DESIGN.md §7)
    supports_batched: bool = True
    #: executions can sit inside jax.jit / jax.grad traces (DESIGN.md §10)
    supports_grad: bool = False
    #: the per-method naive oracle executors are reachable (engine="naive")
    bit_exact_oracle: bool = False
    #: numeric phase runs on the accelerator (results carry device arrays)
    device_resident: bool = False
    #: plans carry a product stream (and obey the plan-memory guard)
    carries_stream: bool = False
    #: unit of the backend's cost-model estimates (core/cost.py):
    #: "seconds" (host wall time; comparable across seconds-domain
    #: backends in a mixed tile grid) or "relative" (kernel work units)
    cost_domain: str = "seconds"
    #: when set, every plannable method collapses to this one (jax: the
    #: numeric phase is the method-independent stream contraction, so
    #: distinct method spellings must share one plan/stream, not build
    #: per-spelling duplicates in the LRU)
    canonical_method: Optional[str] = None


_REGISTRY: "dict[str, ExecutionContract]" = {}


def register_backend(contract: ExecutionContract) -> ExecutionContract:
    """Register (or replace) a backend contract; returns it for chaining.

    Module-internal: a contract alone is not a working backend — it must
    also register an executor pair (``core.executor.register_executor``)
    and an ``AUTO_CANDIDATES`` entry (``core.cost``), which is why this is
    not re-exported as a public extension point.
    """
    _REGISTRY[contract.name] = contract
    return contract


def get_backend(name: str) -> ExecutionContract:
    """The contract of ``name``; raises the canonical unknown-backend error."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; one of {backend_names()}") from None


def backend_names() -> list:
    """Registered backend names, registration order."""
    return list(_REGISTRY)


def engine_spellings() -> tuple:
    """Union of every backend's accepted ``engine=`` spellings."""
    seen: list = []
    for c in _REGISTRY.values():
        for e in c.engines:
            if e not in seen:
                seen.append(e)
    return tuple(seen)


def default_engine(contract: ExecutionContract, method: str) -> str:
    """The engine ``engine=None`` resolves to for ``method`` on ``contract``."""
    if method in contract.stream_default_methods:
        return "stream"
    return contract.default_engine


def check_engine(contract: ExecutionContract, engine: Optional[str]) -> None:
    """Validate an ``engine=`` spelling against one backend's contract.

    Unknown spellings raise naming the full spelling union; known spellings
    the backend does not implement raise a capability error (e.g. the
    product stream is a host-backend/jax engine, and the jax backend has no
    naive oracles — ``bit_exact_oracle`` is False there).
    """
    if engine in contract.engines:
        return
    spellings = engine_spellings()
    if engine not in spellings:
        raise ValueError(
            f"unknown engine {engine!r}; one of "
            f"{', '.join(repr(e) for e in spellings)}")
    supported = sorted(
        c.name for c in _REGISTRY.values() if engine in c.engines)
    raise ValueError(
        f"engine={engine!r} is not available on the {contract.name!r} "
        f"backend; a {engine!r} execution needs a "
        f"{'-backend or '.join(supported)}-backend plan")


def check_method_knobs(contract: ExecutionContract, t, b_min, b_max) -> None:
    """Reject explicit oracle-tuning knobs on canonical-method backends.

    On a backend whose methods collapse to one canonical plan (jax), the
    t/b_min/b_max knobs configure executors that never run — loud
    rejection beats silently discarding an explicit argument.  Shared by
    ``core.api`` (cached paths) and ``core.planner.plan_spgemm``.
    """
    if contract.canonical_method and (
            t is not None or b_min is not None or b_max is not None):
        raise ValueError(
            f"t/b_min/b_max do not apply to backend={contract.name!r} "
            "(its numeric phase is the method-independent stream "
            "contraction)")


# ---------------------------------------------------------------------------
# the built-in contracts (DESIGN.md §10 capability matrix)
# ---------------------------------------------------------------------------

HOST = register_backend(ExecutionContract(
    name="host",
    engines=(None, "naive", "stream"),
    default_engine="naive",
    stream_default_methods=("expand",),
    supports_batched=True,
    supports_grad=False,
    bit_exact_oracle=True,
    device_resident=False,
    carries_stream=True,
))

PALLAS = register_backend(ExecutionContract(
    name="pallas",
    # "naive" is a no-op: the per-group kernel schedule.  "fused" is the
    # single-launch fused stream kernel (core/pallas_stream.py, DESIGN.md
    # §11) — it rides the plan's product stream, which is why the pallas
    # backend now carries one (built lazily: per-group executions never
    # touch it)
    engines=(None, "naive", "fused"),
    default_engine="naive",
    # the host-only executors have no kernel family, and the "jax" auto
    # candidate (the device stream riding a tile grid) has no pallas lane
    excluded_methods=HOST_ONLY_METHODS + ("jax",),
    supports_batched=True,
    supports_grad=False,
    bit_exact_oracle=False,
    device_resident=True,
    carries_stream=True,
    cost_domain="relative",
))

JAX = register_backend(ExecutionContract(
    name="jax",
    # the device stream, plus its fused-Pallas lowering (DESIGN.md §11)
    engines=(None, "stream", "fused"),
    default_engine="stream",
    supports_batched=True,
    supports_grad=True,
    bit_exact_oracle=False,
    device_resident=True,
    carries_stream=True,
    canonical_method="expand",   # the stream computes expand's contraction
))

MESH = register_backend(ExecutionContract(
    name="mesh",
    # one engine: every device replays its slice of the sharded stream
    # inside a single shard_map, partials reduced by a plan-static
    # psum_scatter over destination bins (DESIGN.md §13).  The per-device
    # replay *is* the jax stream, so the contract mirrors jax — including
    # canonical-method collapse and the bilinear custom_vjp — but the
    # plan-memory guard applies per shard, not to the whole stream.
    engines=(None, "stream"),
    default_engine="stream",
    supports_batched=True,
    supports_grad=True,
    bit_exact_oracle=False,
    device_resident=True,
    carries_stream=True,
    canonical_method="expand",
))

"""Product-stream numeric engine: gather → multiply → segment-reduce.

Every SpGEMM algorithm in the paper enumerates the same multiset of scalar
products ``A[i,k] * B[k,j]``; once a symbolic plan has cached C's structure,
the numeric phase is a *fixed contraction* — which products exist, which C
slot each lands in, and in what order they sum is all pattern-only.  This
module precomputes that contraction as a flat :class:`ProductStream` (the
propagation-blocking formulation of Gu et al., built once at plan time) and
replays it with a handful of vectorized numpy kernels::

    prod   = a_values[a_pos] * b_values[b_pos]      # every scalar product
    c_vals = segment_reduce(prod, seg_starts)       # one sum per C slot

No per-column Python loop survives; batching over a leading value axis is a
free broadcast of the same two lines (DESIGN.md §9).

Contract versus the naive executors: output structure is *canonical* (rows
ascending within each column, exactly the ``expand`` method's layout) and
each C slot sums its products in the same sorted stream order ``expand``
uses — but ``np.add.reduceat`` may re-associate long sums pairwise, so
values agree with the oracles to last-ulp accumulation differences, not
necessarily bit-for-bit.  The naive executors remain the faithful oracles;
this engine is the fast path (``engine="stream"``).

Memory guard: a stream costs O(flops) plan-resident memory, so
:func:`build_product_stream` refuses streams above ``max_products`` and the
plan stores ``stream=None``.  Execution then rebuilds the stream
*transiently* (same code path, nothing retained), so results are
bit-identical whether or not the guard tripped.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.expand import expand_positions, product_count
from repro.sparse.format import CSC, _np, segment_reduce

# plan-resident stream guard: ~20 bytes per product of retained index data.
# Above this the plan keeps stream=None and executions rebuild transiently.
# DEFAULT_STREAM_MAX_PRODUCTS is the shipped fallback; the live knob below
# is what the cost model and planner consult, and a calibrated machine
# profile can retune it to this host's RAM via
# ``core.profile.apply_tuning`` (DESIGN.md §15).
DEFAULT_STREAM_MAX_PRODUCTS = 8_000_000
STREAM_MAX_PRODUCTS = DEFAULT_STREAM_MAX_PRODUCTS

# batched execution: streams up to this many products run the whole value
# axis through one 2-D gather/reduce pass (amortizing per-call numpy
# overhead, the regime of small per-tile streams); longer streams loop the
# 1-D pass row by row — numpy's axis-1 fancy gather and reduceat are
# strided per segment and measure ~5x slower per element than the
# contiguous 1-D kernels, so a monolithic [B, P] pass only wins while
# per-row fixed overhead dominates (measured crossover ~1k products)
STREAM_BATCH_VECTOR_MAX = 1024
# ...and 2-D passes are row-blocked to bound the [block, P] working set
STREAM_BATCH_BLOCK_ELEMS = 1 << 20


@dataclasses.dataclass(frozen=True)
class ProductStream:
    """Pattern-only flat layout of every scalar product of ``C = A @ B``.

    ``a_pos``/``b_pos`` index the operands' value arrays, one entry per
    scalar product, stored with the C-slot sort permutation *pre-applied*
    (composed at plan time — re-executions pay no permute pass): products of
    C's p-th stored slot occupy ``[seg_starts[p], seg_starts[p+1])``, slots
    in canonical CSC order (column-major, rows ascending).  Within a
    segment, products keep Gustavson stream order — the same stable-lexsort
    order ``core.expand`` sums in.
    """

    a_pos: np.ndarray       # [P] int64: A value position of each product
    b_pos: np.ndarray       # [P] int64: B value position of each product
    seg_starts: np.ndarray  # [nnz_c] int64: reduceat segment boundaries
    c_rows: np.ndarray      # [nnz_c] int32: C's row indices
    c_col_ptr: np.ndarray   # [n+1] int32: C's column offsets
    shape: Tuple[int, int]

    @property
    def n_products(self) -> int:
        return int(self.a_pos.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.c_col_ptr[-1])

    @property
    def nbytes(self) -> int:
        """Plan-resident size of the stream's index arrays."""
        return (self.a_pos.nbytes + self.b_pos.nbytes
                + self.seg_starts.nbytes + self.c_rows.nbytes
                + self.c_col_ptr.nbytes)


def build_product_stream(a, b, max_products: int | None = None
                         ) -> Optional[ProductStream]:
    """Build the product stream for ``C = A @ B`` from structure alone.

    ``a``/``b``: anything with ``col_ptr``/``row_indices``/``shape``
    (:class:`~repro.core.planner.Pattern` or :class:`CSC`); values are never
    read.  Returns ``None`` when the stream would exceed ``max_products``
    (the plan-memory guard) — pass ``None`` to build unconditionally, as the
    transient fallback in :func:`execute_stream` does.

    The returned stream's arrays are frozen (non-writeable): results built
    by the engine share ``c_rows``/``c_col_ptr`` with the plan-resident
    stream, so an in-place mutation of a result must raise instead of
    silently corrupting every later same-plan execution.
    """
    a_cp = _np(a.col_ptr)
    a_rows = _np(a.row_indices)[: int(a_cp[-1])]
    b_cp = _np(b.col_ptr)
    b_rows = _np(b.row_indices)
    m, n = int(a.shape[0]), int(b.shape[1])

    if max_products is not None and product_count(
            a_cp, b_cp, b_rows) > max_products:
        return None
    # one entry per scalar product in Gustavson stream order — the same
    # index arithmetic core.expand builds on (single source: expand.py)
    a_pos, b_pos, cols = expand_positions(a_cp, b_cp, b_rows)
    total = len(a_pos)
    if total == 0:
        z = np.zeros(0, np.int64)
        return _frozen_stream(z, z.copy(), z.copy(), np.zeros(0, np.int32),
                              np.zeros(n + 1, np.int32), (m, n))
    rows = a_rows[a_pos].astype(np.int64)

    # sort products to C slots (stable: stream order survives per slot) and
    # pre-apply the permutation to the index arrays
    order = np.lexsort((rows, cols))
    rows, cols = rows[order], cols[order]
    key = cols * m + rows                  # ascending after the lexsort
    boundary = np.empty(total, bool)
    boundary[0] = True
    np.not_equal(key[1:], key[:-1], out=boundary[1:])
    starts = np.nonzero(boundary)[0].astype(np.int64)
    c_rows = rows[boundary].astype(np.int32)
    col_ptr = np.zeros(n + 1, np.int32)
    np.cumsum(np.bincount(cols[boundary], minlength=n), out=col_ptr[1:])
    return _frozen_stream(a_pos[order], b_pos[order], starts, c_rows,
                          col_ptr, (m, n))


def _frozen_stream(a_pos, b_pos, seg_starts, c_rows, c_col_ptr,
                   shape) -> ProductStream:
    for arr in (a_pos, b_pos, seg_starts, c_rows, c_col_ptr):
        arr.flags.writeable = False
    return ProductStream(a_pos, b_pos, seg_starts, c_rows, c_col_ptr, shape)


def _plan_stream(plan) -> tuple:
    """(stream, was_cached) — transient rebuild when the guard tripped."""
    s = plan.stream
    if s is not None:
        return s, True
    return build_product_stream(plan.a, plan.b), False


def execute_stream(plan, a_values: np.ndarray, b_values: np.ndarray,
                   stats: dict | None = None) -> CSC:
    """Numeric phase of a host plan through the product stream.

    ``a_values``/``b_values``: raw value arrays aligned with the planned
    patterns (already compatibility-checked by the executor).  The result is
    independent of ``plan.method`` — the stream engine computes the one
    canonical contraction every method agrees on.
    """
    s, cached = _plan_stream(plan)
    dtype = np.result_type(a_values.dtype, b_values.dtype)
    if s.n_products == 0:
        vals = np.zeros(0, dtype)
    else:
        prod = a_values[s.a_pos]
        prod = prod * b_values[s.b_pos]
        vals = segment_reduce(prod, s.seg_starts)
    if stats is not None:
        stats["engine"] = "stream"
        stats["stream_products"] = s.n_products
        stats["stream_cached"] = cached
        stats["result_shape"] = s.shape
    return CSC(vals.astype(dtype, copy=False), s.c_rows, s.c_col_ptr,
               s.shape)


def execute_stream_batched(plan, a_values: np.ndarray, b_values: np.ndarray,
                           stats: dict | None = None) -> list:
    """Batched stream execution: ``[B, nnz]`` stacks over the value axis.

    Short streams (``<= STREAM_BATCH_VECTOR_MAX`` products) run the whole
    value axis through 2-D gather/reduce passes in cache-bounded row
    blocks; longer streams loop the contiguous 1-D pass row by row (see
    the constants above for why).  ``np.add.reduceat`` along axis 1 is
    bit-identical per row to the 1-D reduction, so batched == looped either
    way.
    """
    s, cached = _plan_stream(plan)
    batch = a_values.shape[0]
    dtype = np.result_type(a_values.dtype, b_values.dtype)
    path = ("vectorized" if s.n_products <= STREAM_BATCH_VECTOR_MAX
            else "rowloop")
    if s.n_products == 0:
        vals = np.zeros((batch, 0), dtype)
    elif s.n_products <= STREAM_BATCH_VECTOR_MAX:
        blk = max(1, STREAM_BATCH_BLOCK_ELEMS // s.n_products)
        vals = np.empty((batch, s.nnz), dtype)
        for b0 in range(0, batch, blk):
            prod = a_values[b0:b0 + blk, s.a_pos]
            prod = prod * b_values[b0:b0 + blk, s.b_pos]
            vals[b0:b0 + blk] = segment_reduce(prod, s.seg_starts, axis=1)
    else:
        vals = np.empty((batch, s.nnz), dtype)
        for bi in range(batch):
            prod = a_values[bi, s.a_pos]
            prod = prod * b_values[bi, s.b_pos]
            vals[bi] = segment_reduce(prod, s.seg_starts)
    if stats is not None:
        stats["engine"] = "stream"
        stats["path"] = path
        stats["stream_products"] = s.n_products
        stats["stream_cached"] = cached
        stats["result_shape"] = s.shape
    vals = vals.astype(dtype, copy=False)
    return [CSC(vals[b], s.c_rows, s.c_col_ptr, s.shape)
            for b in range(batch)]

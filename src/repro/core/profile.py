"""Self-calibrating cost-model profiles (DESIGN.md §15).

Every constant in :mod:`repro.core.cost` used to be a hand-pasted snapshot
of one ``benchmarks/tiled.py --calibrate`` run on one CI container — so
``method="auto"`` on any *other* machine ranked engines with a stale model
(the honest-but-wrong-on-GPU ``jax_base`` of DESIGN.md §10 is the
documented symptom).  This module closes the loop the way the schedtool
exemplar infers LLVM machine models: **measure, fit, persist, predict,
cross-check**.

* :func:`machine_fingerprint` identifies the execution environment (CPU
  model, accelerator kind and count, jax version).  A profile is only ever
  trusted on the fingerprint it was measured on — change the device count
  (``--xla_force_host_platform_device_count``), the platform, or the jax
  version, and the persisted profile is invalidated instead of silently
  reused.
* :func:`calibrate_profile` runs a small synthetic microbenchmark ladder
  per (backend, engine) family — host SPA, the plan-resident product
  stream, the guard-tripped transient rebuild, the jitted device stream,
  the fused Pallas kernel, and (for the mesh backend) a real
  ``psum_scatter`` payload ladder — and fits each family's
  :class:`~repro.core.cost.CostConstants` terms by weighted least squares.
  It can also *auto-tune* the structural knobs the cost model sits on: the
  plan-memory guard (``fast.STREAM_MAX_PRODUCTS``), the fused product-axis
  block (``pallas_stream.FUSED_BLOCK``) and the auto tile-grid nnz targets
  (``sparse.partition``).
* :func:`save_profile` / :func:`load_profile` persist the fit as one JSON
  file per fingerprint under ``REPRO_PROFILE_DIR`` (default
  ``~/.cache/repro-spgemm/profiles``); :func:`current_profile` loads it
  lazily on the first cost-model consult, so ``DEFAULT_CONSTANTS`` is the
  *fallback*, not the truth.  Set ``REPRO_AUTO_CALIBRATE=1`` to run the
  smoke ladder automatically on first use when no profile exists
  (otherwise pre-warm with ``benchmarks/calibrate_profile.py``).

Provenance (``measured`` vs ``default``, fingerprint, age) is stamped into
``plan_cache_info()['profile']``, every ``BENCH_*.json`` ``env`` header,
and the params of every auto plan — a prediction is only as good as the
calibration it came from, so the calibration is always on the record.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import platform
import threading
import time
import warnings
from typing import Optional

import numpy as np

from repro.core.cost import CostConstants, DEFAULT_CONSTANTS

PROFILE_VERSION = 1

#: structural-knob tuning keys a profile may carry (DESIGN.md §15):
#: ``stream_max_products`` -> ``fast.STREAM_MAX_PRODUCTS`` (plan-memory
#: guard), ``fused_block`` -> ``pallas_stream.FUSED_BLOCK`` (fused kernel
#: product-axis tile), ``tile_n_target``/``tile_k_target`` -> the auto
#: tile-grid nnz targets ``sparse.partition.auto_tile_grid`` sizes from.
TUNING_KEYS = ("stream_max_products", "fused_block",
               "tile_n_target", "tile_k_target")

_LOCK = threading.RLock()
_STATE: dict = {"profile": None, "loading": False}
_COUNTERS = {"default_auto_uses": 0, "stale_discards": 0, "load_errors": 0,
             "auto_calibrations": 0}
_WARNED: set = set()


# ---------------------------------------------------------------------------
# machine fingerprint
# ---------------------------------------------------------------------------


def _cpu_model() -> str:
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return platform.processor() or platform.machine()


def machine_fingerprint() -> dict:
    """Identity of the execution environment a profile is valid on.

    Captures everything the measured constants depend on: the host CPU, the
    accelerator platform / device kind / *device count* (a forced
    ``--xla_force_host_platform_device_count`` run is a different machine
    as far as the comm ladder is concerned), and the jax version (compiler
    changes move the constants).  Deliberately excludes anything
    per-process (pid, time, cwd).
    """
    import jax

    devices = jax.devices()
    return {
        "cpu": _cpu_model(),
        "machine": platform.machine(),
        "platform": devices[0].platform if devices else "none",
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
        "jax": jax.__version__,
        "profile_version": PROFILE_VERSION,
    }


def fingerprint_key(fp: dict | None = None) -> str:
    """Short stable hash of a fingerprint (profile filename stem)."""
    fp = machine_fingerprint() if fp is None else fp
    blob = json.dumps(fp, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def profile_dir() -> str:
    """Where profiles persist: ``$REPRO_PROFILE_DIR`` or the user cache."""
    d = os.environ.get("REPRO_PROFILE_DIR")
    if d:
        return d
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-spgemm",
                        "profiles")


# ---------------------------------------------------------------------------
# the profile object
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """One machine's measured cost model + tuned structural knobs.

    ``fitted`` names the :class:`CostConstants` fields that actually came
    out of this machine's microbenchmark ladder — everything else is the
    ``DEFAULT_CONSTANTS`` fallback riding along (e.g. ``comm_byte`` on a
    single-device host, where no collective moves real payload).
    ``source`` is ``"measured"`` or ``"default"``.
    """

    constants: CostConstants
    fingerprint: dict
    source: str = "default"
    created_at: float = 0.0
    fitted: tuple = ()
    tuning: dict = dataclasses.field(default_factory=dict)
    path: Optional[str] = None

    @property
    def key(self) -> str:
        return fingerprint_key(self.fingerprint)

    @property
    def tag(self) -> str:
        """Provenance token recorded in plan params / cache keys: two
        plans built under different calibrations must never alias."""
        if self.source == "default":
            return "default"
        return f"{self.source}:{self.key}:{int(self.created_at)}"

    def age_seconds(self) -> Optional[float]:
        if not self.created_at:
            return None
        return max(time.time() - self.created_at, 0.0)

    def provenance(self) -> dict:
        """The stamp BENCH ``env`` headers and ``plan_cache_info`` carry."""
        age = self.age_seconds()
        return {
            "source": self.source,
            "fingerprint_key": self.key,
            "fingerprint": dict(self.fingerprint),
            "created_at": self.created_at,
            "age_seconds": None if age is None else round(age, 3),
            "fitted": list(self.fitted),
            "tuning": dict(self.tuning),
            "path": self.path,
        }

    def to_json(self) -> dict:
        return {
            "version": PROFILE_VERSION,
            "fingerprint": dict(self.fingerprint),
            "source": self.source,
            "created_at": self.created_at,
            "fitted": list(self.fitted),
            "tuning": dict(self.tuning),
            "constants": dataclasses.asdict(self.constants),
        }

    @staticmethod
    def from_json(doc: dict, path: str | None = None) -> "MachineProfile":
        known = {f.name for f in dataclasses.fields(CostConstants)}
        vals = {k: float(v) for k, v in doc.get("constants", {}).items()
                if k in known}
        return MachineProfile(
            constants=dataclasses.replace(DEFAULT_CONSTANTS, **vals),
            fingerprint=dict(doc["fingerprint"]),
            source=str(doc.get("source", "measured")),
            created_at=float(doc.get("created_at", 0.0)),
            fitted=tuple(doc.get("fitted", ())),
            tuning={k: v for k, v in doc.get("tuning", {}).items()
                    if k in TUNING_KEYS},
            path=path,
        )


def default_profile() -> MachineProfile:
    """The fallback: hand-tuned ``DEFAULT_CONSTANTS``, no tuning, honest
    ``source="default"`` provenance."""
    return MachineProfile(constants=DEFAULT_CONSTANTS,
                          fingerprint=machine_fingerprint(),
                          source="default")


def save_profile(prof: MachineProfile, directory: str | None = None) -> str:
    """Persist ``prof`` as ``<fingerprint-key>.json`` under ``directory``
    (default :func:`profile_dir`); returns the written path."""
    d = profile_dir() if directory is None else directory
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{prof.key}.json")
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(prof.to_json(), f, indent=2, sort_keys=True)
    os.replace(tmp, path)   # atomic: a concurrent loader never sees a torn file
    return path


def load_profile(directory: str | None = None,
                 path: str | None = None) -> Optional[MachineProfile]:
    """Load the persisted profile for *this* machine, or ``None``.

    Looks for ``<fingerprint-key>.json`` under ``directory`` (default
    :func:`profile_dir`), or reads the explicit ``path``.  A file whose
    stored fingerprint does not match the current machine — the device
    count changed (e.g. a forced host-device run), different platform,
    different jax — is **discarded**, not silently reused: it returns
    ``None`` and counts a ``stale_discards`` in
    ``plan_cache_info()['profile']``.  Unreadable/corrupt files count
    ``load_errors`` and also fall back to ``None``.
    """
    fp = machine_fingerprint()
    if path is None:
        d = profile_dir() if directory is None else directory
        path = os.path.join(d, f"{fingerprint_key(fp)}.json")
        env_file = os.environ.get("REPRO_PROFILE_FILE")
        if env_file:
            path = env_file
        elif not os.path.exists(path):
            return None
    try:
        with open(path) as f:
            doc = json.load(f)
        prof = MachineProfile.from_json(doc, path=path)
    except (OSError, ValueError, KeyError, TypeError):
        with _LOCK:
            _COUNTERS["load_errors"] += 1
        return None
    if prof.fingerprint != fp:
        # the machine changed under the profile — invalidate, do not reuse
        with _LOCK:
            _COUNTERS["stale_discards"] += 1
        _warn_once(
            f"stale:{path}",
            f"persisted cost profile {path} was measured on a different "
            f"machine fingerprint (e.g. device count "
            f"{prof.fingerprint.get('device_count')} vs "
            f"{fp['device_count']}); discarding it and falling back to "
            "DEFAULT_CONSTANTS — re-run benchmarks/calibrate_profile.py")
        return None
    return prof


# ---------------------------------------------------------------------------
# current-profile state (lazy load; the cost model's constant source)
# ---------------------------------------------------------------------------


def current_profile() -> MachineProfile:
    """The profile the cost model consults when no explicit constants are
    passed: the persisted fit for this machine's fingerprint if one exists
    (loaded lazily, once), else :func:`default_profile`.  With
    ``REPRO_AUTO_CALIBRATE=1`` a missing profile triggers the smoke
    calibration ladder on first use (and persists its result)."""
    p = _STATE["profile"]
    if p is not None:
        return p
    with _LOCK:
        if _STATE["profile"] is not None:
            return _STATE["profile"]
        if _STATE["loading"]:
            # re-entrant consult from inside the auto-calibration ladder
            return default_profile()
        _STATE["loading"] = True
        try:
            prof = load_profile()
            if prof is None and os.environ.get(
                    "REPRO_AUTO_CALIBRATE", "0") not in ("", "0"):
                try:
                    prof = calibrate_profile(scale=0.25, reps=2, save=True)
                    _COUNTERS["auto_calibrations"] += 1
                except Exception as e:   # calibration must never take down
                    _warn_once("autocal",  # the caller's multiply
                               f"first-use auto-calibration failed ({e!r}); "
                               "continuing on DEFAULT_CONSTANTS")
            _STATE["profile"] = prof or default_profile()
        finally:
            _STATE["loading"] = False
        return _STATE["profile"]


def set_profile(prof: Optional[MachineProfile]) -> None:
    """Install ``prof`` as the current profile (``None`` resets to the
    unloaded state, so the next consult re-reads disk).  Test/benchmark
    hook — also clears the warn-once dedup so a fresh profile regime
    warns afresh."""
    with _LOCK:
        _STATE["profile"] = prof
        _WARNED.clear()


def reset(counters: bool = True) -> None:
    """Forget the loaded profile (and optionally zero the telemetry
    counters) — used by tests to isolate profile state."""
    with _LOCK:
        _STATE["profile"] = None
        _WARNED.clear()
        if counters:
            for k in _COUNTERS:
                _COUNTERS[k] = 0


def current_constants() -> CostConstants:
    return current_profile().constants


def profile_info() -> dict:
    """Provenance + telemetry block surfaced as
    ``plan_cache_info()['profile']`` and in BENCH ``env`` headers."""
    prof = current_profile()
    out = prof.provenance()
    with _LOCK:
        out.update(_COUNTERS)
    return out


def _warn_once(dedup_key: str, message: str) -> None:
    with _LOCK:
        if dedup_key in _WARNED:
            return
        _WARNED.add(dedup_key)
    warnings.warn(message, RuntimeWarning, stacklevel=3)


def note_default_auto(backend: str, candidates: tuple = ()) -> None:
    """Record that ``method="auto"`` just ranked device-resident engines on
    ``DEFAULT_CONSTANTS`` — the stale-constants trap.  Counts every use in
    ``plan_cache_info()['profile']['default_auto_uses']`` and warns once
    per backend.  Called by the cost model only when the resolved profile
    is the default *and* the ranking involves a device family (the device
    constants are the ones known to be machine-sensitive)."""
    from repro.core import backends

    device_families = {"jax", "fused"}
    contract = backends.get_backend(backend)
    if not (contract.device_resident or device_families & set(candidates)):
        return
    with _LOCK:
        _COUNTERS["default_auto_uses"] += 1
    _warn_once(
        f"default-auto:{backend}",
        f"method='auto' on backend={backend!r} is ranking device engines "
        "with uncalibrated DEFAULT_CONSTANTS (no cost profile persisted "
        f"for this machine fingerprint {fingerprint_key()}); its picks are "
        "a stale snapshot of another machine — run "
        "benchmarks/calibrate_profile.py (or set REPRO_AUTO_CALIBRATE=1) "
        "to measure this machine")


def apply_tuning(prof: MachineProfile | None = None) -> dict:
    """Apply a profile's tuned structural knobs to the live module globals.

    Sets ``fast.STREAM_MAX_PRODUCTS`` and ``pallas_stream.FUSED_BLOCK``
    from ``prof.tuning`` (the tile targets are consulted live by
    ``sparse.partition.auto_tile_grid`` and need no global).  Explicit —
    never run implicitly on load, because mutating the guard re-keys every
    cached stream plan.  Returns ``{knob: value}`` for what was applied.
    """
    import repro.core.fast as fast
    import repro.core.pallas_stream as pallas_stream

    prof = current_profile() if prof is None else prof
    applied = {}
    t = prof.tuning
    if "stream_max_products" in t:
        fast.STREAM_MAX_PRODUCTS = int(t["stream_max_products"])
        applied["stream_max_products"] = fast.STREAM_MAX_PRODUCTS
    if "fused_block" in t:
        pallas_stream.FUSED_BLOCK = int(t["fused_block"])
        applied["fused_block"] = pallas_stream.FUSED_BLOCK
    return applied


# ---------------------------------------------------------------------------
# rank correlation (the predict-vs-measure cross-check metric)
# ---------------------------------------------------------------------------


def rank_correlation(x, y) -> float:
    """Spearman rank correlation (average ranks for ties, scipy-free).

    The cross-check the whole subsystem is graded on: the cost model only
    has to order candidates correctly, so the fit is validated by how well
    predicted costs *rank* against measured times, not by absolute error.
    """
    x = np.asarray(x, float)
    y = np.asarray(y, float)
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"need equal-length 1-D arrays, got {x.shape} "
                         f"vs {y.shape}")
    if len(x) < 2:
        return 1.0

    def _ranks(v):
        order = np.argsort(v, kind="stable")
        sv = v[order]
        # average rank per tie group
        boundary = np.empty(len(sv), bool)
        boundary[0] = True
        np.not_equal(sv[1:], sv[:-1], out=boundary[1:])
        group = np.cumsum(boundary) - 1
        counts = np.bincount(group)
        firsts = np.concatenate(([0], np.cumsum(counts)[:-1]))
        avg = firsts + (counts - 1) / 2.0
        out = np.empty(len(v))
        out[order] = avg[group]
        return out

    rx, ry = _ranks(x), _ranks(y)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = math.sqrt(float((rx ** 2).sum()) * float((ry ** 2).sum()))
    if denom == 0.0:
        return 1.0
    return float((rx * ry).sum() / denom)


# ---------------------------------------------------------------------------
# fitting (pure: measurement rows in, constants out)
# ---------------------------------------------------------------------------


def fit_fields(fields: tuple, rows, times, floor: float = 1e-12) -> dict:
    """Weighted least squares fit of ``times ~ rows @ coeffs``.

    ``rows[i]`` holds one feature value per field (e.g. ``[1, flops]`` for
    a base+slope family).  Rows are weighted by ``1/t`` so every config
    contributes its *relative* error — without this the largest config
    dominates and the base terms come out meaningless or negative.
    Coefficients are clamped to ``>= floor`` (a cost term is a physical
    duration; a negative fit means the ladder under-determined it).
    """
    a = np.asarray(rows, float)
    t = np.asarray(times, float)
    if a.ndim != 2 or a.shape != (len(t), len(fields)):
        raise ValueError(
            f"rows {a.shape} inconsistent with {len(t)} times / "
            f"{len(fields)} fields")
    w = 1.0 / np.maximum(t, 1e-12)
    coef, *_ = np.linalg.lstsq(a * w[:, None], t * w, rcond=None)
    return {f: float(max(c, floor)) for f, c in zip(fields, coef)}


def fit_constants(sections, base: CostConstants | None = None
                  ) -> tuple[CostConstants, tuple]:
    """Fold per-family measurement sections into one ``CostConstants``.

    ``sections`` is an iterable of ``(fields, rows, times)`` triples (one
    per microbenchmark family, as produced by the measurement ladder or by
    a synthetic-timing test).  Returns the merged constants (unmeasured
    fields keep ``base``'s values) and the tuple of fitted field names.
    """
    base = DEFAULT_CONSTANTS if base is None else base
    fitted: dict = {}
    for fields, rows, times in sections:
        fitted.update(fit_fields(tuple(fields), rows, times))
    return dataclasses.replace(base, **fitted), tuple(sorted(fitted))


# ---------------------------------------------------------------------------
# the synthetic microbenchmark ladder
# ---------------------------------------------------------------------------


def _best_of(fn, reps: int) -> float:
    """Min-of-reps wall time: the de-noised estimate a fit can trust."""
    best = math.inf
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _dense_sparse_pair(m: int, n: int, per_col: int, rng):
    """Dense A (every B entry fans out m products) x sparse B — the flop
    ladder's workhorse: flops = nnz_b * m, exactly controllable."""
    from repro.sparse.format import csc_from_dense

    a = csc_from_dense(np.ones((m, m)))
    bd = np.zeros((m, n))
    for j in range(n):
        bd[rng.integers(m, size=min(per_col, m)), j] = 1.0
    return a, csc_from_dense(bd)


def _measure_spa(scale: float, reps: int, rng):
    """Host SPA family: time = spa_col*n + spa_entry*nnz_b + spa_flop*flops.

    Three regimes isolate the three terms (all-empty columns, entry-heavy,
    flop-heavy) plus a mixed row to anchor the joint fit.
    """
    from repro.core.naive import spa_numpy
    from repro.sparse.format import CSC, csc_from_dense

    fields = ("spa_col", "spa_entry", "spa_flop")
    rows, times = [], []

    n = max(int(3000 * scale), 200)
    a0 = csc_from_dense(np.zeros((32, 32)))
    b0 = CSC(np.zeros(0), np.zeros(0, np.int32),
             np.zeros(n + 1, np.int32), (32, n))
    rows.append([n, 0.0, 0.0])
    times.append(_best_of(lambda: spa_numpy(a0, b0), reps))

    k, n = 256, max(int(1500 * scale), 150)
    ad = np.zeros((k, k))
    ad[0, :] = 1.0
    a1 = csc_from_dense(ad)
    bd = np.zeros((k, n))
    for j in range(n):
        bd[rng.integers(k, size=4), j] = 1.0
    b1 = csc_from_dense(bd)
    rows.append([n, b1.nnz, b1.nnz])     # 1 nnz/A-col: flops == nnz_b
    times.append(_best_of(lambda: spa_numpy(a1, b1), reps))

    m = max(int(768 * scale), 192)
    a2, b2 = _dense_sparse_pair(m, 192, 8, rng)
    rows.append([192, b2.nnz, b2.nnz * m])
    times.append(_best_of(lambda: spa_numpy(a2, b2), reps))

    m = max(int(384 * scale), 96)
    a3, b3 = _dense_sparse_pair(m, max(int(600 * scale), 100), 3, rng)
    rows.append([b3.n_cols, b3.nnz, b3.nnz * m])
    times.append(_best_of(lambda: spa_numpy(a3, b3), reps))
    return fields, rows, times


def _stream_ladder(scale: float, rng):
    """(plan, flops) pairs spanning the stream engine's flop range."""
    from repro.core.planner import plan_spgemm

    out = []
    # the near-empty (8, 4, 1) rung pins the base (dispatch) terms of all
    # three stream families — see the matching note in _measure_fused
    for m, n, per in ((8, 4, 1), (64, 32, 2), (192, 96, 4),
                      (max(int(512 * scale), 128), 128, 6),
                      (max(int(1024 * scale), 256), 256, 8)):
        a, b = _dense_sparse_pair(m, n, per, rng)
        out.append((plan_spgemm(a, b, "expand", stream_limit=b.nnz * m + 1),
                    a, b, b.nnz * m))
    return out


def _measure_stream(ladder, reps: int):
    """Plan-resident product stream: time = stream_base + stream_prod*P."""
    fields = ("stream_base", "stream_prod")
    rows, times = [], []
    for plan, a, b, flops in ladder:
        plan.execute(a, b, engine="stream")   # warmup: lazy stream build
        rows.append([1.0, flops])
        times.append(_best_of(
            lambda: plan.execute(a, b, engine="stream"), reps))
    return fields, rows, times


def _measure_expand(ladder, reps: int):
    """Guard-tripped transient rebuild: expand_base + expand_prod*P +
    expand_sort*P*log2(P) per call (nothing plan-resident)."""
    from repro.core.expand import spgemm_expand

    fields = ("expand_base", "expand_prod", "expand_sort")
    rows, times = [], []
    for _, a, b, flops in ladder:
        rows.append([1.0, flops, flops * math.log2(max(flops, 2))])
        times.append(_best_of(lambda: spgemm_expand(a, b), reps))
    return fields, rows, times


def _measure_jax(ladder, reps: int):
    """Jitted device stream: jax_base + jax_prod*P, cached-trace steady
    state (block_until_ready — dispatch is async)."""
    from repro.core.planner import plan_spgemm

    fields = ("jax_base", "jax_prod")
    rows, times = [], []
    for _, a, b, flops in ladder:
        plan = plan_spgemm(a, b, "expand", backend="jax",
                           stream_limit=flops + 1)
        plan.execute(a, b).values.block_until_ready()   # lift + trace
        rows.append([1.0, flops])
        times.append(_best_of(
            lambda: plan.execute(a, b).values.block_until_ready(), reps))
    return fields, rows, times


def _measure_fused(scale: float, reps: int, rng):
    """Fused Pallas stream kernel: fused_base + fused_prod*P.

    Small sizes only — on CPU the kernel runs under
    ``pallas_call(interpret=True)`` and costs minutes per Mproduct; the
    honest interpret-mode constants keep auto from ever picking "fused"
    here, which is exactly what they should do.
    """
    from repro.core.planner import plan_spgemm

    fields = ("fused_base", "fused_prod")
    rows, times = [], []
    # the (8, 4, 1) rung is near-empty on purpose: it pins the base
    # (dispatch) term, which a flop ladder alone under-determines — an
    # unpinned base fits negative, clamps to the floor, and a ~free
    # fused_base makes auto pick "fused" for every tiny tile
    for m, n, per in ((8, 4, 1), (32, 16, 2), (96, 48, 3),
                      (max(int(160 * scale), 64), 64, 4)):
        a, b = _dense_sparse_pair(m, n, per, rng)
        flops = b.nnz * m
        plan = plan_spgemm(a, b, "expand", backend="jax",
                           stream_limit=flops + 1)
        plan.execute(a, b, engine="fused").values.block_until_ready()
        rows.append([1.0, flops])
        times.append(_best_of(
            lambda: plan.execute(a, b, engine="fused")
            .values.block_until_ready(), reps))
    return fields, rows, times


def _measure_comm(scale: float, reps: int):
    """Mesh collective ladder: a real tiled ``psum_scatter`` over growing
    payloads — comm_base + comm_byte * bytes, where a D-device scatter of
    an S-slot f32 axis moves ``4*S*(D-1)/D`` bytes per device
    (DESIGN.md §13's comm model, measured instead of assumed).

    On a single-device mesh no payload crosses any link, so only
    ``comm_base`` (collective dispatch overhead) is measurable —
    ``comm_byte`` keeps its default and is not reported as fitted.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    devices = jax.devices()
    d = len(devices)
    mesh = Mesh(np.asarray(devices), ("shards",))
    fields = ("comm_base", "comm_byte") if d > 1 else ("comm_base",)
    rows, times = [], []
    for s in (int(8e3 * scale) + d, int(1e5 * scale) + d,
              int(5e5 * scale) + d, int(2e6 * scale) + d):
        s = -(-s // d) * d
        fn = jax.jit(shard_map(
            lambda v: jax.lax.psum_scatter(
                v[0], "shards", scatter_dimension=0, tiled=True)[None],
            mesh=mesh,
            in_specs=PartitionSpec("shards", None),
            out_specs=PartitionSpec("shards", None)))
        x = jnp.ones((d, s), jnp.float32)
        fn(x).block_until_ready()
        row = [1.0, 4.0 * s * (d - 1) / d]
        rows.append(row[: len(fields)])
        times.append(_best_of(lambda: fn(x).block_until_ready(), reps))
    return fields, rows, times


# ---------------------------------------------------------------------------
# structural-knob tuning searches
# ---------------------------------------------------------------------------


def _tune_stream_guard() -> int:
    """Plan-memory guard sized from this machine's RAM instead of the
    hardcoded 8M: ~20 plan-resident bytes per product, budgeted at 5% of
    physical memory, clamped to [1M, 64M] products."""
    import repro.core.fast as fast

    try:
        ram = os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE")
    except (ValueError, OSError, AttributeError):
        return fast.DEFAULT_STREAM_MAX_PRODUCTS
    return int(min(max(ram * 0.05 / 20.0, 1_000_000), 64_000_000))


def _tune_fused_block(scale: float, reps: int, rng) -> int:
    """Measured argmin over candidate fused product-axis blocks."""
    from repro.core.pallas_stream import fused_stream
    from repro.core.planner import plan_spgemm

    a, b = _dense_sparse_pair(96, 48, 3, rng)
    best_block, best_t = None, math.inf
    for block in (64, 128, 256):
        plan = plan_spgemm(a, b, "expand", backend="jax",
                           stream_limit=b.nnz * 96 + 1)
        fused_stream(plan, block=block)   # build the views under this block
        plan.execute(a, b, engine="fused").values.block_until_ready()
        t = _best_of(lambda: plan.execute(a, b, engine="fused")
                     .values.block_until_ready(), reps)
        if t < best_t:
            best_block, best_t = block, t
    return int(best_block)


def _tune_tile_targets(constants: CostConstants, scale: float, reps: int,
                       rng) -> tuple[int, int]:
    """Measured argmin over auto tile-grid nnz targets on a small
    mixed-density probe (the §8 workload in miniature).  Each candidate is
    evaluated through the real consumption path: a trial profile carrying
    the candidate targets is installed, the auto plan built under it, and
    its plan-reuse numeric time measured."""
    from repro.core.planner import plan_spgemm_tiled
    from repro.sparse.format import csc_from_dense

    m, n_sparse, dense = 128, max(int(512 * scale), 128), 12
    ad = np.zeros((m, m))
    ad[:, :dense] = rng.uniform(0.5, 1.5, size=(m, dense))
    for j in range(dense, m):
        ad[rng.integers(m, size=2), j] = 1.0
    bd = np.zeros((m, dense + n_sparse))
    for j in range(dense):
        bd[rng.choice(dense, size=dense, replace=False), j] = 1.0
    for j in range(dense, dense + n_sparse):
        bd[dense + rng.integers(m - dense, size=2), j] = 1.0
    a, b = csc_from_dense(ad), csc_from_dense(bd)

    prev = _STATE["profile"]
    best, best_t = None, math.inf
    try:
        for n_target in (2048, 8192, 32768):
            trial = MachineProfile(
                constants=constants, fingerprint=machine_fingerprint(),
                source="measured", created_at=time.time(),
                tuning={"tile_n_target": n_target,
                        "tile_k_target": 16 * n_target})
            set_profile(trial)
            plan = plan_spgemm_tiled(a, b, cache=False, constants=constants)
            plan.execute(a, b)
            t = _best_of(lambda: plan.execute(a, b), reps)
            if t < best_t:
                best, best_t = n_target, t
    finally:
        set_profile(prev)
    return int(best), int(16 * best)


# ---------------------------------------------------------------------------
# the calibration entry point
# ---------------------------------------------------------------------------

SECTIONS = ("spa", "stream", "expand", "jax", "fused", "comm")


def calibrate_profile(*, scale: float = 1.0, reps: int = 3,
                      sections: tuple = SECTIONS, tune: bool = True,
                      seed: int = 0, save: bool = False,
                      directory: str | None = None,
                      base: MachineProfile | None = None) -> MachineProfile:
    """Run the microbenchmark ladder, fit constants, optionally persist.

    ``scale`` shrinks ladder sizes (0.25 = the smoke ladder CI runs);
    ``sections`` restricts which (backend, engine) families are
    re-measured — unmeasured fields keep ``base``'s values (default: the
    currently persisted profile if any, else ``DEFAULT_CONSTANTS``), so a
    forced-8-device run can refresh just the ``comm`` ladder into the same
    directory.  ``tune=True`` additionally searches the structural knobs
    (guard, fused block, tile targets).  ``save=True`` persists via
    :func:`save_profile` and installs the result as the current profile.
    """
    bad = [s for s in sections if s not in SECTIONS]
    if bad:
        raise ValueError(f"unknown sections {bad}; one of {SECTIONS}")
    rng = np.random.default_rng(seed)
    if base is None:
        base = load_profile(directory=directory) or default_profile()

    measured = []
    ladder = None
    if {"stream", "expand", "jax"} & set(sections):
        ladder = _stream_ladder(scale, rng)
    if "spa" in sections:
        measured.append(_measure_spa(scale, reps, rng))
    if "stream" in sections:
        measured.append(_measure_stream(ladder, reps))
    if "expand" in sections:
        measured.append(_measure_expand(ladder, reps))
    if "jax" in sections:
        measured.append(_measure_jax(ladder, reps))
    if "fused" in sections:
        measured.append(_measure_fused(scale, reps, rng))
    if "comm" in sections:
        measured.append(_measure_comm(scale, reps))

    constants, fitted = fit_constants(measured, base=base.constants)
    fitted = tuple(sorted(set(base.fitted) | set(fitted)))

    tuning = dict(base.tuning)
    if tune:
        tuning["stream_max_products"] = _tune_stream_guard()
        if "fused" in sections:
            tuning["fused_block"] = _tune_fused_block(scale, reps, rng)
        if "spa" in sections or "stream" in sections:
            n_t, k_t = _tune_tile_targets(constants, scale, reps, rng)
            tuning["tile_n_target"], tuning["tile_k_target"] = n_t, k_t

    prof = MachineProfile(constants=constants,
                          fingerprint=machine_fingerprint(),
                          source="measured", created_at=time.time(),
                          fitted=fitted, tuning=tuning)
    if save:
        path = save_profile(prof, directory=directory)
        prof = dataclasses.replace(prof, path=path)
        set_profile(prof)
    return prof

"""Dense oracle for SpGEMM — the ground truth every algorithm is tested against."""

from __future__ import annotations

import numpy as np

from repro.sparse.format import CSC, csc_from_dense, csc_to_dense


def spgemm_dense(a: CSC, b: CSC, tol: float = 0.0) -> CSC:
    """C = A @ B by densification. O(m*n*k) — tests and small inputs only."""
    da = csc_to_dense(a)
    db = csc_to_dense(b)
    return csc_from_dense(da @ db, tol=tol)


def dense_product(a: CSC, b: CSC) -> np.ndarray:
    return csc_to_dense(a) @ csc_to_dense(b)

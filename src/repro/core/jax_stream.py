"""Device-resident stream execution: jit-compatible, differentiable SpGEMM.

The product stream (``core.fast``, DESIGN.md §9) already reduced the numeric
phase of a cached host plan to a fixed gather → multiply → segment-reduce
contraction.  This module compiles that contraction for the ``"jax"``
backend (DESIGN.md §10): the plan's frozen index arrays move to the device
once (cached on the plan alongside the numpy ones), and the numeric phase
becomes a jitted pure-JAX function of the two value arrays::

    prod   = a_values[a_pos] * b_values[b_pos]          # jnp.take
    c_vals = segment_sum(prod, seg_ids, num_segments)   # plan-static nnz_c

Because every shape in that function is plan-static, it traces once and
replays from XLA's compiled-call cache — an execution is a single device
dispatch, with no per-group Python loop (the Pallas path launches one
kernel per plan group from Python) and no host round-trip.

**Differentiability.**  The contraction is bilinear, so its VJP is two more
stream replays through the *same* index arrays — no new symbolic work::

    dL/dA[p] = Σ_{q : a_pos[q]=p}  ḡ[seg(q)] · B[b_pos[q]]
    dL/dB[p] = Σ_{q : b_pos[q]=p}  ḡ[seg(q)] · A[a_pos[q]]

i.e. broadcast the output cotangent back over the products (a ``take``
through ``seg_ids``), weight by the *other* operand's gathered values, and
scatter-add through ``a_pos``/``b_pos`` (a ``segment_sum`` with the
operand's nnz as the static segment count).  :func:`stream_fn` installs
this as a ``jax.custom_vjp`` so ``jax.grad`` of anything downstream of the
C values is itself a pair of stream replays.  ``jax.vmap`` composes with
the custom vjp, which is how the batched path (DESIGN.md §7) rides one
trace for a whole ``[B, nnz]`` value stack.

**Guard semantics.**  Device streams obey the same plan-memory guard as
host streams (``stream_limit`` resolved at plan time).  A guarded jax plan
executes by falling back to the *host* stream engine (transient rebuild,
numerically the host stream's result) when the operands are concrete;
under a trace (``jax.jit``/``jax.grad`` — the operands are tracers) the
fallback is impossible and a capability error explains the fix.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fast, faults
from repro.sparse.format import CSC, BatchedCSC

# int32 device indices: the plan-memory guard caps streams far below 2**31
# products.  a_pos/b_pos index the *operand* value arrays, whose nnz is not
# bounded by the stream length, so the overflow check below covers both.
_I32_MAX = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class DeviceStream:
    """Device-resident half of a plan's :class:`~repro.core.fast.ProductStream`.

    ``a_pos``/``b_pos``/``seg_ids`` live on the device (int32; one entry per
    scalar product, C-slot sort permutation pre-applied exactly as in the
    host stream).  ``c_rows``/``c_col_ptr`` stay host-side numpy — they are
    the *structure* of every result this plan produces and are shared
    (frozen) with the host stream.
    """

    a_pos: jax.Array        # [P] int32: A value position of each product
    b_pos: jax.Array        # [P] int32: B value position of each product
    seg_ids: jax.Array      # [P] int32: C slot of each product (ascending)
    c_rows: np.ndarray      # [nnz_c] int32 (host, frozen)
    c_col_ptr: np.ndarray   # [n+1] int32 (host, frozen)
    shape: Tuple[int, int]
    n_products: int
    num_segments: int       # nnz_c — the static segment_sum count

    @property
    def nbytes(self) -> int:
        """Device bytes held by the stream's index arrays."""
        return int(self.a_pos.nbytes + self.b_pos.nbytes
                   + self.seg_ids.nbytes)


def check_int32_stream(plan, s) -> None:
    """Reject streams whose indices overflow int32 device arrays.

    A hard error beats int32-wrapped in-bounds-promised gathers:
    products/output slots (huge guard) or *operand* positions
    (``a_pos``/``b_pos`` index the value arrays — a small stream over a
    >2**31-nnz operand still needs wide indices) past int32.  Shared by
    the device stream and the fused Pallas stream (``core.pallas_stream``),
    whose index arrays bound-check identically.
    """
    if max(s.n_products, s.nnz, int(plan.a.col_ptr[-1]),
           int(plan.b.col_ptr[-1])) > _I32_MAX:
        raise ValueError(
            f"stream of {s.n_products} products over operands of nnz "
            f"{int(plan.a.col_ptr[-1])}/{int(plan.b.col_ptr[-1])} "
            "exceeds int32 device indexing; lower stream_limit / "
            "fast.STREAM_MAX_PRODUCTS or shrink the tile")


def stream_seg_ids(s) -> np.ndarray:
    """Per-product C-slot id of a host stream (int32, non-decreasing).

    Segment p spans ``[seg_starts[p], seg_starts[p+1])`` of the sorted
    stream, so the ids are the consecutive integers ``0..nnz_c-1`` repeated
    by segment length — every stored C slot has at least one product.
    """
    lens = np.diff(np.append(s.seg_starts, s.n_products))
    return np.repeat(np.arange(s.nnz, dtype=np.int32), lens)


def device_stream(plan) -> Optional[DeviceStream]:
    """The plan's device-resident stream, built lazily and memoized.

    Derived from the (host) :attr:`plan.stream` on first access and cached
    on the plan alongside it — ``plan.device_stream_nbytes`` /
    ``plan_cache_info()['device_stream_bytes']`` report the device half
    separately.  ``None`` when the plan-memory guard tripped (no host
    stream to lift) or the plan's backend carries no stream.
    """
    s = plan.stream
    if s is None:
        return None
    memo = plan._stream_memo
    if "device" not in memo:
        faults.check("device_lift", key=getattr(plan, "backend", None))
        check_int32_stream(plan, s)
        seg_ids = stream_seg_ids(s)
        with jax.ensure_compile_time_eval():
            # the lazy build may run *inside* a caller's jit trace (the
            # first traced execution of a fresh plan); the index arrays
            # must still come out concrete — they are plan state shared by
            # every later trace, not constants of this one
            dev_arrays = (jnp.asarray(s.a_pos, jnp.int32),
                          jnp.asarray(s.b_pos, jnp.int32),
                          jnp.asarray(seg_ids))
        memo["device"] = DeviceStream(
            a_pos=dev_arrays[0],
            b_pos=dev_arrays[1],
            seg_ids=dev_arrays[2],
            c_rows=s.c_rows,
            c_col_ptr=s.c_col_ptr,
            shape=s.shape,
            n_products=s.n_products,
            num_segments=s.nnz,
        )
    return memo["device"]


def _guard_error(plan) -> ValueError:
    if not plan.contract.carries_stream:
        # stream-less backend (pallas): a capability gap, not a guard trip
        return ValueError(
            f"the {plan.backend!r} backend carries no product stream — "
            "plan on backend='jax' (or 'host') for stream execution")
    return ValueError(
        f"plan's product stream exceeds its plan-memory guard "
        f"(stream_limit={plan.stream_limit}), so there is no device-resident "
        "stream to trace: a jitted/differentiated execution cannot fall "
        "back to the host engine.  Raise stream_limit= (or "
        "fast.STREAM_MAX_PRODUCTS) when planning, or execute on the host "
        "backend outside the trace")


# the stream's indices are plan-frozen and in-bounds by construction, so
# every gather/scatter skips XLA's out-of-bounds clamping (the default
# "fill" mode materializes [P]-sized bounds-check compares that dominate
# both compile and run time on large streams)
_IN_BOUNDS = jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS


def _take(values, idx):
    return jnp.asarray(values).at[idx].get(mode=_IN_BOUNDS)


def bilinear_custom_vjp(forward, grad_a, grad_b):
    """``jax.custom_vjp`` wrapper for a bilinear stream contraction.

    ``forward(a_values, b_values)`` is the primal replay; the contraction is
    bilinear, so its VJP is two more replays through the same frozen plan
    indices (module docstring): ``grad_a(g, a_values, b_values)`` and
    ``grad_b(g, a_values, b_values)`` each take the broadcast output
    cotangent plus both residual operands and return the corresponding
    operand cotangent (shaped like the primal operand — oversized raw value
    arrays get oversized cotangents).  Shared by the XLA device stream
    (:func:`_bilinear_contract`) and the fused Pallas stream
    (``core.pallas_stream``), which differ only in how a replay is lowered.
    ``jax.vmap`` composes with the returned function, which is how both
    batched paths ride one trace for a whole ``[B, nnz]`` value stack.
    """

    @jax.custom_vjp
    def contract(a_values, b_values):
        return forward(a_values, b_values)

    def fwd(a_values, b_values):
        return contract(a_values, b_values), (a_values, b_values)

    def bwd(residuals, g):
        a_values, b_values = residuals
        return (grad_a(g, a_values, b_values),
                grad_b(g, a_values, b_values))

    contract.defvjp(fwd, bwd)
    return contract


def _bilinear_contract(dev: DeviceStream):
    """The custom-vjp gather→multiply→segment-sum contraction for ``dev``."""

    def forward(a_values, b_values):
        prod = _take(a_values, dev.a_pos) * _take(b_values, dev.b_pos)
        return jax.ops.segment_sum(prod, dev.seg_ids,
                                   num_segments=dev.num_segments,
                                   indices_are_sorted=True,
                                   mode=_IN_BOUNDS)

    # cotangent per product (a take through seg_ids), then scatter-add
    # through the same frozen indices the forward gathered through; the
    # shared g_prod gather is deduped by XLA CSE across the two replays
    def grad_a(g, a_values, b_values):
        g_prod = _take(g, dev.seg_ids)
        return jax.ops.segment_sum(g_prod * _take(b_values, dev.b_pos),
                                   dev.a_pos,
                                   num_segments=a_values.shape[0],
                                   mode=_IN_BOUNDS)

    def grad_b(g, a_values, b_values):
        g_prod = _take(g, dev.seg_ids)
        return jax.ops.segment_sum(g_prod * _take(a_values, dev.a_pos),
                                   dev.b_pos,
                                   num_segments=b_values.shape[0],
                                   mode=_IN_BOUNDS)

    return bilinear_custom_vjp(forward, grad_a, grad_b)


def stream_fn(plan):
    """The plan's jitted numeric function ``f(a_values, b_values) -> c_values``.

    Pure, jit-compatible, differentiable (custom vjp) — the traced entry
    point of the jax backend.  Memoized on the plan, so repeated calls hit
    one trace cache; guarded plans raise the capability error.
    """
    memo = plan._stream_memo
    if "jax_fn" not in memo:
        dev = device_stream(plan)
        if dev is None:
            raise _guard_error(plan)
        memo["jax_contract"] = _bilinear_contract(dev)
        memo["jax_fn"] = jax.jit(memo["jax_contract"])
    return memo["jax_fn"]


def stream_fn_batched(plan):
    """Vmapped twin of :func:`stream_fn`: ``[B, nnz]`` stacks, one trace.

    ``jit(vmap(contract))`` — the batch axis becomes a leading device axis,
    so the dispatch count is independent of B and a new batch size is a
    shape change (one retrace), never B traces.
    """
    memo = plan._stream_memo
    if "jax_fn_batched" not in memo:
        stream_fn(plan)   # ensures jax_contract (or raises the guard error)
        memo["jax_fn_batched"] = jax.jit(jax.vmap(memo["jax_contract"]))
    return memo["jax_fn_batched"]


def _is_traced(*arrays) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in arrays)


def _operand_values(operand):
    """Raw value array of an execute-time operand, namespace-preserving."""
    return operand.values if isinstance(operand, (CSC, BatchedCSC)) \
        else operand


def execute_jax(plan, a_values, b_values, *, interpret: bool = True,
                stats: dict | None = None,
                validate: str | None = None) -> CSC:
    """Numeric phase of a jax-backend plan (executor dispatch target).

    Returns a CSC whose values are a device array on the plan's canonical
    stream structure.  Guarded plans (``plan.stream is None``) fall back to
    the host stream engine on concrete operands and raise the capability
    error under a trace.  ``interpret`` is accepted for signature
    uniformity and ignored (nothing to interpret — the function is XLA).
    """
    del interpret
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    av = _operand_values(a_values)
    bv = _operand_values(b_values)
    if plan.stream is None:
        if _is_traced(av, bv):
            raise _guard_error(plan)
        out = fast.execute_stream(plan, np.asarray(av), np.asarray(bv),
                                  stats=stats)
        if stats is not None:
            stats["backend"] = "jax"
            stats["fallback"] = "host"
        return out
    vals = stream_fn(plan)(av, bv)
    s = plan.stream
    if stats is not None:
        stats.update(engine="stream", backend="jax", device=True,
                     fallback=None, stream_products=s.n_products,
                     result_shape=s.shape)
    return CSC(vals, s.c_rows, s.c_col_ptr, s.shape)


def _batched_operand(pattern, operand, validate):
    """[B, nnz] value stack of a batched operand, tracer- and device-safe
    (validation shared with the host paths via the Pattern contract; the
    values keep their namespace — no ``np.asarray`` materialization)."""
    pattern.check_batched_compatible(operand, validate)
    return operand.values if isinstance(operand, BatchedCSC) else operand


def execute_jax_batched(plan, a_values, b_values, *, interpret: bool = True,
                        stats: dict | None = None,
                        validate: str | None = None) -> list:
    """Batched numeric phase: B value sets through one vmapped dispatch."""
    del interpret
    from repro.core.executor import _check_batch   # lazy: executor imports us

    av = _batched_operand(plan.a, a_values, validate)
    bv = _batched_operand(plan.b, b_values, validate)
    batch = _check_batch(av, bv)
    if plan.stream is None:
        if _is_traced(av, bv):
            raise _guard_error(plan)
        out = fast.execute_stream_batched(
            plan, np.asarray(av)[:, : int(plan.a.col_ptr[-1])],
            np.asarray(bv)[:, : int(plan.b.col_ptr[-1])], stats=stats)
        if stats is not None:
            stats["backend"] = "jax"
            stats["fallback"] = "host"
            stats["batch"] = batch
        return out
    vals = stream_fn_batched(plan)(av, bv)
    s = plan.stream
    if stats is not None:
        stats.update(engine="stream", backend="jax", device=True,
                     fallback=None, path="vmap", batch=batch,
                     stream_products=s.n_products, result_shape=s.shape)
    return [CSC(vals[b], s.c_rows, s.c_col_ptr, s.shape)
            for b in range(batch)]

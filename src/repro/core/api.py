"""Public SpGEMM API: ``spgemm(A, B, method=...)`` over cached plans.

Methods mirror the paper's evaluated algorithms. ``backend="host"`` runs the
faithful numpy executors; ``backend="pallas"`` runs the TPU kernels (interpret
mode on CPU). Default parameters are the paper's best settings.

``spgemm`` is a thin wrapper over the plan/execute split (DESIGN.md §6): it
builds — or fetches from a bounded LRU keyed on pattern fingerprints — a
:class:`~repro.core.planner.SpgemmPlan` and executes it against the operand
values.  Repeated-pattern workloads can also hold a plan explicitly::

    plan = plan_spgemm(a, b, "h-hash-256/256")
    c1 = plan.execute(a_vals_1, b_vals_1)   # numeric phase only
    c2 = spgemm(a2, b2, plan=plan)          # equivalent spelling
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.planner import (
    ALGORITHMS,
    SpgemmPlan,
    pattern_fingerprint,
    plan_spgemm,
    resolve_params,
)
from repro.sparse.format import BatchedCSC, CSC

# bounded LRU of SpgemmPlan keyed by (a_fp, b_fp, method, backend, params)
PLAN_CACHE_SIZE = 64
_PLAN_CACHE: "OrderedDict[tuple, SpgemmPlan]" = OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0}


def plan_cache_clear() -> None:
    """Drop all cached plans and reset hit/miss counters."""
    _PLAN_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


def plan_cache_info() -> dict:
    """Current cache occupancy and hit/miss counters."""
    return dict(_CACHE_STATS, size=len(_PLAN_CACHE),
                max_size=PLAN_CACHE_SIZE)


def _cached_plan(a: CSC, b: CSC, method: str, backend: str,
                 params: dict) -> SpgemmPlan:
    key = (pattern_fingerprint(a), pattern_fingerprint(b), method, backend,
           tuple(sorted(params.items())))
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        _CACHE_STATS["hits"] += 1
        return plan
    _CACHE_STATS["misses"] += 1
    plan = plan_spgemm(a, b, method, backend=backend,
                       t=params.get("t"), b_min=params.get("b_min"),
                       b_max=params.get("b_max"))
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > PLAN_CACHE_SIZE:
        _PLAN_CACHE.popitem(last=False)
    return plan


def spgemm(
    a: CSC,
    b: CSC,
    method: str = "h-hash-256/256",
    *,
    backend: str = "host",
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
    plan: SpgemmPlan | None = None,
    cache: bool = True,
    validate: str | None = None,
) -> CSC:
    """Compute C = A @ B with one of the paper's algorithms.

    Overriding t/b_min/b_max customizes the named method's defaults.  With
    ``plan`` the symbolic phase is skipped outright (method/backend arguments
    are ignored — the plan carries its own); with ``cache=False`` the plan is
    rebuilt from scratch, bypassing the LRU.  ``validate="fingerprint"``
    re-hashes the operand structure against the plan (O(nnz)) instead of the
    default O(1) shape/nnz check — useful when reusing a held plan against
    operands of uncertain provenance.
    """
    if plan is not None:
        return plan.execute(a, b, validate=validate)
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; one of {list(ALGORITHMS)}")
    if backend not in ("host", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    params = resolve_params(method, t=t, b_min=b_min, b_max=b_max)
    if cache:
        p = _cached_plan(a, b, method, backend, params)
    else:
        p = plan_spgemm(a, b, method, backend=backend, t=params.get("t"),
                        b_min=params.get("b_min"), b_max=params.get("b_max"))
    return p.execute(a, b)


def spgemm_batched(
    a: BatchedCSC,
    b: BatchedCSC,
    method: str = "h-hash-256/256",
    *,
    backend: str = "host",
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
    plan: SpgemmPlan | None = None,
    cache: bool = True,
    validate: str | None = None,
) -> list:
    """B same-pattern multiplies C_b = A_b @ B_b through one plan execution.

    ``a``/``b`` are :class:`~repro.sparse.format.BatchedCSC` stacks (shared
    sparsity pattern, values ``[B, nnz]``).  The symbolic plan is built — or
    fetched from the same LRU as ``spgemm`` — once for the shared pattern,
    then all B value sets run through one set of kernel launches
    (``plan.execute_batched``, DESIGN.md §7).  Returns a list of B CSC
    results, bit-identical to calling ``spgemm`` per element.

    With ``plan`` the symbolic phase is skipped and ``a``/``b`` may also be
    raw ``[B, nnz]`` value stacks aligned with the planned patterns.
    """
    if plan is not None:
        return plan.execute_batched(a, b, validate=validate)
    if not isinstance(a, BatchedCSC) or not isinstance(b, BatchedCSC):
        raise TypeError(
            "spgemm_batched operands must be BatchedCSC (use BatchedCSC"
            ".stack / .from_values, or pass plan= with raw value stacks)")
    if a.batch != b.batch:
        raise ValueError(f"batch mismatch: {a.batch} vs {b.batch}")
    if a.batch < 1:
        raise ValueError("empty batch")
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; one of {list(ALGORITHMS)}")
    if backend not in ("host", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    params = resolve_params(method, t=t, b_min=b_min, b_max=b_max)
    a0, b0 = a.element(0), b.element(0)
    if cache:
        p = _cached_plan(a0, b0, method, backend, params)
    else:
        p = plan_spgemm(a0, b0, method, backend=backend, t=params.get("t"),
                        b_min=params.get("b_min"), b_max=params.get("b_max"))
    return p.execute_batched(a, b, validate=validate)

"""Public SpGEMM API: ``spgemm(A, B, method=...)``.

Methods mirror the paper's evaluated algorithms. ``backend="host"`` runs the
faithful numpy executors; ``backend="pallas"`` runs the TPU kernels (interpret
mode on CPU). Default parameters are the paper's best settings.
"""

from __future__ import annotations

import numpy as np

from repro.core import naive
from repro.core.analysis import preprocess
from repro.core.expand import spgemm_expand
from repro.sparse.format import CSC

# method -> (callable kwargs); paper's Section 5.3 configurations
ALGORITHMS = {
    "spa": {},
    "spars-16/64": dict(b_min=16, b_max=64),
    "spars-40/40": dict(b_min=40, b_max=40),
    "h-spa-16/64": dict(t=40, b_min=16, b_max=64, accumulator="spa"),
    "h-spa-40/40": dict(t=40, b_min=40, b_max=40, accumulator="spa"),
    "hash-32/256": dict(b_min=32, b_max=256),
    "hash-256/256": dict(b_min=256, b_max=256),
    "h-hash-32/256": dict(t=40, b_min=32, b_max=256, accumulator="hash"),
    "h-hash-256/256": dict(t=40, b_min=256, b_max=256, accumulator="hash"),
    "esc": {},
    "expand": {},  # fast vectorized host executor (not a paper algorithm)
}


def spgemm(
    a: CSC,
    b: CSC,
    method: str = "h-hash-256/256",
    *,
    backend: str = "host",
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
) -> CSC:
    """Compute C = A @ B with one of the paper's algorithms.

    Overriding t/b_min/b_max customizes the named method's defaults.
    """
    if method not in ALGORITHMS:
        raise ValueError(f"unknown method {method!r}; one of {list(ALGORITHMS)}")
    params = dict(ALGORITHMS[method])
    if t is not None:
        params["t"] = t
    if b_min is not None:
        params["b_min"] = b_min
    if b_max is not None:
        params["b_max"] = b_max

    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.spgemm_pallas(a, b, method=method, **params)
    if backend != "host":
        raise ValueError(f"unknown backend {backend!r}")

    if method == "spa":
        return naive.spa_numpy(a, b)
    if method == "expand":
        return spgemm_expand(a, b)
    if method == "esc":
        return naive.esc_numpy(a, b)
    if method.startswith("spars"):
        pre = preprocess(a, b, t=np.inf, b_min=params["b_min"],
                         b_max=params["b_max"])
        return naive.spars_numpy(a, b, pre)
    if method.startswith("hash"):
        pre = preprocess(a, b, t=np.inf, b_min=params["b_min"],
                         b_max=params["b_max"])
        return naive.hash_numpy(a, b, pre)
    if method.startswith("h-"):
        return naive.hybrid_numpy(
            a, b, t=params["t"], b_min=params["b_min"], b_max=params["b_max"],
            accumulator=params["accumulator"],
        )
    raise AssertionError(method)

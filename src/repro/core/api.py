"""Public SpGEMM API: ``spgemm(A, B, method=...)`` over cached plans.

Methods mirror the paper's evaluated algorithms, plus ``method="auto"`` —
the self-tuning entry point (DESIGN.md §8): the operands are sliced into a
2D tile grid and every tile runs the method an analytical cost model picks
for that tile's work profile.  ``backend="host"`` runs the faithful numpy
executors; ``backend="pallas"`` runs the TPU kernels (interpret mode on
CPU).  Default parameters are the paper's best settings.

``spgemm`` is a thin wrapper over the plan/execute split (DESIGN.md §6): it
builds — or fetches from a bounded LRU keyed on pattern fingerprints — a
:class:`~repro.core.planner.SpgemmPlan` (or
:class:`~repro.core.planner.TiledSpgemmPlan` for ``"auto"``) and executes
it against the operand values.  Repeated-pattern workloads can also hold a
plan explicitly::

    plan = plan_spgemm(a, b, "h-hash-256/256")
    c1 = plan.execute(a_vals_1, b_vals_1)   # numeric phase only
    c2 = spgemm(a2, b2, plan=plan)          # equivalent spelling

A held plan carries its own method/backend/parameters; passing conflicting
``method=``/``backend=``/``t=``/``b_min=``/``b_max=`` alongside ``plan=``
raises instead of being silently ignored.
"""

from __future__ import annotations

import threading
import time
import weakref
from collections import OrderedDict

from repro.core import backends
import repro.core.fast as _fast
from repro.core.cost import AUTO_CANDIDATES
from repro.core.planner import (
    ALGORITHMS,
    SpgemmPlan,
    TiledSpgemmPlan,
    normalize_tile_spec,
    pattern_fingerprint,
    plan_spgemm,
    plan_spgemm_tiled,
    resolve_params,
)
from repro.sparse.format import BatchedCSC, CSC

DEFAULT_METHOD = "h-hash-256/256"

# bounded LRU of plans keyed by (a_fp, b_fp, method, backend, params);
# resize at runtime with plan_cache_resize()
PLAN_CACHE_SIZE = 64
_PLAN_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0, "wasted_builds": 0,
                "listener_errors": 0, "wait_timeouts": 0}

# Default bound (seconds) on how long a synchronous caller may wait on
# ANOTHER thread's in-flight build of the same key before _build_once
# raises PlanBuildTimeout (DESIGN.md §14).  None = wait forever (the
# pre-resilience behavior); cached_plan(build_timeout=...) overrides
# per call.  Owners are never interrupted — only waiters time out.
DEFAULT_BUILD_TIMEOUT: float | None = None


class PlanBuildTimeout(TimeoutError):
    """A single-flight waiter outlived its deadline on another thread's
    in-flight build (the build itself may still complete later)."""

# keys inserted but never since hit: evicting one of these means the build
# was pure waste (typically plan_cache_resize() shrinking below the number
# of in-flight PlanBuilder builds — the build completed into a cache too
# small to hold it).  Surfaced as the "wasted_builds" counter.
_NEVER_HIT: set = set()

# callables fn(keys, reason) notified after evictions caused by an explicit
# plan_cache_resize() shrink (reason="resize"), *outside* the cache lock.
# Capacity-pressure evictions do not notify — re-warming those would fight
# the LRU.  Registered by PlanBuilder.enable_rewarm().
_EVICTION_LISTENERS: list = []

# Weak references to live PlanBuilders: plan_cache_info() surfaces their
# queue-depth / retry / recycle counters next to the cache telemetry, so
# one probe reads the whole pipeline's health (DESIGN.md §14).
_BUILDERS: "list[weakref.ref]" = []


def _register_builder(builder) -> None:
    with _CACHE_LOCK:
        _BUILDERS[:] = [r for r in _BUILDERS if r() is not None]
        _BUILDERS.append(weakref.ref(builder))


def _unregister_builder(builder) -> None:
    with _CACHE_LOCK:
        _BUILDERS[:] = [r for r in _BUILDERS
                        if r() is not None and r() is not builder]

# The LRU locking contract (DESIGN.md §12): every read or write of
# _PLAN_CACHE/_CACHE_STATS holds _CACHE_LOCK — required since the
# background plan builder (core/plan_builder.py) shares the LRU with
# latency-critical serving threads.  Symbolic builds themselves run
# *outside* the lock (they are the expensive part); _BUILDING holds one
# Event per key with a build in flight so concurrent requests for the
# same pattern wait for that build instead of duplicating it
# (single-flight — the "no double-builds" guarantee the hammer test
# asserts).
_CACHE_LOCK = threading.RLock()
_BUILDING: "dict[tuple, threading.Event]" = {}


def plan_cache_clear() -> None:
    """Drop all cached plans and reset hit/miss counters."""
    with _CACHE_LOCK:
        _PLAN_CACHE.clear()
        _NEVER_HIT.clear()
        for k in _CACHE_STATS:
            _CACHE_STATS[k] = 0


def register_eviction_listener(fn) -> None:
    """Register ``fn(keys, reason)`` for post-shrink eviction batches.

    Called *outside* the cache lock after :func:`plan_cache_resize` evicts
    entries (``reason="resize"``); capacity-pressure evictions from normal
    inserts never notify.  Listener exceptions are swallowed — eviction is
    a memory-pressure path and must not fail the resizer.  The standard
    listener is ``PlanBuilder.enable_rewarm()``, which re-queues the
    evicted keys' builds (DESIGN.md §12).
    """
    if fn not in _EVICTION_LISTENERS:
        _EVICTION_LISTENERS.append(fn)


def unregister_eviction_listener(fn) -> None:
    """Remove a listener registered by :func:`register_eviction_listener`."""
    if fn in _EVICTION_LISTENERS:
        _EVICTION_LISTENERS.remove(fn)


def plan_cache_info() -> dict:
    """Current cache occupancy, hit/miss counters, and hit rate.

    ``stream_bytes`` totals the *host* product-stream index data
    materialized by cached plans, including streams held through tiled
    plans' child tile plans (each counted once even when shared) — see
    DESIGN.md §9.  ``device_stream_bytes`` separately totals the
    device-resident index arrays jax-backend plans cache alongside the host
    ones (DESIGN.md §10), and ``fused_stream_bytes`` the fused-kernel
    replay views (padded gather indices + segment metadata,
    ``core.pallas_stream``, DESIGN.md §11) — all three can be resident on
    one plan at once.  The guard bounds each *plan's* stream; the LRU
    bounds entries, but a tiled plan holds one guard-sized stream per
    distinct tile pattern, so watch these numbers (and shrink via
    ``plan_cache_resize`` or a lower guard) when caching large tiled
    workloads.

    ``mesh_stream_bytes`` totals the device-stacked shard-stream index
    arrays held by mesh-backend plans (DESIGN.md §13) on top of their
    children's host/device streams (the children are ordinary jax tile
    plans, counted by the other totals).  ``wasted_builds`` counts evicted
    entries that were never hit after insertion — a build whose result the
    cache could not keep, the signature of :func:`plan_cache_resize`
    shrinking below the number of in-flight ``PlanBuilder`` builds.

    Resilience telemetry (DESIGN.md §14): ``wait_timeouts`` counts
    single-flight waiters that hit their ``build_timeout`` deadline,
    ``listener_errors`` counts eviction-listener exceptions swallowed by
    :func:`plan_cache_resize`, and ``builders`` lists each live
    ``PlanBuilder``'s :meth:`~repro.core.plan_builder.PlanBuilder.info`
    (queue depth, retries, timeouts, recycled workers, backpressure
    policy).
    """
    with _CACHE_LOCK:
        lookups = _CACHE_STATS["hits"] + _CACHE_STATS["misses"]
        host_seen: dict = {}
        dev_seen: dict = {}
        fused_seen: dict = {}
        mesh_seen: dict = {}
        for p in _PLAN_CACHE.values():
            mesh_seen[id(p)] = getattr(p, "mesh_stream_nbytes", 0)
            for sp in [t.plan for t in getattr(p, "tiles", ())] or [p]:
                host_seen[id(sp)] = getattr(sp, "stream_nbytes", 0)
                dev_seen[id(sp)] = getattr(sp, "device_stream_nbytes", 0)
                fused_seen[id(sp)] = getattr(sp, "fused_stream_nbytes", 0)
        out = dict(_CACHE_STATS, size=len(_PLAN_CACHE),
                   max_size=PLAN_CACHE_SIZE,
                   hit_rate=(_CACHE_STATS["hits"] / lookups
                             if lookups else 0.0),
                   in_flight=len(_BUILDING),
                   stream_bytes=sum(host_seen.values()),
                   device_stream_bytes=sum(dev_seen.values()),
                   fused_stream_bytes=sum(fused_seen.values()),
                   mesh_stream_bytes=sum(mesh_seen.values()))
        refs = list(_BUILDERS)
    # builder.info() takes the builder's own lock — collect outside ours
    builders = []
    for r in refs:
        b = r()
        if b is not None:
            builders.append(b.info())
    out["builders"] = builders
    # cost-profile provenance + telemetry (DESIGN.md §15): which constants
    # ("measured" fit vs "default") the auto plans in this cache were
    # ranked under, how stale the calibration is, and how often auto ran
    # on uncalibrated defaults for device-resident work
    from repro.core import profile

    out["profile"] = profile.profile_info()
    return out


def plan_cache_resize(n: int) -> dict:
    """Set the plan LRU capacity (evicting least-recently-used overflow).

    The supported way to bound plan memory — callers no longer need to
    mutate the ``PLAN_CACHE_SIZE`` module constant.  ``n == 0`` disables
    caching (every insert is immediately evicted).  Returns
    :func:`plan_cache_info` after the resize.
    """
    global PLAN_CACHE_SIZE
    n = int(n)
    if n < 0:
        raise ValueError(f"cache size must be >= 0, got {n}")
    evicted: list = []
    with _CACHE_LOCK:
        PLAN_CACHE_SIZE = n
        while len(_PLAN_CACHE) > PLAN_CACHE_SIZE:
            evicted.append(_evict_locked())
    if evicted:
        # outside the lock: listeners may re-enter the cache (re-warm).
        # One raising listener must not starve the rest or propagate into
        # the resizing caller — count it and continue.
        for fn in list(_EVICTION_LISTENERS):
            try:
                fn(tuple(evicted), "resize")
            except Exception:
                with _CACHE_LOCK:
                    _CACHE_STATS["listener_errors"] += 1
    return plan_cache_info()


def _evict_locked():
    """Pop the LRU head (lock held); accounts eviction + waste, returns key."""
    key, _ = _PLAN_CACHE.popitem(last=False)
    _CACHE_STATS["evictions"] += 1
    if key in _NEVER_HIT:
        _NEVER_HIT.discard(key)
        _CACHE_STATS["wasted_builds"] += 1
    return key


def _cache_get(key):
    with _CACHE_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _PLAN_CACHE.move_to_end(key)
            _CACHE_STATS["hits"] += 1
            _NEVER_HIT.discard(key)
            return plan
        _CACHE_STATS["misses"] += 1
        return None


def _cache_put(key, plan):
    with _CACHE_LOCK:
        _PLAN_CACHE[key] = plan
        _NEVER_HIT.add(key)
        while len(_PLAN_CACHE) > PLAN_CACHE_SIZE:
            _evict_locked()


def plan_cache_peek(key):
    """Non-mutating cache lookup: no LRU promotion, no counter updates.

    The latency-critical probe (DESIGN.md §12): a serving tick asks "is the
    device plan for this pattern already built?" without perturbing the
    eviction order or the hit/miss telemetry.  ``key`` comes from
    :func:`plan_cache_key`.  Returns the plan or ``None``.
    """
    with _CACHE_LOCK:
        return _PLAN_CACHE.get(key)


def _build_once(key, build, timeout: float | None = None):
    """Fetch ``key`` from the LRU, or run ``build()`` exactly once.

    Single-flight across threads: the first requester of a missing key
    becomes the owner and runs the (expensive, unlocked) symbolic build;
    concurrent requesters for the same key wait on the owner's completion
    event and then take the cache hit, instead of duplicating the build.
    A failed build wakes the waiters, one of which becomes the new owner
    and retries.  With ``PLAN_CACHE_SIZE == 0`` the published entry is
    evicted immediately, so every caller builds — the documented
    cache-disabled semantics.

    ``timeout`` (default :data:`DEFAULT_BUILD_TIMEOUT`) bounds the total
    time a *waiter* blocks on another thread's in-flight build: past it,
    :class:`PlanBuildTimeout` is raised (counted as ``wait_timeouts`` in
    :func:`plan_cache_info`) instead of blocking unboundedly on a doomed
    or wedged owner.  The owner itself runs its build to completion —
    hung *background* builds are the PlanBuilder watchdog's job.
    """
    if timeout is None:
        timeout = DEFAULT_BUILD_TIMEOUT
    deadline = None if timeout is None else time.monotonic() + timeout
    while True:
        with _CACHE_LOCK:
            plan = _PLAN_CACHE.get(key)
            if plan is not None:
                _PLAN_CACHE.move_to_end(key)
                _CACHE_STATS["hits"] += 1
                _NEVER_HIT.discard(key)
                return plan
            done = _BUILDING.get(key)
            owner = done is None
            if owner:
                done = _BUILDING[key] = threading.Event()
                _CACHE_STATS["misses"] += 1
        if owner:
            try:
                plan = build()
                _cache_put(key, plan)
            finally:
                with _CACHE_LOCK:
                    _BUILDING.pop(key, None)
                done.set()
            return plan
        remaining = None if deadline is None else deadline - time.monotonic()
        if (remaining is not None and remaining <= 0) \
                or not done.wait(remaining):
            with _CACHE_LOCK:
                _CACHE_STATS["wait_timeouts"] += 1
            raise PlanBuildTimeout(
                f"waited {timeout:.3f}s on another thread's in-flight "
                f"build of plan key {key[2:4]}; the build may still land "
                "later — retry, or serve a fallback plan")


def _single_plan_key(a: CSC, b: CSC, method: str, backend: str,
                     params: dict,
                     stream_limit: int | None = None) -> tuple:
    # for stream-capable plans (host, jax) the stream guard is part of the
    # key: plans resolve it at build time, so changing
    # fast.STREAM_MAX_PRODUCTS must not hand back plans built under the old
    # budget (an explicit per-plan stream_limit= keys on its own value).
    # Pallas plans carry no stream (stream_limit=None), so the knob must
    # not invalidate them.
    contract = backends.get_backend(backend)
    if contract.canonical_method:
        # method spellings collapse on such backends (jax: one stream
        # contraction) — key on the canonical form so they share one entry
        method = contract.canonical_method
        params = resolve_params(method)
    if not contract.carries_stream:
        limit = None
    elif stream_limit is not None:
        limit = int(stream_limit)
    else:
        limit = _fast.STREAM_MAX_PRODUCTS
    return (pattern_fingerprint(a), pattern_fingerprint(b), method, backend,
            tuple(sorted(params.items())), limit)


def plan_cache_key(a: CSC, b: CSC, method: str | None = None, *,
                   backend: str | None = None, t: float | None = None,
                   b_min: int | None = None, b_max: int | None = None,
                   stream_limit: int | None = None,
                   shards: int | None = None) -> tuple:
    """The LRU key :func:`cached_plan` would use for these arguments.

    For non-blocking probes (DESIGN.md §12): compute the key once, then
    :func:`plan_cache_peek` it on the latency path while a background
    :class:`~repro.core.plan_builder.PlanBuilder` owns the build.  Costs
    two pattern fingerprints (O(nnz)), no plan construction.  On
    ``backend="mesh"`` the key carries the mesh shape (``shards``,
    defaulting to the visible device count) and the per-shard guard.
    """
    method, backend = _resolve_method_backend(method, backend)
    _check_shards(backend, shards)
    if method == "auto":
        raise ValueError(
            "plan_cache_key addresses single-method plans; method='auto' "
            "uses the tiled entry points")
    _check_canonical_only(backend, t, b_min, b_max)
    if backend == "mesh":
        return _mesh_plan_key(a, b, shards, None, stream_limit)
    return _single_plan_key(a, b, method, backend,
                            resolve_params(method, t=t, b_min=b_min,
                                           b_max=b_max),
                            stream_limit=stream_limit)


def _cached_plan(a: CSC, b: CSC, method: str, backend: str,
                 params: dict,
                 stream_limit: int | None = None,
                 build_timeout: float | None = None) -> SpgemmPlan:
    key = _single_plan_key(a, b, method, backend, params, stream_limit)
    return _build_once(
        key,
        lambda: plan_spgemm(a, b, method, backend=backend,
                            t=params.get("t"), b_min=params.get("b_min"),
                            b_max=params.get("b_max"),
                            stream_limit=stream_limit),
        timeout=build_timeout)


def cached_plan(a: CSC, b: CSC, method: str | None = None, *,
                backend: str | None = None, t: float | None = None,
                b_min: int | None = None, b_max: int | None = None,
                stream_limit: int | None = None,
                shards: int | None = None,
                build_timeout: float | None = None) -> SpgemmPlan:
    """Fetch-or-build a plan through the shared LRU (public accessor).

    The plan-holding companion of :func:`spgemm`: out-of-package callers
    (model layers, serving) that want to hold a plan *and* share it with
    the api's cache use this instead of reaching for the private LRU
    internals.  Arguments and defaults mirror :func:`spgemm`
    (``method="auto"`` has its own tiled entry point,
    :func:`~repro.core.planner.plan_spgemm_tiled`); ``stream_limit``
    overrides the plan-memory guard for this plan only (part of the cache
    key), without mutating the global ``fast.STREAM_MAX_PRODUCTS`` knob.
    ``build_timeout`` bounds how long this call may wait on *another*
    thread's in-flight build of the same key (:class:`PlanBuildTimeout`
    past it; default :data:`DEFAULT_BUILD_TIMEOUT`).
    """
    method, backend = _resolve_method_backend(method, backend)
    _check_shards(backend, shards)
    if method == "auto":
        raise ValueError(
            "cached_plan builds single-method plans; use plan_spgemm_tiled "
            "for method='auto'")
    _check_canonical_only(backend, t, b_min, b_max)
    if backend == "mesh":
        return _cached_mesh_plan(a, b, shards, None, stream_limit)
    return _cached_plan(a, b, method, backend,
                        resolve_params(method, t=t, b_min=b_min,
                                       b_max=b_max),
                        stream_limit=stream_limit,
                        build_timeout=build_timeout)


def _cached_tiled_plan(a: CSC, b: CSC, backend: str, tile,
                       candidates) -> TiledSpgemmPlan:
    spec = normalize_tile_spec(tile)
    # resolve the default candidate set before keying, so an explicit
    # candidates= equal to the backend default hits the same entry
    cands = AUTO_CANDIDATES[backend] if candidates is None \
        else tuple(candidates)
    # the cost-profile tag keys the entry too (mirrors
    # TiledSpgemmPlan.cache_key): per-tile picks ranked under a measured
    # calibration must not alias picks ranked under defaults
    from repro.core import profile

    key = (pattern_fingerprint(a), pattern_fingerprint(b), "auto", backend,
           spec, cands,
           _fast.STREAM_MAX_PRODUCTS
           if backends.get_backend(backend).carries_stream else None,
           profile.current_profile().tag)
    return _build_once(
        key,
        lambda: plan_spgemm_tiled(a, b, backend=backend, tile=tile,
                                  candidates=cands))


def _mesh_plan_key(a: CSC, b: CSC, shards, tile,
                   stream_limit: int | None = None) -> tuple:
    # the mesh key mirrors _single_plan_key but carries the mesh shape and
    # grid spec in the params slot: plans for different shard counts (or
    # per-shard guards) are different placements and must not alias
    import jax

    from repro.core import profile

    n_shards = len(jax.devices()) if shards is None else int(shards)
    limit = (_fast.STREAM_MAX_PRODUCTS if stream_limit is None
             else int(stream_limit))
    # the profile tag rides along for the same reason as in the tiled key:
    # the LPT shard placement is ranked on the profile's constants
    params = (("profile", profile.current_profile().tag),
              ("shard_limit", limit), ("shards", n_shards),
              ("tile", normalize_tile_spec(tile)))
    return (pattern_fingerprint(a), pattern_fingerprint(b), "expand",
            "mesh", params, limit)


def _cached_mesh_plan(a: CSC, b: CSC, shards=None, tile=None,
                      stream_limit: int | None = None):
    key = _mesh_plan_key(a, b, shards, tile, stream_limit)
    n_shards = dict(key[4])["shards"]

    def build():
        from repro.distributed.spgemm_mesh import plan_spgemm_mesh

        return plan_spgemm_mesh(a, b, shards=n_shards, tile=tile,
                                shard_limit=stream_limit)

    return _build_once(key, build)


def _auto_mesh_plan(a: CSC, b: CSC, shards, tile, candidates, cache):
    """``method="auto"`` on the mesh backend: distribute or stay local.

    The communication-aware cost model (``core.cost.should_distribute``)
    decides: shard when the whole product stream is above the single-device
    guard (a mesh plan lifts it per shard) or when the mesh estimate beats
    the single-device stream outright; otherwise fall back to the ordinary
    single-device jax tile grid, where the per-tile method race still
    applies.
    """
    import jax

    from repro.core.cost import should_distribute
    from repro.sparse.stats import tile_stats

    n_shards = len(jax.devices()) if shards is None else int(shards)
    if should_distribute(tile_stats(a, b), n_shards):
        if cache:
            return _cached_mesh_plan(a, b, n_shards, tile)
        from repro.distributed.spgemm_mesh import plan_spgemm_mesh

        return plan_spgemm_mesh(a, b, shards=n_shards, tile=tile,
                                cache=False)
    if cache:
        return _cached_tiled_plan(a, b, "jax", tile, candidates)
    return plan_spgemm_tiled(a, b, backend="jax", tile=tile,
                             candidates=candidates, cache=False)


def _check_shards(backend, shards) -> None:
    if shards is not None and backend != "mesh":
        raise ValueError(
            f"shards= applies only to backend='mesh', not {backend!r}")


def _check_plan_overrides(plan, method, backend, t, b_min, b_max,
                          tile=None, candidates=None) -> None:
    """Reject ``spgemm(plan=...)`` calls whose explicit arguments conflict
    with what the held plan was built with (held-plan misuse is loud)."""
    own = dict(plan.params)
    conflicts = []
    if method is not None and method != plan.method:
        conflicts.append(f"method={method!r} (plan has {plan.method!r})")
    if backend is not None and backend != plan.backend:
        conflicts.append(f"backend={backend!r} (plan has {plan.backend!r})")
    for name, given in (("t", t), ("b_min", b_min), ("b_max", b_max)):
        if given is None:
            continue
        if name not in own or own[name] != given:
            have = own.get(name, "<unset>")
            conflicts.append(f"{name}={given!r} (plan has {have})")
    if tile is not None:
        spec = normalize_tile_spec(tile)
        if own.get("tile") != spec:
            conflicts.append(
                f"tile={tile!r} (plan has {own.get('tile', '<unset>')})")
    if candidates is not None and own.get("candidates") != tuple(candidates):
        conflicts.append(
            f"candidates={tuple(candidates)!r} "
            f"(plan has {own.get('candidates', '<unset>')})")
    if conflicts:
        raise ValueError(
            "arguments conflict with the held plan (a plan carries its own "
            "method/backend/parameters): " + "; ".join(conflicts))


def _resolve_method_backend(method, backend):
    method = DEFAULT_METHOD if method is None else method
    backend = "host" if backend is None else backend
    if method != "auto" and method not in ALGORITHMS:
        raise ValueError(
            f"unknown method {method!r}; one of {list(ALGORITHMS)} or 'auto'")
    backends.get_backend(backend)   # canonical unknown-backend error
    return method, backend


def _check_auto_only(method, t, b_min, b_max, tile, candidates):
    """Arguments specific to one mode must not be passed with the other."""
    if method != "auto" and (tile is not None or candidates is not None):
        raise ValueError(
            "tile=/candidates= only apply to method='auto' "
            f"(got method={method!r})")
    if method == "auto" and (t is not None or b_min is not None
                             or b_max is not None):
        raise ValueError(
            "t/b_min/b_max do not apply to method='auto' (per-tile methods "
            "use their own defaults; restrict candidates= instead)")


def _check_canonical_only(backend, t, b_min, b_max):
    backends.check_method_knobs(backends.get_backend(backend),
                                t, b_min, b_max)


def spgemm(
    a: CSC,
    b: CSC,
    method: str | None = None,
    *,
    backend: str | None = None,
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
    tile=None,
    candidates: tuple | None = None,
    plan=None,
    cache: bool = True,
    validate: str | None = None,
    engine: str | None = None,
    shards: int | None = None,
) -> CSC:
    """Compute C = A @ B with one of the paper's algorithms, or ``"auto"``.

    The default method is ``"h-hash-256/256"`` (the paper's best overall).
    Overriding t/b_min/b_max customizes the named method's defaults.
    ``method="auto"`` builds a :class:`~repro.core.planner.TiledSpgemmPlan`:
    the operands are tiled (grid auto-sized from nnz, or set with ``tile=``)
    and each tile runs the candidate method the cost model predicts cheapest
    (DESIGN.md §8).  With ``plan`` the symbolic phase is skipped outright —
    the plan carries its own method/backend/parameters, and explicitly
    passing any that conflict raises.  With ``cache=False`` the plan is
    rebuilt from scratch, bypassing the LRU.  ``validate="fingerprint"``
    re-hashes the operand structure against the plan (O(nnz)) instead of
    the default O(1) shape/nnz check.

    ``engine`` selects the host numeric engine (DESIGN.md §9):
    ``"stream"`` replays the plan's vectorized product stream (canonical
    output order, fp re-association vs the oracles), ``"naive"`` forces the
    faithful per-method executor, ``None`` uses the method's default
    (``"stream"`` for ``expand``, ``"naive"`` otherwise).  Engine choice is
    per *execution*, not baked into the plan, so it never conflicts with
    ``plan=``.

    ``backend="mesh"`` distributes across devices (DESIGN.md §13):
    ``shards`` sets the mesh size (default: all visible devices) and the
    plan-memory guard applies per shard.  With ``method="auto"`` the
    communication-aware cost model decides whether to distribute at all,
    falling back to the single-device jax tile grid when sharding is
    predicted to lose.
    """
    if plan is not None:
        _check_plan_overrides(plan, method, backend, t, b_min, b_max,
                              tile, candidates)
        return plan.execute(a, b, validate=validate, engine=engine)
    method, backend = _resolve_method_backend(method, backend)
    _check_shards(backend, shards)
    _check_auto_only(method, t, b_min, b_max, tile, candidates)
    _check_canonical_only(backend, t, b_min, b_max)
    if backend == "mesh":
        if method == "auto":
            p = _auto_mesh_plan(a, b, shards, tile, candidates, cache)
        elif cache:
            p = _cached_mesh_plan(a, b, shards)
        else:
            p = plan_spgemm(a, b, method, backend="mesh", shards=shards)
        return p.execute(a, b, validate=validate, engine=engine)
    if method == "auto":
        if cache:
            p = _cached_tiled_plan(a, b, backend, tile, candidates)
        else:
            p = plan_spgemm_tiled(a, b, backend=backend, tile=tile,
                                  candidates=candidates, cache=False)
        return p.execute(a, b, validate=validate, engine=engine)
    params = resolve_params(method, t=t, b_min=b_min, b_max=b_max)
    if cache:
        p = _cached_plan(a, b, method, backend, params)
    else:
        p = plan_spgemm(a, b, method, backend=backend, t=t,
                        b_min=b_min, b_max=b_max)
    return p.execute(a, b, validate=validate, engine=engine)


def spgemm_batched(
    a: BatchedCSC,
    b: BatchedCSC,
    method: str | None = None,
    *,
    backend: str | None = None,
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
    tile=None,
    candidates: tuple | None = None,
    plan=None,
    cache: bool = True,
    validate: str | None = None,
    engine: str | None = None,
    shards: int | None = None,
) -> list:
    """B same-pattern multiplies C_b = A_b @ B_b through one plan execution.

    ``a``/``b`` are :class:`~repro.sparse.format.BatchedCSC` stacks (shared
    sparsity pattern, values ``[B, nnz]``).  The symbolic plan is built — or
    fetched from the same LRU as ``spgemm`` — once for the shared pattern,
    then all B value sets run through one set of kernel launches
    (``plan.execute_batched``, DESIGN.md §7).  ``method="auto"`` rides the
    tiled plan's batched path (§8).  Returns a list of B CSC results,
    bit-identical to calling ``spgemm`` per element.  ``engine`` — as in
    :func:`spgemm` (the stream engine broadcasts over the value axis).

    With ``plan`` the symbolic phase is skipped (conflicting explicit
    arguments raise, as in :func:`spgemm`) and ``a``/``b`` may also be raw
    ``[B, nnz]`` value stacks aligned with the planned patterns.
    """
    if plan is not None:
        _check_plan_overrides(plan, method, backend, t, b_min, b_max,
                              tile, candidates)
        return plan.execute_batched(a, b, validate=validate, engine=engine)
    if not isinstance(a, BatchedCSC) or not isinstance(b, BatchedCSC):
        raise TypeError(
            "spgemm_batched operands must be BatchedCSC (use BatchedCSC"
            ".stack / .from_values, or pass plan= with raw value stacks)")
    if a.batch != b.batch:
        raise ValueError(f"batch mismatch: {a.batch} vs {b.batch}")
    if a.batch < 1:
        raise ValueError("empty batch")
    method, backend = _resolve_method_backend(method, backend)
    _check_shards(backend, shards)
    _check_auto_only(method, t, b_min, b_max, tile, candidates)
    _check_canonical_only(backend, t, b_min, b_max)
    a0, b0 = a.element(0), b.element(0)
    if backend == "mesh":
        if method == "auto":
            p = _auto_mesh_plan(a0, b0, shards, tile, candidates, cache)
        elif cache:
            p = _cached_mesh_plan(a0, b0, shards)
        else:
            p = plan_spgemm(a0, b0, method, backend="mesh", shards=shards)
        return p.execute_batched(a, b, validate=validate, engine=engine)
    if method == "auto":
        if cache:
            p = _cached_tiled_plan(a0, b0, backend, tile, candidates)
        else:
            p = plan_spgemm_tiled(a0, b0, backend=backend, tile=tile,
                                  candidates=candidates, cache=False)
        return p.execute_batched(a, b, validate=validate, engine=engine)
    params = resolve_params(method, t=t, b_min=b_min, b_max=b_max)
    if cache:
        p = _cached_plan(a0, b0, method, backend, params)
    else:
        p = plan_spgemm(a0, b0, method, backend=backend, t=t,
                        b_min=b_min, b_max=b_max)
    return p.execute_batched(a, b, validate=validate, engine=engine)

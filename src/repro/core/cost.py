"""Analytical per-tile cost model for ``method="auto"`` (DESIGN.md §8).

Each tile of a :class:`~repro.core.planner.TiledSpgemmPlan` gets the method
the model predicts cheapest for that tile's work profile — the paper's
per-column hybrid switching generalized to per-tile method selection, in
the spirit of Nagasaka et al.'s per-region accumulator choice.

Two separate models, selected by backend:

* **host** — predicts wall time (seconds) of the host executors.  Their
  cost structure is dominated by Python-loop overhead versus vectorized
  throughput: SPA pays a per-column and per-B-entry loop toll but touches
  each product once; ``expand`` replays the plan's cached product stream
  (``core.fast``, DESIGN.md §9) — a flat per-product cost with no sort —
  *when the stream fits the plan-memory guard*; above the guard every
  execution rebuilds the stream transiently (lexsort + boundary scan per
  call), which is where SPA wins back flop-heavy tiles.  The lock-step
  executors (SPARS/HASH) pay a Python iteration per lock-step round.
  Constants are calibrated by ``benchmarks/tiled.py --calibrate`` (values
  below are from that script on the CI container class; they only need to
  be right *relative* to each other, and the regimes they separate differ
  by orders of magnitude).
* **pallas** — predicts relative kernel work from the DESIGN.md §2 cost
  dictionary: SPA streams every B entry against an ``[m, L]`` tile, SPARS
  pays the block-max trip count against the same tile, HASH pays it against
  an ``[H, L]`` table with ``H`` sized from the block's worst column — so
  sparse tiles favour HASH (``H << m``) and dense tiles favour SPA, exactly
  the paper's Figure 3/4 crossover.

The model consumes only :class:`~repro.sparse.stats.TileStats` (pattern
statistics, O(nnz)); it never looks at values.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import backends
import repro.core.fast as _fast
from repro.sparse.stats import TileStats

# default per-backend candidate sets for method="auto" (one entry per
# registered backend contract — core/backends.py).  Host: the engines with
# complementary regimes (expand -> the plan-resident product stream,
# cheapest per product while the stream fits the memory guard; SPA: no
# plan-resident O(flops) state, wins guard-tripped flop-heavy tiles; "jax"
# -> the device-resident stream of DESIGN.md §10, picked for in-guard
# tiles wherever the calibrated device per-product cost undercuts the
# numpy stream — on accelerator-backed installs, not the CI CPU, see
# CostConstants.jax_prod; "fused" -> the single-launch fused Pallas
# kernel of DESIGN.md §11, same admission logic with its own calibrated
# constants).  Pallas: the paper's families — dense-tile SPA vs
# small-table HASH, with SPARS between.  Jax: the device stream and its
# fused lowering.
AUTO_CANDIDATES = {
    "host": ("spa", "expand", "jax", "fused"),
    "pallas": ("spa", "spars-40/40", "hash-256/256"),
    "jax": ("jax", "fused"),
    # mesh children are device-stream replays; the distribute-or-not
    # decision itself is estimate_mesh_cost/should_distribute, not a
    # per-tile method race
    "mesh": ("jax",),
}


@dataclasses.dataclass(frozen=True)
class CostConstants:
    """Calibrated coefficients (host entries in seconds; pallas relative).

    Host values measured by ``benchmarks/tiled.py --calibrate``; see module
    docstring.
    """

    # host spa_numpy: per-column loop + per-B-entry vector op + per product
    spa_col: float = 3.0e-6
    spa_entry: float = 6.7e-6
    spa_flop: float = 1.0e-8
    # host stream engine (core/fast.py): fixed kernel-call overhead + flat
    # per-product gather/multiply/segment-reduce cost (plan-resident stream)
    stream_base: float = 5.9e-6
    stream_prod: float = 6.6e-9
    # guard-tripped expand: per-call transient stream rebuild (expansion +
    # lexsort) on top of the per-product stream work
    expand_base: float = 1.0e-4
    expand_prod: float = 1.5e-7
    expand_sort: float = 8.0e-9       # per product per log2(products)
    # jax device stream (core/jax_stream.py): fixed jitted-dispatch
    # overhead + flat per-product device cost (cached-trace steady state;
    # measured by ``benchmarks/tiled.py --calibrate``).  On the CI
    # container class XLA *CPU* scatter-add dominates (segment_sum is
    # near-serial there), so the honest per-product constant is above the
    # numpy stream's and host auto only picks "jax" after re-calibration
    # on hardware where the scatter is parallel (real devices)
    jax_base: float = 1.4e-5
    jax_prod: float = 3.7e-8
    # fused Pallas stream kernel (core/pallas_stream.py): one launch for
    # the whole numeric phase.  Constants are the honest CI-container
    # numbers (``benchmarks/tiled.py --calibrate``), where the kernel runs
    # under pallas_call(interpret=True) and the [block, block] one-hot
    # contraction is emulated on CPU — per-product cost sits ~40x above
    # the numpy stream's, so auto never picks "fused" here.  Re-calibrate
    # on a real device, where the MXU absorbs the one-hot matmul and this
    # becomes the cheapest in-guard family.
    fused_base: float = 7.9e-5
    fused_prod: float = 3.0e-7
    # mesh backend communication terms (DESIGN.md §13): fixed collective
    # dispatch/launch overhead per sharded execution, plus a per-byte toll
    # on the cross-device partial-C reduction — a tiled psum_scatter moves
    # ~(D-1)/D of the padded slot axis through the interconnect.  The
    # defaults are honest CI-container numbers (host mesh of XLA CPU
    # devices: the "interconnect" is memcpy), deliberately conservative so
    # auto only distributes when the stream guard forces it or the matrix
    # is far past single-device scale.
    comm_base: float = 1.0e-3
    comm_byte: float = 5.0e-10
    # host esc_numpy: expand + explicit LSD radix rounds
    esc_base: float = 2.0e-4
    esc_round: float = 1.2e-7         # per product per radix round
    # host lock-step executors: per Python round + per product probe work
    lockstep_iter: float = 3.0e-5
    hash_probe: float = 3.0e-6
    # pallas relative-work coefficients (unitless; compared per backend)
    p_spa_entry: float = 1.0          # x m per streamed B entry
    p_spa_col: float = 1.0            # x m per output column (tile init)
    p_lock_iter: float = 1.0          # x accumulator height per round
    p_hash_col: float = 1.0           # x H per column (compaction)


DEFAULT_CONSTANTS = CostConstants()


def _resolve_constants(constants: CostConstants | None) -> CostConstants:
    """Explicit constants win; otherwise consult the machine profile
    (measured fit for this fingerprint if one is persisted, else
    ``DEFAULT_CONSTANTS`` — see ``core.profile``).  Lazy import: profile
    depends on this module for :class:`CostConstants`."""
    if constants is not None:
        return constants
    from repro.core import profile

    return profile.current_constants()


def _note_if_default(backend: str, candidates: tuple) -> None:
    """Count/warn when auto ranks device engines on uncalibrated defaults
    (satellite: the stale-constants trap)."""
    from repro.core import profile

    if profile.current_profile().source == "default":
        profile.note_default_auto(backend, candidates)


def _family(method: str) -> str:
    if method in ("spa", "expand", "esc", "jax", "fused"):
        return method
    if method.startswith("h-"):
        return "hybrid"
    if method.startswith("spars"):
        return "spars"
    if method.startswith("hash"):
        return "hash"
    raise ValueError(f"cost model does not know method {method!r}")


def _params(method: str) -> dict:
    from repro.core.planner import resolve_params

    return resolve_params(method)


def _lockstep_rounds(steps: np.ndarray, b: int) -> int:
    """Total lock-step iterations: sum of per-block max trip counts.

    Columns are processed sorted by load in blocks of ~``b`` lanes and every
    round runs until the block's slowest lane finishes, so the bound is the
    sum of block maxima over the descending-sorted step counts.
    """
    work = np.sort(steps[steps > 0])[::-1]
    if not len(work):
        return 0
    return int(work[::max(int(b), 1)].sum())


def _next_pow2(x: int) -> int:
    return 1 << max(int(math.ceil(math.log2(max(x, 2)))), 1)


def _guarded_rebuild_cost(flops: int, c: CostConstants) -> float:
    """Per-call transient stream rebuild (expansion + lexsort): what any
    stream engine costs above the plan-memory guard."""
    return c.expand_base + flops * (
        c.expand_prod + c.expand_sort * math.log2(max(flops, 2)))


def _host_cost(stats: TileStats, method: str, c: CostConstants) -> float:
    fam = _family(method)
    flops = stats.flops
    if fam == "spa":
        return (c.spa_col * stats.n + c.spa_entry * stats.nnz_b
                + c.spa_flop * flops)
    if fam == "expand":
        if flops <= _fast.STREAM_MAX_PRODUCTS:
            # plan-resident product stream: flat vectorized replay
            return c.stream_base + c.stream_prod * flops
        # guard-tripped: every call rebuilds the stream transiently
        return _guarded_rebuild_cost(flops, c)
    if fam == "jax":
        if flops <= _fast.STREAM_MAX_PRODUCTS:
            # jitted device stream: one dispatch, flat per-product cost
            return c.jax_base + c.jax_prod * flops
        # guard-tripped jax plans fall back to the host transient rebuild
        # (core/jax_stream.py), so they cost what guarded expand costs
        return _guarded_rebuild_cost(flops, c)
    if fam == "fused":
        if flops <= _fast.STREAM_MAX_PRODUCTS:
            # single fused kernel launch: one dispatch, flat per-product
            return c.fused_base + c.fused_prod * flops
        # guard-tripped fused executions fall back to the host transient
        # rebuild (core/pallas_stream.py), same as the other stream engines
        return _guarded_rebuild_cost(flops, c)
    if fam == "esc":
        rounds = (math.ceil(math.log2(max(stats.m, 2)) / 5)
                  + math.ceil(math.log2(max(stats.n, 2)) / 5))
        return c.esc_base + c.esc_round * flops * rounds
    params = _params(method)
    t = params.get("t", np.inf)
    head = stats.ops >= t
    tail_steps = stats.steps[~head]
    cost = (c.spa_col * int(head.sum())
            + c.spa_flop * int(stats.ops[head].sum())
            + c.spa_entry * int(head.sum()) * stats.nnz_b
            / max(stats.n, 1))
    rounds = _lockstep_rounds(tail_steps, params.get("b_max", 256))
    cost += c.lockstep_iter * rounds
    if fam == "hash" or params.get("accumulator") == "hash":
        cost += c.hash_probe * int(stats.ops[~head].sum())
    return cost


def _pallas_cost(stats: TileStats, method: str, c: CostConstants) -> float:
    fam = _family(method)
    m = max(stats.m, 1)
    if fam in ("expand", "esc", "jax", "fused"):
        # "fused" is an engine on pallas plans, not a per-group kernel
        # family the relative-work model ranks — it never competes in a
        # pallas-domain tile grid (host/jax grids admit it in seconds)
        raise ValueError(f"method {method!r} has no Pallas kernel family")
    if fam == "spa":
        return c.p_spa_entry * m * stats.nnz_b + c.p_spa_col * m * stats.n
    params = _params(method)
    t = params.get("t", np.inf)
    head = stats.ops >= t
    cost = (c.p_spa_entry * m * stats.nnz_b * int(head.sum())
            / max(stats.n, 1) + c.p_spa_col * m * int(head.sum()))
    tail_steps = stats.steps[~head]
    rounds = _lockstep_rounds(tail_steps, params.get("b_max", 256))
    acc = params.get("accumulator",
                     "hash" if fam == "hash" else "spa")
    if fam == "spars" or acc == "spa":
        cost += c.p_lock_iter * m * rounds
    else:
        tail_ops = stats.ops[~head]
        h = _next_pow2(int(tail_ops.max()) if len(tail_ops) else 2)
        cost += (c.p_lock_iter * h * rounds
                 + c.p_hash_col * h * int((~head).sum()))
    return cost


def estimate_cost(stats: TileStats, method: str, backend: str = "host",
                  constants: CostConstants | None = None) -> float:
    """Predicted cost of running ``method`` on one tile (lower is better).

    The model is selected by the backend's registered contract
    (``core.backends``): host and jax estimates are wall seconds (the
    "jax" family models the device stream's dispatch + per-product cost,
    so it is directly comparable with the host engines it competes with in
    a mixed tile grid); Pallas estimates are relative work units.  Only
    compare estimates within one cost domain.

    When ``constants`` is ``None`` the machine profile is consulted
    (``core.profile``): the measured fit for this host/device fingerprint
    if one is persisted, ``DEFAULT_CONSTANTS`` otherwise.
    """
    c = _resolve_constants(constants)
    contract = backends.get_backend(backend)
    if contract.cost_domain == "relative":
        return _pallas_cost(stats, method, c)
    return _host_cost(stats, method, c)


def estimate_mesh_cost(stats: TileStats, n_shards: int,
                       constants: CostConstants | None = None) -> float:
    """Predicted wall seconds of a mesh-distributed execution (DESIGN.md §13).

    Compute: the jax device-stream cost of one shard's ~1/D slice of the
    product stream (the guard applies per shard, so the slice never pays
    the transient-rebuild penalty as long as it fits — callers sizing
    shards so it does is the whole point of distributing).  Communication:
    a fixed collective overhead plus the per-byte toll of the tiled
    ``psum_scatter`` partial-C reduction, which moves ``(D-1)/D`` of the
    f32 slot axis (|C| estimated from the flops upper bound) through the
    interconnect.  Seconds domain — directly comparable with the host/jax
    estimates of :func:`estimate_cost`.  ``constants=None`` resolves
    through the machine profile, so a measured ``psum_scatter`` ladder
    (``benchmarks/calibrate_profile.py``) replaces the default comm terms.
    """
    c = _resolve_constants(constants)
    d = max(int(n_shards), 1)
    flops = stats.flops
    per_shard = -(-flops // d)
    if per_shard <= _fast.STREAM_MAX_PRODUCTS:
        compute = c.jax_base + c.jax_prod * per_shard
    else:
        compute = _guarded_rebuild_cost(per_shard, c)
    if d == 1:
        return compute
    nnz_c = min(flops, stats.m * stats.n)
    comm = c.comm_base + c.comm_byte * 4.0 * nnz_c * (d - 1) / d
    return compute + comm


def should_distribute(stats: TileStats, n_shards: int,
                      constants: CostConstants | None = None,
                      shard_limit: int | None = None) -> bool:
    """Whether ``method="auto"`` on the mesh backend should shard.

    True when distributing is predicted to win: either the whole product
    stream is above the single-device plan-memory guard (a single-device
    execution would pay the per-call transient rebuild; sharding lifts the
    guard to ``n_shards x shard_limit``), or the communication-aware mesh
    estimate undercuts the best single-device stream estimate outright.
    With one shard (or one device) the answer is always False.
    """
    if int(n_shards) <= 1:
        return False
    if constants is None:
        _note_if_default("mesh", AUTO_CANDIDATES["mesh"])
    c = _resolve_constants(constants)
    limit = (_fast.STREAM_MAX_PRODUCTS if shard_limit is None
             else int(shard_limit))
    if stats.flops > limit:
        return True
    single = c.jax_base + c.jax_prod * stats.flops
    return estimate_mesh_cost(stats, n_shards, c) < single


def choose_method(stats: TileStats, backend: str = "host",
                  candidates: tuple | None = None,
                  constants: CostConstants | None = None) -> str:
    """Cheapest candidate method for this tile (deterministic: first wins
    ties in candidate order)."""
    cands = AUTO_CANDIDATES[backend] if candidates is None \
        else tuple(candidates)
    if not cands:
        raise ValueError("empty candidate set")
    if constants is None:
        _note_if_default(backend, cands)
        constants = _resolve_constants(None)
    best, best_cost = cands[0], None
    for m in cands:
        cost = estimate_cost(stats, m, backend, constants)
        if best_cost is None or cost < best_cost:
            best, best_cost = m, cost
    return best

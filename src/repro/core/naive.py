"""Faithful value-level executions of the paper's algorithms (host numpy).

These follow the pseudocode structurally — SPA's per-column accumulation
(Algorithm 1/2), SPARS's lock-step lane cursors over blocks (Algorithm 3),
HASH's per-lane linear-probed tables, ESC's expand/sort/compress — and are the
oracles the Pallas kernels and the instruction-schedule models are tested
against. They favour clarity over speed; benchmarks use vm/schedule.py which
never touches values.
"""

from __future__ import annotations

import numpy as np

from repro.core.analysis import HASH_C, Preprocess, hash_table_size, preprocess
from repro.sparse.format import CSC, _np


# ---------------------------------------------------------------------------
# assembly helper
# ---------------------------------------------------------------------------


def _assemble(cols_rows, cols_vals, shape, dtype) -> CSC:
    """Build CSC from per-column (rows, vals) lists in original column order."""
    n = shape[1]
    col_ptr = np.zeros(n + 1, np.int32)
    np.cumsum([len(r) for r in cols_rows], out=col_ptr[1:])
    rows = (
        np.concatenate(cols_rows)
        if col_ptr[-1]
        else np.zeros(0, np.int32)
    )
    vals = np.concatenate(cols_vals) if col_ptr[-1] else np.zeros(0, dtype)
    return CSC(vals, rows.astype(np.int32), col_ptr, shape)


# ---------------------------------------------------------------------------
# SPA (Algorithms 1–2)
# ---------------------------------------------------------------------------


def spa_numpy(a: CSC, b: CSC, columns: np.ndarray | None = None) -> CSC:
    """Vectorized-SPA semantics: one C column at a time; per B non-zero, a
    vector op of length nnz(A[:,k]) accumulates into the dense SPA arrays.

    ``columns``: process only these B columns (hybrids); output still spans
    all of C's columns (others empty).
    """
    a_cp = _np(a.col_ptr)
    a_rows = _np(a.row_indices)
    a_vals = _np(a.values)
    b_cp = _np(b.col_ptr)
    b_rows = _np(b.row_indices)
    b_vals = _np(b.values)
    m = a.n_rows
    n = b.n_cols
    dtype = np.result_type(a_vals.dtype, b_vals.dtype)

    spa_values = np.zeros(m, dtype)
    spa_flags = np.zeros(m, bool)

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros(0, dtype)] * n
    todo = range(n) if columns is None else [int(c) for c in columns]
    for j in todo:
        touched = []  # SPA_indices, in discovery order
        for p in range(b_cp[j], b_cp[j + 1]):
            k = b_rows[p]
            bv = b_vals[p]
            sl = slice(a_cp[k], a_cp[k + 1])
            ar = a_rows[sl]
            spa_values[ar] += a_vals[sl] * bv  # rows unique within an A column
            new = ar[~spa_flags[ar]]
            spa_flags[new] = True
            if len(new):
                touched.append(new)
        idx = (
            np.concatenate(touched) if touched else np.zeros(0, np.int32)
        )
        out_rows[j] = idx.astype(np.int32)
        out_vals[j] = spa_values[idx].astype(dtype)
        # reset only the touched entries (standard SPA trick)
        spa_values[idx] = 0
        spa_flags[idx] = False
    return _assemble(out_rows, out_vals, (m, n), dtype)


# ---------------------------------------------------------------------------
# SPARS (Algorithm 3)
# ---------------------------------------------------------------------------


def spars_numpy(
    a: CSC, b: CSC, pre: Preprocess | None = None,
    *, b_min: int = 256, b_max: int = 256,
) -> CSC:
    """Lock-step block execution with lane cursors, faithful to Algorithm 3."""
    if pre is None:
        pre = preprocess(a, b, t=np.inf, b_min=b_min, b_max=b_max)
    a_cp = _np(a.col_ptr).astype(np.int64)
    a_rows = _np(a.row_indices)
    a_vals = _np(a.values)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)
    b_vals = _np(b.values)
    m = a.n_rows
    n = b.n_cols
    dtype = np.result_type(a_vals.dtype, b_vals.dtype)

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros(0, dtype)] * n

    for start, size in pre.blocks:
        cols = pre.perm[start : start + size]  # original column ids (lanes)
        L = len(cols)
        vidx_b = b_cp[cols].copy()       # vIndices_B
        vend_b = b_cp[cols + 1]          # vEnd_B
        vcnt_a = np.zeros(L, np.int64)   # vCounter_A
        spa_values = np.zeros((m, L), dtype)
        spa_flags = np.zeros((m, L), bool)
        touched = [[] for _ in range(L)]
        active = vidx_b < vend_b
        while active.any():
            lanes = np.nonzero(active)[0]
            bk = b_rows[vidx_b[lanes]]
            bv = b_vals[vidx_b[lanes]]
            apos = a_cp[bk] + vcnt_a[lanes]
            # a lane whose B entry references an *empty* A column produces no
            # product; it just consumes that B entry this step (ok == False)
            ok = apos < a_cp[bk + 1]
            l_ok, r_ok = lanes[ok], a_rows[apos[ok]]
            spa_values[r_ok, l_ok] += a_vals[apos[ok]] * bv[ok]
            newm = ~spa_flags[r_ok, l_ok]
            spa_flags[r_ok[newm], l_ok[newm]] = True
            for ln, r in zip(l_ok[newm], r_ok[newm]):
                touched[ln].append(r)
            last = apos + 1 >= a_cp[bk + 1]
            vcnt_a[lanes] = np.where(last, 0, vcnt_a[lanes] + 1)
            vidx_b[lanes] += last
            active = vidx_b < vend_b
        for ln, col in enumerate(cols):
            idx = np.asarray(touched[ln], np.int32)
            out_rows[col] = idx
            out_vals[col] = spa_values[idx, ln].astype(dtype)
    return _assemble(out_rows, out_vals, (m, n), dtype)


# ---------------------------------------------------------------------------
# HASH (Section 3.2)
# ---------------------------------------------------------------------------


def hash_numpy(
    a: CSC, b: CSC, pre: Preprocess | None = None,
    *, b_min: int = 256, b_max: int = 256,
) -> CSC:
    """Lock-step blocks with per-lane linear-probed hash tables.

    Table size H is per block (dynamic shrink, Section 3.2). Collisions are
    resolved by real probing, so this validates the hash path end to end.
    """
    if pre is None:
        pre = preprocess(a, b, t=np.inf, b_min=b_min, b_max=b_max)
    a_cp = _np(a.col_ptr).astype(np.int64)
    a_rows = _np(a.row_indices)
    a_vals = _np(a.values)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)
    b_vals = _np(b.values)
    m = a.n_rows
    n = b.n_cols
    dtype = np.result_type(a_vals.dtype, b_vals.dtype)

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros(0, dtype)] * n

    for bi, (start, size) in enumerate(pre.blocks):
        cols = pre.perm[start : start + size]
        L = len(cols)
        H = int(pre.hash_sizes[bi])
        keys = np.full((H, L), -1, np.int64)
        vals = np.zeros((H, L), dtype)
        vidx_b = b_cp[cols].copy()
        vend_b = b_cp[cols + 1]
        vcnt_a = np.zeros(L, np.int64)
        insert_order = [[] for _ in range(L)]
        active = vidx_b < vend_b
        while active.any():
            all_lanes = np.nonzero(active)[0]
            bk = b_rows[vidx_b[all_lanes]]
            bv_all = b_vals[vidx_b[all_lanes]]
            apos_all = a_cp[bk] + vcnt_a[all_lanes]
            # lanes whose B entry references an empty A column produce no
            # product; they only consume that B entry this step (ok == False)
            ok = apos_all < a_cp[bk + 1]
            lanes, apos, bv = all_lanes[ok], apos_all[ok], bv_all[ok]
            ar = a_rows[apos].astype(np.int64)
            av = a_vals[apos]
            # vectorized linear probing across lanes (lanes independent)
            pos = (ar * HASH_C) % H
            pending = np.ones(len(lanes), bool)
            while pending.any():
                pl = np.nonzero(pending)[0]
                kk = keys[pos[pl], lanes[pl]]
                hit = kk == ar[pl]
                empty = kk == -1
                place = hit | empty
                tgt = pl[place]
                keys[pos[tgt], lanes[tgt]] = ar[tgt]
                vals[pos[tgt], lanes[tgt]] += av[tgt] * bv[tgt]
                for t_i, was_empty in zip(tgt, empty[place]):
                    if was_empty:
                        insert_order[lanes[t_i]].append(int(ar[t_i]))
                pending[tgt] = False
                nxt = pl[~place]
                pos[nxt] = (pos[nxt] + 1) % H
            last = apos_all + 1 >= a_cp[bk + 1]
            vcnt_a[all_lanes] = np.where(last, 0, vcnt_a[all_lanes] + 1)
            vidx_b[all_lanes] += last
            active = vidx_b < vend_b
        for ln, col in enumerate(cols):
            idx = np.asarray(insert_order[ln], np.int64)
            if len(idx) == 0:
                out_rows[col] = np.zeros(0, np.int32)
                out_vals[col] = np.zeros(0, dtype)
                continue
            # read back through the table (probe again)
            v = np.empty(len(idx), dtype)
            for q, key in enumerate(idx):
                p = (key * HASH_C) % H
                while keys[p, ln] != key:
                    p = (p + 1) % H
                v[q] = vals[p, ln]
            out_rows[col] = idx.astype(np.int32)
            out_vals[col] = v
    return _assemble(out_rows, out_vals, (m, n), dtype)


# ---------------------------------------------------------------------------
# ESC (Section 4)
# ---------------------------------------------------------------------------


def esc_numpy(
    a: CSC, b: CSC, *, group_threshold: int = 10_000, radix_bits: int = 5
) -> CSC:
    """Expand-Sort-Compress with an explicit LSD radix sort (row key first,
    then column key), grouping columns until >= group_threshold products."""
    from repro.core.expand import expand_products, product_col_ptr

    coo = expand_products(a, b)
    pcp = product_col_ptr(a, b)
    m, n = coo.shape
    dtype = coo.values.dtype

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros(0, dtype)] * n

    j = 0
    while j < n:
        j2 = j + 1
        while j2 < n and pcp[j2 + 1] - pcp[j] < group_threshold:
            j2 += 1
        lo, hi = pcp[j], pcp[j2]
        id_row = coo.rows[lo:hi].astype(np.int64)
        id_col = coo.cols[lo:hi].astype(np.int64)
        esc_val = coo.values[lo:hi]
        # --- Sort: LSD radix, row digits then col digits -------------------
        order = np.arange(len(id_row))
        for key, kmax in ((id_row, m), (id_col, n)):
            bits = max(int(np.ceil(np.log2(max(kmax, 2)))), 1)
            r = radix_bits if radix_bits * ((bits + 5) // 6) else radix_bits
            # paper: r=5 unless r=6 lowers the round count
            r5, r6 = -(-bits // 5), -(-bits // 6)
            r = 6 if r6 < r5 else 5
            kk = key[order]
            for d in range(0, bits, r):
                digit = (kk >> d) & ((1 << r) - 1)
                o2 = np.argsort(digit, kind="stable")
                order = order[o2]
                kk = kk[o2]
        id_row, id_col, esc_val = id_row[order], id_col[order], esc_val[order]
        # --- Compress: segment-sum equal (row, col) pairs -------------------
        if len(id_row):
            key = id_col * m + id_row
            boundary = np.empty(len(key), bool)
            boundary[0] = True
            boundary[1:] = key[1:] != key[:-1]
            seg = np.cumsum(boundary) - 1
            sums = np.zeros(seg[-1] + 1, dtype)
            np.add.at(sums, seg, esc_val)
            u_rows = id_row[boundary]
            u_cols = id_col[boundary]
            for c in np.unique(u_cols):
                sel = u_cols == c
                out_rows[int(c)] = u_rows[sel].astype(np.int32)
                out_vals[int(c)] = sums[sel]
        j = j2
    return _assemble(out_rows, out_vals, (m, n), dtype)


# ---------------------------------------------------------------------------
# Hybrids (Section 3.3)
# ---------------------------------------------------------------------------


def hybrid_numpy(
    a: CSC, b: CSC, *, t: float, b_min: int, b_max: int,
    accumulator: str = "spa", pre: Preprocess | None = None,
) -> CSC:
    """H-SPA(t) / H-HASH(t): SPA on sorted columns while Op_j >= t, then the
    blocked algorithm (SPARS or HASH) on the sparse tail.

    ``pre``: pass a matching plan's pre-processing to skip re-analysis.
    """
    if pre is None:
        pre = preprocess(a, b, t=t, b_min=b_min, b_max=b_max)
    head_cols = pre.perm[: pre.split]
    c_head = spa_numpy(a, b, columns=head_cols)
    if accumulator == "spa":
        c_tail = spars_numpy(a, b, pre)
    elif accumulator == "hash":
        c_tail = hash_numpy(a, b, pre)
    else:
        raise ValueError(accumulator)
    # merge: head columns from c_head, tail columns from c_tail
    n = b.n_cols
    dtype = c_head.values.dtype
    rows_l = [np.zeros(0, np.int32)] * n
    vals_l = [np.zeros(0, dtype)] * n
    head_set = set(int(x) for x in head_cols)
    for j in range(n):
        src = c_head if j in head_set else c_tail
        r, v = src.column(j)
        rows_l[j] = r.astype(np.int32)
        vals_l[j] = v
    return _assemble(rows_l, vals_l, (a.n_rows, n), dtype)


# ---------------------------------------------------------------------------
# BEYOND-PAPER: lock-step with lane refill ("work-stealing" SPARS)
# ---------------------------------------------------------------------------


def spars_ws_numpy(
    a: CSC, b: CSC, pre: Preprocess | None = None,
    *, b_min: int = 256, b_max: int = 256,
) -> CSC:
    """SPARS with lane refill: when a lane exhausts its column it flushes the
    column and immediately claims the next unprocessed one, instead of idling
    masked until the block's longest column finishes (the semi-transparent
    area of the paper's Figure 2). Extra cost per refill: one store-out +
    accumulator reset + cursor reload — all machinery SPARS already has.
    Value-identical to SPARS (tested against the dense oracle)."""
    if pre is None:
        pre = preprocess(a, b, t=np.inf, b_min=b_min, b_max=b_max)
    a_cp = _np(a.col_ptr).astype(np.int64)
    a_rows = _np(a.row_indices)
    a_vals = _np(a.values)
    b_cp = _np(b.col_ptr).astype(np.int64)
    b_rows = _np(b.row_indices)
    b_vals = _np(b.values)
    m = a.n_rows
    n = b.n_cols
    dtype = np.result_type(a_vals.dtype, b_vals.dtype)

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros(0, dtype)] * n

    for start, size in pre.blocks:
        cols = pre.perm[start : start + size]
        L = len(cols)
        queue = list(range(L))          # column indices waiting for a lane
        lane_col = np.full(L, -1, np.int64)
        vidx_b = np.zeros(L, np.int64)
        vend_b = np.zeros(L, np.int64)
        vcnt_a = np.zeros(L, np.int64)
        spa_values = np.zeros((m, L), dtype)
        spa_flags = np.zeros((m, L), bool)
        touched = [[] for _ in range(L)]

        def flush(ln):
            ci = lane_col[ln]
            if ci < 0:
                return
            col = cols[ci]
            idx = np.asarray(touched[ln], np.int32)
            out_rows[col] = idx
            out_vals[col] = spa_values[idx, ln].astype(dtype)
            spa_values[idx, ln] = 0
            spa_flags[idx, ln] = False
            touched[ln] = []

        def refill(ln):
            flush(ln)
            if queue:
                ci = queue.pop(0)
                lane_col[ln] = ci
                vidx_b[ln] = b_cp[cols[ci]]
                vend_b[ln] = b_cp[cols[ci] + 1]
                vcnt_a[ln] = 0
            else:
                lane_col[ln] = -1

        for ln in range(L):
            refill(ln)
        # drain columns that start empty
        for ln in range(L):
            while lane_col[ln] >= 0 and vidx_b[ln] >= vend_b[ln]:
                refill(ln)
        active = (lane_col >= 0) & (vidx_b < vend_b)
        while active.any():
            lanes = np.nonzero(active)[0]
            bk = b_rows[vidx_b[lanes]]
            bv = b_vals[vidx_b[lanes]]
            apos = a_cp[bk] + vcnt_a[lanes]
            # empty A column referenced: no product, consume the B entry
            ok = apos < a_cp[bk + 1]
            l_ok, r_ok = lanes[ok], a_rows[apos[ok]]
            spa_values[r_ok, l_ok] += a_vals[apos[ok]] * bv[ok]
            newm = ~spa_flags[r_ok, l_ok]
            spa_flags[r_ok[newm], l_ok[newm]] = True
            for ln, r in zip(l_ok[newm], r_ok[newm]):
                touched[ln].append(r)
            last = apos + 1 >= a_cp[bk + 1]
            vcnt_a[lanes] = np.where(last, 0, vcnt_a[lanes] + 1)
            vidx_b[lanes] += last
            for ln in lanes:
                while lane_col[ln] >= 0 and vidx_b[ln] >= vend_b[ln]:
                    refill(ln)
            active = (lane_col >= 0) & (vidx_b < vend_b)
        for ln in range(L):
            flush(ln)
    return _assemble(out_rows, out_vals, (m, n), dtype)

"""Deterministic fault injection for the plan-build/serve pipeline.

The resilience layer (DESIGN.md §14) is only trustworthy if its failure
paths are exercised by *real* injected faults rather than mocks: a
``FaultPlan`` installed process-globally (test-scoped, via
:func:`inject`) makes the instrumented sites fail, hang, or delay
deterministically — seeded, by call count (``every=``) or key pattern
(``match=``) — so the retry/backoff machinery, the builder watchdog, and
the serving circuit breaker all see the same faults on every run.

Instrumented sites (each calls :func:`check` with a site name and a
cheap key):

* ``"plan_spgemm"``    — the symbolic phase (``core.planner.plan_spgemm``);
  key is ``(backend, method)``, so ``match="jax"`` scopes faults to
  background device builds without touching the foreground host fallback.
* ``"device_lift"``    — the lazy device-stream lift
  (``core.jax_stream.device_stream``).
* ``"warm_compile"``   — XLA warm compiles: ``plan_builder.warm_plan``
  and the serving engine's background decode-step warm.
* ``"builder_worker"`` — the top of every ``PlanBuilder`` worker task
  (hangs here simulate a wedged worker for the watchdog to recycle).

With no plan installed every ``check`` is one attribute read and a
``None`` test — the hooks cost nothing in production paths.

Hangs are *bounded*: a ``"hang"`` rule waits on the plan's release event
for ``seconds`` (default 30), so an abandoned (watchdog-recycled) zombie
thread always unwedges eventually; :func:`uninstall` — and therefore the
:func:`inject` context exit — releases all hung sites immediately.
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time

SITES = ("plan_spgemm", "device_lift", "warm_compile", "builder_worker")
MODES = ("fail", "hang", "delay")


class InjectedFault(RuntimeError):
    """Raised at an instrumented site by a ``mode="fail"`` rule."""


@dataclasses.dataclass
class FaultRule:
    """One fault at one site.

    Exactly how it fires:

    * ``every=N`` — fires on every Nth *matched* call (1-based: calls
      N, 2N, ...).  Deterministic by construction.
    * ``rate=p`` — fires with probability ``p`` per matched call, drawn
      from a per-rule RNG seeded by ``(plan seed, site, rule index)`` —
      the same seed replays the same firing pattern.
    * ``match="s"`` — only calls whose ``str(key)`` contains ``s`` are
      matched (and counted) at all.
    * ``max_fires=K`` — stop firing after K hits (e.g. "fail twice,
      then recover").

    ``mode``: ``"fail"`` raises :class:`InjectedFault`; ``"hang"`` blocks
    for up to ``seconds`` (released early by ``FaultPlan.release()`` /
    :func:`uninstall`); ``"delay"`` sleeps ``seconds`` then continues.
    """

    site: str
    mode: str = "fail"
    rate: float = 0.0
    every: int | None = None
    match: str | None = None
    seconds: float = 30.0
    max_fires: int | None = None
    # runtime counters, not configuration
    calls: int = 0
    fires: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; one of {SITES}")
        if self.mode not in MODES:
            raise ValueError(
                f"unknown fault mode {self.mode!r}; one of {MODES}")
        if self.every is not None and self.every < 1:
            raise ValueError(f"every= must be >= 1, got {self.every}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate= must be in [0, 1], got {self.rate}")


class FaultPlan:
    """A set of :class:`FaultRule`\\ s plus the seed that replays them."""

    def __init__(self, rules, seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._release = threading.Event()
        self._rngs = [random.Random(f"{self.seed}:{r.site}:{i}")
                      for i, r in enumerate(self.rules)]

    def check(self, site: str, key=None) -> None:
        """Evaluate every matching rule for one call at ``site``."""
        actions = []
        with self._lock:
            for rule, rng in zip(self.rules, self._rngs):
                if rule.site != site:
                    continue
                if rule.match is not None and rule.match not in str(key):
                    continue
                rule.calls += 1
                if rule.max_fires is not None and rule.fires >= rule.max_fires:
                    continue
                if rule.every is not None:
                    fire = rule.calls % rule.every == 0
                else:
                    fire = rng.random() < rule.rate
                if fire:
                    rule.fires += 1
                    actions.append(rule)
        # act outside the lock: hangs/delays must not serialize other sites
        for rule in actions:
            if rule.mode == "fail":
                raise InjectedFault(
                    f"injected failure at {site} (key={key!r})")
            if rule.mode == "hang":
                self._release.wait(timeout=rule.seconds)
            elif rule.mode == "delay":
                time.sleep(rule.seconds)

    def release(self) -> None:
        """Unblock every site currently hung by a ``"hang"`` rule."""
        self._release.set()

    def fired(self, site: str) -> int:
        """Total fires across this plan's rules for ``site``."""
        with self._lock:
            return sum(r.fires for r in self.rules if r.site == site)

    def describe(self) -> dict:
        """JSON-able config + counters — recorded in BENCH ``env`` headers
        so no fault-mode result can pass as a clean baseline."""
        with self._lock:
            return {
                "seed": self.seed,
                "rules": [
                    {"site": r.site, "mode": r.mode, "rate": r.rate,
                     "every": r.every, "match": r.match,
                     "seconds": r.seconds, "max_fires": r.max_fires,
                     "calls": r.calls, "fires": r.fires}
                    for r in self.rules
                ],
            }


_ACTIVE: FaultPlan | None = None
_INSTALL_LOCK = threading.Lock()


def install(plan: FaultPlan) -> FaultPlan:
    """Install ``plan`` process-globally.  One plan at a time — nesting
    would make "which rule fired" ambiguous, so it raises instead."""
    global _ACTIVE
    with _INSTALL_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError(
                "a FaultPlan is already installed; uninstall() it first")
        _ACTIVE = plan
    return plan


def uninstall() -> None:
    """Remove the active plan (idempotent) and release hung sites."""
    global _ACTIVE
    with _INSTALL_LOCK:
        plan, _ACTIVE = _ACTIVE, None
    if plan is not None:
        plan.release()


def active() -> FaultPlan | None:
    """The installed plan, or ``None`` — benchmarks use this to stamp
    fault configs into their ``env`` headers."""
    return _ACTIVE


def check(site: str, key=None) -> None:
    """The instrumented-site hook: a no-op unless a plan is installed."""
    plan = _ACTIVE
    if plan is not None:
        plan.check(site, key)


@contextlib.contextmanager
def inject(*rules: FaultRule, seed: int = 0):
    """``with faults.inject(FaultRule(...), seed=7) as plan: ...`` —
    install for the block, always uninstall (and release hangs) after."""
    plan = install(FaultPlan(rules, seed=seed))
    try:
        yield plan
    finally:
        uninstall()

"""Numeric SpGEMM execution of a cached symbolic plan (DESIGN.md §6).

``execute(plan, a_values, b_values)`` runs only the value-dependent work of
C = A @ B; every pattern-dependent decision (sorting, blocking, hash sizing,
padded layouts, kernel groups) was made once by ``core.planner.plan_spgemm``.

Host backend: binds the values to the planned patterns and dispatches to the
faithful numpy executors, passing the plan's pre-computed ``Preprocess`` so
nothing is re-analyzed.  Pallas backend: re-pads the values with the plan's
gather indices (one vectorized gather per operand), launches one kernel per
plan group via ``kernels.ops.run_{spa,spars,hash}``, and compacts each
group's accumulator tile / hash tables straight into column-sliced CSC
through ``sparse.format.CSCBuilder`` — the dense ``[m, n]`` sink of the
pre-plan backend no longer exists; peak transient memory is one
``[m, tile_cols]`` tile.
"""

from __future__ import annotations

import numpy as np

from repro.core import naive
from repro.core.expand import spgemm_expand
from repro.core.planner import SpgemmPlan
from repro.sparse.format import CSC, CSCBuilder, padded_values


def execute(plan: SpgemmPlan, a_values, b_values, *,
            interpret: bool = True, stats: dict | None = None) -> CSC:
    """C = A @ B for new numeric values on the plan's sparsity patterns.

    ``a_values``/``b_values``: CSC matrices or raw nnz-length value arrays.
    Shapes and nnz are checked against the planned patterns (O(1)); a
    same-shape same-nnz operand with a different pattern is the caller's
    responsibility — full validation would cost the O(nnz) fingerprint this
    path exists to avoid.  ``stats``, if given, is filled with execution
    statistics (tile shapes, launch count) — tests use it to assert the
    no-dense-intermediate guarantee.
    """
    plan.a.check_compatible(a_values)
    plan.b.check_compatible(b_values)
    if plan.backend == "host":
        return _execute_host(plan, a_values, b_values)
    return _execute_pallas(plan, a_values, b_values, interpret=interpret,
                           stats=stats)


def _execute_host(plan: SpgemmPlan, a_values, b_values) -> CSC:
    a = plan.a.with_values(a_values)
    b = plan.b.with_values(b_values)
    method = plan.method
    params = dict(plan.params)
    if method == "spa":
        return naive.spa_numpy(a, b)
    if method == "expand":
        return spgemm_expand(a, b)
    if method == "esc":
        return naive.esc_numpy(a, b)
    if method.startswith("spars"):
        return naive.spars_numpy(a, b, plan.pre)
    if method.startswith("hash"):
        return naive.hash_numpy(a, b, plan.pre)
    if method.startswith("h-"):
        return naive.hybrid_numpy(
            a, b, t=params["t"], b_min=params["b_min"],
            b_max=params["b_max"], accumulator=params["accumulator"],
            pre=plan.pre,
        )
    raise AssertionError(method)


def _execute_pallas(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool, stats: dict | None) -> CSC:
    from repro.kernels import ops as kops

    lay = plan.pallas
    m, n = plan.shape
    av = padded_values(_values(a_values), lay.a_gather,
                       lay.a_mask).astype(np.float32, copy=False)
    bv = padded_values(_values(b_values), lay.b_gather,
                       lay.b_mask).astype(np.float32, copy=False)
    a_arrs = kops.device_operand(lay.a_rows, av, lay.a_nnz)

    builder = CSCBuilder((m, n), np.float32)
    for g in lay.groups:
        g_vals = np.where(g.valid[:, None], bv[g.sel], np.float32(0))
        if g.kind == "spa":
            tile = kops.run_spa(g, a_arrs, g_vals, m=m,
                                block_cols=lay.block_cols,
                                interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "spars":
            tile = kops.run_spars(g, a_arrs, g_vals, m=m,
                                  block_cols=lay.block_cols,
                                  interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "hash":
            keys, vals = kops.run_hash(g, a_arrs, g_vals, m=m,
                                       block_cols=lay.block_cols,
                                       interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    c = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)
        stats["result_shape"] = (m, n)
    return c


def _values(x) -> np.ndarray:
    return np.asarray(x.values) if isinstance(x, CSC) else np.asarray(x)

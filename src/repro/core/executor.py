"""Numeric SpGEMM execution of a cached symbolic plan (DESIGN.md §6–§10).

``execute(plan, a_values, b_values)`` runs only the value-dependent work of
C = A @ B; every pattern-dependent decision (sorting, blocking, hash sizing,
padded layouts, kernel groups, the product stream) was made once by
``core.planner.plan_spgemm``.

Execution is dispatched through the backend/engine registry
(``core.backends``): each backend registers one executor pair per engine in
``_DISPATCH``, and :func:`resolve_engine` turns the caller's ``engine=``
argument into a dispatch key by consulting the plan's
:class:`~repro.core.backends.ExecutionContract` — no backend string
matching at the call sites.  The registered pairs:

* ``("host", "naive")`` — the faithful numpy executors, passing the plan's
  pre-computed ``Preprocess`` so nothing is re-analyzed.  These are the
  bit-exact oracles of the paper's algorithms.
* ``("host", "stream")`` — the plan's precomputed product stream
  (``core.fast``, DESIGN.md §9): one vectorized gather → multiply →
  segment-reduce pass, no per-column Python loop.  Canonical output order,
  last-ulp fp-reassociation vs the oracles.  Default for ``expand``.
* ``("pallas", "naive")`` — gathers each group's padded value operand with
  the plan's precomputed ``b_vgather``/``b_vmask``, launches one kernel per
  plan group via ``kernels.ops.run_{spa,spars,hash}``, and compacts each
  group's tile straight into column-sliced CSC (no dense ``[m, n]`` sink;
  peak transient memory is one ``[m, tile_cols]`` tile).
* ``("jax", "stream")`` — the device-resident stream (``core.jax_stream``,
  DESIGN.md §10): a jitted, differentiable pure-JAX replay of the same
  contraction; one device dispatch per execution.
* ``("pallas", "fused")`` / ``("jax", "fused")`` — the fused stream kernel
  (``core.pallas_stream``, DESIGN.md §11): the plan's whole numeric phase
  as *one* Pallas launch (gather → multiply → segmented accumulate inside
  the kernel), differentiable through the same shared ``custom_vjp``
  machinery as the jax stream.  Both backends dispatch to the same pair —
  the fused kernel is the meeting point of the two device contracts.

``execute_batched(plan, a_vals [B, nnz], b_vals [B, nnz])`` is the batched
numeric phase (DESIGN.md §7): B same-pattern multiplies through *one*
traversal of the plan (Pallas: each group launches once with a leading
batch axis; jax: one vmapped dispatch; host: vectorized value-axis passes
where available).  Results are bit-identical to a Python loop of
``execute`` per backend engine.
"""

from __future__ import annotations

import numpy as np

from repro.core import backends, fast, jax_stream, naive, pallas_stream
from repro.core.backends import check_engine, default_engine, get_backend
from repro.core.expand import spgemm_expand
from repro.core.planner import SpgemmPlan
from repro.sparse.format import (
    CSC,
    BatchedCSCBuilder,
    CSCBuilder,
    padded_values,
    padded_values_batched,
)
from repro.sparse.partition import csc_empty, csc_hstack, merge_csc_partials

# filled below: host methods whose *naive-engine* batched path is vectorized
# over the value axis (accumulation structure is pattern-only); the stream
# engine is always vectorized and every other naive executor loops
_BATCHED_HOST: dict = {}

# union of every backend's accepted engine= spellings (back-compat alias)
ENGINES = backends.engine_spellings()

# (backend, resolved engine) -> (execute_fn, execute_batched_fn); the
# executor half of the backend registry.  Uniform signature:
# fn(plan, a_values, b_values, *, interpret, stats, validate)
_DISPATCH: dict = {}


def register_executor(backend: str, engine: str, fn, fn_batched) -> None:
    _DISPATCH[(backend, engine)] = (fn, fn_batched)


def resolve_engine(plan, engine: str | None) -> str:
    """The engine an execution will run: explicit choice or the default.

    Consults the plan backend's contract (``core.backends``): unknown
    spellings and engines the backend does not implement raise there
    (e.g. ``"stream"`` needs a stream-capable plan, and the jax backend
    has no ``"naive"`` oracles).  ``None`` resolves to the contract's
    default for the plan's method: host defaults to the bit-exact naive
    oracles except for ``expand`` (whose naive executor computes the same
    contraction as the stream, slower); jax always runs its device stream.
    """
    contract = get_backend(plan.backend)
    check_engine(contract, engine)
    if engine is None:
        return default_engine(contract, plan.method)
    return engine


def _check_engine(plan, engine: str | None) -> None:
    """Engine-argument validation shared by the untiled and tiled paths."""
    check_engine(get_backend(plan.backend), engine)


def execute(plan: SpgemmPlan, a_values, b_values, *,
            interpret: bool = True, stats: dict | None = None,
            validate: str | None = None,
            engine: str | None = None) -> CSC:
    """C = A @ B for new numeric values on the plan's sparsity patterns.

    ``a_values``/``b_values``: CSC matrices or raw nnz-length value arrays.
    Shapes and nnz are checked against the planned patterns (O(1)); a
    same-shape same-nnz operand with a different pattern is by default the
    caller's responsibility — pass ``validate="fingerprint"`` to re-hash the
    operand structure (O(nnz)) and reject any pattern mismatch (honoured by
    every engine, including the stream and jax paths).  ``engine`` selects
    the numeric engine (see :func:`resolve_engine`).  ``stats``, if given,
    is filled with execution statistics (engine, tile shapes, launch
    count) — tests use it to assert the no-dense-intermediate guarantee.
    """
    eng = resolve_engine(plan, engine)
    fn, _ = _DISPATCH[(plan.backend, eng)]
    return fn(plan, a_values, b_values, interpret=interpret, stats=stats,
              validate=validate)


def execute_batched(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool = True, stats: dict | None = None,
                    validate: str | None = None,
                    engine: str | None = None) -> list:
    """B same-pattern multiplies through one execution of the plan.

    ``a_values``/``b_values``: :class:`~repro.sparse.format.BatchedCSC`
    operands or raw ``[B, nnz]`` value stacks (row b = value set b, aligned
    with the planned pattern).  Returns a list of B CSC results,
    bit-identical to ``[plan.execute(a_values[b], b_values[b]) ...]``.

    Pallas backend: every plan group launches once for all B value sets (a
    vmapped leading batch axis), so the launch count is independent of B and
    peak transient memory is one ``[B, m, tile_cols]`` tile.  Jax backend:
    one vmapped device dispatch.  Host backend: the stream engine
    broadcasts its gather/segment-reduce pass over the value axis, naive
    SPA runs one vectorized pass, and the remaining naive executors
    (SPARS/HASH/hybrids/ESC) fall back to a per-element loop
    (DESIGN.md §7/§9/§10).  ``engine``/``validate`` behave exactly as in
    :func:`execute`.
    """
    eng = resolve_engine(plan, engine)
    _, fn = _DISPATCH[(plan.backend, eng)]
    return fn(plan, a_values, b_values, interpret=interpret, stats=stats,
              validate=validate)


def _check_batch(av, bv) -> int:
    if av.shape[0] != bv.shape[0]:
        raise ValueError(
            f"batch mismatch: A has {av.shape[0]} value sets, "
            f"B has {bv.shape[0]}")
    batch = int(av.shape[0])
    if batch == 0:
        raise ValueError("empty batch")
    return batch


# ---------------------------------------------------------------------------
# host executors (naive oracles + the product stream)
# ---------------------------------------------------------------------------


def _host_naive(plan, a_values, b_values, *, interpret=True, stats=None,
                validate=None) -> CSC:
    del interpret
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    if stats is not None:
        stats["engine"] = "naive"
    return _execute_host(plan, a_values, b_values)


def _host_stream(plan, a_values, b_values, *, interpret=True, stats=None,
                 validate=None) -> CSC:
    del interpret
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    return fast.execute_stream(plan, _values(a_values), _values(b_values),
                               stats=stats)


def _host_naive_batched(plan, a_values, b_values, *, interpret=True,
                        stats=None, validate=None) -> list:
    del interpret
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    batch = _check_batch(av, bv)
    vectorized = _BATCHED_HOST.get(plan.method)
    if vectorized is not None:
        out = vectorized(plan, av, bv)
    else:
        out = [_execute_host(plan, av[b], bv[b]) for b in range(batch)]
    if stats is not None:
        stats["engine"] = "naive"
        stats["batch"] = batch
        stats["path"] = "vectorized" if vectorized is not None else "loop"
    return out


def _host_stream_batched(plan, a_values, b_values, *, interpret=True,
                         stats=None, validate=None) -> list:
    del interpret
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    batch = _check_batch(av, bv)
    # fast.py reports stats["path"]: "vectorized" (2-D passes) or
    # "rowloop" (per-row 1-D passes on long streams)
    out = fast.execute_stream_batched(plan, av, bv, stats=stats)
    if stats is not None:
        stats["batch"] = batch
    return out


register_executor("host", "naive", _host_naive, _host_naive_batched)
register_executor("host", "stream", _host_stream, _host_stream_batched)
register_executor("jax", "stream", jax_stream.execute_jax,
                  jax_stream.execute_jax_batched)
# one executor pair serves both device backends: the fused kernel runs the
# plan's product stream, which every stream-carrying contract exposes
register_executor("pallas", "fused", pallas_stream.execute_fused,
                  pallas_stream.execute_fused_batched)
register_executor("jax", "fused", pallas_stream.execute_fused,
                  pallas_stream.execute_fused_batched)


# ---------------------------------------------------------------------------
# tiled execution: per-tile plans + the merge/stitch reduction (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _tiled_dtype(plan, av, bv):
    return np.float32 if plan.backend == "pallas" \
        else np.result_type(av.dtype, bv.dtype)


def _tile_values(plan, tile, av, bv):
    """Slice the parent value arrays down to one tile (pattern-static)."""
    lo, hi = tile.a_vals
    return av[..., lo:hi], bv[..., tile.b_vals]


def _check_tile_engines(plan, engine) -> None:
    """An explicit engine must hold on *every* tile of the grid.

    A tile grid may mix backends (host tiles + "jax" device-stream tiles);
    silently downgrading a tile that lacks the requested engine would hand
    back e.g. f32 device results where ``engine="naive"`` promised the
    bit-exact f64 oracles — loud rejection instead (``engine=None`` runs
    each tile's per-method default).
    """
    if engine is None:
        return
    missing = sorted({t.plan.backend for t in plan.tiles
                      if engine not in t.plan.contract.engines})
    if missing:
        raise ValueError(
            f"engine={engine!r} is not available on every tile of this "
            f"grid (missing on {missing} tile backends); use engine=None "
            "for per-tile defaults, or restrict candidates= at plan time")


def _host_child(c: CSC) -> CSC:
    """Host view of a child tile result (jax tiles return device values;
    the merge/stitch reduction is a host pass)."""
    if isinstance(c.values, np.ndarray):
        return c
    return CSC(np.asarray(c.values), c.row_indices, c.col_ptr, c.shape)


def _merge_and_stitch(plan, per_block, dtype) -> CSC:
    """Reduce per-column-block partial lists into the final CSC.

    ``per_block[ni]`` holds the row-block partials of column block ``ni``
    in k-ascending order.  Each block merges (single partials pass through
    bit-identically), then the blocks stitch left-to-right.
    """
    m = plan.shape[0]
    blocks = []
    for ni, (j0, j1) in enumerate(zip(plan.n_bounds[:-1],
                                      plan.n_bounds[1:])):
        shape = (m, int(j1 - j0))
        parts = per_block[ni]
        if not parts:
            blocks.append(csc_empty(shape, dtype))
        else:
            blocks.append(merge_csc_partials(parts, shape, dtype=dtype))
    if not blocks:
        return csc_empty((m, 0), dtype)
    return csc_hstack(blocks, m)


def _record_tile_stats(plan, stats, child_stats):
    if stats is None:
        return
    stats["grid"] = plan.grid
    stats["tiles"] = [
        {"k": t.k, "n": t.n, "method": t.method} for t in plan.tiles]
    stats["methods"] = sorted({t.method for t in plan.tiles})
    stats["merged_blocks"] = len(
        {t.n for t in plan.tiles
         if sum(u.n == t.n for u in plan.tiles) > 1})
    stats["result_shape"] = plan.shape
    if child_stats:
        stats["n_launches"] = sum(
            s.get("n_launches", 0) for s in child_stats)
        stats["peak_tile_elems"] = max(
            (s.get("peak_tile_elems", 0) for s in child_stats), default=0)


def execute_tiled(plan, a_values, b_values, *, interpret: bool = True,
                  stats: dict | None = None,
                  validate: str | None = None,
                  engine: str | None = None) -> CSC:
    """Numeric phase of a :class:`~repro.core.planner.TiledSpgemmPlan`.

    Runs every tile's child plan on the tile's value slices, accumulates
    row-block partials per column block (k-ascending; a single row block is
    a bit-identical passthrough), and stitches the column blocks.
    ``engine`` is forwarded to every child plan and must be available on
    every tile's backend (:func:`_check_tile_engines` — a mixed host/jax
    grid accepts ``None``/``"stream"`` but rejects ``"naive"``, whose
    bit-exact promise the device tiles cannot keep); ``engine=None`` runs
    each tile's cost-model-chosen engine (``TilePlan.engine`` — the
    "fused" auto candidate sets it) falling back to the method default.
    ``stats`` records
    the grid, the per-tile method choices, and — on the Pallas backend —
    the aggregated launch count and peak transient tile size.
    """
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    _check_engine(plan, engine)
    _check_tile_engines(plan, engine)
    av = _values(a_values)[: int(plan.a.col_ptr[-1])]
    bv = _values(b_values)[: int(plan.b.col_ptr[-1])]
    dtype = _tiled_dtype(plan, av, bv)
    per_block = {ni: [] for ni in range(plan.grid[1])}
    child_stats = []
    for tile in plan.tiles:
        ta, tb = _tile_values(plan, tile, av, bv)
        cs = {} if (stats is not None
                    and plan.backend == "pallas") else None
        per_block[tile.n].append(_host_child(
            tile.plan.execute(ta, tb, interpret=interpret, stats=cs,
                              engine=engine if engine is not None
                              else tile.engine)))
        if cs is not None:
            child_stats.append(cs)
    _record_tile_stats(plan, stats, child_stats)
    return _merge_and_stitch(plan, per_block, dtype)


def execute_tiled_batched(plan, a_values, b_values, *,
                          interpret: bool = True,
                          stats: dict | None = None,
                          validate: str | None = None,
                          engine: str | None = None) -> list:
    """Batched tiled execution: B value sets through one plan traversal.

    Each tile's child plan executes batched (one launch set per tile,
    independent of B on the Pallas backend); the merge/stitch reduction
    then runs per batch element, bit-identical to looping
    :func:`execute_tiled`.  ``engine`` forwards per tile exactly as in
    :func:`execute_tiled`.
    """
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    batch = _check_batch(av, bv)
    _check_engine(plan, engine)
    _check_tile_engines(plan, engine)
    dtype = _tiled_dtype(plan, av, bv)
    per_block = [{ni: [] for ni in range(plan.grid[1])}
                 for _ in range(batch)]
    child_stats = []
    for tile in plan.tiles:
        ta, tb = _tile_values(plan, tile, av, bv)
        cs = {} if (stats is not None
                    and plan.backend == "pallas") else None
        outs = tile.plan.execute_batched(
            ta, tb, interpret=interpret, stats=cs,
            engine=engine if engine is not None else tile.engine)
        for bi, c in enumerate(outs):
            per_block[bi][tile.n].append(_host_child(c))
        if cs is not None:
            child_stats.append(cs)
    _record_tile_stats(plan, stats, child_stats)
    if stats is not None:
        stats["batch"] = batch
    return [_merge_and_stitch(plan, per_block[bi], dtype)
            for bi in range(batch)]


def _execute_host(plan: SpgemmPlan, a_values, b_values) -> CSC:
    a = plan.a.with_values(a_values)
    b = plan.b.with_values(b_values)
    method = plan.method
    params = dict(plan.params)
    if method == "spa":
        return naive.spa_numpy(a, b)
    if method == "expand":
        return spgemm_expand(a, b)
    if method == "esc":
        return naive.esc_numpy(a, b)
    if method.startswith("spars"):
        return naive.spars_numpy(a, b, plan.pre)
    if method.startswith("hash"):
        return naive.hash_numpy(a, b, plan.pre)
    if method.startswith("h-"):
        return naive.hybrid_numpy(
            a, b, t=params["t"], b_min=params["b_min"],
            b_max=params["b_max"], accumulator=params["accumulator"],
            pre=plan.pre,
        )
    raise AssertionError(method)


# ---------------------------------------------------------------------------
# vectorized host batched executors (value axis only; structure is
# pattern-only, so every op below repeats naive.py's accumulation order
# element-wise across the batch — bit-identical per element)
# ---------------------------------------------------------------------------


def _spa_host_batched(plan: SpgemmPlan, av: np.ndarray,
                      bv: np.ndarray) -> list:
    """Batched ``naive.spa_numpy``: one pass, SPA arrays carry [B, m]."""
    a_cp, a_rows = plan.a.col_ptr, plan.a.row_indices
    b_cp, b_rows = plan.b.col_ptr, plan.b.row_indices
    m, n = plan.shape
    batch = av.shape[0]
    dtype = np.result_type(av.dtype, bv.dtype)

    spa_values = np.zeros((batch, m), dtype)
    spa_flags = np.zeros(m, bool)       # pattern-only: shared by the batch

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros((batch, 0), dtype)] * n
    for j in range(n):
        touched = []
        for p in range(b_cp[j], b_cp[j + 1]):
            k = b_rows[p]
            sl = slice(a_cp[k], a_cp[k + 1])
            ar = a_rows[sl]
            spa_values[:, ar] += av[:, sl] * bv[:, p, None]
            new = ar[~spa_flags[ar]]
            spa_flags[new] = True
            if len(new):
                touched.append(new)
        idx = np.concatenate(touched) if touched else np.zeros(0, np.int32)
        out_rows[j] = idx.astype(np.int32)
        out_vals[j] = spa_values[:, idx].astype(dtype)
        spa_values[:, idx] = 0
        spa_flags[idx] = False
    return _assemble_batched(batch, out_rows, out_vals, (m, n), dtype)


# the batched expand fast path lives in core/fast.py now: expand's default
# engine is the product stream, whose batched execution is a broadcast of
# the same gather/segment-reduce pass (no per-row np.add.at loop)
_BATCHED_HOST.update(spa=_spa_host_batched)
VECTORIZED_HOST = tuple(_BATCHED_HOST)


def _assemble_batched(batch, cols_rows, cols_vals, shape, dtype) -> list:
    """Batched ``naive._assemble``: per-column [B, cnt] value slabs."""
    n = shape[1]
    col_ptr = np.zeros(n + 1, np.int32)
    np.cumsum([len(r) for r in cols_rows], out=col_ptr[1:])
    if col_ptr[-1]:
        rows = np.concatenate(cols_rows).astype(np.int32)
        vals = np.concatenate(cols_vals, axis=1)
    else:
        rows = np.zeros(0, np.int32)
        vals = np.zeros((batch, 0), dtype)
    return [CSC(vals[b], rows, col_ptr, shape) for b in range(batch)]


# ---------------------------------------------------------------------------
# Pallas paths
# ---------------------------------------------------------------------------


def _execute_pallas(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool = True, stats: dict | None = None,
                    validate: str | None = None) -> CSC:
    from repro.kernels import ops as kops

    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    lay = plan.pallas
    m, n = plan.shape
    av = padded_values(_values(a_values), lay.a_gather,
                       lay.a_mask).astype(np.float32, copy=False)
    b_raw = _values(b_values)
    a_arrs = kops.device_operand(lay.a_rows, av, lay.a_nnz)

    builder = CSCBuilder((m, n), np.float32)
    for g in lay.groups:
        # plan-time-composed masked gather: straight from raw values to the
        # group operand, no full padded-B intermediate or per-call mask
        g_vals = padded_values(b_raw, g.b_vgather,
                               g.b_vmask).astype(np.float32, copy=False)
        if g.kind == "spa":
            tile = kops.run_spa(g, a_arrs, g_vals, m=m,
                                block_cols=lay.block_cols,
                                interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "spars":
            tile = kops.run_spars(g, a_arrs, g_vals, m=m,
                                  block_cols=lay.block_cols,
                                  interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "hash":
            keys, vals = kops.run_hash(g, a_arrs, g_vals, m=m,
                                       block_cols=lay.block_cols,
                                       interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    c = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)
        stats["result_shape"] = (m, n)
    return c


def _execute_pallas_batched(plan: SpgemmPlan, a_values, b_values, *,
                            interpret: bool = True,
                            stats: dict | None = None,
                            validate: str | None = None) -> list:
    from repro.kernels import ops as kops

    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    batch = _check_batch(av, bv)
    lay = plan.pallas
    m, n = plan.shape
    avp = padded_values_batched(av, lay.a_gather,
                                lay.a_mask).astype(np.float32, copy=False)
    a_arrs = kops.device_operand(lay.a_rows, avp, lay.a_nnz)

    builder = BatchedCSCBuilder(batch, (m, n), np.float32)
    for g in lay.groups:
        g_vals = padded_values_batched(bv, g.b_vgather,
                                       g.b_vmask).astype(np.float32,
                                                         copy=False)
        if g.kind == "spa":
            tiles = kops.run_spa_batched(g, a_arrs, g_vals, m=m,
                                         block_cols=lay.block_cols,
                                         interpret=interpret)
            builder.add_dense_tile(g.cols, tiles)
        elif g.kind == "spars":
            tiles = kops.run_spars_batched(g, a_arrs, g_vals, m=m,
                                           block_cols=lay.block_cols,
                                           interpret=interpret)
            builder.add_dense_tile(g.cols, tiles)
        elif g.kind == "hash":
            keys, vals = kops.run_hash_batched(g, a_arrs, g_vals, m=m,
                                               block_cols=lay.block_cols,
                                               interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    out = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)   # independent of the batch
        stats["result_shape"] = (m, n)
        stats["batch"] = batch
    return out


register_executor("pallas", "naive", _execute_pallas,
                  _execute_pallas_batched)


def _values(x) -> np.ndarray:
    return np.asarray(x.values) if isinstance(x, CSC) else np.asarray(x)

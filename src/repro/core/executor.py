"""Numeric SpGEMM execution of a cached symbolic plan (DESIGN.md §6–§7).

``execute(plan, a_values, b_values)`` runs only the value-dependent work of
C = A @ B; every pattern-dependent decision (sorting, blocking, hash sizing,
padded layouts, kernel groups) was made once by ``core.planner.plan_spgemm``.

Host backend: binds the values to the planned patterns and dispatches to the
faithful numpy executors, passing the plan's pre-computed ``Preprocess`` so
nothing is re-analyzed.  Pallas backend: re-pads the values with the plan's
gather indices (one vectorized gather per operand), launches one kernel per
plan group via ``kernels.ops.run_{spa,spars,hash}``, and compacts each
group's accumulator tile / hash tables straight into column-sliced CSC
through ``sparse.format.CSCBuilder`` — the dense ``[m, n]`` sink of the
pre-plan backend no longer exists; peak transient memory is one
``[m, tile_cols]`` tile.

``execute_batched(plan, a_vals [B, nnz], b_vals [B, nnz])`` is the batched
numeric phase (DESIGN.md §7): B same-pattern multiplies through *one* set of
kernel launches (Pallas: each plan group launches once with a leading batch
axis) or one vectorized numpy pass over the value axis (host SPA / expand,
whose accumulation structure is pattern-only; the remaining host executors
fall back to a per-element loop).  Results are bit-identical to a Python
loop of ``execute``.
"""

from __future__ import annotations

import numpy as np

from repro.core import naive
from repro.core.expand import spgemm_expand
from repro.core.planner import SpgemmPlan
from repro.sparse.format import (
    CSC,
    BatchedCSCBuilder,
    CSCBuilder,
    padded_values,
    padded_values_batched,
)
from repro.sparse.partition import csc_empty, csc_hstack, merge_csc_partials

# filled below: host methods whose batched path is vectorized over the value
# axis (their accumulation structure is pattern-only); everything else loops
_BATCHED_HOST: dict = {}


def execute(plan: SpgemmPlan, a_values, b_values, *,
            interpret: bool = True, stats: dict | None = None,
            validate: str | None = None) -> CSC:
    """C = A @ B for new numeric values on the plan's sparsity patterns.

    ``a_values``/``b_values``: CSC matrices or raw nnz-length value arrays.
    Shapes and nnz are checked against the planned patterns (O(1)); a
    same-shape same-nnz operand with a different pattern is by default the
    caller's responsibility — pass ``validate="fingerprint"`` to re-hash the
    operand structure (O(nnz)) and reject any pattern mismatch.  ``stats``,
    if given, is filled with execution statistics (tile shapes, launch
    count) — tests use it to assert the no-dense-intermediate guarantee.
    """
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    if plan.backend == "host":
        return _execute_host(plan, a_values, b_values)
    return _execute_pallas(plan, a_values, b_values, interpret=interpret,
                           stats=stats)


def execute_batched(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool = True, stats: dict | None = None,
                    validate: str | None = None) -> list:
    """B same-pattern multiplies through one execution of the plan.

    ``a_values``/``b_values``: :class:`~repro.sparse.format.BatchedCSC`
    operands or raw ``[B, nnz]`` value stacks (row b = value set b, aligned
    with the planned pattern).  Returns a list of B CSC results,
    bit-identical to ``[plan.execute(a_values[b], b_values[b]) ...]``.

    Pallas backend: every plan group launches once for all B value sets (a
    vmapped leading batch axis), so the launch count is independent of B and
    peak transient memory is one ``[B, m, tile_cols]`` tile.  Host backend:
    SPA and expand run one vectorized numpy pass over the value axis; the
    lock-step executors (SPARS/HASH/hybrids/ESC) fall back to a per-element
    loop (DESIGN.md §7).
    """
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    if av.shape[0] != bv.shape[0]:
        raise ValueError(
            f"batch mismatch: A has {av.shape[0]} value sets, "
            f"B has {bv.shape[0]}")
    batch = av.shape[0]
    if batch == 0:
        raise ValueError("empty batch")
    if plan.backend == "host":
        vectorized = _BATCHED_HOST.get(plan.method)
        if vectorized is not None:
            out = vectorized(plan, av, bv)
        else:
            out = [_execute_host(plan, av[b], bv[b]) for b in range(batch)]
        if stats is not None:
            stats["batch"] = batch
            stats["path"] = "vectorized" if vectorized is not None else "loop"
        return out
    return _execute_pallas_batched(plan, av, bv, interpret=interpret,
                                   stats=stats)


# ---------------------------------------------------------------------------
# tiled execution: per-tile plans + the merge/stitch reduction (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _tiled_dtype(plan, av, bv):
    return np.float32 if plan.backend == "pallas" \
        else np.result_type(av.dtype, bv.dtype)


def _tile_values(plan, tile, av, bv):
    """Slice the parent value arrays down to one tile (pattern-static)."""
    lo, hi = tile.a_vals
    return av[..., lo:hi], bv[..., tile.b_vals]


def _merge_and_stitch(plan, per_block, dtype) -> CSC:
    """Reduce per-column-block partial lists into the final CSC.

    ``per_block[ni]`` holds the row-block partials of column block ``ni``
    in k-ascending order.  Each block merges (single partials pass through
    bit-identically), then the blocks stitch left-to-right.
    """
    m = plan.shape[0]
    blocks = []
    for ni, (j0, j1) in enumerate(zip(plan.n_bounds[:-1],
                                      plan.n_bounds[1:])):
        shape = (m, int(j1 - j0))
        parts = per_block[ni]
        if not parts:
            blocks.append(csc_empty(shape, dtype))
        else:
            blocks.append(merge_csc_partials(parts, shape, dtype=dtype))
    if not blocks:
        return csc_empty((m, 0), dtype)
    return csc_hstack(blocks, m)


def _record_tile_stats(plan, stats, child_stats):
    if stats is None:
        return
    stats["grid"] = plan.grid
    stats["tiles"] = [
        {"k": t.k, "n": t.n, "method": t.method} for t in plan.tiles]
    stats["methods"] = sorted({t.method for t in plan.tiles})
    stats["merged_blocks"] = len(
        {t.n for t in plan.tiles
         if sum(u.n == t.n for u in plan.tiles) > 1})
    stats["result_shape"] = plan.shape
    if child_stats:
        stats["n_launches"] = sum(
            s.get("n_launches", 0) for s in child_stats)
        stats["peak_tile_elems"] = max(
            (s.get("peak_tile_elems", 0) for s in child_stats), default=0)


def execute_tiled(plan, a_values, b_values, *, interpret: bool = True,
                  stats: dict | None = None,
                  validate: str | None = None) -> CSC:
    """Numeric phase of a :class:`~repro.core.planner.TiledSpgemmPlan`.

    Runs every tile's child plan on the tile's value slices, accumulates
    row-block partials per column block (k-ascending; a single row block is
    a bit-identical passthrough), and stitches the column blocks.  ``stats``
    records the grid, the per-tile method choices, and — on the Pallas
    backend — the aggregated launch count and peak transient tile size.
    """
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    av = _values(a_values)[: int(plan.a.col_ptr[-1])]
    bv = _values(b_values)[: int(plan.b.col_ptr[-1])]
    dtype = _tiled_dtype(plan, av, bv)
    per_block = {ni: [] for ni in range(plan.grid[1])}
    child_stats = []
    for tile in plan.tiles:
        ta, tb = _tile_values(plan, tile, av, bv)
        cs = {} if (stats is not None
                    and plan.backend == "pallas") else None
        per_block[tile.n].append(
            tile.plan.execute(ta, tb, interpret=interpret, stats=cs))
        if cs is not None:
            child_stats.append(cs)
    _record_tile_stats(plan, stats, child_stats)
    return _merge_and_stitch(plan, per_block, dtype)


def execute_tiled_batched(plan, a_values, b_values, *,
                          interpret: bool = True,
                          stats: dict | None = None,
                          validate: str | None = None) -> list:
    """Batched tiled execution: B value sets through one plan traversal.

    Each tile's child plan executes batched (one launch set per tile,
    independent of B on the Pallas backend); the merge/stitch reduction
    then runs per batch element, bit-identical to looping
    :func:`execute_tiled`.
    """
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    if av.shape[0] != bv.shape[0]:
        raise ValueError(
            f"batch mismatch: A has {av.shape[0]} value sets, "
            f"B has {bv.shape[0]}")
    batch = av.shape[0]
    if batch == 0:
        raise ValueError("empty batch")
    dtype = _tiled_dtype(plan, av, bv)
    per_block = [{ni: [] for ni in range(plan.grid[1])}
                 for _ in range(batch)]
    child_stats = []
    for tile in plan.tiles:
        ta, tb = _tile_values(plan, tile, av, bv)
        cs = {} if (stats is not None
                    and plan.backend == "pallas") else None
        outs = tile.plan.execute_batched(ta, tb, interpret=interpret,
                                         stats=cs)
        for bi, c in enumerate(outs):
            per_block[bi][tile.n].append(c)
        if cs is not None:
            child_stats.append(cs)
    _record_tile_stats(plan, stats, child_stats)
    if stats is not None:
        stats["batch"] = batch
    return [_merge_and_stitch(plan, per_block[bi], dtype)
            for bi in range(batch)]


def _execute_host(plan: SpgemmPlan, a_values, b_values) -> CSC:
    a = plan.a.with_values(a_values)
    b = plan.b.with_values(b_values)
    method = plan.method
    params = dict(plan.params)
    if method == "spa":
        return naive.spa_numpy(a, b)
    if method == "expand":
        return spgemm_expand(a, b)
    if method == "esc":
        return naive.esc_numpy(a, b)
    if method.startswith("spars"):
        return naive.spars_numpy(a, b, plan.pre)
    if method.startswith("hash"):
        return naive.hash_numpy(a, b, plan.pre)
    if method.startswith("h-"):
        return naive.hybrid_numpy(
            a, b, t=params["t"], b_min=params["b_min"],
            b_max=params["b_max"], accumulator=params["accumulator"],
            pre=plan.pre,
        )
    raise AssertionError(method)


# ---------------------------------------------------------------------------
# vectorized host batched executors (value axis only; structure is
# pattern-only, so every op below repeats naive.py's accumulation order
# element-wise across the batch — bit-identical per element)
# ---------------------------------------------------------------------------


def _spa_host_batched(plan: SpgemmPlan, av: np.ndarray,
                      bv: np.ndarray) -> list:
    """Batched ``naive.spa_numpy``: one pass, SPA arrays carry [B, m]."""
    a_cp, a_rows = plan.a.col_ptr, plan.a.row_indices
    b_cp, b_rows = plan.b.col_ptr, plan.b.row_indices
    m, n = plan.shape
    batch = av.shape[0]
    dtype = np.result_type(av.dtype, bv.dtype)

    spa_values = np.zeros((batch, m), dtype)
    spa_flags = np.zeros(m, bool)       # pattern-only: shared by the batch

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros((batch, 0), dtype)] * n
    for j in range(n):
        touched = []
        for p in range(b_cp[j], b_cp[j + 1]):
            k = b_rows[p]
            sl = slice(a_cp[k], a_cp[k + 1])
            ar = a_rows[sl]
            spa_values[:, ar] += av[:, sl] * bv[:, p, None]
            new = ar[~spa_flags[ar]]
            spa_flags[new] = True
            if len(new):
                touched.append(new)
        idx = np.concatenate(touched) if touched else np.zeros(0, np.int32)
        out_rows[j] = idx.astype(np.int32)
        out_vals[j] = spa_values[:, idx].astype(dtype)
        spa_values[:, idx] = 0
        spa_flags[idx] = False
    return _assemble_batched(batch, out_rows, out_vals, (m, n), dtype)


def _expand_host_batched(plan: SpgemmPlan, av: np.ndarray,
                         bv: np.ndarray) -> list:
    """Batched ``core.expand.spgemm_expand``: the product stream's positions
    and the compress structure (sort order, duplicate groups, col_ptr) are
    pattern-only and computed once; only the [B, n_products] value stream and
    the per-group sums are per-element."""
    a_cp = plan.a.col_ptr.astype(np.int64)
    a_rows = plan.a.row_indices
    b_cp = plan.b.col_ptr.astype(np.int64)
    b_rows = plan.b.row_indices
    m, n = plan.shape
    batch = av.shape[0]

    seg_starts = a_cp[b_rows]
    seg_lens = (a_cp[b_rows + 1] - seg_starts).astype(np.int64)
    total = int(seg_lens.sum())
    if total == 0:
        empty = CSC(np.zeros(0, av.dtype), np.zeros(0, np.int32),
                    np.zeros(n + 1, np.int32), (m, n))
        return [empty] * batch
    stream_starts = np.concatenate(([0], np.cumsum(seg_lens)[:-1]))
    apos = np.arange(total, dtype=np.int64) + np.repeat(
        seg_starts - stream_starts, seg_lens)
    rows = a_rows[apos].astype(np.int64)
    cols = np.repeat(
        np.repeat(np.arange(n, dtype=np.int64), np.diff(b_cp)), seg_lens)
    vals = av[:, apos] * np.repeat(bv, seg_lens, axis=1)   # [B, total]

    # compress exactly as csc_from_coo(sum_duplicates=True) does
    order = np.lexsort((rows, cols))
    rows, cols, vals = rows[order], cols[order], vals[:, order]
    key = cols * m + rows
    uniq, inv = np.unique(key, return_inverse=True)
    acc = np.zeros((batch, len(uniq)), vals.dtype)
    for b in range(batch):                 # np.add.at per row, same op order
        np.add.at(acc[b], inv, vals[b])
    u_cols = (uniq // m).astype(np.int64)
    u_rows = (uniq % m).astype(np.int32)
    col_ptr = np.zeros(n + 1, np.int32)
    np.add.at(col_ptr[1:], u_cols, 1)
    np.cumsum(col_ptr, out=col_ptr)
    return [CSC(acc[b], u_rows, col_ptr, (m, n)) for b in range(batch)]


_BATCHED_HOST.update(spa=_spa_host_batched, expand=_expand_host_batched)
VECTORIZED_HOST = tuple(_BATCHED_HOST)


def _assemble_batched(batch, cols_rows, cols_vals, shape, dtype) -> list:
    """Batched ``naive._assemble``: per-column [B, cnt] value slabs."""
    n = shape[1]
    col_ptr = np.zeros(n + 1, np.int32)
    for j in range(n):
        col_ptr[j + 1] = col_ptr[j] + len(cols_rows[j])
    if col_ptr[-1]:
        rows = np.concatenate(cols_rows).astype(np.int32)
        vals = np.concatenate(cols_vals, axis=1)
    else:
        rows = np.zeros(0, np.int32)
        vals = np.zeros((batch, 0), dtype)
    return [CSC(vals[b], rows, col_ptr, shape) for b in range(batch)]


# ---------------------------------------------------------------------------
# Pallas paths
# ---------------------------------------------------------------------------


def _execute_pallas(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool, stats: dict | None) -> CSC:
    from repro.kernels import ops as kops

    lay = plan.pallas
    m, n = plan.shape
    av = padded_values(_values(a_values), lay.a_gather,
                       lay.a_mask).astype(np.float32, copy=False)
    bv = padded_values(_values(b_values), lay.b_gather,
                       lay.b_mask).astype(np.float32, copy=False)
    a_arrs = kops.device_operand(lay.a_rows, av, lay.a_nnz)

    builder = CSCBuilder((m, n), np.float32)
    for g in lay.groups:
        g_vals = np.where(g.valid[:, None], bv[g.sel], np.float32(0))
        if g.kind == "spa":
            tile = kops.run_spa(g, a_arrs, g_vals, m=m,
                                block_cols=lay.block_cols,
                                interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "spars":
            tile = kops.run_spars(g, a_arrs, g_vals, m=m,
                                  block_cols=lay.block_cols,
                                  interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "hash":
            keys, vals = kops.run_hash(g, a_arrs, g_vals, m=m,
                                       block_cols=lay.block_cols,
                                       interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    c = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)
        stats["result_shape"] = (m, n)
    return c


def _execute_pallas_batched(plan: SpgemmPlan, av: np.ndarray,
                            bv: np.ndarray, *, interpret: bool,
                            stats: dict | None) -> list:
    from repro.kernels import ops as kops

    lay = plan.pallas
    m, n = plan.shape
    batch = av.shape[0]
    avp = padded_values_batched(av, lay.a_gather,
                                lay.a_mask).astype(np.float32, copy=False)
    bvp = padded_values_batched(bv, lay.b_gather,
                                lay.b_mask).astype(np.float32, copy=False)
    a_arrs = kops.device_operand(lay.a_rows, avp, lay.a_nnz)

    builder = BatchedCSCBuilder(batch, (m, n), np.float32)
    for g in lay.groups:
        g_vals = np.where(g.valid[None, :, None], bvp[:, g.sel],
                          np.float32(0))
        if g.kind == "spa":
            tiles = kops.run_spa_batched(g, a_arrs, g_vals, m=m,
                                         block_cols=lay.block_cols,
                                         interpret=interpret)
            builder.add_dense_tile(g.cols, tiles)
        elif g.kind == "spars":
            tiles = kops.run_spars_batched(g, a_arrs, g_vals, m=m,
                                           block_cols=lay.block_cols,
                                           interpret=interpret)
            builder.add_dense_tile(g.cols, tiles)
        elif g.kind == "hash":
            keys, vals = kops.run_hash_batched(g, a_arrs, g_vals, m=m,
                                               block_cols=lay.block_cols,
                                               interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    out = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)   # independent of the batch
        stats["result_shape"] = (m, n)
        stats["batch"] = batch
    return out


def _values(x) -> np.ndarray:
    return np.asarray(x.values) if isinstance(x, CSC) else np.asarray(x)

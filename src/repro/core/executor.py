"""Numeric SpGEMM execution of a cached symbolic plan (DESIGN.md §6–§9).

``execute(plan, a_values, b_values)`` runs only the value-dependent work of
C = A @ B; every pattern-dependent decision (sorting, blocking, hash sizing,
padded layouts, kernel groups, the product stream) was made once by
``core.planner.plan_spgemm``.

Host backend — two engines, selected by ``engine=``:

* ``"naive"`` — binds the values to the planned patterns and dispatches to
  the faithful numpy executors, passing the plan's pre-computed
  ``Preprocess`` so nothing is re-analyzed.  These are the bit-exact
  oracles of the paper's algorithms.
* ``"stream"`` — replays the plan's precomputed product stream
  (``core.fast``, DESIGN.md §9): one vectorized gather → multiply →
  segment-reduce pass, no per-column Python loop.  Canonical output order,
  last-ulp fp-reassociation vs the oracles.  Default for ``expand`` (whose
  naive executor computes the same contraction in the same order, slower);
  opt-in for every other host method.

Pallas backend: gathers each group's padded value operand with the plan's
precomputed ``b_vgather``/``b_vmask`` (one fused masked gather per launch —
no full padded-B intermediate, no per-call ``np.where`` mask allocation),
launches one kernel per plan group via ``kernels.ops.run_{spa,spars,hash}``,
and compacts each group's accumulator tile / hash tables straight into
column-sliced CSC through ``sparse.format.CSCBuilder`` — the dense
``[m, n]`` sink of the pre-plan backend no longer exists; peak transient
memory is one ``[m, tile_cols]`` tile.

``execute_batched(plan, a_vals [B, nnz], b_vals [B, nnz])`` is the batched
numeric phase (DESIGN.md §7): B same-pattern multiplies through *one* set of
kernel launches (Pallas: each plan group launches once with a leading batch
axis) or one vectorized numpy pass over the value axis (the stream engine
and host SPA; the remaining naive host executors fall back to a per-element
loop).  Results are bit-identical to a Python loop of ``execute``.
"""

from __future__ import annotations

import numpy as np

from repro.core import fast, naive
from repro.core.expand import spgemm_expand
from repro.core.planner import SpgemmPlan
from repro.sparse.format import (
    CSC,
    BatchedCSCBuilder,
    CSCBuilder,
    padded_values,
    padded_values_batched,
)
from repro.sparse.partition import csc_empty, csc_hstack, merge_csc_partials

# filled below: host methods whose *naive-engine* batched path is vectorized
# over the value axis (accumulation structure is pattern-only); the stream
# engine is always vectorized and every other naive executor loops
_BATCHED_HOST: dict = {}

ENGINES = (None, "naive", "stream")


def resolve_engine(plan, engine: str | None) -> str:
    """The engine an execution will run: explicit choice or the default.

    ``None`` resolves to the method's default: ``"stream"`` for host
    ``expand`` — the stream computes the same canonical contraction
    (identical structure; values agree to ``np.add.reduceat``'s possible
    within-segment re-association, see ``core.fast``) — and ``"naive"``
    for every other method, so the oracle executors stay the bit-exact
    reference.  ``"stream"`` is a host-backend engine; requesting it on a
    Pallas plan raises.
    """
    _check_engine(plan, engine)
    if plan.backend != "host":
        return "naive"
    if engine is None:
        return "stream" if plan.method == "expand" else "naive"
    return engine


def _check_engine(plan, engine: str | None) -> None:
    """Engine-argument validation shared by the untiled and tiled paths."""
    if engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; one of None, 'naive', 'stream'")
    if engine == "stream" and plan.backend != "host":
        raise ValueError(
            "engine='stream' is a host-backend engine (Pallas plans "
            "run their own kernel schedule)")


def execute(plan: SpgemmPlan, a_values, b_values, *,
            interpret: bool = True, stats: dict | None = None,
            validate: str | None = None,
            engine: str | None = None) -> CSC:
    """C = A @ B for new numeric values on the plan's sparsity patterns.

    ``a_values``/``b_values``: CSC matrices or raw nnz-length value arrays.
    Shapes and nnz are checked against the planned patterns (O(1)); a
    same-shape same-nnz operand with a different pattern is by default the
    caller's responsibility — pass ``validate="fingerprint"`` to re-hash the
    operand structure (O(nnz)) and reject any pattern mismatch.  ``engine``
    selects the host numeric engine (see :func:`resolve_engine`).
    ``stats``, if given, is filled with execution statistics (engine, tile
    shapes, launch count) — tests use it to assert the
    no-dense-intermediate guarantee.
    """
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    eng = resolve_engine(plan, engine)
    if plan.backend == "host":
        if eng == "stream":
            return fast.execute_stream(plan, _values(a_values),
                                       _values(b_values), stats=stats)
        if stats is not None:
            stats["engine"] = "naive"
        return _execute_host(plan, a_values, b_values)
    return _execute_pallas(plan, a_values, b_values, interpret=interpret,
                           stats=stats)


def execute_batched(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool = True, stats: dict | None = None,
                    validate: str | None = None,
                    engine: str | None = None) -> list:
    """B same-pattern multiplies through one execution of the plan.

    ``a_values``/``b_values``: :class:`~repro.sparse.format.BatchedCSC`
    operands or raw ``[B, nnz]`` value stacks (row b = value set b, aligned
    with the planned pattern).  Returns a list of B CSC results,
    bit-identical to ``[plan.execute(a_values[b], b_values[b]) ...]``.

    Pallas backend: every plan group launches once for all B value sets (a
    vmapped leading batch axis), so the launch count is independent of B and
    peak transient memory is one ``[B, m, tile_cols]`` tile.  Host backend:
    the stream engine broadcasts its gather/segment-reduce pass over the
    value axis, naive SPA runs one vectorized pass, and the remaining naive
    executors (SPARS/HASH/hybrids/ESC) fall back to a per-element loop
    (DESIGN.md §7/§9).
    """
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    if av.shape[0] != bv.shape[0]:
        raise ValueError(
            f"batch mismatch: A has {av.shape[0]} value sets, "
            f"B has {bv.shape[0]}")
    batch = av.shape[0]
    if batch == 0:
        raise ValueError("empty batch")
    eng = resolve_engine(plan, engine)
    if plan.backend == "host":
        if eng == "stream":
            # fast.py reports stats["path"]: "vectorized" (2-D passes) or
            # "rowloop" (per-row 1-D passes on long streams)
            out = fast.execute_stream_batched(plan, av, bv, stats=stats)
            if stats is not None:
                stats["batch"] = batch
            return out
        vectorized = _BATCHED_HOST.get(plan.method)
        if vectorized is not None:
            out = vectorized(plan, av, bv)
        else:
            out = [_execute_host(plan, av[b], bv[b]) for b in range(batch)]
        if stats is not None:
            stats["engine"] = "naive"
            stats["batch"] = batch
            stats["path"] = "vectorized" if vectorized is not None else "loop"
        return out
    return _execute_pallas_batched(plan, av, bv, interpret=interpret,
                                   stats=stats)


# ---------------------------------------------------------------------------
# tiled execution: per-tile plans + the merge/stitch reduction (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _tiled_dtype(plan, av, bv):
    return np.float32 if plan.backend == "pallas" \
        else np.result_type(av.dtype, bv.dtype)


def _tile_values(plan, tile, av, bv):
    """Slice the parent value arrays down to one tile (pattern-static)."""
    lo, hi = tile.a_vals
    return av[..., lo:hi], bv[..., tile.b_vals]


def _merge_and_stitch(plan, per_block, dtype) -> CSC:
    """Reduce per-column-block partial lists into the final CSC.

    ``per_block[ni]`` holds the row-block partials of column block ``ni``
    in k-ascending order.  Each block merges (single partials pass through
    bit-identically), then the blocks stitch left-to-right.
    """
    m = plan.shape[0]
    blocks = []
    for ni, (j0, j1) in enumerate(zip(plan.n_bounds[:-1],
                                      plan.n_bounds[1:])):
        shape = (m, int(j1 - j0))
        parts = per_block[ni]
        if not parts:
            blocks.append(csc_empty(shape, dtype))
        else:
            blocks.append(merge_csc_partials(parts, shape, dtype=dtype))
    if not blocks:
        return csc_empty((m, 0), dtype)
    return csc_hstack(blocks, m)


def _record_tile_stats(plan, stats, child_stats):
    if stats is None:
        return
    stats["grid"] = plan.grid
    stats["tiles"] = [
        {"k": t.k, "n": t.n, "method": t.method} for t in plan.tiles]
    stats["methods"] = sorted({t.method for t in plan.tiles})
    stats["merged_blocks"] = len(
        {t.n for t in plan.tiles
         if sum(u.n == t.n for u in plan.tiles) > 1})
    stats["result_shape"] = plan.shape
    if child_stats:
        stats["n_launches"] = sum(
            s.get("n_launches", 0) for s in child_stats)
        stats["peak_tile_elems"] = max(
            (s.get("peak_tile_elems", 0) for s in child_stats), default=0)


def execute_tiled(plan, a_values, b_values, *, interpret: bool = True,
                  stats: dict | None = None,
                  validate: str | None = None,
                  engine: str | None = None) -> CSC:
    """Numeric phase of a :class:`~repro.core.planner.TiledSpgemmPlan`.

    Runs every tile's child plan on the tile's value slices, accumulates
    row-block partials per column block (k-ascending; a single row block is
    a bit-identical passthrough), and stitches the column blocks.
    ``engine`` is forwarded to every child plan (``None``: per-method
    defaults).  ``stats`` records the grid, the per-tile method choices,
    and — on the Pallas backend — the aggregated launch count and peak
    transient tile size.
    """
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    _check_engine(plan, engine)
    av = _values(a_values)[: int(plan.a.col_ptr[-1])]
    bv = _values(b_values)[: int(plan.b.col_ptr[-1])]
    dtype = _tiled_dtype(plan, av, bv)
    per_block = {ni: [] for ni in range(plan.grid[1])}
    child_stats = []
    for tile in plan.tiles:
        ta, tb = _tile_values(plan, tile, av, bv)
        cs = {} if (stats is not None
                    and plan.backend == "pallas") else None
        per_block[tile.n].append(
            tile.plan.execute(ta, tb, interpret=interpret, stats=cs,
                              engine=engine))
        if cs is not None:
            child_stats.append(cs)
    _record_tile_stats(plan, stats, child_stats)
    return _merge_and_stitch(plan, per_block, dtype)


def execute_tiled_batched(plan, a_values, b_values, *,
                          interpret: bool = True,
                          stats: dict | None = None,
                          validate: str | None = None,
                          engine: str | None = None) -> list:
    """Batched tiled execution: B value sets through one plan traversal.

    Each tile's child plan executes batched (one launch set per tile,
    independent of B on the Pallas backend); the merge/stitch reduction
    then runs per batch element, bit-identical to looping
    :func:`execute_tiled`.
    """
    av = plan.a.batched_values(a_values, validate)
    bv = plan.b.batched_values(b_values, validate)
    if av.shape[0] != bv.shape[0]:
        raise ValueError(
            f"batch mismatch: A has {av.shape[0]} value sets, "
            f"B has {bv.shape[0]}")
    batch = av.shape[0]
    if batch == 0:
        raise ValueError("empty batch")
    _check_engine(plan, engine)
    dtype = _tiled_dtype(plan, av, bv)
    per_block = [{ni: [] for ni in range(plan.grid[1])}
                 for _ in range(batch)]
    child_stats = []
    for tile in plan.tiles:
        ta, tb = _tile_values(plan, tile, av, bv)
        cs = {} if (stats is not None
                    and plan.backend == "pallas") else None
        outs = tile.plan.execute_batched(ta, tb, interpret=interpret,
                                         stats=cs, engine=engine)
        for bi, c in enumerate(outs):
            per_block[bi][tile.n].append(c)
        if cs is not None:
            child_stats.append(cs)
    _record_tile_stats(plan, stats, child_stats)
    if stats is not None:
        stats["batch"] = batch
    return [_merge_and_stitch(plan, per_block[bi], dtype)
            for bi in range(batch)]


def _execute_host(plan: SpgemmPlan, a_values, b_values) -> CSC:
    a = plan.a.with_values(a_values)
    b = plan.b.with_values(b_values)
    method = plan.method
    params = dict(plan.params)
    if method == "spa":
        return naive.spa_numpy(a, b)
    if method == "expand":
        return spgemm_expand(a, b)
    if method == "esc":
        return naive.esc_numpy(a, b)
    if method.startswith("spars"):
        return naive.spars_numpy(a, b, plan.pre)
    if method.startswith("hash"):
        return naive.hash_numpy(a, b, plan.pre)
    if method.startswith("h-"):
        return naive.hybrid_numpy(
            a, b, t=params["t"], b_min=params["b_min"],
            b_max=params["b_max"], accumulator=params["accumulator"],
            pre=plan.pre,
        )
    raise AssertionError(method)


# ---------------------------------------------------------------------------
# vectorized host batched executors (value axis only; structure is
# pattern-only, so every op below repeats naive.py's accumulation order
# element-wise across the batch — bit-identical per element)
# ---------------------------------------------------------------------------


def _spa_host_batched(plan: SpgemmPlan, av: np.ndarray,
                      bv: np.ndarray) -> list:
    """Batched ``naive.spa_numpy``: one pass, SPA arrays carry [B, m]."""
    a_cp, a_rows = plan.a.col_ptr, plan.a.row_indices
    b_cp, b_rows = plan.b.col_ptr, plan.b.row_indices
    m, n = plan.shape
    batch = av.shape[0]
    dtype = np.result_type(av.dtype, bv.dtype)

    spa_values = np.zeros((batch, m), dtype)
    spa_flags = np.zeros(m, bool)       # pattern-only: shared by the batch

    out_rows = [np.zeros(0, np.int32)] * n
    out_vals = [np.zeros((batch, 0), dtype)] * n
    for j in range(n):
        touched = []
        for p in range(b_cp[j], b_cp[j + 1]):
            k = b_rows[p]
            sl = slice(a_cp[k], a_cp[k + 1])
            ar = a_rows[sl]
            spa_values[:, ar] += av[:, sl] * bv[:, p, None]
            new = ar[~spa_flags[ar]]
            spa_flags[new] = True
            if len(new):
                touched.append(new)
        idx = np.concatenate(touched) if touched else np.zeros(0, np.int32)
        out_rows[j] = idx.astype(np.int32)
        out_vals[j] = spa_values[:, idx].astype(dtype)
        spa_values[:, idx] = 0
        spa_flags[idx] = False
    return _assemble_batched(batch, out_rows, out_vals, (m, n), dtype)


# the batched expand fast path lives in core/fast.py now: expand's default
# engine is the product stream, whose batched execution is a broadcast of
# the same gather/segment-reduce pass (no per-row np.add.at loop)
_BATCHED_HOST.update(spa=_spa_host_batched)
VECTORIZED_HOST = tuple(_BATCHED_HOST)


def _assemble_batched(batch, cols_rows, cols_vals, shape, dtype) -> list:
    """Batched ``naive._assemble``: per-column [B, cnt] value slabs."""
    n = shape[1]
    col_ptr = np.zeros(n + 1, np.int32)
    np.cumsum([len(r) for r in cols_rows], out=col_ptr[1:])
    if col_ptr[-1]:
        rows = np.concatenate(cols_rows).astype(np.int32)
        vals = np.concatenate(cols_vals, axis=1)
    else:
        rows = np.zeros(0, np.int32)
        vals = np.zeros((batch, 0), dtype)
    return [CSC(vals[b], rows, col_ptr, shape) for b in range(batch)]


# ---------------------------------------------------------------------------
# Pallas paths
# ---------------------------------------------------------------------------


def _execute_pallas(plan: SpgemmPlan, a_values, b_values, *,
                    interpret: bool, stats: dict | None) -> CSC:
    from repro.kernels import ops as kops

    lay = plan.pallas
    m, n = plan.shape
    av = padded_values(_values(a_values), lay.a_gather,
                       lay.a_mask).astype(np.float32, copy=False)
    b_raw = _values(b_values)
    a_arrs = kops.device_operand(lay.a_rows, av, lay.a_nnz)

    builder = CSCBuilder((m, n), np.float32)
    for g in lay.groups:
        # plan-time-composed masked gather: straight from raw values to the
        # group operand, no full padded-B intermediate or per-call mask
        g_vals = padded_values(b_raw, g.b_vgather,
                               g.b_vmask).astype(np.float32, copy=False)
        if g.kind == "spa":
            tile = kops.run_spa(g, a_arrs, g_vals, m=m,
                                block_cols=lay.block_cols,
                                interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "spars":
            tile = kops.run_spars(g, a_arrs, g_vals, m=m,
                                  block_cols=lay.block_cols,
                                  interpret=interpret)
            builder.add_dense_tile(g.cols, tile)
        elif g.kind == "hash":
            keys, vals = kops.run_hash(g, a_arrs, g_vals, m=m,
                                       block_cols=lay.block_cols,
                                       interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    c = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)
        stats["result_shape"] = (m, n)
    return c


def _execute_pallas_batched(plan: SpgemmPlan, av: np.ndarray,
                            bv: np.ndarray, *, interpret: bool,
                            stats: dict | None) -> list:
    from repro.kernels import ops as kops

    lay = plan.pallas
    m, n = plan.shape
    batch = av.shape[0]
    avp = padded_values_batched(av, lay.a_gather,
                                lay.a_mask).astype(np.float32, copy=False)
    a_arrs = kops.device_operand(lay.a_rows, avp, lay.a_nnz)

    builder = BatchedCSCBuilder(batch, (m, n), np.float32)
    for g in lay.groups:
        g_vals = padded_values_batched(bv, g.b_vgather,
                                       g.b_vmask).astype(np.float32,
                                                         copy=False)
        if g.kind == "spa":
            tiles = kops.run_spa_batched(g, a_arrs, g_vals, m=m,
                                         block_cols=lay.block_cols,
                                         interpret=interpret)
            builder.add_dense_tile(g.cols, tiles)
        elif g.kind == "spars":
            tiles = kops.run_spars_batched(g, a_arrs, g_vals, m=m,
                                           block_cols=lay.block_cols,
                                           interpret=interpret)
            builder.add_dense_tile(g.cols, tiles)
        elif g.kind == "hash":
            keys, vals = kops.run_hash_batched(g, a_arrs, g_vals, m=m,
                                               block_cols=lay.block_cols,
                                               interpret=interpret)
            builder.add_hash_tables(g.cols, keys, vals)
        else:
            raise AssertionError(g.kind)
    out = builder.build()
    if stats is not None:
        stats["tile_shapes"] = list(builder.tile_shapes)
        stats["peak_tile_elems"] = builder.peak_tile_elems
        stats["n_launches"] = len(lay.groups)   # independent of the batch
        stats["result_shape"] = (m, n)
        stats["batch"] = batch
    return out


def _values(x) -> np.ndarray:
    return np.asarray(x.values) if isinstance(x, CSC) else np.asarray(x)

"""Symbolic SpGEMM planning: analyze a sparsity pattern once, execute often.

The paper times its sort/block/hash-size pre-processing separately from the
numeric kernel (Section 5.3); Nagasaka et al.'s hash SpGEMM makes that split
structural — a *symbolic* phase reused whenever the pattern repeats, and a
*numeric* phase that does the flops.  ``plan_spgemm`` runs every
pattern-dependent step once — Op_j analysis, column sorting, blocking,
hash-table sizing, padded kernel layouts, per-family column groups, per-block
trip counts — and captures the result in an immutable :class:`SpgemmPlan`.
Executing the plan against new numeric values (``core.executor``) performs
only value work, so repeated-pattern workloads (graph analytics A·A chains,
static-weight sparse FFNs, iterative solvers) amortize all host-side analysis
(DESIGN.md §6).

Plans are keyed by :func:`pattern_fingerprint`, which hashes only structure
(shape, col_ptr, row_indices) — never values — so ``core.api``'s bounded LRU
can transparently reuse plans across calls with identical patterns.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import backends, faults
from repro.core.analysis import Preprocess, preprocess
from repro.core.cost import AUTO_CANDIDATES, CostConstants, choose_method
import repro.core.fast as _fast
from repro.core.fast import ProductStream, build_product_stream
from repro.sparse.format import BatchedCSC, CSC, _np, csc_pad_gather
from repro.sparse.partition import (
    auto_tile_grid,
    csc_col_slice,
    csc_row_slice,
    nnz_balanced_col_bounds,
    width_col_bounds,
)
from repro.sparse.stats import steps_per_column, tile_stats

# method -> base kwargs; the paper's Section 5.3 configurations
ALGORITHMS = {
    "spa": {},
    "spars-16/64": dict(b_min=16, b_max=64),
    "spars-40/40": dict(b_min=40, b_max=40),
    "h-spa-16/64": dict(t=40, b_min=16, b_max=64, accumulator="spa"),
    "h-spa-40/40": dict(t=40, b_min=40, b_max=40, accumulator="spa"),
    "hash-32/256": dict(b_min=32, b_max=256),
    "hash-256/256": dict(b_min=256, b_max=256),
    "h-hash-32/256": dict(t=40, b_min=32, b_max=256, accumulator="hash"),
    "h-hash-256/256": dict(t=40, b_min=256, b_max=256, accumulator="hash"),
    "esc": {},
    "expand": {},  # fast vectorized host executor (not a paper algorithm)
}

# methods with no Pallas kernel family (host-only executors); the canonical
# definition lives on the pallas backend contract (core/backends.py)
HOST_ONLY = backends.HOST_ONLY_METHODS


def resolve_params(
    method: str,
    *,
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
) -> dict:
    """Named-method defaults with optional overrides.

    Unregistered ``family-x/y`` names (e.g. ``spars-128/128``, accepted by
    ``spgemm_pallas`` since the seed) are parsed from the name itself.
    """
    params = dict(ALGORITHMS.get(method, ()))
    if method not in ALGORITHMS:
        if "-" in method:
            bounds = method.rsplit("-", 1)[1]
            # a trailing all-digit or x/y token is a bounds spec and must
            # parse; anything else (e.g. a bare family prefix) is not
            if "/" in bounds or bounds.isdigit():
                try:
                    bmin, bmax = (int(x) for x in bounds.split("/"))
                except ValueError:
                    raise ValueError(
                        f"malformed block bounds in method {method!r}; "
                        "expected 'family-bmin/bmax'") from None
                params.setdefault("b_min", bmin)
                params.setdefault("b_max", bmax)
        if method.startswith("h-"):
            params.setdefault("t", 40.0)
            params.setdefault(
                "accumulator", "hash" if "hash" in method else "spa")
    if method.startswith(("spars", "hash", "h-")):
        params.setdefault("b_min", 256)
        params.setdefault("b_max", 256)
    if t is not None:
        params["t"] = t
    if b_min is not None:
        params["b_min"] = b_min
    if b_max is not None:
        params["b_max"] = b_max
    return params


def pattern_fingerprint(m: CSC) -> str:
    """Hash of the sparsity pattern only (shape + col_ptr + row_indices).

    Two CSC matrices with equal fingerprints can share one SpgemmPlan; their
    values never enter the hash.
    """
    cp = _np(m.col_ptr)
    ri = _np(m.row_indices)[: int(cp[-1])]
    h = hashlib.blake2b(digest_size=16)
    # raw bytes + dtype tags (no widening copies): fingerprints distinguish
    # index dtypes, which is fine — Pattern.of normalizes to int32 anyway
    h.update(f"{m.shape}:{cp.dtype}:{ri.dtype}".encode())
    h.update(cp.tobytes())
    h.update(ri.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Pattern:
    """Value-free view of one CSC operand: structure + fingerprint."""

    row_indices: np.ndarray
    col_ptr: np.ndarray
    shape: Tuple[int, int]
    fingerprint: str

    @classmethod
    def of(cls, m: CSC) -> "Pattern":
        cp = _np(m.col_ptr)
        return cls(
            np.ascontiguousarray(_np(m.row_indices)[: int(cp[-1])], np.int32),
            np.ascontiguousarray(cp, np.int32),
            tuple(m.shape),
            pattern_fingerprint(m),
        )

    def check_compatible(self, operand, validate: str | None = None) -> None:
        """Compatibility check of an execute-time operand.

        By default O(1): structured operands (CSC/BatchedCSC) must match the
        planned shape and nnz; raw value arrays must cover the planned nnz.
        A same-shape same-nnz operand with a *different* pattern is not
        detected by the default check (the full check costs the O(nnz)
        fingerprint the plan-reuse path exists to avoid) — pass
        ``validate="fingerprint"`` to opt into re-hashing the operand's
        structure and rejecting any pattern mismatch.  Raw value arrays carry
        no structure, so fingerprint validation is vacuous for them.
        """
        if validate not in (None, "fingerprint"):
            raise ValueError(
                f"unknown validate mode {validate!r}; None or 'fingerprint'")
        if isinstance(operand, (CSC, BatchedCSC)):
            if tuple(operand.shape) != self.shape:
                raise ValueError(
                    f"operand shape {tuple(operand.shape)} != planned "
                    f"{self.shape}")
            nnz = int(_np(operand.col_ptr)[-1])
            if nnz != int(self.col_ptr[-1]):
                raise ValueError(
                    f"operand nnz {nnz} != planned {int(self.col_ptr[-1])} "
                    "(sparsity pattern does not match this plan)")
            if (validate == "fingerprint"
                    and pattern_fingerprint(operand) != self.fingerprint):
                raise ValueError(
                    "operand sparsity pattern does not match this plan "
                    "(fingerprint mismatch despite equal shape and nnz)")
        else:
            # shape-only checks (no np.asarray): raw operands may be jax
            # tracers inside a jitted stream execution (DESIGN.md §10)
            shape = np.shape(operand)
            if len(shape) != 1:
                raise ValueError(
                    f"expected a 1-D value array, got shape {shape} "
                    "(use execute_batched for [B, nnz] value stacks)")
            if shape[0] < int(self.col_ptr[-1]):
                raise ValueError(
                    f"need >= {int(self.col_ptr[-1])} values, "
                    f"got {shape[0]}")

    def with_values(self, values, validate: str | None = None) -> CSC:
        """Bind numeric values to this pattern (accepts a CSC or raw array)."""
        self.check_compatible(values, validate)
        v = values.values if isinstance(values, CSC) else np.asarray(values)
        return CSC(v, self.row_indices, self.col_ptr, self.shape)

    def check_batched_compatible(self, operand,
                                 validate: str | None = None) -> None:
        """Batched twin of :meth:`check_compatible`, shape-only for raw
        stacks (tracer-safe — the single source of the batched-operand
        contract, shared by the host/pallas value extraction and the jax
        stream's namespace-preserving path)."""
        if validate not in (None, "fingerprint"):
            raise ValueError(
                f"unknown validate mode {validate!r}; None or 'fingerprint'")
        if isinstance(operand, BatchedCSC):
            self.check_compatible(operand, validate)
            return
        shape = np.shape(operand)
        if len(shape) != 2:
            raise ValueError(
                "batched operand must be a BatchedCSC or a [B, nnz] "
                f"value array, got shape {shape}")
        if shape[1] < int(self.col_ptr[-1]):
            raise ValueError(
                f"need >= {int(self.col_ptr[-1])} values per batch "
                f"element, got {shape[1]}")

    def batched_values(self, values, validate: str | None = None
                       ) -> np.ndarray:
        """Host [B, nnz] value stack from a batched execute-time operand.

        Accepts a :class:`BatchedCSC` with this pattern or a raw ``[B, nnz]``
        array; a single CSC / 1-D array is rejected (use ``execute``).
        """
        self.check_batched_compatible(values, validate)
        v = _np(values.values) if isinstance(values, BatchedCSC) \
            else np.asarray(values)
        return v[:, : int(self.col_ptr[-1])]


@dataclasses.dataclass(frozen=True)
class KernelGroup:
    """One kernel launch of the Pallas execution schedule.

    ``cols`` are the original B/C column ids this launch computes, in lane
    order (pad lanes point at column 0 with nnz forced to 0).
    ``b_rows``/``b_nnz``/``steps`` are the pattern-static halves of the
    padded group operand, stored as device arrays so re-executions pay no
    host-to-device copy; only values are re-gathered per execution.
    ``b_vgather``/``b_vmask`` are that gather, fully precomputed: the
    group's padded value operand is ``where(b_vmask, values[b_vgather], 0)``
    — one fused gather from the raw B value array per launch, composed at
    plan time from the padded layout's gather and the lane-validity mask
    (executions no longer allocate a full padded B nor a per-group
    ``np.where`` mask; the lane selection itself is baked in, so the plan
    retains no separate sel/valid arrays).
    """

    kind: str                 # "spa" | "spars" | "hash"
    cols: np.ndarray          # [n_real] original column ids
    b_rows: jnp.ndarray       # [n_pad, zb] int32 (device)
    b_nnz: jnp.ndarray        # [n_pad] int32 (device)
    b_vgather: np.ndarray     # [n_pad, zb] int64 into B's raw values
    b_vmask: np.ndarray       # [n_pad, zb] bool, False for pad slots/lanes
    steps: Optional[jnp.ndarray] = None  # [n_pad/block_cols] trip counts
    h: Optional[int] = None              # hash-table size (kind == "hash")

    @property
    def n_real(self) -> int:
        return len(self.cols)


@dataclasses.dataclass(frozen=True)
class PallasLayout:
    """Everything ``spgemm_pallas`` used to recompute per call, pattern-only.

    The A operand rides whole into every launch (as in the seed kernels); B
    is pre-sliced per group.  ``*_gather``/``*_mask`` re-pad fresh numeric
    values with one vectorized gather each.
    """

    block_cols: int
    tile_cols: int
    a_rows: jnp.ndarray       # [n_a, za] int32 (device)
    a_nnz: jnp.ndarray        # [n_a] int32 (device)
    a_gather: np.ndarray
    a_mask: np.ndarray
    groups: Tuple[KernelGroup, ...]


@dataclasses.dataclass(frozen=True)
class SpgemmPlan:
    """Immutable symbolic plan for C = A @ B with one algorithm/backend.

    Built once per sparsity pattern by :func:`plan_spgemm`; execute with
    ``plan.execute(a_values, b_values)`` (CSC operands or raw value arrays
    aligned with the planned patterns) or ``spgemm(a, b, plan=plan)``.
    """

    method: str
    backend: str
    params: tuple             # sorted (key, value) pairs, hashable
    a: Pattern
    b: Pattern
    pre: Optional[Preprocess]          # host blocking analysis (if any)
    pallas: Optional[PallasLayout]     # kernel layouts (pallas backend)
    stream_limit: Optional[int] = None  # plan-memory guard (products)
    _stream_memo: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def contract(self) -> "backends.ExecutionContract":
        """This plan's backend capability contract (core/backends.py)."""
        return backends.get_backend(self.backend)

    @property
    def stream(self) -> Optional[ProductStream]:
        """Lazily-built product stream (``engine="stream"``, DESIGN.md §9).

        Built on first access so plans that never run the stream engine pay
        neither the plan-time lexsort nor the O(flops) resident memory;
        memoized on the plan, so tiled child plans shared through the LRU
        share one stream.  Carried by every stream-capable backend
        (``contract.carries_stream``: host, jax, and pallas — the jax
        backend builds its device-resident index arrays from this host
        stream, and the fused Pallas kernel its replay views, DESIGN.md
        §10/§11).  ``None`` when the stream would exceed ``stream_limit``
        (the guard resolved at plan time) — stream executions then rebuild
        transiently.
        """
        if not self.contract.carries_stream:
            return None
        if "stream" not in self._stream_memo:
            self._stream_memo["stream"] = build_product_stream(
                self.a, self.b, self.stream_limit)
        return self._stream_memo["stream"]

    @property
    def stream_nbytes(self) -> int:
        """Bytes of host stream index data currently held by this plan.

        Reads the memo without triggering the lazy build (0 until the
        first stream execution, and 0 when the guard tripped) — this is
        what ``plan_cache_info()['stream_bytes']`` aggregates.
        """
        s = self._stream_memo.get("stream")
        return s.nbytes if s is not None else 0

    @property
    def device_stream_nbytes(self) -> int:
        """Bytes of *device-resident* stream index data held by this plan.

        The jax backend caches the stream's index arrays on device alongside
        the host ones (DESIGN.md §10); this reads the memo without
        triggering the lazy build — ``plan_cache_info()
        ['device_stream_bytes']`` aggregates it separately from host bytes.
        """
        d = self._stream_memo.get("device")
        return d.nbytes if d is not None else 0

    @property
    def fused_stream_nbytes(self) -> int:
        """Bytes of fused-kernel replay views held by this plan.

        The fused engine (``core.pallas_stream``, DESIGN.md §11) caches
        three device-resident index views (forward + two grad replays) on
        the plan; this reads the memo without triggering the lazy build —
        ``plan_cache_info()['fused_stream_bytes']`` aggregates it alongside
        the host and XLA-device stream bytes.
        """
        f = self._stream_memo.get("fused")
        return f.nbytes if f is not None else 0

    def stream_apply(self, a_values, b_values, engine: str = None):
        """Jit-compatible, differentiable numeric phase: C values only.

        The device-backend entry point for traced code (DESIGN.md §10):
        ``a_values``/``b_values`` are value arrays (or tracers) aligned with
        the planned patterns, and the return is the ``[nnz_c]`` C value
        array of the plan's canonical output structure
        (``plan.stream.c_rows`` / ``c_col_ptr``) — a pure function of the
        inputs, safe under ``jax.jit``/``jax.grad``/``jax.vmap``.
        ``engine=None`` lowers through the XLA stream; ``engine="fused"``
        through the single-launch fused Pallas kernel (DESIGN.md §11) —
        both ride the same bilinear custom vjp.  Requires a stream-capable
        backend and a plan-resident stream (guarded plans raise: a traced
        execution cannot fall back to the host rebuild).
        """
        from repro.core import jax_stream

        # shape-only (tracer-safe) operand checks: the jitted gathers run
        # with an in-bounds promise, so a short value array must raise
        # here rather than read undefined memory
        self.a.check_compatible(a_values)
        self.b.check_compatible(b_values)
        if engine == "fused":
            from repro.core import pallas_stream

            return pallas_stream.fused_fn(self)(a_values, b_values)
        if engine is not None and engine != "stream":
            raise ValueError(
                f"stream_apply supports engine=None/'stream'/'fused', "
                f"got {engine!r}")
        return jax_stream.stream_fn(self)(a_values, b_values)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.a.shape[0], self.b.shape[1])

    @property
    def cache_key(self) -> tuple:
        # mirrors core.api._cached_plan's LRU key (which keys host plans on
        # the stream guard in effect at build time)
        return (self.a.fingerprint, self.b.fingerprint, self.method,
                self.backend, self.params, self.stream_limit)

    def execute(self, a_values, b_values, *, interpret: bool = True,
                stats: dict | None = None, validate: str | None = None,
                engine: str | None = None) -> CSC:
        """Numeric phase only: C for new values on the planned patterns.

        ``engine`` selects the host numeric engine: ``"naive"`` (the
        faithful per-method oracle executors), ``"stream"`` (the vectorized
        product-stream engine, DESIGN.md §9), or ``None`` for the method's
        default (``"stream"`` for ``expand``, ``"naive"`` otherwise).
        """
        from repro.core.executor import execute

        return execute(self, a_values, b_values, interpret=interpret,
                       stats=stats, validate=validate, engine=engine)

    def execute_batched(self, a_values, b_values, *, interpret: bool = True,
                        stats: dict | None = None,
                        validate: str | None = None,
                        engine: str | None = None) -> list:
        """Batched numeric phase: B same-pattern multiplies, one schedule.

        ``a_values``/``b_values``: :class:`~repro.sparse.format.BatchedCSC`
        operands or raw ``[B, nnz]`` value stacks aligned with the planned
        patterns.  Returns the B results as a list of CSC matrices,
        bit-identical to a Python loop of :meth:`execute` (DESIGN.md §7).
        ``engine`` — as in :meth:`execute`.
        """
        from repro.core.executor import execute_batched

        return execute_batched(self, a_values, b_values, interpret=interpret,
                               stats=stats, validate=validate, engine=engine)


def _freeze(params: dict) -> tuple:
    return tuple(sorted(params.items()))


def plan_spgemm(
    a: CSC,
    b: CSC,
    method: str = "h-hash-256/256",
    *,
    backend: str = "host",
    t: float | None = None,
    b_min: int | None = None,
    b_max: int | None = None,
    block_cols: int = 128,
    tile_cols: int | None = None,
    stream_limit: int | None = None,
    shards: int | None = None,
) -> SpgemmPlan:
    """Build the symbolic plan for C = A @ B (pattern-dependent work only).

    ``block_cols`` is the Pallas lane-block width; ``tile_cols`` bounds how
    many C columns one kernel launch materializes (defaults to
    ``block_cols``), which caps the transient accumulator tile at
    ``[m, tile_cols]`` — the dense ``[m, n]`` sink of the pre-plan backend is
    gone.

    Host plans also carry the product stream (``engine="stream"``, DESIGN.md
    §9), built lazily on first stream access and kept plan-resident while
    the flop count is within ``stream_limit`` (default: the value of
    ``fast.STREAM_MAX_PRODUCTS`` at plan time); above it ``plan.stream`` is
    ``None`` and stream executions rebuild it transiently — same results,
    no plan-resident O(flops) memory.

    ``backend="mesh"`` delegates to
    :func:`repro.distributed.spgemm_mesh.plan_spgemm_mesh` and returns a
    :class:`~repro.distributed.spgemm_mesh.ShardedSpgemmPlan` — the tile
    grid placed across ``shards`` devices (default: all visible), with
    ``stream_limit`` acting as the *per-shard* plan-memory guard.
    ``shards`` is mesh-only; any other backend rejects it.
    """
    faults.check("plan_spgemm", key=(backend, method))
    if shards is not None and backend != "mesh":
        raise ValueError(
            f"shards= applies only to backend='mesh', not {backend!r}")
    if a.n_cols != b.n_rows:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    if method not in ALGORITHMS and not method.startswith(
            ("spars", "hash", "h-")):
        raise ValueError(
            f"unknown method {method!r}; one of {list(ALGORITHMS)} or a "
            "'spars-*/hash-*/h-*' family name")
    contract = backends.get_backend(backend)
    if method in contract.excluded_methods:
        raise ValueError(
            f"method {method!r} has no {contract.name} kernel family "
            "(host-only)")
    backends.check_method_knobs(contract, t, b_min, b_max)
    if contract.canonical_method:
        # jax: the numeric phase is the method-independent stream
        # contraction, so every method *spelling* shares one canonical
        # plan (plan.method reports the canonical form)
        method = contract.canonical_method
    if backend == "mesh":
        from repro.distributed.spgemm_mesh import plan_spgemm_mesh

        return plan_spgemm_mesh(a, b, shards=shards,
                                shard_limit=stream_limit)
    params = resolve_params(method, t=t, b_min=b_min, b_max=b_max)
    a_pat, b_pat = Pattern.of(a), Pattern.of(b)

    # resolve the guard now (it is a mutable module knob) so every plan's
    # lazy stream build is deterministic no matter when it happens; pallas
    # plans carry it too since the fused engine rides the product stream
    limit = (_fast.STREAM_MAX_PRODUCTS if stream_limit is None
             else int(stream_limit))
    if backend == "pallas":
        pre, layout = _plan_pallas(a, b, method, params, block_cols,
                                   tile_cols)
        return SpgemmPlan(method, "pallas", _freeze(params), a_pat, b_pat,
                          pre, layout, limit)
    # the remaining stream-capable backends (host, jax) are pattern-only
    # plans.  The jax backend never runs the naive oracles
    # (contract.bit_exact_oracle is False), so it skips the blocking
    # analysis they consume.
    pre = None
    if contract.bit_exact_oracle:
        if method.startswith(("spars", "hash")):
            pre = preprocess(a, b, t=np.inf, b_min=params["b_min"],
                             b_max=params["b_max"])
        elif method.startswith("h-"):
            pre = preprocess(a, b, t=params["t"], b_min=params["b_min"],
                             b_max=params["b_max"])
    # resolve the guard now (it is a mutable module knob) so the plan's
    # lazy stream build is deterministic no matter when it happens
    limit = (_fast.STREAM_MAX_PRODUCTS if stream_limit is None
             else int(stream_limit))
    return SpgemmPlan(method, backend, _freeze(params), a_pat, b_pat,
                      pre, None, limit)


# ---------------------------------------------------------------------------
# Tiled plans: a 2D grid of per-tile SpgemmPlans (DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One non-empty tile product ``A[:, k] @ B[k, n]`` of a tiled plan.

    ``a_vals``/``b_vals`` are the pattern-static value-slicing metadata: the
    A tile's values are the contiguous range ``[a_vals[0], a_vals[1])`` of
    the parent A value array, the B tile's values are ``b_parent[b_vals]``
    (a gather — row slicing is not contiguous in CSC).  ``plan`` is an
    ordinary per-tile :class:`SpgemmPlan`, shared through the plan LRU with
    any other tile of identical pattern.
    """

    k: int                       # row-block index (A column block)
    n: int                       # column-block index (B column block)
    a_vals: Tuple[int, int]
    b_vals: np.ndarray
    plan: SpgemmPlan
    #: engine override the cost model chose for this tile (None = the child
    #: plan's method default; "fused" = the single-launch fused kernel)
    engine: Optional[str] = None

    @property
    def method(self) -> str:
        # report the candidate spelling the cost model chose: "jax"/"fused"
        # tiles (the device stream riding a host grid) carry an
        # expand-method child plan on the jax backend
        if self.engine == "fused":
            return "fused"
        return "jax" if self.plan.backend == "jax" else self.plan.method


@dataclasses.dataclass(frozen=True)
class TiledSpgemmPlan:
    """Symbolic plan for ``C = A @ B`` as a 2D grid of tile products.

    Built by :func:`plan_spgemm_tiled` (the ``method="auto"`` path of
    ``core.api.spgemm``): A is sliced into column blocks at ``k_bounds``, B
    into matching row blocks crossed with column blocks at ``n_bounds``,
    and every structurally non-empty tile pair gets its own child
    :class:`SpgemmPlan` whose method the cost model picked for that tile's
    work profile.  Execution (``core.executor.execute_tiled``) runs the
    children and merges: per column block, partial products accumulate over
    row blocks in k order; the blocks then stitch left-to-right into the
    final CSC.  A plan with a single row block is bit-identical per column
    to the untiled method (DESIGN.md §8).
    """

    backend: str
    a: Pattern
    b: Pattern
    k_bounds: np.ndarray         # [K+1] over A's columns / B's rows
    n_bounds: np.ndarray         # [N+1] over B's columns
    tiles: Tuple[TilePlan, ...]  # structurally non-empty tiles, n-major
    params: tuple                # frozen ("candidates", ...), ("tile", ...)

    method = "auto"

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.a.shape[0], self.b.shape[1])

    @property
    def grid(self) -> Tuple[int, int]:
        return (len(self.k_bounds) - 1, len(self.n_bounds) - 1)

    @property
    def methods(self) -> dict:
        """{(k, n): chosen method} for every non-empty tile."""
        return {(t.k, t.n): t.method for t in self.tiles}

    @property
    def stream_nbytes(self) -> int:
        """Stream bytes currently held via this plan's child tile plans.

        Children of identical pattern share one plan (and one stream), so
        the sum is over *distinct* child plans.  Note the per-plan guard
        bounds each tile's stream individually — a tiled plan over a huge
        multiply can hold many guard-sized tile streams at once.
        """
        seen = {id(t.plan): t.plan.stream_nbytes for t in self.tiles}
        return sum(seen.values())

    @property
    def device_stream_nbytes(self) -> int:
        """Device-resident stream bytes held via child tile plans (distinct
        children counted once, as in :attr:`stream_nbytes`)."""
        seen = {id(t.plan): t.plan.device_stream_nbytes for t in self.tiles}
        return sum(seen.values())

    @property
    def fused_stream_nbytes(self) -> int:
        """Fused-kernel replay-view bytes held via child tile plans
        (distinct children counted once, as in :attr:`stream_nbytes`)."""
        seen = {id(t.plan): t.plan.fused_stream_nbytes for t in self.tiles}
        return sum(seen.values())

    @property
    def cache_key(self) -> tuple:
        # mirrors core.api._cached_tiled_plan's LRU key: the stream guard
        # in effect at build time is part of it, because the guard steers
        # the per-tile method choices
        own = dict(self.params)
        return (self.a.fingerprint, self.b.fingerprint, "auto",
                self.backend, own["tile"], own["candidates"],
                own["stream_guard"], own.get("profile", "default"))

    def execute(self, a_values, b_values, *, interpret: bool = True,
                stats: dict | None = None, validate: str | None = None,
                engine: str | None = None) -> CSC:
        """Numeric phase: run every tile plan, merge row blocks, stitch.

        ``engine`` is forwarded to every child tile plan (``None`` lets each
        tile use its method's default engine).
        """
        from repro.core.executor import execute_tiled

        return execute_tiled(self, a_values, b_values, interpret=interpret,
                             stats=stats, validate=validate, engine=engine)

    def execute_batched(self, a_values, b_values, *, interpret: bool = True,
                        stats: dict | None = None,
                        validate: str | None = None,
                        engine: str | None = None) -> list:
        """Batched numeric phase over ``[B, nnz]`` value stacks."""
        from repro.core.executor import execute_tiled_batched

        return execute_tiled_batched(self, a_values, b_values,
                                     interpret=interpret, stats=stats,
                                     validate=validate, engine=engine)


def normalize_tile_spec(tile) -> tuple:
    """Canonical ``(k_width, n_width)`` form of the ``tile=`` argument.

    ``None`` → both axes auto-sized from nnz; an int → that column width on
    the n axis (k auto); a 2-tuple gives per-axis widths, ``None`` meaning
    auto for that axis.
    """
    if tile is None:
        return (None, None)
    if isinstance(tile, (int, np.integer)):
        spec = (None, int(tile))
    else:
        spec = tuple(tile)
    if len(spec) != 2:
        raise ValueError(
            f"tile must be None, an int, or a (k_width, n_width) pair; "
            f"got {tile!r}")
    out = []
    for w in spec:
        if w is None:
            out.append(None)
        elif isinstance(w, (int, np.integer)) and int(w) >= 1:
            out.append(int(w))
        else:
            raise ValueError(f"tile widths must be ints >= 1 or None, "
                             f"got {w!r}")
    return tuple(out)


def plan_spgemm_tiled(
    a: CSC,
    b: CSC,
    *,
    backend: str = "host",
    tile=None,
    candidates: tuple | None = None,
    cache: bool = True,
    constants: CostConstants | None = None,
) -> TiledSpgemmPlan:
    """Build the tiled ``method="auto"`` plan for C = A @ B.

    ``tile`` — see :func:`normalize_tile_spec`; auto axes use nnz-balanced
    boundaries (:func:`~repro.sparse.partition.nnz_balanced_col_bounds`)
    with block counts from :func:`~repro.sparse.partition.auto_tile_grid`.
    ``candidates`` restricts the per-tile method choice (defaults to
    ``cost.AUTO_CANDIDATES[backend]``); with a single candidate every tile
    runs that method, which makes single-row-block grids bit-identical to
    the untiled method.  ``cache=True`` funnels child plans through the
    shared plan LRU, so tiles with identical patterns share one plan.
    """
    if a.n_cols != b.n_rows:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    contract = backends.get_backend(backend)
    cands = AUTO_CANDIDATES[backend] if candidates is None \
        else tuple(candidates)
    if not cands:
        raise ValueError("empty candidate set")
    bad = [m for m in cands if m in contract.excluded_methods]
    if bad:
        raise ValueError(
            f"candidates {bad} have no {contract.name} kernel family "
            "(host-only)")

    k_width, n_width = normalize_tile_spec(tile)
    auto_k, auto_n = auto_tile_grid(a, b)
    k_bounds = (width_col_bounds(a.n_cols, k_width) if k_width
                else nnz_balanced_col_bounds(a, auto_k))
    n_bounds = (width_col_bounds(b.n_cols, n_width) if n_width
                else nnz_balanced_col_bounds(b, auto_n))

    def _tile_plan(ta, tb, method):
        # the "jax" candidate spelling = the device stream (DESIGN.md §10),
        # "fused" = its single-launch Pallas lowering (DESIGN.md §11): both
        # ride an expand-method child plan on the jax backend, so a host
        # grid can mix numpy tiles with device-stream/fused tiles.  The
        # engine distinction lives on the TilePlan, not the child plan —
        # same pattern, same shared plan in the LRU.
        if method in ("jax", "fused"):
            meth, be = "expand", "jax"
            engine = "fused" if method == "fused" else None
        else:
            meth, be, engine = method, backend, None
        if cache:
            from repro.core.api import _cached_plan

            return _cached_plan(ta, tb, meth, be,
                                resolve_params(meth)), engine
        return plan_spgemm(ta, tb, meth, backend=be), engine

    # A column blocks depend only on k: slice them once, not once per n block
    a_tiles = [csc_col_slice(a, int(k0), int(k1))
               for k0, k1 in zip(k_bounds[:-1], k_bounds[1:])]
    tiles: list[TilePlan] = []
    for ni, (j0, j1) in enumerate(zip(n_bounds[:-1], n_bounds[1:])):
        b_col, (b_lo, _) = csc_col_slice(b, int(j0), int(j1))
        for ki, (k0, k1) in enumerate(zip(k_bounds[:-1], k_bounds[1:])):
            a_tile, (a_lo, a_hi) = a_tiles[ki]
            if a_tile.nnz == 0:
                continue
            b_tile, rel = csc_row_slice(b_col, int(k0), int(k1))
            if b_tile.nnz == 0:
                continue
            stats = tile_stats(a_tile, b_tile)
            if stats.flops == 0:
                continue  # stored B entries only reference empty A columns
            method = choose_method(stats, backend, cands, constants)
            child, engine = _tile_plan(a_tile, b_tile, method)
            tiles.append(TilePlan(
                k=ki, n=ni, a_vals=(a_lo, a_hi), b_vals=b_lo + rel,
                plan=child, engine=engine))

    # the cost-constant provenance the per-tile choices were ranked under:
    # a plan built on measured constants must never alias one built on
    # defaults (or on an older calibration) in the plan LRU
    if constants is None:
        from repro.core import profile as _profile

        profile_tag = _profile.current_profile().tag
    else:
        profile_tag = "explicit"
    params = (("candidates", cands),
              ("profile", profile_tag),
              # stream-carrying backends only (all three today): the guard
              # steers host/jax per-tile method choices and bounds every
              # child plan's lazy stream build, fused replays included
              ("stream_guard",
               _fast.STREAM_MAX_PRODUCTS if contract.carries_stream
               else None),
              ("tile", (k_width, n_width)))
    return TiledSpgemmPlan(backend, Pattern.of(a), Pattern.of(b),
                           np.asarray(k_bounds, np.int64),
                           np.asarray(n_bounds, np.int64),
                           tuple(tiles), params)


# ---------------------------------------------------------------------------
# Pallas schedule construction (was recomputed on every spgemm_pallas call)
# ---------------------------------------------------------------------------


def _plan_pallas(a, b, method, params, block_cols, tile_cols):
    if tile_cols is None:
        tile_cols = block_cols
    if tile_cols % block_cols:
        raise ValueError(
            f"tile_cols={tile_cols} not a multiple of block_cols={block_cols}")
    n = b.n_cols
    a_rows, a_gather, a_mask, a_nnz = csc_pad_gather(a)
    b_rows, b_gather, b_mask, b_nnz = csc_pad_gather(b)
    a_nnz = a_nnz.astype(np.int32)
    b_nnz = b_nnz.astype(np.int32)

    groups: list[KernelGroup] = []

    def add_group(kind, cols, steps=None, h=None):
        cols = np.asarray(cols, np.int64)
        n_real = len(cols)
        if n_real == 0:
            return
        n_pad = -(-n_real // block_cols) * block_cols
        sel = np.zeros(n_pad, np.int64)
        sel[:n_real] = cols
        valid = np.zeros(n_pad, bool)
        valid[:n_real] = True
        g_rows = np.where(valid[:, None], b_rows[sel], 0).astype(np.int32)
        g_nnz = np.where(valid, b_nnz[sel], 0).astype(np.int32)
        # the masked value-gather selection, composed once at plan time:
        # executions do where(vmask, values[vgather], 0) per group instead
        # of padding all of B and re-masking on every call
        vgather = b_gather[sel]
        vmask = b_mask[sel] & valid[:, None]
        if steps is not None:
            steps = np.asarray(steps, np.int32)
            assert len(steps) == n_pad // block_cols, (len(steps), n_pad)
            steps = jnp.asarray(steps)
        groups.append(KernelGroup(kind, cols,
                                  jnp.asarray(g_rows), jnp.asarray(g_nnz),
                                  vgather, vmask, steps, h))

    # the kernels process each lane independently, so splitting a family into
    # tile_cols-wide launches changes peak memory, never values
    if method == "spa":
        pre = None
        head = np.arange(n)
    else:
        tt = params["t"] if method.startswith("h-") else np.inf
        # the lock-step kernels use fixed-width lane blocks: the blocking
        # bounds collapse to block_cols (the named method only selects the
        # family), exactly as the seed backend did
        pre = preprocess(a, b, t=tt, b_min=block_cols, b_max=block_cols)
        head = pre.perm[: pre.split]

    for c0 in range(0, len(head), tile_cols):
        add_group("spa", head[c0: c0 + tile_cols])

    if method != "spa" and pre.blocks.n_blocks:
        fam = "hash" if "hash" in method else "spars"
        starts, sizes = pre.blocks.starts, pre.blocks.sizes
        n_blocks = pre.blocks.n_blocks
        # per-block trip count: NOT the block head's Op_j — a lane consumes
        # one step per stored B entry even when it references an empty A
        # column (zero products), so the bound is the block max of
        # steps_per_column.  Blocks tile [split, n) contiguously in sorted
        # order, so reduceat over the sorted steps gives per-block maxima.
        steps_sorted = steps_per_column(a, b)[pre.perm]
        steps_all = np.maximum.reduceat(steps_sorted, starts).astype(np.int32)
        if fam == "hash":
            # blocks with equal table size H form contiguous runs (H shrinks
            # monotonically along sorted blocks, Section 3.2)
            hs = pre.hash_sizes
            run_bounds = np.concatenate(
                ([0], np.nonzero(np.diff(hs))[0] + 1, [n_blocks]))
            runs = list(zip(run_bounds[:-1], run_bounds[1:]))
        else:
            runs = [(0, n_blocks)]
        blocks_per_tile = tile_cols // block_cols
        for r0, r1 in runs:
            h = int(pre.hash_sizes[r0]) if fam == "hash" else None
            for i0 in range(r0, r1, blocks_per_tile):
                i1 = min(i0 + blocks_per_tile, r1)
                lo = int(starts[i0])
                hi = int(starts[i1 - 1] + sizes[i1 - 1])
                add_group(fam, pre.perm[lo:hi], steps=steps_all[i0:i1], h=h)

    layout = PallasLayout(
        block_cols=block_cols,
        tile_cols=tile_cols,
        a_rows=jnp.asarray(a_rows),
        a_nnz=jnp.asarray(a_nnz),
        a_gather=a_gather,
        a_mask=a_mask,
        groups=tuple(groups),
    )
    return pre, layout

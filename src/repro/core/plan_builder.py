"""Background plan construction: the symbolic phase off the latency path.

Serving ticks must never wait on a plan build (ROADMAP item 1, DESIGN.md
§12): under live traffic a plan-cache miss enqueues the build *here* — a
small pool of daemon worker threads feeding a completion queue — and the
caller proceeds immediately on a fallback (the cheap synchronous host
stream, or a queued request).  The expensive part of a device plan is not
the symbolic phase itself but what hangs off it: the device lift of the
product stream and the XLA compile of the jitted numeric function.
``warm=True`` (the default) forces both inside the worker, so by the time
a build completes the serving thread's next call is a pure compiled
replay.

All builds go through :func:`repro.core.api.cached_plan`, i.e. the shared
locked plan LRU — the single-flight protocol there guarantees a build
racing a foreground request runs the symbolic phase once, whichever
thread gets there first.  The builder adds its own layer of dedup on top
(``submit`` of a key already queued or building is a no-op) so a hot
pattern arriving on every tick does not flood the queue, and a
``max_pending`` bound sheds excess work under adversarial all-miss
traffic instead of growing the queue without bound.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.core import api


@dataclasses.dataclass
class BuildResult:
    """One completed background task, as drained from :meth:`poll`."""

    tag: Any
    key: Optional[tuple]
    plan: Any = None
    error: Optional[BaseException] = None
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None


def warm_plan(plan) -> None:
    """Materialize a plan's expensive lazy state inside the builder.

    Touches the host product stream (the §9 lazy build), and on
    stream-capable device backends also lifts the device arrays and runs
    one throwaway numeric execution so XLA compiles the jitted stream
    function (§10) — the state a serving tick would otherwise pay for on
    first use.  Guarded plans (``plan.stream is None``) have nothing to
    warm.  Safe to call on any plan; unknown plan types are ignored.
    """
    stream = getattr(plan, "stream", None)
    if stream is None:
        return
    if getattr(plan, "backend", None) in ("jax", "mesh"):
        a_nnz = int(plan.a.col_ptr[-1])
        b_nnz = int(plan.b.col_ptr[-1])
        out = plan.stream_apply(np.zeros(a_nnz, np.float32),
                                np.zeros(b_nnz, np.float32))
        out.block_until_ready()


class PlanBuilder:
    """Thread-pool plan builder with a completion queue.

    ::

        builder = PlanBuilder()
        builder.submit(a, b, "expand", backend="jax")   # non-blocking
        ...
        for res in builder.poll():                      # drain completions
            ...
        plan, status = builder.plan_or_fallback(a, b, "expand")

    ``workers=1`` (the default) keeps device compiles serialized — XLA
    compilation is itself internally parallel, and serving cares about
    the *foreground* tick latency, not build throughput.  All workers are
    daemon threads; call :meth:`shutdown` (or use the context manager) for
    a deterministic drain.
    """

    def __init__(self, workers: int = 1, max_pending: int | None = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self._q: "queue.Queue" = queue.Queue()
        self._completions: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inflight: set = set()     # plan keys queued or building
        self._pending = 0               # tasks queued or running
        self._stopped = False
        self.max_pending = max_pending
        self.stats = {"submitted": 0, "completed": 0, "failed": 0,
                      "deduped": 0, "shed": 0, "cached": 0, "rewarmed": 0}
        self._known: dict = {}          # plan key -> submit() kwargs
        self._rewarm_cb = None
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"plan-builder-{i}")
            for i in range(workers)]
        for t in self._threads:
            t.start()

    # -- submission ----------------------------------------------------------

    def submit(self, a, b, method: str | None = None, *,
               backend: str = "jax", t: float | None = None,
               b_min: int | None = None, b_max: int | None = None,
               stream_limit: int | None = None, warm: bool = True,
               tag: Any = None) -> str:
        """Enqueue a background build of ``cached_plan(a, b, method, ...)``.

        Returns a status string, never blocks on the build itself:

        * ``"cached"``    — the plan is already in the LRU; nothing queued.
        * ``"inflight"``  — the same key is already queued or building.
        * ``"shed"``      — ``max_pending`` reached; the build was dropped
          (the caller keeps using its fallback and may resubmit later).
        * ``"submitted"`` — queued; a :class:`BuildResult` will appear in
          :meth:`poll` when it lands in the LRU.
        """
        key = api.plan_cache_key(a, b, method, backend=backend, t=t,
                                 b_min=b_min, b_max=b_max,
                                 stream_limit=stream_limit)
        with self._lock:
            # remember how to rebuild this key so a post-shrink re-warm
            # (rewarm / enable_rewarm) can resubmit it without the caller
            self._known[key] = dict(a=a, b=b, method=method,
                                    backend=backend, t=t, b_min=b_min,
                                    b_max=b_max, stream_limit=stream_limit,
                                    warm=warm)
        if api.plan_cache_peek(key) is not None:
            self.stats["cached"] += 1
            return "cached"
        with self._lock:
            if self._stopped:
                raise RuntimeError("PlanBuilder is shut down")
            if key in self._inflight:
                self.stats["deduped"] += 1
                return "inflight"
            if self.max_pending is not None \
                    and self._pending >= self.max_pending:
                self.stats["shed"] += 1
                return "shed"
            self._inflight.add(key)
            self._pending += 1
            self.stats["submitted"] += 1

        def build():
            plan = api.cached_plan(a, b, method, backend=backend, t=t,
                                   b_min=b_min, b_max=b_max,
                                   stream_limit=stream_limit)
            if warm:
                warm_plan(plan)
            return plan

        self._q.put((key if tag is None else tag, key, build))
        return "submitted"

    def submit_task(self, fn: Callable[[], Any], tag: Any = None) -> str:
        """Enqueue an arbitrary warm job (no key dedup).

        The serving engine uses this to trace + compile its jitted sparse
        decode step in the background (every overlay plan builds through
        the locked LRU as a side effect).  The callable's return value
        rides in ``BuildResult.plan``.
        """
        with self._lock:
            if self._stopped:
                raise RuntimeError("PlanBuilder is shut down")
            if self.max_pending is not None \
                    and self._pending >= self.max_pending:
                self.stats["shed"] += 1
                return "shed"
            self._pending += 1
            self.stats["submitted"] += 1
        self._q.put((tag, None, fn))
        return "submitted"

    def plan_or_fallback(self, a, b, method: str | None = None, *,
                         backend: str = "jax",
                         fallback_backend: str = "host",
                         stream_limit: int | None = None,
                         warm: bool = True):
        """Non-blocking plan fetch for a latency-critical tick.

        Probes the LRU for the ``backend`` plan without mutating it; on a
        miss, enqueues the background build and synchronously returns the
        cheap ``fallback_backend`` plan instead (host symbolic phase only —
        no device lift, no XLA compile).  Returns ``(plan, status)`` with
        status ``"ready"`` (device plan served) or ``"fallback"``.
        """
        key = api.plan_cache_key(a, b, method, backend=backend,
                                 stream_limit=stream_limit)
        plan = api.plan_cache_peek(key)
        if plan is not None:
            return plan, "ready"
        self.submit(a, b, method, backend=backend,
                    stream_limit=stream_limit, warm=warm)
        fb = api.cached_plan(a, b, method, backend=fallback_backend,
                             stream_limit=stream_limit)
        return fb, "fallback"

    # -- post-shrink re-warm (DESIGN.md §12) ---------------------------------

    def rewarm(self, keys) -> int:
        """Resubmit builds for evicted plan keys this builder has seen.

        ``plan_cache_resize()`` shrinking below the number of in-flight
        builds silently evicts completed builds (the ``wasted_builds``
        counter in ``plan_cache_info()``); this re-queues the known ones so
        the cache re-converges in the background.  Keys this builder never
        built are skipped.  Returns the number of builds resubmitted.
        """
        count = 0
        for key in keys:
            with self._lock:
                spec = self._known.get(key)
            if spec is None:
                continue
            spec = dict(spec)
            a, b, method = spec.pop("a"), spec.pop("b"), spec.pop("method")
            try:
                if self.submit(a, b, method, tag=("rewarm", key),
                               **spec) == "submitted":
                    count += 1
                    self.stats["rewarmed"] += 1
            except RuntimeError:
                break   # shut down mid-notification; nothing to re-queue
        return count

    def enable_rewarm(self) -> None:
        """Hook :meth:`rewarm` to the plan cache's post-shrink evictions.

        Registers an ``api.register_eviction_listener`` callback that
        resubmits this builder's evicted keys after every
        ``plan_cache_resize()`` shrink (capacity-pressure evictions never
        notify, so re-warming cannot fight the LRU).  Idempotent;
        unhooked automatically by :meth:`shutdown`.
        """
        if self._rewarm_cb is None:
            def cb(keys, reason):
                if reason == "resize":
                    self.rewarm(keys)

            self._rewarm_cb = cb
            api.register_eviction_listener(cb)

    def disable_rewarm(self) -> None:
        """Unhook the :meth:`enable_rewarm` listener (idempotent)."""
        if self._rewarm_cb is not None:
            api.unregister_eviction_listener(self._rewarm_cb)
            self._rewarm_cb = None

    # -- completion / lifecycle ----------------------------------------------

    def poll(self) -> list:
        """Drain the completion queue (non-blocking)."""
        out = []
        while True:
            try:
                out.append(self._completions.get_nowait())
            except queue.Empty:
                return out

    def pending(self) -> int:
        with self._lock:
            return self._pending

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until every queued/running task completed (tests, drain)."""
        with self._cv:
            return self._cv.wait_for(lambda: self._pending == 0, timeout)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work; optionally drain the queue and join."""
        self.disable_rewarm()
        with self._lock:
            if self._stopped:
                return
            self._stopped = True
        if not wait:
            # unblock workers with one sentinel each; queued tasks that
            # run anyway are harmless (they only populate the shared LRU)
            for _ in self._threads:
                self._q.put(None)
            return
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    def _worker(self) -> None:
        while True:
            task = self._q.get()
            if task is None:
                return
            tag, key, fn = task
            t0 = time.perf_counter()
            plan, err = None, None
            try:
                plan = fn()
            except BaseException as e:  # noqa: BLE001 — reported via poll()
                err = e
            dt = time.perf_counter() - t0
            with self._cv:
                if key is not None:
                    self._inflight.discard(key)
                self._pending -= 1
                self.stats["failed" if err is not None
                           else "completed"] += 1
                self._cv.notify_all()
            self._completions.put(BuildResult(tag, key, plan, err, dt))

"""Background plan construction: the symbolic phase off the latency path.

Serving ticks must never wait on a plan build (ROADMAP item 1, DESIGN.md
§12): under live traffic a plan-cache miss enqueues the build *here* — a
small pool of daemon worker threads feeding a completion queue — and the
caller proceeds immediately on a fallback (the cheap synchronous host
stream, or a queued request).  The expensive part of a device plan is not
the symbolic phase itself but what hangs off it: the device lift of the
product stream and the XLA compile of the jitted numeric function.
``warm=True`` (the default) forces both inside the worker, so by the time
a build completes the serving thread's next call is a pure compiled
replay.

All builds go through :func:`repro.core.api.cached_plan`, i.e. the shared
locked plan LRU — the single-flight protocol there guarantees a build
racing a foreground request runs the symbolic phase once, whichever
thread gets there first.  The builder adds its own layer of dedup on top
(``submit`` of a key already queued or building is a no-op) so a hot
pattern arriving on every tick does not flood the queue.

Resilience (DESIGN.md §14): failed attempts retry under a seeded,
jittered capped-exponential :class:`RetryPolicy`; per-task deadlines are
enforced by a watchdog thread that marks an over-deadline task failed
(:class:`BuildTimeoutError`) and *recycles the worker* — the wedged
thread is abandoned (daemon, unwedges eventually) and a fresh worker
takes its slot, so one hung compile can never eat a worker slot forever.
Excess load is governed by a pluggable backpressure policy
(``"shed-newest"``, ``"shed-by-key-age"``, ``"block-with-deadline"``)
instead of the old binary shed.  Every failure path here is exercised by
real injected faults (``core.faults``) in ``tests/test_resilience.py``.
"""

from __future__ import annotations

import dataclasses
import queue
import random
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro.core import api, faults

#: backpressure policies for PlanBuilder(max_pending=..., backpressure=...)
BACKPRESSURE_POLICIES = ("shed-newest", "shed-by-key-age",
                         "block-with-deadline")

_WATCHDOG_TICK = 0.05   # seconds between watchdog deadline scans


class BuildTimeoutError(TimeoutError):
    """A build exceeded its deadline; the watchdog failed the task and
    recycled the worker running it."""


class BuildCancelled(RuntimeError):
    """A queued task was dropped before starting (non-drain shutdown)."""


class BuildShed(RuntimeError):
    """A queued task was evicted by backpressure (``shed-by-key-age``)."""


@dataclasses.dataclass
class RetryPolicy:
    """Capped-exponential backoff with deterministic (seeded) jitter.

    Attempt ``k`` (1-based) that fails with ``k < max_attempts`` sleeps
    ``min(max_delay, base_delay * 2**(k-1))`` scaled by a jitter factor
    drawn uniformly from ``[1 - jitter, 1 + jitter]`` before retrying.
    Deadline (watchdog) expiry does NOT retry — a hung build is assumed
    to hang again; only raising builds are considered transient.
    """

    max_attempts: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")

    def delay(self, attempt: int, rng: random.Random) -> float:
        d = min(self.max_delay, self.base_delay * (2 ** (attempt - 1)))
        if self.jitter:
            d *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return max(0.0, d)


@dataclasses.dataclass
class BuildResult:
    """One completed background task, as drained from :meth:`poll`."""

    tag: Any
    key: Optional[tuple]
    plan: Any = None
    error: Optional[BaseException] = None
    seconds: float = 0.0
    attempts: int = 1

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class _Task:
    tag: Any
    key: Optional[tuple]
    fn: Callable[[], Any]
    deadline: Optional[float]       # per-attempt wall budget, seconds
    max_attempts: int
    enqueued: float = 0.0


class _Running:
    """One attempt in flight on one worker thread (watchdog bookkeeping)."""

    __slots__ = ("task", "started", "deadline", "abandoned")

    def __init__(self, task: _Task):
        self.task = task
        self.started = time.monotonic()
        self.deadline = task.deadline
        self.abandoned = False


def warm_plan(plan) -> None:
    """Materialize a plan's expensive lazy state inside the builder.

    Touches the host product stream (the §9 lazy build), and on
    stream-capable device backends also lifts the device arrays and runs
    one throwaway numeric execution so XLA compiles the jitted stream
    function (§10) — the state a serving tick would otherwise pay for on
    first use.  Guarded plans (``plan.stream is None``) have nothing to
    warm.  Safe to call on any plan; unknown plan types are ignored.
    """
    faults.check("warm_compile", key=getattr(plan, "backend", None))
    stream = getattr(plan, "stream", None)
    if stream is None:
        return
    if getattr(plan, "backend", None) in ("jax", "mesh"):
        a_nnz = int(plan.a.col_ptr[-1])
        b_nnz = int(plan.b.col_ptr[-1])
        out = plan.stream_apply(np.zeros(a_nnz, np.float32),
                                np.zeros(b_nnz, np.float32))
        out.block_until_ready()


class PlanBuilder:
    """Thread-pool plan builder with a completion queue.

    ::

        builder = PlanBuilder()
        builder.submit(a, b, "expand", backend="jax")   # non-blocking
        ...
        for res in builder.poll():                      # drain completions
            ...
        plan, status = builder.plan_or_fallback(a, b, "expand")

    ``workers=1`` (the default) keeps device compiles serialized — XLA
    compilation is itself internally parallel, and serving cares about
    the *foreground* tick latency, not build throughput.  All workers are
    daemon threads; call :meth:`shutdown` (or use the context manager) for
    a deterministic exit.

    Resilience knobs (DESIGN.md §14): ``retry`` (a :class:`RetryPolicy`;
    failed attempts back off and retry inside the worker),
    ``build_deadline`` (default per-attempt wall budget — past it the
    watchdog fails the task with :class:`BuildTimeoutError` and recycles
    the worker), ``backpressure`` + ``max_pending`` (what happens when
    the queue is full: ``"shed-newest"`` rejects the new submit,
    ``"shed-by-key-age"`` evicts the oldest still-queued task to admit
    the new one, ``"block-with-deadline"`` blocks the submitter up to
    ``block_timeout`` seconds for a slot, then sheds).
    """

    def __init__(self, workers: int = 1, max_pending: int | None = None,
                 *, backpressure: str = "shed-newest",
                 retry: RetryPolicy | None = None,
                 build_deadline: float | None = None,
                 block_timeout: float = 1.0):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(
                f"unknown backpressure policy {backpressure!r}; one of "
                f"{BACKPRESSURE_POLICIES}")
        self._queue: "deque[_Task]" = deque()
        self._completions: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inflight: set = set()     # plan keys queued or building
        self._pending = 0               # tasks queued or running
        self._stopped = False           # no new submissions
        self._exit_event = threading.Event()    # workers + watchdog leave
        self._stop_event = threading.Event()    # cuts backoff sleeps short
        self._running: "dict[threading.Thread, _Running]" = {}
        self.max_pending = max_pending
        self.backpressure = backpressure
        self.retry = retry if retry is not None else RetryPolicy()
        self.build_deadline = build_deadline
        self.block_timeout = block_timeout
        self._jitter_rng = random.Random(self.retry.seed)
        self.stats = {"submitted": 0, "completed": 0, "failed": 0,
                      "deduped": 0, "shed": 0, "cached": 0, "rewarmed": 0,
                      "retries": 0, "timed_out": 0, "cancelled": 0,
                      "workers_recycled": 0}
        self._known: dict = {}          # plan key -> submit() kwargs
        self._rewarm_cb = None
        self._worker_seq = workers
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"plan-builder-{i}")
            for i in range(workers)]
        for t in self._threads:
            t.start()
        self._watchdog_thread = threading.Thread(
            target=self._watchdog, daemon=True, name="plan-builder-watchdog")
        self._watchdog_thread.start()
        api._register_builder(self)

    # -- submission ----------------------------------------------------------

    def submit(self, a, b, method: str | None = None, *,
               backend: str = "jax", t: float | None = None,
               b_min: int | None = None, b_max: int | None = None,
               stream_limit: int | None = None, warm: bool = True,
               deadline: float | None = None, retries: int | None = None,
               tag: Any = None) -> str:
        """Enqueue a background build of ``cached_plan(a, b, method, ...)``.

        Returns a status string, never blocks on the build itself (except
        under ``backpressure="block-with-deadline"``, which may wait up to
        ``block_timeout`` for a queue slot):

        * ``"cached"``    — the plan is already in the LRU; nothing queued.
        * ``"inflight"``  — the same key is already queued or building.
        * ``"shed"``      — backpressure dropped the build (the caller
          keeps using its fallback and may resubmit later).
        * ``"submitted"`` — queued; a :class:`BuildResult` will appear in
          :meth:`poll` when it lands in the LRU.

        ``deadline`` overrides the builder's ``build_deadline`` for this
        task; ``retries`` overrides ``retry.max_attempts``.
        """
        key = api.plan_cache_key(a, b, method, backend=backend, t=t,
                                 b_min=b_min, b_max=b_max,
                                 stream_limit=stream_limit)
        with self._lock:
            # remember how to rebuild this key so a post-shrink re-warm
            # (rewarm / enable_rewarm) can resubmit it without the caller
            self._known[key] = dict(a=a, b=b, method=method,
                                    backend=backend, t=t, b_min=b_min,
                                    b_max=b_max, stream_limit=stream_limit,
                                    warm=warm)
        if api.plan_cache_peek(key) is not None:
            self.stats["cached"] += 1
            return "cached"

        def build():
            plan = api.cached_plan(a, b, method, backend=backend, t=t,
                                   b_min=b_min, b_max=b_max,
                                   stream_limit=stream_limit)
            if warm:
                warm_plan(plan)
            return plan

        return self._enqueue(_Task(
            tag=key if tag is None else tag, key=key, fn=build,
            deadline=self.build_deadline if deadline is None else deadline,
            max_attempts=(self.retry.max_attempts if retries is None
                          else max(1, int(retries)))))

    def submit_task(self, fn: Callable[[], Any], tag: Any = None, *,
                    deadline: float | None = None,
                    retries: int | None = None) -> str:
        """Enqueue an arbitrary warm job (no key dedup).

        The serving engine uses this to trace + compile its jitted sparse
        decode step in the background (every overlay plan builds through
        the locked LRU as a side effect).  The callable's return value
        rides in ``BuildResult.plan``.  Default ``retries=1``: arbitrary
        callables are not assumed idempotent, so the builder does not
        retry them unless asked.
        """
        return self._enqueue(_Task(
            tag=tag, key=None, fn=fn,
            deadline=self.build_deadline if deadline is None else deadline,
            max_attempts=1 if retries is None else max(1, int(retries))))

    def _enqueue(self, task: _Task) -> str:
        with self._cv:
            if self._stopped:
                raise RuntimeError("PlanBuilder is shut down")
            if task.key is not None and task.key in self._inflight:
                self.stats["deduped"] += 1
                return "inflight"
            if self.max_pending is not None \
                    and self._pending >= self.max_pending:
                if self.backpressure == "block-with-deadline":
                    ok = self._cv.wait_for(
                        lambda: self._stopped
                        or self._pending < self.max_pending,
                        timeout=self.block_timeout)
                    if self._stopped:
                        raise RuntimeError("PlanBuilder is shut down")
                    if not ok:
                        self.stats["shed"] += 1
                        return "shed"
                    if task.key is not None \
                            and task.key in self._inflight:
                        # a duplicate was admitted while we blocked
                        self.stats["deduped"] += 1
                        return "inflight"
                elif self.backpressure == "shed-by-key-age" and self._queue:
                    # evict the oldest still-queued task to admit the new
                    # one; its submitter learns through the completion
                    old = self._queue.popleft()
                    self.stats["shed"] += 1
                    self._finalize_locked(old, error=BuildShed(
                        "evicted from the build queue by newer work "
                        "(backpressure: shed-by-key-age)"))
                else:   # shed-newest, or nothing queued to evict
                    self.stats["shed"] += 1
                    return "shed"
            if task.key is not None:
                self._inflight.add(task.key)
            task.enqueued = time.monotonic()
            self._pending += 1
            self.stats["submitted"] += 1
            self._queue.append(task)
            self._cv.notify()
        return "submitted"

    def plan_or_fallback(self, a, b, method: str | None = None, *,
                         backend: str = "jax",
                         fallback_backend: str = "host",
                         stream_limit: int | None = None,
                         warm: bool = True):
        """Non-blocking plan fetch for a latency-critical tick.

        Probes the LRU for the ``backend`` plan without mutating it; on a
        miss, enqueues the background build and synchronously returns the
        cheap ``fallback_backend`` plan instead (host symbolic phase only —
        no device lift, no XLA compile).  Returns ``(plan, status)`` with
        status ``"ready"`` (device plan served) or ``"fallback"``.
        """
        key = api.plan_cache_key(a, b, method, backend=backend,
                                 stream_limit=stream_limit)
        plan = api.plan_cache_peek(key)
        if plan is not None:
            return plan, "ready"
        self.submit(a, b, method, backend=backend,
                    stream_limit=stream_limit, warm=warm)
        fb = api.cached_plan(a, b, method, backend=fallback_backend,
                             stream_limit=stream_limit)
        return fb, "fallback"

    # -- post-shrink re-warm (DESIGN.md §12) ---------------------------------

    def rewarm(self, keys) -> int:
        """Resubmit builds for evicted plan keys this builder has seen.

        ``plan_cache_resize()`` shrinking below the number of in-flight
        builds silently evicts completed builds (the ``wasted_builds``
        counter in ``plan_cache_info()``); this re-queues the known ones so
        the cache re-converges in the background.  Keys this builder never
        built are skipped.  Returns the number of builds resubmitted.
        """
        count = 0
        for key in keys:
            with self._lock:
                spec = self._known.get(key)
            if spec is None:
                continue
            spec = dict(spec)
            a, b, method = spec.pop("a"), spec.pop("b"), spec.pop("method")
            try:
                if self.submit(a, b, method, tag=("rewarm", key),
                               **spec) == "submitted":
                    count += 1
                    self.stats["rewarmed"] += 1
            except RuntimeError:
                break   # shut down mid-notification; nothing to re-queue
        return count

    def enable_rewarm(self) -> None:
        """Hook :meth:`rewarm` to the plan cache's post-shrink evictions.

        Registers an ``api.register_eviction_listener`` callback that
        resubmits this builder's evicted keys after every
        ``plan_cache_resize()`` shrink (capacity-pressure evictions never
        notify, so re-warming cannot fight the LRU).  Idempotent;
        unhooked automatically by :meth:`shutdown`.
        """
        if self._rewarm_cb is None:
            def cb(keys, reason):
                if reason == "resize":
                    self.rewarm(keys)

            self._rewarm_cb = cb
            api.register_eviction_listener(cb)

    def disable_rewarm(self) -> None:
        """Unhook the :meth:`enable_rewarm` listener (idempotent)."""
        if self._rewarm_cb is not None:
            api.unregister_eviction_listener(self._rewarm_cb)
            self._rewarm_cb = None

    # -- completion / lifecycle ----------------------------------------------

    def poll(self) -> list:
        """Drain the completion queue (non-blocking)."""
        out = []
        while True:
            try:
                out.append(self._completions.get_nowait())
            except queue.Empty:
                return out

    def pending(self) -> int:
        with self._lock:
            return self._pending

    def info(self) -> dict:
        """Stats + live queue depth / worker counts — surfaced alongside
        the cache telemetry in ``plan_cache_info()['builders']``."""
        with self._lock:
            return dict(self.stats, pending=self._pending,
                        queue_depth=len(self._queue),
                        running=len(self._running),
                        workers=len(self._threads),
                        max_pending=self.max_pending,
                        backpressure=self.backpressure)

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until every queued/running task completed (tests, drain)."""
        with self._cv:
            return self._cv.wait_for(lambda: self._pending == 0, timeout)

    def shutdown(self, wait: bool = True, drain: bool = False) -> None:
        """Stop accepting work and exit the workers.  Idempotent: a second
        call is a no-op.

        ``drain=True`` finishes all queued work first (blocks until the
        queue and running tasks empty, then joins).  ``drain=False`` (the
        default) cancels queued-but-unstarted tasks — each is delivered to
        :meth:`poll` with a :class:`BuildCancelled` error and counted as
        ``cancelled`` — and cuts retry backoffs short; running attempts
        finish.  ``wait=False`` skips joining the worker threads.
        """
        self.disable_rewarm()
        with self._cv:
            if self._stopped:
                return
            self._stopped = True
        api._unregister_builder(self)
        if drain:
            self.wait_idle()
        else:
            self._stop_event.set()
            with self._cv:
                cancelled, self._queue = list(self._queue), deque()
                for task in cancelled:
                    self.stats["cancelled"] += 1
                    self._finalize_locked(task, error=BuildCancelled(
                        "builder shut down before the task started"))
        self._stop_event.set()
        self._exit_event.set()
        with self._cv:
            self._cv.notify_all()
        if wait:
            for t in list(self._threads):
                t.join()
            self._watchdog_thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- internals -----------------------------------------------------------

    def _finalize_locked(self, task: _Task, plan=None, error=None,
                         seconds: float = 0.0, attempts: int = 1) -> None:
        """Account one task's terminal state (lock held) and publish it."""
        if task.key is not None:
            self._inflight.discard(task.key)
        self._pending -= 1
        if error is None:
            self.stats["completed"] += 1
        elif isinstance(error, Exception) \
                and not isinstance(error, (BuildCancelled, BuildShed)):
            self.stats["failed"] += 1
        self._cv.notify_all()
        self._completions.put(BuildResult(task.tag, task.key, plan, error,
                                          seconds, attempts))

    def _next_task(self) -> Optional[_Task]:
        with self._cv:
            while True:
                if self._queue:
                    return self._queue.popleft()
                if self._exit_event.is_set():
                    return None
                self._cv.wait()

    def _worker(self) -> None:
        me = threading.current_thread()
        while True:
            task = self._next_task()
            if task is None:
                return
            if not self._run_task(me, task):
                return      # abandoned by the watchdog: slot was recycled

    def _run_task(self, me: threading.Thread, task: _Task) -> bool:
        """Run one task to a terminal state (retrying per policy).

        Returns False when the watchdog abandoned this thread mid-attempt
        (the task was already finalized and the worker slot recycled) —
        the zombie thread must exit instead of touching shared state.
        """
        attempt = 0
        while True:
            attempt += 1
            rec = _Running(task)
            with self._lock:
                self._running[me] = rec
            plan, err = None, None
            t0 = time.perf_counter()
            try:
                faults.check("builder_worker",
                             key=task.key if task.key is not None
                             else task.tag)
                with self._lock:
                    if rec.abandoned:
                        # the watchdog finalized this attempt while we were
                        # wedged before fn even started — don't burn the
                        # zombie thread on a build nobody will receive
                        return False
                plan = task.fn()
            except BaseException as e:  # noqa: BLE001 — reported via poll()
                err = e
            dt = time.perf_counter() - t0
            with self._cv:
                mine = self._running.pop(me, None)
                if rec.abandoned or mine is not rec:
                    return False    # watchdog finalized + replaced us
                if err is None:
                    self._finalize_locked(task, plan=plan, seconds=dt,
                                          attempts=attempt)
                    return True
                if attempt >= task.max_attempts \
                        or self._stop_event.is_set():
                    self._finalize_locked(task, error=err, seconds=dt,
                                          attempts=attempt)
                    return True
                self.stats["retries"] += 1
                backoff = self.retry.delay(attempt, self._jitter_rng)
            # outside the lock: backoff sleep, cut short by shutdown
            self._stop_event.wait(backoff)
            if self._stop_event.is_set():
                with self._cv:
                    self._finalize_locked(task, error=err, seconds=dt,
                                          attempts=attempt)
                return True

    def _watchdog(self) -> None:
        """Fail over-deadline attempts and recycle their workers.

        A worker past its task's deadline is presumed wedged (a hung
        device compile, a stuck gather): the task is finalized as failed
        with :class:`BuildTimeoutError`, the thread is abandoned (daemon;
        it exits on its own once the hang releases — its late result is
        discarded) and a fresh worker thread takes the slot, so capacity
        is never permanently lost.
        """
        while not self._exit_event.wait(_WATCHDOG_TICK):
            now = time.monotonic()
            with self._cv:
                for th, rec in list(self._running.items()):
                    if rec.deadline is None or rec.abandoned:
                        continue
                    if now - rec.started < rec.deadline:
                        continue
                    rec.abandoned = True
                    del self._running[th]
                    self.stats["timed_out"] += 1
                    self.stats["workers_recycled"] += 1
                    self._finalize_locked(rec.task, error=BuildTimeoutError(
                        f"build exceeded its {rec.deadline:.3f}s deadline; "
                        "worker recycled"))
                    try:
                        self._threads.remove(th)
                    except ValueError:
                        pass
                    nt = threading.Thread(
                        target=self._worker, daemon=True,
                        name=f"plan-builder-{self._worker_seq}")
                    self._worker_seq += 1
                    self._threads.append(nt)
                    nt.start()

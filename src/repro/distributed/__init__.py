"""Distribution layer: sharding rules, compression, pipeline, mesh SpGEMM."""

from repro.distributed.sharding import (
    batch_spec, cache_specs, dp_axes, mesh_axis_sizes, param_sharding,
    sharding_rules,
)
from repro.distributed.compression import (
    dequantize_tree, ef_compress, psum_compressed, quantize_tree,
)
from repro.distributed.pipeline import pipelined_apply, pipeline_forward
from repro.distributed.spgemm_mesh import (
    ShardedSpgemmPlan, ShardStream, plan_spgemm_mesh,
)

__all__ = [
    "batch_spec", "cache_specs", "dp_axes", "mesh_axis_sizes",
    "param_sharding", "sharding_rules", "dequantize_tree", "ef_compress",
    "psum_compressed", "quantize_tree", "pipelined_apply",
    "pipeline_forward", "ShardedSpgemmPlan", "ShardStream",
    "plan_spgemm_mesh",
]

"""Multi-device SpGEMM: the tile grid and product stream across a mesh.

Single-device execution is bounded by the plan-memory guard — a product
stream above ``fast.STREAM_MAX_PRODUCTS`` cannot live on one device, so the
biggest multiplies fell back to the slow transient host path.  This module
lifts that ceiling by composing two existing decompositions (DESIGN.md §13):

* the PR 3 outer-block-product grid — ``C[:, n] = Σ_k A[:, k] @ B[k, n]`` —
  provides tiles whose *child* streams each fit a per-shard guard, and
* the propagation-blocking formulation of Gu et al. (arXiv 2002.11302) —
  bin intermediate products by destination at plan time so the runtime
  reduction streams over contiguous segments instead of scattering —
  provides the cross-device merge shape.

:func:`plan_spgemm_mesh` builds a :class:`ShardedSpgemmPlan`: the grid is
sized so every tile's stream fits ``shard_limit`` (the guard applies *per
shard*, which is how matrices above one device's guard become plannable),
tiles are binned to devices by the PR 3/PR 5 cost model balancing predicted
flops — greedy LPT on the calibrated per-tile device-stream cost, not tile
count — and every tile's frozen product stream is rewritten into *global*
coordinates: positions into the full A/B value arrays, C slots into the
plan-wide canonical output structure (the union of the tiles' structures,
assembled per column block with the deterministic k-ordered
``merge_csc_partials`` contract).

Execution is one ``shard_map``: each device replays its own padded slice of
the stacked ``[D, Pmax]`` index arrays (gather → multiply → ``segment_sum``
into the padded slot axis), and the partial-C reduction is a single
plan-static ``psum_scatter`` over the contiguous slot segments — the
destination binning happened at plan time, so no dynamic cross-device
scatter exists at runtime.  The contraction is bilinear, so gradients are
two more sharded replays through the same frozen indices, installed with
the shared :func:`~repro.core.jax_stream.bilinear_custom_vjp` — the mesh
backend is jit-compatible and differentiable end to end.

Determinism contract: within a device, tiles accumulate in the plan's fixed
(n-major, k-ascending) order; across devices, the reduction order is the
mesh order baked into ``psum_scatter``.  Both orders are plan-static —
independent of device *completion* order — so repeated executions are
bit-identical, and integer-valued operands reproduce the single-device
host stream bit for bit (see DESIGN.md §9 for the fp-reassociation
boundary on generic floats).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

import repro.core.fast as _fast
from repro.core.cost import CostConstants, DEFAULT_CONSTANTS
from repro.core.executor import register_executor
from repro.core.jax_stream import (
    _IN_BOUNDS,
    _I32_MAX,
    _take,
    bilinear_custom_vjp,
    stream_seg_ids,
)
from repro.core.planner import (
    Pattern,
    TilePlan,
    normalize_tile_spec,
    plan_spgemm,
    resolve_params,
)
from repro.sparse.format import CSC, BatchedCSC, _np
from repro.sparse.partition import (
    csc_col_slice,
    csc_empty,
    csc_hstack,
    csc_row_slice,
    merge_csc_partials,
    nnz_balanced_col_bounds,
    width_col_bounds,
)
from repro.sparse.stats import ops_per_column, tile_stats

MESH_AXIS = "shards"


# ---------------------------------------------------------------------------
# the sharded stream: every device's replay indices, stacked and padded
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardStream:
    """Device-stacked product stream of a :class:`ShardedSpgemmPlan`.

    Row ``d`` of the ``[D, Pmax]`` arrays is device ``d``'s replay: global
    positions into the full A/B value arrays (``a_pos``/``b_pos``), the
    *global padded* C slot of each product (``seg``), and a validity mask
    (pad entries gather position 0 and point ``seg`` at the trash slot
    ``num_slots``, so they can never contaminate a real output).  The slot
    axis is padded to ``padded_slots = D * (padded_slots // D)`` so the
    cross-device reduction is one tiled ``psum_scatter`` over contiguous
    segments.  ``c_rows``/``c_col_ptr`` are the plan-wide canonical output
    structure (host, frozen), shared by every result the plan produces.
    """

    a_pos: jax.Array        # [D, Pmax] int32 into A's value array
    b_pos: jax.Array        # [D, Pmax] int32 into B's value array
    seg: jax.Array          # [D, Pmax] int32 global padded C slot
    mask: jax.Array         # [D, Pmax] bool, False on pad entries
    c_rows: np.ndarray      # [nnz_c] int32 (host, frozen)
    c_col_ptr: np.ndarray   # [n+1] int32 (host, frozen)
    shape: Tuple[int, int]
    n_products: int         # real (unpadded) products, all devices
    num_slots: int          # nnz_c
    padded_slots: int       # psum_scatter axis length, divisible by D
    per_device: np.ndarray  # [D] int64 real products per device

    @property
    def nbytes(self) -> int:
        """Device bytes held by the stacked index arrays."""
        return int(self.a_pos.nbytes + self.b_pos.nbytes
                   + self.seg.nbytes + self.mask.nbytes)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedSpgemmPlan:
    """Immutable symbolic plan for a mesh-distributed ``C = A @ B``.

    Built by :func:`plan_spgemm_mesh`; a ``backend="mesh"`` entry of the
    ``ExecutionContract`` registry.  ``tiles`` are ordinary
    :class:`~repro.core.planner.TilePlan` children (expand-method plans on
    the jax backend, shared through the plan LRU with any same-pattern
    tile); ``device_of[i]`` is the device the cost model placed
    ``tiles[i]`` on.  Execute with ``plan.execute(a, b)`` or trace
    ``plan.stream_apply(a_values, b_values)`` (jit-compatible,
    differentiable).
    """

    a: Pattern
    b: Pattern
    k_bounds: np.ndarray          # [K+1] over A's columns / B's rows
    n_bounds: np.ndarray          # [N+1] over B's columns
    tiles: Tuple[TilePlan, ...]   # non-empty tiles, n-major, k-ascending
    device_of: np.ndarray         # [n_tiles] int32 device index
    n_shards: int
    shard_limit: int              # per-shard plan-memory guard (products)
    predicted_cost: np.ndarray    # [D] float64 placed seconds per device
    predicted_flops: np.ndarray   # [D] int64 placed flops per device
    params: tuple
    _memo: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    method = "expand"             # the canonical stream contraction
    backend = "mesh"

    @property
    def contract(self):
        from repro.core import backends

        return backends.get_backend("mesh")

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.a.shape[0], self.b.shape[1])

    @property
    def grid(self) -> Tuple[int, int]:
        return (len(self.k_bounds) - 1, len(self.n_bounds) - 1)

    @property
    def stream_limit(self) -> int:
        # uniform spelling with SpgemmPlan (the guard here is per shard)
        return self.shard_limit

    @property
    def imbalance(self) -> float:
        """max/mean predicted flops across devices (1.0 = perfect)."""
        mean = float(self.predicted_flops.mean())
        if mean <= 0:
            return 1.0
        return float(self.predicted_flops.max()) / mean

    @property
    def stream(self) -> ShardStream:
        """The device-stacked sharded stream (lazy, memoized)."""
        return shard_stream(self)

    @property
    def mesh_stream_nbytes(self) -> int:
        """Bytes of stacked shard-stream index data currently held.

        Reads the memo without triggering the lazy build — what
        ``plan_cache_info()['mesh_stream_bytes']`` aggregates.  The child
        tile plans' own streams are counted by the existing host/device
        stream totals (children live in the shared LRU).
        """
        ss = self._memo.get("mesh")
        return ss.nbytes if ss is not None else 0

    @property
    def cache_key(self) -> tuple:
        # mirrors core.api's mesh LRU key
        return (self.a.fingerprint, self.b.fingerprint, self.method,
                self.backend, self.params, self.shard_limit)

    def stream_apply(self, a_values, b_values):
        """Jit-compatible, differentiable numeric phase: C values only.

        Mirrors ``SpgemmPlan.stream_apply`` for the mesh backend: value
        arrays (or tracers) aligned with the planned patterns in, the
        ``[nnz_c]`` value array of the plan's canonical output structure
        out — a pure function safe under ``jax.jit``/``jax.grad``.
        """
        self.a.check_compatible(a_values)
        self.b.check_compatible(b_values)
        return mesh_fn(self)(a_values, b_values)

    def execute(self, a_values, b_values, *, interpret: bool = True,
                stats: dict | None = None, validate: str | None = None,
                engine: str | None = None) -> CSC:
        """Numeric phase through the executor dispatch (one shard_map)."""
        from repro.core.executor import execute

        return execute(self, a_values, b_values, interpret=interpret,
                       stats=stats, validate=validate, engine=engine)

    def execute_batched(self, a_values, b_values, *, interpret: bool = True,
                        stats: dict | None = None,
                        validate: str | None = None,
                        engine: str | None = None) -> list:
        """Batched numeric phase (B same-pattern value sets)."""
        from repro.core.executor import execute_batched

        return execute_batched(self, a_values, b_values,
                               interpret=interpret, stats=stats,
                               validate=validate, engine=engine)


# ---------------------------------------------------------------------------
# planning: grid sizing, child plans, cost-model placement
# ---------------------------------------------------------------------------


def _ops_balanced_bounds(ops: np.ndarray, n_blocks: int) -> np.ndarray:
    """Column-block boundaries that roughly equalize *predicted flops*.

    The destination-binning twin of ``nnz_balanced_col_bounds``: cuts at
    the quantiles of cumulative ``Op_j`` (flops per output column), so
    column blocks carry comparable work — which is what the placement
    balances — rather than comparable stored entries.
    """
    n = len(ops)
    if n == 0:
        return np.asarray([0], np.int64)
    n_blocks = max(1, min(int(n_blocks), n))
    cum = np.concatenate(([0], np.cumsum(ops, dtype=np.int64)))
    if n == 1 or n_blocks == 1:
        return np.asarray([0, n], np.int64)
    targets = np.linspace(0, cum[-1], n_blocks + 1)[1:-1]
    cuts = np.clip(np.searchsorted(cum, targets, side="left"), 1, n - 1)
    return np.unique(np.concatenate(([0], cuts, [n]))).astype(np.int64)


def _auto_bounds(a: CSC, b: CSC, n_shards: int, budget: int) -> tuple:
    """(k_bounds, n_bounds) sized so every tile's stream fits ``budget``.

    The n axis splits at flop quantiles until the largest column block
    fits (with 2x headroom for placement slack) and there are at least a
    few tiles per device for the LPT bin-packing to balance; a single
    output column hotter than the budget then forces the k axis to split
    (a k split divides one column's products across row blocks).
    """
    ops = ops_per_column(a, b)
    total = int(ops.sum())
    target = max(1, budget // 2)
    n_cols = b.n_cols
    want = max(min(2 * n_shards, max(n_cols, 1)), -(-total // target))
    n_bounds = _ops_balanced_bounds(ops, want)
    for _ in range(32):
        if len(n_bounds) - 1 >= n_cols or len(ops) == 0:
            break
        block = np.add.reduceat(ops, n_bounds[:-1])
        if block.max() <= budget:
            break
        want *= 2
        n_bounds = _ops_balanced_bounds(ops, want)
    hottest = int(ops.max()) if len(ops) else 0
    if hottest > budget:
        k_blocks = min(max(a.n_cols, 1), -(-hottest // target))
        k_bounds = nnz_balanced_col_bounds(a, k_blocks)
    else:
        k_bounds = np.asarray([0, a.n_cols], np.int64)
    return k_bounds, n_bounds


def plan_spgemm_mesh(
    a: CSC,
    b: CSC,
    *,
    shards: int | None = None,
    tile=None,
    shard_limit: int | None = None,
    cache: bool = True,
    constants: CostConstants | None = None,
) -> ShardedSpgemmPlan:
    """Build the mesh-distributed symbolic plan for ``C = A @ B``.

    ``shards`` — mesh size (defaults to every visible device; planning for
    more shards than currently visible is allowed, execution then raises
    with the ``XLA_FLAGS`` fix).  ``shard_limit`` — the *per-shard*
    plan-memory guard (defaults to ``fast.STREAM_MAX_PRODUCTS``): the grid
    is auto-sized so every tile's stream fits it, which is how a multiply
    whose total stream exceeds the single-device guard stays plannable.
    ``tile`` — explicit ``(k_width, n_width)`` grid override (see
    ``normalize_tile_spec``); the default auto grid bins output columns at
    flop quantiles.  ``cache=True`` funnels child tile plans through the
    shared plan LRU.  Raises when the total stream cannot fit
    ``shards x shard_limit`` at all.
    """
    if a.n_cols != b.n_rows:
        raise ValueError(f"shape mismatch {a.shape} @ {b.shape}")
    n_shards = len(jax.devices()) if shards is None else int(shards)
    if n_shards < 1:
        raise ValueError(f"shards must be >= 1, got {n_shards}")
    limit = (_fast.STREAM_MAX_PRODUCTS if shard_limit is None
             else int(shard_limit))
    if limit < 1:
        raise ValueError(f"shard_limit must be >= 1, got {limit}")
    # constants=None resolves through the machine profile (core.profile):
    # a measured fit re-ranks the LPT placement below, and its provenance
    # tag becomes part of the plan params / cache key
    if constants is None:
        from repro.core import profile as _profile

        prof = _profile.current_profile()
        c, profile_tag = prof.constants, prof.tag
    else:
        c, profile_tag = constants, "explicit"

    spec = normalize_tile_spec(tile)
    k_width, n_width = spec
    auto_k, auto_n = _auto_bounds(a, b, n_shards, limit)
    k_bounds = (width_col_bounds(a.n_cols, k_width) if k_width else auto_k)
    n_bounds = (width_col_bounds(b.n_cols, n_width) if n_width else auto_n)

    def _child(ta, tb):
        if cache:
            from repro.core.api import _cached_plan

            return _cached_plan(ta, tb, "expand", "jax",
                                resolve_params("expand"),
                                stream_limit=limit)
        return plan_spgemm(ta, tb, "expand", backend="jax",
                           stream_limit=limit)

    a_tiles = [csc_col_slice(a, int(k0), int(k1))
               for k0, k1 in zip(k_bounds[:-1], k_bounds[1:])]
    tiles: list[TilePlan] = []
    tile_flops: list[int] = []
    for ni, (j0, j1) in enumerate(zip(n_bounds[:-1], n_bounds[1:])):
        b_col, (b_lo, _) = csc_col_slice(b, int(j0), int(j1))
        for ki, (k0, k1) in enumerate(zip(k_bounds[:-1], k_bounds[1:])):
            a_tile, (a_lo, a_hi) = a_tiles[ki]
            if a_tile.nnz == 0:
                continue
            b_tile, rel = csc_row_slice(b_col, int(k0), int(k1))
            if b_tile.nnz == 0:
                continue
            st = tile_stats(a_tile, b_tile)
            if st.flops == 0:
                continue
            if st.flops > limit:
                raise ValueError(
                    f"tile (k={ki}, n={ni}) carries {st.flops} products, "
                    f"above the per-shard guard shard_limit={limit}; "
                    "shrink tile= or raise shard_limit")
            tiles.append(TilePlan(
                k=ki, n=ni, a_vals=(a_lo, a_hi), b_vals=b_lo + rel,
                plan=_child(a_tile, b_tile), engine=None))
            tile_flops.append(int(st.flops))

    # LPT placement on the calibrated device-stream cost (dispatch + flat
    # per-product work): heaviest tile first onto the least-loaded device.
    # Cost is affine in flops, so balancing cost balances flops — the
    # imbalance the benchmark gates on.
    cost_of = [c.jax_base + c.jax_prod * f for f in tile_flops]
    device_of = np.zeros(len(tiles), np.int32)
    loads = np.zeros(n_shards, np.float64)
    flops_d = np.zeros(n_shards, np.int64)
    for i in sorted(range(len(tiles)), key=lambda i: -cost_of[i]):
        d = int(np.argmin(loads))
        device_of[i] = d
        loads[d] += cost_of[i]
        flops_d[d] += tile_flops[i]
    if len(tiles) and int(flops_d.max()) > limit:
        raise ValueError(
            f"placement puts {int(flops_d.max())} products on one shard, "
            f"above shard_limit={limit} (total {sum(tile_flops)} products "
            f"over {n_shards} shards); raise shards= or shard_limit=")

    params = (("profile", profile_tag), ("shard_limit", limit),
              ("shards", n_shards), ("tile", spec))
    return ShardedSpgemmPlan(
        Pattern.of(a), Pattern.of(b),
        np.asarray(k_bounds, np.int64), np.asarray(n_bounds, np.int64),
        tuple(tiles), device_of, n_shards, limit,
        loads, flops_d, params)


# ---------------------------------------------------------------------------
# plan -> ShardStream: global structure, destination bins, stacked indices
# ---------------------------------------------------------------------------


def _mesh_guard_error(plan, tile) -> ValueError:
    return ValueError(
        f"tile (k={tile.k}, n={tile.n}) of the mesh plan has no product "
        f"stream (child guard shard_limit={plan.shard_limit} tripped); "
        "replan with a higher shard_limit or a finer tile grid")


def shard_stream(plan: ShardedSpgemmPlan) -> ShardStream:
    """Build (lazily, memoized) the plan's device-stacked stream.

    Three plan-time passes, all pattern-only:

    1. **Global structure** — per column block, the tiles' child C
       structures merge through the deterministic k-ordered
       ``merge_csc_partials`` contract (values zero — structure union
       only); blocks stitch into the plan-wide canonical CSC structure.
    2. **Destination binning** — each tile's child stream slots map into
       the global slot space with one ``searchsorted`` per tile (child
       structures are sub-sequences of their block's union), and the slot
       axis pads to a multiple of D so the runtime reduction is a tiled
       ``psum_scatter`` over contiguous segments.
    3. **Stacking** — per device, its tiles' streams concatenate in the
       plan's fixed n-major/k-ascending order, rewritten to global A/B
       value positions, padded to the longest device's length (pads mask
       off and point at the trash slot past ``nnz_c``).
    """
    memo = plan._memo
    if "mesh" in memo:
        return memo["mesh"]
    m, n = plan.shape
    D = plan.n_shards
    N = len(plan.n_bounds) - 1

    per_block: dict = {ni: [] for ni in range(N)}
    for ti, t in enumerate(plan.tiles):
        s = t.plan.stream
        if s is None:
            raise _mesh_guard_error(plan, t)
        per_block[t.n].append((ti, t, s))

    # pass 1: global canonical structure (per-block k-ordered union)
    blocks = []
    for ni in range(N):
        w = int(plan.n_bounds[ni + 1] - plan.n_bounds[ni])
        parts = [CSC(np.zeros(s.nnz), s.c_rows, s.c_col_ptr, (m, w))
                 for _, _, s in per_block[ni]]
        blocks.append(merge_csc_partials(parts, (m, w))
                      if parts else csc_empty((m, w)))
    gc = csc_hstack(blocks, m) if blocks else csc_empty((m, 0))
    c_rows = np.ascontiguousarray(_np(gc.row_indices), np.int32)
    c_col_ptr = np.ascontiguousarray(_np(gc.col_ptr), np.int32)
    nnz_c = int(c_col_ptr[-1])
    block_off = np.concatenate(
        ([0], np.cumsum([blk.nnz for blk in blocks]))).astype(np.int64)

    # pass 2+3: per-device global index streams (plan order within device)
    dev_parts: list = [[] for _ in range(D)]
    for ni in range(N):
        blk = blocks[ni]
        key_b = (np.repeat(np.arange(blk.n_cols, dtype=np.int64),
                           np.diff(_np(blk.col_ptr).astype(np.int64)))
                 * m + _np(blk.row_indices).astype(np.int64))
        for ti, t, s in per_block[ni]:
            key_t = (np.repeat(np.arange(s.shape[1], dtype=np.int64),
                               np.diff(s.c_col_ptr.astype(np.int64)))
                     * m + s.c_rows.astype(np.int64))
            slot = np.searchsorted(key_b, key_t) + block_off[ni]
            seg = slot[stream_seg_ids(s)]
            a_idx = t.a_vals[0] + s.a_pos
            b_idx = np.asarray(t.b_vals, np.int64)[s.b_pos]
            dev_parts[int(plan.device_of[ti])].append((a_idx, b_idx, seg))

    per_device = np.asarray(
        [sum(len(p[0]) for p in parts) for parts in dev_parts], np.int64)
    total = int(per_device.sum())
    p_max = max(1, int(per_device.max()) if D else 1)
    s_per = -(-(nnz_c + 1) // D)          # >= 1 trash slot past nnz_c
    s_pad = D * s_per
    if max(int(plan.a.col_ptr[-1]), int(plan.b.col_ptr[-1]),
           s_pad, p_max) > _I32_MAX:
        raise ValueError(
            f"sharded stream of {total} products over operands of nnz "
            f"{int(plan.a.col_ptr[-1])}/{int(plan.b.col_ptr[-1])} exceeds "
            "int32 device indexing; lower shard_limit or shrink the tiles")

    ap = np.zeros((D, p_max), np.int32)
    bp = np.zeros((D, p_max), np.int32)
    sg = np.full((D, p_max), nnz_c, np.int32)   # pads -> the trash slot
    mk = np.zeros((D, p_max), bool)
    for d, parts in enumerate(dev_parts):
        if not parts:
            continue
        a_idx = np.concatenate([p[0] for p in parts])
        b_idx = np.concatenate([p[1] for p in parts])
        seg = np.concatenate([p[2] for p in parts])
        L = len(a_idx)
        ap[d, :L] = a_idx
        bp[d, :L] = b_idx
        sg[d, :L] = seg
        mk[d, :L] = True
    with jax.ensure_compile_time_eval():
        dev_arrays = (jnp.asarray(ap), jnp.asarray(bp),
                      jnp.asarray(sg), jnp.asarray(mk))
    memo["mesh"] = ShardStream(
        a_pos=dev_arrays[0], b_pos=dev_arrays[1], seg=dev_arrays[2],
        mask=dev_arrays[3], c_rows=c_rows, c_col_ptr=c_col_ptr,
        shape=(m, n), n_products=total, num_slots=nnz_c,
        padded_slots=s_pad, per_device=per_device)
    return memo["mesh"]


# ---------------------------------------------------------------------------
# execution: one shard_map, plan-static psum_scatter reduction, custom vjp
# ---------------------------------------------------------------------------


def _device_mesh(n_shards: int) -> Mesh:
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"mesh plan needs {n_shards} devices, found {len(devs)}; run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} (or replan with shards={len(devs)})")
    return Mesh(np.asarray(devs[:n_shards]), (MESH_AXIS,))


def _pad_to(vec, length):
    """Zero-pad a 1-D array to ``length`` (identity when already there)."""
    if vec.shape[0] == length:
        return vec
    return jnp.zeros((length,), vec.dtype).at[:vec.shape[0]].set(vec)


def mesh_fn(plan: ShardedSpgemmPlan):
    """The plan's jitted sharded numeric function ``f(av, bv) -> c_values``.

    Memoized on the plan.  Forward: every shard gathers/multiplies its own
    ``[Pmax]`` product slice, ``segment_sum``s into the padded global slot
    axis, and one tiled ``psum_scatter`` finishes the reduction — each
    device keeps its contiguous destination bin, and the stitched output
    slices back to ``[nnz_c]``.  Gradients are the same shape twice over
    (bilinear contraction): cotangents broadcast back over the products
    and scatter-add into padded *operand* axes, reduced by the same
    plan-static ``psum_scatter``, so ``jax.grad`` costs two more sharded
    replays.
    """
    memo = plan._memo
    if "mesh_fn" in memo:
        return memo["mesh_fn"]
    ss = shard_stream(plan)
    nnz_a = int(plan.a.col_ptr[-1])
    nnz_b = int(plan.b.col_ptr[-1])
    nnz_c, s_pad = ss.num_slots, ss.padded_slots
    D = plan.n_shards

    if ss.n_products == 0:
        # nothing to contract: C values are structurally zero (or empty)
        def forward(av, bv):
            dt = jnp.result_type(jnp.asarray(av).dtype,
                                 jnp.asarray(bv).dtype)
            return jnp.zeros((nnz_c,), dt)

        def grad_a(g, av, bv):
            return jnp.zeros_like(jnp.asarray(av))

        def grad_b(g, av, bv):
            return jnp.zeros_like(jnp.asarray(bv))
    else:
        mesh = _device_mesh(D)
        P = PartitionSpec
        a_pad = D * (-(-max(nnz_a, 1) // D))
        b_pad = D * (-(-max(nnz_b, 1) // D))
        sharded = functools.partial(
            shard_map, mesh=mesh, check_rep=False,
            in_specs=(P(), P(), P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS),
                      P(MESH_AXIS)),
            out_specs=P(MESH_AXIS))

        def _scatter(part):
            return jax.lax.psum_scatter(part, MESH_AXIS,
                                        scatter_dimension=0, tiled=True)

        @sharded
        def _fwd(av, bv, ap, bp, sg, mk):
            prod = jnp.where(mk[0], _take(av, ap[0]) * _take(bv, bp[0]), 0)
            part = jax.ops.segment_sum(prod, sg[0], num_segments=s_pad,
                                       mode=_IN_BOUNDS)
            return _scatter(part)

        @sharded
        def _grad_a(gp, bv, ap, bp, sg, mk):
            gq = _take(gp, sg[0])
            contrib = jnp.where(mk[0], gq * _take(bv, bp[0]), 0)
            part = jax.ops.segment_sum(contrib, ap[0], num_segments=a_pad,
                                       mode=_IN_BOUNDS)
            return _scatter(part)

        @sharded
        def _grad_b(gp, av, ap, bp, sg, mk):
            gq = _take(gp, sg[0])
            contrib = jnp.where(mk[0], gq * _take(av, ap[0]), 0)
            part = jax.ops.segment_sum(contrib, bp[0], num_segments=b_pad,
                                       mode=_IN_BOUNDS)
            return _scatter(part)

        idx = (ss.a_pos, ss.b_pos, ss.seg, ss.mask)

        def forward(av, bv):
            return _fwd(av, bv, *idx)[:nnz_c]

        def _fit(cot, primal, nnz):
            # the cotangent must match the primal operand's (possibly
            # oversized) value-array shape; positions past nnz never
            # entered the contraction, so their cotangent is zero
            want = jnp.asarray(primal).shape[0]
            cot = cot[:nnz]
            if want == nnz:
                return cot
            return jnp.zeros((want,), cot.dtype).at[:nnz].set(cot)

        def grad_a(g, av, bv):
            gp = _pad_to(g, s_pad)
            return _fit(_grad_a(gp, bv, *idx), av, nnz_a)

        def grad_b(g, av, bv):
            gp = _pad_to(g, s_pad)
            return _fit(_grad_b(gp, av, *idx), bv, nnz_b)

    memo["mesh_contract"] = bilinear_custom_vjp(forward, grad_a, grad_b)
    memo["mesh_fn"] = jax.jit(memo["mesh_contract"])
    return memo["mesh_fn"]


def _operand_values(operand):
    return operand.values if isinstance(operand, (CSC, BatchedCSC)) \
        else operand


def _record_stats(plan, ss, stats):
    if stats is None:
        return
    stats.update(engine="stream", backend="mesh", device=True,
                 shards=plan.n_shards, grid=plan.grid,
                 stream_products=ss.n_products,
                 per_device_products=ss.per_device.tolist(),
                 imbalance=plan.imbalance, result_shape=ss.shape)


def execute_mesh(plan, a_values, b_values, *, interpret: bool = True,
                 stats: dict | None = None,
                 validate: str | None = None) -> CSC:
    """Numeric phase of a mesh plan (executor dispatch target).

    One jitted ``shard_map`` dispatch; the result's values are a device
    array on the plan's canonical global output structure.  ``interpret``
    is accepted for signature uniformity and ignored.
    """
    del interpret
    plan.a.check_compatible(a_values, validate)
    plan.b.check_compatible(b_values, validate)
    av = _operand_values(a_values)
    bv = _operand_values(b_values)
    vals = mesh_fn(plan)(av, bv)
    ss = shard_stream(plan)
    _record_stats(plan, ss, stats)
    return CSC(vals, ss.c_rows, ss.c_col_ptr, ss.shape)


def execute_mesh_batched(plan, a_values, b_values, *,
                         interpret: bool = True,
                         stats: dict | None = None,
                         validate: str | None = None) -> list:
    """Batched numeric phase: B value sets through the sharded replay.

    Dispatches the jitted sharded function once per batch element (the
    collective-bearing ``shard_map`` does not ride ``vmap``); results are
    bit-identical to looping :func:`execute_mesh` by construction.
    """
    del interpret
    from repro.core.executor import _check_batch

    plan.a.check_batched_compatible(a_values, validate)
    plan.b.check_batched_compatible(b_values, validate)
    av = _operand_values(a_values)
    bv = _operand_values(b_values)
    batch = _check_batch(av, bv)
    fn = mesh_fn(plan)
    ss = shard_stream(plan)
    out = [CSC(fn(av[i], bv[i]), ss.c_rows, ss.c_col_ptr, ss.shape)
           for i in range(batch)]
    _record_stats(plan, ss, stats)
    if stats is not None:
        stats["batch"] = batch
    return out


register_executor("mesh", "stream", execute_mesh, execute_mesh_batched)

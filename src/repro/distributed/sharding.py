"""Sharding rules: logical parameter/activation axes -> mesh axes.

One rule set serves all ten architectures (DESIGN.md §5):
  * TP over 'model'  — heads (fused q/kv dims), d_ff, experts, vocab, d_inner
  * FSDP over 'data' (+ 'pod' when present) — the d_model ('embed') axis of
    every weight, so parameters + optimizer state are fully sharded (ZeRO-3);
    GSPMD inserts the all-gathers at use sites
  * DP over ('pod','data') — the batch dim of every activation/input
Divisibility fallbacks are applied per-tensor in params.partition_specs.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh) -> tuple:
    """Axes carrying data parallelism (pod is DP unless pipelining)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def sharding_rules(mesh: Mesh, mode: str = "train") -> dict:
    """mode="train": ZeRO-3 (params+optimizer FSDP over dp) x TP.
    mode="serve": params replicated over dp, TP only — decode reads every
    weight once per token, so per-token FSDP all-gathers would dominate the
    step (§Perf iteration 4); replication costs params_bytes/TP per chip."""
    dp = dp_axes(mesh)
    return {
        "__sizes__": mesh_axis_sizes(mesh),
        # parameters
        "embed": dp if mode == "train" else None,  # FSDP on d_model (train)
        "vocab": "model",
        "mlp": "model",
        "heads": "model",         # fused (n_heads * d_head) projection dim
        # EP: train shards experts over TP; serving shards them over DP so
        # per-chip expert bytes stay bounded with replicated dense weights
        "experts": "model" if mode == "train" else tuple(dp),
        "ssm_inner": "model",
        "layers": None,           # scan axis never sharded
        None: None,
    }


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """[B, ...] activations/inputs: shard B over the DP axes that divide it."""
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    chosen = None
    for k in range(len(dp), 0, -1):
        prod = 1
        for a in dp[:k]:
            prod *= sizes[a]
        if batch % prod == 0:
            chosen = dp[:k]
            break
    lead = chosen if chosen is None or len(chosen) > 1 else chosen[0]
    return P(lead, *([None] * extra_dims))


def param_sharding(table_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), table_specs,
        is_leaf=lambda x: isinstance(x, P))


def cache_specs(cfg, cache_abstract, mesh: Mesh):
    """Serve-cache sharding, leaf-by-leaf (DESIGN.md §5).

    KV caches [rep, B, S, Hkv, Dh]: B over DP when divisible; heads over
    'model' when divisible, else the sequence dim (context-parallel cache).
    SSM states: d_inner over 'model'. Cross-memory caches like KV.
    """
    sizes = mesh_axis_sizes(mesh)
    dp = dp_axes(mesh)
    model = sizes.get("model", 1)

    def dp_for(b):
        for k in range(len(dp), 0, -1):
            prod = 1
            for a in dp[:k]:
                prod *= sizes[a]
            if b % prod == 0:
                return dp[:k] if k > 1 else dp[0]
        return None

    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv"):      # [rep, B, S, H, Dh]
            _, b, s, h, _ = shape
            bspec = dp_for(b)
            if h % model == 0 and h >= model:
                return P(None, bspec, None, "model", None)
            if s % model == 0:
                return P(None, bspec, "model", None, None)
            return P(None, bspec, None, None, None)
        if name == "conv":                       # [rep, B, K-1, d_inner]
            din = shape[-1]
            return P(None, dp_for(shape[1]), None,
                     "model" if din % model == 0 else None)
        if name == "h":                          # mamba state
            if len(shape) == 4:                  # [rep, B, din, ds]
                din = shape[2]
                return P(None, dp_for(shape[1]),
                         "model" if din % model == 0 else None, None)
            # [rep, B, nh, hd, ds]
            nh = shape[2]
            return P(None, dp_for(shape[1]),
                     "model" if nh % model == 0 else None, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_abstract)

"""Optional pipeline parallelism over the 'pod' axis (GPipe schedule).

The default multi-pod posture treats 'pod' as DP (lower collective volume at
2 pods — EXPERIMENTS.md §Perf); this module provides the alternative: layer
stages sharded over 'pod', microbatches streamed with collective_permute, for
topologies where cross-pod all-reduce is the bottleneck.

Implementation: shard_map over the stage axis. Stage s holds stacked
super-block params slice s. The classic GPipe loop runs n_micro + n_stages-1
ticks; at each tick a stage processes the activation it received last tick
and ppermutes its output to stage s+1. Bubbles are masked compute.

Compile-checked in the multi-pod dry-run (--pipeline); numerically validated
against the unpipelined model on a 1-stage degenerate mesh in tests and on
4 fake devices in the dry-run harness.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_forward(stage_fn, n_stages: int, axis: str = "pod"):
    """Build fn(stage_params, x_micro) -> y_micro running under shard_map.

    stage_params: pytree with leading stage axis (sharded over ``axis``).
    x_micro: [n_micro, Bm, S, D] microbatched activations (replicated).
    stage_fn(params_slice, x) -> y, applied by every stage to its slice.
    """

    def run(stage_params, x_micro):
        stage_id = jax.lax.axis_index(axis)
        n_micro = x_micro.shape[0]
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_micro[0])
        outs = jnp.zeros_like(x_micro)

        p_local = jax.tree_util.tree_map(lambda a: a[0], stage_params)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            feed = jnp.where(t < n_micro, 1, 0)
            x_in = jnp.where(
                (stage_id == 0) & (feed == 1),
                x_micro[jnp.minimum(t, n_micro - 1)], buf)
            y = stage_fn(p_local, x_in)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)
            emit = (stage_id == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # pass activations downstream (ring permute; stage 0 receives
            # garbage from the last stage and overwrites it on ingest)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return run


def pipelined_apply(mesh: Mesh, stage_fn, stage_params, x_micro,
                    axis: str = "pod"):
    """shard_map wrapper; stage_params leading dim == mesh axis size."""
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    run = pipeline_forward(stage_fn, n_stages, axis)
    spec_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        run, mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x_micro)

"""Activation-sharding hints: with_sharding_constraint with graceful fallback.

GSPMD propagates parameter shardings well, but on models whose head counts
don't divide the TP axis it falls back to contraction-dim sharding inside
attention (all-reducing score tensors every step) and can drop the batch
sharding of the residual stream entirely — both observed in the baseline
dry-runs (EXPERIMENTS.md §Perf, iteration 1). These hints pin the sharding of
the residual stream, attention heads, and MoE dispatch buffers wherever the
dimensions divide; on a 1-device mesh (tests, examples) they are no-ops.

Dim vocabulary: 'dp' (batch over pod+data), 'model', 'kv_or_seq', None.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def current_mesh():
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def _dp_part(mesh, size):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for k in range(len(dp), 0, -1):
        prod = 1
        for a in dp[:k]:
            prod *= sizes[a]
        if size % prod == 0 and prod > 1:
            return dp[:k] if k > 1 else dp[0]
    return None


def _manual_axes() -> bool:
    """True when tracing inside shard_map (Manual mesh axes): constraints
    are illegal there — the caller already owns the layout."""
    try:
        am = jax.sharding.get_abstract_mesh()
        return (not am.empty) and any(
            "Manual" in str(t) for t in am.axis_types)
    except Exception:
        return False


def hint(x, *dims):
    """Constrain x's sharding; silently no-op without an active mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.devices.size == 1 or _manual_axes():
        return x
    if len(dims) != x.ndim:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    parts = []
    used_model = False
    for size, d in zip(x.shape, dims):
        if d == "dp":
            parts.append(_dp_part(mesh, size))
        elif d == "model" and not used_model and model > 1 \
                and size % model == 0:
            parts.append("model")
            used_model = True
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))


def hint_heads(x, *, batch_dim=0, head_dims=(2, 3)):
    """Attention tensors [B, S, Hkv, (G,) Dh]: shard the first head-ish dim
    that divides the model axis; otherwise leave heads unsharded (batch-DP
    attention — the non-divisible-head fallback, DESIGN.md §5)."""
    mesh = current_mesh()
    if mesh is None or mesh.devices.size == 1 or _manual_axes():
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    parts = [None] * x.ndim
    parts[batch_dim] = _dp_part(mesh, x.shape[batch_dim])
    if model > 1:
        for hd in head_dims:
            if hd < x.ndim - 1 and x.shape[hd] % model == 0:
                parts[hd] = "model"
                break
    return jax.lax.with_sharding_constraint(x, P(*parts))

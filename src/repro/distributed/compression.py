"""Gradient compression: int8 quantization with error feedback.

For bandwidth-bound DP reductions, gradients can be all-reduced in int8 with
per-row scales; the quantization residual is fed back into the next step so
the compression error stays bounded instead of accumulating (EF-SGD). In the
pjit/GSPMD world explicit all-reduces are implicit in autodiff, so this is
exposed as (a) a wrapper for the grad-accumulation buffer, and (b)
``psum_compressed`` for shard_map deployments (used by the pipeline module).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_tree(tree):
    """int8 + per-row fp32 absmax scales; 1-D leaves pass through."""

    def q(x):
        if x.ndim < 2:
            return {"raw": x}
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-20)
        return {"q": jnp.clip(jnp.round(x / scale), -127, 127
                              ).astype(jnp.int8),
                "scale": scale.astype(jnp.float32)}

    return jax.tree_util.tree_map(q, tree)


def dequantize_tree(qtree):
    def d(leaf):
        if "raw" in leaf:
            return leaf["raw"]
        return leaf["q"].astype(jnp.float32) * leaf["scale"]

    return jax.tree_util.tree_map(
        d, qtree, is_leaf=lambda x: isinstance(x, dict)
        and ("q" in x or "raw" in x))


def ef_compress(grads, residual):
    """(compressed, new_residual): quantize grads+residual, keep the error."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    comp = quantize_tree(corrected)
    deq = dequantize_tree(comp)
    new_residual = jax.tree_util.tree_map(
        lambda c, d: c - d, corrected, deq)
    return comp, new_residual


def psum_compressed(grads, axis_name: str):
    """shard_map helper: all-reduce int8-quantized grads over ``axis_name``.

    Dequantize -> psum -> return fp32 mean. (Scales are reduced with the
    payload; int8 payloads are summed in int32 to avoid overflow.)
    """

    def reduce_leaf(x):
        if x.ndim < 2:
            return jax.lax.pmean(x, axis_name)
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-20)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        # sum of per-shard dequantized values == dequantize with shared scale
        # only when scales match; reduce exactly by moving to fp before psum
        return jax.lax.pmean(q.astype(jnp.float32) * scale, axis_name)

    return jax.tree_util.tree_map(reduce_leaf, grads)

"""Tiled SpGEMM with cost-model-driven per-tile method selection
(DESIGN.md §8): differential correctness of ``method="auto"`` on the
adversarial harness, bit-identity of column-only grids, exact equality of
2D grids, degenerate tiles, batched execution, and plan-cache sharing."""

import numpy as np
import pytest

from conftest import bit_identical as _bit_identical
from test_differential import CASES, _adversarial, oracle_product

from repro.core import (
    ALGORITHMS,
    AUTO_CANDIDATES,
    choose_method,
    estimate_cost,
    plan_cache_clear,
    plan_cache_info,
    plan_spgemm_tiled,
    spgemm,
    spgemm_batched,
)
from repro.sparse import BatchedCSC, random_powerlaw_csc, tile_stats, \
    validate_csc
from repro.sparse.format import CSC, csc_from_dense, csc_to_dense


def _integerize(m: CSC, seed: int = 0) -> CSC:
    """Same pattern, small-integer values: every sum is exact in fp, so
    tiled (re-associated) results must equal untiled ones with atol=0."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 4, size=m.nnz).astype(np.float64)
    return CSC(vals, m.row_indices, m.col_ptr, m.shape)


# --- method="auto" against the differential harness ------------------------


@pytest.mark.parametrize("case", CASES)
def test_auto_differential_host(case):
    a, b = _adversarial(case)
    c = spgemm(a, b, method="auto", cache=False)
    validate_csc(c)
    np.testing.assert_allclose(
        csc_to_dense(c), oracle_product(a, b), rtol=1e-9, atol=1e-11,
        err_msg=f"auto diverged from the oracle on {case!r}")


@pytest.mark.parametrize("case", CASES)
def test_auto_differential_pallas(case):
    a, b = _adversarial(case)
    c = spgemm(a, b, method="auto", backend="pallas", cache=False)
    validate_csc(c)
    np.testing.assert_allclose(
        csc_to_dense(c), oracle_product(a, b), rtol=1e-4, atol=1e-5,
        err_msg=f"pallas auto diverged from the oracle on {case!r}")


@pytest.mark.parametrize("case", CASES)
def test_auto_2d_grid_exact_vs_single_plan_host(case):
    """Explicit 2D grids: with integer values every fp sum is exact, so the
    tiled result must equal the untiled single-plan result with atol=0
    after canonical (dense) ordering — on every adversarial pattern."""
    a, b = _adversarial(case)
    a, b = _integerize(a, 1), _integerize(b, 2)
    single = csc_to_dense(spgemm(a, b, method="spa", cache=False))
    plan = plan_spgemm_tiled(a, b, tile=(8, 8), cache=False)
    tiled = plan.execute(a, b)
    validate_csc(tiled)
    np.testing.assert_array_equal(csc_to_dense(tiled), single)
    # auto-sized grid as well
    auto = spgemm(a, b, method="auto", cache=False)
    np.testing.assert_array_equal(csc_to_dense(auto), single)


@pytest.mark.parametrize("case", ("random", "empty_cols", "dup_heavy"))
def test_auto_2d_grid_exact_vs_single_plan_pallas(case):
    a, b = _adversarial(case)
    a, b = _integerize(a, 3), _integerize(b, 4)
    single = csc_to_dense(
        spgemm(a, b, method="spa", backend="pallas", cache=False))
    plan = plan_spgemm_tiled(a, b, backend="pallas", tile=(8, 8),
                             cache=False)
    tiled = plan.execute(a, b)
    np.testing.assert_array_equal(csc_to_dense(tiled), single)


# --- column-only grids are bit-identical to the untiled method -------------


@pytest.mark.parametrize("method", sorted(ALGORITHMS))
def test_column_tiling_bit_identical_host(method):
    a = random_powerlaw_csc(48, 3.0, seed=11)
    fresh = spgemm(a, a, method=method, cache=False)
    plan = plan_spgemm_tiled(a, a, tile=(a.n_cols, 7),
                             candidates=(method,), cache=False)
    assert plan.grid[0] == 1
    assert _bit_identical(plan.execute(a, a), fresh), method


@pytest.mark.parametrize("method", ("spa", "h-hash-256/256"))
def test_column_tiling_bit_identical_pallas(method):
    a = random_powerlaw_csc(36, 3.0, seed=12)
    fresh = spgemm(a, a, method=method, backend="pallas", cache=False)
    plan = plan_spgemm_tiled(a, a, backend="pallas", tile=(a.n_cols, 12),
                             candidates=(method,), cache=False)
    assert _bit_identical(plan.execute(a, a), fresh), method


# --- degenerate tiles (ISSUE 3 satellite) ----------------------------------


def test_tile_larger_than_matrix_is_single_tile():
    a = random_powerlaw_csc(20, 3.0, seed=13)
    plan = plan_spgemm_tiled(a, a, tile=(1000, 1000),
                             candidates=("spa",), cache=False)
    assert plan.grid == (1, 1) and len(plan.tiles) == 1
    assert _bit_identical(plan.execute(a, a),
                          spgemm(a, a, method="spa", cache=False))


def test_all_empty_column_blocks():
    # B columns 8..23 empty: two whole column blocks produce no tiles
    d = np.zeros((32, 32))
    rng = np.random.default_rng(14)
    d[:, :8] = rng.normal(size=(32, 8)) * (rng.uniform(size=(32, 8)) < 0.4)
    d[:, 24:] = rng.normal(size=(32, 8)) * (rng.uniform(size=(32, 8)) < 0.4)
    a = csc_from_dense(d)
    plan = plan_spgemm_tiled(a, a, tile=(8, 8), cache=False)
    assert {t.n for t in plan.tiles}.isdisjoint({1, 2})
    c = plan.execute(a, a)
    validate_csc(c)
    np.testing.assert_allclose(csc_to_dense(c), oracle_product(a, a),
                               rtol=1e-9, atol=1e-11)
    # empty A column blocks drop the matching row-block tiles too
    assert {t.k for t in plan.tiles}.isdisjoint({1, 2})


def test_empty_operands_produce_no_tiles():
    e = csc_from_dense(np.zeros((16, 16)))
    plan = plan_spgemm_tiled(e, e, tile=(4, 4), cache=False)
    assert plan.tiles == ()
    c = plan.execute(e, e)
    assert c.shape == (16, 16) and c.nnz == 0


def test_width_one_tiles():
    a = random_powerlaw_csc(12, 2.0, seed=15)
    plan = plan_spgemm_tiled(a, a, tile=(a.n_cols, 1),
                             candidates=("expand",), cache=False)
    assert plan.grid[1] == a.n_cols
    assert _bit_identical(plan.execute(a, a),
                          spgemm(a, a, method="expand", cache=False))


# --- batched tiled execution ----------------------------------------------


def test_auto_batched_bit_identical_to_looped():
    a = random_powerlaw_csc(40, 3.0, seed=16)
    rng = np.random.default_rng(17)
    vals = rng.normal(size=(4, a.nnz))
    plan = plan_spgemm_tiled(a, a, tile=(13, 9), cache=False)
    looped = [plan.execute(vals[i], vals[i]) for i in range(4)]
    batched = plan.execute_batched(vals, vals)
    assert len(batched) == 4
    for x, y in zip(batched, looped):
        assert _bit_identical(x, y)
    # the spgemm_batched entry point rides the same path
    ab = BatchedCSC.from_values(a, vals)
    via_api = spgemm_batched(ab, ab, method="auto", tile=(13, 9),
                             cache=False)
    for x, y in zip(via_api, looped):
        assert _bit_identical(x, y)


def test_auto_batched_pallas_single_launch_set():
    a = random_powerlaw_csc(24, 2.0, seed=18)
    rng = np.random.default_rng(19)
    vals = rng.normal(size=(3, a.nnz))
    plan = plan_spgemm_tiled(a, a, backend="pallas", tile=(24, 12),
                             cache=False)
    stats = {}
    batched = plan.execute_batched(vals, vals, stats=stats)
    assert stats["batch"] == 3
    assert stats["n_launches"] > 0     # aggregated over tiles, B-independent
    looped = [plan.execute(vals[i], vals[i]) for i in range(3)]
    for x, y in zip(batched, looped):
        assert _bit_identical(x, y)


# --- plan caching and tile-pattern sharing ---------------------------------


def test_tiled_plan_cached_and_tiles_shared():
    plan_cache_clear()
    a = random_powerlaw_csc(30, 3.0, seed=20)
    # B with two identical-pattern column blocks -> identical tile patterns
    dup = csc_from_dense(np.hstack([csc_to_dense(a)[:, :15]] * 2))
    c1 = spgemm(a, dup, method="auto", tile=(a.n_cols, 15))
    plan = plan_spgemm_tiled(a, dup, tile=(a.n_cols, 15))  # LRU hit
    assert len(plan.tiles) == 2
    # identical tile patterns share one child plan through the LRU
    assert plan.tiles[0].plan is plan.tiles[1].plan
    before = plan_cache_info()["hits"]
    c2 = spgemm(a, dup, method="auto", tile=(a.n_cols, 15))
    assert plan_cache_info()["hits"] > before
    assert _bit_identical(c1, c2)
    plan_cache_clear()


def test_tiled_held_plan_through_spgemm():
    a = random_powerlaw_csc(26, 3.0, seed=21)
    plan = plan_spgemm_tiled(a, a, tile=(9, 9), cache=False)
    assert _bit_identical(spgemm(a, a, plan=plan), plan.execute(a, a))
    # fingerprint validation works on tiled plans too
    bigger = random_powerlaw_csc(26, 5.0, seed=22)
    with pytest.raises(ValueError, match="pattern does not match"):
        spgemm(bigger, bigger, plan=plan)


def test_tiled_execute_stats():
    a = random_powerlaw_csc(40, 3.0, seed=23)
    plan = plan_spgemm_tiled(a, a, tile=(13, 9), cache=False)
    stats = {}
    plan.execute(a, a, stats=stats)
    k_blocks, n_blocks = stats["grid"]
    assert k_blocks > 1 and n_blocks > 1
    assert stats["tiles"] and all(
        set(t) == {"k", "n", "method"} for t in stats["tiles"])
    assert stats["merged_blocks"] > 0
    assert stats["result_shape"] == (40, 40)


# --- the cost model --------------------------------------------------------


def _dense_tile_stats():
    rng = np.random.default_rng(24)
    a = csc_from_dense(rng.uniform(0.5, 1.5, size=(64, 64)))
    b = csc_from_dense(
        (rng.uniform(size=(64, 8)) < 0.5) * rng.uniform(size=(64, 8)))
    return tile_stats(a, b)


def _sparse_tile_stats():
    a = random_powerlaw_csc(64, 1.5, seed=25)
    b = random_powerlaw_csc(64, 1.5, seed=26)
    return tile_stats(a, b)


def _guard_tripped_tile_stats():
    """A tile whose product stream exceeds the plan-memory guard: flops =
    nnz_b * m > fast.STREAM_MAX_PRODUCTS (pattern built directly — values
    are never read by the cost model)."""
    import repro.core.fast as fast

    k, nb, per = 64, 8, 32
    m = fast.STREAM_MAX_PRODUCTS // (nb * per) + 1
    a = CSC(np.zeros(0), np.tile(np.arange(m, dtype=np.int32), k),
            np.arange(k + 1, dtype=np.int32) * m, (m, k))
    rng = np.random.default_rng(29)
    b_rows = np.concatenate(
        [np.sort(rng.choice(k, size=per, replace=False)) for _ in range(nb)])
    b = CSC(np.zeros(0), b_rows.astype(np.int32),
            np.arange(nb + 1, dtype=np.int32) * per, (k, nb))
    return tile_stats(a, b)


def test_cost_model_host_regimes():
    # while the product stream fits the plan-memory guard the stream engine
    # (method "expand") dominates every host tile profile (DESIGN.md §9)...
    assert choose_method(_dense_tile_stats(), "host") == "expand"
    assert choose_method(_sparse_tile_stats(), "host") == "expand"
    # ...above the guard, executions pay a per-call transient stream rebuild
    # and SPA wins back flop-heavy tiles
    import repro.core.fast as fast

    st = _guard_tripped_tile_stats()
    assert st.flops > fast.STREAM_MAX_PRODUCTS
    assert choose_method(st, "host") == "spa"


def test_cost_model_pallas_regimes():
    # dense tiles keep the [m, L] accumulator busy -> SPA; sparse tiles
    # favour the small-H hash tables (the paper's crossover)
    assert choose_method(_dense_tile_stats(), "pallas") == "spa"
    assert choose_method(
        _sparse_tile_stats(), "pallas") in ("hash-256/256", "spars-40/40")
    sp = _sparse_tile_stats()
    assert (estimate_cost(sp, "hash-256/256", "pallas")
            < estimate_cost(sp, "spa", "pallas"))


def test_cost_model_monotone_in_flops():
    small, big = _sparse_tile_stats(), _dense_tile_stats()
    for method in ("spa", "expand"):
        assert (estimate_cost(big, method, "host")
                > estimate_cost(small, method, "host"))


def test_cost_model_candidate_restriction_and_errors():
    st = _sparse_tile_stats()
    assert choose_method(st, "host", candidates=("spa",)) == "spa"
    with pytest.raises(ValueError):
        choose_method(st, "host", candidates=())
    with pytest.raises(ValueError):
        estimate_cost(st, "expand", "pallas")   # host-only family
    with pytest.raises(ValueError):
        estimate_cost(st, "bogus", "host")


def test_auto_candidates_are_valid_methods():
    from repro.core import backend_names

    assert sorted(AUTO_CANDIDATES) == sorted(backend_names())
    for backend, cands in AUTO_CANDIDATES.items():
        for m in cands:
            # "jax"/"fused" are the cross-backend candidate spellings: the
            # device stream / fused Pallas kernel riding a tile grid
            # (DESIGN.md §10/§11)
            assert (m in ALGORITHMS or m in ("jax", "fused")
                    or m.startswith(("spars", "hash", "h-")))


# --- argument validation ---------------------------------------------------


def test_auto_argument_errors():
    a = random_powerlaw_csc(16, 2.0, seed=27)
    with pytest.raises(ValueError, match="auto"):
        spgemm(a, a, method="auto", t=40.0)
    with pytest.raises(ValueError, match="auto"):
        spgemm(a, a, method="spa", tile=(4, 4))
    with pytest.raises(ValueError, match="tile"):
        plan_spgemm_tiled(a, a, tile=(4, 4, 4))
    with pytest.raises(ValueError, match="tile"):
        plan_spgemm_tiled(a, a, tile=0)
    with pytest.raises(ValueError, match="host-only"):
        plan_spgemm_tiled(a, a, backend="pallas", candidates=("expand",))
    with pytest.raises(ValueError, match="shape mismatch"):
        plan_spgemm_tiled(a, random_powerlaw_csc(12, 2.0, seed=28))

"""Fused Pallas stream kernel (core/pallas_stream.py, DESIGN.md §11):
differential equivalence vs the host stream on the adversarial harness
(atol=0 on integer-valued inputs), segment-boundary edge cases of the
window-accumulate strategy (straddling segments, tile-edge boundaries,
P % block != 0, grad-view empty segments), gradient checks vs finite
differences and the XLA device stream, vmap-vs-looped bit-identity with a
B-independent launch count, cached-trace steady state, guard
fallback/capability errors, cross-backend engine="fused" spellings, tiled
"fused" auto-candidate grids, and fused_stream_bytes cache telemetry."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import bit_identical
from test_differential import CASES, _adversarial, oracle_product

from repro.core import (
    pallas_stream,
    plan_cache_clear,
    plan_cache_info,
    plan_spgemm,
    plan_spgemm_tiled,
    spgemm,
    spgemm_batched,
)
from repro.core.api import cached_plan
from repro.core.pallas_stream import fused_fn, fused_fn_batched, fused_stream
from repro.sparse import BatchedCSC, random_powerlaw_csc
from repro.sparse.format import CSC, csc_from_dense, csc_to_dense

F32 = np.float32


def _integerize(m: CSC, seed: int = 0) -> CSC:
    """Same pattern, small-integer values: every f32 sum is exact, so the
    fused kernel must agree with the f64 host stream with atol=0."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 4, size=m.nnz).astype(np.float64)
    return CSC(vals, m.row_indices, m.col_ptr, m.shape)


def _stored_coords(m: CSC):
    cp = np.asarray(m.col_ptr)
    rows = np.asarray(m.row_indices)[: m.nnz]
    cols = np.repeat(np.arange(m.n_cols, dtype=np.int32), np.diff(cp))
    return rows, cols


def _host_stream(a: CSC, b: CSC) -> CSC:
    return plan_spgemm(a, b, "expand").execute(a, b, engine="stream")


# --- differential: fused kernel vs host stream vs oracle ---------------------


@pytest.mark.parametrize("case", CASES)
def test_fused_vs_host_stream_and_oracle(case):
    """engine="fused" shares the host stream's canonical structure
    bit-for-bit and matches its values at f32 tolerance on every
    adversarial pattern."""
    a, b = _adversarial(case)
    pf = plan_spgemm(a, b, "expand", backend="jax")
    cf = pf.execute(a, b, engine="fused")
    ch = _host_stream(a, b)
    assert np.array_equal(np.asarray(cf.col_ptr), np.asarray(ch.col_ptr))
    assert np.array_equal(np.asarray(cf.row_indices)[: cf.nnz],
                          np.asarray(ch.row_indices)[: ch.nnz])
    np.testing.assert_allclose(
        np.asarray(cf.values), np.asarray(ch.values)[: ch.nnz],
        rtol=1e-5, atol=1e-6,
        err_msg=f"fused kernel diverged from the host stream on {case!r}")
    np.testing.assert_allclose(
        csc_to_dense(cf.to_host()), oracle_product(a, b),
        rtol=1e-4, atol=1e-5,
        err_msg=f"fused kernel diverged from the oracle on {case!r}")


@pytest.mark.parametrize("case", CASES)
def test_fused_integer_exact_vs_host_stream(case):
    """Integer-valued operands: the fused kernel is bit-comparable (atol=0)
    to the host stream — f32 vs f64 and any re-association are invisible
    when every partial sum is exactly representable."""
    a, b = _adversarial(case)
    a, b = _integerize(a, 1), _integerize(b, 2)
    cf = plan_spgemm(a, b, "expand", backend="jax").execute(
        a, b, engine="fused")
    ch = _host_stream(a, b)
    np.testing.assert_array_equal(
        np.asarray(cf.values), np.asarray(ch.values)[: ch.nnz],
        err_msg=f"fused kernel not bit-comparable on integer {case!r}")


def test_api_spellings_reach_the_fused_engine():
    """engine="fused" works through spgemm() on both device backends."""
    a = random_powerlaw_csc(24, 2.0, seed=3)
    ref = csc_to_dense(_host_stream(a, a))
    for backend, method in (("jax", "expand"), ("pallas", "spa")):
        c = spgemm(a, a, method=method, backend=backend, engine="fused",
                   cache=False)
        np.testing.assert_allclose(
            csc_to_dense(c.to_host()), ref, rtol=1e-5, atol=1e-6,
            err_msg=f"engine='fused' wrong through backend={backend!r}")


def test_fused_single_launch_on_both_backends():
    a = random_powerlaw_csc(30, 2.5, seed=4)
    for backend, method in (("jax", "expand"), ("pallas", "spa")):
        plan = plan_spgemm(a, a, method, backend=backend)
        stats = {}
        plan.execute(a, a, engine="fused", stats=stats)
        assert stats["engine"] == "fused"
        assert stats["backend"] == backend
        assert stats["n_launches"] == 1       # the whole numeric phase
        assert stats["fused_block"] == pallas_stream.FUSED_BLOCK


# --- segment-boundary edge cases (the window-accumulate invariant) -----------


def _fused_vals(plan, a, b, block):
    fn = fused_fn(plan, block=block)
    return np.asarray(fn(jnp.asarray(np.asarray(a.values)[: a.nnz], F32),
                         jnp.asarray(np.asarray(b.values)[: b.nnz], F32)))


def test_single_segment_spanning_every_tile():
    """A [1, k] @ B [k, 1] with k products: one output segment straddles
    every product-axis tile, so every grid step accumulates into the same
    output slot."""
    k = 23                                     # not divisible by block=4
    a = csc_from_dense(np.arange(1, k + 1, dtype=np.float64).reshape(1, k))
    b = csc_from_dense(np.ones((k, 1)))
    plan = plan_spgemm(a, b, "expand", backend="jax")
    ch = _host_stream(a, b)
    for block in (1, 4, 8, 64):
        got = _fused_vals(plan, a, b, block)
        np.testing.assert_array_equal(
            got, np.asarray(ch.values)[: ch.nnz],
            err_msg=f"straddling segment wrong at block={block}")


def test_segment_boundary_exactly_on_tile_edge():
    """Segments of exactly block-size products: every segment boundary
    coincides with a tile edge (local ids hit block-1 then reset)."""
    block = 4
    # A = [1, k] dense row blocks, B block-diagonal: C[0, j] sums exactly
    # `block` products for every j, so seg_starts = 0, 4, 8, ...
    n_seg = 6
    k = block * n_seg
    a = csc_from_dense(np.arange(1, k + 1, dtype=np.float64).reshape(1, k))
    bd = np.zeros((k, n_seg))
    for j in range(n_seg):
        bd[j * block:(j + 1) * block, j] = np.arange(1, block + 1)
    b = csc_from_dense(bd)
    plan = plan_spgemm(a, b, "expand", backend="jax")
    s = plan.stream
    assert np.array_equal(np.asarray(s.seg_starts),
                          np.arange(n_seg) * block)
    ch = _host_stream(a, b)
    got = _fused_vals(plan, a, b, block)
    np.testing.assert_array_equal(got, np.asarray(ch.values)[: ch.nnz])


def test_products_not_divisible_by_tile_size():
    """P % block != 0: the padded tail (masked to zero) must not perturb
    the last real segments."""
    a = _integerize(random_powerlaw_csc(20, 2.5, seed=7), 3)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    p = plan.stream.n_products
    ch = _host_stream(a, a)
    for block in (7, 13, p - 1, p + 1):
        if block < 1:
            continue
        got = _fused_vals(plan, a, a, block)
        np.testing.assert_array_equal(
            got, np.asarray(ch.values)[: ch.nnz],
            err_msg=f"padded-tail corruption at block={block} (P={p})")


def test_empty_grad_segments_scatter_zero():
    """Stored operand values with zero products (empty grad segments) must
    receive exactly-zero cotangent through the compact-id out_map scatter —
    the case that would break the [0, block) window invariant if the grad
    views kept empty segments inline."""
    # A[:, 0] has a stored value but B row 0 is empty: a_pos never visits it
    ad = np.array([[1.0, 2.0], [0.0, 3.0]])
    bd = np.array([[0.0, 0.0], [4.0, 5.0]])
    a, b = csc_from_dense(ad), csc_from_dense(bd)
    plan = plan_spgemm(a, b, "expand", backend="jax")
    fs = fused_stream(plan, block=2)
    assert fs.grad_a.n_out < a.nnz            # compact: absent positions
    av = jnp.asarray(np.asarray(a.values)[: a.nnz], F32)
    bv = jnp.asarray(np.asarray(b.values)[: b.nnz], F32)
    fn = fused_fn(plan, block=2)
    ga, gb = jax.grad(lambda x, y: jnp.sum(fn(x, y)),
                      argnums=(0, 1))(av, bv)
    # d sum(C) / dA[0,0] = 0 (row 0 of B empty); dA[0,1] = dA[1,1] = 4+5;
    # d sum(C) / dB[1,j] = sum of A's column 1 = 2+3
    np.testing.assert_array_equal(np.asarray(ga), [0.0, 9.0, 9.0])
    np.testing.assert_array_equal(np.asarray(gb), [5.0, 5.0])


def test_empty_stream_and_empty_operand():
    """P == 0 plans (empty A) still execute and differentiate: zero values
    on the canonical structure, zero gradients."""
    a = csc_from_dense(np.zeros((8, 8)))
    b = csc_from_dense(np.random.default_rng(0).normal(size=(8, 8)))
    plan = plan_spgemm(a, b, "expand", backend="jax")
    c = plan.execute(a, b, engine="fused")
    assert c.nnz == 0
    bv = jnp.asarray(np.asarray(b.values)[: b.nnz], F32)
    fn = fused_fn(plan)
    gb = jax.grad(lambda y: jnp.sum(fn(jnp.zeros(0, F32), y)))(bv)
    np.testing.assert_array_equal(np.asarray(gb), np.zeros(b.nnz, F32))


# --- gradients ---------------------------------------------------------------


@pytest.mark.parametrize("case", ("random", "dup_heavy", "single_row",
                                  "rect_chain"))
def test_fused_grad_matches_finite_differences(case):
    a, b = _adversarial(case)
    plan = plan_spgemm(a, b, "expand", backend="jax")
    fn = fused_fn(plan)
    av = np.asarray(a.values)[: a.nnz].astype(F32)
    bv = np.asarray(b.values)[: b.nnz].astype(F32)

    def loss(x, y):
        return jnp.sum(fn(x, y))

    ga, gb = jax.grad(loss, argnums=(0, 1))(jnp.asarray(av),
                                            jnp.asarray(bv))
    assert ga.shape == av.shape and gb.shape == bv.shape
    rng = np.random.default_rng(0)
    eps = 1e-2
    for arr, grad, which in ((av, ga, 0), (bv, gb, 1)):
        for i in rng.choice(len(arr), size=min(4, len(arr)), replace=False):
            hi, lo = arr.copy(), arr.copy()
            hi[i] += eps
            lo[i] -= eps
            args_hi = (hi, bv) if which == 0 else (av, hi)
            args_lo = (lo, bv) if which == 0 else (av, lo)
            fd = (float(loss(*map(jnp.asarray, args_hi)))
                  - float(loss(*map(jnp.asarray, args_lo)))) / (2 * eps)
            np.testing.assert_allclose(
                float(grad[i]), fd, rtol=5e-2, atol=5e-3,
                err_msg=f"fd mismatch at {which}/{i} on {case!r}")


@pytest.mark.parametrize("case", ("random", "dup_heavy", "rect_chain"))
def test_fused_grad_matches_dense_matmul_oracle(case):
    a, b = _adversarial(case)
    plan = plan_spgemm(a, b, "expand", backend="jax")
    fn = fused_fn(plan)
    av = jnp.asarray(np.asarray(a.values)[: a.nnz].astype(F32))
    bv = jnp.asarray(np.asarray(b.values)[: b.nnz].astype(F32))
    ga, gb = jax.grad(lambda x, y: jnp.sum(fn(x, y)),
                      argnums=(0, 1))(av, bv)

    ar, ac = _stored_coords(a)
    br, bc = _stored_coords(b)

    def dense_loss(x, y):
        ad = jnp.zeros(a.shape, F32).at[ar, ac].set(x)
        bd = jnp.zeros(b.shape, F32).at[br, bc].set(y)
        return jnp.sum(ad @ bd)

    da, db = jax.grad(dense_loss, argnums=(0, 1))(av, bv)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(da),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(db),
                               rtol=1e-4, atol=1e-5)


def test_fused_grad_matches_xla_stream_grad():
    """Both device lowerings of the same bilinear contraction must agree
    on the gradient (shared custom-vjp machinery, different replays)."""
    a = random_powerlaw_csc(28, 2.5, seed=11)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    fn = fused_fn(plan)
    av = jnp.asarray(np.asarray(a.values)[: a.nnz].astype(F32))
    w = jnp.asarray(np.random.default_rng(12).normal(
        size=plan.stream.nnz).astype(F32))
    gf = jax.grad(lambda x: jnp.sum(w * fn(x, x)))(av)
    gx = jax.grad(lambda x: jnp.sum(w * plan.stream_apply(x, x)))(av)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gx),
                               rtol=1e-5, atol=1e-6)


def test_stream_apply_engine_fused_is_the_traced_entry():
    """``plan.stream_apply(..., engine="fused")`` is the README/traced-code
    spelling of the fused lowering: same values as ``fused_fn``, same
    gradients, and unknown engines are rejected."""
    a = random_powerlaw_csc(24, 2.5, seed=21)
    plan = plan_spgemm(a, a, "spa", backend="pallas")
    av = jnp.asarray(np.asarray(a.values)[: a.nnz].astype(F32))
    via_apply = plan.stream_apply(av, av, engine="fused")
    assert np.array_equal(np.asarray(via_apply),
                          np.asarray(fused_fn(plan)(av, av)))
    ga = jax.grad(
        lambda x: jnp.sum(plan.stream_apply(x, x, engine="fused")))(av)
    gx = jax.grad(lambda x: jnp.sum(plan.stream_apply(x, x)))(av)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gx),
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="engine"):
        plan.stream_apply(av, av, engine="naive")


# --- vmap batched path -------------------------------------------------------


def test_fused_vmap_batched_bit_identical_to_looped():
    a = random_powerlaw_csc(36, 3.0, seed=4)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    rng = np.random.default_rng(5)
    vals = rng.normal(size=(5, a.nnz)).astype(F32)
    stats = {}
    batched = plan.execute_batched(vals, vals, engine="fused", stats=stats)
    assert stats["path"] == "vmap" and stats["batch"] == 5
    assert stats["n_launches"] == 1           # independent of B
    looped = [plan.execute(vals[i], vals[i], engine="fused")
              for i in range(5)]
    for x, y in zip(batched, looped):
        assert np.array_equal(np.asarray(x.values), np.asarray(y.values))
        assert x.row_indices is y.row_indices  # shared frozen structure


def test_spgemm_batched_rides_the_fused_engine():
    a = random_powerlaw_csc(30, 2.5, seed=6)
    rng = np.random.default_rng(7)
    ab = BatchedCSC.from_values(a, rng.normal(size=(3, a.nnz)).astype(F32))
    got = spgemm_batched(ab, ab, method="expand", backend="jax",
                         engine="fused", cache=False)
    want = [spgemm(ab[i], ab[i], method="expand", cache=False)
            for i in range(3)]
    for x, y in zip(got, want):
        np.testing.assert_allclose(
            csc_to_dense(x.to_host()), csc_to_dense(y),
            rtol=1e-5, atol=1e-6)


def test_fused_zero_retrace_after_warmup():
    a = random_powerlaw_csc(28, 2.5, seed=8)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    fn = fused_fn(plan)
    assert fused_fn(plan) is fn               # memoized on the plan
    rng = np.random.default_rng(9)
    for _ in range(4):
        v = rng.normal(size=a.nnz).astype(F32)
        fn(v, v)
    assert fn._cache_size() == 1
    bfn = fused_fn_batched(plan)
    for _ in range(3):
        v = rng.normal(size=(6, a.nnz)).astype(F32)
        bfn(v, v)
    assert bfn._cache_size() == 1


# --- guard fallback and capability errors ------------------------------------


def test_guarded_fused_falls_back_to_host_engine():
    a = random_powerlaw_csc(40, 3.0, seed=10)
    full_host = plan_spgemm(a, a, "expand")
    for backend, method in (("jax", "expand"), ("pallas", "spa")):
        guarded = plan_spgemm(a, a, method, backend=backend,
                              stream_limit=1)
        stats = {}
        c = guarded.execute(a, a, engine="fused", stats=stats)
        assert stats["fallback"] == "host"
        assert stats["backend"] == backend
        assert bit_identical(c, full_host.execute(a, a, engine="stream"))
        vals = np.random.default_rng(11).normal(size=(3, a.nnz))
        for x, y in zip(
                guarded.execute_batched(vals, vals, engine="fused"),
                full_host.execute_batched(vals, vals, engine="stream")):
            assert bit_identical(x, y)


def test_guarded_fused_raises_under_trace():
    a = random_powerlaw_csc(24, 2.5, seed=12)
    guarded = plan_spgemm(a, a, "expand", backend="jax", stream_limit=1)
    vals = jnp.asarray(np.asarray(a.values)[: a.nnz].astype(F32))
    with pytest.raises(ValueError, match="guard"):
        jax.jit(lambda v: pallas_stream.execute_fused(
            guarded, v, v).values)(vals)
    with pytest.raises(ValueError, match="guard"):
        fused_fn(guarded)


def test_fused_rejects_streamless_spelling_on_host():
    a = random_powerlaw_csc(16, 2.0, seed=13)
    plan = plan_spgemm(a, a, "expand")          # host backend
    with pytest.raises(ValueError, match="fused"):
        plan.execute(a, a, engine="fused")


# --- tiled "fused" auto candidate --------------------------------------------


def test_tiled_fused_candidate_runs_the_fused_engine():
    a = _integerize(random_powerlaw_csc(40, 3.0, seed=14), 5)
    tp = plan_spgemm_tiled(a, a, backend="jax", candidates=("fused",),
                           cache=False)
    assert set(tp.methods.values()) == {"fused"}
    assert all(t.engine == "fused" for t in tp.tiles)
    ch = _host_stream(a, a)
    ct = tp.execute(a.values, a.values)
    np.testing.assert_array_equal(csc_to_dense(ct), csc_to_dense(ch))
    assert tp.fused_stream_nbytes > 0           # views built by execution
    # an explicit engine= overrides the per-tile choice uniformly
    cs = tp.execute(a.values, a.values, engine="stream")
    np.testing.assert_allclose(csc_to_dense(cs), csc_to_dense(ch),
                               rtol=1e-5, atol=1e-6)


def test_host_auto_never_picks_fused_on_cpu_constants():
    """The calibrated interpret-mode constants keep "fused" out of every
    CPU tile choice even though it is a host auto candidate."""
    a = random_powerlaw_csc(48, 3.0, seed=15)
    tp = plan_spgemm_tiled(a, a, backend="host", cache=False)
    assert "fused" not in set(tp.methods.values())


# --- cache telemetry ---------------------------------------------------------


def test_fused_stream_bytes_reported_separately():
    plan_cache_clear()
    a = random_powerlaw_csc(32, 3.0, seed=16)
    plan = cached_plan(a, a, "expand", backend="jax")
    info = plan_cache_info()
    assert info["fused_stream_bytes"] == 0      # lazy: not built yet
    plan.execute(a, a, engine="stream")
    assert plan_cache_info()["fused_stream_bytes"] == 0   # stream != fused
    plan.execute(a, a, engine="fused")
    info = plan_cache_info()
    assert info["fused_stream_bytes"] > 0
    assert info["fused_stream_bytes"] == plan.fused_stream_nbytes
    # the three stream kinds are accounted independently
    assert info["stream_bytes"] > 0
    assert info["device_stream_bytes"] > 0      # stream engine built it
    plan_cache_clear()
    assert plan_cache_info()["fused_stream_bytes"] == 0

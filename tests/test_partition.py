"""Tile partition/stitch primitives: slicing round-trips, value-gather
metadata, the k-merge reduction, and grid-boundary helpers (DESIGN.md §8)."""

import numpy as np
import pytest

from conftest import bit_identical as _bit_identical
from repro.sparse import (
    auto_tile_grid,
    csc_col_slice,
    csc_empty,
    csc_hstack,
    csc_row_slice,
    merge_csc_partials,
    nnz_balanced_col_bounds,
    random_density_csc,
    random_powerlaw_csc,
    validate_csc,
    width_col_bounds,
)
from repro.sparse.format import CSC, csc_from_dense, csc_to_dense


# --- slicing ---------------------------------------------------------------


def test_col_slice_matches_dense_and_value_range():
    m = random_powerlaw_csc(40, 3.0, seed=0)
    d = csc_to_dense(m)
    sl, (lo, hi) = csc_col_slice(m, 5, 21)
    assert sl.shape == (40, 16)
    np.testing.assert_array_equal(csc_to_dense(sl), d[:, 5:21])
    # the slice's values are the contiguous [lo, hi) range of the parent's
    np.testing.assert_array_equal(
        np.asarray(sl.values), np.asarray(m.values)[lo:hi])
    validate_csc(sl)


def test_col_slice_hstack_round_trip_bit_identical():
    m = random_powerlaw_csc(30, 4.0, seed=1)
    for bounds in ([0, 7, 13, 30], [0, 30], list(range(31))):
        parts = [csc_col_slice(m, j0, j1)[0]
                 for j0, j1 in zip(bounds[:-1], bounds[1:])]
        assert _bit_identical(csc_hstack(parts, m.n_rows), m)


def test_row_slice_matches_dense_and_gather():
    m = random_density_csc(24, 18, 0.3, seed=2)
    d = csc_to_dense(m)
    sl, idx = csc_row_slice(m, 6, 17)
    assert sl.shape == (11, 18)
    np.testing.assert_array_equal(csc_to_dense(sl), d[6:17, :])
    validate_csc(sl)
    # the gather is pattern-static: it re-slices any same-pattern value set
    rng = np.random.default_rng(0)
    new_vals = rng.normal(size=m.nnz)
    resliced = CSC(new_vals[idx], sl.row_indices, sl.col_ptr, sl.shape)
    ref = csc_from_dense(csc_to_dense(
        CSC(new_vals, m.row_indices, m.col_ptr, m.shape))[6:17, :])
    np.testing.assert_allclose(csc_to_dense(resliced), csc_to_dense(ref),
                               rtol=0, atol=0)


def test_row_slices_partition_all_entries():
    m = random_powerlaw_csc(32, 3.0, seed=3)
    bounds = [0, 10, 20, 32]
    total = 0
    for i0, i1 in zip(bounds[:-1], bounds[1:]):
        sl, idx = csc_row_slice(m, i0, i1)
        assert sl.nnz == len(idx)
        total += sl.nnz
    assert total == m.nnz


def test_slice_range_errors():
    m = random_powerlaw_csc(10, 2.0, seed=4)
    with pytest.raises(ValueError):
        csc_col_slice(m, 3, 11)
    with pytest.raises(ValueError):
        csc_col_slice(m, -1, 5)
    with pytest.raises(ValueError):
        csc_row_slice(m, 5, 3)


def test_empty_slices():
    m = random_powerlaw_csc(12, 2.0, seed=5)
    sl, (lo, hi) = csc_col_slice(m, 4, 4)
    assert sl.shape == (12, 0) and sl.nnz == 0 and lo == hi
    sl, idx = csc_row_slice(m, 7, 7)
    assert sl.shape == (0, 12) and sl.nnz == 0 and len(idx) == 0


# --- merge -----------------------------------------------------------------


def test_merge_partials_exact_sum_of_dense():
    rng = np.random.default_rng(6)
    shape = (20, 14)
    parts = []
    for s in range(3):
        d = rng.integers(-3, 4, size=shape).astype(np.float64)
        d *= rng.uniform(size=shape) < 0.3
        parts.append(csc_from_dense(d))
    merged = merge_csc_partials(parts, shape)
    validate_csc(merged, sorted_rows=True)
    # integer values: the sum is exact regardless of association
    np.testing.assert_array_equal(
        csc_to_dense(merged),
        sum(csc_to_dense(p) for p in parts))


def test_merge_single_part_is_passthrough():
    p = random_powerlaw_csc(16, 3.0, seed=7)
    assert merge_csc_partials([p], p.shape) is p


def test_merge_keeps_cancelled_entries_explicit():
    d = np.zeros((4, 3))
    d[1, 1] = 2.5
    p1 = csc_from_dense(d)
    p2 = csc_from_dense(-d)
    merged = merge_csc_partials([p1, p2], (4, 3))
    assert merged.nnz == 1            # pattern is value-independent
    assert float(np.asarray(merged.values)[0]) == 0.0


def test_merge_accumulates_in_k_order():
    # three partials hitting one element: fold order must be k-ascending
    vals = [1e16, 1.0, -1e16]
    parts = []
    for v in vals:
        d = np.zeros((2, 2))
        d[0, 0] = v
        parts.append(csc_from_dense(d))
    merged = merge_csc_partials(parts, (2, 2))
    expect = ((vals[0] + vals[1]) + vals[2])   # == 0.0, not 1.0
    assert float(csc_to_dense(merged)[0, 0]) == expect


def test_merge_empty_and_shape_errors():
    out = merge_csc_partials([], (5, 4))
    assert out.shape == (5, 4) and out.nnz == 0
    with pytest.raises(ValueError):
        merge_csc_partials(
            [csc_empty((3, 3)), csc_empty((3, 4))], (3, 3))


def test_hstack_errors():
    with pytest.raises(ValueError):
        csc_hstack([], 4)
    with pytest.raises(ValueError):
        csc_hstack([csc_empty((3, 2)), csc_empty((4, 2))], 3)


# --- grid boundaries -------------------------------------------------------


def test_width_col_bounds():
    np.testing.assert_array_equal(width_col_bounds(10, 4), [0, 4, 8, 10])
    np.testing.assert_array_equal(width_col_bounds(8, 8), [0, 8])
    np.testing.assert_array_equal(width_col_bounds(3, 100), [0, 3])
    np.testing.assert_array_equal(width_col_bounds(0, 4), [0])
    with pytest.raises(ValueError):
        width_col_bounds(10, 0)


def test_nnz_balanced_bounds_properties():
    m = random_powerlaw_csc(60, 3.0, seed=8)
    for nb in (1, 2, 4, 7, 60):
        bounds = nnz_balanced_col_bounds(m, nb)
        assert bounds[0] == 0 and bounds[-1] == m.n_cols
        assert (np.diff(bounds) >= 1).all()
        assert len(bounds) - 1 <= nb
    # balance: with a heavy head, the head block holds fewer columns
    d = np.zeros((32, 32))
    d[:, :4] = 1.0
    d[0, 4:] = 1.0
    skew = csc_from_dense(d)
    bounds = nnz_balanced_col_bounds(skew, 2)
    assert bounds[1] < 16   # the cut lands inside/near the dense head


def test_auto_tile_grid_scales_with_nnz():
    small = random_powerlaw_csc(20, 2.0, seed=9)
    assert auto_tile_grid(small, small) == (1, 1)
    big = random_powerlaw_csc(600, 40.0, seed=10)
    k_blocks, n_blocks = auto_tile_grid(big, big)
    assert n_blocks > 1          # past the n-axis nnz target
    assert k_blocks >= 1


# --- deterministic reduction order (the distributed-merge contract) --------


def _rand_part(seed, shape):
    return random_density_csc(shape[0], shape[1], 0.25, seed=seed)


def test_merge_bit_identical_regardless_of_completion_order():
    """The mesh contract (DESIGN.md §9/§13): partials are merged in plan
    (k) order — list position — so the merged bits must not depend on the
    order the parts were *computed* in (device completion order)."""
    import threading

    shape = (30, 20)
    seeds = [1, 2, 3, 4, 5]
    ref = merge_csc_partials([_rand_part(s, shape) for s in seeds], shape)
    # parts computed in arbitrary sequential order, merged in k order
    for perm_seed in range(4):
        order = np.random.default_rng(perm_seed).permutation(len(seeds))
        computed = {}
        for i in order:
            computed[int(i)] = _rand_part(seeds[int(i)], shape)
        merged = merge_csc_partials(
            [computed[i] for i in range(len(seeds))], shape)
        assert _bit_identical(merged, ref)
    # parts computed concurrently (racing "devices"), merged in k order
    slots = [None] * len(seeds)

    def build(i):
        slots[i] = _rand_part(seeds[i], shape)

    threads = [threading.Thread(target=build, args=(i,))
               for i in range(len(seeds))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert _bit_identical(merge_csc_partials(slots, shape), ref)


def test_merge_k_order_is_the_fp_reassociation_boundary():
    """List position IS the reduction order: reordering the partial list
    reassociates the float sum and may change bits (which is exactly why
    the mesh plan presents partials mesh-ordered, never completion-
    ordered).  1e20 + (-1e20) + 1 makes the boundary deterministic."""
    shape = (2, 2)

    def part(v):
        return CSC(np.array([v]), np.array([0], np.int32),
                   np.array([0, 1, 1], np.int32), shape)

    in_order = merge_csc_partials(
        [part(1e20), part(-1e20), part(1.0)], shape)
    reassociated = merge_csc_partials(
        [part(1e20), part(1.0), part(-1e20)], shape)
    assert in_order.values[0] == 1.0
    assert reassociated.values[0] == 0.0
    # same list twice -> same bits: the order sensitivity is *only* in the
    # list order, never in run-to-run nondeterminism
    again = merge_csc_partials([part(1e20), part(-1e20), part(1.0)], shape)
    assert _bit_identical(in_order, again)

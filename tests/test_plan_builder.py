"""Plan-cache locking, single-flight builds, and the background builder.

The DESIGN.md §12 contracts: the plan LRU is safe under concurrent
readers/writers (no lost entries, no double-builds, consistent counters),
and ``PlanBuilder`` keeps plan construction off the calling thread — a
latency-critical tick gets a fallback plan immediately while the device
build lands in the background.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    PlanBuilder, api, cached_plan, plan_cache_clear, plan_cache_info,
    plan_cache_key, plan_cache_peek, spgemm, warm_plan,
)
from repro.sparse import random_density_csc


@pytest.fixture(autouse=True)
def fresh_cache():
    plan_cache_clear()
    yield
    plan_cache_clear()


def _mats(n_patterns, n=24, density=0.2):
    return [(random_density_csc(n, n, density, seed=2 * i),
             random_density_csc(n, n, density, seed=2 * i + 1))
            for i in range(n_patterns)]


@pytest.fixture
def counting_builds(monkeypatch):
    """Wrap the symbolic build so tests can count real plan constructions."""
    calls = []
    real = api.plan_spgemm

    def counting(*a, **kw):
        calls.append(1)
        time.sleep(0.002)  # widen the race window
        return real(*a, **kw)

    monkeypatch.setattr(api, "plan_spgemm", counting)
    return calls


# ---------------------------------------------------------------------------
# LRU locking + single-flight (the ISSUE's plan-cache race bugfix)
# ---------------------------------------------------------------------------


def test_concurrent_hammer_no_double_builds(counting_builds):
    """8 threads x 4 patterns: each pattern's plan is built exactly once,
    nothing is lost, and the hit/miss counters stay consistent."""
    mats = _mats(4)
    n_threads, reps = 8, 6
    plans: dict = {}
    errs = []
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            barrier.wait()
            for r in range(reps):
                for i, (a, b) in enumerate(mats):
                    p = cached_plan(a, b, "expand", backend="host")
                    prev = plans.setdefault(i, p)
                    assert p is prev  # everyone sees the one shared plan
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(counting_builds) == len(mats)  # no double-builds
    info = plan_cache_info()
    assert info["size"] == len(mats)  # no lost entries
    assert info["misses"] == len(mats)
    assert info["hits"] + info["misses"] == n_threads * reps * len(mats)
    assert info["in_flight"] == 0


def test_single_flight_failed_build_retries(monkeypatch):
    """A failed owner build wakes waiters; a later caller rebuilds."""
    a, b = _mats(1)[0]
    real = api.plan_spgemm
    boom = {"on": True}

    def flaky(*args, **kw):
        if boom["on"]:
            raise RuntimeError("injected build failure")
        return real(*args, **kw)

    monkeypatch.setattr(api, "plan_spgemm", flaky)
    with pytest.raises(RuntimeError, match="injected"):
        cached_plan(a, b, "expand", backend="host")
    assert plan_cache_info()["in_flight"] == 0  # no leaked build event
    boom["on"] = False
    plan = cached_plan(a, b, "expand", backend="host")
    assert plan is plan_cache_peek(
        plan_cache_key(a, b, "expand", backend="host"))


def test_peek_does_not_promote_or_count():
    a, b = _mats(1)[0]
    key = plan_cache_key(a, b, "expand", backend="host")
    assert plan_cache_peek(key) is None
    before = plan_cache_info()
    assert plan_cache_peek(key) is None
    after = plan_cache_info()
    assert (before["hits"], before["misses"]) == (after["hits"],
                                                  after["misses"])
    plan = cached_plan(a, b, "expand", backend="host")
    assert plan_cache_peek(key) is plan


def test_eviction_counter():
    mats = _mats(5)
    orig = plan_cache_info()["max_size"]
    api.plan_cache_resize(2)
    try:
        for a, b in mats:
            cached_plan(a, b, "expand", backend="host")
        info = plan_cache_info()
        assert info["size"] == 2
        assert info["evictions"] == 3
    finally:
        api.plan_cache_resize(orig)


# ---------------------------------------------------------------------------
# PlanBuilder: background builds, dedup, shedding, fallback protocol
# ---------------------------------------------------------------------------


def test_builder_submit_and_poll():
    a, b = _mats(1)[0]
    with PlanBuilder() as builder:
        status = builder.submit(a, b, "expand", backend="host", warm=False)
        assert status == "submitted"
        assert builder.wait_idle(30)
        results = builder.poll()
    assert len(results) == 1
    assert results[0].ok
    key = plan_cache_key(a, b, "expand", backend="host")
    assert results[0].key == key
    assert plan_cache_peek(key) is results[0].plan


def test_builder_dedup_and_cached_statuses():
    a, b = _mats(1)[0]
    gate = threading.Event()
    with PlanBuilder() as builder:
        builder.submit_task(gate.wait, tag="gate")  # pin the worker
        assert builder.submit(a, b, "expand", backend="host") == "submitted"
        assert builder.submit(a, b, "expand", backend="host") == "inflight"
        assert builder.stats["deduped"] == 1
        gate.set()
        assert builder.wait_idle(30)
        assert builder.submit(a, b, "expand", backend="host") == "cached"
        assert builder.stats["cached"] == 1


def test_builder_sheds_over_max_pending():
    mats = _mats(4)
    gate = threading.Event()
    with PlanBuilder(max_pending=2) as builder:
        builder.submit_task(gate.wait, tag="gate")  # occupies one slot
        statuses = [builder.submit(a, b, "expand", backend="host")
                    for a, b in mats]
        assert statuses.count("shed") >= 2  # bounded queue under churn
        gate.set()
        assert builder.wait_idle(30)
    assert builder.stats["shed"] >= 2


def test_builder_shutdown_rejects_new_work():
    builder = PlanBuilder()
    builder.shutdown()
    a, b = _mats(1)[0]
    with pytest.raises(RuntimeError, match="shut down"):
        builder.submit(a, b, "expand", backend="host")


def test_builder_reports_failed_builds(monkeypatch):
    a, b = _mats(1)[0]
    monkeypatch.setattr(api, "plan_spgemm",
                        lambda *x, **k: (_ for _ in ()).throw(
                            RuntimeError("injected")))
    with PlanBuilder() as builder:
        builder.submit(a, b, "expand", backend="host", warm=False)
        assert builder.wait_idle(30)
        results = builder.poll()
    assert len(results) == 1
    assert not results[0].ok
    assert "injected" in str(results[0].error)
    assert builder.stats["failed"] == 1


def test_plan_or_fallback_never_blocks_then_promotes():
    """Cold pattern: the call returns a host plan immediately (status
    'fallback') while the device build runs behind it; once the build
    lands, the same call serves the device plan ('ready')."""
    a, b = _mats(1)[0]
    with PlanBuilder() as builder:
        plan, status = builder.plan_or_fallback(a, b, "expand",
                                                backend="jax")
        assert status == "fallback"
        assert plan.backend == "host"
        assert builder.wait_idle(120)
        plan2, status2 = builder.plan_or_fallback(a, b, "expand",
                                                  backend="jax")
    assert status2 == "ready"
    assert plan2.backend == "jax"


def test_warm_plan_materializes_stream():
    a, b = _mats(1)[0]
    plan = cached_plan(a, b, "expand", backend="jax")
    assert plan.stream_nbytes == 0  # lazy until warmed
    warm_plan(plan)
    assert plan.stream_nbytes > 0
    assert plan.device_stream_nbytes > 0


def test_allmiss_churn_bit_identical_to_cold_cache():
    """Adversarial eviction churn must not change numerics: results under
    a too-small LRU (every request misses + evicts) are bit-identical to
    uncached cold builds — whichever of the fallback (host) or promoted
    (device) plan serves a given lap.  Small-integer values make every f32
    sum exact, so host f64 and device f32 agree with atol=0."""
    from repro.sparse.format import csc_to_dense

    def integerize(m, seed):
        rng = np.random.default_rng(seed)
        vals = rng.integers(1, 4, size=m.nnz).astype(np.float64)
        return type(m)(vals, m.row_indices, m.col_ptr, m.shape)

    mats = [(integerize(a, 3 * i), integerize(b, 3 * i + 1))
            for i, (a, b) in enumerate(_mats(6, n=32, density=0.15))]
    ref = [csc_to_dense(spgemm(a, b, method="expand", backend="host",
                               cache=False))
           for a, b in mats]
    orig = plan_cache_info()["max_size"]
    api.plan_cache_resize(2)
    try:
        with PlanBuilder(max_pending=2) as builder:
            for _ in range(3):  # three churn laps
                for (a, b), r in zip(mats, ref):
                    plan, _ = builder.plan_or_fallback(
                        a, b, "expand", backend="jax", warm=False)
                    got = plan.execute(a, b)
                    if hasattr(got, "to_host"):
                        got = got.to_host()
                    np.testing.assert_array_equal(csc_to_dense(got), r)
            builder.wait_idle(120)
    finally:
        api.plan_cache_resize(orig)
    assert plan_cache_info()["evictions"] > 0  # churn actually happened


# ---------------------------------------------------------------------------
# post-shrink waste accounting + re-warm (the resize-under-builds fix)
# ---------------------------------------------------------------------------


def test_wasted_builds_counts_insert_then_evict():
    """A build completing into a cache too small to keep it (the resize-
    below-in-flight-builds race) must be surfaced, not silent."""
    a, b = _mats(1)[0]
    orig = plan_cache_info()["max_size"]
    gate = threading.Event()
    try:
        with PlanBuilder() as builder:
            builder.submit_task(gate.wait, tag="gate")
            # queued behind the gate: the shrink lands mid-"flight"
            assert builder.submit(a, b, "expand", backend="host",
                                  warm=False) == "submitted"
            api.plan_cache_resize(0)
            gate.set()
            assert builder.wait_idle(60)
        info = plan_cache_info()
        assert info["size"] == 0
        assert info["wasted_builds"] == 1, info
        # a hit-then-evicted entry is NOT waste
        api.plan_cache_resize(2)
        plan = cached_plan(a, b, "expand", backend="host")   # miss, insert
        assert cached_plan(a, b, "expand", backend="host") is plan  # hit
        api.plan_cache_resize(0)
        assert plan_cache_info()["wasted_builds"] == 1
    finally:
        api.plan_cache_resize(orig)


def test_rewarm_hook_rebuilds_after_shrink():
    mats = _mats(2)
    orig = plan_cache_info()["max_size"]
    try:
        api.plan_cache_resize(4)
        with PlanBuilder() as builder:
            builder.enable_rewarm()
            builder.enable_rewarm()   # idempotent
            for a, b in mats:
                builder.submit(a, b, "expand", backend="host", warm=False)
            assert builder.wait_idle(60)
            keys = [plan_cache_key(a, b, "expand", backend="host")
                    for a, b in mats]
            assert all(plan_cache_peek(k) is not None for k in keys)
            # shrink evicts the LRU entry; the listener resubmits it
            api.plan_cache_resize(1)
            assert builder.wait_idle(60)
            assert builder.stats["rewarmed"] == 1, builder.stats
            # the re-warmed build landed back in the (now size-1) cache,
            # evicting the survivor through ordinary capacity pressure —
            # which must NOT re-notify (no listener ping-pong)
            rewarmed = builder.stats["rewarmed"]
            assert sum(plan_cache_peek(k) is not None for k in keys) == 1
            assert builder.stats["rewarmed"] == rewarmed
        # shutdown unhooked the listener
        assert api._EVICTION_LISTENERS == []
        api.plan_cache_resize(0)   # no listener left to fire
    finally:
        api.plan_cache_resize(orig)


def test_rewarm_skips_unknown_keys():
    a, b = _mats(1)[0]
    with PlanBuilder() as builder:
        key = plan_cache_key(a, b, "expand", backend="host")
        assert builder.rewarm([key, ("bogus",)]) == 0   # never submitted
        builder.submit(a, b, "expand", backend="host", warm=False)
        assert builder.wait_idle(60)
        api.plan_cache_resize(0)
        api.plan_cache_resize(64)
        assert builder.rewarm([key]) == 1
        assert builder.wait_idle(60)
        assert plan_cache_peek(key) is not None

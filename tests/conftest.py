"""Lock the jax backend to this container's single CPU device before any
test can import repro.launch.dryrun (which sets the 512-fake-device XLA flag
for the dry-run entry point — that flag must never apply to tests).

Also pins ``REPRO_PROFILE_DIR`` to a non-existent scratch path *before*
repro imports: tier-1 tests assert the cost model's behavior on
``DEFAULT_CONSTANTS``, so a machine profile persisted in the developer's
user cache (``core.profile``) must never leak in and re-rank
``choose_method`` picks under the suite.  Tests that exercise measured
profiles install them explicitly via ``profile.set_profile``/tmp dirs.

Also re-exports the shared ``bit_identical`` CSC-equality helper
(``from conftest import bit_identical``; the single implementation lives
in ``repro.sparse.format.csc_bit_identical``)."""

import os
import tempfile

os.environ.setdefault(
    "REPRO_PROFILE_DIR",
    os.path.join(tempfile.gettempdir(), "repro-test-profiles-unwritten"))
os.environ.pop("REPRO_PROFILE_FILE", None)
os.environ.pop("REPRO_AUTO_CALIBRATE", None)

import jax

from repro.sparse.format import csc_bit_identical as bit_identical  # noqa: F401


def pytest_configure(config):
    jax.devices()  # initializes the backend with the default device count

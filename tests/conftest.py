"""Lock the jax backend to this container's single CPU device before any
test can import repro.launch.dryrun (which sets the 512-fake-device XLA flag
for the dry-run entry point — that flag must never apply to tests).

Also re-exports the shared ``bit_identical`` CSC-equality helper
(``from conftest import bit_identical``; the single implementation lives
in ``repro.sparse.format.csc_bit_identical``)."""

import jax

from repro.sparse.format import csc_bit_identical as bit_identical  # noqa: F401


def pytest_configure(config):
    jax.devices()  # initializes the backend with the default device count

"""Lock the jax backend to this container's single CPU device before any
test can import repro.launch.dryrun (which sets the 512-fake-device XLA flag
for the dry-run entry point — that flag must never apply to tests)."""

import jax


def pytest_configure(config):
    jax.devices()  # initializes the backend with the default device count

"""Mesh-distributed SpGEMM (DESIGN.md §13): sharded plans, destination
binning, the deterministic cross-device merge contract, gradients, and the
cost-model distribute decision.

In-process tests run on the conftest-pinned single CPU device (a 1-shard
mesh exercises the full plan/stream/shard_map/psum_scatter machinery); the
multi-device path runs in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import bit_identical as _bit_identical
from repro.core import api, cached_plan, plan_cache_clear, spgemm
from repro.core.cost import estimate_mesh_cost, should_distribute
from repro.core.executor import execute, execute_batched
from repro.core.planner import plan_spgemm
from repro.distributed import ShardedSpgemmPlan, plan_spgemm_mesh
from repro.distributed.spgemm_mesh import _ops_balanced_bounds
from repro.sparse import random_density_csc, random_uniform_csc
from repro.sparse.format import CSC
from repro.sparse.stats import ops_per_column, tile_stats

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def fresh_cache():
    plan_cache_clear()
    yield
    plan_cache_clear()


def _int_csc(n, z, seed, n_rows):
    """Integer-valued f32 operand: device sums are exact, so the mesh
    result must match the f64 host oracle bit for bit."""
    m = random_uniform_csc(n, z, seed=seed, n_rows=n_rows)
    rng = np.random.default_rng(seed + 100)
    return CSC(rng.integers(1, 8, m.nnz).astype(np.float32),
               m.row_indices, m.col_ptr, m.shape)


def _host_oracle(a, b):
    plan = plan_spgemm(a, b, "expand", backend="host", stream_limit=10**12)
    return execute(plan, a, b, engine="stream")


def _as_host(c):
    return CSC(np.asarray(c.values), np.asarray(c.row_indices),
               np.asarray(c.col_ptr), c.shape)


# --- planning --------------------------------------------------------------


def test_ops_balanced_bounds_properties():
    ops = np.array([100, 1, 1, 1, 100, 1, 1, 1, 100, 1])
    bounds = _ops_balanced_bounds(ops, 3)
    assert bounds[0] == 0 and bounds[-1] == len(ops)
    assert np.all(np.diff(bounds) >= 1)
    # flop-balanced: no block should carry everything
    blk = np.add.reduceat(ops, bounds[:-1])
    assert blk.max() < ops.sum()
    assert len(_ops_balanced_bounds(np.zeros(0, np.int64), 4)) == 1
    assert list(_ops_balanced_bounds(np.array([5]), 4)) == [0, 1]


def test_mesh_plan_structure_and_guard():
    a = _int_csc(60, 6, seed=0, n_rows=50)
    b = _int_csc(40, 5, seed=1, n_rows=60)
    total = int(ops_per_column(a, b).sum())
    plan = plan_spgemm_mesh(a, b, shards=1, shard_limit=2 * total)
    assert isinstance(plan, ShardedSpgemmPlan)
    assert plan.backend == "mesh" and plan.method == "expand"
    assert plan.shape == (50, 40)
    assert plan.n_shards == 1
    # every tile fits the per-shard guard, placement covers all flops
    assert int(plan.predicted_flops.sum()) == total
    assert plan.imbalance >= 1.0
    ss = plan.stream
    assert ss.n_products == total
    assert ss.padded_slots % plan.n_shards == 0
    assert ss.padded_slots > ss.num_slots   # trash slot exists
    assert int(ss.per_device.sum()) == total
    assert plan.mesh_stream_nbytes == ss.nbytes > 0


def test_mesh_plan_overfull_raises():
    a = _int_csc(60, 6, seed=0, n_rows=50)
    b = _int_csc(40, 5, seed=1, n_rows=60)
    total = int(ops_per_column(a, b).sum())
    with pytest.raises(ValueError, match="shard_limit"):
        plan_spgemm_mesh(a, b, shards=1, shard_limit=total // 4)


def test_mesh_shards_validation():
    a = _int_csc(10, 2, seed=0, n_rows=10)
    with pytest.raises(ValueError, match="shards"):
        plan_spgemm_mesh(a, a, shards=0)
    with pytest.raises(ValueError, match="shape mismatch"):
        plan_spgemm_mesh(a, _int_csc(5, 2, seed=0, n_rows=9))
    with pytest.raises(ValueError, match="backend='mesh'"):
        spgemm(a, a, "expand", backend="host", shards=2)


# --- execution: bit-identity, grads, jit, batched --------------------------


def test_mesh_bit_matches_guard_lifted_host_stream():
    a = _int_csc(60, 6, seed=0, n_rows=50)
    b = _int_csc(40, 5, seed=1, n_rows=60)
    # force a real multi-tile grid (k and n both split) on one shard
    plan = plan_spgemm_mesh(a, b, shards=1, tile=(20, 8))
    assert len(plan.tiles) > 4
    c = plan.execute(a, b)
    assert _bit_identical(_as_host(c), _host_oracle(a, b))


def test_mesh_execution_is_deterministic():
    a = _int_csc(50, 5, seed=4, n_rows=45)
    b = _int_csc(35, 4, seed=5, n_rows=50)
    plan = plan_spgemm_mesh(a, b, shards=1)
    c1 = _as_host(plan.execute(a, b))
    c2 = _as_host(plan.execute(a, b))
    assert _bit_identical(c1, c2)


def test_mesh_gradients_match_single_device_stream():
    a = _int_csc(50, 5, seed=2, n_rows=40)
    b = _int_csc(30, 4, seed=3, n_rows=50)
    mesh_plan = plan_spgemm_mesh(a, b, shards=1)
    jax_plan = plan_spgemm(a, b, "expand", backend="jax")
    av, bv = jnp.asarray(a.values), jnp.asarray(b.values)

    def loss(apply):
        return lambda x, y: jnp.sum(apply(x, y) ** 2)

    ga_m, gb_m = jax.grad(loss(mesh_plan.stream_apply), (0, 1))(av, bv)
    ga_j, gb_j = jax.grad(loss(jax_plan.stream_apply), (0, 1))(av, bv)
    np.testing.assert_allclose(np.asarray(ga_m), np.asarray(ga_j))
    np.testing.assert_allclose(np.asarray(gb_m), np.asarray(gb_j))


def test_mesh_stream_apply_is_jittable():
    a = _int_csc(40, 4, seed=6, n_rows=30)
    b = _int_csc(25, 3, seed=7, n_rows=40)
    plan = plan_spgemm_mesh(a, b, shards=1)
    eager = np.asarray(plan.stream_apply(a.values, b.values))
    jitted = np.asarray(jax.jit(plan.stream_apply)(a.values, b.values))
    assert np.array_equal(eager, jitted)


def test_mesh_batched_matches_loop():
    a = _int_csc(40, 4, seed=8, n_rows=30)
    b = _int_csc(25, 3, seed=9, n_rows=40)
    plan = plan_spgemm_mesh(a, b, shards=1)
    B = 3
    av = (np.stack([np.asarray(a.values)] * B)
          * np.arange(1, B + 1, dtype=np.float32)[:, None])
    bv = np.stack([np.asarray(b.values)] * B)
    outs = execute_batched(plan, av, bv)
    assert len(outs) == B
    for i in range(B):
        ci = execute(plan, av[i], bv[i])
        assert np.array_equal(np.asarray(outs[i].values),
                              np.asarray(ci.values))


def test_mesh_empty_operand():
    b = _int_csc(20, 3, seed=10, n_rows=30)
    ea = CSC(np.zeros(0, np.float32), np.zeros(0, np.int32),
             np.zeros(31, np.int32), (25, 30))
    plan = plan_spgemm_mesh(ea, b, shards=1)
    c = plan.execute(ea, b)
    assert c.shape == (25, 20) and c.nnz == 0
    # gradient of the empty contraction is zero, not an error
    g = jax.grad(lambda y: jnp.sum(plan.stream_apply(ea.values, y)))(
        jnp.asarray(b.values))
    assert np.array_equal(np.asarray(g), np.zeros(b.nnz, np.float32))


def test_mesh_oversized_value_arrays():
    # serving overlays pad value arrays past nnz; the vjp must hand back
    # cotangents in the oversized shape with zero tail
    a = _int_csc(30, 3, seed=11, n_rows=25)
    b = _int_csc(20, 3, seed=12, n_rows=30)
    plan = plan_spgemm_mesh(a, b, shards=1)
    pad = 7
    av = jnp.concatenate([jnp.asarray(a.values),
                          jnp.full(pad, 99.0, jnp.float32)])
    bv = jnp.asarray(b.values)
    ref = np.asarray(plan.stream_apply(a.values, b.values))
    assert np.array_equal(np.asarray(plan.stream_apply(av, bv)), ref)
    ga = jax.grad(lambda x, y: jnp.sum(plan.stream_apply(x, y)), 0)(av, bv)
    assert ga.shape == av.shape
    assert np.array_equal(np.asarray(ga[a.nnz:]), np.zeros(pad, np.float32))


# --- api threading: cache, auto, executor contract -------------------------


def test_spgemm_mesh_through_api_and_cache():
    a = _int_csc(50, 5, seed=2, n_rows=40)
    b = _int_csc(30, 4, seed=3, n_rows=50)
    c = spgemm(a, b, "expand", backend="mesh", shards=1)
    assert _bit_identical(_as_host(c), _host_oracle(a, b))
    key = api.plan_cache_key(a, b, "expand", backend="mesh", shards=1)
    plan = api.plan_cache_peek(key)
    assert plan is not None and plan.backend == "mesh"
    assert cached_plan(a, b, "expand", backend="mesh", shards=1) is plan
    # method spellings collapse to the canonical stream contraction
    assert cached_plan(a, b, "spa", backend="mesh", shards=1) is plan
    info = api.plan_cache_info()
    assert info["mesh_stream_bytes"] >= plan.mesh_stream_nbytes > 0


def test_mesh_plans_key_on_shard_count():
    a = _int_csc(30, 3, seed=13, n_rows=25)
    b = _int_csc(20, 3, seed=14, n_rows=30)
    k1 = api.plan_cache_key(a, b, "expand", backend="mesh", shards=1)
    k2 = api.plan_cache_key(a, b, "expand", backend="mesh", shards=4)
    assert k1 != k2


def test_auto_mesh_small_matrix_stays_single_device():
    a = _int_csc(30, 3, seed=15, n_rows=25)
    b = _int_csc(20, 3, seed=16, n_rows=30)
    assert not should_distribute(tile_stats(a, b), 8)
    c = spgemm(a, b, "auto", backend="mesh", shards=1)
    ref = spgemm(a, b, "auto", backend="jax")
    np.testing.assert_allclose(np.asarray(c.values), np.asarray(ref.values))


def test_should_distribute_above_guard():
    a = random_density_csc(64, 64, 0.3, seed=17)
    b = random_density_csc(64, 64, 0.3, seed=18)
    st = tile_stats(a, b)
    # a stream above the (per-shard) guard must distribute on any D > 1
    assert should_distribute(st, 8, shard_limit=st.flops // 2)
    assert not should_distribute(st, 1, shard_limit=st.flops // 2)
    # far below the guard, communication overhead wins on CI constants
    assert not should_distribute(st, 8)


def test_estimate_mesh_cost_comm_terms():
    from repro.sparse.stats import TileStats

    small = tile_stats(random_density_csc(64, 64, 0.4, seed=19),
                       random_density_csc(64, 64, 0.4, seed=20))
    # in-guard: sharding splits compute but pays collective overhead, so
    # small multiplies must predict slower distributed
    assert estimate_mesh_cost(small, 2) > estimate_mesh_cost(small, 1)
    # far above the guard: the single-device estimate pays the per-call
    # transient rebuild, the sharded one does not — distribution wins
    import repro.core.fast as fast

    big_flops = 4 * fast.STREAM_MAX_PRODUCTS
    big = TileStats(m=10**5, k=10**5, n=10**5, nnz_a=10**6, nnz_b=10**6,
                    ops=np.array([big_flops], np.int64),
                    steps=np.array([1], np.int64))
    assert should_distribute(big, 8)
    assert estimate_mesh_cost(big, 8) < estimate_mesh_cost(big, 1)


def test_mesh_needs_enough_devices_at_execute():
    a = _int_csc(30, 3, seed=21, n_rows=25)
    b = _int_csc(20, 3, seed=22, n_rows=30)
    plan = plan_spgemm_mesh(a, b, shards=len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        plan.execute(a, b)


# --- the multi-device path (subprocess: conftest pins one device) ----------


_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core.planner import plan_spgemm
    from repro.core.executor import execute
    from repro.distributed import plan_spgemm_mesh
    from repro.sparse import random_uniform_csc
    from repro.sparse.format import CSC, csc_bit_identical

    assert len(jax.devices()) == 8, jax.devices()
    rng = np.random.default_rng(0)
    a = random_uniform_csc(160, 8, seed=0, n_rows=120)
    b = random_uniform_csc(120, 7, seed=1, n_rows=160)
    a = CSC(rng.integers(1, 8, a.nnz).astype(np.float32),
            a.row_indices, a.col_ptr, a.shape)
    b = CSC(rng.integers(1, 8, b.nnz).astype(np.float32),
            b.row_indices, b.col_ptr, b.shape)

    # per-shard guard far below the total stream: only a mesh plan fits
    total = int(sum(np.diff(a.col_ptr)[b.row_indices]))
    limit = total // 4
    plan = plan_spgemm_mesh(a, b, shards=8, shard_limit=limit)
    ss = plan.stream
    assert ss.n_products == total
    assert int(ss.per_device.max()) <= limit
    c = plan.execute(a, b)
    ref = execute(plan_spgemm(a, b, "expand", backend="host",
                              stream_limit=10**12), a, b, engine="stream")
    ok = csc_bit_identical(
        CSC(np.asarray(c.values), np.asarray(c.row_indices),
            np.asarray(c.col_ptr), c.shape), ref)

    # grads across the 8-device psum_scatter reduction
    jp = plan_spgemm(a, b, "expand", backend="jax", stream_limit=10**12)
    f_m = lambda x, y: jnp.sum(plan.stream_apply(x, y) ** 2)
    f_j = lambda x, y: jnp.sum(jp.stream_apply(x, y) ** 2)
    ga_m, gb_m = jax.grad(f_m, (0, 1))(jnp.asarray(a.values),
                                       jnp.asarray(b.values))
    ga_j, gb_j = jax.grad(f_j, (0, 1))(jnp.asarray(a.values),
                                       jnp.asarray(b.values))
    grads_ok = (np.allclose(np.asarray(ga_m), np.asarray(ga_j))
                and np.allclose(np.asarray(gb_m), np.asarray(gb_j)))
    print(json.dumps({
        "bit_identical": bool(ok), "grads_ok": bool(grads_ok),
        "imbalance": plan.imbalance,
        "per_device": ss.per_device.tolist(),
        "devices": len(jax.devices())}))
""")


def test_eight_device_mesh_subprocess():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    report = json.loads(out.stdout.strip().splitlines()[-1])
    assert report["devices"] == 8
    assert report["bit_identical"], report
    assert report["grads_ok"], report
    assert report["imbalance"] < 2.0, report

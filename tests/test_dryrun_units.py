"""Dry-run machinery units that don't need 512 devices: HLO parsing,
accounting, collective regex. (The real multi-pod compile sweep is
launch/dryrun.py; its artifacts are checked in test_dryrun_artifacts.py.)"""

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.accounting import (
    active_params, model_flops, total_params)
from repro.models.config import DECODE_32K, TRAIN_4K


def test_collective_regex():
    import importlib

    dr = importlib.import_module("repro.launch.dryrun")
    hlo = """
  %ag = bf16[128,512]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add
  %t = (f32[2,2]{1,0}, f32[4]{0}) all-to-all(%a, %b)
  %rs-start = bf16[64]{0} reduce-scatter-start(%z)
"""
    res = dr.collective_bytes(hlo)
    assert res["bytes"]["all-gather"] == 128 * 512 * 2
    assert res["bytes"]["all-reduce"] == 4096
    assert res["bytes"]["all-to-all"] == 16 + 16
    assert res["counts"]["all-gather"] == 1


def test_hlo_analyzer_on_synthetic_module():
    import sys
    sys.path.insert(0, ".")
    from benchmarks.hlo_analysis import analyze

    hlo = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %w = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,8]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tup = (s32[], f32[8,8]) tuple(%c, %dot.1)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %k = s32[] constant(5)
  ROOT %cmp = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %wh = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%wh), index=1
}
"""
    res = analyze(hlo)
    assert res["flops"] == 5 * 2 * 8 * 8 * 8  # trip-count multiplied


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_accounting_sane(arch):
    cfg = ARCHS[arch]
    n_tot = total_params(cfg)
    n_act = active_params(cfg)
    if cfg.attn_every:  # weight-tied shared block: active counts each apply
        assert 0 < n_act <= n_tot * 1.6
    else:
        assert 0 < n_act <= n_tot * 1.05  # unembed-vs-embed rounding slack
    if cfg.moe:
        assert n_act < n_tot * 0.5  # MoE: most params inactive
    # published ballparks (within 2x — configs are from the assignment table)
    expect = {"yi-34b": 34e9, "granite-20b": 20e9, "falcon-mamba-7b": 7e9,
              "zamba2-2.7b": 2.7e9, "qwen2-0.5b": 0.5e9,
              "llama4-maverick-400b-a17b": 400e9}.get(arch)
    if expect:
        assert 0.5 * expect < n_tot < 2.2 * expect, (arch, n_tot)


def test_llama4_active_matches_a17b():
    n_act = active_params(ARCHS["llama4-maverick-400b-a17b"])
    assert 10e9 < n_act < 25e9  # "a17b"


def test_model_flops_scaling():
    cfg = ARCHS["yi-34b"]
    tr = model_flops(cfg, TRAIN_4K)
    de = model_flops(cfg, DECODE_32K)
    # train: 6·N·D with D=1M tokens
    assert tr["model_flops"] > 6 * 30e9 * 1e6 * 0.8
    # decode: 2·N per token x 128 slots
    assert de["model_flops"] < tr["model_flops"] / 1000
    assert de["tokens"] == 128


def test_accum_heuristic():
    from repro.launch import dryrun as dr  # safe: only reads env at main

    assert dr._accum_for(ARCHS["qwen2-0.5b"]) == 1
    assert dr._accum_for(ARCHS["yi-34b"]) == 8
    assert dr._accum_for(ARCHS["zamba2-2.7b"]) == 4

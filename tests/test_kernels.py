"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import spgemm_dense
from repro.core.reference import dense_product
from repro.kernels import (
    bsr_from_dense, bsr_spmm, spgemm_pallas, spa_spgemm, spars_spgemm,
    hash_spgemm,
)
from repro.kernels.ref import (
    spgemm_padded_ref, spars_ref, hash_tables_to_dense, bsr_spmm_ref,
)
from repro.sparse import (
    csc_to_padded_columns, random_powerlaw_csc, random_uniform_csc,
)
from repro.sparse.format import csc_equal


def _padded(m, dtype):
    r, v, n = csc_to_padded_columns(m)
    return (jnp.asarray(r, jnp.int32), jnp.asarray(v, dtype),
            jnp.asarray(n, jnp.int32))


@pytest.mark.parametrize("n,z,block", [(64, 2, 16), (96, 4, 32), (128, 6, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_spa_kernel_sweep(n, z, block, dtype):
    a = random_uniform_csc(n, z, seed=n + z)
    ar, av, an = _padded(a, dtype)
    got = spa_spgemm(ar, av, an, ar, av, an, m=n, block_cols=block)
    ref = spgemm_padded_ref(ar, av, an, ar, av, an, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), dense_product(a, a),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,avg,block", [(64, 2.0, 16), (96, 3.0, 32)])
def test_spars_kernel_sweep(n, avg, block):
    from repro.sparse.stats import ops_per_column

    a = random_powerlaw_csc(n, avg, seed=int(avg * 10))
    ar, av, an = _padded(a, jnp.float32)
    ops = ops_per_column(a, a)
    order = np.argsort(-ops, kind="stable")
    n_pad = -(-n // block) * block
    br = np.zeros((n_pad, ar.shape[1]), np.int32)
    bv = np.zeros((n_pad, av.shape[1]), np.float32)
    bn = np.zeros(n_pad, np.int32)
    br[:n], bv[:n], bn[:n] = (np.asarray(ar)[order], np.asarray(av)[order],
                              np.asarray(an)[order])
    steps = np.pad(ops[order], (0, n_pad - n)).reshape(-1, block).max(1)
    got, flags = spars_spgemm(
        ar, av, an, jnp.asarray(br), jnp.asarray(bv), jnp.asarray(bn),
        jnp.asarray(steps, jnp.int32), m=n, block_cols=block)
    dense = dense_product(a, a)
    np.testing.assert_allclose(np.asarray(got)[:, :n], dense[:, order],
                               rtol=1e-5, atol=1e-5)
    # flags cover exactly the structurally-touched cells
    struct = (np.abs(dense[:, order]) > 0)
    got_flags = np.asarray(flags)[:, :n] > 0
    assert (got_flags | ~struct).all()  # every nonzero is flagged


@pytest.mark.parametrize("n,z,h,block", [(64, 2, 16, 16), (80, 3, 32, 16)])
def test_hash_kernel_sweep(n, z, h, block):
    from repro.sparse.stats import ops_per_column

    a = random_uniform_csc(n, z, seed=7 * z)
    ar, av, an = _padded(a, jnp.float32)
    ops = ops_per_column(a, a)
    assert ops.max() <= h, "test setup: table must fit"
    n_pad = -(-n // block) * block
    br = np.zeros((n_pad, ar.shape[1]), np.int32)
    bv = np.zeros((n_pad, av.shape[1]), np.float32)
    bn = np.zeros(n_pad, np.int32)
    br[:n], bv[:n], bn[:n] = np.asarray(ar), np.asarray(av), np.asarray(an)
    steps = np.pad(ops, (0, n_pad - n)).reshape(-1, block).max(1)
    keys, vals = hash_spgemm(
        ar, av, an, jnp.asarray(br), jnp.asarray(bv), jnp.asarray(bn),
        jnp.asarray(steps, jnp.int32), m=n, h=h, block_cols=block)
    got = np.asarray(hash_tables_to_dense(keys, vals, n))[:, :n]
    np.testing.assert_allclose(got, dense_product(a, a), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", [
    "spa", "spars-128/128", "hash-256/256", "h-spa-40/40", "h-hash-256/256",
])
def test_spgemm_pallas_end_to_end(method):
    a = random_powerlaw_csc(72, 3.0, seed=11)
    ref = spgemm_dense(a, a)
    c = spgemm_pallas(a, a, method=method, block_cols=24)
    assert csc_equal(c, ref, rtol=1e-4, atol=1e-5), method


def test_spgemm_backend_dispatch():
    from repro.core import spgemm

    a = random_uniform_csc(48, 2, seed=3)
    ref = spgemm_dense(a, a)
    c = spgemm(a, a, method="spa", backend="pallas")
    assert csc_equal(c, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (8, 16, 32), (16, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_kernel_sweep(bm, bk, bn, dtype):
    rng = np.random.default_rng(bm * bk)
    mdim, kdim, ndim = bm * 6, bk * 5, bn * 3
    w = rng.normal(size=(mdim, kdim)).astype(np.float32)
    # knock out ~half the blocks
    for i in range(0, mdim, bm):
        for j in range(0, kdim, bk):
            if rng.uniform() < 0.5:
                w[i : i + bm, j : j + bk] = 0
    x = rng.normal(size=(kdim, ndim)).astype(np.float32)
    bi, bnnz, blocks = bsr_from_dense(w, bm, bk)
    got = bsr_spmm(jnp.asarray(bi), jnp.asarray(bnnz),
                   jnp.asarray(blocks, dtype), jnp.asarray(x, dtype), bn=bn)
    ref = bsr_spmm_ref(jnp.asarray(bi), jnp.asarray(bnnz),
                       jnp.asarray(blocks, dtype), jnp.asarray(x, dtype))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), w @ x, rtol=tol,
        atol=tol * np.abs(w @ x).max())


def test_bsr_empty_rows():
    w = np.zeros((16, 16), np.float32)
    w[:8, :8] = 1.0
    bi, bnnz, blocks = bsr_from_dense(w, 8, 8)
    x = np.ones((16, 8), np.float32)
    got = bsr_spmm(jnp.asarray(bi), jnp.asarray(bnnz), jnp.asarray(blocks),
                   jnp.asarray(x), bn=8)
    np.testing.assert_allclose(np.asarray(got), w @ x)

"""Hash-accumulator edge paths (Section 3.2), host oracle and Pallas kernel:
full-load collision chains, exactly-full tables (the MAX_PROBES == H bound),
empty-A-column step consumption, and the degenerate b_min == b_max grouping
of the h-hash hybrids."""

import numpy as np
import pytest

from repro.core import HASH_C, hash_numpy, hash_table_size, preprocess, \
    spgemm, spgemm_dense
from repro.sparse import random_powerlaw_csc, random_uniform_csc
from repro.sparse.format import (
    csc_equal, csc_from_dense, csc_to_dense, validate_csc,
)


def _colliding_rows(h: int, count: int, m: int) -> np.ndarray:
    """``count`` distinct rows < m that all hash to the same slot of an
    h-slot table.  h(i) = (i * HASH_C) % h is bijective mod h (HASH_C odd),
    so rows congruent mod h collide exactly."""
    rows = np.arange(0, count) * h + 1
    assert rows.max() < m and len(set((rows * HASH_C) % h)) == 1
    return rows


def _single_chain_case(count: int, table: int, n_cols: int | None = None):
    """A @ B whose populated C columns are built from one collision chain:
    A column 0 holds ``count`` rows that all probe to the same slot of a
    ``table``-slot hash table; three B columns reference A column 0 once."""
    m = table * count + 2
    k = n_cols if n_cols is not None else m
    rows = _colliding_rows(table, count, m)
    a_dense = np.zeros((m, k))
    a_dense[rows, 0] = np.arange(1.0, count + 1)
    b_dense = np.zeros((k, k))
    b_dense[0, :3] = (2.0, -1.0, 0.5)     # three C columns, same chain
    return csc_from_dense(a_dense), csc_from_dense(b_dense)


@pytest.mark.parametrize("h", [4, 8, 16])
def test_hash_numpy_high_load_collision_chain(h):
    """Maximal planner-sized load ((h-1)/h, every key in one probe chain):
    insertion and the read-back probe loop must both terminate and stay
    exact.  h-1 keys congruent mod h chain through h-1 of the h slots."""
    a, b = _single_chain_case(h - 1, table=h)
    pre = preprocess(a, b, t=np.inf, b_min=4, b_max=4)
    # sizing invariant: H is the power of two strictly above max Op_j, so a
    # planner-sized table is never exactly full — (h-1)/h is the ceiling
    assert int(pre.hash_sizes[0]) == h == hash_table_size(h - 1)
    c = hash_numpy(a, b, pre)
    validate_csc(c)
    assert csc_equal(c, spgemm_dense(a, b), rtol=1e-12, atol=0)
    # the chain really is maximal: each C column holds all h-1 entries
    assert np.diff(np.asarray(c.col_ptr))[:3].tolist() == [h - 1] * 3


@pytest.mark.parametrize("h", [2, 4, 8])
def test_hash_numpy_exactly_full_table(h):
    """White-box table-full path: force H == number of distinct colliding
    keys (below what the planner would size), so every slot fills and the
    probe wraps the whole table; must terminate and stay exact."""
    import dataclasses

    a, b = _single_chain_case(h, table=h)
    pre = preprocess(a, b, t=np.inf, b_min=4, b_max=4)
    assert int(pre.hash_sizes[0]) == 2 * h      # planner would size 2h
    full = dataclasses.replace(
        pre, hash_sizes=np.full(pre.blocks.n_blocks, h, np.int64))
    c = hash_numpy(a, b, full)
    validate_csc(c)
    assert csc_equal(c, spgemm_dense(a, b), rtol=1e-12, atol=0)


@pytest.mark.parametrize("h", [2, 4, 8])
def test_hash_kernel_exactly_full_table(h):
    """Same exactly-full chain through the Pallas kernel, called directly
    with H == chain length: MAX_PROBES == H is an exact bound, so a full
    table must still resolve every key within one sweep."""
    import jax.numpy as jnp

    from repro.kernels.hash_spgemm import hash_spgemm
    from repro.kernels.ref import hash_tables_to_dense
    from repro.sparse import csc_to_padded_columns, steps_per_column

    block = 8
    m = h * h + 2
    a, b = _single_chain_case(h, table=h, n_cols=block)
    ar, av, an = (jnp.asarray(x) for x in csc_to_padded_columns(a))
    br, bv, bn = (jnp.asarray(x) for x in csc_to_padded_columns(b))
    steps = jnp.asarray([int(steps_per_column(a, b).max())], jnp.int32)
    keys, vals = hash_spgemm(
        ar, jnp.asarray(av, jnp.float32), an,
        br, jnp.asarray(bv, jnp.float32), bn,
        steps, m=m, h=h, block_cols=block)
    got = np.asarray(hash_tables_to_dense(keys, vals, m))
    want = csc_to_dense(spgemm_dense(a, b)).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # the first lane's table is exactly full
    assert (np.asarray(keys)[:, 0] >= 0).all()


def test_hash_numpy_accumulates_through_collisions():
    """Repeated (row, col) products must accumulate in-place even when the
    key sits at the end of a probe chain."""
    h = 4
    m = h * h + 2
    rows = _colliding_rows(h, h, m)
    a_dense = np.zeros((m, m))
    a_dense[rows, 0] = 1.0
    a_dense[rows, 1] = 10.0               # same rows via a second A column
    b_dense = np.zeros((m, m))
    b_dense[0, 0] = 1.0
    b_dense[1, 0] = 1.0                   # C col 0 = A col 0 + A col 1
    a, b = csc_from_dense(a_dense), csc_from_dense(b_dense)
    c = hash_numpy(a, b, preprocess(a, b, t=np.inf, b_min=4, b_max=4))
    got = csc_to_dense(c)
    assert (got[rows, 0] == 11.0).all()
    assert csc_equal(c, spgemm_dense(a, b), rtol=1e-12, atol=0)


def test_hash_numpy_empty_a_column_consumes_b_entry():
    """B entries referencing empty A columns yield no products but must not
    derail the lane cursors (regression: IndexError / lost products)."""
    m = 12
    a_dense = np.zeros((m, m))
    a_dense[1, 3] = 2.0                    # only A column 3 is non-empty
    b_dense = np.zeros((m, m))
    b_dense[0, 5] = 1.0                    # empty A col 0, consumed first
    b_dense[3, 5] = 4.0                    # then the real product
    b_dense[7, 5] = 1.0                    # empty A col 7, consumed last
    a, b = csc_from_dense(a_dense), csc_from_dense(b_dense)
    for method in ("hash-256/256", "spars-40/40", "h-hash-32/256"):
        c = spgemm(a, b, method=method, cache=False)
        assert csc_equal(c, spgemm_dense(a, b), rtol=1e-12, atol=0), method
        for backend_method in (method,):
            cp = spgemm(a, b, method=backend_method, backend="pallas",
                        cache=False)
            assert csc_equal(cp, spgemm_dense(a, b), rtol=1e-5,
                             atol=1e-6), method


def test_h_hash_degenerate_equal_block_bounds():
    """b_min == b_max: the blocking loop's grow phase never fires; every
    block is exactly b_min wide (except the tail) and execution stays exact
    on both backends."""
    a = random_powerlaw_csc(50, 3.0, seed=3)
    params_h = dict(t=40.0, b_min=8, b_max=8)
    pre = preprocess(a, a, **params_h)
    sizes = pre.blocks.sizes
    assert (sizes[:-1] == 8).all() and sizes[-1] <= 8
    ref = spgemm_dense(a, a)
    c_host = spgemm(a, a, method="h-hash-256/256", t=40.0, b_min=8, b_max=8,
                    cache=False)
    assert csc_equal(c_host, ref, rtol=1e-9, atol=1e-11)
    c_pal = spgemm(a, a, method="h-hash-256/256", t=40.0, b_min=8, b_max=8,
                   backend="pallas", cache=False)
    assert csc_equal(c_pal, ref, rtol=1e-4, atol=1e-5)


def test_h_hash_b_min_eq_b_max_single_spa_regime():
    """Degenerate grouping where t sends *every* column to one side: t=0 puts
    all columns in the blocked tail; t=inf-like large t puts all in SPA."""
    a = random_uniform_csc(40, 3, seed=4)
    ref = spgemm_dense(a, a)
    all_blocked = spgemm(a, a, method="h-hash-256/256", t=1e9, cache=False)
    assert csc_equal(all_blocked, ref, rtol=1e-9, atol=1e-11)
    pre = preprocess(a, a, t=1e9, b_min=256, b_max=256)
    assert pre.split == 0                  # nothing reaches the SPA head


def test_hash_sizes_monotone_and_exact_po2():
    """Section 3.2 invariants the kernel relies on: per-block H is a power
    of two >= the block's max Op_j, and never grows along sorted blocks."""
    a = random_powerlaw_csc(80, 4.0, seed=5)
    pre = preprocess(a, a, t=np.inf, b_min=8, b_max=8)
    hs = pre.hash_sizes
    assert ((hs & (hs - 1)) == 0).all()
    assert (np.diff(hs) <= 0).all()
    for i, (s, z) in enumerate(pre.blocks):
        assert hs[i] >= pre.ops_sorted[s]   # every block's keys always fit


def test_hash_kernel_rejects_non_power_of_two_table():
    from repro.kernels.hash_spgemm import hash_spgemm
    import jax.numpy as jnp

    z = jnp.zeros((16, 2), jnp.int32)
    v = jnp.zeros((16, 2), jnp.float32)
    n = jnp.zeros(16, jnp.int32)
    with pytest.raises(AssertionError, match="power of two"):
        hash_spgemm(z, v, n, z, v, n, jnp.zeros(1, jnp.int32),
                    m=16, h=3, block_cols=16)

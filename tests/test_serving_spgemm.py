"""Serving under stream-backed SpGEMM + the engine's boundary bugfixes.

Covers the ISSUE 7 regressions (empty prompt, prompt/cache bounds) and the
DESIGN.md §12 serving protocol: spgemm-overlaid FFNs in the jitted decode
step, the eager host-stream fallback tick while the background warm is in
flight, and promotion to the compiled step afterwards — with the decode
results independent of which path served which tick.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import PlanBuilder
from repro.models import (
    decode_step, decode_step_loop, init_cache, init_model, smoke,
)
from repro.models.sparse_ffn import densify_ffn_params, sparsify_ffn_params
from repro.serving import ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke(ARCHS["qwen2-0.5b"])
    params = init_model(cfg, KEY)
    return cfg, params


@pytest.fixture(scope="module")
def sparse_model(small_model):
    cfg, params = small_model
    sparse_params, overlay = sparsify_ffn_params(cfg, params,
                                                 keep_density=0.5)
    return cfg, sparse_params, overlay


# ---------------------------------------------------------------------------
# request-boundary regressions (satellites 1 + 2)
# ---------------------------------------------------------------------------


def test_empty_prompt_rejected_at_submit(small_model):
    """Regression: an empty prompt used to be admitted and then crash
    _next_tokens mid-flight (IndexError on req.generated[-1])."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    assert not eng.queue  # nothing admitted


def test_oversize_prompt_rejected_at_submit(small_model):
    """Regression: a prompt longer than the KV cache used to be admitted
    and overrun the cache during prefill."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=16)
    with pytest.raises(ValueError, match="cannot fit"):
        eng.submit(list(range(16)))
    assert not eng.queue


def test_prompt_exactly_cache_minus_one(small_model):
    """The largest admissible prompt prefills fully and still produces a
    token before the slot retires at the cache bound."""
    cfg, params = small_model
    cache_len = 16
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=cache_len)
    rid = eng.submit(list(range(1, cache_len)), max_new_tokens=8)
    done = eng.run_to_completion()
    req = done[rid]
    assert len(req.generated) == 1  # room for exactly one generated token
    assert req.done


def test_eos_on_first_sampled_token(small_model):
    """EOS fired by the very first generated token retires the request
    with exactly that one token."""
    cfg, params = small_model
    probe = ServeEngine(cfg, params, max_batch=1, cache_len=32)
    probe.submit([3, 4], max_new_tokens=1)
    eos = list(probe.run_to_completion().values())[0].generated[0]
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=32)
    rid = eng.submit([3, 4], max_new_tokens=10, eos_id=eos)
    done = eng.run_to_completion()
    assert done[rid].generated == [eos]
    assert done[rid].done


def test_slot_reuse_is_deterministic(small_model):
    """A slot freed by a finished request serves the next request with no
    state leaking from the previous occupant."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=32)
    rids = [eng.submit([7, 8, 9], max_new_tokens=4) for _ in range(3)]
    done = eng.run_to_completion()
    gens = [done[r].generated for r in rids]
    assert gens[0] == gens[1] == gens[2]


# ---------------------------------------------------------------------------
# sparse decode correctness (tentpole: spgemm FFNs inside decode)
# ---------------------------------------------------------------------------


def test_sparse_decode_matches_dense_reference(sparse_model):
    """decode_step with the spgemm overlay == decode_step on the densified
    weights, for the scanned, eager-loop, and jitted spellings."""
    cfg, sparse_params, overlay = sparse_model
    dense_ref = densify_ffn_params(cfg, sparse_params, overlay)
    cache = init_cache(cfg, 2, 16, jnp.float32)
    tok = jnp.array([[3], [5]], jnp.int32)
    cur = jnp.zeros(2, jnp.int32)

    ref, _ = decode_step(dense_ref, cfg, tok, cache, cur)
    got, _ = decode_step(sparse_params, cfg, tok, cache, cur,
                         sparse_ffn=overlay)
    loop, _ = decode_step_loop(sparse_params, cfg, tok, cache, cur,
                               sparse_ffn=overlay, sparse_host=True)
    jitted, _ = jax.jit(
        lambda p, t, c, l: decode_step(p, cfg, t, c, l,
                                       sparse_ffn=overlay)
    )(sparse_params, tok, cache, cur)

    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(loop, got, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(got))


def test_sparse_engine_plain_serving(sparse_model):
    """No builder: the engine serves the overlay synchronously (ready from
    tick 0) and produces valid tokens."""
    cfg, sparse_params, overlay = sparse_model
    eng = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                      sparse_ffn=overlay)
    assert eng.sparse_ready()
    rid = eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run_to_completion()
    assert len(done[rid].generated) == 4
    assert all(0 <= t < cfg.vocab for t in done[rid].generated)
    assert eng.tick_stats["fallback_ticks"] == 0


# ---------------------------------------------------------------------------
# the async warm protocol (tentpole: ticks never block on plan builds)
# ---------------------------------------------------------------------------


def test_tick_completes_while_build_in_flight(sparse_model):
    """Acceptance test: with the background warm held in flight (worker
    pinned behind a gate), decode ticks still complete — on the fallback
    path — and the engine promotes to the jitted step once the warm lands,
    generating the same tokens as a jit-only run."""
    cfg, sparse_params, overlay = sparse_model
    gate = threading.Event()
    with PlanBuilder() as builder:
        builder.submit_task(gate.wait, tag="gate")  # warm cannot start
        eng = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                          sparse_ffn=overlay, plan_builder=builder)
        assert not eng.sparse_ready()
        rid = eng.submit([1, 2, 3], max_new_tokens=6)
        for _ in range(3):
            assert eng.step()  # completes with the build still gated
        assert eng.tick_stats["fallback_ticks"] == 3
        assert eng.tick_stats["jit_ticks"] == 0
        assert not eng.sparse_ready()

        gate.set()
        assert eng.wait_sparse(120)
        done = eng.run_to_completion()
        assert eng.tick_stats["jit_ticks"] > 0
    mixed_gen = done[rid].generated
    assert len(mixed_gen) == 6

    # jit-only reference run: same request, warm path from the start
    ref = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                      sparse_ffn=overlay)
    rid2 = ref.submit([1, 2, 3], max_new_tokens=6)
    assert ref.run_to_completion()[rid2].generated == mixed_gen


def test_dense_engine_unaffected_by_builder(small_model):
    """A dense engine handed a builder stays on the jitted path — there is
    nothing to warm — and behaves exactly as without one."""
    cfg, params = small_model
    with PlanBuilder() as builder:
        eng = ServeEngine(cfg, params, max_batch=1, cache_len=32,
                          plan_builder=builder)
        assert eng.sparse_ready()
        rid = eng.submit([5, 6], max_new_tokens=3)
        done = eng.run_to_completion()
    assert eng.tick_stats["fallback_ticks"] == 0
    assert len(done[rid].generated) == 3


def test_sampled_decode_equivalent_across_promotion(sparse_model):
    """Sampled (temperature>0) serving across the fallback->jit promotion
    boundary: same engine seed => same token sequence whether ticks ran
    eager-fallback, jitted, or a mix.  Requires both paths to produce the
    same sampling distributions *and* to consume PRNG entropy identically
    per tick — a promotion mid-request must not shift the stream."""
    cfg, sparse_params, overlay = sparse_model
    gate = threading.Event()
    with PlanBuilder() as builder:
        builder.submit_task(gate.wait, tag="gate")  # warm cannot start
        eng = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                          sparse_ffn=overlay, plan_builder=builder,
                          seed=123)
        rid = eng.submit([1, 2, 3], max_new_tokens=8, temperature=0.7)
        for _ in range(4):
            assert eng.step()   # sampled ticks on the fallback path
        assert eng.tick_stats["fallback_ticks"] == 4
        gate.set()
        assert eng.wait_sparse(120)
        done = eng.run_to_completion()
        assert eng.tick_stats["jit_ticks"] > 0  # promotion happened
    mixed_gen = done[rid].generated

    # jit-only reference: same PRNG seed, warm path from the start
    ref = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                      sparse_ffn=overlay, seed=123)
    rid2 = ref.submit([1, 2, 3], max_new_tokens=8, temperature=0.7)
    assert ref.run_to_completion()[rid2].generated == mixed_gen

    # a different seed draws a different sequence (the test has teeth)
    other = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                        sparse_ffn=overlay, seed=124)
    rid3 = other.submit([1, 2, 3], max_new_tokens=8, temperature=0.7)
    assert other.run_to_completion()[rid3].generated != mixed_gen


# ---------------------------------------------------------------------------
# many engines, one shared builder (ISSUE 9 satellite / ROADMAP item 1)
# ---------------------------------------------------------------------------


def test_many_engines_share_one_builder(small_model, sparse_model):
    """Concurrent engines on one PlanBuilder: warms never cross-deliver
    (an engine only becomes ready via its own warm), each engine's greedy
    output matches a solo reference, and closing one engine leaves the
    shared builder serving the others."""
    cfg, sparse_params, overlay = sparse_model
    _, params = small_model
    sparse_params3, overlay3 = sparsify_ffn_params(cfg, params,
                                                   keep_density=0.25)
    prompts = {1: [1, 2, 3], 2: [4, 5], 3: [6, 7, 8]}
    with PlanBuilder() as builder:
        eng1 = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                           sparse_ffn=overlay, plan_builder=builder)
        assert eng1.wait_sparse(120)

        # eng2 shares eng1's overlay (same plans, deduped through the
        # LRU) but must NOT inherit eng1's readiness: gate the builder so
        # eng2's own warm cannot have run yet
        gate = threading.Event()
        builder.submit_task(gate.wait, tag="gate2")
        eng2 = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                           sparse_ffn=overlay, plan_builder=builder)
        assert eng1.sparse_ready() and not eng2.sparse_ready()
        gate.set()

        eng3 = ServeEngine(cfg, sparse_params3, max_batch=2, cache_len=32,
                           sparse_ffn=overlay3, plan_builder=builder)
        engines = {1: eng1, 2: eng2, 3: eng3}
        rids = {i: e.submit(prompts[i], max_new_tokens=5)
                for i, e in engines.items()}
        for _ in range(200):        # interleaved ticks across all engines
            if not any(e.queue or any(e.slots) for e in engines.values()):
                break
            for e in engines.values():
                if e.queue or any(e.slots):
                    e.step()
        gens = {i: e.finished[rids[i]].generated
                for i, e in engines.items()}

        # closing one engine must not kill the shared builder
        eng1.close()
        builder.submit_task(lambda: "alive", tag="alive")
        assert builder.wait_idle(120)
        assert any(r.tag == "alive" and r.ok for r in builder.poll())

    for i, (model, ovl) in {1: (sparse_params, overlay),
                            2: (sparse_params, overlay),
                            3: (sparse_params3, overlay3)}.items():
        ref = ServeEngine(cfg, model, max_batch=2, cache_len=32,
                          sparse_ffn=ovl)
        rid = ref.submit(prompts[i], max_new_tokens=5)
        assert ref.run_to_completion()[rid].generated == gens[i], i
        assert len(gens[i]) == 5

"""Deliverable (e) gate: every required (arch x shape x mesh) cell must have
a successful dry-run artifact. Skipped (with an explicit message) until
launch/dryrun.py --all has produced them — CI order: dry-run first, then
pytest."""

import glob
import json
import os

import pytest

from repro.configs import ARCHS
from repro.models import shapes_for

DRY = os.path.join(os.environ.get("REPRO_CACHE", ".cache"), "dryrun")

_have = bool(glob.glob(os.path.join(DRY, "*.json")))


def _cells():
    out = []
    for arch in sorted(ARCHS):
        for shape in shapes_for(ARCHS[arch]):
            out.append((arch, shape.name))
    return out


@pytest.mark.skipif(not _have, reason="run repro.launch.dryrun --all first")
@pytest.mark.parametrize("mesh", ["16x16", "2x16x16"])
def test_all_cells_compiled(mesh):
    missing = []
    for arch, shape in _cells():
        path = os.path.join(DRY, f"{arch}__{shape}__{mesh}.json")
        if not os.path.exists(path):
            missing.append(f"{arch}/{shape}")
            continue
        rec = json.load(open(path))
        assert rec["collectives"]["total_bytes"] >= 0
        assert rec["compile_seconds"] > 0
    if mesh == "2x16x16" and missing == [f"{a}/{s}" for a, s in _cells()]:
        pytest.skip("multi-pod sweep not yet run")
    assert not missing, f"{len(missing)} cells missing for {mesh}: {missing}"


@pytest.mark.skipif(not _have, reason="run repro.launch.dryrun --all first")
def test_long_context_cells_only_for_subquadratic():
    for path in glob.glob(os.path.join(DRY, "*long_500k*.json")):
        rec = json.load(open(path))
        assert ARCHS[rec["arch"]].supports_long_context


@pytest.mark.skipif(not _have, reason="run repro.launch.dryrun --all first")
def test_decode_cells_donate_cache_fit():
    """Serve-cache argument bytes per device stay under the v5e HBM budget."""
    for path in glob.glob(os.path.join(DRY, "*decode_32k__16x16.json")):
        rec = json.load(open(path))
        args = rec.get("memory", {}).get("argument_size_in_bytes")
        if args:
            assert args < 16 * 2**30, (path, args)

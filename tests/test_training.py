"""Training substrate: optimizer (incl. 8-bit moments), checkpoint drill
(E11), data determinism, end-to-end loss decrease."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import smoke
from repro.training import (
    AdamWConfig, DataConfig, SyntheticLoader, TrainConfig, Trainer,
    adamw_init, adamw_update, build_train_step, init_train_state,
    latest_checkpoint, restore_checkpoint, save_checkpoint, synth_batch,
    warmup_cosine,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def _quad_params():
    return {"w": jnp.array([[1.0, -2.0], [3.0, 0.5]]),
            "b": jnp.array([0.1, -0.1])}


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = _quad_params()
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, cfg, cfg.lr)
    assert float(loss(params)) < l0 * 0.01


def test_adamw_quantized_matches_fp32_approximately():
    base = AdamWConfig(lr=0.01, weight_decay=0.0)
    quant = AdamWConfig(lr=0.01, weight_decay=0.0, quantize_moments=True)
    params_a = _quad_params()
    params_b = jax.tree_util.tree_map(jnp.array, params_a)
    sa, sb = adamw_init(params_a, base), adamw_init(params_b, quant)
    # moments of 2-D leaves are quantized, 1-D leaves stay fp32
    assert isinstance(sb["m"]["w"], dict) and sb["m"]["w"]["q"].dtype == jnp.int8
    assert not isinstance(sb["m"]["b"], dict)

    def loss(p):
        return jnp.sum((p["w"] - 1.0) ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(30):
        ga = jax.grad(loss)(params_a)
        gb = jax.grad(loss)(params_b)
        params_a, sa = adamw_update(ga, sa, params_a, base, base.lr)
        params_b, sb = adamw_update(gb, sb, params_b, quant, quant.lr)
    np.testing.assert_allclose(np.asarray(params_a["w"]),
                               np.asarray(params_b["w"]), atol=0.05)


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = _quad_params()
    state = adamw_init(params, cfg)
    huge = jax.tree_util.tree_map(lambda p: 1e9 * jnp.ones_like(p), params)
    new_params, _ = adamw_update(huge, state, params, cfg, 1e-3)
    delta = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), new_params, params)
    assert max(jax.tree_util.tree_leaves(delta)) < 1.0


def test_schedule_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1e-3, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert abs(max(lrs) - 1e-3) < 1e-9
    assert lrs[-1] < lrs[50] < lrs[10]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=4, seed=7)
    b1 = synth_batch(cfg, 5)
    b2 = synth_batch(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    loader = SyntheticLoader(cfg)
    for _ in range(3):
        next(loader)
    state = loader.state()
    b_next = next(loader)
    resumed = SyntheticLoader.restore(cfg, state)
    np.testing.assert_array_equal(next(resumed)["tokens"], b_next["tokens"])


def test_data_is_learnable_structure():
    cfg = DataConfig(vocab=53, seq_len=64, global_batch=8, seed=0, noise=0.0)
    b = synth_batch(cfg, 0)
    # with zero noise, labels are a deterministic function of tokens
    t, l = b["tokens"][0], b["labels"][0]
    assert (t[1:] == l[:-1]).all()


# ---------------------------------------------------------------------------
# checkpointing (E11)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        save_checkpoint(d, step, tree, extra={"x": step}, keep=2)
    assert latest_checkpoint(d).endswith("step_00000004")
    assert len([n for n in os.listdir(d) if n.startswith("step_")]) == 2
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, step, extra = restore_checkpoint(latest_checkpoint(d), template)
    assert step == 4 and extra == {"x": 4}
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((8, 8))}
    path = save_checkpoint(d, 1, tree)
    # corrupt a leaf
    victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    data = np.load(os.path.join(path, victim))
    data[0, 0] += 1
    np.save(os.path.join(path, victim), data)
    with pytest.raises(IOError):
        restore_checkpoint(path, tree)


def test_trainer_resume_replays_stream(tmp_path):
    """Kill/restart drill: loss trajectory must continue, not restart."""
    cfg = smoke(ARCHS["qwen2-0.5b"])
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=1)
    tc = TrainConfig(total_steps=6, checkpoint_dir=str(tmp_path / "run"),
                     checkpoint_every=3, log_every=100, peak_lr=1e-3,
                     warmup_steps=2)
    state = init_train_state(cfg, tc, KEY)
    t1 = Trainer(cfg, tc, SyntheticLoader(dcfg), state)
    t1.run(n_steps=4)  # checkpoints at step 3
    # simulated crash: new trainer, fresh state, auto-resume
    state2 = init_train_state(cfg, tc, KEY)
    t2 = Trainer(cfg, tc, SyntheticLoader(dcfg), state2)
    assert t2.try_resume()
    assert t2.step_idx == 3
    assert t2.loader.step == 3
    t2.run(n_steps=2)
    assert t2.step_idx == 5


def test_trainer_loss_decreases():
    import dataclasses

    cfg = dataclasses.replace(smoke(ARCHS["qwen2-0.5b"]), vocab=128)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=2,
                      noise=0.0, n_maps=2)
    tc = TrainConfig(total_steps=80, peak_lr=2e-2, warmup_steps=10,
                     log_every=100)
    state = init_train_state(cfg, tc, KEY)
    t = Trainer(cfg, tc, SyntheticLoader(dcfg), state)
    log = t.run()
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    # must both decrease and beat the uniform floor ln(128)=4.85
    assert last < first - 0.4, (first, last)
    assert last < 4.85, last


def test_accum_steps_equivalent_loss_scale():
    """accum=2 and accum=1 see the same data => similar first-step loss."""
    cfg = smoke(ARCHS["qwen2-0.5b"])
    tc1 = TrainConfig(accum_steps=1)
    tc2 = TrainConfig(accum_steps=2)
    state = init_train_state(cfg, tc1, KEY)
    batch = synth_batch(DataConfig(cfg.vocab, 64, 4, seed=3), 0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    s1 = build_train_step(cfg, tc1)
    s2 = build_train_step(cfg, tc2)
    _, m1 = s1(jax.tree_util.tree_map(jnp.array, state), batch, jnp.int32(0))
    _, m2 = s2(jax.tree_util.tree_map(jnp.array, state), batch, jnp.int32(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-3)

"""Plan/execute architecture: reuse bit-identity, cache behavior, and the
no-dense-intermediate guarantee of the Pallas backend (DESIGN.md §6)."""

import numpy as np
import pytest

from repro.core import (
    ALGORITHMS, pattern_fingerprint, plan_cache_clear, plan_cache_info,
    plan_cache_resize, plan_spgemm, spgemm, spgemm_dense,
)
from repro.core import api as core_api
from repro.sparse import random_powerlaw_csc, random_uniform_csc
from repro.sparse.format import (
    CSC, CSCBuilder, csc_equal, csc_from_dense, validate_csc,
)

PALLAS_METHODS = [m for m in ALGORITHMS if m not in ("esc", "expand")]


def _reweight(m: CSC, seed: int) -> CSC:
    """Same sparsity pattern, fresh values."""
    rng = np.random.default_rng(seed)
    return CSC(rng.normal(size=m.nnz), m.row_indices, m.col_ptr, m.shape)


def _bit_identical(x: CSC, y: CSC) -> bool:
    return (
        x.shape == y.shape
        and np.array_equal(np.asarray(x.col_ptr), np.asarray(y.col_ptr))
        and np.array_equal(np.asarray(x.row_indices)[: x.nnz],
                           np.asarray(y.row_indices)[: y.nnz])
        and np.array_equal(np.asarray(x.values)[: x.nnz],
                           np.asarray(y.values)[: y.nnz])
    )


# --- plan reuse is bit-identical to planning from scratch ----------------


@pytest.mark.parametrize("method", sorted(ALGORITHMS))
def test_plan_reuse_bit_identical_host(method):
    a = random_powerlaw_csc(80, 3.0, seed=1)
    plan = plan_spgemm(a, a, method)          # planned on a's values
    a2 = _reweight(a, seed=7)                 # same pattern, new values
    fresh = spgemm(a2, a2, method=method, cache=False)
    reused = plan.execute(a2, a2)
    assert _bit_identical(reused, fresh), method
    validate_csc(reused)
    # raw value arrays are accepted too
    raw = plan.execute(np.asarray(a2.values), np.asarray(a2.values))
    assert _bit_identical(raw, fresh), method


@pytest.mark.parametrize("method", sorted(PALLAS_METHODS))
def test_plan_reuse_bit_identical_pallas(method):
    a = random_powerlaw_csc(64, 3.0, seed=2)
    plan = plan_spgemm(a, a, method, backend="pallas", block_cols=16)
    a2 = _reweight(a, seed=8)
    fresh = spgemm(a2, a2, method=method, backend="pallas", cache=False)
    reused = plan.execute(a2, a2)
    assert _bit_identical(reused, fresh), method
    assert csc_equal(reused, spgemm_dense(a2, a2), rtol=1e-4, atol=1e-5)


def test_spgemm_plan_kwarg():
    a = random_uniform_csc(48, 3, seed=3)
    plan = plan_spgemm(a, a, "spars-40/40")
    assert _bit_identical(spgemm(a, a, plan=plan),
                          spgemm(a, a, method="spars-40/40", cache=False))


def test_host_only_methods_rejected_on_pallas():
    a = random_uniform_csc(32, 2, seed=0)
    for method in ("esc", "expand"):
        with pytest.raises(ValueError):
            plan_spgemm(a, a, method, backend="pallas")


def test_unknown_method_rejected_at_plan_time():
    from repro.kernels.ops import spgemm_pallas

    a = random_uniform_csc(32, 2, seed=0)
    for backend in ("host", "pallas"):
        with pytest.raises(ValueError, match="unknown method"):
            plan_spgemm(a, a, "bogus", backend=backend)
    with pytest.raises(ValueError, match="unknown method"):
        spgemm_pallas(a, a, method="bogus")
    # unregistered but well-formed family names stay accepted (seed behavior)
    assert plan_spgemm(a, a, "spars-128/128").method == "spars-128/128"
    # ... but malformed bounds specs are rejected, not silently defaulted
    for bad in ("hash-64", "spars-16//64", "hash-a/b"):
        with pytest.raises(ValueError, match="malformed|unknown"):
            plan_spgemm(a, a, bad)


def test_execute_rejects_mismatched_operands():
    a = random_uniform_csc(32, 2, seed=0)
    plan = plan_spgemm(a, a, "hash-256/256")
    with pytest.raises(ValueError, match="shape"):
        plan.execute(random_uniform_csc(16, 2, seed=1), a)
    bigger = random_uniform_csc(32, 4, seed=2)  # same shape, different nnz
    assert bigger.nnz != a.nnz
    with pytest.raises(ValueError, match="pattern does not match"):
        spgemm(bigger, bigger, plan=plan)
    # a [B, nnz] stack belongs to execute_batched, not execute
    stack = np.zeros((3, a.nnz))
    with pytest.raises(ValueError, match="execute_batched"):
        plan.execute(stack, stack)


def _colliding_pair(n=16):
    """Two patterns with identical (shape, nnz) — and even col_ptr — but
    different row structure: the O(1) compatibility check cannot tell them
    apart."""
    a = csc_from_dense(np.eye(n))
    b = csc_from_dense(np.roll(np.eye(n), 1, axis=0))
    assert a.shape == b.shape and a.nnz == b.nnz
    assert np.array_equal(np.asarray(a.col_ptr), np.asarray(b.col_ptr))
    return a, b


def test_validate_fingerprint_rejects_corrupt_pattern():
    a, corrupt = _colliding_pair()
    plan = plan_spgemm(a, a, "hash-256/256")
    # the O(1) default accepts the wrong pattern silently (documented hole)
    plan.execute(corrupt, corrupt)
    # the opt-in O(nnz) re-hash catches it, on both entry points
    with pytest.raises(ValueError, match="fingerprint"):
        plan.execute(corrupt, corrupt, validate="fingerprint")
    with pytest.raises(ValueError, match="fingerprint"):
        spgemm(corrupt, corrupt, plan=plan, validate="fingerprint")
    # a matching operand passes validation with an unchanged result
    ok = plan.execute(a, a, validate="fingerprint")
    assert _bit_identical(ok, plan.execute(a, a))
    # raw value arrays carry no structure: validation is vacuous for them
    vals = np.asarray(a.values)
    plan.execute(vals, vals, validate="fingerprint")
    with pytest.raises(ValueError, match="validate"):
        plan.execute(a, a, validate="bogus")


def test_validate_fingerprint_batched():
    from repro.sparse import BatchedCSC

    a, corrupt = _colliding_pair()
    plan = plan_spgemm(a, a, "spa")
    bad = BatchedCSC.stack([corrupt, corrupt])
    plan.execute_batched(bad, bad)               # O(1) check passes
    with pytest.raises(ValueError, match="fingerprint"):
        plan.execute_batched(bad, bad, validate="fingerprint")
    good = BatchedCSC.stack([a, a])
    got = plan.execute_batched(good, good, validate="fingerprint")
    assert _bit_identical(got[0], plan.execute(a, a))


def test_plan_cache_distinct_entries_for_colliding_shape_nnz():
    """Two patterns that collide on every O(1) statistic (shape, nnz, even
    col_ptr) must still occupy distinct LRU entries and execute correctly."""
    plan_cache_clear()
    a, b = _colliding_pair()
    assert pattern_fingerprint(a) != pattern_fingerprint(b)
    ca = spgemm(a, a, method="spa")
    cb = spgemm(b, b, method="spa")
    info = plan_cache_info()
    assert (info["hits"], info["misses"], info["size"]) == (0, 2, 2)
    assert csc_equal(ca, spgemm_dense(a, a), rtol=1e-12, atol=0)
    assert csc_equal(cb, spgemm_dense(b, b), rtol=1e-12, atol=0)
    assert not csc_equal(ca, cb)                 # the results really differ
    # re-running hits each pattern's own entry
    assert _bit_identical(spgemm(a, a, method="spa"), ca)
    assert _bit_identical(spgemm(b, b, method="spa"), cb)
    assert plan_cache_info()["hits"] == 2
    plan_cache_clear()


# --- plan cache hit/miss behavior ----------------------------------------


def test_plan_cache_hit_miss_and_eviction(monkeypatch):
    plan_cache_clear()
    a = random_powerlaw_csc(60, 3.0, seed=4)
    spgemm(a, a, method="spa")
    info = plan_cache_info()
    assert (info["hits"], info["misses"]) == (0, 1)
    # same pattern again -> hit, even with different values
    spgemm(_reweight(a, 1), _reweight(a, 2), method="spa")
    info = plan_cache_info()
    assert (info["hits"], info["misses"]) == (1, 1)
    # different pattern -> miss; different method/backend -> miss
    b = random_powerlaw_csc(60, 3.0, seed=5)
    assert pattern_fingerprint(b) != pattern_fingerprint(a)
    spgemm(b, b, method="spa")
    spgemm(a, a, method="hash-256/256")
    info = plan_cache_info()
    assert (info["hits"], info["misses"]) == (1, 3)
    # bounded: evicts least-recently-used beyond PLAN_CACHE_SIZE
    monkeypatch.setattr(core_api, "PLAN_CACHE_SIZE", 2)
    spgemm(a, a, method="spars-40/40")
    assert plan_cache_info()["size"] <= 2
    plan_cache_clear()
    cleared = plan_cache_info()
    # the cost-profile provenance block is machine-dependent (fingerprint,
    # age) and survives a cache clear by design — covered in
    # test_profile.py, compared loosely here
    assert cleared.pop("profile")["source"] in ("default", "measured")
    assert cleared == {
        "hits": 0, "misses": 0, "evictions": 0, "size": 0, "max_size": 2,
        "hit_rate": 0.0, "in_flight": 0, "stream_bytes": 0,
        "device_stream_bytes": 0, "fused_stream_bytes": 0,
        "mesh_stream_bytes": 0, "wasted_builds": 0,
        "listener_errors": 0, "wait_timeouts": 0, "builders": []}


def test_plan_cache_resize_and_hit_rate(monkeypatch):
    """plan_cache_resize() is the supported capacity knob (no module-constant
    mutation) and plan_cache_info() reports the hit rate."""
    monkeypatch.setattr(core_api, "PLAN_CACHE_SIZE", 64)
    plan_cache_clear()
    mats = [random_powerlaw_csc(40, 3.0, seed=s) for s in range(4)]
    for m in mats:
        spgemm(m, m, method="spa")
    assert plan_cache_info()["size"] == 4
    # shrinking evicts the least-recently-used down to the new capacity
    info = plan_cache_resize(2)
    assert info["size"] == 2 and info["max_size"] == 2
    spgemm(mats[0], mats[0], method="spa")     # evicted earlier -> miss
    spgemm(mats[3], mats[3], method="spa")     # most recent -> hit
    info = plan_cache_info()
    assert info["hits"] == 1 and info["misses"] == 5
    assert info["hit_rate"] == pytest.approx(1 / 6)
    # growing keeps entries; zero disables caching entirely
    assert plan_cache_resize(64)["max_size"] == 64
    assert plan_cache_resize(0)["size"] == 0
    spgemm(mats[1], mats[1], method="spa")
    assert plan_cache_info()["size"] == 0
    with pytest.raises(ValueError):
        plan_cache_resize(-1)
    plan_cache_resize(64)
    plan_cache_clear()


# --- held-plan argument conflicts (ISSUE 3 satellite) ---------------------


def test_held_plan_conflicting_arguments_raise():
    a = random_uniform_csc(32, 3, seed=5)
    plan = plan_spgemm(a, a, "h-hash-256/256")
    # conflicting method/backend/params are loud, not silently ignored
    with pytest.raises(ValueError, match="conflict.*method"):
        spgemm(a, a, method="spa", plan=plan)
    with pytest.raises(ValueError, match="conflict.*backend"):
        spgemm(a, a, backend="pallas", plan=plan)
    with pytest.raises(ValueError, match="conflict.*t="):
        spgemm(a, a, t=7.0, plan=plan)
    with pytest.raises(ValueError, match="conflict.*b_min"):
        spgemm(a, a, b_min=16, plan=plan)
    with pytest.raises(ValueError, match="conflict.*b_max"):
        spgemm(a, a, b_max=16, plan=plan)
    # matching arguments (and None) pass through
    c = spgemm(a, a, method="h-hash-256/256", backend="host", t=40,
               b_min=256, b_max=256, plan=plan)
    assert _bit_identical(c, plan.execute(a, a))
    # a parameterless plan rejects any explicit parameter
    spa_plan = plan_spgemm(a, a, "spa")
    with pytest.raises(ValueError, match="conflict"):
        spgemm(a, a, t=40.0, plan=spa_plan)


def test_held_plan_conflicts_batched():
    from repro.core import spgemm_batched
    from repro.sparse import BatchedCSC

    a = random_uniform_csc(24, 2, seed=6)
    plan = plan_spgemm(a, a, "spa")
    ab = BatchedCSC.stack([a, a])
    with pytest.raises(ValueError, match="conflict"):
        spgemm_batched(ab, ab, method="hash-256/256", plan=plan)
    got = spgemm_batched(ab, ab, method="spa", plan=plan)
    assert _bit_identical(got[0], plan.execute(a, a))


def test_fingerprint_ignores_values():
    a = random_powerlaw_csc(50, 3.0, seed=6)
    assert pattern_fingerprint(a) == pattern_fingerprint(_reweight(a, 9))


# --- the Pallas path never materializes an [m, n] dense array ------------


def test_pallas_peak_intermediate_is_tile_bounded():
    n, block = 256, 32
    a = random_powerlaw_csc(n, 3.0, seed=0)
    for method in ("spa", "h-hash-256/256", "spars-40/40"):
        plan = plan_spgemm(a, a, method, backend="pallas", block_cols=block)
        stats = {}
        c = plan.execute(a, a, stats=stats)
        m_dim, n_dim = stats["result_shape"]
        assert stats["peak_tile_elems"] < m_dim * n_dim, method
        for kind, shape in stats["tile_shapes"]:
            if kind == "dense":
                assert shape[0] == m_dim and shape[1] <= block, (method, shape)
            else:  # hash tables are [H, L]: never m-sized at all
                assert shape[1] <= block, (method, shape)
        assert csc_equal(c, spgemm_dense(a, a), rtol=1e-4, atol=1e-5), method


def test_builder_matches_dense_compaction():
    rng = np.random.default_rng(0)
    m, n = 40, 24
    dense = rng.normal(size=(m, n)) * (rng.uniform(size=(m, n)) < 0.2)
    dense = dense.astype(np.float32)
    builder = CSCBuilder((m, n), np.float32)
    builder.add_dense_tile(np.arange(8), dense[:, :8])
    builder.add_dense_tile(np.arange(16, 24), dense[:, 16:24])  # out of order
    builder.add_dense_tile(np.arange(8, 16), dense[:, 8:16])
    got = builder.build()
    assert _bit_identical(got, csc_from_dense(dense))
    assert builder.peak_tile_elems == m * 8


def test_builder_hash_tables_match_densified():
    from repro.kernels.ref import hash_tables_to_dense

    rng = np.random.default_rng(1)
    m, H, L = 30, 8, 6
    keys = np.full((H, L), -1, np.int32)
    vals = np.zeros((H, L), np.float32)
    for l in range(L):
        rows = rng.choice(m, size=rng.integers(0, H), replace=False)
        slots = rng.choice(H, size=len(rows), replace=False)
        keys[slots, l] = rows
        vals[slots, l] = rng.normal(size=len(rows)).astype(np.float32)
    ref = csc_from_dense(np.asarray(hash_tables_to_dense(
        np.asarray(keys), np.asarray(vals), m)))
    builder = CSCBuilder((m, L), np.float32)
    builder.add_hash_tables(np.arange(L), keys, vals)
    assert _bit_identical(builder.build(), ref)


def test_builder_rejects_double_assembly():
    builder = CSCBuilder((4, 4), np.float32)
    builder.add_dense_tile([0, 1], np.zeros((4, 2), np.float32))
    with pytest.raises(ValueError):
        builder.add_dense_tile([1], np.ones((4, 1), np.float32))

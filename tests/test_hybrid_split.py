"""`hybrid_split` boundary behavior (ISSUE 3 satellite): columns with
Op_j == t exactly, all-above-t, all-below-t — at the analysis level and
end-to-end through the hybrid executors."""

import numpy as np
import pytest

from repro.core import hybrid_split, preprocess, spgemm, spgemm_dense
from repro.sparse import ops_per_column, random_powerlaw_csc
from repro.sparse.format import csc_equal


def test_exact_threshold_columns_go_to_spa():
    # Op_j >= t is the SPA head (paper Section 3.3): equality included
    ops_sorted = np.asarray([100, 40, 40, 40, 10, 2])
    assert hybrid_split(ops_sorted, 40.0) == 4
    assert hybrid_split(ops_sorted, 41.0) == 1
    assert hybrid_split(ops_sorted, 10.0) == 5


def test_all_above_threshold():
    ops_sorted = np.asarray([90, 80, 70])
    assert hybrid_split(ops_sorted, 40.0) == 3       # everything SPA
    assert hybrid_split(ops_sorted, 70.0) == 3       # boundary inclusive


def test_all_below_threshold():
    ops_sorted = np.asarray([30, 20, 5])
    assert hybrid_split(ops_sorted, 40.0) == 0       # everything blocked


def test_degenerate_thresholds_and_empty():
    ops_sorted = np.asarray([30, 20, 5])
    assert hybrid_split(ops_sorted, 0.0) == 3        # t=0 -> all SPA
    assert hybrid_split(ops_sorted, -1.0) == 3
    assert hybrid_split(ops_sorted, np.inf) == 0     # t=inf -> all blocked
    assert hybrid_split(np.zeros(0, np.int64), 40.0) == 0


def test_split_equals_count_of_columns_at_or_above_t():
    a = random_powerlaw_csc(80, 3.0, seed=0)
    ops = ops_per_column(a, a)
    ops_sorted = np.sort(ops)[::-1]
    # draw thresholds from the actual loads so ties are exercised
    for t in sorted({int(ops_sorted[i]) for i in (0, 10, 40, 79)}):
        if t <= 0:
            continue
        assert hybrid_split(ops_sorted, float(t)) == int((ops >= t).sum())


@pytest.mark.parametrize("t_kind", ("all_above", "all_below", "exact"))
def test_hybrid_end_to_end_at_boundaries(t_kind):
    a = random_powerlaw_csc(48, 3.0, seed=1)
    ops = ops_per_column(a, a)
    if t_kind == "all_above":
        t = float(ops.min())                 # every column Op_j >= t
    elif t_kind == "all_below":
        t = float(ops.max()) + 1.0           # every column Op_j < t
    else:
        t = float(np.sort(ops)[len(ops) // 2])   # an exact tie value
    pre = preprocess(a, a, t=t, b_min=32, b_max=64)
    assert pre.split == int((ops >= t).sum())
    for method in ("h-hash-32/256", "h-spa-16/64"):
        c = spgemm(a, a, method=method, t=t, cache=False)
        assert csc_equal(c, spgemm_dense(a, a), rtol=1e-9, atol=1e-11), \
            (method, t_kind)

"""Differential SpGEMM harness: every method × both backends against an
*external* oracle — ``scipy.sparse`` when available, the dense reference
otherwise — on random and adversarial sparsity patterns (empty columns,
all-dense columns, single-row support, duplicate-heavy products), not just
the hand-picked cases of the per-algorithm tests.

The hypothesis property sweep piggybacks when the optional dev dependency is
installed (guarded import); the adversarial fixed cases always run.
"""

import numpy as np
import pytest

from repro.core import ALGORITHMS, spgemm, spgemm_dense
from repro.sparse import (
    random_density_csc, random_powerlaw_csc, random_uniform_csc, validate_csc,
)
from repro.sparse.format import CSC, csc_from_dense, csc_to_dense

try:  # optional; CI runs both with and without
    import scipy.sparse as _sps

    HAVE_SCIPY = True
except ImportError:  # pragma: no cover - exercised by the minimal CI leg
    _sps = None
    HAVE_SCIPY = False

try:  # optional dev dependency (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PALLAS_METHODS = [m for m in ALGORITHMS if m not in ("esc", "expand")]


def oracle_product(a: CSC, b: CSC) -> np.ndarray:
    """Dense C = A @ B from an implementation that shares no code with the
    executors under test (scipy if present, else the densified reference)."""
    if HAVE_SCIPY:
        sa = _sps.csc_matrix(
            (np.asarray(a.values)[: a.nnz],
             np.asarray(a.row_indices)[: a.nnz], np.asarray(a.col_ptr)),
            shape=a.shape)
        sb = _sps.csc_matrix(
            (np.asarray(b.values)[: b.nnz],
             np.asarray(b.row_indices)[: b.nnz], np.asarray(b.col_ptr)),
            shape=b.shape)
        return np.asarray((sa @ sb).todense())
    return csc_to_dense(spgemm_dense(a, b))


def _mask_dense(dense, seed):
    return csc_from_dense(np.asarray(dense, np.float64))


def _adversarial(name: str, seed: int = 0):
    """(a, b) operand pairs stressing structural edge paths."""
    rng = np.random.default_rng(seed)
    if name == "random":
        a = random_powerlaw_csc(36, 3.0, seed=seed)
        return a, a
    if name == "empty_cols":
        # half of B's columns empty, plus empty A columns referenced nowhere
        d = rng.normal(size=(32, 32)) * (rng.uniform(size=(32, 32)) < 0.15)
        d[:, ::2] = 0.0
        d[5] = 0.0
        a = _mask_dense(d, seed)
        return a, a
    if name == "all_dense_cols":
        # every column fully dense: maximal Op_j, single SPA-regime block
        d = rng.normal(size=(20, 20))
        a = _mask_dense(d, seed)
        return a, a
    if name == "single_row":
        # all support in one row: every product lands on output row 3
        d = np.zeros((24, 24))
        d[3] = rng.normal(size=24)
        d[3, 3] = 1.5  # keep (3,3) nonzero so A@A has support
        a = _mask_dense(d, seed)
        return a, a
    if name == "dup_heavy":
        # few distinct rows shared by every column: duplicate-heavy products
        d = np.zeros((24, 24))
        d[:4] = rng.normal(size=(4, 24))
        d[np.abs(d) < 0.3] = 0.0
        d[0, :] = 1.0  # row 0 dense: every output column accumulates 24 hits
        a = _mask_dense(d, seed)
        b_d = np.zeros((24, 24))
        b_d[:4] = rng.normal(size=(4, 24))
        return a, _mask_dense(b_d, seed)
    if name == "empty":
        a = csc_from_dense(np.zeros((16, 16)))
        return a, a
    if name == "empty_a":
        # A has no stored entries at all, B is full: every lane's stream is
        # nothing but empty-A-column references
        return csc_from_dense(np.zeros((12, 12))), \
            csc_from_dense(rng.normal(size=(12, 12)))
    if name == "rect_chain":
        a = random_density_csc(18, 30, 0.12, seed=seed)
        b = random_density_csc(30, 11, 0.2, seed=seed + 1)
        return a, b
    raise AssertionError(name)


CASES = ("random", "empty_cols", "all_dense_cols", "single_row",
         "dup_heavy", "empty", "empty_a", "rect_chain")


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("method", sorted(ALGORITHMS))
def test_differential_host(method, case):
    a, b = _adversarial(case)
    c = spgemm(a, b, method=method, cache=False)
    validate_csc(c)
    np.testing.assert_allclose(
        csc_to_dense(c), oracle_product(a, b), rtol=1e-9, atol=1e-11,
        err_msg=f"{method} diverged from the oracle on {case!r}")


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("method", sorted(PALLAS_METHODS))
def test_differential_pallas(method, case):
    a, b = _adversarial(case)
    c = spgemm(a, b, method=method, backend="pallas", cache=False)
    validate_csc(c)
    np.testing.assert_allclose(
        csc_to_dense(c), oracle_product(a, b), rtol=1e-4, atol=1e-5,
        err_msg=f"pallas {method} diverged from the oracle on {case!r}")


def test_oracle_is_external():
    """The harness must diff against scipy whenever scipy is importable."""
    if not HAVE_SCIPY:
        pytest.skip("scipy absent; oracle falls back to the dense reference")
    a = random_uniform_csc(20, 2, seed=0)
    np.testing.assert_allclose(
        oracle_product(a, a), csc_to_dense(spgemm_dense(a, a)),
        rtol=1e-12, atol=0)


if HAVE_HYPOTHESIS:

    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(8, 40),
        density=st.floats(0.0, 0.35),
        method=st.sampled_from(sorted(ALGORITHMS)),
    )
    @settings(max_examples=40, deadline=None)
    def test_property_differential_host(seed, n, density, method):
        a = random_density_csc(n, n, density, seed=seed)
        b = random_density_csc(n, n, density, seed=seed + 1)
        c = spgemm(a, b, method=method, cache=False)
        validate_csc(c)
        np.testing.assert_allclose(
            csc_to_dense(c), oracle_product(a, b), rtol=1e-9, atol=1e-11)

    @given(
        seed=st.integers(0, 10_000),
        z=st.integers(0, 5),
        method=st.sampled_from(["spa", "spars-16/64", "h-hash-32/256"]),
    )
    @settings(max_examples=8, deadline=None)
    def test_property_differential_pallas(seed, z, method):
        a = random_uniform_csc(24, z, seed=seed)
        c = spgemm(a, a, method=method, backend="pallas", cache=False)
        validate_csc(c)
        np.testing.assert_allclose(
            csc_to_dense(c), oracle_product(a, a), rtol=1e-4, atol=1e-5)

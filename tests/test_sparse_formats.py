"""Sparse container unit + property tests."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    csc_from_dense, csc_to_dense, csc_to_csr, csr_to_csc, csc_from_coo,
    csc_to_padded_columns, validate_csc, random_uniform_csc,
    random_density_csc, random_powerlaw_csc, random_banded_csc,
    column_nnz, ops_per_column, matrix_stats,
)
from repro.sparse.format import COO, transpose_csc, csc_equal


@st.composite
def dense_matrices(draw, max_dim=24):
    n_rows = draw(st.integers(1, max_dim))
    n_cols = draw(st.integers(1, max_dim))
    seed = draw(st.integers(0, 2**31 - 1))
    density = draw(st.floats(0.0, 0.6))
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(n_rows, n_cols))
    d *= rng.uniform(size=d.shape) < density
    return d


@given(dense_matrices())
@settings(max_examples=50, deadline=None)
def test_dense_roundtrip(d):
    m = csc_from_dense(d)
    validate_csc(m, sorted_rows=True)
    np.testing.assert_allclose(csc_to_dense(m), d)


@given(dense_matrices())
@settings(max_examples=50, deadline=None)
def test_csr_roundtrip(d):
    m = csc_from_dense(d)
    back = csr_to_csc(csc_to_csr(m))
    validate_csc(back)
    np.testing.assert_allclose(csc_to_dense(back), d)


@given(dense_matrices())
@settings(max_examples=30, deadline=None)
def test_transpose(d):
    m = csc_from_dense(d)
    np.testing.assert_allclose(csc_to_dense(transpose_csc(m)), d.T)


def test_coo_duplicate_accumulation():
    coo = COO(np.array([0, 0, 1], np.int32), np.array([0, 0, 1], np.int32),
              np.array([1.0, 2.0, 3.0]), (2, 2))
    m = csc_from_coo(coo)
    dense = csc_to_dense(m)
    np.testing.assert_allclose(dense, np.array([[3.0, 0.0], [0.0, 3.0]]))


def test_padded_columns():
    m = random_powerlaw_csc(40, 3.0, seed=1)
    rows, vals, nnz = csc_to_padded_columns(m)
    assert rows.shape == vals.shape and rows.shape[0] == 40
    np.testing.assert_array_equal(nnz, column_nnz(m))
    back = np.zeros(m.shape)
    for j in range(40):
        back[rows[j, : nnz[j]], j] = vals[j, : nnz[j]]
    np.testing.assert_allclose(back, csc_to_dense(m))


def test_uniform_generator_exact_degree():
    m = random_uniform_csc(64, 5, seed=3)
    validate_csc(m, sorted_rows=True)
    assert (column_nnz(m) == 5).all()


def test_ops_per_column_matches_bruteforce():
    a = random_density_csc(30, 30, 0.15, seed=0)
    b = random_density_csc(30, 30, 0.2, seed=1)
    ops = ops_per_column(a, b)
    da, db = csc_to_dense(a) != 0, csc_to_dense(b) != 0
    expect = np.array([
        sum(da[:, k].sum() for k in range(30) if db[k, j]) for j in range(30)
    ])
    np.testing.assert_array_equal(ops, expect)


def test_matrix_stats_consistency():
    m = random_banded_csc(50, 2, seed=0)
    s = matrix_stats(m)
    assert s.nnz == m.nnz
    assert s.nnz_min <= s.nnz_avg <= s.nnz_max
    assert s.mult_min <= s.mult_avg <= s.mult_max


def test_csc_equal_detects_difference():
    a = random_uniform_csc(20, 2, seed=0)
    b = random_uniform_csc(20, 2, seed=1)
    assert csc_equal(a, a)
    assert not csc_equal(a, b)

"""Self-calibrating cost-model profiles (``core.profile``, DESIGN.md §15).

Covers the machine fingerprint, JSON persistence + fingerprint-mismatch
invalidation, the lazy current-profile state, the weighted least-squares
fit, the Spearman cross-check on synthetic timings, profile-driven
``choose_method``/``should_distribute`` decisions (including the comm-x100
flip), the stale-constants warning, structural-knob tuning, and the
provenance stamped into plan params / cache keys / ``plan_cache_info``.

No microbenchmarks run here — fitting and decision logic are exercised on
synthetic rows/timings so the suite stays fast and deterministic.
"""

import dataclasses
import json
import os
import warnings

import numpy as np
import pytest

import repro.core.fast as fast
import repro.core.pallas_stream as pallas_stream
from repro.core import plan_cache_clear, plan_cache_info, profile
from repro.core.cost import (
    DEFAULT_CONSTANTS,
    CostConstants,
    choose_method,
    estimate_cost,
    should_distribute,
)
from repro.core.planner import plan_spgemm_tiled
from repro.sparse.format import csc_from_dense
from repro.sparse.partition import auto_tile_grid
from repro.sparse.stats import tile_stats


@pytest.fixture(autouse=True)
def _isolated_profile(tmp_path, monkeypatch):
    """Every test starts with no loaded profile, a private profile dir,
    and the stock structural knobs (several tests retune them)."""
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path / "profiles"))
    monkeypatch.delenv("REPRO_PROFILE_FILE", raising=False)
    monkeypatch.delenv("REPRO_AUTO_CALIBRATE", raising=False)
    guard, block = fast.STREAM_MAX_PRODUCTS, pallas_stream.FUSED_BLOCK
    profile.reset()
    yield
    profile.reset()
    fast.STREAM_MAX_PRODUCTS, pallas_stream.FUSED_BLOCK = guard, block
    plan_cache_clear()


def _measured(constants=None, tuning=None, fitted=()):
    return profile.MachineProfile(
        constants=constants or DEFAULT_CONSTANTS,
        fingerprint=profile.machine_fingerprint(),
        source="measured", created_at=1.0, fitted=tuple(fitted),
        tuning=dict(tuning or {}))


def _pair(m=24, n=16, per=2, seed=0):
    rng = np.random.default_rng(seed)
    ad = rng.uniform(0.5, 1.5, size=(m, m)) * (rng.random((m, m)) < 0.3)
    bd = np.zeros((m, n))
    for j in range(n):
        bd[rng.integers(m, size=per), j] = 1.0
    return csc_from_dense(ad), csc_from_dense(bd)


# ---------------------------------------------------------------------------
# fingerprint + persistence
# ---------------------------------------------------------------------------


def test_fingerprint_deterministic():
    fp1, fp2 = profile.machine_fingerprint(), profile.machine_fingerprint()
    assert fp1 == fp2
    assert profile.fingerprint_key(fp1) == profile.fingerprint_key(fp2)
    for field in ("cpu", "platform", "device_kind", "device_count", "jax"):
        assert field in fp1


def test_fingerprint_key_sensitive_to_fields():
    fp = profile.machine_fingerprint()
    other = dict(fp, device_count=fp["device_count"] + 7)
    assert profile.fingerprint_key(fp) != profile.fingerprint_key(other)


def test_save_load_roundtrip(tmp_path):
    c = dataclasses.replace(DEFAULT_CONSTANTS, jax_base=1.25e-4,
                            comm_byte=3.5e-9)
    prof = _measured(c, tuning={"fused_block": 64}, fitted=("jax_base",))
    path = profile.save_profile(prof, directory=str(tmp_path))
    assert os.path.exists(path)
    back = profile.load_profile(directory=str(tmp_path))
    assert back is not None
    assert back.source == "measured"
    assert back.constants.jax_base == pytest.approx(1.25e-4)
    assert back.constants.comm_byte == pytest.approx(3.5e-9)
    assert back.constants.spa_col == DEFAULT_CONSTANTS.spa_col
    assert back.fitted == ("jax_base",)
    assert back.tuning == {"fused_block": 64}
    assert back.tag == prof.tag


def test_load_missing_returns_none(tmp_path):
    assert profile.load_profile(directory=str(tmp_path / "empty")) is None


def test_fingerprint_mismatch_invalidates(tmp_path):
    """A profile measured under a different device fingerprint (e.g. a
    forced host device count) is discarded, not silently reused."""
    prof = _measured()
    doc = prof.to_json()
    doc["fingerprint"]["device_count"] += 7   # the XLA_FLAGS-forced run
    path = tmp_path / f"{prof.key}.json"
    path.write_text(json.dumps(doc))
    before = profile.profile_info()["stale_discards"]
    with pytest.warns(RuntimeWarning, match="different machine"):
        got = profile.load_profile(path=str(path))
    assert got is None
    assert profile.profile_info()["stale_discards"] == before + 1


def test_corrupt_profile_falls_back(tmp_path):
    d = tmp_path / "profiles"
    d.mkdir()
    (d / f"{profile.fingerprint_key()}.json").write_text("{not json")
    assert profile.load_profile(directory=str(d)) is None
    assert profile.profile_info()["load_errors"] >= 1


def test_current_profile_lazy_loads_from_dir(tmp_path, monkeypatch):
    d = tmp_path / "profiles"
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(d))
    profile.save_profile(
        _measured(dataclasses.replace(DEFAULT_CONSTANTS, jax_prod=9e-7)),
        directory=str(d))
    profile.reset()
    p = profile.current_profile()
    assert p.source == "measured"
    assert p.constants.jax_prod == pytest.approx(9e-7)
    # and without a persisted file the fallback is the default profile
    profile.reset()
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path / "nothing"))
    assert profile.current_profile().source == "default"
    assert profile.current_constants() is DEFAULT_CONSTANTS


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------


def test_fit_fields_recovers_exact_coefficients():
    rows = [[1.0, f] for f in (10, 100, 1000, 50_000)]
    times = [2e-5 + 3e-8 * f for _, f in rows]
    out = profile.fit_fields(("base", "slope"), rows, times)
    assert out["base"] == pytest.approx(2e-5, rel=1e-6)
    assert out["slope"] == pytest.approx(3e-8, rel=1e-6)


def test_fit_fields_clamps_negative_coefficients():
    # a decreasing "cost" drives the slope negative; physical durations
    # cannot be, so the fit clamps at the floor instead
    rows = [[1.0, f] for f in (10, 100, 1000)]
    times = [1e-3 - 9e-7 * f for _, f in rows]
    out = profile.fit_fields(("base", "slope"), rows, times)
    assert out["slope"] == pytest.approx(1e-12)


def test_fit_fields_weights_relative_error():
    # one giant config must not drown the small ones: with 1/t weighting
    # the base term of the small rows survives a 1000x larger row
    rows = [[1.0, 1.0], [1.0, 2.0], [1.0, 1e6]]
    times = [1e-4 + 1e-7 * r[1] for r in rows]
    out = profile.fit_fields(("base", "slope"), rows, times)
    assert out["base"] == pytest.approx(1e-4, rel=1e-3)


def test_fit_fields_shape_mismatch():
    with pytest.raises(ValueError, match="inconsistent"):
        profile.fit_fields(("a",), [[1.0, 2.0]], [1.0])


def test_fit_constants_merges_sections():
    c, fitted = profile.fit_constants([
        (("jax_base", "jax_prod"),
         [[1.0, f] for f in (10, 1000, 1e5)],
         [4e-5 + 5e-8 * f for f in (10, 1000, 1e5)]),
        (("comm_base",), [[1.0]], [2e-4]),
    ])
    assert fitted == ("comm_base", "jax_base", "jax_prod")
    assert c.jax_base == pytest.approx(4e-5, rel=1e-5)
    assert c.jax_prod == pytest.approx(5e-8, rel=1e-5)
    assert c.comm_base == pytest.approx(2e-4, rel=1e-6)
    # unmeasured fields ride along from the base constants
    assert c.spa_entry == DEFAULT_CONSTANTS.spa_entry


# ---------------------------------------------------------------------------
# rank correlation
# ---------------------------------------------------------------------------


def test_rank_correlation_basics():
    assert profile.rank_correlation([1, 2, 3], [10, 20, 30]) == 1.0
    assert profile.rank_correlation([1, 2, 3], [3, 2, 1]) == -1.0
    # monotone nonlinear map preserves ranks exactly
    x = np.asarray([1.0, 4.0, 2.0, 8.0, 3.0])
    assert profile.rank_correlation(x, np.exp(x)) == 1.0
    # ties get average ranks on both sides
    assert profile.rank_correlation([1, 1, 2], [5, 5, 9]) == 1.0
    assert profile.rank_correlation([1.0], [2.0]) == 1.0
    assert profile.rank_correlation([2, 2, 2], [1, 5, 9]) == 1.0


def test_rank_correlation_rejects_mismatched():
    with pytest.raises(ValueError):
        profile.rank_correlation([1, 2], [1, 2, 3])


def test_synthetic_fit_ranks_methods(subtests=None):
    """Satellite: a profile fitted from (noisy) synthetic timings must rank
    per-(tile, method) costs with Spearman >= 0.8 against those timings."""
    truth = dataclasses.replace(
        DEFAULT_CONSTANTS, spa_col=5e-6, spa_entry=9e-6, spa_flop=2e-8,
        stream_base=1.2e-5, stream_prod=8e-9, jax_base=9e-5, jax_prod=5e-8)
    rng = np.random.default_rng(7)
    stats = [tile_stats(*_pair(m, n, per, seed))
             for seed, (m, n, per) in enumerate(
                 [(16, 8, 1), (24, 16, 2), (48, 32, 3), (64, 48, 4),
                  (96, 64, 5), (128, 96, 6)])]

    def noisy(t):
        return float(t * rng.uniform(0.9, 1.1))

    sections = [
        (("spa_col", "spa_entry", "spa_flop"),
         [[s.n, s.nnz_b, s.flops] for s in stats],
         [noisy(truth.spa_col * s.n + truth.spa_entry * s.nnz_b
                + truth.spa_flop * s.flops) for s in stats]),
        (("stream_base", "stream_prod"),
         [[1.0, s.flops] for s in stats],
         [noisy(truth.stream_base + truth.stream_prod * s.flops)
          for s in stats]),
        (("jax_base", "jax_prod"),
         [[1.0, s.flops] for s in stats],
         [noisy(truth.jax_base + truth.jax_prod * s.flops)
          for s in stats]),
    ]
    fitted, names = profile.fit_constants(sections)
    assert "spa_flop" in names and "jax_prod" in names

    measured, predicted = [], []
    for (fields, _, times), method in zip(sections,
                                          ("spa", "expand", "jax")):
        for s, t in zip(stats, times):
            measured.append(t)
            predicted.append(estimate_cost(s, method, constants=fitted))
    rc = profile.rank_correlation(predicted, measured)
    assert rc >= 0.8, f"Spearman {rc:.3f} below the 0.8 gate"


# ---------------------------------------------------------------------------
# profile-driven decisions
# ---------------------------------------------------------------------------


def test_choose_method_consults_profile():
    a, b = _pair()
    st = tile_stats(a, b)
    baseline = choose_method(st, "host", constants=DEFAULT_CONSTANTS)
    assert baseline == "expand"
    # a machine where every stream engine's dispatch costs a full second
    # must re-rank the same tile to SPA — via the installed profile, with
    # no constants argument at the call site
    slow_streams = dataclasses.replace(
        DEFAULT_CONSTANTS, stream_base=1.0, expand_base=1.0, jax_base=1.0,
        fused_base=1.0)
    profile.set_profile(_measured(slow_streams))
    assert choose_method(st, "host") == "spa"
    profile.set_profile(None)


def test_should_distribute_flips_when_comm_scaled_100x():
    """Acceptance: the distribute decision must flip when the profile's
    measured comm terms are scaled x100 (same workload, same shards)."""
    ad = np.ones((64, 64))
    bd = np.ones((64, 64))
    st = tile_stats(csc_from_dense(ad), csc_from_dense(bd))
    assert st.flops == 64 ** 3

    cheap_comm = dataclasses.replace(
        DEFAULT_CONSTANTS, jax_base=1e-6, jax_prod=1e-8,
        comm_base=1e-3, comm_byte=5e-10)
    profile.set_profile(_measured(cheap_comm, fitted=("comm_base",
                                                      "comm_byte")))
    assert should_distribute(st, 4) is True

    expensive_comm = dataclasses.replace(
        cheap_comm, comm_base=cheap_comm.comm_base * 100,
        comm_byte=cheap_comm.comm_byte * 100)
    profile.set_profile(_measured(expensive_comm))
    assert should_distribute(st, 4) is False


def test_default_auto_warns_once_and_counts():
    a, b = _pair()
    st = tile_stats(a, b)
    before = plan_cache_info()["profile"]["default_auto_uses"]
    with pytest.warns(RuntimeWarning, match="uncalibrated"):
        choose_method(st, "host")
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # second consult must stay silent
        choose_method(st, "host")
    info = plan_cache_info()["profile"]
    assert info["default_auto_uses"] == before + 2
    assert info["source"] == "default"


def test_host_only_candidates_do_not_warn():
    a, b = _pair()
    st = tile_stats(a, b)
    before = plan_cache_info()["profile"]["default_auto_uses"]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        choose_method(st, "host", candidates=("spa", "expand"))
    assert plan_cache_info()["profile"]["default_auto_uses"] == before


def test_measured_profile_does_not_warn():
    a, b = _pair()
    st = tile_stats(a, b)
    profile.set_profile(_measured())
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        choose_method(st, "host")
    assert plan_cache_info()["profile"]["default_auto_uses"] == 0


# ---------------------------------------------------------------------------
# structural-knob tuning
# ---------------------------------------------------------------------------


def test_apply_tuning_sets_knobs():
    prof = _measured(tuning={"stream_max_products": 123_456,
                             "fused_block": 64})
    applied = profile.apply_tuning(prof)
    assert applied == {"stream_max_products": 123_456, "fused_block": 64}
    assert fast.STREAM_MAX_PRODUCTS == 123_456
    assert pallas_stream.FUSED_BLOCK == 64


def test_apply_tuning_untouched_without_keys():
    before = fast.STREAM_MAX_PRODUCTS
    assert profile.apply_tuning(_measured()) == {}
    assert fast.STREAM_MAX_PRODUCTS == before


def test_auto_tile_grid_consults_tuning():
    a, b = _pair(m=32, n=24, per=4)
    default_grid = auto_tile_grid(a, b)
    assert default_grid == (1, 1)   # far under the shipped targets
    profile.set_profile(_measured(tuning={"tile_n_target": 8,
                                          "tile_k_target": 16}))
    tuned_grid = auto_tile_grid(a, b)
    assert tuned_grid[1] > 1
    assert tuned_grid[0] > 1
    # explicit targets always win over the profile
    assert auto_tile_grid(a, b, n_target=10 ** 9, k_target=10 ** 9) == (1, 1)


# ---------------------------------------------------------------------------
# provenance in plans / cache keys / info
# ---------------------------------------------------------------------------


def test_tiled_plan_params_carry_profile_tag():
    a, b = _pair()
    p_default = plan_spgemm_tiled(a, b, cache=False)
    assert dict(p_default.params)["profile"] == "default"

    profile.set_profile(_measured())
    p_measured = plan_spgemm_tiled(a, b, cache=False)
    tag = dict(p_measured.params)["profile"]
    assert tag.startswith("measured:")
    assert p_measured.cache_key != p_default.cache_key

    p_explicit = plan_spgemm_tiled(a, b, cache=False,
                                   constants=DEFAULT_CONSTANTS)
    assert dict(p_explicit.params)["profile"] == "explicit"


def test_tiled_cache_keyed_by_profile():
    """The plan LRU must not serve picks ranked under one calibration to a
    consult running under another."""
    from repro.core.api import _cached_tiled_plan

    a, b = _pair()
    p1 = _cached_tiled_plan(a, b, "host", None, None)
    assert _cached_tiled_plan(a, b, "host", None, None) is p1
    profile.set_profile(_measured())
    p2 = _cached_tiled_plan(a, b, "host", None, None)
    assert p2 is not p1


def test_plan_cache_info_exposes_profile():
    info = plan_cache_info()["profile"]
    assert info["source"] == "default"
    for key in ("fingerprint_key", "fitted", "tuning",
                "default_auto_uses", "stale_discards", "load_errors"):
        assert key in info
    profile.set_profile(_measured(fitted=("jax_base",)))
    info = plan_cache_info()["profile"]
    assert info["source"] == "measured"
    assert info["fitted"] == ["jax_base"]
    assert info["age_seconds"] is not None


def test_mesh_plan_params_carry_profile_tag():
    pytest.importorskip("jax")
    from repro.distributed.spgemm_mesh import plan_spgemm_mesh

    a, b = _pair(m=16, n=8, per=2)
    plan = plan_spgemm_mesh(a, b, shards=1, cache=False)
    assert dict(plan.params)["profile"] == "default"
    profile.set_profile(_measured())
    plan2 = plan_spgemm_mesh(a, b, shards=1, cache=False)
    assert dict(plan2.params)["profile"].startswith("measured:")
    assert plan.cache_key != plan2.cache_key


def test_bench_env_header_stamps_provenance():
    import sys

    bench_dir = os.path.join(os.path.dirname(__file__), "..", "benchmarks")
    sys.path.insert(0, bench_dir)
    try:
        import _util
    finally:
        sys.path.remove(bench_dir)
    profile.set_profile(_measured(fitted=("comm_base",)))
    env = _util.env_info()
    assert env["cost_profile"]["source"] == "measured"
    assert env["cost_profile"]["fitted"] == ["comm_base"]
    assert env["cost_profile"]["fingerprint_key"] == profile.fingerprint_key()

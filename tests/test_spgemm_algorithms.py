"""The paper's algorithms vs the dense oracle + pre-processing invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ALGORITHMS, spgemm, spgemm_dense, preprocess, blocking_schedule,
    hash_table_size, hybrid_split, sort_columns, expand_products,
    spgemm_expand,
)
from repro.sparse import (
    random_uniform_csc, random_powerlaw_csc, random_density_csc,
    ops_per_column, validate_csc,
)
from repro.sparse.format import csc_equal, csc_to_dense

HOST_METHODS = [m for m in ALGORITHMS if m != "expand"]


@pytest.mark.parametrize("method", HOST_METHODS)
@pytest.mark.parametrize("gen,seed", [
    ("uniform2", 0), ("uniform6", 1), ("powerlaw", 2), ("density", 3),
])
def test_algorithms_match_oracle(method, gen, seed):
    a = {
        "uniform2": lambda: random_uniform_csc(120, 2, seed=seed),
        "uniform6": lambda: random_uniform_csc(90, 6, seed=seed),
        "powerlaw": lambda: random_powerlaw_csc(100, 4.0, seed=seed),
        "density": lambda: random_density_csc(80, 80, 0.08, seed=seed),
    }[gen]()
    ref = spgemm_dense(a, a)
    c = spgemm(a, a, method=method)
    validate_csc(c)
    assert csc_equal(c, ref, rtol=1e-9, atol=1e-11), method


def test_rectangular_spgemm():
    a = random_density_csc(40, 60, 0.1, seed=5)
    b = random_density_csc(60, 25, 0.15, seed=6)
    ref = spgemm_dense(a, b)
    for method in ("spa", "spars-40/40", "hash-256/256", "esc"):
        assert csc_equal(spgemm(a, b, method=method), ref, rtol=1e-9)


@given(st.integers(0, 10_000), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_property_spgemm_random(seed, z):
    n = 48
    a = random_uniform_csc(n, min(z, n), seed=seed)
    ref = csc_to_dense(spgemm_dense(a, a))
    for method in ("spa", "spars-16/64", "h-hash-256/256"):
        got = csc_to_dense(spgemm(a, a, method=method))
        np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-11)


def test_expand_is_exact_product_stream():
    a = random_powerlaw_csc(60, 3.0, seed=7)
    coo = expand_products(a, a)
    ops = ops_per_column(a, a)
    assert coo.nnz == ops.sum()
    assert csc_equal(spgemm_expand(a, a), spgemm_dense(a, a), rtol=1e-9)


# --- pre-processing invariants ------------------------------------------


def test_sorting_is_decreasing_permutation():
    a = random_powerlaw_csc(100, 4.0, seed=0)
    ops = ops_per_column(a, a)
    p = sort_columns(ops)
    assert sorted(p.tolist()) == list(range(100))
    assert (np.diff(ops[p]) <= 0).all()


@given(st.integers(0, 1000), st.integers(1, 64), st.integers(0, 6))
@settings(max_examples=50, deadline=None)
def test_blocking_schedule_invariants(seed, b_min, extra):
    b_max = b_min + extra
    rng = np.random.default_rng(seed)
    ops = np.sort(rng.integers(0, 50, size=200))[::-1]
    sched = blocking_schedule(ops, b_min, b_max)
    # covers [0, n) exactly, in order
    assert sched.starts[0] == 0
    ends = sched.starts + sched.sizes
    assert (sched.starts[1:] == ends[:-1]).all()
    assert ends[-1] == 200
    for s, z in sched:
        assert 1 <= z <= b_max
        blk = ops[s : s + z]
        # growth beyond b_min only while Op stays equal to the block head
        if z > b_min:
            assert (blk[b_min:] == blk[0]).all()


def test_hash_table_size_bounds():
    for op in (1, 2, 3, 4, 5, 127, 128, 129, 1000):
        h = hash_table_size(op)
        assert h & (h - 1) == 0
        assert h >= op
        if op > 1:
            assert h < 2 * op + 2


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_hybrid_split_boundary(t):
    ops = np.sort(np.random.default_rng(0).integers(0, 80, 150))[::-1]
    k = hybrid_split(ops, float(t))
    if t == 0:
        assert k == len(ops)
    else:
        assert (ops[:k] >= t).all()
        assert (ops[k:] < t).all()


def test_hybrid_limits_match_pure():
    """t=0 ≡ SPA; t=inf ≡ SPARS/HASH (Section 3.3)."""
    a = random_powerlaw_csc(60, 3.0, seed=9)
    ref = spgemm_dense(a, a)
    from repro.core.naive import hybrid_numpy

    for acc in ("spa", "hash"):
        c0 = hybrid_numpy(a, a, t=0.0, b_min=40, b_max=40, accumulator=acc)
        cinf = hybrid_numpy(a, a, t=np.inf, b_min=40, b_max=40,
                            accumulator=acc)
        assert csc_equal(c0, ref, rtol=1e-9)
        assert csc_equal(cinf, ref, rtol=1e-9)


def test_preprocess_hash_sizes_monotone():
    a = random_powerlaw_csc(120, 4.0, seed=2)
    pre = preprocess(a, a, t=np.inf, b_min=16, b_max=64)
    assert (np.diff(pre.hash_sizes) <= 0).all()
    for (s, z), h in zip(pre.blocks, pre.hash_sizes):
        assert h >= pre.ops_sorted[s] or h == pre.hash_sizes[0]


def test_empty_and_degenerate():
    # empty columns, zero matrix
    a = random_density_csc(20, 20, 0.0, seed=0)
    ref = spgemm_dense(a, a)
    for method in ("spa", "spars-40/40", "hash-256/256"):
        assert csc_equal(spgemm(a, a, method=method), ref)


def test_work_stealing_spars_matches_oracle():
    """Beyond-paper lane-refill variant is value-identical to SPARS."""
    from repro.core.naive import spars_ws_numpy

    for seed in (0, 1):
        a = random_powerlaw_csc(90, 4.0, seed=seed)
        ref = spgemm_dense(a, a)
        assert csc_equal(spars_ws_numpy(a, a), ref, rtol=1e-9)
        # small-block path exercises multiple refills per lane
        assert csc_equal(
            spars_ws_numpy(a, a, b_min=8, b_max=8), ref, rtol=1e-9)


def test_work_stealing_makespan_bound():
    """List-scheduling bound: steps <= ceil(P/L) + max_op."""
    import numpy as np
    from repro.vm.schedule import _ws_makespan

    rng = np.random.default_rng(0)
    for _ in range(20):
        ops = np.sort(rng.integers(1, 100, size=64))[::-1]
        L = 16
        steps, mean_active, refills = _ws_makespan(ops, L)
        assert steps <= -(-int(ops.sum()) // L) + int(ops.max())
        assert steps >= -(-int(ops.sum()) // L)
        assert refills == len(ops)
        assert 0 < mean_active <= L

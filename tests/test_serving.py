"""Serving engine: continuous batching, per-slot cache lengths, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_model, smoke
from repro.serving import ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = smoke(ARCHS["qwen2-0.5b"])
    params = init_model(cfg, KEY)
    return cfg, params


def test_engine_completes_requests(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    rids = [eng.submit([1, 2, 3], max_new_tokens=5) for _ in range(4)]
    done = eng.run_to_completion()
    assert set(done) == set(rids)
    for r in done.values():
        assert len(r.generated) == 5
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_engine_greedy_deterministic(small_model):
    cfg, params = small_model
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
        eng.submit([5, 6, 7, 8], max_new_tokens=6)
        done = eng.run_to_completion()
        outs.append(list(done.values())[0].generated)
    assert outs[0] == outs[1]


def test_engine_continuous_batching_matches_solo(small_model):
    """A request decoded alongside others == decoded alone (slot isolation)."""
    cfg, params = small_model
    solo = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    solo.submit([9, 10, 11], max_new_tokens=4)
    ref = list(solo.run_to_completion().values())[0].generated

    eng = ServeEngine(cfg, params, max_batch=3, cache_len=64)
    eng.submit([1, 2], max_new_tokens=8)       # staggered neighbour
    eng.step()
    eng.step()
    rid = eng.submit([9, 10, 11], max_new_tokens=4)
    done = eng.run_to_completion()
    assert done[rid].generated == ref


def test_engine_eos_stops(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    # find the greedy first token, then use it as the EOS id
    probe = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    probe.submit([3, 4], max_new_tokens=1)
    eos = list(probe.run_to_completion().values())[0].generated[0]
    eng.submit([3, 4], max_new_tokens=10, eos_id=eos)
    done = eng.run_to_completion()
    assert len(list(done.values())[0].generated) == 1


def test_engine_decode_matches_model_decode(small_model):
    """Engine pathway == raw decode_step loop (greedy, single slot)."""
    from repro.models import decode_step, init_cache

    cfg, params = small_model
    prompt = [11, 12, 13, 14]
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    eng.submit(prompt, max_new_tokens=3)
    got = list(eng.run_to_completion().values())[0].generated

    cache = init_cache(cfg, 1, 64, dtype=jnp.float32)
    toks = list(prompt)
    for t in range(len(prompt) + 2):
        logits, cache = decode_step(
            params, cfg, jnp.asarray([[toks[t]]], jnp.int32), cache,
            jnp.asarray([t], jnp.int32))
        if t >= len(prompt) - 1:
            toks.append(int(jnp.argmax(logits[0, 0, : cfg.vocab])))
    assert toks[len(prompt):] == got

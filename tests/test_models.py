"""Model substrate tests: attention/scan equivalences, decode consistency,
MoE dispatch equivalence (E10), per-arch smoke (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import (
    decode_step, init_cache, init_model, smoke, train_loss,
)
from repro.models.layers import _chunked_attn, lm_loss, lm_logits


KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _naive_attn(q, k, v, causal):
    b, sq, hkv, g, dh = q.shape
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q * dh**-0.5, k)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,skv,cq,ck", [
    (64, 64, 16, 16), (32, 32, 32, 8), (64, 128, 16, 64)])
def test_flash_attention_matches_naive(causal, sq, skv, cq, ck):
    if causal and sq != skv:
        pytest.skip("causal requires square here")
    b, hkv, g, dh = 2, 2, 3, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hkv, g, dh))
    k = jax.random.normal(ks[1], (b, skv, hkv, dh))
    v = jax.random.normal(ks[2], (b, skv, hkv, dh))
    got = _chunked_attn(q, k, v, causal=causal, q_offset=0,
                        q_chunk=cq, kv_chunk=ck)
    ref = _naive_attn(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------


def test_chunked_ssm_matches_sequential():
    from repro.models.ssm import _chunked_ssm_apply

    b, s, d, n = 2, 48, 4, 3
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (b, s, d, n), minval=0.5, maxval=0.99)
    u = jax.random.normal(ks[1], (b, s, d, n))
    h0 = jnp.zeros((b, d, n))

    def build(ch):
        a_c, u_c = ch
        return a_c, u_c, lambda h_all: h_all

    got, last = _chunked_ssm_apply(build, (a, u), h0, 16, s)
    # sequential reference
    hs = []
    h = h0
    for t in range(s):
        h = a[:, t] * h + u[:, t]
        hs.append(h)
    ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref[:, -1]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked LM loss
# ---------------------------------------------------------------------------


def test_lm_loss_matches_full_softmax():
    cfg = smoke(ARCHS["granite-20b"])
    d, v = cfg.d_model, cfg.vocab
    h = jax.random.normal(KEY, (2, 128, d))
    w = {"w": jax.random.normal(jax.random.PRNGKey(1),
                                (d, cfg.vocab_padded)) * 0.02}
    labels = jax.random.randint(KEY, (2, 128), 0, v)
    got = lm_loss(w, cfg, h, labels)
    logits = lm_logits(w, cfg, h)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = (lse - gold).mean()
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_lm_loss_ignores_masked_labels():
    cfg = smoke(ARCHS["granite-20b"])
    h = jax.random.normal(KEY, (1, 64, cfg.d_model))
    w = {"w": jax.random.normal(KEY, (cfg.d_model, cfg.vocab_padded)) * 0.02}
    labels = jax.random.randint(KEY, (1, 64), 0, cfg.vocab)
    full = lm_loss(w, cfg, h, labels)
    half = lm_loss(w, cfg, h, labels.at[:, 32:].set(-1))
    assert np.isfinite(float(half)) and abs(float(full) - float(half)) > 0


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_reference(p, cfg, x):
    """All-experts dense reference with the same top-k gating (no capacity)."""
    from repro.models.layers import dense

    b, s, d = x.shape
    xf = x.reshape(-1, d)
    probs = jax.nn.softmax(dense(p["router"], xf).astype(jnp.float32), -1)
    g, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    g = g / g.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xf, p["gate"])
    u = jnp.einsum("td,edf->tef", xf, p["up"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["down"])
    gates_dense = jnp.zeros((xf.shape[0], cfg.moe.n_experts),
                            jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], idx].add(g)
    y = jnp.einsum("ted,te->td", y_all, gates_dense.astype(x.dtype))
    if "shared" in p:
        y = y + dense(p["shared"]["down"],
                      jax.nn.silu(dense(p["shared"]["gate"], xf))
                      * dense(p["shared"]["up"], xf))
    return y.reshape(b, s, d)


def test_moe_sort_dispatch_matches_dense_reference():
    from repro.models.moe import moe_ffn, moe_table
    from repro.models.params import init_params

    cfg = smoke(ARCHS["qwen3-moe-30b-a3b"])
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = init_params(moe_table(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    got = moe_ffn(p, cfg, x)
    ref = _moe_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_dispatch_spgemm_equivalence():
    """E10: MoE dispatch expressed as SpGEMM == dense einsum dispatch."""
    from repro.models.moe import moe_dispatch_spgemm

    t, d, e, k = 32, 16, 8, 2
    x = np.random.default_rng(0).normal(size=(t, d))
    probs = np.random.default_rng(1).uniform(size=(t, e))
    idx = np.argsort(-probs, axis=1)[:, :k].astype(np.int32)
    gates = np.take_along_axis(probs, idx, axis=1)
    got = moe_dispatch_spgemm(x, idx, gates, e)
    # dense reference: per-expert weighted token sums
    r = np.zeros((t, e))
    np.put_along_axis(r, idx, gates, axis=1)
    ref = r.T @ x
    np.testing.assert_allclose(got, ref, rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# decode == teacher-forced forward (KV cache / SSM state / RoPE offsets)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "granite-20b", "falcon-mamba-7b", "zamba2-2.7b", "qwen2-0.5b"])
def test_decode_matches_forward(arch):
    cfg = smoke(ARCHS[arch])
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_model(cfg, KEY)
    b, s = 2, 16
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    from repro.models import backbone

    h, _ = backbone(params, cfg, tokens)
    full_logits = lm_logits(params["unembed"], cfg, h)  # [B,S,Vpad]

    cache = init_cache(cfg, b, 32, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c, i: decode_step(p, cfg, t, c, i))
    for t in range(s):
        logits, cache = step(params, tokens[:, t:t + 1], cache,
                             jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0, :cfg.vocab]),
            np.asarray(full_logits[:, t, :cfg.vocab]),
            rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# per-arch smoke: one train step + one decode step, finite outputs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    cfg = smoke(ARCHS[arch])
    params = init_model(cfg, KEY)
    b, s = 2, 64
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["aux"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["aux"] = jax.random.normal(
            KEY, (b, cfg.n_audio_frames, cfg.d_model))
    loss = jax.jit(lambda p, bt: train_loss(p, cfg, bt))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    cache = init_cache(cfg, b, 64)
    logits, new_cache = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.int32(0)))(
        params, tokens[:, :1], cache)
    assert logits.shape == (b, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab],
                                  np.float32)).all(), arch
    # cache structure preserved
    jax.tree_util.tree_map(lambda a, bb: None, cache, new_cache)


def test_gradients_flow():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 64), 0, cfg.vocab)
    g = jax.grad(lambda p: train_loss(p, cfg,
                                      {"tokens": tokens, "labels": tokens}))(
        params)
    norms = [float(jnp.linalg.norm(x)) for x in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(n > 0 for n in norms) > len(norms) * 0.9

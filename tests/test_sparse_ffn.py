"""SparseFFN: the paper's hybrid policy at TPU block granularity (E-extra)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_model, smoke
from repro.models.layers import ffn
from repro.models.sparse_ffn import SparseFFN, SparseMatmul

KEY = jax.random.PRNGKey(0)


def test_policy_switches_on_density():
    w = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    dense_m = SparseMatmul.from_dense(w, keep_density=0.9, t_density=0.75)
    sparse_m = SparseMatmul.from_dense(w, keep_density=0.2, t_density=0.75)
    assert dense_m.path == "dense"       # >= t stays on the SPA-analogue path
    assert sparse_m.path == "bsr"        # < t switches to the sparse kernel
    assert sparse_m.density <= 0.25


def test_sparse_matmul_exact_on_kept_blocks():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(32, 48)).astype(np.float32)
    m = SparseMatmul.from_dense(w, bm=8, bk=8, keep_density=0.5,
                                t_density=0.99)
    x = rng.normal(size=(48, 16)).astype(np.float32)
    got = np.asarray(m(jnp.asarray(x), bn=16))
    # reconstruct the pruned weight and compare
    if m.path == "bsr":
        from repro.kernels.ref import bsr_spmm_ref

        ref = np.asarray(bsr_spmm_ref(m.block_idx, m.block_nnz, m.blocks,
                                      jnp.asarray(x)))
    else:
        ref = np.asarray(m.dense_w) @ x
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_sparse_ffn_flop_savings_monotone():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"]["l0"]["ffn"])
    prev = None
    for keep in (0.8, 0.4, 0.2):
        sp = SparseFFN.from_params(p, keep_density=keep, t_density=0.9)
        f = sp.flops_per_token
        if prev is not None:
            assert f < prev
        prev = f
        x = jax.random.normal(KEY, (8, cfg.d_model))
        y = sp(x)
        assert y.shape == (8, cfg.d_model)
        assert np.isfinite(np.asarray(y)).all()


def test_sparse_matmul_batched_matches_loop():
    """One vmapped launch over [B, K, N] == the per-sample Python loop, on
    both execution paths (dense and BSR)."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(32, 48)).astype(np.float32)
    xs = jnp.asarray(rng.normal(size=(3, 48, 16)).astype(np.float32))
    for keep, path in ((0.9, "dense"), (0.2, "bsr")):
        m = SparseMatmul.from_dense(w, bm=8, bk=8, keep_density=keep,
                                    t_density=0.75)
        assert m.path == path
        got = np.asarray(m.batched(xs, bn=16))
        want = np.stack([np.asarray(m(xs[b], bn=16)) for b in range(3)])
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_sparse_ffn_batched_matches_loop():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"]["l0"]["ffn"])
    sp = SparseFFN.from_params(p, keep_density=0.3, t_density=0.75)
    xs = jax.random.normal(KEY, (2, 6, cfg.d_model))
    got = np.asarray(sp(xs))
    want = np.stack([np.asarray(sp(xs[b])) for b in range(2)])
    assert got.shape == (2, 6, cfg.d_model)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_sparse_ffn_high_density_matches_dense():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"]["l0"]["ffn"])
    sp = SparseFFN.from_params(p, keep_density=1.0, t_density=0.5)
    x = jax.random.normal(KEY, (4, cfg.d_model))
    ref = ffn(p, x[None])[0]
    np.testing.assert_allclose(np.asarray(sp(x)), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

"""SparseFFN: the paper's hybrid policy at TPU block granularity (E-extra)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_model, smoke
from repro.models.layers import ffn
from repro.models.sparse_ffn import SparseFFN, SparseMatmul

KEY = jax.random.PRNGKey(0)


def test_policy_switches_on_density():
    w = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
    dense_m = SparseMatmul.from_dense(w, keep_density=0.9, t_density=0.75)
    sparse_m = SparseMatmul.from_dense(w, keep_density=0.2, t_density=0.75)
    assert dense_m.path == "dense"       # >= t stays on the SPA-analogue path
    assert sparse_m.path == "bsr"        # < t switches to the sparse kernel
    assert sparse_m.density <= 0.25


def test_sparse_matmul_exact_on_kept_blocks():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(32, 48)).astype(np.float32)
    m = SparseMatmul.from_dense(w, bm=8, bk=8, keep_density=0.5,
                                t_density=0.99)
    x = rng.normal(size=(48, 16)).astype(np.float32)
    got = np.asarray(m(jnp.asarray(x), bn=16))
    # reconstruct the pruned weight and compare
    if m.path == "bsr":
        from repro.kernels.ref import bsr_spmm_ref

        ref = np.asarray(bsr_spmm_ref(m.block_idx, m.block_nnz, m.blocks,
                                      jnp.asarray(x)))
    else:
        ref = np.asarray(m.dense_w) @ x
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_sparse_ffn_flop_savings_monotone():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"]["l0"]["ffn"])
    prev = None
    for keep in (0.8, 0.4, 0.2):
        sp = SparseFFN.from_params(p, keep_density=keep, t_density=0.9)
        f = sp.flops_per_token
        if prev is not None:
            assert f < prev
        prev = f
        x = jax.random.normal(KEY, (8, cfg.d_model))
        y = sp(x)
        assert y.shape == (8, cfg.d_model)
        assert np.isfinite(np.asarray(y)).all()


def test_sparse_matmul_batched_matches_loop():
    """One vmapped launch over [B, K, N] == the per-sample Python loop, on
    both execution paths (dense and BSR)."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(32, 48)).astype(np.float32)
    xs = jnp.asarray(rng.normal(size=(3, 48, 16)).astype(np.float32))
    for keep, path in ((0.9, "dense"), (0.2, "bsr")):
        m = SparseMatmul.from_dense(w, bm=8, bk=8, keep_density=keep,
                                    t_density=0.75)
        assert m.path == path
        got = np.asarray(m.batched(xs, bn=16))
        want = np.stack([np.asarray(m(xs[b], bn=16)) for b in range(3)])
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_sparse_ffn_batched_matches_loop():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"]["l0"]["ffn"])
    sp = SparseFFN.from_params(p, keep_density=0.3, t_density=0.75)
    xs = jax.random.normal(KEY, (2, 6, cfg.d_model))
    got = np.asarray(sp(xs))
    want = np.stack([np.asarray(sp(xs[b])) for b in range(2)])
    assert got.shape == (2, 6, cfg.d_model)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def _tiny_ffn_params(d=24, hid=32, seed=0):
    rng = np.random.default_rng(seed)
    return {"gate": {"w": rng.normal(size=(d, hid), scale=0.3)},
            "up": {"w": rng.normal(size=(d, hid), scale=0.3)},
            "down": {"w": rng.normal(size=(hid, d), scale=0.3)}}


def _densified(m):
    """Dense pruned weight of a spgemm-path SparseMatmul (host numpy)."""
    from repro.sparse.format import csc_to_dense

    c = m.w_csc
    from repro.sparse.format import CSC

    return csc_to_dense(CSC(np.asarray(c.values), c.row_indices,
                            c.col_ptr, c.shape))


def test_spgemm_path_forward_matches_dense_reference():
    """path="spgemm" (the differentiable SpGEMM path, DESIGN.md §10)
    computes the same FFN as dense matmuls with the pruned weights."""
    sp = SparseFFN.from_params(_tiny_ffn_params(), keep_density=0.4,
                               path="spgemm")
    assert all(m.path == "spgemm" for m in (sp.gate, sp.up, sp.down))
    params = sp.trainable_params()
    x = jnp.asarray(np.random.default_rng(1).normal(size=(6, 24))
                    .astype(np.float32))
    got = np.asarray(sp.apply(params, x))
    G, U, D = (_densified(m) for m in (sp.gate, sp.up, sp.down))
    ref = (D @ (jax.nn.silu(G @ np.asarray(x).T)
                * (U @ np.asarray(x).T))).T
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-4, atol=1e-4)
    # the object-style call and batched [B, T, D] input agree with apply
    np.testing.assert_allclose(np.asarray(sp(x)), got, rtol=1e-5,
                               atol=1e-5)
    xb = jnp.stack([x, 2 * x])
    got_b = np.asarray(sp.apply(params, xb))
    np.testing.assert_allclose(
        got_b, np.stack([np.asarray(sp.apply(params, xb[i]))
                         for i in range(2)]), rtol=1e-5, atol=1e-5)


def test_spgemm_path_grads_match_dense_reference():
    sp = SparseFFN.from_params(_tiny_ffn_params(seed=2), keep_density=0.4,
                               path="spgemm")
    params = sp.trainable_params()
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))

    def loss(p):
        return jnp.mean((sp.apply(p, x) - y) ** 2)

    grads = jax.grad(loss)(params)

    # dense oracle: scatter the value vectors into dense weights and run
    # the same computation through plain matmuls
    coords = {}
    for name, m in (("gate", sp.gate), ("up", sp.up), ("down", sp.down)):
        c = m.w_csc
        rows = np.asarray(c.row_indices)[: c.nnz]
        cols = np.repeat(np.arange(c.shape[1], dtype=np.int32),
                         np.diff(np.asarray(c.col_ptr)))
        coords[name] = (rows, cols, c.shape)

    def dense_loss(p):
        def w(name):
            rows, cols, shape = coords[name]
            return jnp.zeros(shape, jnp.float32).at[rows, cols].set(
                p[name])
        h = jax.nn.silu(w("gate") @ x.T) * (w("up") @ x.T)
        pred = (w("down") @ h).T
        return jnp.mean((pred - y) ** 2)

    dense_grads = jax.grad(dense_loss)(params)
    for name in ("gate", "up", "down"):
        np.testing.assert_allclose(
            np.asarray(grads[name]), np.asarray(dense_grads[name]),
            rtol=1e-3, atol=1e-5, err_msg=name)


def test_jitted_train_step_spgemm_in_trace():
    """The acceptance gate: a jitted training step (loss + grads + AdamW)
    with SpGEMM inside the trace — loss decreases, and after the warmup
    trace every step replays one compiled call (zero per-step Python plan
    traversal)."""
    from repro.training.train_loop import build_sparse_ffn_train_step

    sp = SparseFFN.from_params(_tiny_ffn_params(seed=4), keep_density=0.5,
                               path="spgemm")
    step, state = build_sparse_ffn_train_step(sp, lr=5e-2)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(6, 24)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(6, 24)).astype(np.float32))
    losses = []
    for _ in range(6):
        state, metrics = step(state, (x, y))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.7 * losses[0], losses
    assert step._cache_size() == 1        # one trace, then pure replays
    assert np.isfinite(losses).all()


def test_spgemm_path_stream_limit_override():
    """A per-matrix stream_limit= lifts the plan-memory guard for the
    spgemm path without mutating the global knob (and a too-small guard
    raises the actionable error)."""
    w = np.random.default_rng(7).normal(size=(16, 16)).astype(np.float32)
    x = jnp.ones((16, 4))
    tight = SparseMatmul.from_dense(w, path="spgemm", stream_limit=1)
    with pytest.raises(ValueError, match="stream_limit"):
        tight.apply_values(tight.w_values, x)
    roomy = SparseMatmul.from_dense(w, path="spgemm", stream_limit=10**7)
    y = roomy.apply_values(roomy.w_values, x)
    assert y.shape == (16, 4) and np.isfinite(np.asarray(y)).all()


def test_trainable_params_requires_spgemm_path():
    sp = SparseFFN.from_params(_tiny_ffn_params(seed=6), keep_density=0.3,
                               t_density=0.75)
    with pytest.raises(ValueError, match="spgemm"):
        sp.trainable_params()
    with pytest.raises(ValueError, match="spgemm"):
        sp.gate.apply_values(jnp.zeros(3), jnp.zeros((24, 2)))
    with pytest.raises(ValueError, match="path"):
        SparseMatmul.from_dense(np.eye(16, dtype=np.float32),
                                path="bogus")


def test_sparse_ffn_high_density_matches_dense():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, KEY)
    p = jax.tree_util.tree_map(lambda l: l[0], params["blocks"]["l0"]["ffn"])
    sp = SparseFFN.from_params(p, keep_density=1.0, t_density=0.5)
    x = jax.random.normal(KEY, (4, cfg.d_model))
    ref = ffn(p, x[None])[0]
    np.testing.assert_allclose(np.asarray(sp(x)), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

"""Product-stream engine (core/fast.py, DESIGN.md §9): differential
equivalence against every naive host method on the adversarial harness, the
fp-reassociation contract (exact with exactly-representable values,
canonical structure always), batched-vs-looped bit-identity across both
batch strategies, plan-LRU sharing of stream metadata, the memory-guard
fallback path, and engine argument validation."""

import numpy as np
import pytest

from conftest import bit_identical
from test_differential import CASES, _adversarial, oracle_product

from repro.core import (
    ALGORITHMS,
    build_product_stream,
    plan_cache_clear,
    plan_cache_info,
    plan_spgemm,
    plan_spgemm_tiled,
    spgemm,
    spgemm_batched,
)
from repro.core import api as core_api
from repro.core import fast
from repro.sparse import BatchedCSC, random_density_csc, random_powerlaw_csc
from repro.sparse.format import (
    CSC, csc_to_dense, segment_reduce, validate_csc,
)

try:  # optional dev dependency (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _integerize(m: CSC, seed: int = 0) -> CSC:
    """Same pattern, small-integer values: every fp sum is exact, so
    re-associated summation must agree with the oracles with atol=0."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 4, size=m.nnz).astype(np.float64)
    return CSC(vals, m.row_indices, m.col_ptr, m.shape)


# --- differential: stream vs every naive host method -----------------------


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("method", sorted(ALGORITHMS))
def test_stream_vs_naive_differential(method, case):
    """engine="stream" computes the same C as every naive executor (and the
    external oracle) on every adversarial pattern, to summation-order
    tolerance."""
    a, b = _adversarial(case)
    plan = plan_spgemm(a, b, method)
    c_stream = plan.execute(a, b, engine="stream")
    c_naive = plan.execute(a, b, engine="naive")
    validate_csc(c_stream, sorted_rows=True)   # canonical structure
    ref = oracle_product(a, b)
    np.testing.assert_allclose(
        csc_to_dense(c_stream), ref, rtol=1e-9, atol=1e-11,
        err_msg=f"stream diverged from the oracle on {case!r}")
    np.testing.assert_allclose(
        csc_to_dense(c_stream), csc_to_dense(c_naive), rtol=1e-9, atol=1e-11,
        err_msg=f"stream diverged from naive {method} on {case!r}")


@pytest.mark.parametrize("case", CASES)
def test_stream_exact_and_structured_like_expand(case):
    """The stream engine shares ``expand``'s canonical layout and summation
    order: structure is bit-identical always, and with exactly-representable
    values (no rounding, so re-association is invisible) the values match
    the naive expand executor with atol=0."""
    a, b = _adversarial(case)
    a, b = _integerize(a, 1), _integerize(b, 2)
    plan = plan_spgemm(a, b, "expand")
    c_stream = plan.execute(a, b, engine="stream")
    c_naive = plan.execute(a, b, engine="naive")
    assert np.array_equal(np.asarray(c_stream.col_ptr),
                          np.asarray(c_naive.col_ptr))
    assert np.array_equal(np.asarray(c_stream.row_indices)[: c_stream.nnz],
                          np.asarray(c_naive.row_indices)[: c_naive.nnz])
    np.testing.assert_array_equal(
        np.asarray(c_stream.values)[: c_stream.nnz],
        np.asarray(c_naive.values)[: c_naive.nnz])


def test_stream_is_default_engine_for_expand_only():
    a = random_powerlaw_csc(40, 3.0, seed=1)
    for method, engine in (("expand", "stream"), ("spa", "naive"),
                           ("h-hash-256/256", "naive")):
        stats = {}
        plan_spgemm(a, a, method).execute(a, a, stats=stats)
        assert stats["engine"] == engine, method


# --- batched vs looped bit-identity (both batch strategies) ----------------


@pytest.mark.parametrize("n, avg", [(24, 2.0),    # short stream: 2-D passes
                                    (96, 5.0)])   # long stream: row loop
def test_stream_batched_bit_identical_to_looped(n, avg):
    a = random_powerlaw_csc(n, avg, seed=2)
    plan = plan_spgemm(a, a, "expand")
    threshold = fast.STREAM_BATCH_VECTOR_MAX
    # make sure the parametrization actually covers both strategies
    assert (plan.stream.n_products <= threshold) == (n == 24)
    rng = np.random.default_rng(3)
    vals = rng.normal(size=(5, a.nnz))
    looped = [plan.execute(vals[i], vals[i], engine="stream")
              for i in range(5)]
    stats = {}
    batched = plan.execute_batched(vals, vals, engine="stream", stats=stats)
    assert stats["path"] == ("vectorized" if n == 24 else "rowloop")
    assert stats["batch"] == 5
    for x, y in zip(batched, looped):
        assert bit_identical(x, y)


def test_spgemm_batched_default_engine_rides_stream():
    a = random_powerlaw_csc(48, 3.0, seed=4)
    rng = np.random.default_rng(5)
    ab = BatchedCSC.from_values(a, rng.normal(size=(3, a.nnz)))
    got = spgemm_batched(ab, ab, method="expand", cache=False)
    want = [spgemm(ab[i], ab[i], method="expand", cache=False)
            for i in range(3)]
    for x, y in zip(got, want):
        assert bit_identical(x, y)


# --- plan-LRU reuse of stream metadata -------------------------------------


def test_plan_cache_shares_stream_metadata():
    plan_cache_clear()
    a = random_powerlaw_csc(36, 3.0, seed=6)
    p1 = core_api._cached_plan(a, a, "expand", "host", {})
    assert p1.stream is not None
    p2 = core_api._cached_plan(a, a, "expand", "host", {})
    assert p2 is p1 and p2.stream is p1.stream   # one stream, shared
    assert plan_cache_info()["hits"] == 1
    # tiled child plans inherit the stream through the same LRU
    tiled = plan_spgemm_tiled(a, a, tile=(a.n_cols, a.n_cols),
                              candidates=("expand",))
    assert tiled.tiles[0].plan is p1
    plan_cache_clear()


def test_stream_bytes_reported_and_guard_keys_stream_carriers():
    plan_cache_clear()
    a = random_powerlaw_csc(40, 3.0, seed=30)
    spgemm(a, a, method="expand")            # default engine builds a stream
    assert plan_cache_info()["stream_bytes"] > 0
    # the guard knob keys every stream-carrying plan — since PR 6 that is
    # all three backends (pallas plans carry a stream for the fused
    # engine, DESIGN.md §11), so a knob change rebuilds pallas and host
    # plans alike
    spgemm(a, a, method="spa", backend="pallas")
    misses = plan_cache_info()["misses"]
    old = fast.STREAM_MAX_PRODUCTS
    try:
        fast.STREAM_MAX_PRODUCTS = old + 1
        spgemm(a, a, method="spa", backend="pallas")
        assert plan_cache_info()["misses"] == misses + 1  # pallas: rebuilt
        spgemm(a, a, method="expand")
        assert plan_cache_info()["misses"] == misses + 2  # host: rebuilt
    finally:
        fast.STREAM_MAX_PRODUCTS = old
    plan_cache_clear()


def test_stream_result_arrays_are_frozen():
    """Results share structure with the plan-resident stream; mutating them
    must raise rather than corrupt later same-plan executions."""
    a = random_powerlaw_csc(30, 3.0, seed=31)
    plan = plan_spgemm(a, a, "expand")
    c = plan.execute(a, a)
    with pytest.raises(ValueError):
        np.asarray(c.row_indices)[0] = 99
    with pytest.raises(ValueError):
        np.asarray(c.col_ptr)[0] = 1


def test_tiled_engine_forwarding():
    a = random_powerlaw_csc(40, 3.0, seed=7)
    a = _integerize(a, 8)
    plan = plan_spgemm_tiled(a, a, tile=(13, 9), cache=False)
    base = csc_to_dense(plan.execute(a, a))
    for engine in ("naive", "stream"):
        np.testing.assert_array_equal(
            csc_to_dense(plan.execute(a, a, engine=engine)), base)
    # batched forwarding too
    vals = np.stack([np.asarray(a.values)] * 2)
    outs = plan.execute_batched(vals, vals, engine="stream")
    np.testing.assert_array_equal(csc_to_dense(outs[0]), base)


# --- memory-guard fallback -------------------------------------------------


def test_memory_guard_fallback_bit_identical():
    a = random_powerlaw_csc(50, 4.0, seed=9)
    full = plan_spgemm(a, a, "expand")
    assert full.stream is not None
    guarded = plan_spgemm(a, a, "expand", stream_limit=1)
    assert guarded.stream is None     # guard tripped: nothing plan-resident
    stats_g, stats_f = {}, {}
    c_g = guarded.execute(a, a, engine="stream", stats=stats_g)
    c_f = full.execute(a, a, engine="stream", stats=stats_f)
    assert bit_identical(c_g, c_f)    # transient rebuild: same results
    assert stats_g["stream_cached"] is False
    assert stats_f["stream_cached"] is True
    assert stats_g["stream_products"] == stats_f["stream_products"]
    # batched rides the same fallback
    rng = np.random.default_rng(10)
    vals = rng.normal(size=(3, a.nnz))
    for x, y in zip(guarded.execute_batched(vals, vals, engine="stream"),
                    full.execute_batched(vals, vals, engine="stream")):
        assert bit_identical(x, y)


def test_build_product_stream_guard_and_counts():
    a = random_powerlaw_csc(30, 3.0, seed=11)
    s = build_product_stream(a, a)
    from repro.sparse import ops_per_column

    assert s.n_products == int(ops_per_column(a, a).sum())
    assert build_product_stream(a, a, max_products=s.n_products - 1) is None
    assert build_product_stream(
        a, a, max_products=s.n_products) is not None


# --- engine argument validation & edge cases -------------------------------


def test_engine_argument_errors():
    a = random_powerlaw_csc(20, 2.0, seed=12)
    with pytest.raises(ValueError, match="engine"):
        spgemm(a, a, method="spa", engine="bogus", cache=False)
    with pytest.raises(ValueError, match="host-backend"):
        spgemm(a, a, method="spa", backend="pallas", engine="stream",
               cache=False)
    with pytest.raises(ValueError, match="host-backend"):
        plan_spgemm_tiled(a, a, backend="pallas", cache=False).execute(
            a, a, engine="stream")
    # engine="naive" is a no-op on pallas plans (they have no host engine)
    c = spgemm(a, a, method="spa", backend="pallas", engine="naive",
               cache=False)
    validate_csc(c)


def test_stream_empty_operands():
    ea = CSC(np.zeros(0), np.zeros(0, np.int32), np.zeros(13, np.int32),
             (10, 12))
    eb = CSC(np.zeros(0), np.zeros(0, np.int32), np.zeros(8, np.int32),
             (12, 7))
    plan = plan_spgemm(ea, eb, "expand")
    c = plan.execute(ea, eb, engine="stream")
    assert c.shape == (10, 7) and c.nnz == 0
    outs = plan.execute_batched(np.zeros((2, 0)), np.zeros((2, 0)),
                                engine="stream")
    assert all(o.nnz == 0 for o in outs)


def test_segment_reduce_edges():
    assert segment_reduce(np.zeros(0), np.zeros(0, np.int64)).shape == (0,)
    assert segment_reduce(
        np.zeros((3, 0)), np.zeros(0, np.int64), axis=1).shape == (3, 0)
    out = segment_reduce(np.array([1.0, 2.0, 4.0]), np.array([0, 2]))
    np.testing.assert_array_equal(out, [3.0, 4.0])


# --- guarded hypothesis sweep ----------------------------------------------


if HAVE_HYPOTHESIS:

    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(6, 36),
        density=st.floats(0.0, 0.4),
        guard=st.booleans(),
    )
    @settings(max_examples=40, deadline=None)
    def test_property_stream_matches_oracle(seed, n, density, guard):
        a = random_density_csc(n, n, density, seed=seed)
        b = random_density_csc(n, n, density, seed=seed + 1)
        plan = plan_spgemm(a, b, "expand",
                           stream_limit=0 if guard else None)
        c = plan.execute(a, b, engine="stream")
        validate_csc(c, sorted_rows=True)
        np.testing.assert_allclose(
            csc_to_dense(c), oracle_product(a, b), rtol=1e-9, atol=1e-11)

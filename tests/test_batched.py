"""Batched same-pattern execution (DESIGN.md §7): bit-identity with the
per-call loop across all methods/backends, BatchedCSC semantics, launch-count
and tile-bound guarantees, and the spgemm_batched API."""

import numpy as np
import pytest

from repro.core import ALGORITHMS, plan_cache_clear, plan_cache_info, \
    plan_spgemm, spgemm, spgemm_batched
from repro.sparse import BatchedCSC, random_powerlaw_csc, random_uniform_csc
from repro.sparse.format import CSC, validate_csc

PALLAS_METHODS = [m for m in ALGORITHMS if m not in ("esc", "expand")]


def _reweight(m: CSC, seed: int) -> CSC:
    rng = np.random.default_rng(seed)
    return CSC(rng.normal(size=m.nnz), m.row_indices, m.col_ptr, m.shape)


def _stacked(m: CSC, batch: int, seed0: int = 100):
    mats = [_reweight(m, seed0 + b) for b in range(batch)]
    return mats, BatchedCSC.stack(mats)


def _bit_identical(x: CSC, y: CSC) -> bool:
    return (
        x.shape == y.shape
        and np.array_equal(np.asarray(x.col_ptr), np.asarray(y.col_ptr))
        and np.array_equal(np.asarray(x.row_indices)[: x.nnz],
                           np.asarray(y.row_indices)[: y.nnz])
        and np.array_equal(np.asarray(x.values)[: x.nnz],
                           np.asarray(y.values)[: y.nnz])
    )


# --- batched == looped, bit for bit, every method / both backends ---------


@pytest.mark.parametrize("method", sorted(ALGORITHMS))
def test_batched_bit_identical_host(method):
    a = random_powerlaw_csc(70, 3.0, seed=1)
    plan = plan_spgemm(a, a, method)
    mats, batched = _stacked(a, batch=3)
    got = plan.execute_batched(batched, batched)
    want = [plan.execute(m_, m_) for m_ in mats]
    assert len(got) == 3
    for g, w in zip(got, want):
        assert _bit_identical(g, w), method
        validate_csc(g)
    # raw [B, nnz] value stacks are accepted too
    vals = np.stack([np.asarray(m_.values) for m_ in mats])
    raw = plan.execute_batched(vals, vals)
    for g, w in zip(raw, want):
        assert _bit_identical(g, w), method


@pytest.mark.parametrize("method", sorted(PALLAS_METHODS))
def test_batched_bit_identical_pallas(method):
    a = random_powerlaw_csc(48, 3.0, seed=2)
    plan = plan_spgemm(a, a, method, backend="pallas", block_cols=16)
    mats, batched = _stacked(a, batch=2)
    got = plan.execute_batched(batched, batched)
    want = [plan.execute(m_, m_) for m_ in mats]
    for g, w in zip(got, want):
        assert _bit_identical(g, w), method


def test_batched_mixed_operands():
    """A and B stacks with different value streams (not A @ A)."""
    a = random_powerlaw_csc(40, 3.0, seed=3)
    plan = plan_spgemm(a, a, "spa")
    a_mats, a_b = _stacked(a, batch=3, seed0=10)
    b_mats, b_b = _stacked(a, batch=3, seed0=50)
    got = plan.execute_batched(a_b, b_b)
    for g, am, bm in zip(got, a_mats, b_mats):
        assert _bit_identical(g, plan.execute(am, bm))


# --- the launch/tile guarantees of the batched Pallas path ----------------


def test_batched_pallas_launch_count_independent_of_batch():
    a = random_powerlaw_csc(64, 3.0, seed=4)
    plan = plan_spgemm(a, a, "h-hash-256/256", backend="pallas",
                       block_cols=16)
    _, b2 = _stacked(a, batch=2)
    _, b4 = _stacked(a, batch=4)
    s2, s4 = {}, {}
    plan.execute_batched(b2, b2, stats=s2)
    plan.execute_batched(b4, b4, stats=s4)
    assert s2["n_launches"] == s4["n_launches"] == len(plan.pallas.groups)
    assert (s2["batch"], s4["batch"]) == (2, 4)


def test_batched_pallas_peak_is_one_batched_tile():
    n, block, batch = 128, 16, 3
    a = random_powerlaw_csc(n, 3.0, seed=5)
    for method in ("spa", "h-hash-256/256"):
        plan = plan_spgemm(a, a, method, backend="pallas", block_cols=block)
        _, bb = _stacked(a, batch=batch)
        stats = {}
        plan.execute_batched(bb, bb, stats=stats)
        m_dim, n_dim = stats["result_shape"]
        # peak transient = one [B, m, <=tile_cols] tile, never [B, m, n]
        assert stats["peak_tile_elems"] < batch * m_dim * n_dim, method
        for kind, shape in stats["tile_shapes"]:
            assert shape[0] == batch
            if kind == "dense":
                assert shape[1] == m_dim and shape[2] <= block
            else:
                assert shape[2] <= block


def test_batched_host_stats_report_path():
    a = random_powerlaw_csc(40, 3.0, seed=6)
    _, bb = _stacked(a, batch=2)
    for method, path in (("spa", "vectorized"), ("expand", "vectorized"),
                         ("hash-256/256", "loop")):
        stats = {}
        plan_spgemm(a, a, method).execute_batched(bb, bb, stats=stats)
        assert stats["path"] == path, method
        assert stats["batch"] == 2


# --- the spgemm_batched API ----------------------------------------------


def test_spgemm_batched_matches_per_element_and_hits_cache():
    plan_cache_clear()
    a = random_powerlaw_csc(50, 3.0, seed=7)
    mats, bb = _stacked(a, batch=3)
    got = spgemm_batched(bb, bb, method="spars-40/40")
    assert plan_cache_info()["misses"] == 1
    for g, m_ in zip(got, mats):
        assert _bit_identical(g, spgemm(m_, m_, method="spars-40/40"))
    # second batched call on the same pattern reuses the cached plan
    spgemm_batched(bb, bb, method="spars-40/40")
    assert plan_cache_info()["hits"] >= 2
    plan_cache_clear()


def test_spgemm_batched_plan_kwarg_accepts_raw_stacks():
    a = random_uniform_csc(36, 3, seed=8)
    plan = plan_spgemm(a, a, "hash-256/256")
    vals = np.random.default_rng(0).normal(size=(2, a.nnz))
    got = spgemm_batched(vals, vals, plan=plan)
    for b in range(2):
        assert _bit_identical(got[b], plan.execute(vals[b], vals[b]))


def test_spgemm_batched_rejects_non_batched_operands():
    a = random_uniform_csc(36, 3, seed=9)
    with pytest.raises(TypeError, match="BatchedCSC"):
        spgemm_batched(a, a, method="spa")
    _, bb = _stacked(a, batch=2)
    _, bb3 = _stacked(a, batch=3)
    with pytest.raises(ValueError, match="batch mismatch"):
        spgemm_batched(bb, bb3, method="spa")


def test_execute_batched_rejects_malformed_batches():
    a = random_uniform_csc(36, 3, seed=10)
    plan = plan_spgemm(a, a, "spa")
    ok = np.zeros((2, a.nnz))
    with pytest.raises(ValueError, match="batch mismatch"):
        plan.execute_batched(ok, np.zeros((3, a.nnz)))
    with pytest.raises(ValueError, match=r"\[B, nnz\]"):
        plan.execute_batched(np.zeros(a.nnz), ok)     # 1-D: use execute()
    with pytest.raises(ValueError):
        plan.execute_batched(np.zeros((2, a.nnz - 1)), ok)  # short values


# --- BatchedCSC semantics -------------------------------------------------


def test_batched_csc_stack_roundtrip():
    a = random_powerlaw_csc(30, 3.0, seed=11)
    mats, bb = _stacked(a, batch=4)
    assert bb.batch == 4 and bb.nnz == a.nnz and bb.shape == a.shape
    for b, m_ in enumerate(mats):
        assert _bit_identical(bb[b], m_)
    for u, m_ in zip(bb.unstack(), mats):
        assert _bit_identical(u, m_)
    # from_values binds a raw stack to an existing pattern
    vals = np.stack([np.asarray(m_.values) for m_ in mats])
    bb2 = BatchedCSC.from_values(a, vals)
    assert _bit_identical(bb2[1], mats[1])


def test_batched_csc_stack_rejects_mismatched_patterns():
    a = random_powerlaw_csc(30, 3.0, seed=12)
    b = random_powerlaw_csc(30, 3.0, seed=13)
    with pytest.raises(ValueError, match="patterns differ"):
        BatchedCSC.stack([a, b])
    with pytest.raises(ValueError, match="at least one"):
        BatchedCSC.stack([])
    with pytest.raises(ValueError):
        BatchedCSC.from_values(a, np.zeros(a.nnz))    # not [B, nnz]

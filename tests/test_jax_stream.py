"""Device-resident stream backend (core/jax_stream.py, DESIGN.md §10):
differential equivalence vs the host stream and the naive oracles on the
adversarial harness, gradient checks (custom vjp vs finite differences and
vs a dense ``jnp.matmul`` oracle), vmap-vs-looped bit-identity, cached-trace
steady state (zero retrace after warmup), guard fallback/capability errors,
fingerprint validation on the stream engines, the backend capability
registry, and the differentiable SparseFFN training path."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import bit_identical
from test_differential import CASES, _adversarial, oracle_product

from repro.core import (
    backend_names,
    get_backend,
    plan_cache_clear,
    plan_cache_info,
    plan_spgemm,
    plan_spgemm_tiled,
    spgemm,
    spgemm_batched,
)
from repro.core import jax_stream
from repro.core.cost import CostConstants, choose_method
from repro.sparse import BatchedCSC, random_powerlaw_csc
from repro.sparse.format import CSC, csc_from_dense, csc_to_dense

F32 = np.float32


def _integerize(m: CSC, seed: int = 0) -> CSC:
    """Same pattern, small-integer values: every f32 sum is exact, so the
    device stream must agree with the f64 naive oracles with atol=0."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 4, size=m.nnz).astype(np.float64)
    return CSC(vals, m.row_indices, m.col_ptr, m.shape)


def _stored_coords(m: CSC):
    """(rows, cols) of every stored element, in storage order."""
    cp = np.asarray(m.col_ptr)
    rows = np.asarray(m.row_indices)[: m.nnz]
    cols = np.repeat(np.arange(m.n_cols, dtype=np.int32), np.diff(cp))
    return rows, cols


# --- differential: jax stream vs host stream vs oracles ---------------------


@pytest.mark.parametrize("case", CASES)
def test_jax_vs_host_stream_and_oracle(case):
    """backend="jax" computes the same C as the host stream engine and the
    external oracle on every adversarial pattern (f32 tolerance)."""
    a, b = _adversarial(case)
    pj = plan_spgemm(a, b, "expand", backend="jax")
    ph = plan_spgemm(a, b, "expand")
    cj = pj.execute(a, b)
    ch = ph.execute(a, b, engine="stream")
    # canonical structure is shared with the host stream bit-for-bit
    assert np.array_equal(np.asarray(cj.col_ptr), np.asarray(ch.col_ptr))
    assert np.array_equal(np.asarray(cj.row_indices)[: cj.nnz],
                          np.asarray(ch.row_indices)[: ch.nnz])
    np.testing.assert_allclose(
        np.asarray(cj.values), np.asarray(ch.values)[: ch.nnz],
        rtol=1e-5, atol=1e-6,
        err_msg=f"jax stream diverged from the host stream on {case!r}")
    np.testing.assert_allclose(
        csc_to_dense(cj.to_host()), oracle_product(a, b),
        rtol=1e-4, atol=1e-5,
        err_msg=f"jax stream diverged from the oracle on {case!r}")


@pytest.mark.parametrize("case", CASES)
def test_jax_integer_exact_vs_naive_oracles(case):
    """With exactly-representable values the device stream matches the f64
    naive oracles with atol=0 (no rounding anywhere, so f32 vs f64 and any
    re-association are invisible)."""
    a, b = _adversarial(case)
    a, b = _integerize(a, 1), _integerize(b, 2)
    cj = plan_spgemm(a, b, "expand", backend="jax").execute(a, b)
    for method in ("spa", "expand", "h-hash-256/256"):
        cn = plan_spgemm(a, b, method).execute(a, b, engine="naive")
        np.testing.assert_array_equal(
            csc_to_dense(cj.to_host()), csc_to_dense(cn),
            err_msg=f"jax stream != naive {method} on integer {case!r}")


def test_api_spellings_reach_the_jax_backend():
    a = random_powerlaw_csc(24, 2.0, seed=3)
    ref = csc_to_dense(spgemm(a, a, method="expand", cache=False))
    c = spgemm(a, a, method="expand", backend="jax", cache=False)
    np.testing.assert_allclose(csc_to_dense(c.to_host()), ref,
                               rtol=1e-5, atol=1e-6)
    # engine="stream" is the jax backend's (only) engine; explicit works
    c2 = spgemm(a, a, method="expand", backend="jax", engine="stream",
                cache=False)
    np.testing.assert_allclose(csc_to_dense(c2.to_host()), ref,
                               rtol=1e-5, atol=1e-6)


# --- gradients --------------------------------------------------------------


@pytest.mark.parametrize("case", ("random", "dup_heavy", "single_row",
                                  "rect_chain"))
def test_grad_matches_finite_differences(case):
    """jax.grad of sum(C.values) w.r.t. both operands' values matches
    central finite differences on the adversarial patterns."""
    a, b = _adversarial(case)
    plan = plan_spgemm(a, b, "expand", backend="jax")
    av = np.asarray(a.values)[: a.nnz].astype(F32)
    bv = np.asarray(b.values)[: b.nnz].astype(F32)

    def loss(x, y):
        return jnp.sum(plan.stream_apply(x, y))

    ga, gb = jax.grad(loss, argnums=(0, 1))(jnp.asarray(av),
                                            jnp.asarray(bv))
    assert ga.shape == av.shape and gb.shape == bv.shape
    rng = np.random.default_rng(0)
    eps = 1e-2
    for arr, grad, which in ((av, ga, 0), (bv, gb, 1)):
        for i in rng.choice(len(arr), size=min(4, len(arr)), replace=False):
            hi, lo = arr.copy(), arr.copy()
            hi[i] += eps
            lo[i] -= eps
            args_hi = (hi, bv) if which == 0 else (av, hi)
            args_lo = (lo, bv) if which == 0 else (av, lo)
            fd = (float(loss(*map(jnp.asarray, args_hi)))
                  - float(loss(*map(jnp.asarray, args_lo)))) / (2 * eps)
            np.testing.assert_allclose(
                float(grad[i]), fd, rtol=5e-2, atol=5e-3,
                err_msg=f"fd mismatch at {which}/{i} on {case!r}")


@pytest.mark.parametrize("case", ("random", "dup_heavy", "rect_chain"))
def test_grad_matches_dense_matmul_oracle(case):
    """Every product lands in a stored C slot, so sum(C.values) equals
    sum(A_dense @ B_dense) — and the stream's vjp must equal the dense
    matmul gradient gathered at the stored positions."""
    a, b = _adversarial(case)
    plan = plan_spgemm(a, b, "expand", backend="jax")
    av = jnp.asarray(np.asarray(a.values)[: a.nnz].astype(F32))
    bv = jnp.asarray(np.asarray(b.values)[: b.nnz].astype(F32))
    ga, gb = jax.grad(lambda x, y: jnp.sum(plan.stream_apply(x, y)),
                      argnums=(0, 1))(av, bv)

    ar, ac = _stored_coords(a)
    br, bc = _stored_coords(b)

    def dense_loss(x, y):
        ad = jnp.zeros(a.shape, F32).at[ar, ac].set(x)
        bd = jnp.zeros(b.shape, F32).at[br, bc].set(y)
        return jnp.sum(ad @ bd)

    da, db = jax.grad(dense_loss, argnums=(0, 1))(av, bv)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(da),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(db),
                               rtol=1e-4, atol=1e-5)


# --- vmap batched path ------------------------------------------------------


def test_vmap_batched_bit_identical_to_looped():
    a = random_powerlaw_csc(36, 3.0, seed=4)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    rng = np.random.default_rng(5)
    vals = rng.normal(size=(5, a.nnz)).astype(F32)
    stats = {}
    batched = plan.execute_batched(vals, vals, stats=stats)
    assert stats["path"] == "vmap" and stats["batch"] == 5
    looped = [plan.execute(vals[i], vals[i]) for i in range(5)]
    for x, y in zip(batched, looped):
        assert np.array_equal(np.asarray(x.values), np.asarray(y.values))
        assert x.row_indices is y.row_indices  # shared frozen structure


def test_spgemm_batched_rides_the_jax_backend():
    a = random_powerlaw_csc(30, 2.5, seed=6)
    rng = np.random.default_rng(7)
    ab = BatchedCSC.from_values(a, rng.normal(size=(3, a.nnz)).astype(F32))
    got = spgemm_batched(ab, ab, method="expand", backend="jax",
                         engine="stream", cache=False)
    want = [spgemm(ab[i], ab[i], method="expand", cache=False)
            for i in range(3)]
    for x, y in zip(got, want):
        np.testing.assert_allclose(
            csc_to_dense(x.to_host()), csc_to_dense(y),
            rtol=1e-5, atol=1e-6)


# --- cached-trace steady state ---------------------------------------------


def test_zero_retrace_after_warmup():
    """Same-shape executions replay one compiled trace — the per-step
    Python work after warmup is one dispatch, not a plan traversal."""
    a = random_powerlaw_csc(28, 2.5, seed=8)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    fn = jax_stream.stream_fn(plan)
    assert jax_stream.stream_fn(plan) is fn          # memoized on the plan
    rng = np.random.default_rng(9)
    for _ in range(4):
        v = rng.normal(size=a.nnz).astype(F32)
        fn(v, v)
    assert fn._cache_size() == 1
    # the batched fn is its own single trace per batch shape
    bfn = jax_stream.stream_fn_batched(plan)
    for _ in range(3):
        v = rng.normal(size=(6, a.nnz)).astype(F32)
        bfn(v, v)
    assert bfn._cache_size() == 1


# --- guard fallback and capability errors -----------------------------------


def test_guarded_plan_falls_back_to_host_engine():
    a = random_powerlaw_csc(40, 3.0, seed=10)
    guarded = plan_spgemm(a, a, "expand", backend="jax", stream_limit=1)
    full_host = plan_spgemm(a, a, "expand")
    stats = {}
    c = guarded.execute(a, a, stats=stats)
    assert stats["fallback"] == "host" and stats["backend"] == "jax"
    assert bit_identical(c, full_host.execute(a, a, engine="stream"))
    # batched fallback too
    vals = np.random.default_rng(11).normal(size=(3, a.nnz))
    for x, y in zip(guarded.execute_batched(vals, vals),
                    full_host.execute_batched(vals, vals,
                                              engine="stream")):
        assert bit_identical(x, y)


def test_guarded_plan_raises_under_trace():
    a = random_powerlaw_csc(24, 2.5, seed=12)
    guarded = plan_spgemm(a, a, "expand", backend="jax", stream_limit=1)
    vals = jnp.asarray(np.asarray(a.values)[: a.nnz].astype(F32))
    with pytest.raises(ValueError, match="guard"):
        jax.jit(lambda v: guarded.stream_apply(v, v))(vals)
    with pytest.raises(ValueError, match="guard"):
        jax.grad(lambda v: jnp.sum(
            jax_stream.execute_jax(guarded, v, v).values))(vals)


# --- fingerprint validation on the stream engines (host + jax) --------------


def _colliding_pair(n=16):
    a = csc_from_dense(np.eye(n))
    b = csc_from_dense(np.roll(np.eye(n), 1, axis=0))
    assert a.shape == b.shape and a.nnz == b.nnz
    return a, b


@pytest.mark.parametrize("backend, engine", [("host", "stream"),
                                             ("jax", None)])
def test_validate_fingerprint_covers_stream_engines(backend, engine):
    a, corrupt = _colliding_pair()
    plan = plan_spgemm(a, a, "expand", backend=backend)
    plan.execute(corrupt, corrupt, engine=engine)   # O(1) hole: accepted
    with pytest.raises(ValueError, match="fingerprint"):
        plan.execute(corrupt, corrupt, engine=engine,
                     validate="fingerprint")
    ok = plan.execute(a, a, engine=engine, validate="fingerprint")
    assert ok.shape == (16, 16)
    # batched stream paths validate identically
    bad = BatchedCSC.stack([corrupt, corrupt])
    with pytest.raises(ValueError, match="fingerprint"):
        plan.execute_batched(bad, bad, engine=engine,
                             validate="fingerprint")
    good = BatchedCSC.stack([a, a])
    plan.execute_batched(good, good, engine=engine,
                         validate="fingerprint")


# --- engine plumbing and the capability registry ----------------------------


def test_engine_capability_errors():
    a = random_powerlaw_csc(20, 2.0, seed=13)
    pj = plan_spgemm(a, a, "expand", backend="jax")
    with pytest.raises(ValueError, match="unknown engine"):
        pj.execute(a, a, engine="bogus")
    # the jax backend has no naive oracles (bit_exact_oracle=False)
    with pytest.raises(ValueError, match="naive"):
        pj.execute(a, a, engine="naive")
    with pytest.raises(ValueError, match="naive"):
        pj.execute_batched(np.stack([np.asarray(a.values)] * 2),
                           np.stack([np.asarray(a.values)] * 2),
                           engine="naive")
    # uniform spelling across the api entry points
    ab = BatchedCSC.stack([a, a])
    with pytest.raises(ValueError, match="naive"):
        spgemm_batched(ab, ab, method="expand", backend="jax",
                       engine="naive", cache=False)
    with pytest.raises(ValueError, match="host-backend"):
        spgemm(a, a, method="spa", backend="pallas", engine="stream",
               cache=False)


def test_backend_registry_contracts():
    assert set(backend_names()) >= {"host", "pallas", "jax"}
    host, pallas, jx = (get_backend(n) for n in ("host", "pallas", "jax"))
    assert host.bit_exact_oracle and not host.supports_grad
    assert jx.supports_grad and jx.device_resident and jx.carries_stream
    # the fused engine rides the plan's product stream, so since PR 6 the
    # pallas contract carries one too (built lazily)
    assert pallas.carries_stream and pallas.cost_domain == "relative"
    assert "expand" in pallas.excluded_methods
    assert "fused" in pallas.engines and "fused" in jx.engines
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        spgemm(random_powerlaw_csc(8, 1.0, seed=0),
               random_powerlaw_csc(8, 1.0, seed=0), backend="cuda")


def test_jax_method_spellings_share_one_canonical_plan():
    """The jax numeric phase is method-independent, so every method
    spelling must collapse to one canonical plan (one LRU entry, one
    host+device stream) instead of per-spelling duplicates."""
    plan_cache_clear()
    a = random_powerlaw_csc(26, 2.5, seed=18)
    from repro.core.api import _cached_plan
    from repro.core.planner import resolve_params

    p1 = _cached_plan(a, a, "expand", "jax", {})
    p2 = _cached_plan(a, a, "spa", "jax", {})
    p3 = _cached_plan(a, a, "h-hash-256/256", "jax",
                      resolve_params("h-hash-256/256"))
    assert p1 is p2 is p3 and p1.method == "expand"
    assert plan_cache_info()["size"] == 1
    assert plan_spgemm(a, a, "spa", backend="jax").method == "expand"
    # the public accessor shares the same LRU entry
    from repro.core import cached_plan

    assert cached_plan(a, a, "spa", backend="jax") is p1
    # explicit oracle-tuning knobs are rejected loudly, not discarded
    for fn in (lambda: spgemm(a, a, "h-hash-256/256", backend="jax",
                              b_min=8, cache=False),
               lambda: plan_spgemm(a, a, "h-hash-256/256", backend="jax",
                                   b_min=8),
               lambda: cached_plan(a, a, "h-hash-256/256", backend="jax",
                                   b_min=8)):
        with pytest.raises(ValueError, match="do not apply"):
            fn()
    # ...but a named method whose *defaults* carry knobs still collapses
    assert spgemm(a, a, "h-hash-256/256", backend="jax",
                  cache=False).nnz == p1.execute(a, a).nnz
    plan_cache_clear()


def test_stream_apply_works_on_pallas_plans():
    """Pallas plans carry a product stream since PR 6 (the fused engine
    rides it), so ``stream_apply`` — previously a capability error there —
    now traces the same contraction as a host/jax plan of the pattern."""
    a = random_powerlaw_csc(20, 2.0, seed=19)
    pallas_plan = plan_spgemm(a, a, "spa", backend="pallas")
    host_plan = plan_spgemm(a, a, "expand", backend="host")
    vals = pallas_plan.stream_apply(np.asarray(a.values, F32),
                                    np.asarray(a.values, F32))
    ref = host_plan.execute(a, a, engine="stream")
    np.testing.assert_allclose(np.asarray(vals), ref.values, rtol=2e-6)


def test_stream_apply_checks_operand_shapes():
    """The jitted gathers promise in-bounds indices, so short operands
    must be rejected before tracing, tracer-safely."""
    a = random_powerlaw_csc(22, 2.0, seed=20)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    with pytest.raises(ValueError, match="values"):
        plan.stream_apply(np.zeros(2, F32), np.zeros(a.nnz, F32))
    with pytest.raises(ValueError, match="1-D"):
        plan.stream_apply(np.zeros((2, a.nnz), F32), np.zeros(a.nnz, F32))


def test_device_stream_bytes_reported_separately():
    plan_cache_clear()
    a = random_powerlaw_csc(32, 3.0, seed=14)
    spgemm(a, a, method="expand", cache=True)              # host stream
    info = plan_cache_info()
    assert info["stream_bytes"] > 0 and info["device_stream_bytes"] == 0
    spgemm(a, a, method="expand", backend="jax", cache=True)
    info = plan_cache_info()
    assert info["device_stream_bytes"] > 0
    # the jax plan keeps the host stream it was lifted from (both halves)
    assert info["stream_bytes"] > 0
    plan_cache_clear()


# --- the "jax" auto candidate (mixed tile grids) ----------------------------


def test_tiled_jax_candidate_executes_and_matches():
    a = _integerize(random_powerlaw_csc(40, 3.0, seed=15), 16)
    ref = csc_to_dense(plan_spgemm(a, a, "spa").execute(a, a))
    plan = plan_spgemm_tiled(a, a, tile=(20, 20), candidates=("jax",),
                             cache=False)
    stats = {}
    c = plan.execute(a, a, stats=stats)
    assert stats["methods"] == ["jax"]
    np.testing.assert_array_equal(csc_to_dense(c), ref)
    # an explicit engine must hold on every tile: "stream" does (host and
    # jax tiles both implement it), "naive" does not (device tiles cannot
    # keep its bit-exact f64 promise) and is loudly rejected
    mixed = plan_spgemm_tiled(a, a, tile=(20, 20),
                              candidates=("spa", "jax"), cache=False)
    for engine in (None, "stream"):
        np.testing.assert_array_equal(
            csc_to_dense(mixed.execute(a, a, engine=engine)), ref)
    with pytest.raises(ValueError, match="every tile"):
        mixed.execute(a, a, engine="naive")
    with pytest.raises(ValueError, match="every tile"):
        mixed.execute_batched(np.stack([np.asarray(a.values)] * 2),
                              np.stack([np.asarray(a.values)] * 2),
                              engine="naive")
    outs = mixed.execute_batched(
        np.stack([np.asarray(a.values)] * 2),
        np.stack([np.asarray(a.values)] * 2), engine="stream")
    np.testing.assert_array_equal(csc_to_dense(outs[0]), ref)


def test_cost_model_can_pick_the_jax_candidate():
    """With device-favourable calibrated constants the auto chooser picks
    the jax stream for in-guard tiles (deterministic via constants=)."""
    from repro.sparse.stats import tile_stats

    a = random_powerlaw_csc(48, 4.0, seed=17)
    st = tile_stats(a, a)
    fast_dev = CostConstants(jax_base=1e-7, jax_prod=1e-10)
    assert choose_method(st, "host", candidates=("spa", "expand", "jax"),
                         constants=fast_dev) == "jax"
    slow_dev = CostConstants(jax_base=10.0, jax_prod=1.0)
    assert choose_method(st, "host", candidates=("spa", "expand", "jax"),
                         constants=slow_dev) != "jax"

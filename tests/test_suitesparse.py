"""SuiteSparse stand-in generator: published-statistics fidelity (E4 input)."""

import numpy as np
import pytest

from repro.sparse import (
    SUITESPARSE_TABLE1, matrix_stats, synthesize_suitesparse, validate_csc,
)
from repro.sparse.suitesparse import by_name

FAST = ("poli", "olm1000", "oscil_dcop_30", "str_200", "iprob")


@pytest.mark.parametrize("name", FAST)
def test_generated_stats_match_published(name):
    spec = by_name(name)
    m, st = synthesize_suitesparse(spec, seed=0)
    validate_csc(m)
    assert st.nnz == spec.nnz
    assert st.n_rows == spec.n
    assert st.nnz_min == spec.nnz_min
    assert st.nnz_max == spec.nnz_max
    assert abs(st.nnz_var - spec.nnz_var) <= max(0.15 * spec.nnz_var, 0.3)
    assert abs(st.mult_avg - spec.mult_avg) <= max(0.15 * spec.mult_avg, 1.0)


def test_arrow_structure_forced():
    """iprob: every column must reference the 3000-nnz mega column."""
    m, st = synthesize_suitesparse("iprob", seed=0)
    assert st.mult_min >= 2900  # published minimum is 3002


def test_table_is_consistent():
    assert len(SUITESPARSE_TABLE1) == 40
    for s in SUITESPARSE_TABLE1:
        assert len(s.paper_speedups) == 9
        assert s.nnz_min <= s.nnz_avg <= s.nnz_max
        assert s.spa_seconds > 0


def test_caching_roundtrip(tmp_path):
    from repro.sparse.suitesparse import load_or_synthesize

    m1, _ = load_or_synthesize("olm1000", seed=0, cache_dir=str(tmp_path))
    m2, _ = load_or_synthesize("olm1000", seed=0, cache_dir=str(tmp_path))
    np.testing.assert_array_equal(np.asarray(m1.row_indices),
                                  np.asarray(m2.row_indices))

"""Distribution layer: sharding rules, divisibility fallbacks, compression,
pipeline math (degenerate 1-stage), cache specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import (
    batch_spec, cache_specs, dequantize_tree, ef_compress, quantize_tree,
    sharding_rules,
)
from repro.models import abstract_model, model_specs
from repro.models.params import Leaf, _spec_for


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Axis-size metadata stand-in (no devices needed for spec math)."""

    class M:
        axis_names = axes
        devices = np.empty(shape, object)

    return M()


RULES = {
    "__sizes__": {"data": 16, "model": 16, "pod": 2},
    "embed": ("data",), "vocab": "model", "mlp": "model", "heads": "model",
    "experts": "model", "ssm_inner": "model", "layers": None, None: None,
}


def test_spec_basic_tp_fsdp():
    leaf = Leaf((4096, 16384), ("embed", "mlp"))
    assert _spec_for(leaf, RULES) == P("data", "model")


def test_spec_divisibility_fallback():
    # 56-head fused dim 7168 divides; but a 14-dim head axis does not
    leaf = Leaf((14, 64), ("heads", None))
    assert _spec_for(leaf, RULES) == P(None, None)
    leaf2 = Leaf((896, 7168), ("embed", "heads"))
    assert _spec_for(leaf2, RULES) == P("data", "model")


def test_spec_no_duplicate_mesh_axes():
    # expert tensors: experts and mlp both want 'model' -> mlp falls back
    leaf = Leaf((128, 768, 2048), ("experts", "mlp", "embed"))
    spec = _spec_for(leaf, RULES)
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))
    assert spec[0] == "model"


def test_model_specs_cover_every_leaf():
    for arch in ("yi-34b", "qwen3-moe-30b-a3b", "falcon-mamba-7b",
                 "zamba2-2.7b"):
        cfg = ARCHS[arch]
        specs = model_specs(cfg, RULES)
        abst = abstract_model(cfg)
        jax.tree_util.tree_map(
            lambda s, a: None, specs, abst)  # same structure
        for spec, leaf in zip(jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)),
                jax.tree_util.tree_leaves(abst)):
            assert len(spec) <= len(leaf.shape)
            for part, dim in zip(spec, leaf.shape):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                prod = int(np.prod([RULES["__sizes__"][a] for a in axes]))
                assert dim % prod == 0, (arch, leaf.shape, spec)


def test_batch_spec_fallback():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_spec(mesh, 256, 1) == P(("pod", "data"), None)
    # batch=1 (long_500k): nothing divides -> replicated
    assert batch_spec(mesh, 1, 1) == P(None, None)
    # batch=2: only pod divides
    assert batch_spec(mesh, 2, 1) == P("pod", None)


def test_cache_specs_kv_and_seq_fallback():
    mesh = fake_mesh()
    cfg = ARCHS["zamba2-2.7b"]          # kv=32 divisible -> heads sharded
    from repro.models import init_cache

    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
    specs = cache_specs(cfg, cache, mesh)
    kv_spec = specs["l6"]["k"] if "l6" in specs else None
    found_head_shard = False
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]:
        name = path[-1].key
        if name == "k":
            assert spec[3] == "model"   # heads sharded
            found_head_shard = True
    assert found_head_shard

    cfg2 = ARCHS["yi-34b"]              # kv=8 not divisible -> seq sharded
    cache2 = jax.eval_shape(lambda: init_cache(cfg2, 128, 1024))
    specs2 = cache_specs(cfg2, cache2, mesh)
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs2, is_leaf=lambda x: isinstance(x, P))[0]:
        if path[-1].key == "k":
            assert spec[2] == "model" and spec[3] is None


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(64, 128)) * 3),
            "b": jnp.asarray(rng.normal(size=(7,)))}
    deq = dequantize_tree(quantize_tree(tree))
    err = jnp.abs(deq["w"] - tree["w"]).max()
    scale = jnp.abs(tree["w"]).max(axis=-1).max() / 127
    assert float(err) <= float(scale) + 1e-6
    np.testing.assert_array_equal(np.asarray(deq["b"]),
                                  np.asarray(tree["b"]))  # 1-D passthrough


def test_error_feedback_accumulates_to_truth():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((16, 32))
    comp_sum = np.zeros((16, 32))
    residual = None
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(16, 32)) * 0.1)}
        true_sum += np.asarray(g["w"])
        comp, residual = ef_compress(g, residual)
        comp_sum += np.asarray(dequantize_tree(comp)["w"])
    # residual bounds the cumulative error
    gap = np.abs(true_sum - comp_sum).max()
    res = np.abs(np.asarray(residual["w"])).max()
    assert gap <= res + 1e-5
    assert gap < 0.05 * np.abs(true_sum).max() + 0.1


# ---------------------------------------------------------------------------
# pipeline (degenerate single-stage correctness; PP2 compile in dry-run)
# ---------------------------------------------------------------------------


def test_pipeline_single_stage_identity():
    from repro.distributed.pipeline import pipelined_apply

    devs = np.asarray(jax.devices()[:1]).reshape(1,)
    mesh = Mesh(devs, ("pod",))
    w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                    jnp.float32)
    stage_params = {"w": w[None]}  # [n_stages=1, 8, 8]

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    x_micro = jnp.asarray(
        np.random.default_rng(1).normal(size=(3, 4, 8)), jnp.float32)
    got = pipelined_apply(mesh, stage_fn, stage_params, x_micro, axis="pod")
    ref = jnp.stack([stage_fn({"w": w}, x_micro[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

"""Chaos suite: the resilience layer under real injected faults.

DESIGN.md §14 contracts, exercised with seeded deterministic faults from
``core.faults`` rather than mocks: retry/backoff recovers transient build
failures, the watchdog fails hung builds and recycles the worker (no slot
is permanently lost), a synchronous single-flight waiter never blocks
past its deadline, backpressure policies shed deliberately, and the
serving circuit breaker degrades → pins → recovers via half-open probe
with greedy decode output bit-identical to a fault-free run throughout.
"""

import threading
import time

import jax
import pytest

from repro.configs import ARCHS
from repro.core import (
    BuildShed, BuildTimeoutError, InjectedFault, PlanBuildTimeout,
    PlanBuilder, RetryPolicy, api, cached_plan, faults, plan_cache_clear,
    plan_cache_info,
)
from repro.models import init_model, smoke
from repro.models.sparse_ffn import sparsify_ffn_params
from repro.serving import CircuitBreaker, Health, ServeEngine
from repro.sparse import random_density_csc


@pytest.fixture(autouse=True)
def fresh_cache():
    plan_cache_clear()
    yield
    faults.uninstall()      # never leak a fault plan into the next test
    plan_cache_clear()


def _pair(seed=0, n=24, density=0.2):
    return (random_density_csc(n, n, density, seed=2 * seed),
            random_density_csc(n, n, density, seed=2 * seed + 1))


# ---------------------------------------------------------------------------
# fault-injection machinery
# ---------------------------------------------------------------------------


def _fire_pattern(seed):
    plan = faults.FaultPlan(
        [faults.FaultRule("plan_spgemm", "fail", rate=0.5)], seed=seed)
    pattern = []
    for _ in range(32):
        try:
            plan.check("plan_spgemm", key=("jax", "expand"))
            pattern.append(0)
        except InjectedFault:
            pattern.append(1)
    return pattern


def test_rate_faults_replay_deterministically():
    p = _fire_pattern(seed=7)
    assert p == _fire_pattern(seed=7)
    assert 0 < sum(p) < len(p)          # actually probabilistic
    assert p != _fire_pattern(seed=8)   # and seed-sensitive


def test_every_fires_on_exact_calls():
    with faults.inject(faults.FaultRule("plan_spgemm", "fail", every=3,
                                        max_fires=2)) as fp:
        hits = []
        for i in range(1, 10):
            try:
                faults.check("plan_spgemm", key="k")
            except InjectedFault:
                hits.append(i)
        assert hits == [3, 6]           # every 3rd call, capped at 2 fires
        assert fp.fired("plan_spgemm") == 2


def test_match_scopes_by_key():
    """A ``match="jax"`` rule must never touch host-backend calls — the
    guarantee that lets faults target background builds while the
    foreground fallback stays clean."""
    with faults.inject(faults.FaultRule("plan_spgemm", "fail", every=1,
                                        match="jax")):
        faults.check("plan_spgemm", key=("host", "expand"))  # untouched
        with pytest.raises(InjectedFault):
            faults.check("plan_spgemm", key=("jax", "expand"))


def test_uninstall_releases_hangs():
    with faults.inject(faults.FaultRule("builder_worker", "hang",
                                        every=1, seconds=60)):
        t0 = time.monotonic()
        done = threading.Event()

        def hang_then_done():
            faults.check("builder_worker", key="x")
            done.set()

        threading.Thread(target=hang_then_done, daemon=True).start()
        time.sleep(0.05)
        assert not done.is_set()        # genuinely hung
    assert done.wait(5)                 # context exit released it
    assert time.monotonic() - t0 < 10   # not the 60s hang budget


def test_one_fault_plan_at_a_time():
    with faults.inject(faults.FaultRule("plan_spgemm", "fail")):
        with pytest.raises(RuntimeError, match="already installed"):
            faults.install(faults.FaultPlan([]))


def test_checks_are_noops_without_a_plan():
    faults.check("plan_spgemm", key="anything")     # must not raise


# ---------------------------------------------------------------------------
# retry / backoff / watchdog (tentpole part 2)
# ---------------------------------------------------------------------------


def test_retry_recovers_transient_build_failures():
    a, b = _pair(0)
    with faults.inject(faults.FaultRule("builder_worker", "fail",
                                        every=1, max_fires=2)):
        with PlanBuilder(retry=RetryPolicy(base_delay=0.01)) as builder:
            assert builder.submit(a, b, "expand", backend="jax") \
                == "submitted"
            assert builder.wait_idle(30)
            (res,) = builder.poll()
    assert res.ok and res.attempts == 3     # 2 injected failures + success
    assert builder.stats["retries"] == 2
    assert builder.stats["completed"] == 1
    assert builder.stats["failed"] == 0
    assert api.plan_cache_peek(res.key) is not None     # plan landed


def test_retries_exhausted_reports_failure():
    a, b = _pair(1)
    with faults.inject(faults.FaultRule("builder_worker", "fail", every=1)):
        with PlanBuilder(retry=RetryPolicy(max_attempts=2,
                                           base_delay=0.01)) as builder:
            builder.submit(a, b, "expand", backend="jax")
            assert builder.wait_idle(30)
            (res,) = builder.poll()
    assert not res.ok and isinstance(res.error, InjectedFault)
    assert res.attempts == 2
    assert builder.stats["failed"] == 1


def test_watchdog_recycles_hung_worker():
    """A hung build is failed at its deadline and its worker replaced —
    the builder keeps serving new work with full capacity (acceptance:
    no builder worker is permanently lost)."""
    with faults.inject(faults.FaultRule("builder_worker", "hang",
                                        every=1, max_fires=1, seconds=60)):
        with PlanBuilder(build_deadline=0.2) as builder:
            builder.submit_task(lambda: "wedged", tag="hung")
            assert builder.wait_idle(30)
            (res,) = builder.poll()
            assert isinstance(res.error, BuildTimeoutError)
            assert builder.stats["timed_out"] == 1
            assert builder.stats["workers_recycled"] == 1
            assert builder.info()["workers"] == 1   # capacity restored

            # the recycled worker serves the next task normally
            builder.submit_task(lambda: "fresh", tag="after")
            assert builder.wait_idle(30)
            (res2,) = builder.poll()
            assert res2.ok and res2.plan == "fresh"


def test_waiter_deadline_on_single_flight_build(monkeypatch):
    """A sync caller joining another thread's in-flight build times out at
    its own deadline instead of blocking for the build's full duration."""
    a, b = _pair(2)
    gate = threading.Event()
    started = threading.Event()
    real = api.plan_spgemm

    def slow_plan(*args, **kw):
        started.set()
        gate.wait(30)
        return real(*args, **kw)

    monkeypatch.setattr(api, "plan_spgemm", slow_plan)
    owner = threading.Thread(target=lambda: cached_plan(a, b, "expand"),
                             daemon=True)
    owner.start()
    assert started.wait(10)
    with pytest.raises(PlanBuildTimeout):
        cached_plan(a, b, "expand", build_timeout=0.05)
    assert plan_cache_info()["wait_timeouts"] == 1
    gate.set()
    owner.join(30)
    # the owner's build still landed; a fresh call hits the cache
    assert cached_plan(a, b, "expand") is not None
    assert plan_cache_info()["wait_timeouts"] == 1      # no new timeout


# ---------------------------------------------------------------------------
# backpressure policies (tentpole part 4)
# ---------------------------------------------------------------------------


def _pin_worker(builder):
    """Occupy the single worker behind a gate; returns the gate."""
    gate = threading.Event()
    running = threading.Event()

    def task():
        running.set()
        gate.wait(30)

    builder.submit_task(task, tag="pin")
    assert running.wait(10)
    return gate


def test_shed_by_key_age_evicts_oldest_queued():
    with PlanBuilder(max_pending=2,
                     backpressure="shed-by-key-age") as builder:
        gate = _pin_worker(builder)
        assert builder.submit_task(lambda: "old", tag="old") == "submitted"
        # queue full: admitting "new" evicts "old", not the new arrival
        assert builder.submit_task(lambda: "new", tag="new") == "submitted"
        shed = [r for r in builder.poll()
                if isinstance(r.error, BuildShed)]
        assert [r.tag for r in shed] == ["old"]
        assert builder.stats["shed"] == 1
        gate.set()
        assert builder.wait_idle(30)
        done = {r.tag: r for r in builder.poll()}
    assert done["new"].ok and done["new"].plan == "new"
    assert done["pin"].ok


def test_block_with_deadline_blocks_then_sheds():
    with PlanBuilder(max_pending=1, backpressure="block-with-deadline",
                     block_timeout=0.15) as builder:
        gate = _pin_worker(builder)
        t0 = time.monotonic()
        assert builder.submit_task(lambda: "late", tag="late") == "shed"
        waited = time.monotonic() - t0
        assert waited >= 0.1            # actually blocked for the window
        # once capacity frees mid-wait, the submit goes through instead
        threading.Timer(0.03, gate.set).start()
        assert builder.submit_task(lambda: "ok", tag="ok") == "submitted"
        assert builder.wait_idle(30)


def test_unknown_backpressure_policy_rejected():
    with pytest.raises(ValueError, match="backpressure"):
        PlanBuilder(backpressure="drop-everything")


# ---------------------------------------------------------------------------
# satellites: listener errors, idempotent/drain shutdown
# ---------------------------------------------------------------------------


def test_listener_error_counted_not_propagated():
    """One raising eviction listener must not starve the others or leak
    into the resizing caller."""
    for i in range(4):
        cached_plan(*_pair(10 + i), "expand")   # host plans to evict
    seen = []

    def bad(keys, reason):
        raise RuntimeError("boom")

    def good(keys, reason):
        seen.append((tuple(keys), reason))

    api.register_eviction_listener(bad)
    api.register_eviction_listener(good)
    try:
        api.plan_cache_resize(2)        # shrink: evicts, notifies
    finally:
        api.unregister_eviction_listener(bad)
        api.unregister_eviction_listener(good)
        api.plan_cache_resize(64)
    assert seen and seen[0][1] == "resize"      # good listener still fired
    assert plan_cache_info()["listener_errors"] == 1


def test_shutdown_is_idempotent():
    builder = PlanBuilder()
    builder.submit_task(lambda: "x")
    builder.shutdown()
    builder.shutdown()                  # second call: no-op, no error
    builder.shutdown(drain=True)        # and in either flavor
    assert builder.pending() == 0


def test_shutdown_drain_finishes_queued_work():
    done = []
    builder = PlanBuilder()
    gate = _pin_worker(builder)
    builder.submit_task(lambda: done.append("a"), tag="a")
    builder.submit_task(lambda: done.append("b"), tag="b")
    threading.Timer(0.05, gate.set).start()
    builder.shutdown(drain=True)
    assert done == ["a", "b"]
    assert builder.stats["cancelled"] == 0
    with pytest.raises(RuntimeError, match="shut down"):
        builder.submit_task(lambda: None)


def test_default_shutdown_cancels_queued_work():
    builder = PlanBuilder()
    gate = _pin_worker(builder)
    builder.submit_task(lambda: "queued", tag="queued")
    builder.shutdown(wait=False)        # non-drain: queued task cancelled
    gate.set()
    for _ in range(100):
        if builder.stats["cancelled"]:
            break
        time.sleep(0.01)
    assert builder.stats["cancelled"] == 1
    cancelled = [r for r in builder.poll()
                 if r.error is not None and r.tag == "queued"]
    assert len(cancelled) == 1


# ---------------------------------------------------------------------------
# circuit breaker (tentpole part 3)
# ---------------------------------------------------------------------------


def test_breaker_degrade_pin_recover_cycle():
    t = [0.0]
    br = CircuitBreaker(degrade_after=1, pin_after=3, cooldown=5.0,
                        cooldown_factor=2.0, clock=lambda: t[0])
    assert br.health is Health.HEALTHY
    assert br.allow_attempt()
    br.record_failure()
    assert br.health is Health.DEGRADED     # degraded, still attempting
    assert br.allow_attempt()
    br.record_failure()
    br.record_failure()
    assert br.health is Health.FALLBACK_PINNED
    assert not br.allow_attempt()           # cooldown running
    t[0] = 5.1
    assert br.allow_attempt()               # the half-open probe
    assert not br.allow_attempt()           # only one probe at a time
    br.record_failure()                     # probe failed: re-pin, back off
    assert br.health is Health.FALLBACK_PINNED
    t[0] = 10.3                             # one base cooldown later: still
    assert not br.allow_attempt()           # pinned (cooldown doubled)
    t[0] = 15.3
    assert br.allow_attempt()
    br.record_success()                     # clean probe: full reset
    assert br.health is Health.HEALTHY
    assert br.info()["cooldown"] == 5.0     # back to base
    assert br.info()["trips"] == 2


def test_breaker_probe_cancelled_rearms():
    t = [0.0]
    br = CircuitBreaker(pin_after=1, cooldown=1.0, clock=lambda: t[0])
    br.record_failure()
    assert br.health is Health.FALLBACK_PINNED
    t[0] = 1.5
    assert br.allow_attempt()
    br.probe_cancelled()                    # probe shed before running
    assert br.allow_attempt()               # immediately re-armed


# ---------------------------------------------------------------------------
# end-to-end: serving under injected warm failures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sparse_model():
    cfg = smoke(ARCHS["qwen2-0.5b"])
    params = init_model(cfg, jax.random.PRNGKey(0))
    sparse_params, overlay = sparsify_ffn_params(cfg, params,
                                                 keep_density=0.5)
    return cfg, sparse_params, overlay


def test_engine_degrades_pins_and_recovers(sparse_model):
    """Acceptance: under injected warm-compile failures every tick
    completes, the breaker walks HEALTHY -> DEGRADED -> FALLBACK_PINNED,
    a half-open probe recovers to jit ticks, greedy output is identical
    to a fault-free run, and no builder worker is lost."""
    cfg, sparse_params, overlay = sparse_model
    t = [0.0]
    br = CircuitBreaker(degrade_after=1, pin_after=2, cooldown=5.0,
                        clock=lambda: t[0])
    prompt, new = [1, 2, 3], 8
    with faults.inject(faults.FaultRule("warm_compile", "fail", every=1,
                                        max_fires=2, match="serve-warm")):
        with PlanBuilder() as builder:
            eng = ServeEngine(cfg, sparse_params, max_batch=2,
                              cache_len=32, sparse_ffn=overlay,
                              plan_builder=builder, breaker=br)
            assert builder.wait_idle(60)    # init warm: injected failure 1
            assert br.health is Health.DEGRADED
            rid = eng.submit(prompt, max_new_tokens=new)

            assert eng.step()               # resubmits: injected failure 2
            assert builder.wait_idle(60)
            assert br.health is Health.FALLBACK_PINNED
            assert eng.tick_stats["warm_failures"] == 2

            pinned_ticks = 0
            while not eng.sparse_ready() and (eng.queue or any(eng.slots)):
                assert eng.step()           # every tick completes, pinned
                pinned_ticks += 1
                assert builder.wait_idle(60)
                if pinned_ticks == 3:
                    t[0] = 5.1              # cooldown elapses mid-request:
                    # next tick launches the half-open probe (fault budget
                    # exhausted, so it compiles cleanly and promotes)
            assert eng.wait_sparse(120)
            assert br.health is Health.HEALTHY
            done = eng.run_to_completion()
            assert eng.tick_stats["jit_ticks"] > 0
            assert eng.tick_stats["fallback_ticks"] >= 3
            assert eng.tick_stats["health"] == "healthy"
            assert builder.info()["workers"] == 1   # no worker lost
    chaos_gen = done[rid].generated
    assert len(chaos_gen) == new

    # fault-free reference: same request, jit from tick 0 — greedy decode
    # must be bit-identical across every health transition
    ref = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=32,
                      sparse_ffn=overlay)
    rid2 = ref.submit(prompt, max_new_tokens=new)
    assert ref.run_to_completion()[rid2].generated == chaos_gen


def test_engine_close_detaches_from_shared_builder(sparse_model):
    """close() stops an engine's warms without touching the shared
    builder: a late warm completion for a closed engine is discarded."""
    cfg, sparse_params, overlay = sparse_model
    gate = threading.Event()
    with PlanBuilder() as builder:
        builder.submit_task(gate.wait, tag="gate")
        eng = ServeEngine(cfg, sparse_params, max_batch=1, cache_len=32,
                          sparse_ffn=overlay, plan_builder=builder)
        eng.close()
        eng.close()                     # idempotent
        gate.set()
        assert builder.wait_idle(120)
        assert not eng.sparse_ready()   # late warm was discarded
        # the builder itself is alive and serving others
        builder.submit_task(lambda: "alive", tag="alive")
        assert builder.wait_idle(30)
        assert any(r.tag == "alive" and r.ok for r in builder.poll())


def test_bench_env_header_records_fault_plan():
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    try:
        import _util
    finally:
        sys.path.pop(0)
    assert "fault_plan" not in _util.env_info()
    with faults.inject(faults.FaultRule("plan_spgemm", "fail",
                                        rate=0.25), seed=11):
        hdr = _util.env_info()
    assert hdr["fault_plan"]["seed"] == 11
    assert hdr["fault_plan"]["rules"][0]["site"] == "plan_spgemm"
    assert "fault_plan" not in _util.env_info()     # clean again after

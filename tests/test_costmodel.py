"""Vector-machine cost model: invariants + the paper's qualitative claims."""

import numpy as np
import pytest

from repro.core import preprocess
from repro.sparse import random_uniform_csc, ops_per_column
from repro.vm import (
    DEFAULT_MACHINE, Trace, c_column_nnz, trace_esc, trace_hash, trace_hybrid,
    trace_spa, trace_spars,
)
from repro.vm.machine import Machine


@pytest.fixture(scope="module")
def mats():
    return {z: random_uniform_csc(640, z, seed=z) for z in (2, 6, 10)}


def test_trace_utilization_bounds(mats):
    a = mats[2]
    pre = preprocess(a, a, t=np.inf, b_min=40, b_max=40)
    for tr in (trace_spa(a, a), trace_spars(a, a, pre),
               trace_hash(a, a, pre), trace_esc(a, a)):
        assert 0.0 < tr.utilization <= 1.0


def test_spa_active_elements_cover_products(mats):
    """SPA's main-loop FMA lanes == total intermediate products."""
    a = mats[2]
    tr = Trace()
    from repro.vm.schedule import trace_spa as ts

    ts(a, a, trace=tr)
    ops_total = ops_per_column(a, a).sum()
    fma = sum(c * vl for (k, vl, _), c in tr.counts.items() if k == "vfma")
    assert fma == ops_total


def test_spars_processes_blocks_of_equal_load(mats):
    """Uniform Z: every block runs exactly Z^2 steps at full occupancy."""
    a = mats[2]
    pre = preprocess(a, a, t=np.inf, b_min=40, b_max=40)
    tr = trace_spars(a, a, pre)
    assert tr.utilization > 0.99  # no masking when loads are equal


def test_machine_monotone_in_working_set():
    m = DEFAULT_MACHINE
    c_small = m.instr_cycles("vload_idx", 256, 16 << 10)
    c_large = m.instr_cycles("vload_idx", 256, 64 << 20)
    assert c_large > c_small
    assert m.instr_cycles("vload", 256, 0) < c_small


def test_machine_longer_vectors_amortize_issue():
    m = DEFAULT_MACHINE
    per_elem_short = m.instr_cycles("vfma", 8, 0) / 8
    per_elem_long = m.instr_cycles("vfma", 256, 0) / 256
    assert per_elem_long < per_elem_short


# --- the paper's headline qualitative claims, on synthetic matrices -------


def test_paper_claim_spars_wins_sparse_loses_dense(mats):
    """Fig 3: SPARS (b=40) beats SPA for Z=2, loses for Z=10."""
    m = DEFAULT_MACHINE
    for z, expect_faster in ((2, True), (10, False)):
        a = mats[z]
        cn = c_column_nnz(a, a)
        t_spa = m.seconds(trace_spa(a, a, c_nnz=cn))
        pre = preprocess(a, a, t=np.inf, b_min=40, b_max=40)
        t_spars = m.seconds(trace_spars(a, a, pre, c_nnz=cn))
        assert (t_spars < t_spa) == expect_faster, (z, t_spars, t_spa)


def test_paper_claim_spars_bmax_peak(mats):
    """Fig 3: SPARS degrades past b_max ~ 40 (accumulator leaves L2)."""
    a = mats[2]
    cn = c_column_nnz(a, a)
    m = DEFAULT_MACHINE

    def t(bmax):
        pre = preprocess(a, a, t=np.inf, b_min=bmax, b_max=bmax)
        return m.seconds(trace_spars(a, a, pre, c_nnz=cn))

    assert t(40) < t(8)     # longer vectors help at first
    assert t(40) < t(256)   # then the accumulator range penalty dominates


def test_paper_claim_hash_likes_large_blocks(mats):
    """Fig 4: HASH keeps improving to b_max = 256 (small tables stay local)."""
    a = mats[2]
    cn = c_column_nnz(a, a)
    m = DEFAULT_MACHINE

    def t(bmax):
        pre = preprocess(a, a, t=np.inf, b_min=bmax, b_max=bmax)
        return m.seconds(trace_hash(a, a, pre, c_nnz=cn))

    assert t(256) < t(40) < t(8)


def test_paper_claim_hybrid_never_much_worse_than_spa(mats):
    """Table 1: H-* saturates at ~1.0x for dense matrices (switches to SPA)."""
    a = mats[10]
    cn = c_column_nnz(a, a)
    m = DEFAULT_MACHINE
    t_spa = m.seconds(trace_spa(a, a, c_nnz=cn))
    pre = preprocess(a, a, t=40.0, b_min=256, b_max=256)
    t_h = m.seconds(trace_hybrid(a, a, pre, accumulator="hash", c_nnz=cn))
    assert t_h <= t_spa * 1.05


def test_calibrated_machine_loaded():
    assert DEFAULT_MACHINE.issue != Machine.__dataclass_fields__[
        "issue"].default or DEFAULT_MACHINE.beat_idx != 8.0

"""Graph analytics with SpGEMM (the paper's motivating domain): triangle
counting via A@A restricted to edges — triangles = trace-free sum of
(A@A) ⊙ A / 6 for an undirected simple graph.

    PYTHONPATH=src python examples/graph_triangles.py
"""

import numpy as np

from repro.core import spgemm
from repro.sparse.format import csc_from_dense, csc_to_dense


def random_graph(n=300, p=0.02, seed=0):
    rng = np.random.default_rng(seed)
    upper = np.triu(rng.uniform(size=(n, n)) < p, k=1)
    adj = (upper | upper.T).astype(np.float64)
    return adj


def main():
    adj = random_graph()
    a = csc_from_dense(adj)
    print(f"graph: {a.n_rows} nodes, {a.nnz // 2} edges")
    # exact reference
    ref = int(np.round(np.trace(adj @ adj @ adj) / 6))
    for method in ("spa", "h-spa-40/40", "h-hash-256/256"):
        c = spgemm(a, a, method=method)          # paths of length 2
        paths2 = csc_to_dense(c)
        tri = int(np.round((paths2 * adj).sum() / 6))
        status = "OK" if tri == ref else "MISMATCH"
        print(f"  {method:16s} triangles={tri} ({status})")
    print(f"reference (dense): {ref}")


if __name__ == "__main__":
    main()

"""Graph analytics with SpGEMM (the paper's motivating domain): triangle
counting via A@A restricted to edges — triangles = trace-free sum of
(A@A) ⊙ A / 6 for an undirected simple graph — plus the plan-reuse idiom
for repeated-pattern workloads (DESIGN.md §6): the adjacency *pattern* of a
graph is fixed while edge weights evolve, so the A·A pre-processing (sort,
block, hash-size, kernel layouts) is paid once and amortized across every
re-execution.

    PYTHONPATH=src python examples/graph_triangles.py
"""

import time

import numpy as np

from repro.core import plan_spgemm, spgemm
from repro.sparse.format import CSC, csc_from_dense, csc_to_dense


def random_graph(n=300, p=0.02, seed=0):
    rng = np.random.default_rng(seed)
    upper = np.triu(rng.uniform(size=(n, n)) < p, k=1)
    adj = (upper | upper.T).astype(np.float64)
    return adj


def count_triangles(adj):
    a = csc_from_dense(adj)
    print(f"graph: {a.n_rows} nodes, {a.nnz // 2} edges")
    # exact reference
    ref = int(np.round(np.trace(adj @ adj @ adj) / 6))
    for method in ("spa", "h-spa-40/40", "h-hash-256/256"):
        c = spgemm(a, a, method=method)          # paths of length 2
        paths2 = csc_to_dense(c)
        tri = int(np.round((paths2 * adj).sum() / 6))
        status = "OK" if tri == ref else "MISMATCH"
        print(f"  {method:16s} triangles={tri} ({status})")
    print(f"reference (dense): {ref}")
    return a


def weighted_walk_reuse(a, trials=5, method="spa"):
    """Re-execute A@A as edge weights change (same pattern every step).

    Typical of dynamic graph analytics: the topology is static, the weights
    (traffic, affinity, conductance) are updated each tick.  One symbolic
    plan serves all ticks, and the ticks themselves run as one *batched*
    numeric execution — all weight sets through a single plan traversal
    (``execute_batched``, DESIGN.md §7) instead of a per-tick Python loop.
    SPA's host accumulation is vectorized over the value axis, so the
    batched pass costs roughly one tick's structure walk for all ticks.
    """
    print(f"\nplan reuse: weighted 2-walks, {trials} weight updates, "
          f"method={method}")
    t0 = time.perf_counter()
    plan = plan_spgemm(a, a, method)      # symbolic: sort/block/size, once
    t_plan = time.perf_counter() - t0
    rng = np.random.default_rng(1)
    weights = rng.uniform(0.5, 1.5, size=(trials, a.nnz))  # one tick per row
    t0 = time.perf_counter()
    cs = plan.execute_batched(weights, weights)   # numeric only, one pass
    t_batch = time.perf_counter() - t0
    t_loop = 0.0
    for trial, w in enumerate(weights):
        aw = CSC(w, a.row_indices, a.col_ptr, a.shape)
        t0 = time.perf_counter()
        c = plan.execute(w, w)            # the old per-tick inner loop
        t_loop += time.perf_counter() - t0
        c_fresh = spgemm(aw, aw, method=method, cache=False)
        for other, label in ((c_fresh, "fresh call"),
                             (cs[trial], "batched execution")):
            same = (
                np.array_equal(np.asarray(c.col_ptr),
                               np.asarray(other.col_ptr))
                and np.array_equal(np.asarray(c.values)[: c.nnz],
                                   np.asarray(other.values)[: other.nnz])
            )
            assert same, f"trial {trial}: {label} diverged from execute()"
    print(f"  symbolic plan, paid once:     {t_plan*1e3:7.2f}ms")
    print(f"  looped execute, per tick:     {t_loop/trials*1e3:7.2f}ms")
    print(f"  batched execute, per tick:    {t_batch/trials*1e3:7.2f}ms "
          f"({t_loop/max(t_batch, 1e-9):.1f}x; matches the loop bit for bit)")
    print(f"  planning fresh each call would add {t_plan*(trials-1)*1e3:.2f}ms"
          f" over {trials} updates; see benchmarks/batched.py for batched"
          " throughput at scale")


def main():
    adj = random_graph()
    a = count_triangles(adj)
    weighted_walk_reuse(a)


if __name__ == "__main__":
    main()

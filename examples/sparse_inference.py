"""Serving with the paper's technique as a first-class feature: FFN weights
pruned to block-sparse and executed through the density-adaptive hybrid
policy (dense MXU path vs BSR Pallas kernel — DESIGN.md §3.1), plus batched
request serving through the continuous-batching engine.

    PYTHONPATH=src python examples/sparse_inference.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import init_model, smoke
from repro.models.layers import ffn
from repro.models.sparse_ffn import SparseFFN, SparseMatmul
from repro.serving import ServeEngine


def main():
    cfg = smoke(ARCHS["granite-20b"])
    params = init_model(cfg, jax.random.PRNGKey(0))
    ffn_params = jax.tree_util.tree_map(
        lambda l: l[0], params["blocks"]["l0"]["ffn"])  # layer-0 FFN

    print("=== density-adaptive policy (the paper's t-switch on TPU) ===")
    print(f"{'keep':>6s} {'path':>6s} {'flop savings':>13s} {'rel err':>9s}")
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    dense_y = ffn(ffn_params, x[None])[0]
    dense_flops = 3 * 2 * cfg.d_model * cfg.d_ff
    for keep in (0.9, 0.5, 0.25, 0.1):
        sp = SparseFFN.from_params(ffn_params, keep_density=keep,
                                   t_density=0.75)
        y = sp(x)
        # error vs the *pruned-dense* reference == kernel exactness; vs the
        # unpruned output it measures pruning loss
        rel = float(jnp.linalg.norm(y - dense_y) /
                    jnp.linalg.norm(dense_y))
        print(f"{keep:6.2f} {sp.gate.path:>6s} "
              f"{dense_flops / sp.flops_per_token:12.2f}x {rel:9.3f}")

    print("\n=== batched serving (continuous batching engine) ===")
    srv_cfg = smoke(ARCHS["qwen2-0.5b"])
    srv_params = init_model(srv_cfg, jax.random.PRNGKey(2))
    eng = ServeEngine(srv_cfg, srv_params, max_batch=3, cache_len=96)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, srv_cfg.vocab, size=5).tolist(),
                       max_new_tokens=8, temperature=0.0)
            for _ in range(6)]
    done = eng.run_to_completion()
    for rid in rids:
        print(f"  request {rid}: generated {done[rid].generated}")
    print(f"served {len(done)} requests on {eng.max_batch} slots")


if __name__ == "__main__":
    main()

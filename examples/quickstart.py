"""Quickstart: the paper's SpGEMM algorithms through the public API.

    PYTHONPATH=src python examples/quickstart.py

Generates a very sparse and a denser synthetic matrix, runs every algorithm
(host executors + the Pallas TPU kernels in interpret mode), checks them
against the dense oracle, and prints the calibrated vector-machine timing
model's view — the paper's headline effect (hybrids win on sparse inputs,
never lose on dense ones) in one screen.

Plan/execute idiom (DESIGN.md §6) — when the sparsity pattern repeats
(iterative A·A chains, static-weight serving), split the call:

    from repro.core import plan_spgemm
    plan = plan_spgemm(a, b, "h-hash-256/256")   # symbolic phase, once:
                                                 # sort, block, size H, layouts
    c1 = plan.execute(a_vals_1, b_vals_1)        # numeric phase per value set
    c2 = plan.execute(a_vals_2, b_vals_2)        # ... pre-processing amortized

``spgemm()`` does this transparently through a bounded LRU keyed on pattern
fingerprints — repeated same-pattern calls hit the cache — but holding the
plan explicitly skips even the fingerprint hash.  It pays off whenever one
pattern is multiplied more than once; see benchmarks/plan_reuse.py for the
measured overhead split.
"""

import numpy as np

from repro.core import plan_spgemm, preprocess, spgemm, spgemm_dense
from repro.sparse import random_uniform_csc
from repro.sparse.format import csc_equal
from repro.vm import (
    DEFAULT_MACHINE, c_column_nnz, trace_esc, trace_hash, trace_hybrid,
    trace_spa, trace_spars,
)

METHODS = ("spa", "spars-40/40", "hash-256/256", "h-spa-40/40",
           "h-hash-256/256", "esc")


def modeled_seconds(a, method):
    cn = c_column_nnz(a, a)
    if method == "spa":
        return DEFAULT_MACHINE.seconds(trace_spa(a, a, c_nnz=cn))
    if method == "esc":
        return DEFAULT_MACHINE.seconds(trace_esc(a, a))
    fam, bounds = method.rsplit("-", 1)
    b_min, b_max = (int(x) for x in bounds.split("/"))
    t = 40.0 if fam.startswith("h-") else np.inf
    pre = preprocess(a, a, t=t, b_min=b_min, b_max=b_max)
    if fam == "spars":
        return DEFAULT_MACHINE.seconds(trace_spars(a, a, pre, c_nnz=cn))
    if fam == "hash":
        return DEFAULT_MACHINE.seconds(trace_hash(a, a, pre, c_nnz=cn))
    acc = "hash" if "hash" in fam else "spa"
    return DEFAULT_MACHINE.seconds(
        trace_hybrid(a, a, pre, accumulator=acc, c_nnz=cn))


def plan_reuse_demo():
    """The plan/execute split on a repeated-pattern workload."""
    import time

    a = random_uniform_csc(640, 4, seed=1)
    t0 = time.perf_counter()
    plan = plan_spgemm(a, a, "h-hash-256/256")
    t_plan = time.perf_counter() - t0
    rng = np.random.default_rng(0)
    t_exec = 0.0
    reps = 3
    for _ in range(reps):  # same pattern, fresh values each round
        vals = rng.normal(size=a.nnz)
        t0 = time.perf_counter()
        plan.execute(vals, vals)
        t_exec += time.perf_counter() - t0
    print(f"\n=== plan reuse (A 640x640, h-hash-256/256) ===")
    print(f"symbolic plan (once):     {t_plan*1e3:7.2f}ms")
    print(f"numeric execute (/call):  {t_exec/reps*1e3:7.2f}ms "
          f"— pre-processing amortized over every same-pattern call")


def auto_method_demo():
    """method="auto": per-tile method selection on a mixed-density matrix
    (DESIGN.md §8–§9) — tiles whose product stream fits the plan-memory
    guard run the vectorized stream engine (expand); guard-tripped
    flop-heavy blocks fall back to SPA.  The guard is scaled to this demo's
    size (as benchmarks/tiled.py does) so both regimes show."""
    import time

    import repro.core.fast as fast
    from repro.core import plan_spgemm_tiled
    from repro.sparse.format import csc_from_dense

    rng = np.random.default_rng(0)
    m, heavy, dense_b, n = 192, 24, 48, 768
    old_guard = fast.STREAM_MAX_PRODUCTS
    fast.STREAM_MAX_PRODUCTS = (dense_b * 16 * m) // 8
    try:
        ad = np.zeros((m, m))
        ad[:, :heavy] = rng.uniform(0.5, 1.5, size=(m, heavy))  # heavy cols
        for j in range(heavy, m):
            ad[rng.integers(m, size=2), j] = 1.0
        bd = np.zeros((m, n))
        for j in range(dense_b):    # dense B block hits the heavy A columns
            bd[rng.integers(heavy, size=16), j] = 1.0
        for j in range(dense_b, n):  # long sparse tail hits the light ones
            bd[heavy + rng.integers(m - heavy, size=2), j] = 1.0
        a, b = csc_from_dense(ad), csc_from_dense(bd)
        print(f"\n=== method='auto' (mixed density: {dense_b} flop-heavy + "
              f"{n - dense_b} sparse columns) ===")
        rows = []
        for method in ("spa", "expand"):
            plan = plan_spgemm(a, b, method)
            plan.execute(a, b)   # warmup: lazy one-time plan state
            t0 = time.perf_counter()
            plan.execute(a, b)
            rows.append((method, time.perf_counter() - t0, ""))
        tiled = plan_spgemm_tiled(a, b, tile=(None, 96))
        stats = {}
        tiled.execute(a, b)      # warmup
        t0 = time.perf_counter()
        tiled.execute(a, b, stats=stats)
        rows.append(("auto", time.perf_counter() - t0,
                     f"per-tile: {stats['methods']}"))
        for name, t, note in rows:
            print(f"{name:8s} {t*1e3:8.2f}ms  {note}")
    finally:
        fast.STREAM_MAX_PRODUCTS = old_guard


def jax_stream_demo():
    """backend="jax" (DESIGN.md §10): the plan's product stream as a
    jitted, differentiable device function — SpGEMM inside jax.jit/grad."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import plan_spgemm

    a = random_uniform_csc(256, 6, seed=3)
    vals = np.asarray(a.values).astype(np.float32)
    plan = plan_spgemm(a, a, "expand", backend="jax")
    t0 = time.perf_counter()
    plan.execute(vals, vals).values.block_until_ready()
    t_warm = time.perf_counter() - t0          # plan + device stream + trace
    t0 = time.perf_counter()
    plan.execute(vals, vals).values.block_until_ready()
    t_steady = time.perf_counter() - t0        # cached-trace replay

    # gradients w.r.t. both operands' values are stream replays too
    loss = lambda x, y: jnp.sum(plan.stream_apply(x, y))
    ga, gb = jax.grad(loss, argnums=(0, 1))(jnp.asarray(vals),
                                            jnp.asarray(vals))
    print(f"\n=== backend='jax' (A 256x256, jitted device stream) ===")
    print(f"warmup (plan+trace):      {t_warm*1e3:7.2f}ms  (once)")
    print(f"steady state (/call):     {t_steady*1e3:7.2f}ms  "
          f"— one compiled dispatch, no per-group launches")
    print(f"grad(sum C) shapes:       dA {tuple(ga.shape)}, "
          f"dB {tuple(gb.shape)} — SpGEMM is differentiable in-trace")


def mesh_demo():
    """backend="mesh" (DESIGN.md §13): the multiply sharded over every
    visible device — per-device stream replay inside one shard_map, merged
    by a plan-static psum_scatter.  On a default CPU install this is a
    1-device mesh (same machinery, no communication); simulate a real one
    with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    import jax

    from repro.core import spgemm
    from repro.sparse.format import csc_equal

    d = len(jax.devices())
    a = random_uniform_csc(384, 5, seed=7)
    c = spgemm(a, a, "expand", backend="mesh", shards=d)
    ref = spgemm(a, a, "expand", backend="host", engine="stream")
    host_c = type(ref)(np.asarray(c.values), np.asarray(c.row_indices),
                       np.asarray(c.col_ptr), c.shape)
    ok = csc_equal(host_c, ref, rtol=1e-6)
    print(f"\n=== backend='mesh' (A 384x384 over {d} device(s)) ===")
    print(f"distributed == host stream:  {'OK' if ok else 'FAIL'} "
          f"(plan-static merge order — deterministic every run)")
    if d == 1:
        print("1-device mesh; rerun under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "to see an 8-shard placement")


def main():
    for z, label in ((2, "very sparse (Z=2 nnz/col)"),
                     (10, "denser (Z=10 nnz/col)")):
        a = random_uniform_csc(640, z, seed=z)
        ref = spgemm_dense(a, a)
        t_spa = modeled_seconds(a, "spa")
        print(f"\n=== {label}: C = A @ A, A is 640x640 ===")
        print(f"{'method':16s} {'host':>5s} {'pallas':>7s} "
              f"{'model-time':>11s} {'vs SPA':>7s}")
        for m in METHODS:
            c = spgemm(a, a, method=m)
            ok = csc_equal(c, ref, rtol=1e-9)
            ok_pl = "-"
            if m != "esc":  # pallas backend covers the accumulator family
                cp = spgemm(a, a, method=m, backend="pallas")
                ok_pl = "OK" if csc_equal(cp, ref, rtol=1e-4, atol=1e-5) \
                    else "FAIL"
            t = modeled_seconds(a, m)
            print(f"{m:16s} {'OK' if ok else 'FAIL':>5s} {ok_pl:>7s} "
                  f"{t*1e3:9.2f}ms {t_spa/t:6.2f}x")
    print("\n(model-time = calibrated 8-lane VL-256 vector machine; "
          "see EXPERIMENTS.md)")
    plan_reuse_demo()
    auto_method_demo()
    jax_stream_demo()
    mesh_demo()


if __name__ == "__main__":
    main()

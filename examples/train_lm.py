"""End-to-end training driver (E12): qwen2-family reduced model on the
synthetic learnable stream, with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py                 # ~15M, quick
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300

The 100m preset is the assignment's "~100M model for a few hundred steps";
on this 1-core CPU container expect minutes/step — the quick preset exercises
the identical code path at laptop scale. Checkpoints land in
.cache/train_lm/<size>; rerunning resumes automatically.
"""

import argparse
import dataclasses

import jax

from repro.configs import ARCHS
from repro.models import smoke
from repro.training import (
    AdamWConfig, DataConfig, SyntheticLoader, TrainConfig, Trainer,
    init_train_state,
)

PRESETS = {
    # name: (d_model, n_layers, n_heads, n_kv, d_head, d_ff, vocab, seq, batch)
    "15m": (256, 4, 4, 2, 64, 1024, 4096, 128, 8),
    "100m": (640, 10, 10, 2, 64, 2560, 16384, 256, 8),
}


def build_cfg(size: str):
    d, l, h, kv, dh, ff, v, seq, batch = PRESETS[size]
    base = smoke(ARCHS["qwen2-0.5b"])
    cfg = dataclasses.replace(
        base, n_layers=l, d_model=d, n_heads=h, n_kv_heads=kv, d_head=dh,
        d_ff=ff, vocab=v, attn_q_chunk=seq, attn_kv_chunk=seq,
        logits_chunk=min(seq, 128))
    return cfg, seq, batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=sorted(PRESETS), default="15m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg, seq, batch = build_cfg(args.size)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: __import__("repro.models", fromlist=["x"])
                       .init_model(cfg, jax.random.PRNGKey(0)))))
    print(f"model: {args.size} ({n_params/1e6:.1f}M params), "
          f"seq={seq} batch={batch}")

    tc = TrainConfig(
        total_steps=args.steps, peak_lr=args.lr, warmup_steps=args.steps // 10,
        checkpoint_dir=f".cache/train_lm/{args.size}", checkpoint_every=20,
        log_every=5, opt=AdamWConfig(quantize_moments=True))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                      seed=0, noise=0.05)
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    trainer = Trainer(cfg, tc, SyntheticLoader(dcfg), state)
    trainer.install_preemption_handler()
    trainer.try_resume()
    log = trainer.run()
    if log:
        first = sum(m["loss"] for m in log[:3]) / max(len(log[:3]), 1)
        last = sum(m["loss"] for m in log[-3:]) / max(len(log[-3:]), 1)
        print(f"\nloss {first:.3f} -> {last:.3f} over {len(log)} steps "
              f"({'DECREASED' if last < first else 'no decrease yet'})")
    trainer.checkpoint()
    print("checkpoint saved; rerun to resume")


if __name__ == "__main__":
    main()

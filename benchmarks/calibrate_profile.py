"""Measure this machine's cost-model profile and persist it (DESIGN.md §15).

Runs the synthetic microbenchmark ladder of ``repro.core.profile`` — host
SPA regimes, the plan-resident product stream, the guard-tripped transient
rebuild, the jitted device stream, the fused Pallas kernel, and (with >1
device) a real ``psum_scatter`` payload ladder — fits the
``CostConstants`` terms by weighted least squares, searches the structural
knobs (stream guard, fused block, auto tile targets), and writes one JSON
profile per machine fingerprint under ``REPRO_PROFILE_DIR`` (or ``--out``).

After this runs, every ``method="auto"`` consult on this machine ranks
engines on *measured* constants instead of the shipped defaults.  CI runs
``--smoke`` and uploads the profile as an artifact so the tiled
auto-vs-fixed gate (``benchmarks/tiled.py``) judges auto on a calibration
of the machine it actually runs on; re-run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N --sections comm`` to
refresh the mesh comm terms for a forced-device fingerprint (a separate
profile file — the fingerprint differs, by design).

Usage::

    PYTHONPATH=src python benchmarks/calibrate_profile.py [--smoke]
        [--out DIR] [--sections spa,stream,...] [--no-tune]
        [--reps N] [--seed N] [--report PATH]
"""

from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

from _util import write_report  # noqa: E402

from repro.core import profile  # noqa: E402


def _validate(prof) -> dict:
    """Predict-vs-measure cross-check: re-run a small probe ladder and
    report the Spearman rank correlation between the fitted model's
    predictions and fresh measurements (the schedtool-style closing of the
    loop — a profile that cannot rank its own ladder is not worth
    persisting silently)."""
    import numpy as np

    from repro.sparse.stats import tile_stats

    rng = np.random.default_rng(1)
    pred, meas = [], []
    ladder = profile._stream_ladder(0.25, rng)
    from repro.core.cost import estimate_cost

    for plan, a, b, flops in ladder:
        st = tile_stats(a, b)
        for method in ("spa", "expand", "jax"):
            pred.append(estimate_cost(st, method, constants=prof.constants))
            if method == "spa":
                from repro.core.naive import spa_numpy

                meas.append(profile._best_of(lambda: spa_numpy(a, b), 3))
            elif method == "expand":
                plan.execute(a, b, engine="stream")
                meas.append(profile._best_of(
                    lambda: plan.execute(a, b, engine="stream"), 3))
            else:
                from repro.core.planner import plan_spgemm

                jp = plan_spgemm(a, b, "expand", backend="jax",
                                 stream_limit=flops + 1)
                jp.execute(a, b).values.block_until_ready()
                meas.append(profile._best_of(
                    lambda: jp.execute(a, b).values.block_until_ready(), 3))
    rc = profile.rank_correlation(pred, meas)
    return {"spearman": rc, "points": len(pred)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small ladder (scale 0.25, 2 reps) for CI")
    ap.add_argument("--out", default=None,
                    help="profile directory (default REPRO_PROFILE_DIR "
                         "or the user cache)")
    ap.add_argument("--sections", default=None,
                    help="comma list of ladder sections to (re-)measure "
                         f"(default all: {','.join(profile.SECTIONS)})")
    ap.add_argument("--no-tune", action="store_true",
                    help="skip the structural-knob searches")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", default="BENCH_calibrate.json")
    args = ap.parse_args(argv)

    scale = 0.25 if args.smoke else 1.0
    reps = args.reps if args.reps else (2 if args.smoke else 3)
    sections = (profile.SECTIONS if args.sections is None
                else tuple(s for s in args.sections.split(",") if s))

    fp = profile.machine_fingerprint()
    print(f"fingerprint {profile.fingerprint_key(fp)}: {fp}")
    print(f"sections={','.join(sections)} scale={scale} reps={reps} "
          f"tune={not args.no_tune}")

    t0 = time.perf_counter()
    prof = profile.calibrate_profile(
        scale=scale, reps=reps, sections=sections, tune=not args.no_tune,
        seed=args.seed, save=True, directory=args.out)
    elapsed = time.perf_counter() - t0

    print(f"\ncalibrated in {elapsed:.1f}s -> {prof.path}")
    print(f"{'field':14s} {'fitted':>12s} {'default':>12s}")
    from repro.core.cost import DEFAULT_CONSTANTS

    for f in sorted(prof.fitted):
        print(f"{f:14s} {getattr(prof.constants, f):12.3e} "
              f"{getattr(DEFAULT_CONSTANTS, f):12.3e}")
    for k, v in sorted(prof.tuning.items()):
        print(f"tuning {k} = {v}")

    val = _validate(prof)
    print(f"\nvalidation: Spearman(pred, meas) = {val['spearman']:.3f} "
          f"over {val['points']} probe points")

    write_report(args.report, {
        "benchmark": "calibrate_profile",
        "elapsed_seconds": round(elapsed, 3),
        "sections": list(sections),
        "profile_path": prof.path,
        "fitted": list(prof.fitted),
        "constants": {f: getattr(prof.constants, f) for f in prof.fitted},
        "tuning": dict(prof.tuning),
        "validation": val,
    })
    return 0


if __name__ == "__main__":
    sys.exit(main())

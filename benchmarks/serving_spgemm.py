"""Serving benchmark: stream-backed SpGEMM under live traffic (DESIGN.md §12).

Part 1 — plan-cache regimes.  A request loop plays the serving tick's plan
protocol (``PlanBuilder.plan_or_fallback``: probe the locked LRU, enqueue a
background device build on a miss, run this request on the synchronous host
stream) against three pattern-reuse regimes:

  hit100   every request's device plan is resident — pure compiled replay.
  mixed    half the pattern pool is pre-warmed, half cold; background
           builds land mid-run and later requests promote to them.
  allmiss  adversarial: the pool is cycled round-robin through an LRU too
           small to hold it, so every probe misses and every insert evicts
           (plan churn).  The builder absorbs the builds (shedding excess
           under ``max_pending``) while every request rides the fallback.

Each regime reports ``ops_per_sec`` and ``p99_latency_us``.  PASS: the
all-miss p99 stays below the measured cost of ONE synchronous device-plan
warm (symbolic build + device lift + XLA compile) — the latency a tick
would pay if a cache miss blocked on its build, i.e. the bug this PR's
tentpole removes.

Part 2 — ServeEngine.  A smoke model with spgemm-overlaid FFNs served
under the async-warm protocol: ticks start on the eager host-stream
fallback, promote to the jitted sparse step when the background warm
lands; reports the tick split and per-phase tick latency.

Part 3 — resilience (DESIGN.md §14).  The all-miss churn regime replayed
twice: fault-free, then under an injected ``FaultPlan`` (10% of device
plan builds fail, 5% of builder tasks hang past the build deadline) with
the resilient builder config (shed-by-key-age backpressure, watchdog
deadline, retry/backoff).  PASS: every request is served (the foreground
fallback path never depends on a background build landing) and the
faulted p99 stays within 3x the fault-free p99.  Writes
BENCH_resilience.json with the fault config stamped into its env header.

    PYTHONPATH=src python benchmarks/serving_spgemm.py [--smoke]

Writes BENCH_serving.json and BENCH_resilience.json.
"""

from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from _util import write_report
from repro.core import PlanBuilder, api, cached_plan, faults, warm_plan
from repro.sparse import random_density_csc


def _pct_us(lats, q):
    return float(np.percentile(np.asarray(lats) * 1e6, q))


def measure_sync_warm(n, density, seed=10_000):
    """Cost of one blocking device-plan warm: the latency being hidden."""
    a = random_density_csc(n, n, density, seed=seed)
    b = random_density_csc(n, n, density, seed=seed + 1)
    api.plan_cache_clear()
    t0 = time.perf_counter()
    plan = cached_plan(a, b, "expand", backend="jax")
    warm_plan(plan)
    return time.perf_counter() - t0


def serve_request(builder, a, b):
    """One serving-style SpGEMM request; returns (seconds, status)."""
    t0 = time.perf_counter()
    plan, status = builder.plan_or_fallback(a, b, "expand", backend="jax")
    if status == "ready":
        out = plan.stream_apply(np.asarray(plan_values(a), np.float32),
                                np.asarray(plan_values(b), np.float32))
        out.block_until_ready()
    else:
        plan.execute(a, b, engine="stream")
    return time.perf_counter() - t0, status


def plan_values(mat):
    return np.asarray(mat.values, np.float32)


def run_regime(name, pool, requests, *, cache_size, prewarm, max_pending):
    """Replay ``requests`` (indices into ``pool``) under one reuse regime."""
    api.plan_cache_clear()
    api.plan_cache_resize(cache_size)
    for i in prewarm:
        a, b = pool[i]
        warm_plan(cached_plan(a, b, "expand", backend="jax"))
    lats, statuses = [], {"ready": 0, "fallback": 0}
    with PlanBuilder(max_pending=max_pending) as builder:
        t0 = time.perf_counter()
        for i in requests:
            a, b = pool[i]
            dt, status = serve_request(builder, a, b)
            lats.append(dt)
            statuses[status] += 1
        wall = time.perf_counter() - t0
        builder_stats = dict(builder.stats)
    info = api.plan_cache_info()
    row = {
        "regime": name,
        "requests": len(requests),
        "ops_per_sec": len(requests) / wall,
        "p50_latency_us": _pct_us(lats, 50),
        "p99_latency_us": _pct_us(lats, 99),
        "ready": statuses["ready"],
        "fallback": statuses["fallback"],
        "cache_evictions": info["evictions"],
        "builder": builder_stats,
    }
    print(f"{name:8s} {row['ops_per_sec']:10.1f} ops/s "
          f"p50 {row['p50_latency_us']:9.1f}us "
          f"p99 {row['p99_latency_us']:9.1f}us "
          f"ready {statuses['ready']:4d} fallback {statuses['fallback']:4d} "
          f"evict {info['evictions']:4d} shed {builder_stats['shed']:3d}")
    return row


def bench_regimes(n, density, reqs):
    default_size = api.plan_cache_info()["max_size"]
    pool = [(random_density_csc(n, n, density, seed=2 * i),
             random_density_csc(n, n, density, seed=2 * i + 1))
            for i in range(16)]
    print(f"plan-cache regimes: {n}x{n} patterns, density={density}, "
          f"{reqs} requests each")
    print(f"{'regime':8s} {'ops/s':>10s} {'p50':>12s} {'p99':>12s}")
    rows = [
        # 4 resident patterns, LRU comfortably larger: every probe hits.
        run_regime("hit100", pool, [i % 4 for i in range(reqs)],
                   cache_size=64, prewarm=range(4), max_pending=8),
        # 8-pattern pool, half pre-warmed; cold builds land mid-run.
        run_regime("mixed", pool, [i % 8 for i in range(reqs)],
                   cache_size=64, prewarm=range(4), max_pending=8),
        # 16-pattern pool cycled through an 8-entry LRU: pure churn.
        run_regime("allmiss", pool, [i % 16 for i in range(reqs)],
                   cache_size=8, prewarm=(), max_pending=4),
    ]
    api.plan_cache_resize(default_size)
    api.plan_cache_clear()
    return rows


# ---------------------------------------------------------------------------
# Part 2: ServeEngine under the async-warm protocol
# ---------------------------------------------------------------------------


def bench_engine(max_new_tokens):
    import jax

    from repro.configs import ARCHS
    from repro.models import init_model, smoke
    from repro.models.sparse_ffn import sparsify_ffn_params
    from repro.serving import ServeEngine

    cfg = smoke(ARCHS["qwen2-0.5b"])
    params = init_model(cfg, jax.random.PRNGKey(0))
    sparse_params, overlay = sparsify_ffn_params(cfg, params,
                                                 keep_density=0.5)
    fallback_lats, jit_lats = [], []
    with PlanBuilder() as builder:
        eng = ServeEngine(cfg, sparse_params, max_batch=2, cache_len=64,
                          sparse_ffn=overlay, plan_builder=builder)
        for p in ([1, 2, 3, 4], [5, 6, 7]):
            eng.submit(p, max_new_tokens=max_new_tokens)
        while eng.queue or any(eng.slots):
            ready = eng.sparse_ready()
            t0 = time.perf_counter()
            eng.step()
            (jit_lats if ready else fallback_lats).append(
                time.perf_counter() - t0)
        eng.wait_sparse(120)
    row = {
        "fallback_ticks": eng.tick_stats["fallback_ticks"],
        "jit_ticks": eng.tick_stats["jit_ticks"],
        "tokens": sum(len(r.generated) for r in eng.finished.values()),
    }
    if fallback_lats:
        row["fallback_p50_us"] = _pct_us(fallback_lats, 50)
    if jit_lats:
        # first jit tick can still include dispatch warmup; report both
        row["jit_p50_us"] = _pct_us(jit_lats, 50)
        row["jit_p99_us"] = _pct_us(jit_lats, 99)
    print(f"\nServeEngine (smoke qwen2, spgemm FFN overlay): "
          f"{row['fallback_ticks']} fallback ticks -> "
          f"{row['jit_ticks']} jit ticks, {row['tokens']} tokens")
    if fallback_lats and jit_lats:
        print(f"  tick p50: fallback {row['fallback_p50_us']:.0f}us, "
              f"jit {row['jit_p50_us']:.0f}us")
    return row


# ---------------------------------------------------------------------------
# Part 3: all-miss churn under injected faults (DESIGN.md §14)
# ---------------------------------------------------------------------------


def _churn_run(pool, requests, *, workers, max_pending, backpressure,
               build_deadline):
    """One all-miss replay; returns (latencies, unserved, builder stats).

    Requests are paced (2 ms apart, outside the timed window) so the
    background builder makes real progress during the replay — that is
    where the injected failures/hangs live — and the builder is drained
    before stats are read so failed/timed-out/recycled counters reflect
    every admitted build, not just the ones that finished mid-run.
    """
    api.plan_cache_clear()
    api.plan_cache_resize(8)
    lats, unserved = [], 0
    with PlanBuilder(workers=workers, max_pending=max_pending,
                     backpressure=backpressure,
                     build_deadline=build_deadline) as builder:
        for i in requests:
            a, b = pool[i]
            try:
                dt, _ = serve_request(builder, a, b)
                lats.append(dt)
            except Exception:
                unserved += 1
            time.sleep(0.002)
        builder.wait_idle(30)
        stats = dict(builder.stats)
    return lats, unserved, stats


def bench_resilience(n, density, reqs, reps=3):
    default_size = api.plan_cache_info()["max_size"]
    pool = [(random_density_csc(n, n, density, seed=2 * i),
             random_density_csc(n, n, density, seed=2 * i + 1))
            for i in range(16)]
    requests = [i % 16 for i in range(max(reqs, 96))]
    # deadline: ~6x one warm (so only injected hangs trip the watchdog,
    # not a slow-but-healthy compile), hangs injected well past it; two
    # workers so background build attempts — the fault sites — keep
    # flowing while the foreground replays
    cfg = dict(workers=2, max_pending=4, backpressure="shed-by-key-age",
               build_deadline=1.0)

    print("\nresilience: all-miss churn, fault-free vs injected faults "
          f"({reps} reps each, median p99)")
    clean_p99s, clean_served, clean_unserved = [], 0, 0
    for _ in range(reps):
        lats, unserved, clean_stats = _churn_run(pool, requests, **cfg)
        clean_p99s.append(_pct_us(lats, 99))
        clean_served += len(lats)
        clean_unserved += unserved

    rules = (faults.FaultRule("plan_spgemm", "fail", rate=0.10,
                              match="jax"),
             faults.FaultRule("builder_worker", "hang", rate=0.05,
                              seconds=2.0))
    with faults.inject(*rules, seed=2026) as fp:
        fault_p99s, served, fault_unserved = [], 0, 0
        fault_stats = {}
        for _ in range(reps):
            lats, unserved, stats = _churn_run(pool, requests, **cfg)
            fault_p99s.append(_pct_us(lats, 99))
            served += len(lats)
            fault_unserved += unserved
            for k, v in stats.items():
                if isinstance(v, (int, float)):
                    fault_stats[k] = fault_stats.get(k, 0) + v
        p99_clean = float(np.median(clean_p99s))
        p99_fault = float(np.median(fault_p99s))
        total = reps * len(requests)
        ok = (fault_unserved == 0 and served == total
              and p99_fault <= 3.0 * p99_clean)
        fired = {r["site"]: r["fires"]
                 for r in fp.describe()["rules"]}
        print(f"  clean  p99 {p99_clean:9.1f}us  served {clean_served:4d}"
              f"  builder {clean_stats['failed']} failed")
        print(f"  faults p99 {p99_fault:9.1f}us  served {served:4d}  "
              f"builder {fault_stats['failed']} failed "
              f"{fault_stats['timed_out']} timed-out "
              f"{fault_stats['workers_recycled']} recycled, "
              f"fires {fired}")
        print(f"  p99 ratio {p99_fault / max(p99_clean, 1e-9):.2f}x "
              f"(bound 3.00x), unserved {fault_unserved} -> "
              f"{'PASS' if ok else 'FAIL'}")
        # written inside the inject block so env_info() stamps the fault
        # config into the header — this report can never pass as clean
        write_report("BENCH_resilience.json", {
            "bench": "serving_resilience",
            "n": n,
            "density": density,
            "requests_per_rep": len(requests),
            "reps": reps,
            "clean": {"p99_latency_us": p99_clean,
                      "p99_per_rep_us": clean_p99s,
                      "served": clean_served,
                      "unserved": clean_unserved,
                      "builder": clean_stats},
            "faulted": {"p99_latency_us": p99_fault,
                        "p99_per_rep_us": fault_p99s,
                        "served": served,
                        "unserved": fault_unserved,
                        "builder": fault_stats},
            "p99_ratio": p99_fault / max(p99_clean, 1e-9),
            "pass": ok,
        })
    api.plan_cache_resize(default_size)
    api.plan_cache_clear()
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.05)
    ap.add_argument("--reqs", type=int, default=96,
                    help="requests per regime")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, short generations)")
    args = ap.parse_args()
    reqs = 32 if args.smoke else args.reqs

    sync_warm = measure_sync_warm(args.n, args.density)
    print(f"one synchronous device-plan warm (build + lift + compile): "
          f"{sync_warm * 1e3:.1f} ms\n")

    regimes = bench_regimes(args.n, args.density, reqs)
    engine = bench_engine(max_new_tokens=4 if args.smoke else 16)
    resilience_ok = bench_resilience(args.n, args.density, reqs)

    allmiss_p99 = next(r for r in regimes
                       if r["regime"] == "allmiss")["p99_latency_us"]
    ok = allmiss_p99 < sync_warm * 1e6 and resilience_ok
    print(f"\nall-miss p99 {allmiss_p99:.0f}us vs one blocking warm "
          f"{sync_warm * 1e6:.0f}us -> "
          f"{'PASS (ticks never block on plan builds)' if ok else 'FAIL'}")

    write_report("BENCH_serving.json", {
        "bench": "serving_spgemm",
        "n": args.n,
        "density": args.density,
        "sync_warm_us": sync_warm * 1e6,
        "regimes": regimes,
        "engine": engine,
        "pass": ok,
    })
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

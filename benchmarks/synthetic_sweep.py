"""E2/E3 — Figures 3 and 4: synthetic matrices (n=2560, Z nnz/col), execution
time of SPA vs SPARS (Fig 3) and SPA vs HASH (Fig 4) across b_max.

CSV: table,Z,b_max,algo,seconds.
"""

from __future__ import annotations

import numpy as np

from repro.core import preprocess
from repro.sparse import random_uniform_csc
from repro.vm import c_column_nnz, trace_hash, trace_spa, trace_spars
from repro.vm.machine import DEFAULT_MACHINE

N = 2560
ZS = (2, 4, 5, 6, 8, 10)
BMAXES = (8, 16, 24, 32, 40, 64, 96, 128, 192, 256)


def run(csv=True):
    mach = DEFAULT_MACHINE
    out = []
    for z in ZS:
        a = random_uniform_csc(N, z, seed=z)
        cn = c_column_nnz(a, a)
        t_spa = mach.seconds(trace_spa(a, a, c_nnz=cn))
        out.append(("fig3", z, 0, "spa", t_spa))
        out.append(("fig4", z, 0, "spa", t_spa))
        for bmax in BMAXES:
            pre = preprocess(a, a, t=np.inf, b_min=bmax, b_max=bmax)
            out.append(("fig3", z, bmax, "spars",
                        mach.seconds(trace_spars(a, a, pre, c_nnz=cn))))
            out.append(("fig4", z, bmax, "hash",
                        mach.seconds(trace_hash(a, a, pre, c_nnz=cn))))
    if csv:
        print("table,Z,b_max,algo,seconds")
        for r in out:
            print(f"{r[0]},{r[1]},{r[2]},{r[3]},{r[4]:.6g}")
        # headline crossovers (Section 5.2)
        for z in ZS:
            spa = next(r[4] for r in out if r[0] == "fig3" and r[1] == z
                       and r[3] == "spa")
            sp40 = next(r[4] for r in out if r[0] == "fig3" and r[1] == z
                        and r[2] == 40)
            h256 = next(r[4] for r in out if r[0] == "fig4" and r[1] == z
                        and r[2] == 256)
            print(f"fig34_summary,{z},,spars40_speedup,{spa/sp40:.3f}")
            print(f"fig34_summary,{z},,hash256_speedup,{spa/h256:.3f}")
    return out


if __name__ == "__main__":
    run()
